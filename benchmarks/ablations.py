"""Beyond-paper ablations of the scheduler (the paper leaves these open).

    PYTHONPATH=src python -m benchmarks.ablations

* λ sensitivity — the Eq. (2) normalization scale (DESIGN.md fidelity
  note): λ→0 recovers the paper's literal greedy collapse, λ→∞ decays
  nothing (probabilities stay uniform -> policies degrade toward RR).
* window-size sensitivity — §3.2's time-window length: bigger windows
  give MLML better pairing context but stale loads within the window.
* threshold sensitivity — §3.4.1's redirect guard on the Fig. 18
  straggler workload: too high re-admits stragglers.
* multi-client contention — private logs (no gossip) vs one shared log:
  quantifies the client-side blind spot.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.core import analysis, simulate
from repro.core.policies import PolicyConfig
from repro.core.simulate import SimConfig
from repro.core.statlog import LogConfig

BASE = SimConfig(n_servers=40, n_requests=600, n_trials=8,
                 straggler_frac=0.10, straggler_factor=5.0)
KEY = jax.random.key(0)


def lam_sensitivity():
    print("\n== λ (Eq. 2 normalization) sensitivity — TRH, stragglers ==")
    print(f"{'lam':>12s} {'cv':>8s} {'strag_hit%':>11s}")
    ref = simulate.default_log_cfg(BASE).lam
    for lam in (ref / 100, ref / 10, ref, ref * 10, ref * 100):
        log = LogConfig(n_servers=BASE.n_servers, lam=float(lam))
        res = simulate.run_trials(KEY, BASE,
                                  PolicyConfig(name="trh", threshold=5.0),
                                  log)
        cv = analysis.load_balance_stats(res.server_loads)["cv"]
        hit = analysis.straggler_summary(res)["hit_fraction"]
        tag = " (default)" if lam == ref else ""
        print(f"{lam:12.1f} {cv:8.4f} {100*hit:11.2f}{tag}")


def window_sensitivity():
    print("\n== time-window size sensitivity — MLML (pairing context) ==")
    print(f"{'window':>8s} {'cv_mlml':>9s} {'cv_trh':>8s}")
    for w in (10, 50, 100, 300):
        cfg = SimConfig(n_servers=BASE.n_servers,
                        n_requests=BASE.n_requests, n_trials=BASE.n_trials,
                        window_size=w)
        log = simulate.default_log_cfg(cfg)
        cvs = {}
        for pol in ("mlml", "trh"):
            res = simulate.run_trials(
                KEY, cfg, PolicyConfig(name=pol, threshold=5.0), log)
            cvs[pol] = analysis.load_balance_stats(res.server_loads)["cv"]
        print(f"{w:8d} {cvs['mlml']:9.4f} {cvs['trh']:8.4f}")


def threshold_sensitivity():
    print("\n== redirect-threshold sensitivity — TRH, Fig. 18 workload ==")
    print(f"{'threshold':>10s} {'strag_hit%':>11s} {'redirected':>10s}")
    log = simulate.default_log_cfg(BASE)
    mean_load = simulate.expected_server_load_mb(BASE)
    for thr in (0.0, 5.0, mean_load / 4, mean_load, 4 * mean_load):
        res = simulate.run_trials(KEY, BASE,
                                  PolicyConfig(name="trh",
                                               threshold=float(thr)), log)
        hit = analysis.straggler_summary(res)["hit_fraction"]
        red = float(np.asarray(res.redirected).mean())
        print(f"{thr:10.1f} {100*hit:11.2f} {red:10.1f}")


def contention():
    print("\n== shared log vs private per-client logs (no gossip) ==")
    print(f"{'model':>12s} {'clients':>8s} {'cv':>8s} {'strag_hit%':>11s}")
    for model, nc in (("shared_log", 1), ("per_client", 10),
                      ("per_client", 50)):
        cfg = SimConfig(n_servers=20, n_clients=nc, n_requests=400,
                        n_trials=6, client_model=model,
                        straggler_frac=0.10, straggler_factor=5.0)
        log = simulate.default_log_cfg(cfg)
        res = simulate.run_trials(KEY, cfg,
                                  PolicyConfig(name="trh", threshold=5.0),
                                  log)
        cv = analysis.load_balance_stats(res.server_loads)["cv"]
        hit = analysis.straggler_summary(res)["hit_fraction"]
        print(f"{model:>12s} {nc:8d} {cv:8.4f} {100*hit:11.2f}")


def run_all():
    lam_sensitivity()
    window_sensitivity()
    threshold_sensitivity()
    contention()


if __name__ == "__main__":
    run_all()
