"""Kernel micro-benchmarks: correctness deltas + CPU-interpret timings.

Wall-clock on CPU interpret mode is NOT a TPU performance signal — the
meaningful numbers here are (a) allclose deltas vs the oracles and (b) the
analytic FLOPs/bytes per call that the §Roofline discussion uses.  TPU
timings come from running the same entry points on real hardware.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.sched_select import (sched_select, sched_select_ref,
                                        sched_stream, sched_stream_ref)


def _time(fn, *args, reps=3, **kw):
    fn(*args, **kw)  # compile/warm
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps


def flash_cases():
    print("\n== flash_attention kernel (interpret-mode validation) ==")
    print(f"{'case':>38s} {'err':>10s} {'GFLOP':>8s} {'us/call':>9s}")
    for (b, s, h, kv, hd, win, ck) in [
        (1, 128, 4, 2, 64, None, None),
        (1, 256, 8, 2, 64, None, None),
        (1, 256, 8, 2, 64, 64, None),
        (1, 256, 8, 2, 64, None, 64),
    ]:
        keys = jax.random.split(jax.random.key(0), 3)
        q = jax.random.normal(keys[0], (b, s, h, hd), jnp.float32)
        k = jax.random.normal(keys[1], (b, s, kv, hd), jnp.float32)
        v = jax.random.normal(keys[2], (b, s, kv, hd), jnp.float32)
        out = flash_attention(q, k, v, window=win, chunk=ck,
                              block_q=64, block_k=64)
        ref = attention_ref(q, k, v, window=win, chunk=ck)
        err = float(jnp.max(jnp.abs(out - ref)))
        us = _time(flash_attention, q, k, v, window=win, chunk=ck,
                   block_q=64, block_k=64) * 1e6
        gflop = 4 * b * h * s * s * hd / 1e9  # qk + pv
        tag = f"B{b} S{s} H{h}/{kv} hd{hd} w={win} c={ck}"
        print(f"{tag:>38s} {err:10.2e} {gflop:8.3f} {us:9.0f}")
        assert err < 1e-4


def sched_cases():
    print("\n== sched_select kernel (VMEM-resident statistic log) ==")
    print(f"{'case':>30s} {'match':>6s} {'us/call':>9s} {'ns/req':>8s}")
    for (c, n, m, policy) in [(4, 256, 100, "minload"),
                              (4, 256, 100, "two_random"),
                              (16, 512, 256, "two_random")]:
        keys = jax.random.split(jax.random.key(1), 3)
        objs = jax.random.randint(keys[0], (c, n), 0, 10000, jnp.int32)
        lens = jax.random.uniform(keys[1], (c, n), minval=1.0, maxval=64.0)
        init = jax.random.uniform(keys[2], (c, m), maxval=50.0)
        seeds = jnp.arange(c, dtype=jnp.uint32)
        ch, fl = sched_select(objs, lens, init, seeds, n_servers=m,
                              threshold=4.0, policy=policy)
        m_pad = max(-(-m // 128) * 128, 128)
        rch, _ = sched_select_ref(objs[0], lens[0],
                                  jnp.pad(init[0], (0, m_pad - m)),
                                  seeds[0], n_servers=m, threshold=4.0,
                                  lam=32.0, policy=policy)
        match = bool((np.asarray(ch[0]) == np.asarray(rch)).all())
        us = _time(sched_select, objs, lens, init, seeds, n_servers=m,
                   threshold=4.0, policy=policy) * 1e6
        tag = f"C{c} N{n} M{m} {policy}"
        print(f"{tag:>30s} {'yes' if match else 'NO':>6s} {us:9.0f} "
              f"{us*1000/(c*n):8.1f}")
        assert match


def sched_stream_cases():
    """Temporal stream kernel: whole windowed trace (drain + completion
    feedback) as one pallas_call, vs the scan oracle."""
    print("\n== sched_stream kernel (temporal, packed (4,M) log in VMEM) ==")
    print(f"{'case':>34s} {'match':>6s} {'us/call':>9s} {'ns/req':>8s}")
    for (c, n_win, win, m, policy) in [(4, 8, 60, 100, "ect"),
                                       (4, 8, 60, 100, "trh"),
                                       (8, 4, 128, 256, "ect")]:
        n = n_win * win
        keys = jax.random.split(jax.random.key(2), 4)
        objs = jax.random.randint(keys[0], (c, n), 0, 10000, jnp.int32)
        lens = jax.random.uniform(keys[1], (c, n), minval=1.0, maxval=32.0)
        valid = jnp.ones((c, n), bool)
        rates = jax.random.uniform(keys[2], (c, n_win, m), minval=50.0,
                                   maxval=400.0)
        tables = jnp.stack([jnp.zeros((c, m)), jnp.full((c, m), 1.0 / m),
                            jnp.zeros((c, m)), jnp.ones((c, m))], axis=1)
        seeds = jnp.arange(c, dtype=jnp.uint32) * 31 + 5
        kw = dict(n_servers=m, window_size=win, threshold=1.0, lam=64.0,
                  window_dt=0.05, policy=policy, observe=True, renorm=True)
        ch, lat, tab, wl = sched_stream(objs, lens, valid, tables, seeds,
                                        rates, **kw)
        rch, _, rtab, _ = sched_stream_ref(objs[0], lens[0], valid[0],
                                           tables[0], seeds[0], rates[0],
                                           **kw)
        match = bool((np.asarray(ch[0]) == np.asarray(rch)).all()
                     and (np.asarray(tab[0]) == np.asarray(rtab)).all())
        us = _time(sched_stream, objs, lens, valid, tables, seeds, rates,
                   **kw) * 1e6
        tag = f"C{c} W{n_win}x{win} M{m} {policy}"
        print(f"{tag:>34s} {'yes' if match else 'NO':>6s} {us:9.0f} "
              f"{us * 1000 / (c * n):8.1f}")
        assert match


def run_all():
    flash_cases()
    sched_cases()
    sched_stream_cases()


if __name__ == "__main__":
    run_all()
