"""Paper §4 evaluation benchmarks — one function per table/figure.

Reproduces, at the paper's full scale (100 OSSs, 200 clients, 2,000
requests, 100 trials):

* Figs. 12-17 — per-OSS load distribution under RR / MLML / TRH / 1LTR /
  2LTR (CSV + ascii plot + balance stats table);
* Fig. 18     — straggler-injection experiment (10% of servers at 5x
  average load): max requests landed per load bucket, per policy;
* probe-message table — log-assisted policies vs the SC'14 two-choice
  baseline (§1/§5 claim: zero probes);
* nLTR n-sensitivity (n = 1, 2, 3) — §3.4.3 claim: n=2 suffices;
* I/O completion-time simulation on the queueing cluster (phase time with
  and without stragglers) — the end-metric the paper's balance serves.
"""

from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import analysis, simulate
from repro.core.engine import ClusterTrace
from repro.core.policies import PolicyConfig
from repro.core.simulate import ScenarioConfig, SimConfig
from repro.io import IOClient, IOClientConfig, SimulatedCluster

FULL = SimConfig()          # the paper's numbers: 100 OSS, 2000 reqs, 100 trials
QUICK = SimConfig(n_servers=50, n_requests=800, n_trials=12)


def _policies(threshold=5.0):
    return {
        "rr": PolicyConfig(name="rr"),
        "mlml": PolicyConfig(name="mlml", threshold=threshold),
        "trh": PolicyConfig(name="trh", threshold=threshold),
        "1ltr": PolicyConfig(name="nltr", threshold=threshold, nltr_n=1),
        "2ltr": PolicyConfig(name="nltr", threshold=threshold, nltr_n=2),
        "two_choice": PolicyConfig(name="two_choice", threshold=threshold),
    }


def figs_12_17(cfg: SimConfig = QUICK, plot: bool = True) -> Dict[str, dict]:
    """Headline summary per policy (Figs. 12-17 + latency promotion).

    The paper ranks by load balance; the quantity balance ultimately
    serves is request latency — so the summary LEADS with p99 latency
    and makespan (ROADMAP "latency metrics everywhere"), with the
    balance statistics as secondary columns.
    """
    log = simulate.default_log_cfg(cfg)
    key = jax.random.key(0)
    out = {}
    print("\n== Figs 12-17 headline: latency first, balance second "
          f"(M={cfg.n_servers}, R={cfg.n_requests}, T={cfg.n_trials}) ==")
    print(f"{'policy':>10s} {'p99_lat_s':>10s} {'makespan_s':>10s} "
          f"{'p50_lat_s':>10s} | {'cv':>7s} {'max':>10s} {'jain':>6s} "
          f"{'time_s':>7s}")
    for name, pol in _policies().items():
        t0 = time.time()
        res = simulate.run_trials(key, cfg, pol, log)
        jax.block_until_ready(res.server_loads)
        dt = time.time() - t0
        st = analysis.load_balance_stats(res.server_loads)
        ls = analysis.latency_stats(res.latencies)
        mk = analysis.makespan(res)
        out[name] = {"stats": st, "latency": ls, "makespan": mk,
                     "loads": analysis.mean_server_loads(res.server_loads)}
        print(f"{name:>10s} {ls['p99']:10.2f} {mk:10.2f} {ls['p50']:10.2f} "
              f"| {st['cv']:7.3f} {st['max']:10.1f} {st['jain']:6.3f} "
              f"{dt:7.2f}")
    if plot:
        for name in ("rr", "mlml", "trh"):
            print(analysis.ascii_plot(np.sort(out[name]["loads"]),
                                      label=f"Fig. sorted loads — {name}"))
    return out


def scenario_sweep_full(cfg: SimConfig = FULL) -> Dict[str, dict]:
    """ROADMAP item: the full-scale temporal scenario sweep — 100 OSS x
    2,000 requests x 100 trials per (scenario, policy) cell, all jitted.
    Ranks every sweep policy by p99 latency / makespan per scenario."""
    print(f"\n== FULL-scale scenario sweep (M={cfg.n_servers}, "
          f"R={cfg.n_requests}, T={cfg.n_trials}) ==")
    out = simulate.run_scenario_eval(seed=0, cfg=cfg)
    print(f"{'scenario':>16s} {'policy':>8s} {'p99_lat_s':>10s} "
          f"{'makespan_s':>10s} {'strag_hit%':>10s}")
    table: Dict[str, dict] = {}
    for scn, row in out.items():
        ranked = {}
        for pol, res in row.items():
            ls = analysis.latency_stats(res.latencies)
            ranked[pol] = {
                "p99": ls["p99"],
                "makespan": analysis.makespan(res),
                "hit": analysis.straggler_summary(res)["hit_fraction"],
            }
            print(f"{scn:>16s} {pol:>8s} {ranked[pol]['p99']:10.2f} "
                  f"{ranked[pol]['makespan']:10.2f} "
                  f"{100 * ranked[pol]['hit']:10.2f}")
        best = min(ranked, key=lambda p: ranked[p]["p99"])
        print(f"{'':>16s} best p99: {best}")
        table[scn] = ranked
    return table


def fig_18(cfg: SimConfig = None, plot: bool = True) -> Dict[str, dict]:
    """Straggler injection: 10% of OSSs at 5x average load (Fig. 18)."""
    cfg = cfg or SimConfig(n_servers=QUICK.n_servers,
                           n_requests=QUICK.n_requests,
                           n_trials=QUICK.n_trials,
                           straggler_frac=0.10, straggler_factor=5.0)
    log = simulate.default_log_cfg(cfg)
    key = jax.random.key(0)
    out = {}
    print("\n== Fig 18: straggler avoidance (10% stragglers @5x) ==")
    print(f"{'policy':>10s} {'strag_hit%':>10s} {'bytes->strag':>13s} "
          f"{'max_load':>10s} {'probes/req':>10s}")
    for name, pol in _policies().items():
        res = simulate.run_trials(key, cfg, pol, log)
        ss = analysis.straggler_summary(res)
        probes = float(np.asarray(res.probe_msgs).mean()) / cfg.n_requests
        out[name] = ss
        print(f"{name:>10s} {100*ss['hit_fraction']:10.2f} "
              f"{ss['mean_bytes_added_to_stragglers_mb']:13.1f} "
              f"{ss['max_load']:10.1f} {probes:10.2f}")
        xs, ys = analysis.fig18_curve(res.server_loads, res.n_assigned, 24)
        out[name]["curve"] = (xs, ys)
    if plot:
        for name in ("rr", "trh"):
            xs, ys = out[name]["curve"]
            print(analysis.ascii_plot(ys,
                                      label=f"Fig18 max-reqs vs load — {name}"))
    return out


def table_probe_overhead(cfg: SimConfig = QUICK) -> Dict[str, float]:
    """Probe messages per request (the cost the client-side log removes)."""
    log = simulate.default_log_cfg(cfg)
    out = simulate.run_paper_eval(
        seed=0, cfg=cfg,
        policy_names=("rr", "mlml", "trh", "nltr", "two_choice"))
    probes = analysis.probe_overhead(out, cfg.n_requests)
    print("\n== Probe-message overhead (per request) ==")
    for k, v in probes.items():
        print(f"{k:>10s} {v:8.3f}")
    return probes


def nltr_sensitivity(cfg: SimConfig = QUICK) -> Dict[int, float]:
    """nLTR n = 1, 2, 3 (§3.4.3: n=2 suffices; n=3 adds only overhead)."""
    log = simulate.default_log_cfg(cfg)
    key = jax.random.key(0)
    print("\n== nLTR n-sensitivity ==")
    out = {}
    for n in (1, 2, 3):
        t0 = time.time()
        res = simulate.run_trials(
            key, cfg, PolicyConfig(name="nltr", threshold=5.0, nltr_n=n),
            log)
        jax.block_until_ready(res.server_loads)
        cv = analysis.load_balance_stats(res.server_loads)["cv"]
        out[n] = cv
        print(f"  n={n} (K={2**n:2d}): cv={cv:.4f}  "
              f"wall={time.time()-t0:.2f}s")
    return out


def completion_time(n_servers: int = 24, n_files: int = 120,
                    file_mb: float = 16.0) -> Dict[str, float]:
    """End metric: synchronous I/O phase time on the queueing cluster with
    one slow-rate straggler + one pre-loaded server."""
    print("\n== Simulated I/O phase completion time (s) ==")
    out = {}
    for name in ("rr", "mlml", "trh", "nltr", "ect", "two_choice"):
        sim = SimulatedCluster(n_servers, base_rate_mb_s=200.0, seed=3)
        sim.make_straggler(1, 8.0)
        sim.add_external_load(1, 800.0)
        sim.add_external_load(5, 400.0)
        cli = IOClient(sim, IOClientConfig(
            policy=PolicyConfig(name=name, threshold=4.0)))
        for s in range(n_servers):  # client knows current queue depths
            cli.log.loads[s] = sim.queued_mb(s)
        for f in range(n_files):
            cli.write_file(f, size_mb=file_mb)
        phase = cli.flush()
        out[name] = phase
        print(f"{name:>10s} {phase:8.2f}s  straggler_hits="
              f"{sim.servers[1].n_requests:3d} probes={cli.probe_messages}")
    return out


def fig_temporal(n_trials: int = 12) -> Dict[str, dict]:
    """Beyond-paper temporal figure: time-varying stragglers.

    Left: jitted scenario sweep — straggler-hit fraction per window under
    the transient trace (does the policy track onset and recovery?) plus
    p99/makespan slowdown vs RR.  Right: the SAME ClusterTrace driven
    through the host-path queueing cluster (``SimulatedCluster(trace=)``)
    for one policy, cross-checking the two substrates.
    """
    cfg = SimConfig(n_servers=24, n_requests=480, n_trials=n_trials,
                    window_size=60,
                    scenario=ScenarioConfig(name="transient"))
    out = simulate.run_scenario_eval(
        seed=0, cfg=cfg, scenario_names=("transient",),
        policy_names=("rr", "trh", "ect"))["transient"]
    print("\n== Temporal (transient stragglers): hit-rate over time ==")
    for pol, res in out.items():
        hits = analysis.straggler_hits_over_time(
            res.chosen, res.straggler_mask, cfg.window_size)
        curve = " ".join(f"{100 * h:5.1f}" for h in hits)
        print(f"{pol:>6s} hit% per window: {curve}")
    slow = analysis.slowdown_vs_baseline(out, baseline="rr")
    print(f"{'policy':>8s} {'p99 vs rr':>10s} {'makespan vs rr':>15s}")
    for pol, s in slow.items():
        print(f"{pol:>8s} {s['p99_vs_rr']:10.2f} {s['makespan_vs_rr']:15.2f}")

    # host path on the same kind of trace: 2 servers flap slow mid-run
    m, base = 12, 200.0
    slow_row = np.full(m, base)
    slow_row[[1, 5]] = base / 8.0
    trace = ClusterTrace(times=jnp.asarray([0.0, 2.0, 6.0], jnp.float32),
                         rates=jnp.asarray(
                             np.stack([np.full(m, base), slow_row,
                                       np.full(m, base)]), jnp.float32))
    host = {}
    for pol, thr in (("rr", 0.0), ("trh", 4.0), ("ect", 0.05)):
        sim = SimulatedCluster(m, base_rate_mb_s=base, seed=3, trace=trace)
        cli = IOClient(sim, IOClientConfig(
            policy=PolicyConfig(name=pol, threshold=thr)))
        for f in range(48):
            cli.write_file(f, size_mb=16.0)
            sim.advance_time(0.25)          # writes spread over the trace
            for s in range(m):
                cli.log.loads[s] = sim.queued_mb(s)
        cli.flush()
        st = cli.stats()
        host[pol] = {"p99_write_s": st["p99_write_s"],
                     "done_at_s": sim.clock}
    print("host path, same transient trace: "
          + "  ".join(f"{p}: p99={h['p99_write_s']:.2f}s "
                      f"done@{h['done_at_s']:.1f}s" for p, h in host.items()))
    return {"sweep": out, "host": host}


def run_all(full: bool = False):
    cfg = FULL if full else QUICK
    figs_12_17(cfg)
    fig_18(SimConfig(n_servers=cfg.n_servers, n_requests=cfg.n_requests,
                     n_trials=cfg.n_trials, straggler_frac=0.10,
                     straggler_factor=5.0))
    table_probe_overhead(cfg)
    nltr_sensitivity(cfg)
    completion_time()
    fig_temporal()
    if full:
        # the paper-scale temporal sweep rides only on --full (it is the
        # single most expensive section: 5 scenarios x 5 policies x 100
        # jitted trials at 100 OSS / 2,000 requests)
        scenario_sweep_full()


if __name__ == "__main__":
    import sys
    run_all(full="--full" in sys.argv)
