"""§Roofline reporting: format the dry-run artifacts into the 3-term table.

Reads ``dryrun_results.json`` (produced by ``repro.launch.dryrun``) and
prints, per (arch x shape) on the single-pod mesh:

    compute_s    = HLO_FLOPs / peak_FLOP/s          (per device)
    memory_s     = HLO_bytes / HBM_bw               (per device)
    collective_s = wire_bytes / ICI_bw              (per device)

plus the dominant term, MODEL_FLOPS/HLO_FLOPs (useful-compute ratio,
catches remat/redundancy waste), the roofline fraction
(model-compute-time / dominant-term), and a one-line "what would move the
dominant term" note.
"""

from __future__ import annotations

import json
import os
import sys
from typing import Dict, List, Optional


def _advice(rec: Dict) -> str:
    dom = rec["roofline"]["dominant"]
    shape = rec["shape"]
    arch = rec["arch"]
    if dom == "memory_s":
        if shape.startswith("train") or shape.startswith("prefill"):
            return ("materialized attention scores / remat traffic -> "
                    "Pallas flash kernel (VMEM-resident) + lighter remat")
        return "KV-cache streaming dominates -> bigger batch per chip, " \
               "quantized (int8) cache"
    if dom == "collective_s":
        if shape.startswith("decode"):
            return ("TP all-reduces per token dominate -> gather-weights "
                    "FSDP, overlap collectives, or shift TP->DP for decode")
        return ("grad/TP collectives -> force weight all-gather (ZeRO-3 "
                "style) instead of activation psum; int8 grad compression "
                "on the pod axis")
    return "MXU-bound: increase per-chip batch or enable bf16 everywhere"


def load(path: str = "dryrun_results.json") -> List[Dict]:
    with open(path) as f:
        return json.load(f)


def table(path: str = "dryrun_results.json", mesh: str = "16x16",
          tag: str = "") -> None:
    rows = [r for r in load(path)
            if r["mesh"] == mesh and r.get("tag", "") == tag]
    print(f"\n== §Roofline — mesh {mesh} (per-device terms, seconds) ==")
    hdr = (f"{'arch':>22s} {'shape':>11s} {'compute':>9s} {'memory':>9s} "
           f"{'coll':>9s} {'dom':>6s} {'MF/HLO':>7s} {'RLfrac':>7s} "
           f"{'fits16G':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if r["status"] == "skip":
            print(f"{r['arch']:>22s} {r['shape']:>11s} "
                  f"{'— skipped: ' + r['reason'][:60]}")
            continue
        if r["status"] != "ok":
            print(f"{r['arch']:>22s} {r['shape']:>11s} ERROR {r['error'][:60]}")
            continue
        rf = r["roofline"]
        dom = rf["dominant"].replace("_s", "")
        print(f"{r['arch']:>22s} {r['shape']:>11s} "
              f"{rf['compute_s']:9.4f} {rf['memory_s']:9.4f} "
              f"{rf['collective_s']:9.4f} {dom:>6s} "
              f"{rf['useful_flops_ratio']:7.3f} "
              f"{rf['roofline_frac']:7.4f} "
              f"{'yes' if r['memory']['fits_16gb'] else 'NO':>7s}")
    print("\n-- bottleneck notes --")
    for r in rows:
        if r["status"] == "ok":
            print(f"  {r['arch']} x {r['shape']}: {_advice(r)}")


def summary(path: str = "dryrun_results.json") -> None:
    rows = load(path)
    ok = [r for r in rows if r["status"] == "ok"]
    err = [r for r in rows if r["status"] == "error"]
    skip = [r for r in rows if r["status"] == "skip"]
    print(f"\n== Dry-run summary: {len(ok)} ok / {len(skip)} skip / "
          f"{len(err)} error over {len(rows)} cells ==")
    by_mesh: Dict[str, int] = {}
    for r in ok:
        by_mesh[r["mesh"]] = by_mesh.get(r["mesh"], 0) + 1
    for m, n in sorted(by_mesh.items()):
        print(f"  mesh {m}: {n} cells compiled")
    fits = sum(1 for r in ok if r["memory"]["fits_16gb"])
    print(f"  {fits}/{len(ok)} compiled cells fit 16 GB/chip")
    for r in err:
        print(f"  ERROR {r['arch']} x {r['shape']} x {r['mesh']}: "
              f"{r['error'][:100]}")


def hillclimb_candidates(path: str = "dryrun_results.json") -> None:
    """Pick the three §Perf cells: worst roofline fraction, most
    collective-bound, most representative of the paper's technique."""
    rows = [r for r in load(path) if r["status"] == "ok"
            and r["mesh"] == "16x16" and not r.get("tag")]
    if not rows:
        return
    # "worst fraction" among train/prefill cells — B=1 decode cells have
    # intrinsically ~0 model-FLOP fractions and would always win vacuously
    compute_rows = [r for r in rows
                    if r["shape"] in ("train_4k", "prefill_32k")] or rows
    worst = min(compute_rows, key=lambda r: r["roofline"]["roofline_frac"])
    coll = max(rows, key=lambda r: (r["roofline"]["collective_s"]
                                    / max(sum((r["roofline"]["compute_s"],
                                               r["roofline"]["memory_s"],
                                               r["roofline"]["collective_s"])),
                                          1e-12)))
    print("\n== §Perf hillclimb candidates ==")
    print(f"  worst roofline fraction : {worst['arch']} x {worst['shape']} "
          f"({worst['roofline']['roofline_frac']:.4f})")
    print(f"  most collective-bound   : {coll['arch']} x {coll['shape']} "
          f"(coll {coll['roofline']['collective_s']:.3f}s)")
    print("  paper-representative    : checkpoint-write path (scheduler) — "
          "see benchmarks/paper_figs.completion_time")


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    if not os.path.exists(path):
        print(f"[roofline] {path} not found — run "
              "`python -m repro.launch.dryrun` first")
        return
    summary(path)
    table(path, "16x16")
    hillclimb_candidates(path)


if __name__ == "__main__":
    main()
