"""Benchmark driver: one section per paper table/figure + kernels + roofline.

    PYTHONPATH=src python -m benchmarks.run [--full] [--trajectory]

``--full`` runs the paper's exact scale (100 OSS / 2,000 requests / 100
trials) and adds the full-scale temporal scenario sweep; the default is a
faster configuration with identical structure.  ``--trajectory`` skips
the benchmarks and renders the BENCH_sched.json history instead: the
phase-time/p99 delta table, the scheduling-throughput table
(``engine_req_s`` / ``kernel_req_s`` / ``kernel_batch_req_s`` /
the sort-policy pairs ``kernel_batch_req_s_{mlml,nltr}`` vs their
same-policy engine twins ``engine_req_s_{mlml,nltr}`` /
``sharded_req_s_{d}d`` / the §14 batched-pipeline e2e pairs
``e2e_req_s_{kernel,jax}`` vs their same-backend sequential
(lax.map-halo) twins ``e2e_seq_req_s_{kernel,jax}``, flagging runs
where a kernel path fell behind its engine twin or a batched e2e fell
behind its sequential twin) and a two-panel figure.  Each point also
records the prep/sched/post stage wall times
(``prep_s``/``sched_s_{kernel,jax}``/``post_s``) of the batched trial
pipeline at the 64-client short-stream instance.  BENCH_sched.json is the
IN-REPO file at the repo root (``sched_perf.BENCH_PATH``), one point
per git sha (each point stamps ``git_dirty``) — re-running on the same
commit replaces the point.  The roofline section formats whatever
``dryrun_results.json`` the dry-run has produced so far.
"""

from __future__ import annotations

import json
import os
import sys
import time

# Kernel throughput series gated by --check-regression (req/s — higher
# is better).  Sharded series join dynamically: their keys carry the
# device count (sharded_req_s_{d}d), so matching keys across two points
# automatically compares same-device-count runs only.
REGRESSION_KEYS = (
    "kernel_req_s", "kernel_batch_req_s",
    "kernel_batch_req_s_mlml", "kernel_batch_req_s_nltr",
    "kernel_batch_req_s_per_client", "e2e_req_s_kernel",
    "tuned_kernel_req_s", "tuned_kernel_req_s_mlml",
    "tuned_kernel_req_s_nltr", "tuned_kernel_req_s_per_client_4c",
)


def check_regression(path: str | None = None,
                     tolerance: float = 0.3) -> int:
    """Gate the LATEST bench point against the most recent earlier CLEAN
    point (``git_dirty`` stamped false): exit nonzero when any kernel
    throughput series fell more than ``tolerance`` below the baseline.

    Dirty-tree points never serve as the baseline — their numbers were
    measured on uncommitted code.  With fewer than two comparable points
    the gate passes trivially (a fresh fork has no history to regress
    against)."""
    from benchmarks import sched_perf
    path = path or sched_perf.BENCH_PATH
    if not os.path.exists(path):
        print(f"[check-regression] {path} not found — pass (no history)")
        return 0
    try:
        with open(path) as f:
            history = json.load(f)
    except (json.JSONDecodeError, OSError) as e:
        print(f"[check-regression] {path} unreadable ({e}) — pass")
        return 0
    if not isinstance(history, list):
        history = [history]
    history = [pt for pt in history if isinstance(pt, dict)]
    if len(history) < 2:
        print(f"[check-regression] {len(history)} point(s) — pass "
              "(need a baseline and a candidate)")
        return 0
    latest = history[-1]
    base = next((pt for pt in reversed(history[:-1])
                 if pt.get("git_dirty") is False), None)
    if base is None:
        print("[check-regression] no earlier clean (git_dirty=false) "
              "baseline point — pass")
        return 0
    keys = [k for k in REGRESSION_KEYS
            if isinstance(latest.get(k), (int, float))
            and isinstance(base.get(k), (int, float))]
    keys += sorted(k for k in latest
                   if k.startswith("sharded_req_s_")
                   and isinstance(latest.get(k), (int, float))
                   and isinstance(base.get(k), (int, float)))
    if not keys:
        print("[check-regression] no comparable throughput series — pass")
        return 0
    b_sha = str(base.get("git_sha", "?"))[:12]
    l_sha = str(latest.get("git_sha", "?"))[:12]
    print(f"[check-regression] latest ({l_sha}) vs clean baseline "
          f"({b_sha}), tolerance {tolerance:.0%}")
    print(f"{'series':>36s} {'baseline':>12s} {'latest':>12s} "
          f"{'ratio':>7s}")
    failures = []
    for k in keys:
        ratio = latest[k] / max(base[k], 1e-12)
        flag = "" if ratio >= 1.0 - tolerance else "  <-- REGRESSED"
        print(f"{k:>36s} {base[k]:12.0f} {latest[k]:12.0f} "
              f"{ratio:7.2f}{flag}")
        if flag:
            failures.append(k)
    if failures:
        print(f"[check-regression] FAIL: {len(failures)} series past "
              f"tolerance: {', '.join(failures)}")
        return 1
    print(f"[check-regression] ok ({len(keys)} series)")
    return 0


def main() -> None:
    if "--check-regression" in sys.argv:
        sys.exit(check_regression())
    if "--trajectory" in sys.argv:
        from benchmarks import sched_perf
        sched_perf.trajectory(sched_perf.BENCH_PATH)
        return
    full = "--full" in sys.argv
    t0 = time.time()
    print("=" * 72)
    print("repro benchmarks — log-assisted straggler-aware I/O scheduling")
    print("=" * 72)

    from benchmarks import paper_figs
    paper_figs.run_all(full=full)

    from benchmarks import sched_perf
    sched_perf.run_all()
    # one perf-trajectory point per run, appended to the IN-REPO
    # BENCH_sched.json (repo-root anchored, deduped by git sha — a
    # re-run on the same commit replaces that commit's point)
    sched_perf.emit_bench_point(sched_perf.BENCH_PATH)

    from benchmarks import kernels_bench
    kernels_bench.run_all()

    from benchmarks import roofline
    import os
    path = "dryrun_results.json"
    if os.path.exists(path):
        roofline.summary(path)
        roofline.table(path, "16x16")
        roofline.hillclimb_candidates(path)
    else:
        print("\n[roofline] dryrun_results.json not found — skip "
              "(run python -m repro.launch.dryrun)")

    print(f"\n[benchmarks] total wall time {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
