"""Benchmark driver: one section per paper table/figure + kernels + roofline.

    PYTHONPATH=src python -m benchmarks.run [--full] [--trajectory]

``--full`` runs the paper's exact scale (100 OSS / 2,000 requests / 100
trials) and adds the full-scale temporal scenario sweep; the default is a
faster configuration with identical structure.  ``--trajectory`` skips
the benchmarks and renders the BENCH_sched.json history instead: the
phase-time/p99 delta table, the scheduling-throughput table
(``engine_req_s`` / ``kernel_req_s`` / ``kernel_batch_req_s`` /
the sort-policy pairs ``kernel_batch_req_s_{mlml,nltr}`` vs their
same-policy engine twins ``engine_req_s_{mlml,nltr}`` /
``sharded_req_s_{d}d`` / the §14 batched-pipeline e2e pairs
``e2e_req_s_{kernel,jax}`` vs their same-backend sequential
(lax.map-halo) twins ``e2e_seq_req_s_{kernel,jax}``, flagging runs
where a kernel path fell behind its engine twin or a batched e2e fell
behind its sequential twin) and a two-panel figure.  Each point also
records the prep/sched/post stage wall times
(``prep_s``/``sched_s_{kernel,jax}``/``post_s``) of the batched trial
pipeline at the 64-client short-stream instance.  BENCH_sched.json is the
IN-REPO file at the repo root (``sched_perf.BENCH_PATH``), one point
per git sha (each point stamps ``git_dirty``) — re-running on the same
commit replaces the point.  The roofline section formats whatever
``dryrun_results.json`` the dry-run has produced so far.
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    if "--trajectory" in sys.argv:
        from benchmarks import sched_perf
        sched_perf.trajectory(sched_perf.BENCH_PATH)
        return
    full = "--full" in sys.argv
    t0 = time.time()
    print("=" * 72)
    print("repro benchmarks — log-assisted straggler-aware I/O scheduling")
    print("=" * 72)

    from benchmarks import paper_figs
    paper_figs.run_all(full=full)

    from benchmarks import sched_perf
    sched_perf.run_all()
    # one perf-trajectory point per run, appended to the IN-REPO
    # BENCH_sched.json (repo-root anchored, deduped by git sha — a
    # re-run on the same commit replaces that commit's point)
    sched_perf.emit_bench_point(sched_perf.BENCH_PATH)

    from benchmarks import kernels_bench
    kernels_bench.run_all()

    from benchmarks import roofline
    import os
    path = "dryrun_results.json"
    if os.path.exists(path):
        roofline.summary(path)
        roofline.table(path, "16x16")
        roofline.hillclimb_candidates(path)
    else:
        print("\n[roofline] dryrun_results.json not found — skip "
              "(run python -m repro.launch.dryrun)")

    print(f"\n[benchmarks] total wall time {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
