"""§Perf hillclimb C — the paper's own technique: I/O phase completion time.

The metric is the *synchronous I/O phase time* on the queueing cluster
(the quantity the paper's load balance ultimately serves, Fig. 1): 24
servers at 200 MB/s, one slow-rate straggler (8x) with 800 MB of foreign
queue, one half-loaded server; 120 files x 16 MB written through the
client.  Each iteration follows hypothesis -> change -> measure; results
are recorded in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

from typing import Dict, Optional

from repro.core.policies import PolicyConfig
from repro.io import IOClient, IOClientConfig, SimulatedCluster
from repro.io.striping import MB


def phase_time(policy: str, threshold: float = 4.0,
               stripe_mb: float = 4.0, n_files: int = 120,
               file_mb: float = 16.0, lam: float = 32.0,
               know_loads: bool = True, warm_probs: bool = False,
               refresh: bool = False, seed: int = 3) -> Dict[str, float]:
    sim = SimulatedCluster(24, base_rate_mb_s=200.0, seed=seed)
    sim.make_straggler(1, 8.0)
    sim.add_external_load(1, 800.0)
    sim.add_external_load(5, 400.0)
    cli = IOClient(sim, IOClientConfig(
        policy=PolicyConfig(name=policy, threshold=threshold),
        stripe_size=int(stripe_mb * MB), lam_mb=lam,
        refresh_probs=refresh))
    if know_loads:
        for s in range(sim.n_servers):
            cli.log.loads[s] = sim.queued_mb(s)
        if warm_probs:
            cli.log.absorb_loads()  # p_i ∝ e^{-l_i/λ}: sorts become load-aware
    for f in range(n_files):
        cli.write_file(f, size_mb=file_mb)
    t = cli.flush()
    return {"phase_s": t,
            "straggler_hits": sim.servers[1].n_requests,
            "probes": cli.probe_messages,
            "redirect_entries": sum(len(r) for r in sim.redirects)}


def ideal_phase_time() -> float:
    """Roofline for this workload: total bytes spread over the 22 clean
    servers (a perfect scheduler avoids both the straggler's 32 s foreign
    queue and server 5's 2 s queue)."""
    total_mb = 120 * 16.0
    return total_mb / (22 * 200.0)


def run_all() -> None:
    print("\n== §Perf C: scheduler hillclimb (phase completion time) ==")
    print(f"  ideal (napkin) phase time ~ {ideal_phase_time():.2f}s "
          f"(bytes / healthy aggregate, floored by srv5 queue)")
    print(f"{'iter':>28s} {'phase_s':>8s} {'strag_hits':>10s} "
          f"{'probes':>7s} {'redirects':>9s}")

    def row(tag, **kw):
        r = phase_time(**kw)
        print(f"{tag:>28s} {r['phase_s']:8.2f} "
              f"{r['straggler_hits']:10d} {r['probes']:7d} "
              f"{r['redirect_entries']:9d}")
        return r

    row("baseline rr", policy="rr")
    row("two_choice (SC'14, probes)", policy="two_choice")
    row("trh thr=64 (too shy)", policy="trh", threshold=64.0)
    row("trh thr=16", policy="trh", threshold=16.0)
    row("trh thr=4", policy="trh", threshold=4.0)
    row("trh thr=0.5 (eager)", policy="trh", threshold=0.5)
    row("mlml thr=4", policy="mlml", threshold=4.0)
    row("nltr thr=4", policy="nltr", threshold=4.0)
    row("trh stripe=16MB (coarse)", policy="trh", stripe_mb=16.0)
    row("trh stripe=1MB (fine)", policy="trh", stripe_mb=1.0)
    row("trh thr=4 + warm probs", policy="trh", threshold=4.0,
        warm_probs=True)
    row("mlml thr=4 + warm probs", policy="mlml", threshold=4.0,
        warm_probs=True)
    row("nltr thr=4 + warm probs", policy="nltr", threshold=4.0,
        warm_probs=True)
    row("trh + prob refresh/window", policy="trh", threshold=4.0,
        warm_probs=True, refresh=True)
    row("mlml + prob refresh/window", policy="mlml", threshold=4.0,
        warm_probs=True, refresh=True)
    row("nltr + prob refresh/window", policy="nltr", threshold=4.0,
        warm_probs=True, refresh=True)
    row("ect thr=0.05s (rate-aware)", policy="ect", threshold=0.05)
    row("ect + fine stripes", policy="ect", threshold=0.05, stripe_mb=1.0)
    row("ect cold log (no snapshot)", policy="ect", threshold=0.05,
        know_loads=False)


if __name__ == "__main__":
    run_all()
