"""§Perf hillclimb C — the paper's own technique: I/O phase completion time.

The metric is the *synchronous I/O phase time* on the queueing cluster
(the quantity the paper's load balance ultimately serves, Fig. 1): 24
servers at 200 MB/s, one slow-rate straggler (8x) with 800 MB of foreign
queue, one half-loaded server; 120 files x 16 MB written through the
client.  Each iteration follows hypothesis -> change -> measure; results
are recorded in EXPERIMENTS.md §Perf.

Temporal extension (DESIGN.md §Temporal-model): ``scenario_ranking``
ranks every policy by p50/p95/p99 latency and makespan under each
scenario of the library (jitted ``run_trials`` sweep), and
``transient_latency_cdf`` prints the latency CDF under a transient
straggler trace.  ``emit_bench_point`` appends one JSON point per run to
``BENCH_sched.json`` for the perf trajectory.
"""

from __future__ import annotations

import functools
import json
import os
import time
from typing import Dict, Optional

import numpy as np

from repro.core.policies import PolicyConfig
from repro.io import IOClient, IOClientConfig, SimulatedCluster
from repro.io.striping import MB


# Memoized: run_all prints a full iteration table and emit_bench_point
# re-reads three of the same cells — don't pay for the simulation twice.
@functools.lru_cache(maxsize=None)
def phase_time(policy: str, threshold: float = 4.0,
               stripe_mb: float = 4.0, n_files: int = 120,
               file_mb: float = 16.0, lam: float = 32.0,
               know_loads: bool = True, warm_probs: bool = False,
               refresh: bool = False, seed: int = 3) -> Dict[str, float]:
    sim = SimulatedCluster(24, base_rate_mb_s=200.0, seed=seed)
    sim.make_straggler(1, 8.0)
    sim.add_external_load(1, 800.0)
    sim.add_external_load(5, 400.0)
    cli = IOClient(sim, IOClientConfig(
        policy=PolicyConfig(name=policy, threshold=threshold),
        stripe_size=int(stripe_mb * MB), lam_mb=lam,
        refresh_probs=refresh))
    if know_loads:
        for s in range(sim.n_servers):
            cli.log.loads[s] = sim.queued_mb(s)
        if warm_probs:
            cli.log.absorb_loads()  # p_i ∝ e^{-l_i/λ}: sorts become load-aware
    for f in range(n_files):
        cli.write_file(f, size_mb=file_mb)
    t = cli.flush()
    return {"phase_s": t,
            "straggler_hits": sim.servers[1].n_requests,
            "probes": cli.probe_messages,
            "redirect_entries": sum(len(r) for r in sim.redirects)}


def ideal_phase_time() -> float:
    """Roofline for this workload: total bytes spread over the 22 clean
    servers (a perfect scheduler avoids both the straggler's 32 s foreign
    queue and server 5's 2 s queue)."""
    total_mb = 120 * 16.0
    return total_mb / (22 * 200.0)


# ---------------------------------------------------------------------------
# Temporal scenarios (DESIGN.md §Temporal-model): latency / makespan ranking
# ---------------------------------------------------------------------------

# single source of truth: the simulator's scenario/policy libraries
from repro.core.simulate import (SCENARIOS as SWEEP_SCENARIOS,  # noqa: E402
                                 SWEEP_POLICIES)


def _sweep_cfg(n_trials: int = 25):
    from repro.core.simulate import SimConfig
    return SimConfig(n_servers=24, n_requests=480, n_trials=n_trials,
                     window_size=60)


# One seed-0 sweep per (scenarios, policies, trials) per process —
# scenario_ranking, transient_latency_cdf and emit_bench_point overlap.
_SWEEP_CACHE: Dict[tuple, dict] = {}


def _scenario_sweep(scenario_names: tuple, policy_names: tuple,
                    n_trials: int) -> dict:
    key = (scenario_names, policy_names, n_trials)
    if key not in _SWEEP_CACHE:
        from repro.core import simulate
        _SWEEP_CACHE[key] = simulate.run_scenario_eval(
            seed=0, cfg=_sweep_cfg(n_trials),
            scenario_names=scenario_names, policy_names=policy_names)
    return _SWEEP_CACHE[key]


def _transient_results(n_trials: int) -> dict:
    """{policy: TrialResult} under the transient trace, reusing the full
    ranking sweep when it has already run this process."""
    full = (SWEEP_SCENARIOS, SWEEP_POLICIES, n_trials)
    if full in _SWEEP_CACHE:
        row = _SWEEP_CACHE[full]["transient"]
        return {p: row[p] for p in ("rr", "trh", "ect")}
    return _scenario_sweep(("transient",), ("rr", "trh", "ect"),
                           n_trials)["transient"]


def scenario_ranking(n_trials: int = 25) -> Dict[str, Dict[str, dict]]:
    """Policy ranking per scenario: p50/p95/p99 latency + makespan +
    straggler-hit fraction (jitted run_trials sweep)."""
    from repro.core import analysis
    out = _scenario_sweep(SWEEP_SCENARIOS, SWEEP_POLICIES, n_trials)
    table: Dict[str, Dict[str, dict]] = {}
    print("\n== Temporal scenarios: policy ranking "
          "(est. completion latency, s) ==")
    for scn, row in out.items():
        print(f"\n-- scenario: {scn} --")
        print(f"{'policy':>8s} {'p50':>8s} {'p95':>8s} {'p99':>8s} "
              f"{'makespan':>9s} {'strag_hit%':>10s}")
        ranked = {}
        for pol, res in row.items():
            ls = analysis.latency_stats(res.latencies)
            ls["makespan"] = analysis.makespan(res)
            ls["hit_frac"] = analysis.straggler_summary(res)["hit_fraction"]
            ranked[pol] = ls
            print(f"{pol:>8s} {ls['p50']:8.2f} {ls['p95']:8.2f} "
                  f"{ls['p99']:8.2f} {ls['makespan']:9.2f} "
                  f"{100 * ls['hit_frac']:10.2f}")
        best = min(ranked, key=lambda p: ranked[p]["p99"])
        print(f"   best p99: {best} "
              f"({ranked[best]['p99'] / max(ranked['rr']['p99'], 1e-9):.2f}x rr)")
        table[scn] = ranked
    return table


def transient_latency_cdf(n_trials: int = 25) -> None:
    """Latency CDF under the transient straggler trace (rr vs trh vs ect)."""
    from repro.core import analysis
    out = _transient_results(n_trials)
    print("\n== Transient stragglers: request latency CDF ==")
    for pol, res in out.items():
        xs, ys = analysis.latency_cdf(res.latencies, 72)
        print(analysis.ascii_plot(
            ys, label=f"CDF P[lat<=x] — {pol} "
                      f"(x: 0..{xs[-1]:.1f}s, p99={analysis.latency_stats(res.latencies)['p99']:.2f}s)"))


def emit_bench_point(path: str = "BENCH_sched.json",
                     n_trials: int = 25) -> dict:
    """Append one perf-trajectory point: the §Perf C phase time per policy
    plus the transient-scenario p99 for the log-assisted policies.
    Reuses this process's cached run_all results when available."""
    from repro.core import analysis
    point: Dict[str, object] = {"ts": time.time(), "metric_unit": "seconds"}
    # call signatures mirror run_all's rows so the lru_cache hits
    for pol, kw in (("rr", {}), ("trh", {"threshold": 4.0}),
                    ("ect", {"threshold": 0.05})):
        point[f"phase_s_{pol}"] = phase_time(policy=pol, **kw)["phase_s"]
    for pol, res in _transient_results(n_trials).items():
        point[f"transient_p99_{pol}"] = \
            analysis.latency_stats(res.latencies)["p99"]
    history = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                history = json.load(f)
            if not isinstance(history, list):
                history = [history]
        except (json.JSONDecodeError, OSError):
            history = []
    history.append(point)
    with open(path, "w") as f:
        json.dump(history, f, indent=1)
    print(f"[sched_perf] appended point -> {path} "
          f"(trh phase {point['phase_s_trh']:.2f}s, "
          f"transient p99 {point['transient_p99_trh']:.2f}s)")
    return point


def run_all() -> None:
    print("\n== §Perf C: scheduler hillclimb (phase completion time) ==")
    print(f"  ideal (napkin) phase time ~ {ideal_phase_time():.2f}s "
          f"(bytes / healthy aggregate, floored by srv5 queue)")
    print(f"{'iter':>28s} {'phase_s':>8s} {'strag_hits':>10s} "
          f"{'probes':>7s} {'redirects':>9s}")

    def row(tag, **kw):
        r = phase_time(**kw)
        print(f"{tag:>28s} {r['phase_s']:8.2f} "
              f"{r['straggler_hits']:10d} {r['probes']:7d} "
              f"{r['redirect_entries']:9d}")
        return r

    row("baseline rr", policy="rr")
    row("two_choice (SC'14, probes)", policy="two_choice")
    row("trh thr=64 (too shy)", policy="trh", threshold=64.0)
    row("trh thr=16", policy="trh", threshold=16.0)
    row("trh thr=4", policy="trh", threshold=4.0)
    row("trh thr=0.5 (eager)", policy="trh", threshold=0.5)
    row("mlml thr=4", policy="mlml", threshold=4.0)
    row("nltr thr=4", policy="nltr", threshold=4.0)
    row("trh stripe=16MB (coarse)", policy="trh", stripe_mb=16.0)
    row("trh stripe=1MB (fine)", policy="trh", stripe_mb=1.0)
    row("trh thr=4 + warm probs", policy="trh", threshold=4.0,
        warm_probs=True)
    row("mlml thr=4 + warm probs", policy="mlml", threshold=4.0,
        warm_probs=True)
    row("nltr thr=4 + warm probs", policy="nltr", threshold=4.0,
        warm_probs=True)
    row("trh + prob refresh/window", policy="trh", threshold=4.0,
        warm_probs=True, refresh=True)
    row("mlml + prob refresh/window", policy="mlml", threshold=4.0,
        warm_probs=True, refresh=True)
    row("nltr + prob refresh/window", policy="nltr", threshold=4.0,
        warm_probs=True, refresh=True)
    row("ect thr=0.05s (rate-aware)", policy="ect", threshold=0.05)
    row("ect + fine stripes", policy="ect", threshold=0.05, stripe_mb=1.0)
    row("ect cold log (no snapshot)", policy="ect", threshold=0.05,
        know_loads=False)

    scenario_ranking()
    transient_latency_cdf()


if __name__ == "__main__":
    run_all()
    emit_bench_point()
