"""§Perf hillclimb C — the paper's own technique: I/O phase completion time.

The metric is the *synchronous I/O phase time* on the queueing cluster
(the quantity the paper's load balance ultimately serves, Fig. 1): 24
servers at 200 MB/s, one slow-rate straggler (8x) with 800 MB of foreign
queue, one half-loaded server; 120 files x 16 MB written through the
client.  Each iteration follows hypothesis -> change -> measure; results
are recorded in EXPERIMENTS.md §Perf.

Temporal extension (DESIGN.md §Temporal-model): ``scenario_ranking``
ranks every policy by p50/p95/p99 latency and makespan under each
scenario of the library (jitted ``run_trials`` sweep), and
``transient_latency_cdf`` prints the latency CDF under a transient
straggler trace.  ``emit_bench_point`` appends one JSON point per run to
``BENCH_sched.json`` for the perf trajectory.
"""

from __future__ import annotations

import functools
import hashlib
import json
import os
import subprocess
import sys
import time
from typing import Dict, Optional

import numpy as np

from repro.core.policies import PolicyConfig
from repro.io import IOClient, IOClientConfig, SimulatedCluster
from repro.io.striping import MB

# BENCH_sched.json lives at the REPO ROOT regardless of cwd — the
# trajectory is one in-repo history, not a scatter of per-cwd files.
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_PATH = os.path.join(_REPO_ROOT, "BENCH_sched.json")


def _git_sha() -> Optional[str]:
    """HEAD sha for bench-point dedup (one point per commit); None when
    git is unavailable (e.g. a source tarball)."""
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"], cwd=_REPO_ROOT,
                             capture_output=True, text=True, timeout=10)
    except OSError:
        return None
    sha = out.stdout.strip()
    return sha if out.returncode == 0 and sha else None


def _git_dirty() -> Optional[bool]:
    """True when the working tree differs from HEAD — stamped alongside
    ``git_sha`` so the sha-keyed dedupe can't silently merge points
    measured on different trees; None when git is unavailable."""
    try:
        out = subprocess.run(["git", "status", "--porcelain"],
                             cwd=_REPO_ROOT, capture_output=True,
                             text=True, timeout=10)
    except OSError:
        return None
    if out.returncode != 0:
        return None
    return bool(out.stdout.strip())


def _stamp_git(point: dict) -> dict:
    """Stamp ``git_sha``/``git_dirty`` onto a bench point, warning LOUDLY
    when the tree is dirty — a dirty-tree number silently entering the
    trajectory is exactly how a regression hides behind an uncommitted
    tweak.  Returns the point for chaining."""
    sha = _git_sha()
    if sha:
        point["git_sha"] = sha
        dirty = _git_dirty()
        if dirty is not None:
            point["git_dirty"] = dirty
            if dirty:
                print("=" * 72, file=sys.stderr)
                print("[sched_perf] WARNING: working tree is DIRTY — this "
                      "bench point is\n[sched_perf] stamped git_dirty=true "
                      "and will NOT serve as a regression baseline.\n"
                      "[sched_perf] Commit first for a clean trajectory "
                      f"point (HEAD {sha[:12]}).", file=sys.stderr)
                print("=" * 72, file=sys.stderr)
    return point


# Memoized: run_all prints a full iteration table and emit_bench_point
# re-reads three of the same cells — don't pay for the simulation twice.
@functools.lru_cache(maxsize=None)
def phase_time(policy: str, threshold: float = 4.0,
               stripe_mb: float = 4.0, n_files: int = 120,
               file_mb: float = 16.0, lam: float = 32.0,
               know_loads: bool = True, warm_probs: bool = False,
               refresh: bool = False, seed: int = 3) -> Dict[str, float]:
    sim = SimulatedCluster(24, base_rate_mb_s=200.0, seed=seed)
    sim.make_straggler(1, 8.0)
    sim.add_external_load(1, 800.0)
    sim.add_external_load(5, 400.0)
    cli = IOClient(sim, IOClientConfig(
        policy=PolicyConfig(name=policy, threshold=threshold),
        stripe_size=int(stripe_mb * MB), lam_mb=lam,
        refresh_probs=refresh))
    if know_loads:
        for s in range(sim.n_servers):
            cli.log.loads[s] = sim.queued_mb(s)
        if warm_probs:
            cli.log.absorb_loads()  # p_i ∝ e^{-l_i/λ}: sorts become load-aware
    for f in range(n_files):
        cli.write_file(f, size_mb=file_mb)
    t = cli.flush()
    return {"phase_s": t,
            "straggler_hits": sim.servers[1].n_requests,
            "probes": cli.probe_messages,
            "redirect_entries": sum(len(r) for r in sim.redirects)}


def ideal_phase_time() -> float:
    """Roofline for this workload: total bytes spread over the 22 clean
    servers (a perfect scheduler avoids both the straggler's 32 s foreign
    queue and server 5's 2 s queue)."""
    total_mb = 120 * 16.0
    return total_mb / (22 * 200.0)


# ---------------------------------------------------------------------------
# Temporal scenarios (DESIGN.md §Temporal-model): latency / makespan ranking
# ---------------------------------------------------------------------------

# single source of truth: the simulator's scenario/policy libraries
from repro.core.simulate import (SCENARIOS as SWEEP_SCENARIOS,  # noqa: E402
                                 SWEEP_POLICIES)


def _sweep_cfg(n_trials: int = 25):
    from repro.core.simulate import SimConfig
    return SimConfig(n_servers=24, n_requests=480, n_trials=n_trials,
                     window_size=60)


# One seed-0 sweep per (scenarios, policies, trials) per process —
# scenario_ranking, transient_latency_cdf and emit_bench_point overlap.
_SWEEP_CACHE: Dict[tuple, dict] = {}


def _scenario_sweep(scenario_names: tuple, policy_names: tuple,
                    n_trials: int) -> dict:
    key = (scenario_names, policy_names, n_trials)
    if key not in _SWEEP_CACHE:
        from repro.core import simulate
        _SWEEP_CACHE[key] = simulate.run_scenario_eval(
            seed=0, cfg=_sweep_cfg(n_trials),
            scenario_names=scenario_names, policy_names=policy_names)
    return _SWEEP_CACHE[key]


def _transient_results(n_trials: int) -> dict:
    """{policy: TrialResult} under the transient trace, reusing the full
    ranking sweep when it has already run this process."""
    full = (SWEEP_SCENARIOS, SWEEP_POLICIES, n_trials)
    if full in _SWEEP_CACHE:
        row = _SWEEP_CACHE[full]["transient"]
        return {p: row[p] for p in ("rr", "trh", "ect")}
    return _scenario_sweep(("transient",), ("rr", "trh", "ect"),
                           n_trials)["transient"]


def _median_time(run, reps: int):
    """Median wall time of ``reps`` timed calls, warmup (compile) run
    excluded — single-shot numbers flipped kernel/engine winners between
    benchmark runs, so every tracked throughput is a median.  Returns
    ``(median_s, warmup_result)`` so callers needing the outputs (e.g.
    for bit-exactness checks) don't pay for an extra untimed run."""
    import jax
    warm = run()
    jax.block_until_ready(warm)                # compile + warm, untimed
    times = []
    for _ in range(max(reps, 1)):
        t0 = time.time()
        jax.block_until_ready(run())
        times.append(time.time() - t0)
    return float(np.median(times)), warm


@functools.lru_cache(maxsize=None)   # run_all + emit_bench_point share it
def kernel_vs_engine_throughput(n_servers: int = 100,
                                n_requests: int = 2000,
                                window_size: int = 100,
                                reps: int = 3) -> Dict[str, float]:
    """Scheduling throughput (requests scheduled/s): the Pallas temporal
    kernel (whole stream = ONE pallas_call, packed log tensor in VMEM)
    vs the lax.scan JAX engine, on the 100-OSS transient scenario.

    Reported wall times are the MEDIAN of ``reps`` runs (warmup
    excluded); the repeat count rides along in the emitted bench point.
    On CPU the kernel runs in interpret mode, so the absolute numbers are
    a lower bound — the structural point is that both backends schedule
    the SAME trace from the same decision table (bit-exact for ect,
    asserted here) and the kernel-backend wall time is tracked per run in
    BENCH_sched.json.
    """
    import jax
    from repro.core import engine, simulate, statlog
    from repro.core.simulate import ScenarioConfig, SimConfig

    cfg = SimConfig(n_servers=n_servers, n_requests=n_requests, n_trials=1,
                    window_size=window_size,
                    scenario=ScenarioConfig(name="transient"))
    scn = cfg.scenario
    key = jax.random.key(0)
    work = simulate.sample_workload(key, cfg)
    trace = simulate.make_trace(jax.random.fold_in(key, 1), cfg, scn)
    window_dt = simulate.resolve_window_dt(cfg, scn)
    log_cfg = simulate.default_log_cfg(cfg)
    pol = PolicyConfig(name="ect", threshold=0.05)
    state = statlog.init_state(log_cfg, rates=trace.rates[0])

    out: Dict[str, float] = {"n_servers": n_servers,
                             "n_requests": n_requests, "reps": reps}
    chosen = {}
    for backend in ("jax", "kernel"):
        run = functools.partial(
            engine.run_stream_jit, state, work, key, policy=pol,
            log_cfg=log_cfg, window_size=window_size, trace=trace,
            window_dt=window_dt, backend=backend)
        dt, warm = _median_time(lambda: run().chosen, reps)
        chosen[backend] = np.asarray(warm)
        out[f"{backend}_s"] = dt
        out[f"{backend}_req_s"] = n_requests / dt
    out["bit_exact"] = bool((chosen["jax"] == chosen["kernel"]).all())
    print(f"\n== kernel vs JAX engine scheduling throughput "
          f"({n_servers} OSS x {n_requests} reqs, transient trace, "
          f"median of {reps}) ==")
    print(f"{'backend':>8s} {'wall_s':>8s} {'req/s':>10s}")
    for b in ("jax", "kernel"):
        print(f"{b:>8s} {out[f'{b}_s']:8.3f} {out[f'{b}_req_s']:10.0f}")
    print(f"  decisions bit-exact across backends: {out['bit_exact']}"
          + ("" if out["bit_exact"] else "  <-- DIVERGED"))
    return out


@functools.lru_cache(maxsize=None)   # run_all + emit_bench_point share it
def kernel_batch_throughput(n_servers: int = 100, n_requests: int = 2000,
                            window_size: int = 100, n_trials: int = 100,
                            reps: int = 3, policy: str = "ect",
                            threshold: float = 0.05,
                            check_bit_exact: bool = True,
                            measure_engine: bool = False
                            ) -> Dict[str, float]:
    """Trial-grid kernel throughput (DESIGN.md §9): the WHOLE Monte-Carlo
    sweep — ``n_trials`` independent transient-scenario streams — as ONE
    pallas_call (`simulate.run_trials(backend='kernel')`), vs. the same
    sweep mapped trial-by-trial through the sequential kernel path.

    ``policy`` selects the in-kernel decision rule — since the in-VMEM
    sorts (DESIGN.md §10) this includes the sort-based ``mlml``/``nltr``,
    which now run the §13 permutation-apply fast path (one all-pairs
    rank + a constant number of permutation applies per window, tracked
    per run in BENCH_sched.json as ``kernel_batch_req_s_<policy>``).

    ``kernel_batch_req_s`` is aggregate (trials x requests) / median
    wall seconds; ``batch_bit_exact`` asserts every per-trial decision,
    latency and load of the grid kernel equals the ``lax.map`` path —
    the tentpole contract of the trial-grid form.  ``measure_engine``
    also times the SAME sweep through the vmapped jax engine
    (``engine_batch_req_s``) — the same-policy engine twin the
    trajectory's behind-engine flag compares against."""
    import jax
    from repro.core import simulate
    from repro.core.simulate import ScenarioConfig, SimConfig

    cfg = SimConfig(n_servers=n_servers, n_requests=n_requests,
                    n_trials=n_trials, window_size=window_size,
                    backend="kernel",
                    scenario=ScenarioConfig(name="transient"))
    log_cfg = simulate.default_log_cfg(cfg)
    rng = "lcg" if policy in ("trh", "nltr", "two_choice") else "jax"
    pol = PolicyConfig(name=policy, threshold=threshold, rng=rng)
    key = jax.random.key(0)

    # time the whole TrialResult and keep the warm output — the
    # bit-exactness check below reuses it instead of paying for one
    # more full sweep
    dt, batch = _median_time(
        lambda: simulate.run_trials(key, cfg, pol, log_cfg), reps)
    out: Dict[str, float] = {
        "n_servers": n_servers, "n_requests": n_requests,
        "n_trials": n_trials, "reps": reps, "policy": policy,
        "batch_s": dt,
        "kernel_batch_req_s": n_trials * n_requests / dt,
    }
    if measure_engine:
        ecfg = SimConfig(n_servers=n_servers, n_requests=n_requests,
                         n_trials=n_trials, window_size=window_size,
                         backend="jax",
                         scenario=ScenarioConfig(name="transient"))
        elog = simulate.default_log_cfg(ecfg)
        edt, _ = _median_time(
            lambda: simulate.run_trials(key, ecfg, pol, elog), reps)
        out["engine_batch_s"] = edt
        out["engine_batch_req_s"] = n_trials * n_requests / edt
    if check_bit_exact:
        keys = jax.random.split(key, n_trials)
        seq = jax.jit(lambda ks: jax.lax.map(
            lambda k: simulate.run_one_trial(k, cfg, pol, log_cfg), ks)
        )(keys)
        out["batch_bit_exact"] = bool(
            (np.asarray(batch.chosen) == np.asarray(seq.chosen)).all()
            and (np.asarray(batch.latencies)
                 == np.asarray(seq.latencies)).all()
            and (np.asarray(batch.server_loads)
                 == np.asarray(seq.server_loads)).all()
            and (np.asarray(batch.phase_time)
                 == np.asarray(seq.phase_time)).all())
    print(f"\n== trial-grid kernel sweep throughput ({n_servers} OSS x "
          f"{n_requests} reqs x {n_trials} trials, policy={policy}, "
          f"median of {reps}) ==")
    print(f"  one pallas_call for the whole sweep: {dt:8.3f}s  "
          f"{out['kernel_batch_req_s']:10.0f} req/s aggregate")
    if measure_engine:
        print(f"  vmapped jax engine, same sweep:    "
              f"{out['engine_batch_s']:8.3f}s  "
              f"{out['engine_batch_req_s']:10.0f} req/s aggregate")
    if check_bit_exact:
        print(f"  per-trial decisions/latencies/loads bit-exact vs "
              f"sequential kernel path: {out['batch_bit_exact']}"
              + ("" if out["batch_bit_exact"] else "  <-- DIVERGED"))
    return out


@functools.lru_cache(maxsize=None)   # run_all + emit_bench_point share it
def kernel_per_client_throughput(n_servers: int = 100,
                                 n_requests: int = 2000,
                                 window_size: int = 100,
                                 n_trials: int = 100, n_clients: int = 16,
                                 reps: int = 3, policy: str = "ect",
                                 threshold: float = 0.05,
                                 client_tile: Optional[int] = None,
                                 check_bit_exact: bool = False
                                 ) -> Dict[str, float]:
    """per_client contention-sweep throughput (DESIGN.md §11): the whole
    ``n_trials x n_clients`` private-log sweep as ONE 2-D
    (trials × clients) grid pallas_call —
    ``run_trials(backend='kernel', client_model='per_client')`` — vs the
    SAME sweep through the vmapped jax engine path.

    ``per_client_kernel_req_s`` / ``per_client_jax_req_s`` are aggregate
    (trials x requests) / median wall seconds; ``per_client_bit_exact``
    asserts every TrialResult decision/latency/load/aggregate of the 2-D
    grid equals the jax path — the §11 tentpole contract (also covered
    per policy/scenario in tests, so the full-scale sweeps skip it)."""
    import jax
    from repro.core import simulate
    from repro.core.simulate import ScenarioConfig, SimConfig

    out: Dict[str, float] = {
        "n_servers": n_servers, "n_requests": n_requests,
        "n_trials": n_trials, "n_clients": n_clients, "reps": reps,
        "policy": policy}
    key = jax.random.key(0)
    rng = "lcg" if policy in ("trh", "nltr", "two_choice") else "jax"
    pol = PolicyConfig(name=policy, threshold=threshold, rng=rng)
    warm_res = {}
    for backend in ("kernel", "jax"):
        cfg = SimConfig(n_servers=n_servers, n_requests=n_requests,
                        n_trials=n_trials, window_size=window_size,
                        n_clients=n_clients, client_model="per_client",
                        client_tile=client_tile, backend=backend,
                        scenario=ScenarioConfig(name="transient"))
        log_cfg = simulate.default_log_cfg(cfg)
        dt, warm = _median_time(
            lambda: simulate.run_trials(key, cfg, pol, log_cfg), reps)
        warm_res[backend] = warm
        tag = "kernel" if backend == "kernel" else "jax"
        out[f"per_client_{tag}_s"] = dt
        out[f"per_client_{tag}_req_s"] = n_trials * n_requests / dt
    if check_bit_exact:
        out["per_client_bit_exact"] = bool(all(
            (np.asarray(getattr(warm_res["kernel"], f))
             == np.asarray(getattr(warm_res["jax"], f))).all()
            for f in ("chosen", "latencies", "server_loads",
                      "window_loads", "phase_time", "probe_msgs")))
    print(f"\n== per_client 2-D grid sweep throughput ({n_servers} OSS x "
          f"{n_requests} reqs x {n_trials} trials x {n_clients} clients, "
          f"policy={policy}, median of {reps}) ==")
    for tag in ("kernel", "jax"):
        print(f"  {tag:>6s}: {out[f'per_client_{tag}_s']:8.3f}s  "
              f"{out[f'per_client_{tag}_req_s']:10.0f} req/s aggregate")
    if check_bit_exact:
        print(f"  TrialResult bit-exact across backends: "
              f"{out['per_client_bit_exact']}"
              + ("" if out["per_client_bit_exact"] else "  <-- DIVERGED"))
    return out


@functools.lru_cache(maxsize=None)   # run_all + emit_bench_point share it
def tuned_kernel_throughput(n_servers: int = 100, n_requests: int = 2000,
                            window_size: int = 100, n_trials: int = 100,
                            reps: int = 3, policy: str = "ect",
                            threshold: float = 0.05,
                            n_clients: Optional[int] = None
                            ) -> Dict[str, float]:
    """Tuned-lowering sweep throughput (DESIGN.md §16): the SAME kernel
    trial sweep with ``SimConfig.tiles="tuned"`` (tile shapes from the
    TUNE_sched.json table, fused-resolver fallback on a cache miss) vs
    the static default lowering, plus the bitwise equality of the two
    TrialResults — tiles are association parameters, so a tuned run must
    be the same result, just lowered faster.

    ``n_clients`` switches to the per_client 2-D grid form (the
    fused-block case: a 4-client stream wastes 28 of 32 sublanes at the
    static default client tile)."""
    import dataclasses

    import jax
    from repro.core import simulate
    from repro.core.simulate import ScenarioConfig, SimConfig

    cfg = SimConfig(n_servers=n_servers, n_requests=n_requests,
                    n_trials=n_trials, window_size=window_size,
                    backend="kernel",
                    n_clients=(n_clients or 1),
                    client_model=("per_client" if n_clients else
                                  "shared_log"),
                    scenario=ScenarioConfig(name="transient"))
    log_cfg = simulate.default_log_cfg(cfg)
    rng = "lcg" if policy in ("trh", "nltr", "two_choice") else "jax"
    pol = PolicyConfig(name=policy, threshold=threshold, rng=rng)
    key = jax.random.key(0)
    out: Dict[str, float] = {
        "n_servers": n_servers, "n_requests": n_requests,
        "n_trials": n_trials, "reps": reps, "policy": policy}
    # interleaved best-of-reps: the two lowerings run the same
    # deterministic work, so alternating reps and keeping each mode's
    # minimum decorrelates machine drift over the long bench run —
    # a median of back-to-back blocks recorded phantom 0.97x/1.25x
    # "speedups" that a quiet-process A/B could not reproduce
    modes = {m: dataclasses.replace(cfg, tiles=m)
             for m in ("default", "tuned")}
    warm, best = {}, {m: float("inf") for m in modes}
    for m, mcfg in modes.items():
        warm[m] = jax.block_until_ready(
            simulate.run_trials(key, mcfg, pol, log_cfg))
    for _ in range(max(reps, 1)):
        for m, mcfg in modes.items():
            t0 = time.time()
            jax.block_until_ready(simulate.run_trials(key, mcfg, pol,
                                                      log_cfg))
            best[m] = min(best[m], time.time() - t0)
    for m in modes:
        out[f"{m}_s"] = best[m]
        out[f"{m}_req_s"] = n_trials * n_requests / best[m]
    out["speedup"] = out["default_s"] / out["tuned_s"]
    out["tuned_bit_exact"] = bool(all(
        (np.asarray(getattr(warm["tuned"], f))
         == np.asarray(getattr(warm["default"], f))).all()
        for f in warm["tuned"]._fields))
    form = (f"per_client {n_clients}c 2-D grid" if n_clients
            else "trial grid")
    print(f"\n== tuned-lowering sweep throughput ({n_servers} OSS x "
          f"{n_requests} reqs x {n_trials} trials, {form}, "
          f"policy={policy}, interleaved best of {reps}) ==")
    for mode in ("default", "tuned"):
        print(f"  {mode:>8s} tiles: {out[f'{mode}_s']:8.3f}s  "
              f"{out[f'{mode}_req_s']:10.0f} req/s aggregate")
    print(f"  tuned speedup {out['speedup']:.2f}x; TrialResult bit-exact "
          f"vs default lowering: {out['tuned_bit_exact']}"
          + ("" if out["tuned_bit_exact"] else "  <-- DIVERGED"))
    return out


@functools.lru_cache(maxsize=None)   # run_all + emit_bench_point share it
def kernel_phase_profile_point(n_servers: int = 100,
                               n_requests: int = 2000,
                               window_size: int = 100,
                               n_trials: int = 100,
                               reps: int = 3) -> Dict[str, float]:
    """Per-window-phase attribution of the full-scale trial-grid kernel
    wall time (differential over the kernel's cumulative ``ablate``
    levels, `repro.tune.profile.kernel_phase_profile`) — names WHICH
    phase owns the kernel-vs-engine gap instead of leaving it a single
    opaque number."""
    from repro.tune import profile as tune_profile

    prof = tune_profile.kernel_phase_profile(
        n_servers=n_servers, n_requests=n_requests,
        window_size=window_size, n_trials=n_trials, reps=reps)
    phases = {k: prof[k] for k in ("metrics_s", "steps_s", "plan_s",
                                   "dispatch_s")}
    gap = max(phases, key=lambda k: phases[k])
    print(f"\n== kernel per-phase profile ({n_servers} OSS x "
          f"{n_requests} reqs x {n_trials} trials, differential over "
          f"ablate levels, median of {reps}) ==")
    for k in ("total_s",) + tuple(phases):
        frac = prof[k] / max(prof["total_s"], 1e-12)
        print(f"  {k:>11s}: {prof[k]:8.3f}s  ({100 * frac:5.1f}%)")
    print(f"  dominant phase: {gap.replace('_s', '')}")
    return {**prof, "gap_phase": gap.replace("_s", "")}


@functools.lru_cache(maxsize=None)   # run_all + emit_bench_point share it
def per_client_phase_breakdown(n_servers: int = 100,
                               n_requests: int = 2000,
                               window_size: int = 100,
                               n_trials: int = 100, n_clients: int = 64,
                               reps: int = 3, policy: str = "ect",
                               threshold: float = 0.05
                               ) -> Dict[str, float]:
    """End-to-end ``run_trials`` throughput + the prep/sched/post phase
    breakdown of the batched trial pipeline (DESIGN.md §14), per backend,
    on the per_client contention instance — default the 64-client
    SHORT-STREAM case (2000 requests over 64 clients = 32-request
    slices), where the lax.map prep/post halo used to dominate the wall
    clock.

    * ``e2e_req_s_{kernel,jax}`` — jitted ``run_trials`` end-to-end
      (workload sampling through TrialResult stack), aggregate
      trials×requests / median wall seconds, batched pipeline
      (``SimConfig.prep="batched"``, the default);
    * ``e2e_seq_req_s_{kernel,jax}`` — the same dispatch with
      ``prep="sequential"`` (the lax.map halo, the pre-§14 shape);
    * ``e2e_speedup_{kernel,jax}`` — sequential wall / batched wall;
    * ``prep_s`` / ``sched_s_{kernel,jax}`` / ``post_s`` (+ the
      ``*_seq`` twins for prep/post) — each pipeline stage jitted and
      timed alone (``cfg``/``policy``/``log_cfg`` are jit statics);
    * ``e2e_batched_bit_exact`` — every TrialResult field of the
      batched pipeline equals the sequential oracle, both backends.
    """
    import dataclasses

    import jax
    from repro.core import simulate
    from repro.core.simulate import ScenarioConfig, SimConfig

    out: Dict[str, float] = {
        "n_servers": n_servers, "n_requests": n_requests,
        "n_trials": n_trials, "n_clients": n_clients, "reps": reps,
        "policy": policy}
    key = jax.random.key(0)
    rng = "lcg" if policy in ("trh", "nltr", "two_choice") else "jax"
    pol = PolicyConfig(name=policy, threshold=threshold, rng=rng)
    prep_jit = jax.jit(simulate._prep_trials, static_argnums=(1, 2))
    sched_jit = jax.jit(simulate._sched_trials, static_argnums=(0, 1, 2))
    post_jit = jax.jit(simulate._post_trials, static_argnums=(0,))
    bit_exact = True
    print(f"\n== per_client batched-pipeline breakdown ({n_servers} OSS x "
          f"{n_requests} reqs x {n_trials} trials x {n_clients} clients, "
          f"policy={policy}, median of {reps}) ==")
    for backend in ("kernel", "jax"):
        cfg = SimConfig(n_servers=n_servers, n_requests=n_requests,
                        n_trials=n_trials, window_size=window_size,
                        n_clients=n_clients, client_model="per_client",
                        backend=backend,
                        scenario=ScenarioConfig(name="transient"))
        log_cfg = simulate.default_log_cfg(cfg)
        cfg_seq = dataclasses.replace(cfg, prep="sequential")
        dt_b, warm_b = _median_time(
            lambda: simulate.run_trials(key, cfg, pol, log_cfg), reps)
        dt_s, warm_s = _median_time(
            lambda: simulate.run_trials(key, cfg_seq, pol, log_cfg), reps)
        bit_exact &= bool(all(
            (np.asarray(getattr(warm_b, f))
             == np.asarray(getattr(warm_s, f))).all()
            for f in warm_b._fields))
        out[f"e2e_req_s_{backend}"] = n_trials * n_requests / dt_b
        out[f"e2e_seq_req_s_{backend}"] = n_trials * n_requests / dt_s
        out[f"e2e_speedup_{backend}"] = dt_s / dt_b
        # stage breakdown: each stage jitted alone (prep/post batched
        # AND sequential; scheduling is prep-agnostic so once per
        # backend)
        keys = jax.random.split(key, n_trials)
        p_t, prep = _median_time(lambda: prep_jit(keys, cfg, log_cfg),
                                 reps)
        init, strag, works, states, traces, k_sched = prep
        s_t, sched = _median_time(
            lambda: sched_jit(cfg, pol, log_cfg, works, states, k_sched,
                              traces), reps)
        o_t, _ = _median_time(
            lambda: post_jit(cfg, init, strag, works, traces, *sched),
            reps)
        out[f"sched_s_{backend}"] = s_t
        if backend == "kernel":    # prep/post are backend-independent
            out["prep_s"], out["post_s"] = p_t, o_t
            ps_t, _ = _median_time(
                lambda: prep_jit(keys, cfg_seq, log_cfg), reps)
            os_t, _ = _median_time(
                lambda: post_jit(cfg_seq, init, strag, works, traces,
                                 *sched), reps)
            out["prep_seq_s"], out["post_seq_s"] = ps_t, os_t
        print(f"  {backend:>6s}: e2e {out[f'e2e_req_s_{backend}']:10.0f} "
              f"req/s batched vs "
              f"{out[f'e2e_seq_req_s_{backend}']:10.0f} sequential "
              f"({out[f'e2e_speedup_{backend}']:.2f}x) | stages "
              f"prep {p_t:.3f}s sched {s_t:.3f}s post {o_t:.3f}s")
    out["e2e_batched_bit_exact"] = bit_exact
    print(f"  prep {out['prep_s']:.3f}s vs sequential "
          f"{out['prep_seq_s']:.3f}s; post {out['post_s']:.3f}s vs "
          f"{out['post_seq_s']:.3f}s; TrialResult bit-exact: {bit_exact}"
          + ("" if bit_exact else "  <-- DIVERGED"))
    return out


def _sharded_env(n_devices: int) -> Dict[str, str]:
    """Env for a sharded-worker subprocess: force ``n_devices`` host
    devices (replacing any count already in XLA_FLAGS) and make sure
    ``src`` is importable."""
    env = dict(os.environ)
    flags = [t for t in env.get("XLA_FLAGS", "").split()
             if "xla_force_host_platform_device_count" not in t]
    flags.append(f"--xla_force_host_platform_device_count={n_devices}")
    env["XLA_FLAGS"] = " ".join(flags)
    src = os.path.join(_REPO_ROOT, "src")
    pp = env.get("PYTHONPATH", "")
    if src not in pp.split(os.pathsep):
        env["PYTHONPATH"] = src + (os.pathsep + pp if pp else "")
    return env


def _sharded_worker(spec: dict) -> None:
    """Body of one ``--sharded-worker`` subprocess: run the 100-OSS
    transient Monte-Carlo sweep at ``mesh_shape=(devices,)`` (plain
    single-device dispatch when ``devices == 1``) on BOTH backends and
    print one ``SHARDED_RESULT`` json line with req/s plus a sha1 digest
    of the per-trial decisions/latencies/phase times — the parent
    compares digests across device counts for the DESIGN.md §12
    bit-exactness claim."""
    import jax
    from repro.core import simulate
    from repro.core.simulate import ScenarioConfig, SimConfig

    d = int(spec["devices"])
    assert jax.device_count() == d, (jax.device_count(), d)
    key = jax.random.key(0)
    pol = PolicyConfig(name="ect", threshold=0.05)
    out: Dict[str, object] = {"devices": d}
    for backend in ("kernel", "jax"):
        cfg = SimConfig(n_servers=spec["n_servers"],
                        n_requests=spec["n_requests"],
                        n_trials=spec["n_trials"],
                        window_size=spec["window_size"], backend=backend,
                        mesh_shape=None if d == 1 else (d,),
                        scenario=ScenarioConfig(name="transient"))
        log_cfg = simulate.default_log_cfg(cfg)
        dt, warm = _median_time(
            lambda: simulate.run_trials(key, cfg, pol, log_cfg),
            spec["reps"])
        h = hashlib.sha1()
        for f in ("chosen", "latencies", "phase_time"):
            h.update(np.asarray(getattr(warm, f)).tobytes())
        out[f"{backend}_s"] = dt
        out[f"{backend}_req_s"] = spec["n_trials"] * spec["n_requests"] / dt
        out[f"{backend}_digest"] = h.hexdigest()
    print("SHARDED_RESULT " + json.dumps(out), flush=True)


@functools.lru_cache(maxsize=None)   # run_all + emit_bench_point share it
def sharded_sweep_throughput(n_servers: int = 100, n_requests: int = 2000,
                             window_size: int = 100, n_trials: int = 100,
                             reps: int = 1,
                             devices: tuple = (1, 2, 4, 8)
                             ) -> Dict[str, object]:
    """Sharded sweep throughput (DESIGN.md §12): the full Monte-Carlo
    sweep through ``parallel/sweep.py`` at each host device count in
    ``devices``, both backends, vs the single-device dispatch (the
    ``devices == 1`` row).

    Each device count runs in its own SUBPROCESS under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — XLA fixes
    the host device count at first jax init, so one process cannot
    measure two counts.  The workers' sha1 digests of (chosen,
    latencies, phase_time) must agree across every device count AND
    across backends — the sharded dispatch is a pure re-layout.

    Scaling honesty: forced host "devices" are threads on the same CPU,
    so aggregate req/s tracks ``min(devices, physical cores)`` — on a
    1-core CI box the sharded rows measure dispatch overhead, not
    speedup; the series exists so multi-core/multi-chip runs of the same
    benchmark expose real scaling against the same baseline."""
    spec = {"n_servers": n_servers, "n_requests": n_requests,
            "window_size": window_size, "n_trials": n_trials, "reps": reps}
    out: Dict[str, object] = dict(spec)
    out["devices"] = list(devices)
    rows = {}
    for d in devices:
        r = subprocess.run(
            [sys.executable, "-m", "benchmarks.sched_perf",
             "--sharded-worker", json.dumps({**spec, "devices": d})],
            cwd=_REPO_ROOT, env=_sharded_env(d),
            capture_output=True, text=True)
        line = next((ln for ln in r.stdout.splitlines()
                     if ln.startswith("SHARDED_RESULT ")), None)
        if r.returncode != 0 or line is None:
            raise RuntimeError(
                f"sharded worker (devices={d}) failed:\n"
                f"{r.stdout[-2000:]}\n{r.stderr[-2000:]}")
        rows[d] = json.loads(line[len("SHARDED_RESULT "):])
    base = rows[devices[0]]
    out["sharded_bit_exact"] = all(
        rows[d][f"{b}_digest"] == base[f"{b}_digest"]
        for d in devices for b in ("kernel", "jax"))
    out["sharded_cross_backend_exact"] = all(
        rows[d]["kernel_digest"] == rows[d]["jax_digest"] for d in devices)
    for d in devices:
        out[f"sharded_req_s_{d}d"] = rows[d]["kernel_req_s"]
        out[f"sharded_engine_req_s_{d}d"] = rows[d]["jax_req_s"]
    print(f"\n== sharded sweep throughput ({n_servers} OSS x "
          f"{n_requests} reqs x {n_trials} trials, mesh=(d,), "
          f"median of {reps}) ==")
    print(f"{'devices':>8s} {'kernel req/s':>14s} {'engine req/s':>14s}")
    for d in devices:
        print(f"{d:>8d} {out[f'sharded_req_s_{d}d']:14.0f} "
              f"{out[f'sharded_engine_req_s_{d}d']:14.0f}")
    print(f"  bit-exact across device counts: {out['sharded_bit_exact']}"
          + ("" if out["sharded_bit_exact"] else "  <-- DIVERGED"))
    print(f"  bit-exact across backends:      "
          f"{out['sharded_cross_backend_exact']}"
          + ("" if out["sharded_cross_backend_exact"] else "  <-- DIVERGED"))
    return out


def scenario_ranking(n_trials: int = 25) -> Dict[str, Dict[str, dict]]:
    """Policy ranking per scenario: p50/p95/p99 latency + makespan +
    straggler-hit fraction (jitted run_trials sweep)."""
    from repro.core import analysis
    out = _scenario_sweep(SWEEP_SCENARIOS, SWEEP_POLICIES, n_trials)
    table: Dict[str, Dict[str, dict]] = {}
    print("\n== Temporal scenarios: policy ranking "
          "(est. completion latency, s) ==")
    for scn, row in out.items():
        print(f"\n-- scenario: {scn} --")
        print(f"{'policy':>8s} {'p50':>8s} {'p95':>8s} {'p99':>8s} "
              f"{'makespan':>9s} {'strag_hit%':>10s}")
        ranked = {}
        for pol, res in row.items():
            ls = analysis.latency_stats(res.latencies)
            ls["makespan"] = analysis.makespan(res)
            ls["hit_frac"] = analysis.straggler_summary(res)["hit_fraction"]
            ranked[pol] = ls
            print(f"{pol:>8s} {ls['p50']:8.2f} {ls['p95']:8.2f} "
                  f"{ls['p99']:8.2f} {ls['makespan']:9.2f} "
                  f"{100 * ls['hit_frac']:10.2f}")
        best = min(ranked, key=lambda p: ranked[p]["p99"])
        print(f"   best p99: {best} "
              f"({ranked[best]['p99'] / max(ranked['rr']['p99'], 1e-9):.2f}x rr)")
        table[scn] = ranked
    return table


def transient_latency_cdf(n_trials: int = 25) -> None:
    """Latency CDF under the transient straggler trace (rr vs trh vs ect)."""
    from repro.core import analysis
    out = _transient_results(n_trials)
    print("\n== Transient stragglers: request latency CDF ==")
    for pol, res in out.items():
        xs, ys = analysis.latency_cdf(res.latencies, 72)
        print(analysis.ascii_plot(
            ys, label=f"CDF P[lat<=x] — {pol} "
                      f"(x: 0..{xs[-1]:.1f}s, p99={analysis.latency_stats(res.latencies)['p99']:.2f}s)"))


def emit_bench_point(path: str = BENCH_PATH,
                     n_trials: int = 25,
                     kernel_scale: int = 100,
                     batch_trials: int = 100) -> dict:
    """Append one perf-trajectory point: the §Perf C phase time per policy,
    the transient-scenario p99 for the log-assisted policies, the
    kernel-backend numbers (wall time of scheduling the 100-OSS transient
    stream through the Pallas backend + req/s for both backends), the
    trial-grid sweep throughput (`kernel_batch_req_s`: the full
    100 OSS x 2000 req x ``batch_trials`` sweep as ONE pallas_call), and
    the sharded-sweep series (`sharded_req_s_{d}d`, DESIGN.md §12) at
    the device counts in ``SCHED_SHARDED_DEVICES`` (comma list, default
    "1,2,4,8"; set empty to skip the subprocess sweeps).
    All throughput cells are medians of ``reps`` repeats (recorded in
    the point).  Points are keyed by ``git_sha``: re-running on the same
    commit REPLACES that commit's point instead of appending a
    duplicate, and each point stamps ``git_dirty`` so points measured
    on an uncommitted tree are distinguishable from their commit's.
    The sort-policy rows carry same-policy engine twins
    (``engine_req_s_{mlml,nltr}``) for the behind-engine flag.
    Reuses this process's cached run_all results."""
    from repro.core import analysis
    point: Dict[str, object] = {"ts": time.time(), "metric_unit": "seconds"}
    # call signatures mirror run_all's rows so the lru_cache hits
    for pol, kw in (("rr", {}), ("trh", {"threshold": 4.0}),
                    ("ect", {"threshold": 0.05})):
        point[f"phase_s_{pol}"] = phase_time(policy=pol, **kw)["phase_s"]
    for pol, res in _transient_results(n_trials).items():
        point[f"transient_p99_{pol}"] = \
            analysis.latency_stats(res.latencies)["p99"]
    thr = kernel_vs_engine_throughput(n_servers=kernel_scale)
    point["kernel_backend_phase_s"] = thr["kernel_s"]
    point["kernel_req_s"] = thr["kernel_req_s"]
    point["engine_req_s"] = thr["jax_req_s"]
    point["kernel_bit_exact"] = thr["bit_exact"]
    point["bench_reps"] = thr["reps"]
    bat = kernel_batch_throughput(n_servers=kernel_scale,
                                  n_trials=batch_trials)
    point["kernel_batch_req_s"] = bat["kernel_batch_req_s"]
    point["kernel_batch_trials"] = bat["n_trials"]
    point["kernel_batch_bit_exact"] = bat.get("batch_bit_exact")
    # sort-based policies through the same trial-grid kernel (§13 fast
    # path), with their SAME-POLICY engine twins so the trajectory's
    # behind-engine flag can fire for them; parity is covered by tests,
    # so skip the lax.map re-check here
    for spol in ("mlml", "nltr"):
        sb = kernel_batch_throughput(n_servers=kernel_scale,
                                     n_trials=batch_trials, policy=spol,
                                     threshold=5.0, check_bit_exact=False,
                                     measure_engine=True)
        point[f"kernel_batch_req_s_{spol}"] = sb["kernel_batch_req_s"]
        point[f"engine_req_s_{spol}"] = sb["engine_batch_req_s"]
    # per_client contention sweeps on the 2-D (trials × clients) grid
    # (DESIGN.md §11): kernel vs the vmapped jax path at {4, 16, 64}
    # clients; 16 is the headline pair tracked by --trajectory and
    # carries the full-scale bit-exactness flag
    for n_c in (4, 16, 64):
        pc = kernel_per_client_throughput(n_servers=kernel_scale,
                                          n_trials=batch_trials,
                                          n_clients=n_c,
                                          check_bit_exact=(n_c == 16))
        suffix = "" if n_c == 16 else f"_{n_c}c"
        point[f"kernel_batch_req_s_per_client{suffix}"] = \
            pc["per_client_kernel_req_s"]
        point[f"engine_req_s_per_client{suffix}"] = \
            pc["per_client_jax_req_s"]
        if n_c == 16:
            point["kernel_per_client_bit_exact"] = \
                pc.get("per_client_bit_exact")
    # batched trial pipeline (DESIGN.md §14): end-to-end run_trials and
    # the prep/sched/post phase breakdown at the 64-client short-stream
    # instance — the case where the lax.map prep/post halo dominated
    pb = per_client_phase_breakdown(n_servers=kernel_scale,
                                    n_trials=batch_trials, n_clients=64)
    for k in ("e2e_req_s_kernel", "e2e_req_s_jax",
              "e2e_seq_req_s_kernel", "e2e_seq_req_s_jax",
              "prep_s", "sched_s_kernel", "sched_s_jax", "post_s",
              "prep_seq_s", "post_seq_s"):
        point[k] = pb[k]
    point["e2e_batched_bit_exact"] = pb["e2e_batched_bit_exact"]
    # sharded sweep series (DESIGN.md §12): the same full-scale sweep
    # through parallel/sweep.py at forced host device counts, one
    # subprocess each; env-gated because each count pays its own
    # compile + warmup
    dev_env = os.environ.get("SCHED_SHARDED_DEVICES", "1,2,4,8")
    devs = tuple(int(t) for t in dev_env.split(",") if t.strip())
    if devs:
        sh = sharded_sweep_throughput(n_servers=kernel_scale,
                                      n_trials=batch_trials, devices=devs)
        for d in devs:
            point[f"sharded_req_s_{d}d"] = sh[f"sharded_req_s_{d}d"]
            point[f"sharded_engine_req_s_{d}d"] = \
                sh[f"sharded_engine_req_s_{d}d"]
        point["sharded_bit_exact"] = bool(
            sh["sharded_bit_exact"] and sh["sharded_cross_backend_exact"])
    # §16 tuned-lowering series: the same full-scale sweeps with tiles
    # resolved through the tuner table (fused-resolver fallback on a
    # cache miss), each with its bit-exact flag vs the default lowering
    # and the tuned/default speedup; the per_client 4-client row is the
    # fused multi-trial block case (28 of 32 sublanes idle at the static
    # default client tile)
    for spol, thr_ in (("ect", 0.05), ("mlml", 5.0), ("nltr", 5.0)):
        tn = tuned_kernel_throughput(n_servers=kernel_scale,
                                     n_trials=batch_trials, policy=spol,
                                     threshold=thr_)
        suffix = "" if spol == "ect" else f"_{spol}"
        point[f"tuned_kernel_req_s{suffix}"] = tn["tuned_req_s"]
        point[f"tuned_speedup{suffix}"] = tn["speedup"]
        point[f"tuned_bit_exact{suffix}"] = tn["tuned_bit_exact"]
    tp = tuned_kernel_throughput(n_servers=kernel_scale,
                                 n_trials=batch_trials, n_clients=4)
    point["tuned_kernel_req_s_per_client_4c"] = tp["tuned_req_s"]
    point["tuned_speedup_per_client_4c"] = tp["speedup"]
    point["tuned_bit_exact_per_client_4c"] = tp["tuned_bit_exact"]
    # §16 per-phase kernel profile: attributes the kernel-vs-engine gap
    # to a NAMED window phase (differential over ablate levels)
    prof = kernel_phase_profile_point(n_servers=kernel_scale,
                                      n_trials=batch_trials)
    for k in ("total_s", "metrics_s", "steps_s", "plan_s", "dispatch_s"):
        point[f"kernel_phase_{k}"] = prof[k]
    point["kernel_gap_phase"] = prof["gap_phase"]
    # contract linter (DESIGN.md §15): lint wall time as a trajectory
    # series plus the clean flag — a point measured on a dirty-contract
    # tree is visibly tainted
    from repro.contractcheck import check_tree, load_config
    t_lint = time.time()
    lint_live = [f for f in check_tree(load_config())
                 if not f.suppressed]
    point["contractcheck_s"] = time.time() - t_lint
    point["contractcheck_clean"] = not lint_live
    _stamp_git(point)
    sha = point.get("git_sha")
    history = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                history = json.load(f)
            if not isinstance(history, list):
                history = [history]
        except (json.JSONDecodeError, OSError):
            history = []
    # one point per commit: a re-run on the same HEAD replaces its
    # earlier point (uncommitted tweaks would otherwise pile up
    # same-sha near-duplicates and skew the delta table)
    if sha:
        history = [p for p in history
                   if not (isinstance(p, dict) and p.get("git_sha") == sha)]
    history.append(point)
    with open(path, "w") as f:
        json.dump(history, f, indent=1)
    print(f"[sched_perf] appended point -> {path} "
          f"(trh phase {point['phase_s_trh']:.2f}s, "
          f"transient p99 {point['transient_p99_trh']:.2f}s)")
    return point


def trajectory(path: str = BENCH_PATH,
               fig_path: str = os.path.join(
                   _REPO_ROOT, "BENCH_sched_trajectory.png")) -> list:
    """Perf trajectory across benchmark runs: stdout table of phase-time
    deltas plus a plotted figure (matplotlib when available, ascii-plot
    file otherwise).  Each `benchmarks/run.py` invocation appends one
    point; this renders the history."""
    from repro.core import analysis
    if not os.path.exists(path):
        print(f"[trajectory] {path} not found — run benchmarks first")
        return []
    # Tolerant history load: a zero-byte / half-written / corrupt file
    # (e.g. an interrupted emit_bench_point) must render as "empty", not
    # crash the whole benchmark report.
    try:
        with open(path) as f:
            history = json.load(f)
    except (json.JSONDecodeError, OSError) as e:
        print(f"[trajectory] {path} is empty or unreadable ({e}) — "
              "run benchmarks first")
        return []
    if not isinstance(history, list):
        history = [history]
    # points must be dicts; anything else (schema drift) is dropped, not
    # crashed on — older points simply miss the newer series
    history = [pt for pt in history if isinstance(pt, dict)]
    if not history:
        print(f"[trajectory] {path} is empty")
        return history

    cols = ("phase_s_rr", "phase_s_trh", "phase_s_ect",
            "transient_p99_trh", "kernel_backend_phase_s")
    # scheduling throughput series (req/s — higher is better); the
    # delta table flags any run where a kernel path fell behind the
    # engine (the regression the trial-grid kernel exists to prevent).
    # Older points predate the later series (kernel_batch_req_s, the
    # sort-policy rows, the per_client 2-D-grid pair) — every access is
    # a tolerant .get.
    thr_cols = ("engine_req_s", "kernel_req_s", "kernel_batch_req_s",
                "tuned_kernel_req_s",
                "kernel_batch_req_s_mlml", "engine_req_s_mlml",
                "tuned_kernel_req_s_mlml",
                "kernel_batch_req_s_nltr", "engine_req_s_nltr",
                "tuned_kernel_req_s_nltr",
                "kernel_batch_req_s_per_client", "engine_req_s_per_client",
                "tuned_kernel_req_s_per_client_4c",
                "e2e_req_s_kernel", "e2e_seq_req_s_kernel",
                "e2e_req_s_jax", "e2e_seq_req_s_jax",
                "sharded_req_s_8d", "sharded_engine_req_s_8d")
    print(f"\n== perf trajectory ({len(history)} runs, {path}) ==")
    # the dirty column marks points measured on an uncommitted tree
    # (git_dirty=true): their numbers are real but must never serve as
    # regression baselines — "·" is clean, "D" is dirty, "?" predates
    # the stamp
    print(f"{'run':>4s} {'when':>16s} {'dirty':>5s} " +
          " ".join(f"{c.replace('phase_s_', 'ph_'):>14s}" for c in cols))
    prev = None
    for i, pt in enumerate(history):
        when = time.strftime("%m-%d %H:%M", time.localtime(pt.get("ts", 0)))
        dirty = ("?" if "git_dirty" not in pt
                 else "D" if pt["git_dirty"] else "·")
        cells = []
        for c in cols:
            v = pt.get(c)
            if v is None:
                cells.append(f"{'—':>14s}")
            elif prev is not None and isinstance(prev.get(c), (int, float)):
                d = v - prev[c]
                cells.append(f"{v:8.2f}{d:+6.2f}")
            else:
                cells.append(f"{v:8.2f}{'':>6s}")
        print(f"{i:>4d} {when:>16s} {dirty:>5s} " + " ".join(cells))
        prev = pt

    # only the SAME-policy kernel series compare against engine_req_s;
    # the sort-policy rows compare against THEIR engine twins
    # (engine_req_s_{mlml,nltr}, emitted since the §13 fast path) and
    # the per_client kernel series against ITS jax twin — flagging any
    # of them against the ect engine number would be apples-to-oranges.
    flag_cols = ("kernel_req_s", "kernel_batch_req_s")
    print(f"\n{'run':>4s} " + " ".join(f"{c:>20s}" for c in thr_cols)
          + "  kernel vs engine")
    for i, pt in enumerate(history):
        eng = pt.get("engine_req_s")
        cells = []
        behind = []
        for c in thr_cols:
            v = pt.get(c)
            cells.append(f"{'—':>20s}" if v is None else f"{v:20.0f}")
            if (v is not None and eng is not None and c in flag_cols
                    and v < eng):
                behind.append(c.replace("_req_s", ""))
        pck = pt.get("kernel_batch_req_s_per_client")
        pce = pt.get("engine_req_s_per_client")
        if pck is not None and pce is not None and pck < pce:
            behind.append("kernel_batch_per_client")
        for spol in ("mlml", "nltr"):
            sk = pt.get(f"kernel_batch_req_s_{spol}")
            se = pt.get(f"engine_req_s_{spol}")
            if sk is not None and se is not None and sk < se:
                behind.append(f"kernel_batch_{spol}")
        # tuned series compare against their UNTUNED kernel twins — the
        # tuner's whole contract is "never slower than the static
        # default lowering"
        for tuned, untuned in (
                ("tuned_kernel_req_s", "kernel_batch_req_s"),
                ("tuned_kernel_req_s_mlml", "kernel_batch_req_s_mlml"),
                ("tuned_kernel_req_s_nltr", "kernel_batch_req_s_nltr"),
                ("tuned_kernel_req_s_per_client_4c",
                 "kernel_batch_req_s_per_client_4c")):
            tk, uk = pt.get(tuned), pt.get(untuned)
            if tk is not None and uk is not None and tk < uk:
                behind.append(tuned.replace("_kernel_req_s", ""))
        # sharded series compare ONLY against the same-device-count
        # engine twin — a 2-device sharded row vs the 1-device engine
        # number would conflate scaling with backend speed
        for d_ct in (2, 4, 8):
            sk = pt.get(f"sharded_req_s_{d_ct}d")
            se = pt.get(f"sharded_engine_req_s_{d_ct}d")
            if sk is not None and se is not None and sk < se:
                behind.append(f"sharded_{d_ct}d")
        # batched-pipeline series compare ONLY against their SAME-backend
        # sequential (lax.map-halo) twin — the regression the §14 batched
        # prep/post exists to prevent is "batched slower than the halo",
        # not "jax e2e slower than kernel e2e"
        for be in ("kernel", "jax"):
            eb = pt.get(f"e2e_req_s_{be}")
            es = pt.get(f"e2e_seq_req_s_{be}")
            if eb is not None and es is not None and eb < es:
                behind.append(f"e2e_batched_{be}")
        flag = ("  <-- " + ", ".join(behind) + " BEHIND baseline"
                if behind else "")
        print(f"{i:>4d} " + " ".join(cells) + flag)

    series = {c: [pt.get(c) for pt in history] for c in cols}
    thr_series = {c: [pt.get(c) for pt in history] for c in thr_cols}
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
        fig, (ax, ax2) = plt.subplots(2, 1, figsize=(8, 7), sharex=True)
        for c in cols:
            ys = series[c]
            if any(v is not None for v in ys):
                ax.plot(range(len(ys)),
                        [float("nan") if v is None else v for v in ys],
                        marker="o", label=c)
        ax.set_ylabel("seconds")
        ax.set_title("scheduler perf trajectory (BENCH_sched.json)")
        ax.legend(fontsize=8)
        for c in thr_cols:
            ys = thr_series[c]
            if any(v is not None for v in ys):
                ax2.plot(range(len(ys)),
                         [float("nan") if v is None else v for v in ys],
                         marker="s", label=c)
        ax2.set_xlabel("benchmark run")
        ax2.set_ylabel("req/s (higher is better)")
        ax2.legend(fontsize=8)
        fig.tight_layout()
        fig.savefig(fig_path, dpi=120)
        print(f"[trajectory] figure -> {fig_path}")
    except ImportError:
        txt_path = fig_path.rsplit(".", 1)[0] + ".txt"
        with open(txt_path, "w") as f:
            for c in cols + thr_cols:
                ys = [v for v in {**series, **thr_series}[c]
                      if v is not None]
                if len(ys) >= 2:
                    f.write(analysis.ascii_plot(
                        np.asarray(ys), label=f"{c} per run") + "\n")
        print(f"[trajectory] matplotlib unavailable; ascii figure -> "
              f"{txt_path}")
    return history


def run_smoke() -> None:
    """CI benchmark smoke: a fast subset proving the host path, the jitted
    sweep, the kernel backend AND the trial-grid dispatch all still run
    (sched_perf --smoke)."""
    print("== sched_perf --smoke ==")
    t0 = time.time()
    r = phase_time(policy="rr", n_files=24)
    e = phase_time(policy="ect", threshold=0.05, n_files=24)
    print(f"  phase_s rr={r['phase_s']:.2f} ect={e['phase_s']:.2f} "
          f"(24 files)")
    assert e["phase_s"] <= r["phase_s"] * 1.05, (e, r)
    thr = kernel_vs_engine_throughput(n_servers=24, n_requests=480,
                                      window_size=60, reps=1)
    assert thr["bit_exact"], "kernel/engine divergence"
    # trial-grid dispatch: T=10 is NOT a multiple of the default tile
    # (8), so the smoke also covers the inert-padded-trial path pre-merge
    bat = kernel_batch_throughput(n_servers=24, n_requests=480,
                                  window_size=60, n_trials=10, reps=1)
    assert bat["batch_bit_exact"], "trial-grid/sequential divergence"
    # a SORT-BASED policy through the batch kernel: the in-VMEM bitonic
    # request sort + section bounds path (DESIGN.md §10) must stay
    # bit-exact vs the lax.map sequential kernel pre-merge
    srt = kernel_batch_throughput(n_servers=24, n_requests=480,
                                  window_size=60, n_trials=10, reps=1,
                                  policy="nltr", threshold=4.0)
    assert srt["batch_bit_exact"], "sort-policy trial-grid divergence"
    # mlml rides the same §13 permutation-apply fast path (all-pairs
    # rank + vectorized sort/unsort applies): bit-exactness AND a
    # timing guard — the fast path keeps a sort policy within a small
    # factor of the ect batch wall time (the pre-§13 bitonic networks
    # sat ~10x behind; 8x leaves headroom for CI jitter at reps=1)
    sml = kernel_batch_throughput(n_servers=24, n_requests=480,
                                  window_size=60, n_trials=10, reps=1,
                                  policy="mlml", threshold=4.0)
    assert sml["batch_bit_exact"], "mlml trial-grid divergence"
    assert sml["batch_s"] <= 8.0 * bat["batch_s"], (
        "mlml batch fell behind the §13 fast-path envelope",
        sml["batch_s"], bat["batch_s"])
    # per_client on the 2-D (trials × clients) grid (DESIGN.md §11):
    # T=10 vs trial tile 8 AND C=5 over client_tile=2 exercise inert
    # trial padding, phantom-client padding AND the multi-block
    # cross-client accumulator; the whole TrialResult must match the
    # jax path (the default tile would clamp to 5 — one block, no pad)
    pc = kernel_per_client_throughput(n_servers=24, n_requests=480,
                                      window_size=60, n_trials=10,
                                      n_clients=5, client_tile=2, reps=1,
                                      check_bit_exact=True)
    assert pc["per_client_bit_exact"], "per_client 2-D grid divergence"
    # merged-p99 lane (DESIGN.md §14): on a small per_client grid the
    # kernel's in-VMEM MET_P99 == the host `nearest_rank_p99` bisection
    # over its merged latency block == the bisection over the jax
    # grouped-block twin rebuilt from the request-order latencies
    import jax
    import jax.numpy as jnp
    from repro.core import engine, policy_core, statlog
    t_g, c_g, m_g, ws_g, per = 2, 3, 12, 4, 8
    lcfg = statlog.LogConfig(n_servers=m_g)
    ko, kl, kk2 = jax.random.split(jax.random.key(9), 3)
    works = engine.Workload(
        jax.random.randint(ko, (t_g, c_g, per), 0, 8 * m_g,
                           dtype=jnp.int32),
        jax.random.uniform(kl, (t_g, c_g, per), minval=1.0, maxval=4.0),
        jnp.ones((t_g, c_g, per), bool).at[:, -1, per // 2:].set(False))
    states = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (t_g, c_g) + a.shape),
        statlog.init_state(lcfg))
    gkeys = jax.vmap(lambda k_: jax.random.split(k_, c_g))(
        jax.random.split(kk2, t_g))
    res_g, _, merged = engine.run_stream_batch(
        states, works, gkeys,
        policy=PolicyConfig(name="ect", threshold=0.05), log_cfg=lcfg,
        window_size=ws_g, backend="kernel")
    host = policy_core.nearest_rank_p99(
        merged.lats.reshape(t_g, -1),
        merged.lats_valid.reshape(t_g, -1) != 0.0)[:, 0]
    assert (np.asarray(merged.metrics[:, policy_core.MET_P99])
            == np.asarray(host)).all(), "kernel MET_P99 != host bisection"
    g_lat, g_val = engine.grouped_latency_block(works, res_g.latencies,
                                                ws_g)
    twin = policy_core.nearest_rank_p99(
        g_lat.reshape(t_g, -1), g_val.reshape(t_g, -1))[:, 0]
    assert (np.asarray(host) == np.asarray(twin)).all(), \
        "kernel merged latency block != jax grouped-block twin"
    print("  merged p99 (in-VMEM block vs host bisection vs jax twin) "
          "bit-exact: True")
    # batched trial pipeline (DESIGN.md §14): the vmapped prep/post
    # stack must equal the lax.map sequential oracle bit-for-bit
    import dataclasses
    from repro.core import simulate
    from repro.core.simulate import ScenarioConfig, SimConfig
    cfg_b = SimConfig(n_servers=24, n_requests=240, n_trials=5,
                      window_size=60, backend="kernel",
                      scenario=ScenarioConfig(name="transient"))
    log_b = simulate.default_log_cfg(cfg_b)
    pol_b = PolicyConfig(name="ect", threshold=0.05)
    key_b = jax.random.key(0)
    r_bat = simulate.run_trials(key_b, cfg_b, pol_b, log_b)
    r_seq = simulate.run_trials(
        key_b, dataclasses.replace(cfg_b, prep="sequential"), pol_b, log_b)
    assert all((np.asarray(getattr(r_bat, f))
                == np.asarray(getattr(r_seq, f))).all()
               for f in r_bat._fields), "batched prep != sequential oracle"
    print("  batched prep/post pipeline bit-exact vs lax.map oracle: True")
    # lowering autotuner (DESIGN.md §16): one tiny tune round into a
    # throwaway table, then a tuned-tiles kernel run must be bit-exact
    # with the DEFAULT-tile jax engine twin — trial_tile is
    # lowering-only and the tuned client tile resolves identically
    # cross-backend (the kernel-key fallback), so tuning can never move
    # a result
    import tempfile
    from repro.tune import autotune
    from repro.tune import profile as tune_profile
    old_tune_path = os.environ.get("SCHED_TUNE_PATH")
    with tempfile.TemporaryDirectory() as td:
        os.environ["SCHED_TUNE_PATH"] = os.path.join(td, "TUNE.json")
        try:
            cfg_t = SimConfig(n_servers=24, n_requests=480, n_trials=10,
                              window_size=60, backend="kernel",
                              scenario=ScenarioConfig(name="transient"))
            log_t = simulate.default_log_cfg(cfg_t)
            pol_t = PolicyConfig(name="ect", threshold=0.05, rng="lcg")
            _, entry = autotune.tune_config(cfg_t, pol_t, reps=1)
            r_tuned = simulate.run_trials(
                jax.random.key(0),
                dataclasses.replace(cfg_t, tiles="tuned"), pol_t, log_t)
            r_twin = simulate.run_trials(
                jax.random.key(0),
                dataclasses.replace(cfg_t, backend="jax"), pol_t, log_t)
            assert all((np.asarray(getattr(r_tuned, f))
                        == np.asarray(getattr(r_twin, f))).all()
                       for f in r_tuned._fields), \
                "tuned-tile kernel != default-tile engine twin"
            print(f"  tuned tiles (tt={entry['trial_tile']}) bit-exact vs "
                  "default-tile engine twin: True")
        finally:
            if old_tune_path is None:
                os.environ.pop("SCHED_TUNE_PATH", None)
            else:
                os.environ["SCHED_TUNE_PATH"] = old_tune_path
    # ablate phase profiling sanity: levels are cumulative, so every
    # differential phase is nonnegative and the full run dominates
    prof = tune_profile.kernel_phase_profile(
        n_servers=24, n_requests=480, window_size=60, n_trials=6, reps=1)
    assert prof["total_s"] > 0 and all(
        prof[k] >= 0 for k in ("metrics_s", "steps_s", "plan_s",
                               "dispatch_s")), prof
    print(f"  ablate phase profile sane (total {prof['total_s']:.3f}s)")
    # sharded sweep (DESIGN.md §12) when the process has devices to
    # shard over (CI's multidevice job forces 8): the whole mesh=(dc,)
    # sweep must be bit-exact vs this process's single-device dispatch,
    # both backends
    import jax
    dc = jax.device_count()
    if dc >= 2:
        from repro.core import simulate
        from repro.core.simulate import ScenarioConfig, SimConfig
        key = jax.random.key(0)
        pol = PolicyConfig(name="ect", threshold=0.05)
        for backend in ("kernel", "jax"):
            res = {}
            for ms in (None, (dc,)):
                cfg = SimConfig(n_servers=24, n_requests=480,
                                window_size=60, n_trials=10,
                                backend=backend, mesh_shape=ms,
                                scenario=ScenarioConfig(name="transient"))
                res[ms] = simulate.run_trials(
                    key, cfg, pol, simulate.default_log_cfg(cfg))
            same = all(
                (np.asarray(getattr(res[None], f))
                 == np.asarray(getattr(res[(dc,)], f))).all()
                for f in ("chosen", "latencies", "phase_time"))
            assert same, f"sharded {backend} sweep != single-device"
            print(f"  sharded mesh=({dc},) {backend} sweep bit-exact vs "
                  f"single-device: True")
    else:
        print("  sharded smoke skipped (1 device; set XLA_FLAGS="
              "--xla_force_host_platform_device_count=N)")
    # contract linter (DESIGN.md §15): the AST layer over the whole
    # scoped surface must be strict-clean pre-merge (the CI `contract`
    # job adds the jaxpr layer); timed so a lint slowdown is visible
    from repro.contractcheck import check_tree, load_config
    t_lint = time.time()
    lint_live = [f for f in check_tree(load_config())
                 if not f.suppressed]
    assert not lint_live, [f.format() for f in lint_live]
    print(f"  contractcheck AST layer strict-clean "
          f"({time.time() - t_lint:.2f}s)")
    _scenario_sweep(("transient",), ("rr", "ect"), 4)
    print(f"[smoke] ok in {time.time() - t0:.1f}s")


def run_all() -> None:
    print("\n== §Perf C: scheduler hillclimb (phase completion time) ==")
    print(f"  ideal (napkin) phase time ~ {ideal_phase_time():.2f}s "
          f"(bytes / healthy aggregate, floored by srv5 queue)")
    print(f"{'iter':>28s} {'phase_s':>8s} {'strag_hits':>10s} "
          f"{'probes':>7s} {'redirects':>9s}")

    def row(tag, **kw):
        r = phase_time(**kw)
        print(f"{tag:>28s} {r['phase_s']:8.2f} "
              f"{r['straggler_hits']:10d} {r['probes']:7d} "
              f"{r['redirect_entries']:9d}")
        return r

    row("baseline rr", policy="rr")
    row("two_choice (SC'14, probes)", policy="two_choice")
    row("trh thr=64 (too shy)", policy="trh", threshold=64.0)
    row("trh thr=16", policy="trh", threshold=16.0)
    row("trh thr=4", policy="trh", threshold=4.0)
    row("trh thr=0.5 (eager)", policy="trh", threshold=0.5)
    row("mlml thr=4", policy="mlml", threshold=4.0)
    row("nltr thr=4", policy="nltr", threshold=4.0)
    row("trh stripe=16MB (coarse)", policy="trh", stripe_mb=16.0)
    row("trh stripe=1MB (fine)", policy="trh", stripe_mb=1.0)
    row("trh thr=4 + warm probs", policy="trh", threshold=4.0,
        warm_probs=True)
    row("mlml thr=4 + warm probs", policy="mlml", threshold=4.0,
        warm_probs=True)
    row("nltr thr=4 + warm probs", policy="nltr", threshold=4.0,
        warm_probs=True)
    row("trh + prob refresh/window", policy="trh", threshold=4.0,
        warm_probs=True, refresh=True)
    row("mlml + prob refresh/window", policy="mlml", threshold=4.0,
        warm_probs=True, refresh=True)
    row("nltr + prob refresh/window", policy="nltr", threshold=4.0,
        warm_probs=True, refresh=True)
    row("ect thr=0.05s (rate-aware)", policy="ect", threshold=0.05)
    row("ect + fine stripes", policy="ect", threshold=0.05, stripe_mb=1.0)
    row("ect cold log (no snapshot)", policy="ect", threshold=0.05,
        know_loads=False)

    scenario_ranking()
    transient_latency_cdf()
    # keyword calls match emit_bench_point's exactly so the lru_cache hits
    kernel_vs_engine_throughput(n_servers=100)
    kernel_batch_throughput(n_servers=100, n_trials=100)
    for spol in ("mlml", "nltr"):
        kernel_batch_throughput(n_servers=100, n_trials=100, policy=spol,
                                threshold=5.0, check_bit_exact=False,
                                measure_engine=True)
    for n_c in (4, 16, 64):
        kernel_per_client_throughput(n_servers=100, n_trials=100,
                                     n_clients=n_c,
                                     check_bit_exact=(n_c == 16))
    for spol, thr_ in (("ect", 0.05), ("mlml", 5.0), ("nltr", 5.0)):
        tuned_kernel_throughput(n_servers=100, n_trials=100, policy=spol,
                                threshold=thr_)
    tuned_kernel_throughput(n_servers=100, n_trials=100, n_clients=4)
    kernel_phase_profile_point(n_servers=100, n_trials=100)


if __name__ == "__main__":
    # --profile-trace [dir]: wrap the selected mode in a jax.profiler
    # trace (viewable with tensorboard/perfetto) — opt-in because trace
    # files are large and tracing perturbs the wall numbers
    _ctx = None
    if "--profile-trace" in sys.argv:
        _i = sys.argv.index("--profile-trace")
        _dir = (sys.argv[_i + 1]
                if len(sys.argv) > _i + 1
                and not sys.argv[_i + 1].startswith("--")
                else os.path.join(_REPO_ROOT, "profile_trace"))
        import jax
        _ctx = jax.profiler.trace(_dir)
        print(f"[sched_perf] jax.profiler trace -> {_dir}")
        _ctx.__enter__()
    try:
        if "--sharded-worker" in sys.argv:
            _sharded_worker(
                json.loads(sys.argv[sys.argv.index("--sharded-worker") + 1]))
        elif "--smoke" in sys.argv:
            run_smoke()
        elif "--trajectory" in sys.argv:
            trajectory()
        else:
            run_all()
            emit_bench_point()
    finally:
        if _ctx is not None:
            _ctx.__exit__(None, None, None)
