"""Reproduce the paper's §4 evaluation (Figs. 12-18 + probe table).

    PYTHONPATH=src python examples/paper_simulation.py [--full]

``--full`` uses the paper's exact scale (100 OSSs, 200 clients, 2,000
requests, 100 trials); default is a faster configuration with the same
structure.  See benchmarks/paper_figs.py for the underlying harness.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks import paper_figs  # noqa: E402

if __name__ == "__main__":
    paper_figs.run_all(full="--full" in sys.argv)
