"""Quickstart: train a small LM end-to-end with straggler-aware checkpoints.

    PYTHONPATH=src python examples/quickstart.py

What it shows, in ~2 minutes on CPU:
  1. pick an assigned architecture (reduced config) from the registry;
  2. train a few hundred steps on the deterministic synthetic pipeline;
  3. checkpoint every 50 steps THROUGH the paper's scheduler (each shard is
     striped into objects placed by the TRH policy against the client-side
     statistic log — zero probe messages);
  4. kill the "job", restore from the newest committed checkpoint, and
     continue — bitwise-identical to an uninterrupted run.
"""

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.checkpoint import CheckpointConfig, Checkpointer
from repro.configs import get_config
from repro.core.policies import PolicyConfig
from repro.data import DataConfig, SyntheticTokens
from repro.io import IOClientConfig
from repro.io.striping import MB
from repro.train import OptConfig, init_state, make_train_step

STEPS, CKPT_EVERY, KILL_AT = 200, 50, 120


def main():
    cfg = get_config("gemma-2b", reduced=True)
    opt = OptConfig(peak_lr=3e-3, warmup_steps=20, total_steps=STEPS)
    pipe = SyntheticTokens(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                      global_batch=8, seed=0))
    step_fn = jax.jit(make_train_step(cfg, opt))

    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, n_servers=8, cfg=CheckpointConfig(
            shard_size_mb=1.0, keep_n=2, async_save=True,
            io=IOClientConfig(policy=PolicyConfig("trh", threshold=0.5),
                              stripe_size=MB // 2)))

        print(f"== training {cfg.name} for {STEPS} steps "
              f"(kill at {KILL_AT}) ==")
        state = init_state(jax.random.key(0), cfg)
        for i in range(KILL_AT):
            state, m = step_fn(state, pipe.batch_at(i))
            if (i + 1) % CKPT_EVERY == 0:
                ck.save(i + 1, state, block=False)
            if (i + 1) % 40 == 0:
                print(f"  step {i+1:4d} loss={float(m['loss']):.4f}")
        ck.wait_until_finished()
        print(f"!! job killed at step {KILL_AT}; newest committed "
              f"checkpoint: step {ck.latest_step()}")
        del state

        template = jax.tree.map(np.zeros_like,
                                init_state(jax.random.key(0), cfg))
        state = ck.restore(target=template)
        start = int(np.asarray(state.step))
        print(f"== restored at step {start}; resuming ==")
        for i in range(start, STEPS):
            state, m = step_fn(state, pipe.batch_at(i))
            if (i + 1) % 40 == 0:
                print(f"  step {i+1:4d} loss={float(m['loss']):.4f}")
        ck.save(STEPS, state)

        stats = ck.client.stats()
        print("== done ==")
        print(f"  final loss           : {float(m['loss']):.4f}")
        print(f"  checkpoint objects   : {int(stats['writes'])} "
              f"({stats['total_mb']:.1f} MB)")
        print(f"  probe messages       : {int(stats['probe_messages'])} "
              f"(log-assisted scheduling)")
        print(f"  redirect rate        : {stats['redirect_rate']:.2f}")
        ck.close()


if __name__ == "__main__":
    main()
