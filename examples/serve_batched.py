"""Batched serving example: prefill + greedy decode with ring KV caches.

    PYTHONPATH=src python examples/serve_batched.py [--arch mixtral-8x22b]

Runs the reduced config of an assigned architecture through the serving
path: batch of prompts -> decode loop -> tokens/s.  The production-mesh
version of the same step function is what ``decode_32k`` / ``long_500k``
dry-run cells lower (see repro/launch/dryrun.py).
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import serve  # noqa: E402

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x22b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    args.reduced = True
    serve.serve(args)
