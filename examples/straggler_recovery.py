"""Straggler + node-failure recovery on the checkpoint write path.

    PYTHONPATH=src python examples/straggler_recovery.py

Scenario (the paper's Fig. 1, on a real local object store):
  * 8 object storage servers; server 2 becomes a straggler (slow writes),
    server 5 dies outright mid-run;
  * a training job checkpoints through (a) round-robin placement and
    (b) the log-assisted ECT policy;
  * the scheduler masks the dead server after the first failed write,
    retries on the next-best target, and steers bytes away from the
    straggler — RR keeps paying the straggler tax on every save;
  * after the incident, the metadata maintainer migrates redirected
    objects back to their default homes (redirect tables drain to zero).
"""

import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.checkpoint import CheckpointConfig, Checkpointer
from repro.configs import get_config
from repro.core.policies import PolicyConfig
from repro.data import DataConfig, SyntheticTokens
from repro.io import IOClientConfig, MaintainerThread
from repro.io.striping import MB
from repro.train import OptConfig, init_state, make_train_step


def run(policy: str) -> dict:
    cfg = get_config("stablelm-1.6b", reduced=True)
    pipe = SyntheticTokens(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                      global_batch=4, seed=0))
    step_fn = jax.jit(make_train_step(cfg, OptConfig(peak_lr=1e-3)))
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, n_servers=8, cfg=CheckpointConfig(
            shard_size_mb=0.5, keep_n=10,
            io=IOClientConfig(policy=PolicyConfig(policy, threshold=0.02),
                              stripe_size=MB // 4)))
        ck.store.set_write_delay(2, 0.2)       # straggler: 200 ms/MB
        state = init_state(jax.random.key(0), cfg)
        t0 = time.time()
        for i in range(6):
            state, _ = step_fn(state, pipe.batch_at(i))
            if i == 3:
                ck.store.fail_server(5)        # node dies mid-run
            ck.save(i + 1, state)
        wall = time.time() - t0
        stats = ck.client.stats()
        per_server = []
        for s in range(8):
            sd = os.path.join(d, "objects", f"server_{s:04d}")
            per_server.append(sum(
                os.path.getsize(os.path.join(sd, f))
                for f in os.listdir(sd) if f.endswith(".bin")) / MB)
        # restore works even with server 5 still dead
        template = jax.tree.map(np.zeros_like,
                                init_state(jax.random.key(0), cfg))
        restored = ck.restore(target=template)
        assert int(np.asarray(restored.step)) == 6

        # heal + let the maintainer migrate redirected objects home
        ck.store.heal_server(5)
        mt = MaintainerThread(ck.store, interval_s=0.01, max_objects=64)
        mt.start()
        deadline = time.time() + 10
        while ck.store.redirect_count() and time.time() < deadline:
            time.sleep(0.05)
        mt.stop()
        redirects_left = ck.store.redirect_count()
        ck.close()
        return {"wall_s": wall, "stats": stats, "per_server_mb": per_server,
                "redirects_after_maintainer": redirects_left}


def main():
    print("== checkpointing under a straggler (srv 2) + failure (srv 5) ==")
    for policy in ("rr", "ect"):
        r = run(policy)
        st = r["stats"]
        mb = r["per_server_mb"]
        print(f"\npolicy={policy}")
        print(f"  wall time          : {r['wall_s']:.2f}s")
        print(f"  failed writes      : {int(st['failed_writes'])} "
              f"(retried on next-best server)")
        print(f"  probe messages     : {int(st['probe_messages'])}")
        print(f"  MB on straggler(2) : {mb[2]:.1f}")
        print(f"  MB on dead srv (5) : {mb[5]:.1f}")
        print(f"  MB per server      : " +
              " ".join(f"{x:5.1f}" for x in mb))
        print(f"  redirects after maintainer: "
              f"{r['redirects_after_maintainer']}")


if __name__ == "__main__":
    main()
