"""repro — log-assisted straggler-aware I/O scheduling (Tavakoli, Dai &
Chen, 2018) built out as a multi-pod JAX training/inference framework.

Subpackages:
    core        the paper's contribution (statistic log, Eqs. 1-3,
                RR/MLML/TRH/nLTR policies, window/step engine, simulator)
    io          object-storage substrate (striping, redirect tables,
                simulated + local-FS stores, scheduler client)
    checkpoint  sharded atomic checkpoints through the scheduler
    data        deterministic step-indexed pipelines
    models      10-architecture model substrate (GQA/MoE/SSM/xLSTM/enc-dec)
    parallel    logical-axis sharding rules
    train       optimizer, step functions, gradient compression
    kernels     Pallas TPU kernels (flash attention, scheduler select)
    configs     assigned architecture x shape registry
    launch      mesh, dry-run, train, serve drivers
"""

__version__ = "1.0.0"
