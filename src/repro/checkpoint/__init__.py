"""repro.checkpoint — sharded atomic checkpoints written through the
paper's straggler-aware I/O scheduler."""

from repro.checkpoint.manifest import (  # noqa: F401
    LeafEntry, Manifest, ShardEntry, committed_steps, flatten_with_paths,
    load_manifest, unflatten_like,
)
from repro.checkpoint.checkpointer import (  # noqa: F401
    CheckpointConfig, Checkpointer,
)
