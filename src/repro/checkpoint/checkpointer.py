"""Sharded, atomic, async checkpointing through the paper's I/O scheduler.

Every checkpoint shard is a "file" handed to :class:`repro.io.IOClient`:
it gets striped into objects, and each object is *scheduled* onto an object
storage server by the log-assisted straggler-aware policy — checkpointing
is exactly the HPC synchronous-write workload the paper targets (thousands
of hosts flushing state behind a barrier, gated by the slowest OSS).

Scale/fault-tolerance features (DESIGN.md §7):

* **atomic commit** — shards, then manifest, then COMMIT marker; a save
  killed anywhere leaves the previous checkpoint authoritative;
* **async save** — leaves are snapshotted to host memory synchronously,
  bytes written on a background thread; ``wait_until_finished()`` is the
  barrier (overlaps checkpoint I/O with compute);
* **failure retry** — a write landing on a failed server is masked +
  re-scheduled by the client (next-best server per the log);
* **elastic restore** — leaves are reassembled on host and re-``device_put``
  with *any* target sharding, so a job can restart on a different mesh;
* **GC** — ``keep_n`` newest committed steps are retained.
"""

from __future__ import annotations

import dataclasses
import os
import threading
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.checkpoint import manifest as M
from repro.io.client import IOClient, IOClientConfig
from repro.io.objectstore import MB, LocalFSStore


@dataclasses.dataclass(frozen=True)
class CheckpointConfig:
    shard_size_mb: float = 8.0     # split big leaves into this many MB
    keep_n: int = 3
    async_save: bool = False
    io: IOClientConfig = IOClientConfig()


class Checkpointer:
    """Save/restore pytrees against an object store via the scheduler."""

    def __init__(self, root: str, n_servers: int = 16,
                 cfg: CheckpointConfig = CheckpointConfig(),
                 store=None, seed: int = 0):
        self.root = root
        self.manifest_dir = os.path.join(root, "manifests")
        self.store = store if store is not None else LocalFSStore(
            os.path.join(root, "objects"), n_servers)
        self.cfg = cfg
        self.client = IOClient(self.store, cfg.io, seed=seed)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def _shard_bytes(self, buf: bytes) -> List[bytes]:
        step_len = max(int(self.cfg.shard_size_mb * MB), 1 * MB)
        return [buf[i:i + step_len] for i in range(0, max(len(buf), 1), step_len)]

    def _write_tree(self, step: int, named_leaves, meta: Dict[str, Any]) -> None:
        leaves_meta: List[M.LeafEntry] = []
        for li, (path, arr) in enumerate(named_leaves):
            buf = arr.tobytes()
            shards: List[M.ShardEntry] = []
            pos = 0
            for si, chunk in enumerate(self._shard_bytes(buf)):
                fid = M.file_id_for(step, li, si)
                self.client.write_file(fid, chunk if chunk else b"\x00")
                shards.append(M.ShardEntry(
                    file_id=fid, byte_start=pos, byte_len=len(chunk),
                    checksum=M.checksum(chunk)))
                pos += len(chunk)
            leaves_meta.append(M.LeafEntry(
                path=path, shape=tuple(arr.shape), dtype=str(arr.dtype),
                nbytes=len(buf), shards=shards))
        self.client.flush()
        man = M.Manifest(step=step, leaves=leaves_meta, meta=meta)
        M.write_manifest(self.manifest_dir, man)
        M.commit(self.manifest_dir, step)
        self._gc()

    def save(self, step: int, tree, meta: Optional[Dict[str, Any]] = None,
             block: Optional[bool] = None) -> None:
        """Checkpoint ``tree`` at ``step``.  ``block=False`` (or
        ``cfg.async_save``) returns after the host snapshot; the bytes are
        written on a background thread."""
        import jax
        self.wait_until_finished()
        meta = dict(meta or {})
        meta.setdefault("step", step)
        # snapshot to host memory synchronously (consistency point)
        named = [(p, np.asarray(jax.device_get(a)))
                 for p, a in M.flatten_with_paths(tree)]
        asynchronous = self.cfg.async_save if block is None else not block
        if not asynchronous:
            self._write_tree(step, named, meta)
            return

        def run():
            try:
                self._write_tree(step, named, meta)
            except BaseException as e:  # surfaced at the next barrier
                self._error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait_until_finished(self) -> None:
        """Async-save barrier; re-raises any background failure."""
        t, self._thread = self._thread, None
        if t is not None:
            t.join()
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = M.committed_steps(self.manifest_dir)
        for s in steps[:-self.cfg.keep_n] if self.cfg.keep_n > 0 else []:
            man = M.load_manifest(self.manifest_dir, s)
            M.remove_step(self.manifest_dir, s)
            for leaf in man.leaves:
                for sh in leaf.shards:
                    for req in self._stripe(sh):
                        try:
                            self.store.delete_object(req.object_id)
                        except Exception:
                            pass

    def _stripe(self, sh: M.ShardEntry):
        from repro.io import striping
        return striping.stripe_file(self.client.striping, sh.file_id,
                                    max(sh.byte_len, 1))

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        steps = M.committed_steps(self.manifest_dir)
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, target=None,
                shardings=None, strict_checksum: bool = True):
        """Restore a checkpoint.

        * ``target``     — pytree giving the structure (and, if
          ``shardings`` is None, the shardings) to restore onto.  With no
          target, returns ``{path: np.ndarray}``.
        * ``shardings``  — optional pytree of ``jax.sharding.Sharding`` (or
          a callable ``path -> sharding``) for elastic restore onto a new
          mesh.
        """
        import jax
        self.wait_until_finished()
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no committed checkpoint in {self.root}")
        man = M.load_manifest(self.manifest_dir, step)
        named: Dict[str, np.ndarray] = {}
        for leaf in man.leaves:
            buf = bytearray(leaf.nbytes)
            for sh in leaf.shards:
                data = self.client.read_file(sh.file_id, max(sh.byte_len, 1))
                data = data[:sh.byte_len]
                if strict_checksum and M.checksum(bytes(data)) != sh.checksum:
                    raise IOError(f"checksum mismatch for {leaf.path} "
                                  f"shard {sh.file_id:#x}")
                buf[sh.byte_start:sh.byte_start + sh.byte_len] = data
            arr = np.frombuffer(bytes(buf), dtype=leaf.dtype).reshape(leaf.shape)
            named[leaf.path] = arr
        if target is None:
            return named
        restored = M.unflatten_like(target, named)
        if shardings is not None:
            if callable(shardings):
                flat = M.flatten_with_paths(target)
                shardings = M.unflatten_like(
                    target, {p: shardings(p) for p, _ in flat})
            restored = jax.tree.map(jax.device_put, restored, shardings)
        else:
            # adopt target leaves' shardings when they are concrete arrays
            def put(new, old):
                if isinstance(old, jax.Array) and hasattr(old, "sharding"):
                    return jax.device_put(new, old.sharding)
                return new
            restored = jax.tree.map(put, restored, target)
        return restored

    def manifest(self, step: int) -> M.Manifest:
        return M.load_manifest(self.manifest_dir, step)

    def close(self) -> None:
        self.wait_until_finished()
        self.client.close()
