"""Checkpoint manifests: the metadata side of sharded, atomic checkpoints.

A checkpoint at step ``s`` is a set of *shards* (each shard = one "file"
written through the straggler-aware I/O client, i.e. striped into objects
and scheduled via the statistic log) plus one JSON manifest describing how
to reassemble every pytree leaf.  Commit protocol (crash safety):

    1. write all shards;
    2. write ``manifest-<step>.json``;
    3. write the empty ``COMMIT-<step>`` marker  (atomic rename).

A restore only ever considers steps whose COMMIT marker exists, so a save
killed at any point is simply invisible (tests kill a save mid-flight).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.compat import simple_keystr


def file_id_for(step: int, leaf_index: int, shard_index: int) -> int:
    """Stable 63-bit file id for a checkpoint shard."""
    h = hashlib.blake2b(f"ckpt/{step}/{leaf_index}/{shard_index}".encode(),
                        digest_size=8).digest()
    return int.from_bytes(h, "big") & 0x7FFFFFFFFFFFFFFF


@dataclasses.dataclass
class ShardEntry:
    """One contiguous byte-range of one leaf's flattened buffer."""

    file_id: int
    byte_start: int
    byte_len: int
    checksum: str  # blake2b-64 hex of the shard bytes


@dataclasses.dataclass
class LeafEntry:
    path: str                  # '/'-joined pytree key path
    shape: Tuple[int, ...]
    dtype: str
    nbytes: int
    shards: List[ShardEntry]


@dataclasses.dataclass
class Manifest:
    step: int
    leaves: List[LeafEntry]
    meta: Dict[str, Any]       # free-form (mesh shape, config digest, ...)
    format_version: int = 1

    def to_json(self) -> str:
        return json.dumps({
            "format_version": self.format_version,
            "step": self.step,
            "meta": self.meta,
            "leaves": [{
                "path": l.path, "shape": list(l.shape), "dtype": l.dtype,
                "nbytes": l.nbytes,
                "shards": [dataclasses.asdict(s) for s in l.shards],
            } for l in self.leaves],
        }, indent=1)

    @staticmethod
    def from_json(text: str) -> "Manifest":
        d = json.loads(text)
        return Manifest(
            step=d["step"], meta=d.get("meta", {}),
            format_version=d.get("format_version", 1),
            leaves=[LeafEntry(
                path=l["path"], shape=tuple(l["shape"]), dtype=l["dtype"],
                nbytes=l["nbytes"],
                shards=[ShardEntry(**s) for s in l["shards"]],
            ) for l in d["leaves"]])


def checksum(data: bytes) -> str:
    return hashlib.blake2b(data, digest_size=8).hexdigest()


# --- manifest directory protocol (plain local dir next to the store) -------

def manifest_path(root: str, step: int) -> str:
    return os.path.join(root, f"manifest-{step:010d}.json")


def commit_path(root: str, step: int) -> str:
    return os.path.join(root, f"COMMIT-{step:010d}")


def write_manifest(root: str, m: Manifest) -> None:
    os.makedirs(root, exist_ok=True)
    tmp = manifest_path(root, m.step) + ".tmp"
    with open(tmp, "w") as f:
        f.write(m.to_json())
    os.replace(tmp, manifest_path(root, m.step))


def commit(root: str, step: int) -> None:
    tmp = commit_path(root, step) + ".tmp"
    with open(tmp, "w"):
        pass
    os.replace(tmp, commit_path(root, step))


def committed_steps(root: str) -> List[int]:
    if not os.path.isdir(root):
        return []
    steps = []
    for name in os.listdir(root):
        if name.startswith("COMMIT-"):
            try:
                s = int(name.split("-", 1)[1])
            except ValueError:
                continue
            if os.path.exists(manifest_path(root, s)):
                steps.append(s)
    return sorted(steps)


def load_manifest(root: str, step: int) -> Manifest:
    with open(manifest_path(root, step)) as f:
        return Manifest.from_json(f.read())


def remove_step(root: str, step: int) -> None:
    for p in (commit_path(root, step), manifest_path(root, step)):
        try:
            os.remove(p)
        except FileNotFoundError:
            pass


# --- pytree <-> flat path helpers ------------------------------------------

def flatten_with_paths(tree) -> List[Tuple[str, np.ndarray]]:
    """Flatten a pytree to [(path_str, leaf)] with stable, readable paths."""
    import jax
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for kp, leaf in flat:
        out.append((simple_keystr(kp), leaf))
    return out


def unflatten_like(target, named: Dict[str, np.ndarray]):
    """Map {path: array} back onto the structure of ``target``."""
    import jax
    flat, treedef = jax.tree_util.tree_flatten_with_path(target)
    leaves = []
    for kp, old in flat:
        path = simple_keystr(kp)
        if path not in named:
            raise KeyError(f"checkpoint missing leaf {path!r}")
        leaves.append(named[path])
    return jax.tree_util.tree_unflatten(treedef, leaves)
