"""JAX version-tolerance shims.

The repo targets current JAX APIs; the container (and some CI images) run
older releases.  Everything version-sensitive funnels through here so the
rest of the code reads as if on the newest API:

* ``simple_keystr``  — ``jax.tree_util.keystr(kp, simple=True,
  separator="/")`` (newer JAX) on any version.  Checkpoint manifests,
  sharding rules and the optimizer's decay mask key off these stable
  path strings.
* ``make_mesh``      — ``jax.make_mesh`` with ``axis_types=Auto``
  (newer JAX) falling back to the positional form.
* ``shard_map``      — ``jax.shard_map`` falling back to
  ``jax.experimental.shard_map.shard_map``.
* ``shard_map_unchecked`` — ``shard_map`` with the static replication
  check disabled on every version (``check_rep=False`` on older
  releases, ``check_vma=False`` after the rename).
"""

from __future__ import annotations

import jax
import jax.tree_util as jtu

try:  # newer JAX: top-level shard_map
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - version-dependent
    from jax.experimental.shard_map import shard_map  # noqa: F401


def _simple_key(k) -> str:
    if isinstance(k, jtu.GetAttrKey):
        return k.name
    if isinstance(k, jtu.DictKey):
        return str(k.key)
    if isinstance(k, jtu.SequenceKey):
        return str(k.idx)
    if isinstance(k, jtu.FlattenedIndexKey):
        return str(k.key)
    return str(k)


def simple_keystr(kp, separator: str = "/") -> str:
    """`keystr(kp, simple=True, separator=...)` on every JAX version."""
    try:
        return jtu.keystr(kp, simple=True, separator=separator)
    except TypeError:
        return separator.join(_simple_key(k) for k in kp)


def shard_map_unchecked(f, mesh, in_specs, out_specs):
    """``shard_map`` with the static replication check disabled.

    The sharded sweep (parallel/sweep.py) replicates its merged outputs
    across the client mesh axis via ``all_gather`` + the pinned
    ``policy_core.tree_sum`` fold; the checker cannot infer replication
    through tree_sum's pad/slice ops and rejects the ``out_specs``, so
    the check is turned off (the replication is real: every device
    gathers identical operands and folds them with the same
    deterministic tree).  Newer JAX renamed ``check_rep`` to
    ``check_vma`` — try both so either version works.
    """
    try:
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)
    except TypeError:  # pragma: no cover - version-dependent
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)


def make_mesh(shape, axis_names):
    """`jax.make_mesh` with explicit Auto axis types where supported."""
    try:
        return jax.make_mesh(
            shape, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axis_names)
