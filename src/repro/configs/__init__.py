"""repro.configs — assigned architectures x input shapes."""

from repro.configs.registry import ARCH_IDS, all_configs, get_config  # noqa: F401
from repro.configs.shapes import (  # noqa: F401
    SHAPES, SMOKE_SHAPES, ShapeSpec, input_specs, is_subquadratic,
    shape_applies,
)
