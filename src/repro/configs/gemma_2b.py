"""gemma-2b [dense] — 18L d_model=2048 8H (MQA kv=1) d_ff=16384
vocab=256000; GeGLU, head_dim=256, tied embeddings scaled by sqrt(d)
[arXiv:2403.08295; hf]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    activation="geglu",
    tie_embeddings=True,
    embed_scale=True,
    rope_theta=1e4,
    notes="MQA; GeGLU; head_dim=256; tied+scaled embeddings",
)

REDUCED = ModelConfig(
    name="gemma-2b-reduced",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    activation="geglu",
    tie_embeddings=True,
    embed_scale=True,
    rope_theta=1e4,
)
