"""h2o-danube-3-4b [dense] — 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000; llama+mistral mix with sliding-window attention
[arXiv:2401.16818; unverified]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    sliding_window=4096,
    rope_theta=5e5,
    notes="SWA 4096; head_dim=120 (non-128 MXU note in DESIGN.md)",
)

REDUCED = ModelConfig(
    name="h2o-danube-3-4b-reduced",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=160,
    vocab_size=512,
    sliding_window=16,
    rope_theta=5e5,
)
