"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2; Mamba:attention 7:1 interleave, MoE every
other layer [arXiv:2403.19887; hf].

Group of 8 = [mamba x4, attn, mamba x3] (attn_layer_offset=4, period=8);
MoE on odd layers (expert_layer_offset=1, period=2).  No positional
encoding (use_rope=False), as in the paper.
"""

from repro.models.config import ModelConfig, MoEConfig, SSMConfig

_PATTERN = ("mamba", "mamba", "mamba", "mamba",
            "attn", "mamba", "mamba", "mamba")

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    group_pattern=_PATTERN,
    # local dispatch: EXPERIMENTS.md §Perf A (2.0x roofline fraction)
    moe=MoEConfig(n_experts=16, top_k=2, every_n_layers=2,
                  dispatch="local"),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, chunk=128),
    use_rope=False,
    notes="hybrid 1:7 attn:mamba; MoE 16e top-2 every other layer; NoPE",
)

REDUCED = ModelConfig(
    name="jamba-v0.1-52b-reduced",
    n_layers=8,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=160,
    vocab_size=512,
    group_pattern=_PATTERN,
    moe=MoEConfig(n_experts=4, top_k=2, every_n_layers=2),
    ssm=SSMConfig(d_state=4, d_conv=4, expand=2, chunk=16),
    use_rope=False,
)
