"""llama4-scout-17b-a16e [moe] — 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 every layer; chunked-local attention
(8192) with a global NoPE layer every 4th; early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]."""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    # groups of 4 express the same layer sequence but let decode caches
    # size per position: only the every-4th global layer gets a full-
    # length ring (EXPERIMENTS.md §Perf D: 2.9-5.6x decode memory)
    group_pattern=("attn", "attn", "attn", "attn"),
    moe=MoEConfig(n_experts=16, top_k=1, every_n_layers=1,
                  dispatch="local"),
    chunk_attn=8192,
    global_every=4,
    rope_theta=5e5,
    notes="MoE 16e top-1; chunked-local 8192 + global NoPE every 4th; "
          "40 heads not divisible by 16-way TP -> attn weights FSDP-only",
)

REDUCED = ModelConfig(
    name="llama4-scout-17b-a16e-reduced",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    moe=MoEConfig(n_experts=4, top_k=1, every_n_layers=1),
    chunk_attn=16,
    global_every=4,
    rope_theta=5e5,
)
