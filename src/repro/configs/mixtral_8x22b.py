"""mixtral-8x22b [moe] — 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2 every layer, SWA [arXiv:2401.04088; hf]."""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    # dispatch="local": per-DP-shard capacity pools (EXPERIMENTS.md §Perf
    # A, 2.3x roofline fraction); "global" reproduces the baseline
    moe=MoEConfig(n_experts=8, top_k=2, every_n_layers=1,
                  dispatch="local"),
    sliding_window=4096,
    rope_theta=1e6,
    notes="MoE 8e top-2 all layers; SWA 4096",
)

REDUCED = ModelConfig(
    name="mixtral-8x22b-reduced",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    moe=MoEConfig(n_experts=4, top_k=2, every_n_layers=1),
    sliding_window=16,
    rope_theta=1e6,
)
