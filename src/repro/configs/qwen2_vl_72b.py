"""qwen2-vl-72b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064; M-RoPE + dynamic resolution [arXiv:2409.12191; hf].

Backbone only: the vision tower is a STUB — ``input_specs`` provides
precomputed patch embeddings for the first P token slots plus the
(3, B, S) M-RoPE position streams (temporal / height / width).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    mrope=True,
    mrope_sections=(16, 24, 24),
    rope_theta=1e6,
    notes="M-RoPE (16,24,24); vision tower stubbed",
)

REDUCED = ModelConfig(
    name="qwen2-vl-72b-reduced",
    n_layers=4,
    d_model=96,
    n_heads=8,
    n_kv_heads=2,
    d_ff=192,
    vocab_size=512,
    qkv_bias=True,
    mrope=True,
    mrope_sections=(2, 2, 2),
    rope_theta=1e6,
)
