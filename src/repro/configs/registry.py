"""Architecture registry: ``--arch <id>`` -> ModelConfig (+ reduced twin)."""

from __future__ import annotations

import importlib
from typing import Dict, List, Tuple

from repro.models.config import ModelConfig

_MODULES: Dict[str, str] = {
    "whisper-tiny": "repro.configs.whisper_tiny",
    "qwen2-72b": "repro.configs.qwen2_72b",
    "h2o-danube-3-4b": "repro.configs.h2o_danube3_4b",
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "gemma-2b": "repro.configs.gemma_2b",
    "qwen2-vl-72b": "repro.configs.qwen2_vl_72b",
    "xlstm-1.3b": "repro.configs.xlstm_1_3b",
    "jamba-v0.1-52b": "repro.configs.jamba_v01_52b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCH_IDS}")
    mod = importlib.import_module(_MODULES[arch])
    return mod.REDUCED if reduced else mod.CONFIG


def all_configs(reduced: bool = False) -> Dict[str, ModelConfig]:
    return {a: get_config(a, reduced) for a in ARCH_IDS}
