"""Assigned input shapes and ShapeDtypeStruct builders for the dry-run.

Four shapes per LM architecture (seq_len x global_batch):

    train_4k     4,096 x 256    training       -> lowers train_step
    prefill_32k  32,768 x 32    inference      -> lowers prefill_step
    decode_32k   32,768 x 128   decode         -> lowers decode_step
                                                   (1 token, 32k KV cache)
    long_500k    524,288 x 1    long-context   -> decode_step; only for
                                                   sub-quadratic archs

``input_specs`` returns (args, in_roles): ``args`` are ShapeDtypeStructs
(weak-type correct, zero allocation); ``in_roles`` mirror them with logical
sharding roles that ``repro.launch.dryrun`` resolves against the active
mesh (tokens -> batch, cache seq -> "model" axis, etc.).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models import encdec as E
from repro.models.config import ModelConfig
from repro.compat import simple_keystr


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

# smoke-scale twins of the four shapes (same code paths, CPU-runnable)
SMOKE_SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 64, 4),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 128, 2),
    "decode_32k": ShapeSpec("decode_32k", "decode", 128, 4),
    "long_500k": ShapeSpec("long_500k", "decode", 256, 1),
}


def is_subquadratic(cfg: ModelConfig) -> bool:
    """long_500k applicability: any non-full-attention mechanism counts
    (SWA, chunked-local, SSM/recurrent blocks)."""
    if cfg.sliding_window is not None or cfg.chunk_attn is not None:
        return True
    return any(k != "attn" for k in cfg.group_pattern)


def shape_applies(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    if shape.name == "long_500k" and not is_subquadratic(cfg):
        return False, ("pure full-attention arch: 500k decode needs "
                       "sub-quadratic attention (skip noted in DESIGN.md)")
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _token_batch(cfg: ModelConfig, b: int, s: int, with_targets: bool
                 ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    args = {"tokens": _sds((b, s), jnp.int32)}
    roles = {"tokens": ["batch", None]}
    if with_targets:
        args["targets"] = _sds((b, s), jnp.int32)
        roles["targets"] = ["batch", None]
    if cfg.enc_dec:
        args["frames"] = _sds((b, cfg.enc_seq, cfg.d_model), jnp.float32)
        roles["frames"] = ["batch", None, None]
    if cfg.mrope:
        args["positions"] = _sds((3, b, s), jnp.int32)
        roles["positions"] = [None, "batch", None]
        n_patch = min(1024, s // 2)
        args["patch_embeds"] = _sds((b, n_patch, cfg.d_model), jnp.float32)
        roles["patch_embeds"] = ["batch", None, None]
    return args, roles


def _cache_roles(cfg: ModelConfig, caches_abs, batch: int):
    """Logical roles for decode-cache leaves: batch on DP axes, the big
    sequence dim of KV rings on the "model" axis (sequence-sharded cache),
    wide state dims on "model"."""
    import jax.tree_util as jtu

    def role_for(kp, leaf):
        path = simple_keystr(kp)
        name = path.rsplit("/", 1)[-1]
        nd = len(leaf.shape)
        if name in ("k", "v", "k_scale", "v_scale"):  # (G, B, S, KV, *)
            return [None, "batch", "seq_model", None, None]
        if name == "slot_pos":            # (G, S)
            return [None, "seq_model"]
        if name in ("ck", "cv"):          # whisper cross kv (G,B,Se,KV,hd)
            return [None, "batch", None, None, None]
        if name == "conv":                # (G, B, dc-1, inner)
            return [None, "batch", None, "model"]
        if name == "ssm":                 # (G, B, inner, N)
            return [None, "batch", "model", None]
        if name == "c" and nd == 5:       # mlstm (G, B, H, hd, hd)
            return [None, "batch", None, "model", None]
        if name == "n" and nd == 4:       # mlstm (G, B, H, hd)
            return [None, "batch", None, "model"]
        if nd >= 2:                       # slstm (G, B, d) & friends
            return [None, "batch"] + ["model" if i == 2 and nd == 3 else None
                                      for i in range(2, nd)]
        return [None] * nd

    flat, treedef = jtu.tree_flatten_with_path(caches_abs)
    return jtu.tree_unflatten(treedef,
                              [role_for(kp, leaf) for kp, leaf in flat])


def input_specs(cfg: ModelConfig, shape: ShapeSpec
                ) -> Tuple[Tuple[Any, ...], Tuple[Any, ...]]:
    """(args, roles) for the step function of ``shape.kind``.

    * train:   (batch,)                      for train_step(state, batch)
    * prefill: (batch,)                      for prefill_step(params, batch)
    * decode:  (caches, tokens, pos)         for decode_step(params, ...)
    """
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        args, roles = _token_batch(cfg, b, s, with_targets=True)
        return (args,), (roles,)
    if shape.kind == "prefill":
        args, roles = _token_batch(cfg, b, s, with_targets=False)
        return (args,), (roles,)
    if shape.kind == "decode":
        if cfg.enc_dec:
            enc_abs = _sds((b, cfg.enc_seq, cfg.d_model), jnp.float32)
            params_abs = jax.eval_shape(lambda k: E.init_encdec(k, cfg),
                                        jax.random.key(0))
            caches_abs = jax.eval_shape(
                lambda p, e: E.init_caches(p, e, cfg, b, s),
                params_abs, enc_abs)
        else:
            caches_abs = jax.eval_shape(lambda: T.init_caches(cfg, b, s))
        tokens = _sds((b, 1), jnp.int32)
        pos = _sds((), jnp.int32)
        c_roles = _cache_roles(cfg, caches_abs, b)
        return ((caches_abs, tokens, pos),
                (c_roles, ["batch", None], None))
    raise ValueError(shape.kind)
