"""stablelm-1.6b [dense] — 24L d_model=2048 32H (MHA, kv=32) d_ff=5632
vocab=100352; LayerNorm + partial rotary (25%)
[hf:stabilityai/stablelm-2-1_6b; unverified]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
    norm="layernorm",
    rotary_pct=0.25,
    qkv_bias=True,
    rope_theta=1e4,
    notes="MHA; partial rotary 25%; LayerNorm",
)

REDUCED = ModelConfig(
    name="stablelm-1.6b-reduced",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=160,
    vocab_size=512,
    norm="layernorm",
    rotary_pct=0.25,
    qkv_bias=True,
    rope_theta=1e4,
)
