"""whisper-tiny [audio] — enc-dec, conv frontend stubbed.

4L enc + 4L dec, d_model=384, 6H (MHA), d_ff=1536, vocab=51865
[arXiv:2212.04356; unverified].  The mel/conv frontend is a stub:
``input_specs`` feeds precomputed frame embeddings (B, 1500, 384).
Positional embeddings are sinusoidal (whisper uses sinusoid-encoder /
learned-decoder; deviation noted in DESIGN.md — shape/FLOP identical).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    enc_dec=True,
    n_enc_layers=4,
    enc_seq=1500,
    norm="layernorm",
    activation="gelu",
    qkv_bias=True,
    use_rope=False,
    tie_embeddings=True,
    notes="enc-dec; frontend stub; MHA (kv=6)",
)

REDUCED = ModelConfig(
    name="whisper-tiny-reduced",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    enc_dec=True,
    n_enc_layers=2,
    enc_seq=24,
    norm="layernorm",
    activation="gelu",
    qkv_bias=True,
    use_rope=False,
    tie_embeddings=True,
)
