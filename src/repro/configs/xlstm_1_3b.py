"""xlstm-1.3b [ssm] — 48L d_model=2048 4H d_ff=0 vocab=50304; sLSTM +
mLSTM blocks at 1:7 per group of 8 [arXiv:2405.04517; unverified].

mLSTM blocks carry an (hd x hd) matrix memory per head (chunkwise-parallel
linear attention); sLSTM blocks are sequential scalar-memory cells with
block-diagonal recurrence + 4/3-factor post-FFN.  Attention-free ->
long_500k applies.
"""

from repro.models.config import ModelConfig

_PATTERN = ("slstm",) + ("mlstm",) * 7

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    group_pattern=_PATTERN,
    norm="layernorm",
    notes="1 sLSTM : 7 mLSTM; attention-free",
)

REDUCED = ModelConfig(
    name="xlstm-1.3b-reduced",
    n_layers=8,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=512,
    group_pattern=_PATTERN,
    norm="layernorm",
)
