"""Static enforcement of the DESIGN.md §9–§14 bit-exactness contract.

Two layers (DESIGN.md §15): `astcheck` lints the fused-body surface for
banned primitives, FMA-hazard shapes, out-of-resolver association
parameters and xp-twin drift; `jaxprcheck` traces the real kernel entry
points and walks the closed jaxpr for violations hiding behind helper
indirection.  ``python -m repro.contractcheck --strict`` is the CI
gate; `run_check` is the library entry point.
"""

from repro.contractcheck.astcheck import (check_file, check_source,
                                          check_tree)
from repro.contractcheck.config import CheckConfig, load_config
from repro.contractcheck.jaxprcheck import check_callable, check_kernels
from repro.contractcheck.rules import RULES, Finding, Rule

__all__ = ["CheckConfig", "Finding", "Rule", "RULES", "check_callable",
           "check_file", "check_kernels", "check_source", "check_tree",
           "load_config", "run_check"]


def run_check(root=None, paths=None, jaxpr=True, config=None):
    """Full checker run: AST layer over every scoped file plus the
    jaxpr layer over the kernel surface.  Returns all findings."""
    cfg = config or load_config(root)
    findings = check_tree(cfg, paths)
    if jaxpr:
        findings.extend(check_kernels(cfg))
    return findings
