"""CLI: ``python -m repro.contractcheck [--strict] [paths…]``.

Exit codes: 0 clean, 1 violations, 2 internal error.  Default mode
fails on error-severity findings only; ``--strict`` (the CI `contract`
shard) fails on warnings too.  Suppressed findings never fail a run
(they are visible with ``--show-suppressed``).
"""

from __future__ import annotations

import argparse
import json
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.contractcheck",
        description="DESIGN.md §9–§14 bit-exactness contract checker")
    ap.add_argument("paths", nargs="*",
                    help="restrict the AST layer to these files "
                         "(default: every scoped file)")
    ap.add_argument("--strict", action="store_true",
                    help="fail on warnings as well as errors")
    ap.add_argument("--no-jaxpr", action="store_true",
                    help="skip the jaxpr layer (no jax import/trace)")
    ap.add_argument("--root", default=None,
                    help="repo root (default: auto-detect)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also print suppressed findings")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule registry and exit")
    args = ap.parse_args(argv)

    from repro.contractcheck import load_config, run_check
    from repro.contractcheck.rules import RULES, SEV_ERROR

    if args.list_rules:
        for rule in RULES.values():
            print(f"{rule.rule_id:12s} [{rule.layer}/{rule.severity}] "
                  f"{rule.origin}: {rule.summary}")
        return 0

    cfg = load_config(args.root)
    try:
        findings = run_check(paths=args.paths or None,
                             jaxpr=not args.no_jaxpr, config=cfg)
    except Exception as exc:          # pragma: no cover - defensive
        print(f"contractcheck: internal error: {exc!r}", file=sys.stderr)
        return 2

    live = [f for f in findings if not f.suppressed]
    shown = findings if args.show_suppressed else live
    failing = [f for f in live
               if args.strict or f.severity == SEV_ERROR]

    if args.format == "json":
        print(json.dumps([vars(f) for f in shown], indent=2))
    else:
        for f in sorted(shown, key=lambda f: (f.path, f.line, f.rule_id)):
            print(f.format())
        n_sup = sum(f.suppressed for f in findings)
        print(f"contractcheck: {len(live)} finding(s) "
              f"({len(failing)} failing, {n_sup} suppressed) across "
              f"{len(set(f.path for f in findings)) if findings else 0} "
              f"file(s)")
    return 1 if failing else 0


if __name__ == "__main__":
    sys.exit(main())
