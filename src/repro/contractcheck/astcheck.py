"""Layer 1: AST lint over the §9–§14 contract surface.

One :class:`_FileChecker` pass per scoped file.  The rules and the
idioms they deliberately admit:

* **CC-SUM** — backend ``sum`` reductions (``jnp.sum``/``xp.sum``/
  ``x.sum()``) are banned in fused scopes *except* the two
  association-free shapes the contract blesses: a masked select
  (``sum(where(mask, x, 0))`` — at most one non-zero term per lane or a
  0/1 count) and an integer/bool operand (integer adds are exact under
  any association).  Operand classification follows single-assignment
  names within the function, so ``inside = (a >= lo) & (a < hi);
  xp.sum(inside)`` passes without annotation.
* **CC-SORT / CC-CUMSUM / CC-RNG / CC-TIME** — banned-primitive calls
  by dotted-name pattern.  ``jax.random`` is legal in dispatch scopes
  (engine seeding) but not fused ones.
* **CC-FMA** — a multiply as a direct operand of ``+``/``-`` in the
  same expression, the shape XLA may contract to an FMA on real
  hardware (§9 drain, §11 Eq. (3)).  Integer-cast operands
  (``jnp.uint32(…)`` — the LCG) are exempt: integer FMA is exact.
* **CC-ASSOC** — association parameters may be *passed through* calls
  but never fed to ``min``/``max``/arithmetic or defaulted with
  ``x if p is None else p`` outside the shared resolvers.
* **CC-TILE** — attribute reads of tile association fields
  (``cfg.trial_tile`` …) outside resolver bodies are flagged unless the
  read is an argument of a resolver call — every layer takes its tiles
  from the shared resolver/tuner surface (§16), so no layer can read a
  tile the tuner didn't resolve.
* **CC-TWIN** — for ``xp=jnp|np`` twin functions, the np and jnp arms
  of every ``if xp is np`` / ternary must use the same *set* of
  value-combining operations (±*/ and the math-call vocabulary);
  relocations (where/take/pad/reshape) and bitwise ops are neutral.

Suppression: ``# contract-ok: RULE-ID[,RULE-ID…] <reason>`` on the
finding's line (or the line above) suppresses it; a missing reason
keeps the suppression but emits CC-NOREASON (§15).
"""

from __future__ import annotations

import ast
import io
import os
import re
import tokenize
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.contractcheck.config import CheckConfig
from repro.contractcheck.rules import Finding, apply_severity

BACKEND_NAMES = {"jnp", "np", "numpy", "xp", "lax"}
SORT_ATTRS = {"sort", "argsort", "lexsort", "sort_key_val"}
CUMSUM_ATTRS = {"cumsum", "cumprod", "cummax", "cummin",
                "associative_scan"}
TIME_CALLS = {"time.time", "time.perf_counter", "time.monotonic",
              "time.process_time", "time.time_ns",
              "datetime.now", "datetime.datetime.now",
              "datetime.utcnow", "datetime.datetime.utcnow"}
INT_CAST_NAMES = re.compile(r"(int|uint|i32|i64|u32|bool)", re.IGNORECASE)
INT_CAST_FUNCS = {"int8", "int16", "int32", "int64",
                  "uint8", "uint16", "uint32", "uint64", "int"}
# value-combining vocabulary for CC-TWIN arm comparison
COMBINING_CALLS = {"exp", "log", "log1p", "expm1", "sqrt", "maximum",
                   "minimum", "clip", "ceil", "floor", "abs", "power",
                   "sum", "mean", "prod", "dot", "matmul", "cumsum",
                   "tanh", "rem", "fmod", "mod"}
_CALL_CANON = {"rem": "%", "fmod": "%", "mod": "%", "power": "**"}
_BINOP_SYM = {ast.Add: "+", ast.Sub: "-", ast.Mult: "*", ast.Div: "/",
              ast.FloorDiv: "//", ast.Mod: "%", ast.Pow: "**"}

_SUPPRESS_RE = re.compile(
    r"#\s*contract-ok:\s*([A-Z][A-Z0-9\-]*(?:\s*,\s*[A-Z][A-Z0-9\-]*)*)"
    r"[ \t]*(.*)$")


def _dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for an Attribute/Name chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _terminal(name: Optional[str]) -> Optional[str]:
    return name.rsplit(".", 1)[-1] if name else None


def collect_suppressions(src: str) -> Tuple[Dict[int, Set[str]],
                                            List[Finding]]:
    """line -> suppressed rule IDs (a comment covers its own line and
    the next, so both trailing and line-above styles work), plus
    CC-NOREASON findings for reasonless suppressions."""
    lines: Dict[int, Set[str]] = {}
    noreason: List[Finding] = []
    try:
        toks = list(tokenize.generate_tokens(io.StringIO(src).readline))
    except tokenize.TokenizeError:      # pragma: no cover - defensive
        return lines, noreason
    for tok in toks:
        if tok.type != tokenize.COMMENT or "contract-ok" not in tok.string:
            continue
        m = _SUPPRESS_RE.search(tok.string)
        if not m:
            continue
        ids = {s.strip() for s in m.group(1).split(",") if s.strip()}
        ln = tok.start[0]
        for line in (ln, ln + 1):
            lines.setdefault(line, set()).update(ids)
        if not m.group(2).strip():
            noreason.append(Finding(
                "CC-NOREASON", "", ln,
                f"suppression of {','.join(sorted(ids))} has no reason"))
    return lines, noreason


def _is_int_cast_call(node: ast.AST) -> bool:
    """jnp.uint32(x) / x.astype(jnp.int32) / int(x) — integer-exact."""
    if not isinstance(node, ast.Call):
        return False
    attr = (node.func.attr if isinstance(node.func, ast.Attribute)
            else node.func.id if isinstance(node.func, ast.Name) else None)
    if attr in INT_CAST_FUNCS:
        return True
    if attr == "astype" and node.args:
        dt = _dotted(node.args[0])
        if dt is None and isinstance(node.args[0], ast.Constant):
            dt = str(node.args[0].value)
        return bool(dt and INT_CAST_NAMES.search(_terminal(dt) or dt))
    return False


def _is_where_call(node: ast.AST) -> bool:
    return (isinstance(node, ast.Call)
            and _terminal(_dotted(node.func)) == "where")


def _classify(node: ast.AST, kinds: Dict[str, str],
              depth: int = 0) -> Optional[str]:
    """'int' (integer/bool/shape-valued — association-free), 'mask'
    (masked select), or None (assume float tensor)."""
    if depth > 8:
        return None
    if isinstance(node, ast.Compare):
        return "int"
    if _is_int_cast_call(node):
        return "int"
    if _is_where_call(node):
        return "mask"
    if isinstance(node, ast.Constant):
        return "int" if isinstance(node.value, (int, bool)) and \
            not isinstance(node.value, float) else None
    if isinstance(node, (ast.List, ast.Tuple, ast.Dict)):
        # container literals: python-level structure, not float math
        return "int"
    if isinstance(node, ast.Name):
        return kinds.get(node.id)
    if isinstance(node, ast.Attribute) and node.attr in ("ndim", "size"):
        return "int"
    if isinstance(node, ast.Subscript):
        chain = _dotted(node.value)
        if chain and chain.endswith(".shape"):
            return "int"
        return None
    if isinstance(node, ast.Call):
        fname = _dotted(node.func)
        if _terminal(fname) in ("len", "ord", "range", "arange", "iota",
                                "broadcasted_iota"):
            return "int"
        return None
    if isinstance(node, ast.BinOp):
        if isinstance(node.op, (ast.BitAnd, ast.BitOr, ast.BitXor,
                                ast.LShift, ast.RShift, ast.Add, ast.Sub,
                                ast.Mult, ast.FloorDiv, ast.Mod)):
            if (_classify(node.left, kinds, depth + 1) == "int"
                    and _classify(node.right, kinds, depth + 1) == "int"):
                return "int"
        return None
    if isinstance(node, ast.UnaryOp) and \
            isinstance(node.op, (ast.Invert, ast.USub)):
        return _classify(node.operand, kinds, depth + 1)
    return None


def _ann_is_int(ann: Optional[ast.AST]) -> bool:
    return (isinstance(ann, ast.Name) and ann.id in ("int", "bool")) or \
        (isinstance(ann, ast.Constant) and ann.value in ("int", "bool"))


def _prepass_kinds(fn: ast.AST,
                   outer: Optional[Dict[str, str]] = None) -> Dict[str, str]:
    """Name classification for one function: closure kinds, int/bool
    annotated params, range-loop targets, then single-assignment
    propagation to fixpoint (two passes)."""
    kinds: Dict[str, str] = dict(outer or {})
    if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
        every = (list(fn.args.posonlyargs) + list(fn.args.args)
                 + list(fn.args.kwonlyargs))
        for a in every:
            if _ann_is_int(a.annotation):
                kinds[a.arg] = "int"
            elif a.arg in kinds:
                del kinds[a.arg]       # param shadows an outer name
    for _ in range(2):
        for node in ast.walk(fn):
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)):
                k = _classify(node.value, kinds)
                if k:
                    kinds[node.targets[0].id] = k
            elif (isinstance(node, ast.For)
                  and isinstance(node.target, ast.Name)
                  and isinstance(node.iter, ast.Call)
                  and _terminal(_dotted(node.iter.func)) == "range"):
                kinds[node.target.id] = "int"
    return kinds


def _has_xp_param(fn: ast.FunctionDef) -> bool:
    args = fn.args
    every = (list(args.posonlyargs) + list(args.args)
             + list(args.kwonlyargs))
    return any(a.arg == "xp" for a in every)


def _is_xp_test(test: ast.AST) -> bool:
    if not (isinstance(test, ast.Compare) and len(test.ops) == 1):
        return False
    if not isinstance(test.ops[0], (ast.Is, ast.IsNot, ast.Eq, ast.NotEq)):
        return False
    names = {_dotted(test.left), _dotted(test.comparators[0])}
    return "xp" in names and bool(names & {"np", "jnp", "numpy"})


def _stmt_lists(fn: ast.AST):
    for node in ast.walk(fn):
        for field in ("body", "orelse", "finalbody"):
            stmts = getattr(node, field, None)
            if isinstance(stmts, list) and stmts and \
                    isinstance(stmts[0], ast.stmt):
                yield stmts


def _combining_ops(nodes: Sequence[ast.AST]) -> Set[str]:
    ops: Set[str] = set()
    for root in nodes:
        for node in ast.walk(root):
            if isinstance(node, (ast.BinOp, ast.AugAssign)):
                sym = _BINOP_SYM.get(type(node.op))
                if sym is None:
                    continue
                # shape/index arithmetic (len(x) - 1, ndim - 1, tuple
                # concat) is a relocation, not a value-combining op
                if isinstance(node, ast.BinOp) and \
                        _classify(node.left, {}) == "int" and \
                        _classify(node.right, {}) == "int":
                    continue
                ops.add(sym)
            elif isinstance(node, ast.Call):
                term = _terminal(_dotted(node.func))
                if term in COMBINING_CALLS:
                    ops.add(_CALL_CANON.get(term, term))
    return ops


class _FileChecker(ast.NodeVisitor):
    def __init__(self, relpath: str, src: str, cfg: CheckConfig,
                 active: Sequence[str], fused: bool):
        self.relpath = relpath
        self.cfg = cfg
        self.active = set(active)
        self.fused = fused
        self.findings: List[Finding] = []
        self.suppress, noreason = collect_suppressions(src)
        for f in noreason:
            f.path = relpath
            self.findings.append(f)
        self.func_stack: List[str] = []
        # innermost enclosing FunctionDef's name-kind map
        self.kind_stack: List[Dict[str, str]] = [{}]
        # Attribute nodes sanctioned for CC-TILE: tile-field reads that
        # are arguments of a resolver call (registered in visit_Call
        # before descent)
        self._tile_ok: Set[int] = set()

    # -- plumbing ---------------------------------------------------------

    def qualname(self) -> Optional[str]:
        return ".".join(self.func_stack) if self.func_stack else None

    def emit(self, rule_id: str, node: ast.AST, message: str) -> None:
        if rule_id not in self.active:
            return
        if self.cfg.allowed(self.relpath, self.qualname(), rule_id):
            return
        lo = getattr(node, "lineno", 0)
        hi = getattr(node, "end_lineno", lo) or lo
        suppressed = any(rule_id in self.suppress.get(line, ())
                         for line in range(lo, hi + 1))
        self.findings.append(Finding(rule_id, self.relpath, lo, message,
                                     suppressed=suppressed,
                                     func=self.qualname()))

    # -- function scoping + CC-TWIN ---------------------------------------

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self.func_stack.append(node.name)
        self.kind_stack.append(_prepass_kinds(node, self.kind_stack[-1]))
        if "CC-TWIN" in self.active and _has_xp_param(node):
            self._check_twin(node)
        self.generic_visit(node)
        self.kind_stack.pop()
        self.func_stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self.func_stack.append(node.name)
        self.generic_visit(node)
        self.func_stack.pop()

    def _check_twin(self, fn: ast.FunctionDef) -> None:
        for stmts in _stmt_lists(fn):
            for idx, stmt in enumerate(stmts):
                if not isinstance(stmt, ast.If) or not _is_xp_test(stmt.test):
                    continue
                arm_a: Sequence[ast.AST] = stmt.body
                if stmt.orelse:
                    arm_b: Sequence[ast.AST] = stmt.orelse
                elif arm_a and isinstance(arm_a[-1], (ast.Return, ast.Raise)):
                    # `if xp is np: … return` with the other backend's
                    # path continuing after the If
                    arm_b = stmts[idx + 1:]
                else:
                    continue
                self._twin_diff(stmt, arm_a, arm_b)
        for node in ast.walk(fn):
            if isinstance(node, ast.IfExp) and _is_xp_test(node.test):
                self._twin_diff(node, [node.body], [node.orelse])

    def _twin_diff(self, at: ast.AST, arm_a: Sequence[ast.AST],
                   arm_b: Sequence[ast.AST]) -> None:
        ops_a = _combining_ops(arm_a)
        ops_b = _combining_ops(arm_b)
        if ops_a != ops_b:
            only_a = ",".join(sorted(ops_a - ops_b)) or "(none)"
            only_b = ",".join(sorted(ops_b - ops_a)) or "(none)"
            self.emit("CC-TWIN", at,
                      f"xp twin arms diverge: one arm only {{{only_a}}}, "
                      f"other arm only {{{only_b}}}")

    # -- call rules --------------------------------------------------------

    def visit_Call(self, node: ast.Call) -> None:
        name = _dotted(node.func)
        base = name.split(".", 1)[0] if name else None

        if isinstance(node.func, ast.Attribute) and node.func.attr == "sum":
            namespace = (isinstance(node.func.value, ast.Name)
                         and node.func.value.id in BACKEND_NAMES)
            if namespace and node.args:
                self._check_sum(node, node.args[0], name or "sum")
            elif not namespace:
                # method form x.sum(...): classify the receiver
                self._check_sum(node, node.func.value,
                                (name or "<expr>.sum"))
        if isinstance(node.func, ast.Attribute) and \
                node.func.attr in SORT_ATTRS and \
                (base in BACKEND_NAMES or base == "jax"):
            self.emit("CC-SORT", node,
                      f"backend {node.func.attr} ({name}) — fused scopes "
                      "use rank_desc/bitonic; engine sites annotate (§10)")
        if isinstance(node.func, ast.Attribute) and \
                node.func.attr in CUMSUM_ATTRS and \
                (base in BACKEND_NAMES or base == "jax"):
            self.emit("CC-CUMSUM", node,
                      f"backend {node.func.attr} ({name}) — no pinned "
                      "association for prefix reductions (§9)")
        if name:
            self._check_rng_time(node, name)
        self._check_assoc_call(node)
        if _terminal(name) in self.cfg.resolvers:
            # feeding a tile field TO a resolver is the sanctioned read
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                for sub in ast.walk(arg):
                    if isinstance(sub, ast.Attribute) and \
                            sub.attr in self.cfg.assoc_params:
                        self._tile_ok.add(id(sub))
        self.generic_visit(node)

    def _check_sum(self, node: ast.Call, operand: ast.AST,
                   name: str) -> None:
        if "CC-SUM" not in self.active:
            return
        if _classify(operand, self.kind_stack[-1]) in ("int", "mask"):
            return
        self.emit("CC-SUM", node,
                  f"backend sum ({name}) over a non-masked float operand "
                  "— use lane_sum/tree_sum or a jnp.where mask (§9)")

    def _check_rng_time(self, node: ast.Call, name: str) -> None:
        if name.startswith(("np.random.", "numpy.random.",
                            "random.", "secrets.")):
            self.emit("CC-RNG", node,
                      f"{name} — contract randomness is the shared LCG "
                      "(np.random only off the contract surface, §9)")
        elif self.fused and name.startswith(("jax.random.", "jrandom.",
                                             "jr.")):
            self.emit("CC-RNG", node,
                      f"{name} in a fused scope — fused randomness goes "
                      "through lcg_step/lcg_mod (§9)")
        if name in TIME_CALLS:
            self.emit("CC-TIME", node,
                      f"{name} — wall-clock reads are banned on the "
                      "contract surface (simulated time only, §9)")

    # -- CC-FMA ------------------------------------------------------------

    def visit_BinOp(self, node: ast.BinOp) -> None:
        if isinstance(node.op, (ast.Add, ast.Sub)):
            self._check_fma(node, node.left, node.right)
        self._check_assoc_binop(node)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        if isinstance(node.op, (ast.Add, ast.Sub)):
            self._check_fma(node, node.target, node.value)
        self.generic_visit(node)

    def _check_fma(self, at: ast.AST, left: ast.AST, right: ast.AST) -> None:
        if "CC-FMA" not in self.active:
            return
        mul = None
        for side in (left, right):
            if isinstance(side, ast.BinOp) and isinstance(side.op, ast.Mult):
                mul = side
        if mul is None:
            return
        # integer context is exact (the LCG's uint32 arithmetic): any
        # direct operand that is an integer cast exempts the shape
        operands = [left, right, mul.left, mul.right]
        kinds = self.kind_stack[-1]
        if any(_is_int_cast_call(o) or _classify(o, kinds) == "int"
               for o in operands):
            return
        self.emit("CC-FMA", at,
                  "multiply feeding add/sub in one expression — FMA "
                  "contraction hazard; clamp (§9) or split (§11)")

    # -- CC-ASSOC ----------------------------------------------------------

    def _assoc_name(self, node: ast.AST) -> Optional[str]:
        if isinstance(node, ast.Name) and node.id in self.cfg.assoc_params:
            return node.id
        if isinstance(node, ast.Attribute) and \
                node.attr in self.cfg.assoc_params:
            return node.attr
        return None

    def _in_resolver(self) -> bool:
        return any(f in self.cfg.resolvers for f in self.func_stack)

    def _check_assoc_call(self, node: ast.Call) -> None:
        if "CC-ASSOC" not in self.active or self._in_resolver():
            return
        if isinstance(node.func, ast.Name) and node.func.id in ("min",
                                                                "max"):
            for arg in node.args:
                p = self._assoc_name(arg)
                if p:
                    self.emit("CC-ASSOC", node,
                              f"{node.func.id}({p}, …) — tile resolution "
                              "outside the shared resolvers (§12)")

    def visit_Attribute(self, node: ast.Attribute) -> None:
        if ("CC-TILE" in self.active and isinstance(node.ctx, ast.Load)
                and node.attr in self.cfg.assoc_params
                and not self._in_resolver()
                and id(node) not in self._tile_ok):
            self.emit("CC-TILE", node,
                      f"raw read of tile field .{node.attr} outside the "
                      "shared resolvers — route through resolve_*/"
                      "resolve_sim_tiles (§16)")
        self.generic_visit(node)

    def _check_assoc_binop(self, node: ast.BinOp) -> None:
        if "CC-ASSOC" not in self.active or self._in_resolver():
            return
        if not isinstance(node.op, (ast.Add, ast.Sub, ast.Mult, ast.Div,
                                    ast.FloorDiv, ast.Mod)):
            return
        for side in (node.left, node.right):
            p = self._assoc_name(side)
            if p:
                self.emit("CC-ASSOC", node,
                          f"arithmetic on {p} outside the shared "
                          "resolvers (§12)")

    def _assoc_default_subst(self, node: ast.AST, test: ast.AST,
                             has_assign: bool) -> None:
        if "CC-ASSOC" not in self.active or self._in_resolver():
            return
        if not (isinstance(test, ast.Compare) and len(test.ops) == 1
                and isinstance(test.ops[0], (ast.Is, ast.IsNot))
                and isinstance(test.comparators[0], ast.Constant)
                and test.comparators[0].value is None):
            return
        p = self._assoc_name(test.left)
        if p and has_assign:
            self.emit("CC-ASSOC", node,
                      f"default substitution of {p} outside the shared "
                      "resolvers (§12)")

    def visit_IfExp(self, node: ast.IfExp) -> None:
        self._assoc_default_subst(node, node.test, has_assign=True)
        self.generic_visit(node)

    def visit_If(self, node: ast.If) -> None:
        has_assign = any(isinstance(s, (ast.Assign, ast.AugAssign))
                         for s in node.body)
        self._assoc_default_subst(node, node.test, has_assign)
        self.generic_visit(node)


# -- public entry points ----------------------------------------------------

def check_source(src: str, relpath: str, cfg: CheckConfig,
                 rules: Optional[Sequence[str]] = None,
                 fused: Optional[bool] = None) -> List[Finding]:
    """Lint one source blob.  ``rules``/``fused`` default from the
    config's scope table for ``relpath``."""
    active = list(rules) if rules is not None else cfg.rules_for(relpath)
    if not active:
        return []
    if fused is None:
        fused = any(sc.name == "fused" and relpath in sc.files
                    for sc in cfg.scopes)
    tree = ast.parse(src, filename=relpath)
    checker = _FileChecker(relpath, src, cfg, active, fused)
    checker.visit(tree)
    return apply_severity(checker.findings, cfg.severity)


def check_file(path: str, cfg: CheckConfig) -> List[Finding]:
    relpath = os.path.relpath(os.path.abspath(path), cfg.root)
    relpath = relpath.replace(os.sep, "/")
    with open(path, "r", encoding="utf-8") as fh:
        src = fh.read()
    return check_source(src, relpath, cfg)


def check_tree(cfg: CheckConfig,
               paths: Optional[Sequence[str]] = None) -> List[Finding]:
    """Lint every scoped file (or the intersection with ``paths``)."""
    scoped: List[str] = []
    for sc in cfg.scopes:
        for f in sc.files:
            if f not in scoped:
                scoped.append(f)
    if paths:
        want = {os.path.relpath(os.path.abspath(p), cfg.root)
                .replace(os.sep, "/") for p in paths}
        scoped = [f for f in scoped if f in want]
    findings: List[Finding] = []
    for rel in scoped:
        full = os.path.join(cfg.root, rel)
        if os.path.exists(full):
            findings.extend(check_file(full, cfg))
    return findings
