"""Checker configuration: scopes, allowlist, severities — data, not code.

The authoritative baseline lives in :data:`DEFAULTS` below and is
mirrored by the ``[tool.contractcheck]`` table in the repo-root
``pyproject.toml``; when a pyproject table is present it *replaces* the
matching default keys, so downstream scopes/allowances are registered by
editing TOML, not this module (DESIGN.md §15).

TOML parsing uses stdlib ``tomllib`` when available (3.11+) and falls
back to ``tomli``; with neither importable the baked-in defaults apply
unchanged — the checker must run in the bare CI image without new
dependencies.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional

try:                                  # 3.11+
    import tomllib as _toml
except ImportError:                   # pragma: no cover - version dependent
    try:
        import tomli as _toml         # type: ignore[no-redef]
    except ImportError:
        _toml = None                  # type: ignore[assignment]


@dataclasses.dataclass(frozen=True)
class Scope:
    """A named set of files and the rule IDs enforced over them.  A file
    in several scopes gets the union of their rules."""

    name: str
    files: List[str]
    rules: List[str]


@dataclasses.dataclass
class CheckConfig:
    root: str
    scopes: List[Scope]
    # "relpath::qualname" -> rule IDs deliberately allowed there
    allow: Dict[str, List[str]]
    severity: Dict[str, str]
    # function names whose bodies may touch association parameters
    resolvers: List[str]
    # identifiers treated as association/lowering parameters (CC-ASSOC)
    assoc_params: List[str]
    # policies the jaxpr layer traces (one plain + one sort-path policy
    # keeps the CLI fast; tests widen this)
    jaxpr_policies: List[str]

    def rules_for(self, relpath: str) -> List[str]:
        relpath = relpath.replace(os.sep, "/")
        out: List[str] = []
        for sc in self.scopes:
            if relpath in sc.files:
                out.extend(r for r in sc.rules if r not in out)
        return out

    def allowed(self, relpath: str, qualname: Optional[str],
                rule_id: str) -> bool:
        if not qualname:
            return False
        relpath = relpath.replace(os.sep, "/")
        # match the full qualname and every dotted prefix, so a class- or
        # function-level allowance covers nested helpers
        parts = qualname.split(".")
        for i in range(len(parts), 0, -1):
            key = f"{relpath}::{'.'.join(parts[:i])}"
            if rule_id in self.allow.get(key, ()):
                return True
        return False


# The §9–§14 surface.  "fused" files hold code that lowers into (or is
# the oracle of) the Pallas kernel body; "dispatch" files resolve
# lowering parameters and drive the engine, where backend argsort stays
# deliberate (§10) but must be annotated.
DEFAULTS: Dict[str, object] = {
    "scopes": {
        "fused": {
            "files": [
                "src/repro/kernels/sched_select/kernel.py",
                "src/repro/kernels/sched_select/ref.py",
                "src/repro/core/policy_core.py",
                "src/repro/parallel/sweep.py",
            ],
            "rules": ["CC-SUM", "CC-SORT", "CC-CUMSUM", "CC-RNG",
                      "CC-TIME", "CC-FMA", "CC-TWIN", "CC-TILE"],
        },
        "dispatch": {
            "files": [
                "src/repro/kernels/sched_select/ops.py",
                "src/repro/parallel/sweep.py",
                "src/repro/core/engine.py",
                "src/repro/core/simulate.py",
                "src/repro/core/policies.py",
                "src/repro/core/statlog.py",
            ],
            "rules": ["CC-SORT", "CC-CUMSUM", "CC-RNG", "CC-TIME",
                      "CC-ASSOC", "CC-TILE"],
        },
    },
    # deliberate, §-documented deviations registered by scope (inline
    # `# contract-ok` comments cover single lines; this covers functions)
    "allow": {
        # host-side scheduler: python-level RNG feeding the np twin, off
        # the fused path entirely (DESIGN.md §8); its window-start
        # snapshot keeps stable np sorts, pinned equal to the kernel's
        # all-pairs rank (§10/§13)
        "src/repro/core/policies.py::HostScheduler": ["CC-RNG", "CC-SORT"],
        # dataclass validation reads its own tile fields to reject
        # non-positive values before any resolver ever sees them (§16)
        "src/repro/core/simulate.py::SimConfig": ["CC-TILE"],
    },
    "severity": {},
    "resolvers": ["resolve_trial_tile", "resolve_client_tile",
                  "resolve_shard_width", "resolve_grid_tiles",
                  "resolve_sim_tiles"],
    "assoc_params": ["trial_tile", "client_tile", "shard_width",
                     "DEFAULT_TRIAL_TILE", "DEFAULT_CLIENT_TILE"],
    "jaxpr_policies": ["ect", "mlml"],
}


def _scopes_from(raw: Dict[str, dict]) -> List[Scope]:
    return [Scope(name=k, files=list(v.get("files", ())),
                  rules=list(v.get("rules", ())))
            for k, v in raw.items()]


def find_root(start: Optional[str] = None) -> str:
    """Repo root = nearest ancestor with pyproject.toml or .git."""
    d = os.path.abspath(start or os.getcwd())
    while True:
        if (os.path.exists(os.path.join(d, "pyproject.toml"))
                or os.path.exists(os.path.join(d, ".git"))):
            return d
        parent = os.path.dirname(d)
        if parent == d:
            return os.path.abspath(start or os.getcwd())
        d = parent


def load_config(root: Optional[str] = None,
                pyproject: Optional[str] = None) -> CheckConfig:
    root = find_root(root)
    raw = dict(DEFAULTS)
    path = pyproject or os.path.join(root, "pyproject.toml")
    if _toml is not None and os.path.exists(path):
        with open(path, "rb") as fh:
            table = _toml.load(fh).get("tool", {}).get("contractcheck", {})
        for key in ("scopes", "allow", "severity", "resolvers",
                    "assoc_params", "jaxpr_policies"):
            if key in table:
                raw[key] = table[key]
    return CheckConfig(
        root=root,
        scopes=_scopes_from(raw["scopes"]),          # type: ignore[arg-type]
        allow={k: list(v) for k, v in raw["allow"].items()},  # type: ignore
        severity=dict(raw["severity"]),              # type: ignore[arg-type]
        resolvers=list(raw["resolvers"]),            # type: ignore[arg-type]
        assoc_params=list(raw["assoc_params"]),      # type: ignore[arg-type]
        jaxpr_policies=list(raw["jaxpr_policies"]),  # type: ignore[arg-type]
    )
