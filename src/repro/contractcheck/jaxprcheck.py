"""Layer 2: jaxpr verification of the fused surface.

The AST layer sees call sites; it cannot see a ``jnp.sort`` hiding two
helpers deep.  This layer traces the real kernel entry points
(`kernel.sched_stream_call`, `kernel.sched_stream_grid_call`, and
`engine.run_stream_batch`) at tiny abstract shapes, finds every
``pallas_call`` equation in the closed jaxpr, and walks the *inner*
(fused) jaxprs — plus all their scan/cond/pjit sub-jaxprs — asserting:

* **CJ-SORT** — no ``sort`` primitive.  The §10/§13 contract lowers all
  fused ordering through `rank_desc`/the bitonic network, which emit
  compares and selects, never ``sort_p``.
* **CJ-SUM** — no float ``reduce_sum``/``cumsum`` whose operand is not a
  masked select (``select_n``) or integer/bool.  The pinned
  `lane_sum`/`tree_sum` halving trees lower to explicit ``add`` chains
  and are invisible here by construction — so any ``reduce_sum`` that
  shows up was NOT routed through them.
* **CJ-RNG** — no RNG primitives (threefry/random_bits/…).  Fused
  randomness is the shared LCG: integer mul/add/and.

The *outer* jaxpr is deliberately out of scope: the engine keeps
backend argsort for step grouping (§10) and jax.random for seeding, so
only what lowers into a pallas body is held to the fused rules.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, List, Optional, Sequence, Tuple

from repro.contractcheck.config import CheckConfig, load_config
from repro.contractcheck.rules import Finding, apply_severity

RNG_SUBSTRINGS = ("threefry", "random_bits", "random_seed", "random_wrap",
                  "random_fold_in", "random_gamma", "rng_bit_generator")
ACCUM_PRIMS = {"reduce_sum", "cumsum"}
# producers whose output is a masked select or otherwise
# association-free, blessing a downstream reduce_sum
MASK_PRODUCERS = {"select_n"}


def _subjaxprs(params: dict):
    """Yield every Jaxpr/ClosedJaxpr nested in an eqn's params
    (duck-typed — the concrete classes move between jax versions)."""
    for v in params.values():
        vals = v if isinstance(v, (tuple, list)) else (v,)
        for item in vals:
            if hasattr(item, "eqns") or hasattr(item, "jaxpr"):
                yield item


def _as_jaxpr(j):
    return j.jaxpr if hasattr(j, "jaxpr") else j


def _walk_eqns(jaxpr, into_pallas: bool = True):
    """Yield (jaxpr_level, eqn) over a jaxpr and its sub-jaxprs."""
    jaxpr = _as_jaxpr(jaxpr)
    for eqn in jaxpr.eqns:
        yield jaxpr, eqn
        if eqn.primitive.name == "pallas_call" and not into_pallas:
            continue
        for sub in _subjaxprs(eqn.params):
            yield from _walk_eqns(sub, into_pallas)


def find_pallas_jaxprs(closed) -> List[Tuple[str, Any]]:
    """Every pallas_call's inner jaxpr in a traced computation (the
    search recurses through pjit/scan wrappers)."""
    out = []
    for _, eqn in _walk_eqns(closed, into_pallas=False):
        if eqn.primitive.name == "pallas_call":
            name = eqn.params.get("name", "pallas_call")
            out.append((str(name), eqn.params["jaxpr"]))
    return out


def _eqn_location(eqn, fallback: str) -> Tuple[str, int]:
    """Best-effort user file:line from the eqn's source info."""
    try:
        frames = eqn.source_info.traceback.frames
        for fr in frames:
            fname = getattr(fr, "file_name", "")
            if "/repro/" in fname.replace("\\", "/"):
                return fname, int(getattr(fr, "line_num", 0))
    except Exception:
        pass
    return fallback, 0


def _is_integral(aval) -> bool:
    import numpy as np
    dt = getattr(aval, "dtype", None)
    return dt is not None and (np.issubdtype(dt, np.integer)
                               or np.issubdtype(dt, np.bool_))


# wrappers that move data without combining it — resolve through them
# when hunting for the semantic producer of a reduce operand
_TRANSPARENT = {"reshape", "broadcast_in_dim", "squeeze", "transpose",
                "slice", "rev", "copy"}
# call-like primitives whose result is really produced by an inner jaxpr
_CALL_LIKE = {"pjit", "closed_call", "core_call", "custom_jvp_call",
              "custom_vjp_call", "custom_vjp_call_jaxpr", "remat"}


def _producer_map(level):
    producers = {}
    for eqn in _as_jaxpr(level).eqns:
        for ov in eqn.outvars:
            producers[id(ov)] = eqn
    return producers


def _effective_producer(level, var, depth: int = 0):
    """(level, eqn) that semantically produced ``var``, looking through
    call-like wrappers (jnp.where traces as a pjit around select_n) and
    pure data movement.  (None, None) when untraceable."""
    if depth > 16:
        return None, None
    prod = _producer_map(level).get(id(var))
    if prod is None:
        return None, None
    name = prod.primitive.name
    if name in _CALL_LIKE:
        inner = None
        for sub in _subjaxprs(prod.params):
            inner = sub
            break
        if inner is None:
            return level, prod
        ij = _as_jaxpr(inner)
        for idx, ov in enumerate(prod.outvars):
            if ov is var:
                out = ij.outvars[idx]
                for iidx, iv in enumerate(ij.invars):
                    if iv is out:     # pass-through: follow the call arg
                        return _effective_producer(
                            level, prod.invars[iidx], depth + 1)
                return _effective_producer(inner, out, depth + 1)
        return level, prod
    if name in _TRANSPARENT:
        return _effective_producer(level, prod.invars[0], depth + 1)
    return level, prod


def _operand_blessed(level, var, depth: int = 0) -> bool:
    """True when a reduce operand is association-free: integer/bool, a
    masked ``select_n``, or an element-type cast of either (``jnp.where
    (m, 1.0, 0.0)`` lowers to select_n → weak-f32 → convert)."""
    if depth > 8:
        return False
    if _is_integral(getattr(var, "aval", None)):
        return True
    plevel, prod = _effective_producer(level, var)
    if prod is None:
        return False
    if prod.primitive.name in MASK_PRODUCERS:
        return True
    if prod.primitive.name == "convert_element_type":
        return _operand_blessed(plevel, prod.invars[0], depth + 1)
    return False


def check_fused_jaxpr(jaxpr, label: str) -> List[Finding]:
    """Apply the CJ-* rules to one fused (inside-pallas) jaxpr."""
    findings: List[Finding] = []

    def emit(rule_id, eqn, msg):
        path, line = _eqn_location(eqn, f"<jaxpr:{label}>")
        findings.append(Finding(rule_id, path, line,
                                f"[{label}] {msg}", func=label))

    for level, eqn in _walk_eqns(jaxpr):
        name = eqn.primitive.name
        if name == "sort":
            emit("CJ-SORT", eqn,
                 "sort primitive in a fused body — §10 lowers fused "
                 "ordering through rank_desc/bitonic only")
        if any(s in name for s in RNG_SUBSTRINGS):
            emit("CJ-RNG", eqn,
                 f"RNG primitive {name} in a fused body — fused "
                 "randomness is the shared LCG (§9)")
        if name in ACCUM_PRIMS:
            if _operand_blessed(level, eqn.invars[0]):
                continue
            emit("CJ-SUM", eqn,
                 f"raw float {name} whose operand is not a masked "
                 "select/integer — route through lane_sum/tree_sum (§9)")
    return findings


def check_callable(fn: Callable, args: Sequence[Any],
                   label: str = "fn",
                   fused_whole: bool = False) -> List[Finding]:
    """Trace ``fn(*args)`` and check its fused jaxprs.  With
    ``fused_whole`` the entire jaxpr is held to the fused rules (for toy
    bodies in tests); otherwise only pallas inner jaxprs are."""
    import jax
    closed = jax.make_jaxpr(fn)(*args)
    if fused_whole:
        return check_fused_jaxpr(closed, label)
    findings: List[Finding] = []
    for name, inner in find_pallas_jaxprs(closed):
        findings.extend(check_fused_jaxpr(inner, f"{label}:{name}"))
    return findings


# -- tracing the real kernel surface ----------------------------------------

def _tiny_operands(two_d: bool):
    import jax.numpy as jnp
    m, m_pad = 3, 128
    window, n_win = 2, 2
    n = window * n_win
    t, c = 2, 2
    lead = (t, c) if two_d else (t,)
    obj = jnp.zeros(lead + (n,), jnp.int32)
    lens = jnp.ones(lead + (n,), jnp.float32)
    val = jnp.ones(lead + (n,), jnp.int32)
    from repro.core.policy_core import init_table
    table = jnp.broadcast_to(
        jnp.pad(init_table(m), ((0, 0), (0, m_pad - m))),
        lead + (4, m_pad))
    seeds = jnp.ones(lead + (1,), jnp.uint32) if not two_d else \
        jnp.ones(lead, jnp.uint32)
    rates = jnp.ones((t, n_win, m_pad) if two_d else lead + (n_win, m_pad),
                     jnp.float32)
    kw = dict(n_servers=m, window_size=window, threshold=0.0, lam=32.0,
              alpha=0.25, window_dt=0.0, observe=True, renorm=True)
    return (obj, lens, val, table, seeds, rates), kw


def trace_kernel_calls(policies: Sequence[str]) -> List[Finding]:
    """Check the 1-D trial-grid and 2-D grid kernel bodies for each
    policy (tracing only — nothing executes)."""
    from repro.kernels.sched_select import kernel
    findings: List[Finding] = []
    for policy in policies:
        (obj, lens, val, table, seeds, rates), kw = _tiny_operands(False)
        fn = functools.partial(kernel.sched_stream_call, policy=policy,
                               interpret=True, **kw)
        findings.extend(check_callable(
            fn, (obj, lens, val, table, seeds, rates),
            label=f"sched_stream_call[{policy}]"))
        (obj, lens, val, table, seeds, rates), kw = _tiny_operands(True)
        fn = functools.partial(kernel.sched_stream_grid_call, policy=policy,
                               interpret=True, trial_tile=1, client_tile=1,
                               **kw)
        findings.extend(check_callable(
            fn, (obj, lens, val, table, seeds, rates),
            label=f"sched_stream_grid_call[{policy}]"))
    return findings


def trace_run_stream_batch(policy: str = "ect") -> List[Finding]:
    """Check the full `engine.run_stream_batch` dispatch — padding,
    prep, kernel, bookkeeping — as the contract's integration point."""
    import jax
    import jax.numpy as jnp
    from repro.core import engine
    from repro.core.policies import PolicyConfig
    from repro.core.statlog import LogConfig, init_state

    m, t, window, n_win = 3, 2, 2, 2
    n = window * n_win
    state = init_state(LogConfig(n_servers=m))
    states = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (t,) + a.shape), state)
    works = engine.Workload(
        object_ids=jnp.zeros((t, n), jnp.int32),
        lengths=jnp.ones((t, n), jnp.float32),
        valid=jnp.ones((t, n), bool))
    keys = jax.random.split(jax.random.PRNGKey(0), t)

    def fn(states, works, keys):
        return engine.run_stream_batch(
            states, works, keys, policy=PolicyConfig(name=policy),
            log_cfg=LogConfig(n_servers=m), window_size=window,
            backend="kernel")

    return check_callable(fn, (states, works, keys),
                          label=f"run_stream_batch[{policy}]")


def check_kernels(cfg: Optional[CheckConfig] = None) -> List[Finding]:
    """The jaxpr shard of a full checker run."""
    cfg = cfg or load_config()
    findings = trace_kernel_calls(cfg.jaxpr_policies)
    findings.extend(trace_run_stream_batch(cfg.jaxpr_policies[0]))
    return apply_severity(findings, cfg.severity)
