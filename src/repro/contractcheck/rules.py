"""Rule registry + finding type for the §9–§14 contract checker.

Every rule is one clause of the DESIGN.md bit-exactness contract made
machine-checkable.  ``CC-*`` rules are the AST layer (`astcheck`),
``CJ-*`` rules are the jaxpr layer (`jaxprcheck`); the two layers share
this registry so the CLI, the config table and DESIGN.md §15 all speak
the same IDs.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

SEV_ERROR = "error"
SEV_WARNING = "warning"


@dataclasses.dataclass(frozen=True)
class Rule:
    """One contract clause: a stable ID, the layer that checks it, the
    DESIGN.md § it descends from, and the default severity."""

    rule_id: str
    layer: str        # "ast" | "jaxpr"
    origin: str       # DESIGN.md § reference
    summary: str
    severity: str = SEV_ERROR


_ALL = [
    Rule("CC-SUM", "ast", "§9",
         "backend float sum reduction in a fused scope — use the pinned "
         "lane_sum/tree_sum/psum_tree halving trees (masked jnp.where "
         "selects and integer/bool sums are association-free and pass)"),
    Rule("CC-SORT", "ast", "§10/§13",
         "backend argsort/sort in a contract scope — fused bodies use "
         "rank_desc / the bitonic network; engine-side argsort must be "
         "annotated as deliberate"),
    Rule("CC-CUMSUM", "ast", "§9",
         "backend cumulative reduction (cumsum/cumprod/associative_scan) "
         "in a contract scope — float prefix sums have no pinned "
         "association"),
    Rule("CC-RNG", "ast", "§9",
         "non-LCG randomness in a contract scope: np.random/stdlib random "
         "anywhere, jax.random inside a fused body — fused randomness "
         "goes through the shared lcg_step/lcg_mod"),
    Rule("CC-TIME", "ast", "§9",
         "wall-clock read (time.*/datetime.now) in a contract scope — "
         "simulated time is the only clock the contract admits"),
    Rule("CC-FMA", "ast", "§9/§11",
         "multiply feeding an add/sub in one expression in a fused scope "
         "— the FMA-contraction hazard §9 (drain) and §11 (Eq. (3)) each "
         "rewrote once; clamp or split the expression"),
    Rule("CC-ASSOC", "ast", "§12",
         "association/lowering parameter (trial_tile/client_tile/shard "
         "width) resolved outside the shared resolve_trial_tile/"
         "resolve_client_tile/resolve_shard_width resolvers"),
    Rule("CC-TILE", "ast", "§16",
         "raw read of a tile association field (cfg.trial_tile/"
         "cfg.client_tile/…) outside the shared resolvers — layers take "
         "tile shapes from the resolver/tuner surface only, so a tuned "
         "run cannot leak an unresolved tile into lowering"),
    Rule("CC-TWIN", "ast", "§8/§9",
         "xp-twin drift: the np and jnp arms of a policy_core xp-branch "
         "use structurally different combining-op sets",
         severity=SEV_WARNING),
    Rule("CC-NOREASON", "ast", "§15",
         "contract-ok suppression without a reason — every deliberate "
         "deviation must say why", severity=SEV_WARNING),
    Rule("CJ-SORT", "jaxpr", "§10",
         "sort primitive inside a fused (pallas) jaxpr — reaches sorts "
         "hidden behind helper indirection the AST cannot see"),
    Rule("CJ-SUM", "jaxpr", "§9",
         "raw float reduce_sum/cumsum inside a fused jaxpr whose operand "
         "is not a masked select or integer/bool — the pinned trees "
         "lower to explicit add chains and never emit this"),
    Rule("CJ-RNG", "jaxpr", "§9",
         "RNG primitive (threefry/random_bits/…) inside a fused jaxpr — "
         "fused randomness is the shared LCG only"),
]

RULES: Dict[str, Rule] = {r.rule_id: r for r in _ALL}


@dataclasses.dataclass
class Finding:
    """One violation: rule ID + location + message.  ``suppressed``
    findings are kept (for --show-suppressed) but never fail a run."""

    rule_id: str
    path: str
    line: int
    message: str
    severity: str = SEV_ERROR
    suppressed: bool = False
    func: Optional[str] = None

    def format(self) -> str:
        sup = " (suppressed)" if self.suppressed else ""
        where = f"{self.path}:{self.line}"
        return f"{where}: {self.rule_id} [{self.severity}]{sup} {self.message}"


def apply_severity(findings, severity_map: Dict[str, str]):
    """Stamp configured severities (config overrides registry default)."""
    for f in findings:
        rule = RULES.get(f.rule_id)
        default = rule.severity if rule else SEV_ERROR
        f.severity = severity_map.get(f.rule_id, default)
    return findings
