"""repro.core — the paper's contribution: log-assisted straggler-aware
I/O scheduling (client-side statistic log, Eqs. 1-3, RR/MLML/TRH/nLTR),
plus the temporal cluster model (service-rate traces, latency metrics)."""

from repro.core.statlog import (  # noqa: F401
    LogConfig, SchedState, HostStatLog, init_state, apply_assignment,
    observe_completion, advance_time, estimated_latency, renormalize,
)
from repro.core.policies import (  # noqa: F401
    POLICIES, PolicyConfig, HostScheduler, plan_window, select_target,
    apply_threshold,
)
from repro.core.engine import (  # noqa: F401
    ClusterTrace, Workload, ScheduleResult, group_by_object, rates_at,
    run_window, run_stream, run_stream_jit,
)
from repro.core.simulate import (  # noqa: F401
    SCENARIOS, SWEEP_POLICIES, ScenarioConfig, SimConfig, TrialResult,
    make_trace, run_scenario_eval, run_trials,
)
