"""repro.core — the paper's contribution: log-assisted straggler-aware
I/O scheduling (client-side statistic log, Eqs. 1-3, RR/MLML/TRH/nLTR)."""

from repro.core.statlog import (  # noqa: F401
    LogConfig, SchedState, HostStatLog, init_state, apply_assignment,
    observe_completion, renormalize,
)
from repro.core.policies import (  # noqa: F401
    POLICIES, PolicyConfig, HostScheduler, plan_window, select_target,
    apply_threshold,
)
from repro.core.engine import (  # noqa: F401
    Workload, ScheduleResult, group_by_object, run_window, run_stream,
    run_stream_jit,
)
