"""Balance / straggler / latency metrics over scheduling outcomes.

Covers the paper's §4 figures (load balance, Fig. 18 straggler avoidance,
probe overhead) plus the temporal-model metrics (DESIGN.md
§Temporal-model): latency percentiles, makespan, straggler-hit-over-time
and slowdown-vs-baseline summaries.

All functions take numpy-or-jnp arrays with an optional leading trial axis
and return plain floats / numpy arrays, so benchmarks can print CSV without
touching device buffers.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from repro.core import policy_core


def _np(a) -> np.ndarray:
    return np.asarray(a)


def load_balance_stats(server_loads) -> Dict[str, float]:
    """max / min / mean / std / CV / Jain fairness of per-server loads.

    ``server_loads``: (T, M) or (M,).  Trial axis is averaged the way the
    paper does (average load of each OSS over 100 runs, then statistics).
    """
    loads = _np(server_loads).astype(np.float64)
    if loads.ndim == 2:
        loads = loads.mean(axis=0)
    mean = float(loads.mean())
    std = float(loads.std())
    jain = float(loads.sum() ** 2 / (len(loads) * (loads ** 2).sum()))
    return {
        "max": float(loads.max()),
        "min": float(loads.min()),
        "mean": mean,
        "std": std,
        "cv": std / mean if mean else float("inf"),
        "jain": jain,
        "spread": float(loads.max() - loads.min()),
    }


def mean_server_loads(server_loads) -> np.ndarray:
    """(M,) per-OSS load averaged over trials (Figs. 12-17 y-axis)."""
    loads = _np(server_loads).astype(np.float64)
    return loads.mean(axis=0) if loads.ndim == 2 else loads


def fig18_curve(server_loads, n_assigned, n_bins: int = 30,
                lo: Optional[float] = None,
                hi: Optional[float] = None) -> Tuple[np.ndarray, np.ndarray]:
    """Paper Fig. 18: x = possible post-scheduling load; y = the MAX number
    of requests landed on any server having that load.

    Accepts (T, M) arrays; buckets are shared across trials so policies can
    be overlaid on one axis.
    """
    loads = _np(server_loads).astype(np.float64).reshape(-1)
    reqs = _np(n_assigned).astype(np.float64).reshape(-1)
    lo = float(loads.min()) if lo is None else lo
    hi = float(loads.max()) if hi is None else hi
    if hi <= lo:
        hi = lo + 1.0
    edges = np.linspace(lo, hi, n_bins + 1)
    centers = 0.5 * (edges[:-1] + edges[1:])
    which = np.clip(np.digitize(loads, edges) - 1, 0, n_bins - 1)
    ymax = np.zeros(n_bins)
    np.maximum.at(ymax, which, reqs)
    return centers, ymax


def straggler_summary(result) -> Dict[str, float]:
    """Straggler-avoidance metrics from a :class:`TrialResult`."""
    hits = _np(result.straggler_hits).astype(np.float64)
    loads = _np(result.server_loads).astype(np.float64)
    mask = _np(result.straggler_mask).astype(bool)
    n_req = _np(result.chosen).shape[-1]
    if loads.ndim == 1:
        loads, mask = loads[None], mask[None]
    strag_growth = []
    for t in range(loads.shape[0]):
        init = _np(result.init_loads)[t] if _np(result.init_loads).ndim == 2 \
            else _np(result.init_loads)
        if mask[t].any():
            strag_growth.append(float((loads[t] - init)[mask[t]].mean()))
    return {
        "mean_straggler_hits": float(hits.mean()),
        "hit_fraction": float(hits.mean()) / n_req,
        "mean_bytes_added_to_stragglers_mb":
            float(np.mean(strag_growth)) if strag_growth else 0.0,
        "max_load": float(loads.max(axis=1).mean()),
    }


def latency_stats(latencies) -> Dict[str, float]:
    """p50/p95/p99/mean/max of per-request estimated completion latencies.

    ``latencies``: (R,) or (T, R) seconds (temporal model).  Percentiles
    pool all trials' requests — the paper-scale question is "what does the
    99th-percentile request see", not "the 99th-percentile trial".

    Two p99 definitions coexist (pinned against a hand-computed example
    in tests/test_simulate.py):

    * ``p99`` — ``np.percentile``'s LINEAR-interpolated quantile (a
      weighted average of the two order statistics straddling rank
      0.99·(n-1)+1), kept for the figures so existing plots don't move;
    * ``p99_nearest`` — the NEAREST-RANK definition (the smallest value
      with at least ``ceil(0.99·n)`` values ≤ it), computed by the SAME
      `policy_core.nearest_rank_p99` f32 value bisection the kernel
      runs on its in-VMEM merged latency block (DESIGN.md §14) — the
      host-side number that matches ``MET_P99`` / ``SweepMerge.p99``
      bit-for-bit.  Nearest-rank is always an actual observed latency;
      linear interpolation generally is not, so the two differ whenever
      0.99·n falls between order statistics.
    """
    lat = _np(latencies).astype(np.float64).reshape(-1)
    lat32 = lat.astype(np.float32)
    p99_nr = policy_core.nearest_rank_p99(
        lat32, np.ones(lat32.shape, bool), xp=np)
    return {
        "p50": float(np.percentile(lat, 50)),
        "p95": float(np.percentile(lat, 95)),
        "p99": float(np.percentile(lat, 99)),
        "p99_nearest": float(np.asarray(p99_nr).reshape(-1)[0]),
        "mean": float(lat.mean()),
        "max": float(lat.max()),
    }


def makespan(result) -> float:
    """Mean (over trials) I/O-phase makespan: the latest estimated
    completion time of any request (``TrialResult.phase_time``)."""
    return float(_np(result.phase_time).astype(np.float64).mean())


def latency_cdf(latencies, n_points: int = 64) -> Tuple[np.ndarray, np.ndarray]:
    """Empirical CDF of request latencies: (latency grid, P[lat <= x])."""
    lat = np.sort(_np(latencies).astype(np.float64).reshape(-1))
    xs = np.linspace(lat[0], lat[-1] if lat[-1] > lat[0] else lat[0] + 1.0,
                     n_points)
    ys = np.searchsorted(lat, xs, side="right") / len(lat)
    return xs, ys


def straggler_hits_over_time(chosen, straggler_mask,
                             window_size: int) -> np.ndarray:
    """Fraction of each window's requests landing on stragglers, averaged
    over trials — shows onset/recovery tracking under temporal scenarios.

    ``chosen``: (T, R) or (R,); ``straggler_mask``: (T, M) or (M,).
    """
    ch = _np(chosen)
    mask = _np(straggler_mask).astype(bool)
    if ch.ndim == 1:
        ch = ch[None]
    if mask.ndim == 1:                     # shared mask across trials
        mask = np.broadcast_to(mask, (ch.shape[0], mask.shape[0]))
    t, r = ch.shape
    n_win = -(-r // window_size)
    pad = n_win * window_size - r
    hit = np.take_along_axis(mask, ch, axis=1).astype(np.float64)
    if pad:
        hit = np.concatenate([hit, np.full((t, pad), np.nan)], axis=1)
    per_win = np.nanmean(hit.reshape(t, n_win, window_size), axis=2)
    return per_win.mean(axis=0)


def slowdown_vs_baseline(results: Dict[str, object],
                         baseline: str = "rr") -> Dict[str, Dict[str, float]]:
    """Per-policy p99-latency and makespan ratios vs a baseline policy
    (values < 1 mean the policy beats the baseline)."""
    base_p99 = latency_stats(results[baseline].latencies)["p99"]
    base_mk = makespan(results[baseline])
    out = {}
    for name, res in results.items():
        out[name] = {
            "p99_vs_" + baseline: latency_stats(res.latencies)["p99"]
            / max(base_p99, 1e-12),
            "makespan_vs_" + baseline: makespan(res) / max(base_mk, 1e-12),
        }
    return out


def probe_overhead(results: Dict[str, object], n_requests: int) -> Dict[str, float]:
    """Probe messages per request per policy (the cost the log removes)."""
    return {name: float(_np(r.probe_msgs).mean()) / n_requests
            for name, r in results.items()}


def ascii_plot(ys: np.ndarray, width: int = 72, height: int = 12,
               label: str = "") -> str:
    """Tiny dependency-free line plot for benchmark stdout."""
    ys = _np(ys).astype(np.float64)
    if len(ys) > width:
        idx = np.linspace(0, len(ys) - 1, width).astype(int)
        ys = ys[idx]
    lo, hi = float(ys.min()), float(ys.max())
    span = (hi - lo) or 1.0
    rows = []
    q = np.clip(((ys - lo) / span * (height - 1)).round().astype(int), 0,
                height - 1)
    for r in range(height - 1, -1, -1):
        line = "".join("█" if q[c] >= r else " " for c in range(len(ys)))
        rows.append(f"{(lo + span * r / (height - 1)):10.1f} |{line}")
    rows.append(" " * 11 + "+" + "-" * len(ys))
    if label:
        rows.insert(0, f"  {label}  [min={lo:.2f} max={hi:.2f}]")
    return "\n".join(rows)
