"""Balance / straggler metrics over scheduling outcomes (paper §4 figures).

All functions take numpy-or-jnp arrays with an optional leading trial axis
and return plain floats / numpy arrays, so benchmarks can print CSV without
touching device buffers.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np


def _np(a) -> np.ndarray:
    return np.asarray(a)


def load_balance_stats(server_loads) -> Dict[str, float]:
    """max / min / mean / std / CV / Jain fairness of per-server loads.

    ``server_loads``: (T, M) or (M,).  Trial axis is averaged the way the
    paper does (average load of each OSS over 100 runs, then statistics).
    """
    loads = _np(server_loads).astype(np.float64)
    if loads.ndim == 2:
        loads = loads.mean(axis=0)
    mean = float(loads.mean())
    std = float(loads.std())
    jain = float(loads.sum() ** 2 / (len(loads) * (loads ** 2).sum()))
    return {
        "max": float(loads.max()),
        "min": float(loads.min()),
        "mean": mean,
        "std": std,
        "cv": std / mean if mean else float("inf"),
        "jain": jain,
        "spread": float(loads.max() - loads.min()),
    }


def mean_server_loads(server_loads) -> np.ndarray:
    """(M,) per-OSS load averaged over trials (Figs. 12-17 y-axis)."""
    loads = _np(server_loads).astype(np.float64)
    return loads.mean(axis=0) if loads.ndim == 2 else loads


def fig18_curve(server_loads, n_assigned, n_bins: int = 30,
                lo: Optional[float] = None,
                hi: Optional[float] = None) -> Tuple[np.ndarray, np.ndarray]:
    """Paper Fig. 18: x = possible post-scheduling load; y = the MAX number
    of requests landed on any server having that load.

    Accepts (T, M) arrays; buckets are shared across trials so policies can
    be overlaid on one axis.
    """
    loads = _np(server_loads).astype(np.float64).reshape(-1)
    reqs = _np(n_assigned).astype(np.float64).reshape(-1)
    lo = float(loads.min()) if lo is None else lo
    hi = float(loads.max()) if hi is None else hi
    if hi <= lo:
        hi = lo + 1.0
    edges = np.linspace(lo, hi, n_bins + 1)
    centers = 0.5 * (edges[:-1] + edges[1:])
    which = np.clip(np.digitize(loads, edges) - 1, 0, n_bins - 1)
    ymax = np.zeros(n_bins)
    np.maximum.at(ymax, which, reqs)
    return centers, ymax


def straggler_summary(result) -> Dict[str, float]:
    """Straggler-avoidance metrics from a :class:`TrialResult`."""
    hits = _np(result.straggler_hits).astype(np.float64)
    loads = _np(result.server_loads).astype(np.float64)
    mask = _np(result.straggler_mask).astype(bool)
    n_req = _np(result.chosen).shape[-1]
    if loads.ndim == 1:
        loads, mask = loads[None], mask[None]
    strag_growth = []
    for t in range(loads.shape[0]):
        init = _np(result.init_loads)[t] if _np(result.init_loads).ndim == 2 \
            else _np(result.init_loads)
        if mask[t].any():
            strag_growth.append(float((loads[t] - init)[mask[t]].mean()))
    return {
        "mean_straggler_hits": float(hits.mean()),
        "hit_fraction": float(hits.mean()) / n_req,
        "mean_bytes_added_to_stragglers_mb":
            float(np.mean(strag_growth)) if strag_growth else 0.0,
        "max_load": float(loads.max(axis=1).mean()),
    }


def probe_overhead(results: Dict[str, object], n_requests: int) -> Dict[str, float]:
    """Probe messages per request per policy (the cost the log removes)."""
    return {name: float(_np(r.probe_msgs).mean()) / n_requests
            for name, r in results.items()}


def ascii_plot(ys: np.ndarray, width: int = 72, height: int = 12,
               label: str = "") -> str:
    """Tiny dependency-free line plot for benchmark stdout."""
    ys = _np(ys).astype(np.float64)
    if len(ys) > width:
        idx = np.linspace(0, len(ys) - 1, width).astype(int)
        ys = ys[idx]
    lo, hi = float(ys.min()), float(ys.max())
    span = (hi - lo) or 1.0
    rows = []
    q = np.clip(((ys - lo) / span * (height - 1)).round().astype(int), 0,
                height - 1)
    for r in range(height - 1, -1, -1):
        line = "".join("█" if q[c] >= r else " " for c in range(len(ys)))
        rows.append(f"{(lo + span * r / (height - 1)):10.1f} |{line}")
    rows.append(" " * 11 + "+" + "-" * len(ys))
    if label:
        rows.insert(0, f"  {label}  [min={lo:.2f} max={hi:.2f}]")
    return "\n".join(rows)
