"""Jitted window/step scheduling engine (paper §3.2).

The time series of queued I/O requests is split into fixed-size *time
windows*; within a window the requests are grouped into *steps* (all
requests on the same object form one step so the object is fetched once,
Fig. 7) and scheduled sequentially against the client-side statistic log.

Everything is shape-static so a full paper evaluation (100 trials x 5
policies x 2000 requests) runs as a handful of jitted programs:

* ``group_by_object``    — step formation (same-object aggregation) with a
                           static output size (padding marked invalid).
* ``run_window``         — plan (sorts / sections) + ``lax.scan`` over the
                           window's steps, applying Eqs. (1)-(3) per step.
* ``run_stream``         — ``lax.scan`` over windows.

Outputs per request: the chosen server (original request order) and the
probe-message count (0 for all log-assisted policies, 2/request for the
SC'14 two-choice baseline).
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import policies as P
from repro.core import statlog
from repro.core.statlog import LogConfig, SchedState


class Workload(NamedTuple):
    """A batch of I/O requests (static length; ``valid`` marks padding)."""

    object_ids: jax.Array  # (R,) int32
    lengths: jax.Array     # (R,) float32, MB
    valid: jax.Array       # (R,) bool

    @property
    def n_requests(self) -> int:
        return self.object_ids.shape[0]


class ScheduleResult(NamedTuple):
    state: SchedState
    chosen: jax.Array        # (R,) int32 server per request (original order)
    probe_msgs: jax.Array    # () int32 total probe messages issued
    redirected: jax.Array    # (R,) bool — True where chosen != default home


def group_by_object_with_map(work: Workload) -> Tuple[Workload, jax.Array]:
    """Form steps: aggregate same-object requests into one decision (§3.2).

    Static-shape friendly: output has the same length R; the first
    occurrence of each object carries the summed length, duplicates are
    marked invalid (zero length).  Also returns ``req_to_step``: for every
    ORIGINAL request index, the row of its aggregated step (so per-request
    results can be scattered back).
    """
    r = work.n_requests
    ids = jnp.where(work.valid, work.object_ids, jnp.iinfo(jnp.int32).max)
    order = jnp.argsort(ids, stable=True)
    s_ids = ids[order]
    s_len = work.lengths[order] * work.valid[order]
    is_first = jnp.concatenate([jnp.ones((1,), bool), s_ids[1:] != s_ids[:-1]])
    # segment id per sorted row = running count of firsts - 1
    seg = jnp.cumsum(is_first) - 1
    summed = jax.ops.segment_sum(s_len, seg, num_segments=r)
    agg_len = jnp.where(is_first, summed[seg], 0.0)
    agg_valid = is_first & (s_ids != jnp.iinfo(jnp.int32).max)
    grouped = Workload(
        object_ids=jnp.where(agg_valid, s_ids, 0).astype(jnp.int32),
        lengths=agg_len.astype(jnp.float32),
        valid=agg_valid)
    rows = jnp.arange(r, dtype=jnp.int32)
    seg_first = jax.ops.segment_min(rows, seg, num_segments=r)  # step row
    inv_order = jnp.zeros((r,), jnp.int32).at[order].set(rows)
    req_to_step = seg_first[seg[inv_order]]
    return grouped, req_to_step


def group_by_object(work: Workload) -> Workload:
    return group_by_object_with_map(work)[0]


def run_window(state: SchedState, work: Workload, key: jax.Array, *,
               policy: P.PolicyConfig, log_cfg: LogConfig,
               group_steps: bool = True) -> ScheduleResult:
    """Schedule one time window's requests against the log.

    ``chosen``/``redirected`` come back in ORIGINAL request order (grouped
    same-object steps share one decision)."""
    orig_work = work
    req_to_step = None
    if group_steps:
        work, req_to_step = group_by_object_with_map(work)
    r = work.n_requests
    m = state.n_servers
    plan = P.plan_window(policy, state, work.object_ids, work.lengths, work.valid)

    # Process in plan order; emit (orig_index, chosen) pairs and unpermute.
    obj = work.object_ids[plan.order]
    lens = work.lengths[plan.order]
    val = work.valid[plan.order]
    keys = jax.random.split(key, r)

    def body(st: SchedState, xs):
        pos, o, ln, v, k = xs
        default = (o % m).astype(jnp.int32)
        target = P.select_target(policy, plan, st, pos, o, ln, k)
        chosen = P.apply_threshold(policy, st, default, target, ln)
        st2 = statlog.apply_assignment(st, chosen, ln, log_cfg)
        # padding rows leave the log untouched
        st = jax.tree.map(lambda a, b: jnp.where(v, b, a), st, st2)
        return st, (chosen, chosen != default)

    pos = jnp.arange(r, dtype=jnp.int32)
    state, (chosen_sorted, redir_sorted) = jax.lax.scan(
        body, state, (pos, obj, lens, val, keys))
    if log_cfg.renorm:
        state = statlog.renormalize(state)

    # scatter back: plan order -> step order -> original request order
    inv = jnp.zeros((r,), jnp.int32).at[plan.order].set(pos)
    chosen = chosen_sorted[inv]
    redirected = redir_sorted[inv] & work.valid
    if req_to_step is not None:
        chosen = chosen[req_to_step]
        redirected = redirected[req_to_step] & orig_work.valid
    probes = (jnp.sum(work.valid) * policy.probes_per_request).astype(jnp.int32)
    return ScheduleResult(state=state, chosen=chosen, probe_msgs=probes,
                          redirected=redirected)


def run_stream(state: SchedState, work: Workload, key: jax.Array, *,
               policy: P.PolicyConfig, log_cfg: LogConfig, window_size: int,
               group_steps: bool = True) -> ScheduleResult:
    """Split the request time series into windows and schedule each (§3.2).

    Pads the stream to a multiple of ``window_size``; padding is invalid.
    """
    r = work.n_requests
    n_win = -(-r // window_size)
    pad = n_win * window_size - r

    def pad_to(a, fill=0):
        return jnp.concatenate([a, jnp.full((pad,), fill, a.dtype)]) if pad else a

    obj = pad_to(work.object_ids).reshape(n_win, window_size)
    lens = pad_to(work.lengths).reshape(n_win, window_size)
    val = pad_to(work.valid, False).reshape(n_win, window_size)
    keys = jax.random.split(key, n_win)

    def body(st, xs):
        o, ln, v, k = xs
        res = run_window(st, Workload(o, ln, v), k, policy=policy,
                         log_cfg=log_cfg, group_steps=group_steps)
        return res.state, (res.chosen, res.probe_msgs, res.redirected)

    state, (chosen, probes, redirected) = jax.lax.scan(
        body, state, (obj, lens, val, keys))
    return ScheduleResult(
        state=state,
        chosen=chosen.reshape(-1)[:r],
        probe_msgs=jnp.sum(probes).astype(jnp.int32),
        redirected=redirected.reshape(-1)[:r],
    )


@functools.partial(jax.jit, static_argnames=("policy", "log_cfg",
                                             "window_size", "group_steps"))
def run_stream_jit(state, work, key, *, policy, log_cfg, window_size,
                   group_steps=True):
    return run_stream(state, work, key, policy=policy, log_cfg=log_cfg,
                      window_size=window_size, group_steps=group_steps)
