"""Jitted window/step scheduling engine (paper §3.2).

The time series of queued I/O requests is split into fixed-size *time
windows*; within a window the requests are grouped into *steps* (all
requests on the same object form one step so the object is fetched once,
Fig. 7) and scheduled sequentially against the client-side statistic log.

Everything is shape-static so a full paper evaluation (100 trials x 5
policies x 2000 requests) runs as a handful of jitted programs:

* ``group_by_object``    — step formation (same-object aggregation) with a
                           static output size (padding marked invalid).
* ``run_window``         — plan (sorts / sections) + ``lax.scan`` over the
                           window's steps, applying Eqs. (1)-(3) per step.
* ``run_stream``         — ``lax.scan`` over windows.

Outputs per request: the chosen server (original request order), the
probe-message count (0 for all log-assisted policies, 2/request for the
SC'14 two-choice baseline), and the estimated completion latency.

Temporal model (DESIGN.md §Temporal-model): ``run_stream`` optionally
takes a :class:`ClusterTrace` — a static-shape schedule of per-server
service-rate events (straggler onset/recovery, flapping, correlated rack
degradation, permanent heterogeneity).  Between windows the engine
applies the trace's rates, drains each server's queue for ``window_dt``
virtual seconds (:func:`repro.core.statlog.advance_time`), and records a
per-request estimated completion time; completions feed the log's
``ewma_lat`` so the ECT policy sees *slow* servers in the JAX path.  With
``trace=None`` (or the degenerate all-equal-rates, ``window_dt=0``
trace) the engine reproduces the paper's static-load model exactly.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import policies as P
from repro.core import policy_core, statlog
from repro.core.statlog import LogConfig, SchedState
from repro.tune import profile as tune_profile

# Policies the Pallas backend (kernels/sched_select) implements in-VMEM —
# since the in-VMEM bitonic sort (DESIGN.md §10) this is every engine
# policy: the whole §3.4 library dispatches through the kernel.
KERNEL_POLICIES = ("ect", "trh", "mlml", "nltr", "rr", "two_choice")


class Workload(NamedTuple):
    """A batch of I/O requests (static length; ``valid`` marks padding)."""

    object_ids: jax.Array  # (R,) int32
    lengths: jax.Array     # (R,) float32, MB
    valid: jax.Array       # (R,) bool

    @property
    def n_requests(self) -> int:
        return self.object_ids.shape[0]


class ClusterTrace(NamedTuple):
    """Static-shape schedule of service-rate change events.

    Row ``e`` says: from virtual time ``times[e]`` on, server ``i`` serves
    at ``rates[e, i]`` MB/s.  ``times[0]`` must be 0 (the base rates).
    Piecewise-constant rates express every scenario in the library:
    permanent heterogeneity (1 event), transient stragglers (3), flapping
    (alternating events), correlated rack degradation (rack rows slowed).
    """

    times: jax.Array   # (E,) float32, ascending, times[0] == 0
    rates: jax.Array   # (E, M) float32 MB/s per server

    @property
    def n_events(self) -> int:
        return self.times.shape[0]


def rates_at(trace: ClusterTrace, t: jax.Array) -> jax.Array:
    """(M,) service rates in effect at virtual time ``t``."""
    idx = jnp.sum(trace.times <= t) - 1
    return trace.rates[jnp.clip(idx, 0, trace.n_events - 1)]


class ScheduleResult(NamedTuple):
    state: SchedState
    chosen: jax.Array        # (R,) int32 server per request (original order)
    probe_msgs: jax.Array    # () int32 total probe messages issued
    redirected: jax.Array    # (R,) bool — True where chosen != default home
    latencies: jax.Array     # (R,) float32 est. completion latency, seconds
    #                          (queue ahead + own bytes, at assignment time)
    window_loads: jax.Array  # (W, M) per-window post-drain load snapshots
    #                          (W=1 for run_window)
    rng: Optional[jax.Array] = None  # final uint32 LCG state (rng="lcg"
    #                          policies; None for the kernel backend which
    #                          keeps its LCG in VMEM)


def group_by_object_with_map(work: Workload) -> Tuple[Workload, jax.Array]:
    """Form steps: aggregate same-object requests into one decision (§3.2).

    Static-shape friendly: output has the same length R; the first
    occurrence of each object carries the summed length, duplicates are
    marked invalid (zero length).  Also returns ``req_to_step``: for every
    ORIGINAL request index, the row of its aggregated step (so per-request
    results can be scattered back).
    """
    r = work.n_requests
    ids = jnp.where(work.valid, work.object_ids, jnp.iinfo(jnp.int32).max)
    # contract-ok: CC-SORT engine-side step grouping keeps backend argsort (§10)
    order = jnp.argsort(ids, stable=True)
    s_ids = ids[order]
    s_len = work.lengths[order] * work.valid[order]
    is_first = jnp.concatenate([jnp.ones((1,), bool), s_ids[1:] != s_ids[:-1]])
    # segment id per sorted row = running count of firsts - 1
    # contract-ok: CC-CUMSUM integer prefix count — association-free (§9)
    seg = jnp.cumsum(is_first) - 1
    summed = jax.ops.segment_sum(s_len, seg, num_segments=r)
    agg_len = jnp.where(is_first, summed[seg], 0.0)
    agg_valid = is_first & (s_ids != jnp.iinfo(jnp.int32).max)
    grouped = Workload(
        object_ids=jnp.where(agg_valid, s_ids, 0).astype(jnp.int32),
        lengths=agg_len.astype(jnp.float32),
        valid=agg_valid)
    rows = jnp.arange(r, dtype=jnp.int32)
    seg_first = jax.ops.segment_min(rows, seg, num_segments=r)  # step row
    inv_order = jnp.zeros((r,), jnp.int32).at[order].set(rows)
    req_to_step = seg_first[seg[inv_order]]
    return grouped, req_to_step


def group_by_object(work: Workload) -> Workload:
    return group_by_object_with_map(work)[0]


def run_window(state: SchedState, work: Workload, key: jax.Array, *,
               policy: P.PolicyConfig, log_cfg: LogConfig,
               group_steps: bool = True,
               observe: bool = False,
               rng0: Optional[jax.Array] = None) -> ScheduleResult:
    """Schedule one time window's requests against the log.

    ``chosen``/``redirected`` come back in ORIGINAL request order (grouped
    same-object steps share one decision).

    ``observe`` (temporal model; on whenever ``run_stream`` has a trace)
    folds each request's estimated effective MB/s into ``ewma_lat`` right
    after its assignment — the completion-feedback path that lets ECT see
    slow servers.  Off by default so the static model (and the Pallas
    kernel's minload semantics) stay bit-exact with the paper.

    ``rng0`` seeds the kernel-compatible LCG stream (``rng="lcg"``
    policies); the final state comes back in ``ScheduleResult.rng`` so
    ``run_stream`` can carry it across windows exactly like the kernel
    carries its VMEM rng across the whole stream."""
    orig_work = work
    req_to_step = None
    if group_steps:
        work, req_to_step = group_by_object_with_map(work)
    r = work.n_requests
    m = state.n_servers
    plan = P.plan_window(policy, state, work.object_ids, work.lengths, work.valid)
    if rng0 is None:
        rng0 = jnp.zeros((), jnp.uint32)

    # Process in plan order; emit (orig_index, chosen) pairs and unpermute.
    obj = work.object_ids[plan.order]
    lens = work.lengths[plan.order]
    val = work.valid[plan.order]
    keys = jax.random.split(key, r)

    def body(carry, xs):
        st, rng = carry
        pos, o, ln, v, k = xs
        default = (o % m).astype(jnp.int32)
        # NOTE: the LCG advances on padding rows too — the kernel's
        # unconditional draw stream, required for bit-exact parity.
        target, rng = P.select_target_rng(policy, plan, st, pos, o, ln, k,
                                          rng)
        chosen = P.apply_threshold(policy, st, default, target, ln)
        st2 = statlog.apply_assignment(st, chosen, ln, log_cfg)
        # Estimated completion latency: everything queued ahead of (and
        # including) this request, at the server's current service rate.
        lat = statlog.estimated_latency(st2, chosen)
        if observe:
            # Completion feedback: the effective MB/s this request will
            # see folds into ewma_lat — the ECT policy's rate signal (the
            # host twin observes the same via WriteResult.mb_per_s).
            st2 = statlog.observe_completion(
                st2, chosen, ln / jnp.maximum(lat, 1e-9), log_cfg)
        # padding rows leave the log untouched
        st = jax.tree.map(lambda a, b: jnp.where(v, b, a), st, st2)
        return (st, rng), (chosen, chosen != default, jnp.where(v, lat, 0.0))

    pos = jnp.arange(r, dtype=jnp.int32)
    (state, rng), (chosen_sorted, redir_sorted, lat_sorted) = jax.lax.scan(
        body, (state, rng0), (pos, obj, lens, val, keys))
    if log_cfg.renorm:
        state = statlog.renormalize(state)

    # scatter back: plan order -> step order -> original request order.
    # The engine keeps XLA's gather/scatter here; the kernel's §13
    # inverse permutation apply (permute_from_sorted) computes the SAME
    # relocation (property-pinned in tests/test_policies.py), so the
    # backends stay bit-exact without sharing this code path.
    inv = jnp.zeros((r,), jnp.int32).at[plan.order].set(pos)
    chosen = chosen_sorted[inv]
    redirected = redir_sorted[inv] & work.valid
    latencies = lat_sorted[inv] * work.valid
    if req_to_step is not None:
        chosen = chosen[req_to_step]
        redirected = redirected[req_to_step] & orig_work.valid
        latencies = latencies[req_to_step] * orig_work.valid
    probes = (jnp.sum(work.valid) * policy.probes_per_request).astype(jnp.int32)
    return ScheduleResult(state=state, chosen=chosen, probe_msgs=probes,
                          redirected=redirected, latencies=latencies,
                          window_loads=state.loads[None], rng=rng)


def _window_split(work: Workload, window_size: int):
    """Pad the stream to a multiple of ``window_size`` and reshape to
    (W, window_size) arrays (padding rows invalid)."""
    r = work.n_requests
    n_win = -(-r // window_size)
    pad = n_win * window_size - r

    def pad_to(a, fill=0):
        return jnp.concatenate([a, jnp.full((pad,), fill, a.dtype)]) if pad else a

    obj = pad_to(work.object_ids).reshape(n_win, window_size)
    lens = pad_to(work.lengths).reshape(n_win, window_size)
    val = pad_to(work.valid, False).reshape(n_win, window_size)
    return n_win, obj, lens, val


def _window_rates(state: SchedState, trace: Optional[ClusterTrace],
                  n_win: int, window_dt: float) -> jax.Array:
    """(W, M) service rates in effect at each window open."""
    if trace is not None:
        t_open = jnp.arange(n_win, dtype=jnp.float32) * window_dt
        return jax.vmap(lambda t: rates_at(trace, t))(t_open)
    # static model: keep whatever rates the state carries
    return jnp.broadcast_to(state.rates, (n_win, state.n_servers))


def grouped_latency_block(works: Workload, latencies: jax.Array,
                          window_size: int, group_steps: bool = True
                          ) -> Tuple[jax.Array, jax.Array]:
    """Recover the kernel's MERGED LATENCY BLOCK on the jax backend
    (DESIGN.md §14): grouped-step latencies + validity per stream.

    The kernel path schedules pre-grouped streams, so its in-VMEM block
    (``ClientMerge.lats``/``lats_valid``) holds GROUPED-STEP latencies;
    `run_stream` instead scatters step latencies back to original
    request order (duplicate same-object requests share their step's
    bits).  This helper replays the identical window split + grouping
    and recovers each step's latency with a ``segment_min`` over its
    requests — pure selection over identical f32 values, so the result
    is bit-exact with the kernel block's multiset and
    `policy_core.nearest_rank_p99` over either is bit-identical.

    ``works`` fields and ``latencies`` share a shape ``(..., R)`` with
    any number of leading batch axes; returns ``(lats, valid)`` shaped
    ``(..., N)`` where ``N = ceil(R / window_size) * window_size``
    (invalid steps masked to 0.0; ``valid`` is bool).
    """

    def one(obj_r, len_r, val_r, lat_r):
        n_win, obj, lens, val = _window_split(
            Workload(object_ids=obj_r, lengths=len_r, valid=val_r),
            window_size)
        pad = n_win * window_size - obj_r.shape[0]
        lat_p = (jnp.concatenate([lat_r, jnp.zeros((pad,), lat_r.dtype)])
                 if pad else lat_r)
        lat_w = lat_p.reshape(n_win, window_size)
        if not group_steps:
            return (jnp.where(val, lat_w, 0.0).reshape(-1),
                    val.reshape(-1))
        grouped, req_to_step = jax.vmap(group_by_object_with_map)(
            Workload(object_ids=obj, lengths=lens, valid=val))
        g_lat = jax.vmap(lambda lr, mp, v: jax.ops.segment_min(
            jnp.where(v, lr, jnp.float32(jnp.inf)), mp,
            num_segments=window_size))(lat_w, req_to_step, val)
        g_lat = jnp.where(grouped.valid, g_lat, 0.0)
        return g_lat.reshape(-1), grouped.valid.reshape(-1)

    fn = one
    for _ in range(latencies.ndim - 1):
        fn = jax.vmap(fn)
    return fn(works.object_ids, works.lengths, works.valid, latencies)


def run_stream(state: SchedState, work: Workload, key: jax.Array, *,
               policy: P.PolicyConfig, log_cfg: LogConfig, window_size: int,
               group_steps: bool = True,
               trace: Optional[ClusterTrace] = None,
               window_dt: float = 0.0,
               observe: Optional[bool] = None,
               backend: str = "jax") -> ScheduleResult:
    """Split the request time series into windows and schedule each (§3.2).

    Pads the stream to a multiple of ``window_size``; padding is invalid.

    Temporal model: window ``w`` opens at virtual time ``w * window_dt``.
    When a ``trace`` is given, the rates in effect at each window start are
    looked up from it before scheduling, and after the window the queues
    drain for ``window_dt`` seconds at those rates.  ``window_dt`` must be
    a static python float (0.0 disables draining — the static model).

    ``observe`` controls the completion-feedback path (see
    :func:`run_window`); default: on exactly when a trace is given.  Pass
    ``observe=False`` with a trace to keep ewma-reading policies (ECT)
    bit-identical to the no-trace path — the degenerate static scenario
    does this (the feedback would differ from the never-observing static
    model even with all-equal rates).

    ``backend`` selects the execution substrate: ``"jax"`` (the lax.scan
    engine, every policy) or ``"kernel"`` (the Pallas temporal kernel —
    the whole stream as ONE ``pallas_call`` with the packed log tensor in
    VMEM; every policy in ``KERNEL_POLICIES``, i.e. the full §3.4
    library since the in-VMEM sorts of DESIGN.md §10).  The two backends
    are bit-exact for the deterministic policies (``ect``, ``mlml``,
    ``rr``); for the randomized ones (``trh``, ``nltr``, ``two_choice``)
    pass ``PolicyConfig(rng="lcg")`` so the jax path replays the
    kernel's LCG stream.
    """
    P.validate_policy(policy, state.n_servers)
    if observe is None:
        observe = trace is not None
    if backend == "kernel":
        return _run_stream_kernel(state, work, key, policy=policy,
                                  log_cfg=log_cfg, window_size=window_size,
                                  group_steps=group_steps, trace=trace,
                                  window_dt=window_dt, observe=observe)
    if backend != "jax":
        raise ValueError(f"backend must be 'jax' or 'kernel', got {backend!r}")
    r = work.n_requests
    n_win, obj, lens, val = _window_split(work, window_size)
    keys = jax.random.split(key, n_win)
    win_rates = _window_rates(state, trace, n_win, window_dt)
    # Drain decrements materialize OUTSIDE the scan body (scan xs) so the
    # in-body drain is a bare subtract — no FMA-contractable product, the
    # §9 bit-exactness contract shared with the kernel backend.
    win_dec = policy_core.window_decrements(win_rates, window_dt)
    # Kernel-compatible LCG seed: both backends derive it identically
    # from the stream key, then carry ONE rng across all windows.
    rng0 = jax.random.bits(key, dtype=jnp.uint32)

    def body(carry, xs):
        st, rng = carry
        o, ln, v, k, rates, dec = xs
        st = st._replace(rates=rates)
        res = run_window(st, Workload(o, ln, v), k, policy=policy,
                         log_cfg=log_cfg, group_steps=group_steps,
                         observe=observe, rng0=rng)
        st = res.state
        if window_dt:
            st = statlog.advance_time(st, jnp.float32(window_dt), dec=dec)
        return (st, res.rng), (res.chosen, res.probe_msgs, res.redirected,
                               res.latencies, st.loads)

    (state, rng), (chosen, probes, redirected, latencies, window_loads) = \
        jax.lax.scan(body, (state, rng0),
                     (obj, lens, val, keys, win_rates, win_dec))
    return ScheduleResult(
        state=state,
        chosen=chosen.reshape(-1)[:r],
        probe_msgs=jnp.sum(probes).astype(jnp.int32),
        redirected=redirected.reshape(-1)[:r],
        latencies=latencies.reshape(-1)[:r],
        window_loads=window_loads,
        rng=rng,
    )


def _run_stream_kernel(state: SchedState, work: Workload, key: jax.Array, *,
                       policy: P.PolicyConfig, log_cfg: LogConfig,
                       window_size: int, group_steps: bool,
                       trace: Optional[ClusterTrace], window_dt: float,
                       observe: bool) -> ScheduleResult:
    """Pallas-backend stream dispatch: grouping / window planning stays on
    the JAX side (same `group_by_object_with_map` as the jax backend, so
    both backends see identical per-window inputs); the per-request
    decision loop — selection, threshold guard, Eq. (1)-(3), completion
    feedback, per-window renorm + drain — runs as one `pallas_call` with
    the packed (4, M) log tensor pinned in VMEM."""
    from repro.kernels.sched_select import ops as kops

    if policy.name not in KERNEL_POLICIES:
        raise ValueError(
            f"backend='kernel' supports {KERNEL_POLICIES}, got "
            f"{policy.name!r}")
    r = work.n_requests
    m = state.n_servers
    n_win, obj, lens, val = _window_split(work, window_size)
    if group_steps:
        grouped, req_to_step = jax.vmap(group_by_object_with_map)(
            Workload(obj, lens, val))
        g_obj, g_lens, g_val = (grouped.object_ids, grouped.lengths,
                                grouped.valid)
    else:
        g_obj, g_lens, g_val, req_to_step = obj, lens, val, None
    win_rates = _window_rates(state, trace, n_win, window_dt)
    seed = jax.random.bits(key, dtype=jnp.uint32)

    choices, lats, table, wloads = kops.sched_stream(
        g_obj.reshape(-1), g_lens.reshape(-1), g_val.reshape(-1),
        state.log, seed, win_rates,
        n_servers=m, window_size=window_size, threshold=policy.threshold,
        lam=log_cfg.lam, alpha=log_cfg.ewma_alpha, window_dt=window_dt,
        policy=policy.name, observe=observe, renorm=log_cfg.renorm,
        nltr_n=policy.nltr_n, probe_choices=policy.probe_choices)

    return _kernel_bookkeeping(state, choices, lats, table, wloads, g_obj,
                               g_val, val, req_to_step, win_rates[-1],
                               policy=policy, window_dt=window_dt,
                               n_win=n_win, window_size=window_size, r=r)


def _kernel_bookkeeping(state: SchedState, choices, lats, table, wloads,
                        g_obj, g_val, val, req_to_step, rates_last, *,
                        policy: P.PolicyConfig, window_dt: float, n_win: int,
                        window_size: int, r: int) -> ScheduleResult:
    """Host-side bookkeeping the kernel leaves behind, for ONE stream:
    redirect derivation, grouped-step -> request scatter, per-server
    assignment counts, probe accounting (from
    ``PolicyConfig.probes_per_request`` — nonzero only for two_choice)
    and the vclock/free_at replay.  Shared by the sequential kernel path
    and (vmapped) `run_stream_batch`, so batch-vs-sequential parity is
    structural rather than maintained in two copies.

    choices/lats: (N,) over grouped steps; g_obj/g_val/val (and
    req_to_step when grouping): (n_win, window_size); table: (4, M);
    wloads: (n_win, M); rates_last: (M,) rates at the last window.
    """
    m = table.shape[-1]
    chosen_w = choices.reshape(n_win, window_size)
    lat_w = lats.reshape(n_win, window_size)
    redir_w = (chosen_w != (g_obj % m).astype(jnp.int32)) & g_val
    if req_to_step is not None:
        take = jax.vmap(lambda a, idx: a[idx])
        chosen_w = take(chosen_w, req_to_step)
        lat_w = take(lat_w, req_to_step)
        redir_w = take(redir_w, req_to_step)
    lat_w = lat_w * val
    redir_w = redir_w & val

    counts = jax.ops.segment_sum(g_val.reshape(-1).astype(jnp.int32),
                                 choices, num_segments=m)
    if window_dt:
        vclock = state.vclock
        for _ in range(n_win):   # sequential f32 adds: match advance_time
            vclock = vclock + jnp.float32(window_dt)
        free_at = vclock + table[policy_core.ROW_LOADS] / jnp.maximum(
            rates_last, 1e-6)
    else:
        vclock, free_at = state.vclock, state.free_at
    fstate = SchedState(log=table, n_assigned=state.n_assigned + counts,
                        rates=rates_last, vclock=vclock, free_at=free_at)
    probes = (jnp.sum(g_val) * policy.probes_per_request).astype(jnp.int32)
    return ScheduleResult(
        state=fstate,
        chosen=chosen_w.reshape(-1)[:r],
        probe_msgs=probes,
        redirected=redir_w.reshape(-1)[:r],
        latencies=lat_w.reshape(-1)[:r],
        window_loads=wloads,
    )


@functools.partial(jax.jit, static_argnames=("policy", "log_cfg",
                                             "window_size", "group_steps",
                                             "window_dt", "observe",
                                             "backend"))
def run_stream_jit(state, work, key, *, policy, log_cfg, window_size,
                   group_steps=True, trace=None, window_dt=0.0,
                   observe=None, backend="jax"):
    return run_stream(state, work, key, policy=policy, log_cfg=log_cfg,
                      window_size=window_size, group_steps=group_steps,
                      trace=trace, window_dt=window_dt, observe=observe,
                      backend=backend)


class ClientMerge(NamedTuple):
    """Per-trial cross-client aggregates fused in-VMEM by the 2-D
    (trials × clients) grid kernel (DESIGN.md §11) — the per_client
    contention model's "typical client" view, merged over REAL clients
    (a client is real iff its slice scheduled at least one valid
    request; phantom padded clients are masked out with the
    `policy_core.masked_client_sum` association).

    ``lats``/``lats_valid`` are the MERGED LATENCY BLOCK (DESIGN.md
    §14): every client's grouped-step latencies (masked to 0 where
    invalid) and 0/1 validity, accumulated in VMEM across the client
    grid steps.  With ``merge_mean=True`` the kernel has already
    bisected the trial's cross-client nearest-rank p99 out of it into
    ``metrics[:, MET_P99]``; with ``merge_mean=False`` (the sharded
    sweep) the lane is 0 and the raw block ships so
    `parallel.sweep.run_sweep` can all-gather it and bisect the GLOBAL
    p99 once — `policy_core.nearest_rank_p99` is order- and
    layout-insensitive, so the gather order cannot drift it."""

    window_loads_mean: jax.Array  # (T, W, M) masked client-mean snapshots
    metrics: jax.Array            # (T, N_CMETRICS) merged MET_* rows
    lats: jax.Array               # (T, C, N) masked grouped-step latencies
    lats_valid: jax.Array         # (T, C, N) 0/1 f32 validity


def run_stream_batch(states: SchedState, works: Workload, keys: jax.Array, *,
                     policy: P.PolicyConfig, log_cfg: LogConfig,
                     window_size: int, group_steps: bool = True,
                     traces: Optional[ClusterTrace] = None,
                     window_dt: float = 0.0,
                     observe: Optional[bool] = None,
                     trial_tile: Optional[int] = None,
                     client_tile: Optional[int] = None,
                     merge_mean: bool = True,
                     ablate: int = 0,
                     backend: str = "kernel"
                     ) -> Tuple[ScheduleResult, Optional[jax.Array],
                                Optional[ClientMerge]]:
    """Batched dispatch: a whole batch of `run_stream` traces as ONE
    pallas_call, for an arbitrary leading batch shape.

    ``states`` / ``works`` / ``keys`` carry either a ``(T,)`` leading
    trial axis (the PR-3 trial grid) or a ``(T, C)`` (trials × clients)
    axis pair — the per_client contention model, where each of a
    trial's C clients schedules its private request slice against its
    own log and ``traces`` stays per-TRIAL (a trial's clients share the
    cluster's rate schedule).  The JAX-side prep is the per-stream
    `run_stream` prep vmapped (window split, `group_by_object_with_map`
    step formation, per-window trace rates), so every stream sees
    bit-identical inputs to the sequential path; the scheduling itself
    runs on the trial-grid kernel (``grid = ceil(T / trial_tile)``) or
    the 2-D grid kernel (``grid = (ceil(T / tt), ceil(C / ct))``,
    DESIGN.md §11), streams vectorized over VMEM sublanes either way.

    Returns ``(result, metrics, client_merge)``: ``result`` is a
    ScheduleResult whose fields all carry the leading batch axes,
    bit-exact per stream vs. `run_stream(backend="kernel")` under
    ``lax.map``; ``metrics`` is the kernel's fused in-VMEM reduction,
    ``(T[, C], N_METRICS)`` f32 in `policy_core.MET_*` order (makespan /
    nearest-rank p99 / latency sum / latency max / valid count over the
    scheduled steps) — the headline sweep numbers without an HBM
    round-trip of the latency blocks; ``client_merge`` is the
    :class:`ClientMerge` cross-client row for the (T, C) form and
    ``None`` for the (T,) form.

    ``backend="jax"`` runs the same batch on the vmapped lax.scan
    engine instead (the dispatch `simulate._run_batched` used inline
    before the sharded sweep unified both backends behind this one
    entry point): bit-exact per stream vs. the kernel path, returning
    ``(result, None, None)`` — no fused metrics/merge rows; callers
    compute the `policy_core` merge twins host-side.  ``merge_mean``
    (kernel (T, C) form only): ``False`` ships `ClientMerge.
    window_loads_mean` as the raw masked client SUM instead of the mean
    — the pre-reduced per-device block that the sharded sweep
    (`parallel/sweep.py`, DESIGN.md §12) folds across devices with
    `policy_core.psum_tree` before dividing once, globally.

    ``ablate`` (kernel (T,) form only) drops trailing kernel window
    phases for differential per-phase profiling (DESIGN.md §16, see
    `repro.tune.profile.kernel_phase_profile`); outputs past the
    dropped phase are zeros, so nonzero levels are timing-only.
    """
    from repro.kernels.sched_select import ops as kops

    if backend not in ("jax", "kernel"):
        raise ValueError(f"backend={backend!r} must be 'jax' or 'kernel'")
    if ablate and backend != "kernel":
        raise ValueError("ablate profiling levels need backend='kernel'")
    P.validate_policy(policy, states.n_servers)
    if observe is None:
        observe = traces is not None

    if backend == "jax":
        run1 = functools.partial(
            run_stream, policy=policy, log_cfg=log_cfg,
            window_size=window_size, group_steps=group_steps,
            window_dt=window_dt, observe=observe, backend="jax")
        fn = lambda st, w, k, tr: run1(st, w, k, trace=tr)  # noqa: E731
        tr_ax = None if traces is None else 0
        if works.object_ids.ndim == 3:   # (T, C): traces stay per-trial
            inner = jax.vmap(fn, in_axes=(0, 0, 0, None))
            res = jax.vmap(inner, in_axes=(0, 0, 0, tr_ax))(
                states, works, keys, traces)
        else:
            res = jax.vmap(fn, in_axes=(0, 0, 0, tr_ax))(
                states, works, keys, traces)
        return res, None, None

    if policy.name not in KERNEL_POLICIES:
        raise ValueError(
            f"run_stream_batch supports {KERNEL_POLICIES}, got "
            f"{policy.name!r}")
    batch_shape = works.object_ids.shape[:-1]     # (T,) or (T, C)
    two_d = len(batch_shape) == 2
    if ablate and two_d:
        raise ValueError("ablate profiling levels support the trial-grid "
                         "(1-D) form only")
    r = works.object_ids.shape[-1]
    m = states.n_servers

    n_win = -(-r // window_size)

    def prep(state, work, key):
        _, obj, lens, val = _window_split(work, window_size)
        if group_steps:
            grouped, req_to_step = jax.vmap(group_by_object_with_map)(
                Workload(obj, lens, val))
            g_obj, g_lens, g_val = (grouped.object_ids, grouped.lengths,
                                    grouped.valid)
        else:
            g_obj, g_lens, g_val, req_to_step = obj, lens, val, None
        seed = jax.random.bits(key, dtype=jnp.uint32)
        return (g_obj.reshape(-1), g_lens.reshape(-1), g_val.reshape(-1),
                seed, val, req_to_step)

    vprep = jax.vmap(jax.vmap(prep)) if two_d else jax.vmap(prep)
    with tune_profile.stage("engine_prep"):
        g_obj, g_lens, g_val, seeds, val, req_to_step = \
            vprep(states, works, keys)
    if traces is not None:
        win_rates = jax.vmap(
            lambda tr: _window_rates(None, tr, n_win, window_dt)
        )(traces)
    else:
        # 2-D: rates are per TRIAL (client-shared) — read client 0's row
        rate_states = (jax.tree.map(lambda a: a[:, 0], states) if two_d
                       else states)
        win_rates = jax.vmap(
            lambda st: _window_rates(st, None, n_win, window_dt)
        )(rate_states)

    kw = dict(n_servers=m, window_size=window_size,
              threshold=policy.threshold, lam=log_cfg.lam,
              alpha=log_cfg.ewma_alpha, window_dt=window_dt,
              policy=policy.name, observe=observe, renorm=log_cfg.renorm,
              nltr_n=policy.nltr_n, probe_choices=policy.probe_choices)
    with tune_profile.stage("kernel"):
        if two_d:
            (choices, lats, tables, wloads, metrics,
             cm_wl, cm_met, cm_lats, cm_lval) = kops.sched_stream_grid(
                g_obj, g_lens, g_val, states.log, seeds, win_rates,
                trial_tile=trial_tile, client_tile=client_tile,
                merge_mean=merge_mean, **kw)
            merged = ClientMerge(window_loads_mean=cm_wl, metrics=cm_met,
                                 lats=cm_lats, lats_valid=cm_lval)
        else:
            choices, lats, tables, wloads, metrics = kops.sched_stream_batch(
                g_obj, g_lens, g_val, states.log, seeds, win_rates,
                trial_tile=trial_tile, ablate=ablate, **kw)
            merged = None

    # host-side bookkeeping: the SAME single-stream helper as the
    # sequential kernel path, vmapped over the batch axes (every op in
    # it is exact — gathers, bool masks, integer segment sums,
    # elementwise f32 adds — so batching cannot drift it).
    book = functools.partial(
        _kernel_bookkeeping, policy=policy, window_dt=window_dt,
        n_win=n_win, window_size=window_size, r=r)
    if two_d:
        # rates_last is per trial: broadcast over the client axis
        vbook = jax.vmap(jax.vmap(book, in_axes=(0,) * 9 + (None,)))
    else:
        vbook = jax.vmap(book)
    with tune_profile.stage("book"):
        result = vbook(
            states, choices, lats, tables, wloads,
            g_obj.reshape(batch_shape + (n_win, window_size)),
            g_val.reshape(batch_shape + (n_win, window_size)), val,
            req_to_step, win_rates[:, -1])
    return result, metrics, merged
