"""Jitted window/step scheduling engine (paper §3.2).

The time series of queued I/O requests is split into fixed-size *time
windows*; within a window the requests are grouped into *steps* (all
requests on the same object form one step so the object is fetched once,
Fig. 7) and scheduled sequentially against the client-side statistic log.

Everything is shape-static so a full paper evaluation (100 trials x 5
policies x 2000 requests) runs as a handful of jitted programs:

* ``group_by_object``    — step formation (same-object aggregation) with a
                           static output size (padding marked invalid).
* ``run_window``         — plan (sorts / sections) + ``lax.scan`` over the
                           window's steps, applying Eqs. (1)-(3) per step.
* ``run_stream``         — ``lax.scan`` over windows.

Outputs per request: the chosen server (original request order), the
probe-message count (0 for all log-assisted policies, 2/request for the
SC'14 two-choice baseline), and the estimated completion latency.

Temporal model (DESIGN.md §Temporal-model): ``run_stream`` optionally
takes a :class:`ClusterTrace` — a static-shape schedule of per-server
service-rate events (straggler onset/recovery, flapping, correlated rack
degradation, permanent heterogeneity).  Between windows the engine
applies the trace's rates, drains each server's queue for ``window_dt``
virtual seconds (:func:`repro.core.statlog.advance_time`), and records a
per-request estimated completion time; completions feed the log's
``ewma_lat`` so the ECT policy sees *slow* servers in the JAX path.  With
``trace=None`` (or the degenerate all-equal-rates, ``window_dt=0``
trace) the engine reproduces the paper's static-load model exactly.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import policies as P
from repro.core import statlog
from repro.core.statlog import LogConfig, SchedState


class Workload(NamedTuple):
    """A batch of I/O requests (static length; ``valid`` marks padding)."""

    object_ids: jax.Array  # (R,) int32
    lengths: jax.Array     # (R,) float32, MB
    valid: jax.Array       # (R,) bool

    @property
    def n_requests(self) -> int:
        return self.object_ids.shape[0]


class ClusterTrace(NamedTuple):
    """Static-shape schedule of service-rate change events.

    Row ``e`` says: from virtual time ``times[e]`` on, server ``i`` serves
    at ``rates[e, i]`` MB/s.  ``times[0]`` must be 0 (the base rates).
    Piecewise-constant rates express every scenario in the library:
    permanent heterogeneity (1 event), transient stragglers (3), flapping
    (alternating events), correlated rack degradation (rack rows slowed).
    """

    times: jax.Array   # (E,) float32, ascending, times[0] == 0
    rates: jax.Array   # (E, M) float32 MB/s per server

    @property
    def n_events(self) -> int:
        return self.times.shape[0]


def rates_at(trace: ClusterTrace, t: jax.Array) -> jax.Array:
    """(M,) service rates in effect at virtual time ``t``."""
    idx = jnp.sum(trace.times <= t) - 1
    return trace.rates[jnp.clip(idx, 0, trace.n_events - 1)]


class ScheduleResult(NamedTuple):
    state: SchedState
    chosen: jax.Array        # (R,) int32 server per request (original order)
    probe_msgs: jax.Array    # () int32 total probe messages issued
    redirected: jax.Array    # (R,) bool — True where chosen != default home
    latencies: jax.Array     # (R,) float32 est. completion latency, seconds
    #                          (queue ahead + own bytes, at assignment time)
    window_loads: jax.Array  # (W, M) per-window post-drain load snapshots
    #                          (W=1 for run_window)


def group_by_object_with_map(work: Workload) -> Tuple[Workload, jax.Array]:
    """Form steps: aggregate same-object requests into one decision (§3.2).

    Static-shape friendly: output has the same length R; the first
    occurrence of each object carries the summed length, duplicates are
    marked invalid (zero length).  Also returns ``req_to_step``: for every
    ORIGINAL request index, the row of its aggregated step (so per-request
    results can be scattered back).
    """
    r = work.n_requests
    ids = jnp.where(work.valid, work.object_ids, jnp.iinfo(jnp.int32).max)
    order = jnp.argsort(ids, stable=True)
    s_ids = ids[order]
    s_len = work.lengths[order] * work.valid[order]
    is_first = jnp.concatenate([jnp.ones((1,), bool), s_ids[1:] != s_ids[:-1]])
    # segment id per sorted row = running count of firsts - 1
    seg = jnp.cumsum(is_first) - 1
    summed = jax.ops.segment_sum(s_len, seg, num_segments=r)
    agg_len = jnp.where(is_first, summed[seg], 0.0)
    agg_valid = is_first & (s_ids != jnp.iinfo(jnp.int32).max)
    grouped = Workload(
        object_ids=jnp.where(agg_valid, s_ids, 0).astype(jnp.int32),
        lengths=agg_len.astype(jnp.float32),
        valid=agg_valid)
    rows = jnp.arange(r, dtype=jnp.int32)
    seg_first = jax.ops.segment_min(rows, seg, num_segments=r)  # step row
    inv_order = jnp.zeros((r,), jnp.int32).at[order].set(rows)
    req_to_step = seg_first[seg[inv_order]]
    return grouped, req_to_step


def group_by_object(work: Workload) -> Workload:
    return group_by_object_with_map(work)[0]


def run_window(state: SchedState, work: Workload, key: jax.Array, *,
               policy: P.PolicyConfig, log_cfg: LogConfig,
               group_steps: bool = True,
               observe: bool = False) -> ScheduleResult:
    """Schedule one time window's requests against the log.

    ``chosen``/``redirected`` come back in ORIGINAL request order (grouped
    same-object steps share one decision).

    ``observe`` (temporal model; on whenever ``run_stream`` has a trace)
    folds each request's estimated effective MB/s into ``ewma_lat`` right
    after its assignment — the completion-feedback path that lets ECT see
    slow servers.  Off by default so the static model (and the Pallas
    kernel's minload semantics) stay bit-exact with the paper."""
    orig_work = work
    req_to_step = None
    if group_steps:
        work, req_to_step = group_by_object_with_map(work)
    r = work.n_requests
    m = state.n_servers
    plan = P.plan_window(policy, state, work.object_ids, work.lengths, work.valid)

    # Process in plan order; emit (orig_index, chosen) pairs and unpermute.
    obj = work.object_ids[plan.order]
    lens = work.lengths[plan.order]
    val = work.valid[plan.order]
    keys = jax.random.split(key, r)

    def body(st: SchedState, xs):
        pos, o, ln, v, k = xs
        default = (o % m).astype(jnp.int32)
        target = P.select_target(policy, plan, st, pos, o, ln, k)
        chosen = P.apply_threshold(policy, st, default, target, ln)
        st2 = statlog.apply_assignment(st, chosen, ln, log_cfg)
        # Estimated completion latency: everything queued ahead of (and
        # including) this request, at the server's current service rate.
        lat = statlog.estimated_latency(st2, chosen)
        if observe:
            # Completion feedback: the effective MB/s this request will
            # see folds into ewma_lat — the ECT policy's rate signal (the
            # host twin observes the same via WriteResult.mb_per_s).
            st2 = statlog.observe_completion(
                st2, chosen, ln / jnp.maximum(lat, 1e-9), log_cfg)
        # padding rows leave the log untouched
        st = jax.tree.map(lambda a, b: jnp.where(v, b, a), st, st2)
        return st, (chosen, chosen != default, jnp.where(v, lat, 0.0))

    pos = jnp.arange(r, dtype=jnp.int32)
    state, (chosen_sorted, redir_sorted, lat_sorted) = jax.lax.scan(
        body, state, (pos, obj, lens, val, keys))
    if log_cfg.renorm:
        state = statlog.renormalize(state)

    # scatter back: plan order -> step order -> original request order
    inv = jnp.zeros((r,), jnp.int32).at[plan.order].set(pos)
    chosen = chosen_sorted[inv]
    redirected = redir_sorted[inv] & work.valid
    latencies = lat_sorted[inv] * work.valid
    if req_to_step is not None:
        chosen = chosen[req_to_step]
        redirected = redirected[req_to_step] & orig_work.valid
        latencies = latencies[req_to_step] * orig_work.valid
    probes = (jnp.sum(work.valid) * policy.probes_per_request).astype(jnp.int32)
    return ScheduleResult(state=state, chosen=chosen, probe_msgs=probes,
                          redirected=redirected, latencies=latencies,
                          window_loads=state.loads[None])


def run_stream(state: SchedState, work: Workload, key: jax.Array, *,
               policy: P.PolicyConfig, log_cfg: LogConfig, window_size: int,
               group_steps: bool = True,
               trace: Optional[ClusterTrace] = None,
               window_dt: float = 0.0,
               observe: Optional[bool] = None) -> ScheduleResult:
    """Split the request time series into windows and schedule each (§3.2).

    Pads the stream to a multiple of ``window_size``; padding is invalid.

    Temporal model: window ``w`` opens at virtual time ``w * window_dt``.
    When a ``trace`` is given, the rates in effect at each window start are
    looked up from it before scheduling, and after the window the queues
    drain for ``window_dt`` seconds at those rates.  ``window_dt`` must be
    a static python float (0.0 disables draining — the static model).

    ``observe`` controls the completion-feedback path (see
    :func:`run_window`); default: on exactly when a trace is given.  Pass
    ``observe=False`` with a trace to keep ewma-reading policies (ECT)
    bit-identical to the no-trace path — the degenerate static scenario
    does this (the feedback would differ from the never-observing static
    model even with all-equal rates).
    """
    if observe is None:
        observe = trace is not None
    r = work.n_requests
    n_win = -(-r // window_size)
    pad = n_win * window_size - r

    def pad_to(a, fill=0):
        return jnp.concatenate([a, jnp.full((pad,), fill, a.dtype)]) if pad else a

    obj = pad_to(work.object_ids).reshape(n_win, window_size)
    lens = pad_to(work.lengths).reshape(n_win, window_size)
    val = pad_to(work.valid, False).reshape(n_win, window_size)
    keys = jax.random.split(key, n_win)

    if trace is not None:
        t_open = jnp.arange(n_win, dtype=jnp.float32) * window_dt
        win_rates = jax.vmap(lambda t: rates_at(trace, t))(t_open)
    else:  # static model: keep whatever rates the state carries
        win_rates = jnp.broadcast_to(state.rates, (n_win, state.n_servers))

    def body(st, xs):
        o, ln, v, k, rates = xs
        st = st._replace(rates=rates)
        res = run_window(st, Workload(o, ln, v), k, policy=policy,
                         log_cfg=log_cfg, group_steps=group_steps,
                         observe=observe)
        st = res.state
        if window_dt:
            st = statlog.advance_time(st, jnp.float32(window_dt))
        return st, (res.chosen, res.probe_msgs, res.redirected,
                    res.latencies, st.loads)

    state, (chosen, probes, redirected, latencies, window_loads) = \
        jax.lax.scan(body, state, (obj, lens, val, keys, win_rates))
    return ScheduleResult(
        state=state,
        chosen=chosen.reshape(-1)[:r],
        probe_msgs=jnp.sum(probes).astype(jnp.int32),
        redirected=redirected.reshape(-1)[:r],
        latencies=latencies.reshape(-1)[:r],
        window_loads=window_loads,
    )


@functools.partial(jax.jit, static_argnames=("policy", "log_cfg",
                                             "window_size", "group_steps",
                                             "window_dt", "observe"))
def run_stream_jit(state, work, key, *, policy, log_cfg, window_size,
                   group_steps=True, trace=None, window_dt=0.0,
                   observe=None):
    return run_stream(state, work, key, policy=policy, log_cfg=log_cfg,
                      window_size=window_size, group_steps=group_steps,
                      trace=trace, window_dt=window_dt, observe=observe)
