"""Scheduling policies of the log-assisted straggler-aware I/O scheduler.

Implements the paper's §3.4 algorithms plus two baselines:

* ``rr``         — round-robin (paper baseline): ``server = object_id mod M``.
* ``mlml``       — Max Length - Min Load (Alg. 1): length-sorted requests are
                   paired circularly with probability-sorted servers.
* ``trh``        — Two Random from Top Half (Alg. 2): power-of-two-choices
                   restricted to the lightest M/2 servers of the log.
* ``nltr``       — n-Level Two Random (Alg. 3): servers split into K = 2^n
                   equal sections (by middle), requests split into K sections
                   (by recursive average); two random choices inside the
                   matching section.
* ``two_choice`` — the authors' prior SC'14 probing scheduler [18]: probe the
                   default server + one random server, take the lighter.
                   Costs 2 probe messages per request (counted by the engine)
                   — the overhead this paper's log removes.
* ``ect``        — beyond-paper extension: pick argmin of expected completion
                   time ``(load_i + len) / rate_i`` using the EWMA service
                   rate observed from completions.  Sees *slow* servers, not
                   just *loaded* ones.  Documented in DESIGN.md.

Each policy exists in two forms that are cross-validated in tests:

* a pure-JAX form — ``plan_window`` (per-window sorting / sectioning) +
  ``select_target`` (per-request decision inside a ``lax.scan``), and
* ``HostScheduler`` — a numpy twin used on the real I/O request hot path.

All policies except ``rr`` are guarded by the paper's user threshold: the
redirect only happens when ``load(default) - load(target) > threshold``
(prose of §3.4.1; the printed pseudocode has the branch inverted by an OCR
artifact — we follow the prose).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policy_core, statlog
from repro.core.statlog import LogConfig, SchedState

POLICIES = ("rr", "mlml", "trh", "nltr", "two_choice", "ect")

# Baseline probe RPCs per scheduled request (paper defaults).  This is
# the quantity the paper's log design eliminates (§1, §5).  The
# authoritative per-config count is ``PolicyConfig.probes_per_request``,
# which derives from ``probe_choices`` so the engine and the host twin
# can never drift apart (cross-checked in tests/test_policies.py).
PROBES_PER_REQUEST = {
    "rr": 0,
    "mlml": 0,
    "trh": 0,
    "nltr": 0,
    "ect": 0,
    "two_choice": 2,
}

RNG_IMPLS = ("jax", "lcg")


@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    """Static configuration of a scheduling policy."""

    name: str = "trh"
    threshold: float = 0.0      # MB of load benefit required to redirect
    nltr_n: int = 2             # n of nLTR; K = 2**n sections
    # two_choice only: number of candidate servers probed (paper uses 2).
    probe_choices: int = 2
    # Randomness source for the two-random draws: "jax" (threefry keys,
    # the default and the PR-1-compatible behaviour) or "lcg" (the Pallas
    # kernel's in-VMEM LCG, `policy_core.two_random_draws`) — the engine's
    # backend="kernel" parity mode.  Deterministic policies ignore it.
    rng: str = "jax"

    def __post_init__(self):
        if self.name not in POLICIES:
            raise ValueError(f"unknown policy {self.name!r}; choose from {POLICIES}")
        if self.name == "nltr" and not (1 <= self.nltr_n <= 6):
            raise ValueError("nltr_n must be in [1, 6]")
        if self.rng not in RNG_IMPLS:
            raise ValueError(f"rng must be one of {RNG_IMPLS}")

    @property
    def k_sections(self) -> int:
        return 2 ** self.nltr_n

    @property
    def probes_per_request(self) -> int:
        """Probe RPCs per request — derived from ``probe_choices`` (one
        probe per candidate server) so engine accounting and the host
        twin's ``probe_messages`` counter agree by construction."""
        return self.probe_choices if self.name == "two_choice" else 0


class WindowPlan(NamedTuple):
    """Window-start snapshot used by the per-request selection.

    The paper sorts servers (and, for MLML/nLTR, requests) once per time
    window (Algs. 1-3 all hoist ``sort`` out of the scheduling loop); loads
    consulted *inside* the loop are live.
    """

    order: jax.Array           # (R,) request processing order (perm of arange)
    sorted_servers: jax.Array  # (M,) server ids, lightest (highest prob) first
    req_section: jax.Array     # (R,) int32 nLTR section id per request, in
    #                            processing order (zeros for other policies)
    sec_size: int              # static servers-per-section (M // K)


def validate_policy(cfg: PolicyConfig, n_servers: int) -> None:
    """Cross-field validation a :class:`PolicyConfig` alone cannot do.

    nLTR splits the server list into ``K = 2**nltr_n`` sections of
    ``max(M // K, 1)`` servers; with ``K > M`` the integer division
    collapses every section onto the same server range and the policy
    silently degenerates to (clamped) single-server picks.  Raise at the
    dispatch boundary instead (engine / simulator / host scheduler), with
    both offending values named.  ``K == M`` (one server per section) is
    the legal edge.
    """
    if cfg.name == "nltr" and cfg.k_sections > n_servers:
        raise ValueError(
            f"nltr needs 2**nltr_n <= n_servers: nltr_n={cfg.nltr_n} gives "
            f"K={cfg.k_sections} sections for n_servers={n_servers} "
            "(sections would collapse onto the same server range)")


def _recursive_average_boundaries(sorted_len: jax.Array, valid: jax.Array,
                                  n_levels: int) -> jax.Array:
    """Split a desc-sorted length list into 2^n sections by recursive average.

    Returns (K-1,) boundary *indices* into the sorted list: section ``s`` of
    request position ``k`` is ``sum(boundaries <= k)`` (order-free, so the
    tree-order output of the shared core needs no sort).  The paper
    (§3.4.3) uses the *average* element to divide requests ("to better
    utilize the size factor") versus the *middle* element for servers.
    Delegates to `policy_core.recursive_average_bounds` — the single
    implementation the oracle and the Pallas kernel tile form also run,
    lane_sum-associated so all three layers compute identical bounds.
    """
    nvalid = jnp.sum(valid).astype(jnp.int32).reshape(1)
    return policy_core.recursive_average_bounds(sorted_len, nvalid, n_levels)


def plan_window(cfg: PolicyConfig, state: SchedState, object_ids: jax.Array,
                lengths: jax.Array, valid: jax.Array) -> WindowPlan:
    """Build the window-start plan (sorts + sections) for a policy.

    The engine keeps XLA's stable ``argsort`` + take (fast on the scan
    hot path); the Pallas kernel runs `policy_core.rank_desc` +
    `permute_to_sorted` in-VMEM — the §13 fast path: one all-pairs
    comparison and masked-sum permutation applies, no sort network.
    Both order by (key desc, index asc) — a STRICT TOTAL order, so the
    permutation is unique and the two agree bit-for-bit by construction
    (property-pinned in tests/test_policies.py; DESIGN.md §10/§13).
    """
    r = object_ids.shape[0]
    m = state.n_servers
    # Servers sorted by probability desc == lightest first (paper Fig. 9/10).
    # contract-ok: CC-SORT engine keeps backend argsort; kernel twin is rank_desc (§10)
    sorted_servers = jnp.argsort(-state.probs).astype(jnp.int32)

    if cfg.name in ("mlml", "nltr"):
        # Requests processed in length-desc order; invalid (padding) rows sink
        # to the end via -inf keys.
        key_len = jnp.where(valid, lengths, -jnp.inf)
        # contract-ok: CC-SORT engine keeps backend argsort; kernel twin is rank_desc (§13)
        order = jnp.argsort(-key_len).astype(jnp.int32)
    else:
        order = jnp.arange(r, dtype=jnp.int32)

    if cfg.name == "nltr":
        k = cfg.k_sections
        sorted_len = lengths[order]
        sorted_valid = valid[order]
        bounds = _recursive_average_boundaries(sorted_len, sorted_valid, cfg.nltr_n)
        pos = jnp.arange(r, dtype=jnp.int32)
        req_section = jnp.sum(pos[:, None] >= bounds[None, :], axis=1).astype(jnp.int32)
        req_section = jnp.clip(req_section, 0, k - 1)
        sec_size = max(m // k, 1)
    else:
        req_section = jnp.zeros((r,), jnp.int32)
        sec_size = m

    return WindowPlan(order=order, sorted_servers=sorted_servers,
                      req_section=req_section, sec_size=sec_size)


def _two_random_min_load(state: SchedState, sorted_servers: jax.Array,
                         lo: jax.Array, size, key: jax.Array) -> jax.Array:
    """Pick 2 uniform positions in [lo, lo+size) of the sorted list, return
    the id with the smaller *live* load (Algs. 2-3 inner step)."""
    k1, k2 = jax.random.split(key)
    i1 = jax.random.randint(k1, (), 0, size) + lo
    i2 = jax.random.randint(k2, (), 0, size) + lo
    s1 = sorted_servers[i1]
    s2 = sorted_servers[i2]
    return jnp.where(state.loads[s1] <= state.loads[s2], s1, s2).astype(jnp.int32)


def select_target(cfg: PolicyConfig, plan: WindowPlan, state: SchedState,
                  pos: jax.Array, object_id: jax.Array, length: jax.Array,
                  key: jax.Array) -> jax.Array:
    """Per-request target server (before the threshold guard).

    ``pos`` is the request's position in the window processing order (used
    by MLML's circular pairing).  Live ``state.loads`` break two-random ties.
    """
    m = state.n_servers
    default = (object_id % m).astype(jnp.int32)

    if cfg.name == "rr":
        return default
    if cfg.name == "mlml":
        # k-th longest request -> k-th lightest server, circularly (Alg. 1).
        return plan.sorted_servers[pos % m]
    if cfg.name == "trh":
        half = max(m // 2, 1)
        return _two_random_min_load(state, plan.sorted_servers,
                                    jnp.asarray(0, jnp.int32), half, key)
    if cfg.name == "nltr":
        sec = plan.req_section[pos]
        lo = sec * plan.sec_size
        return _two_random_min_load(state, plan.sorted_servers, lo,
                                    plan.sec_size, key)
    if cfg.name == "two_choice":
        # SC'14 baseline: probe default + (probe_choices-1) random others,
        # take the lightest by live load.  Probes counted by the engine.
        keys = jax.random.split(key, cfg.probe_choices - 1)
        cand = [default]
        for i in range(cfg.probe_choices - 1):
            cand.append(jax.random.randint(keys[i], (), 0, m).astype(jnp.int32))
        cand = jnp.stack(cand)
        return cand[jnp.argmin(state.loads[cand])].astype(jnp.int32)
    if cfg.name == "ect":
        # Scored on the client-ESTIMATED rates row (observations only),
        # never the true trace rates — the stale-view contract.
        ect = policy_core.ect_scores(state.loads, state.est_rates, length)
        return jnp.argmin(ect).astype(jnp.int32)
    raise AssertionError(cfg.name)


def select_target_rng(cfg: PolicyConfig, plan: WindowPlan, state: SchedState,
                      pos: jax.Array, object_id: jax.Array, length: jax.Array,
                      key: jax.Array, rng: jax.Array
                      ) -> tuple[jax.Array, jax.Array]:
    """Like :func:`select_target`, but threading the kernel-compatible
    uint32 LCG state.  With ``cfg.rng == "lcg"`` the two-random policies
    consume `policy_core.two_random_draws` exactly as the Pallas kernel
    does (the draws advance on EVERY request, valid or padding, matching
    the kernel's unconditional stream); otherwise the jax key is used and
    ``rng`` passes through untouched."""
    if cfg.rng == "lcg" and cfg.name in ("trh", "nltr", "two_choice"):
        m = state.n_servers
        if cfg.name == "trh":
            half = max(m // 2, 1)
            i1, i2, rng = policy_core.two_random_draws(rng, half)
            s1 = plan.sorted_servers[i1]
            s2 = plan.sorted_servers[i2]
            target = jnp.where(state.loads[s1] <= state.loads[s2], s1,
                               s2).astype(jnp.int32)
            return target, rng
        if cfg.name == "nltr":
            sec = plan.req_section[pos]
            lo = sec * plan.sec_size
            i1, i2, rng = policy_core.two_random_draws(rng, plan.sec_size)
            s1 = plan.sorted_servers[lo + i1]
            s2 = plan.sorted_servers[lo + i2]
            target = jnp.where(state.loads[s1] <= state.loads[s2], s1,
                               s2).astype(jnp.int32)
            return target, rng
        # two_choice: default + (probe_choices-1) LCG-random candidates
        default = (object_id % m).astype(jnp.int32)
        cand = [default]
        for _ in range(cfg.probe_choices - 1):
            rng = policy_core.lcg_step(rng)
            cand.append(policy_core.lcg_mod(rng, m))
        cand = jnp.stack(cand)
        return cand[jnp.argmin(state.loads[cand])].astype(jnp.int32), rng
    return select_target(cfg, plan, state, pos, object_id, length, key), rng


def apply_threshold(cfg: PolicyConfig, state: SchedState, default: jax.Array,
                    target: jax.Array, length: jax.Array) -> jax.Array:
    """Paper's redirect guard: only redirect when the benefit exceeds the
    user threshold (§3.4.1 prose).  For the rate-aware ECT extension the
    benefit is in expected seconds (on the ESTIMATED rates), not bytes."""
    if cfg.name == "rr":
        return default
    benefit = policy_core.redirect_benefit(cfg.name, state.loads,
                                           state.est_rates, default, target,
                                           length)
    return jnp.where(benefit > cfg.threshold, target, default).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Host-side (numpy) twin — the real I/O client hot path (repro.io.client).
# ---------------------------------------------------------------------------


class HostScheduler:
    """Numpy mirror of (plan_window, select_target, apply_threshold).

    Operates on a :class:`~repro.core.statlog.HostStatLog`.  A *window* is
    opened explicitly (:meth:`begin_window`) which snapshots the sorts, then
    :meth:`schedule` is called per request.  Cross-validated against the JAX
    engine in ``tests/test_policies.py``.
    """

    def __init__(self, cfg: PolicyConfig, log: statlog.HostStatLog,
                 seed: int = 0):
        validate_policy(cfg, log.n_servers)
        self.cfg = cfg
        self.log = log
        self.rng = np.random.default_rng(seed)
        self.probe_messages = 0
        self._sorted_servers: Optional[np.ndarray] = None
        self._masked: set[int] = set()

    # -- failure handling (used by repro.checkpoint retry logic) -----------
    def mask_server(self, server: int) -> None:
        """Exclude a failed server from future targets (until unmasked)."""
        self._masked.add(int(server))

    def unmask_server(self, server: int) -> None:
        self._masked.discard(int(server))

    @property
    def masked_servers(self) -> frozenset:
        return frozenset(self._masked)

    # -- window machinery ---------------------------------------------------
    def begin_window(self, lengths: Optional[Sequence[float]] = None) -> None:
        """Snapshot the window-start sorts.  Stable np.argsort == the
        kernel's §13 all-pairs rank (strict total order; DESIGN.md §10).
        ``lengths`` (all requests queued in this window) is needed by
        nLTR's request sectioning."""
        order = np.argsort(-self.log.probs, kind="stable")
        self._sorted_servers = order.astype(np.int64)
        self._pos = 0
        if self.cfg.name == "nltr" and lengths is not None and len(lengths):
            self._req_bounds = self._recursive_average_bounds(
                np.sort(np.asarray(lengths, np.float64))[::-1], self.cfg.nltr_n)
        else:
            self._req_bounds = None

    @staticmethod
    def _recursive_average_bounds(sorted_len: np.ndarray, n: int) -> np.ndarray:
        """Numpy twin of the engine's sectioning — the SAME shared core
        (`policy_core.recursive_average_bounds`, xp=np), all rows valid
        (the host scheduler sections the literal queued lengths)."""
        nvalid = np.asarray([len(sorted_len)], np.int32)
        return policy_core.recursive_average_bounds(
            np.ascontiguousarray(sorted_len), nvalid, n, xp=np)

    def _live_load(self, server: int) -> float:
        return self.log.loads[server]

    def _ect_rates(self) -> np.ndarray:
        """Client-estimated service rates: the packed table's est row,
        maintained by ``HostStatLog.observe_completion`` via the shared
        ``policy_core.observe_update`` (observations only — stale view)."""
        return self.log.est_rates

    def _two_random(self, lo: int, size: int) -> int:
        size = max(size, 1)
        ss = self._sorted_servers
        m = len(ss)
        cands = []
        for _ in range(8):  # rejection-sample around masked servers
            i1 = lo + int(self.rng.integers(0, size))
            i2 = lo + int(self.rng.integers(0, size))
            c1, c2 = int(ss[i1 % m]), int(ss[i2 % m])
            cands = [c for c in (c1, c2) if c not in self._masked]
            if cands:
                break
        if not cands:  # whole section masked: fall back to global lightest
            alive = [s for s in range(m) if s not in self._masked]
            return min(alive, key=self._live_load)
        return min(cands, key=self._live_load)

    def schedule(self, object_id: int, length_mb: float,
                 offset: int = 0) -> int:
        """Schedule one request; returns the chosen server and updates the
        log per Eqs. (1)-(3)."""
        if self._sorted_servers is None:
            self.begin_window()
        cfg, log = self.cfg, self.log
        m = log.n_servers
        default = int(object_id) % m
        pos = self._pos
        self._pos += 1
        log.record_request(object_id, offset, length_mb)

        if cfg.name == "rr":
            target = default
        elif cfg.name == "mlml":
            target = int(self._sorted_servers[pos % m])
        elif cfg.name == "trh":
            target = self._two_random(0, max(m // 2, 1))
        elif cfg.name == "nltr":
            k = cfg.k_sections
            if self._req_bounds is None:
                sec = 0
            else:
                sec = int((self._req_bounds <= pos).sum())
            sec = min(sec, k - 1)
            sec_size = max(m // k, 1)
            target = self._two_random(sec * sec_size, sec_size)
        elif cfg.name == "two_choice":
            cand = [default] + [int(self.rng.integers(0, m))
                                for _ in range(cfg.probe_choices - 1)]
            self.probe_messages += cfg.probe_choices
            cand = [c for c in cand if c not in self._masked] or cand
            target = min(cand, key=self._live_load)
        elif cfg.name == "ect":
            ect = policy_core.ect_scores(log.loads, self._ect_rates(),
                                         length_mb, xp=np)
            if self._masked:
                ect = ect.copy()
                ect[list(self._masked)] = np.inf
            target = int(np.argmin(ect))
        else:  # pragma: no cover
            raise AssertionError(cfg.name)

        if target in self._masked:
            alive = [s for s in range(m) if s not in self._masked]
            target = min(alive, key=self._live_load)
        if cfg.name != "rr" and default not in self._masked:
            benefit = policy_core.redirect_benefit(
                cfg.name, log.loads, self._ect_rates(), default, target,
                length_mb, xp=np)
            chosen = target if benefit > cfg.threshold else default
        else:
            chosen = target
        log.apply_assignment(chosen, length_mb)
        return chosen
