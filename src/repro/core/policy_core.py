"""Single source of truth for the scheduler's decision math.

Every layer of the stack — the jitted JAX engine (`core/engine.py` via
`core/statlog.py` / `core/policies.py`), the numpy host twin on the real
I/O request path (`HostStatLog` / `HostScheduler`, used by `io/client`),
and the Pallas kernel (`kernels/sched_select`) — schedules against the
same packed **log tensor**:

    row 0  ``loads``      expected outstanding MB per server (Eq. 1)
    row 1  ``probs``      selection probability, sums to 1 (Eqs. 2-3)
    row 2  ``ewma_lat``   EWMA of *observed* service rate, MB/s (0 = unseen)
    row 3  ``est_rates``  client-estimated service rate — derived ONLY from
                          completion observations (``ect_rates`` of row 2),
                          never from the cluster's true rates.  Stale by
                          construction: when a server's true rate changes,
                          this row lags until completions reveal it.

one ``(4, M)`` float table (`N_ROWS` x servers).  ``SchedState.log``
stores it as a jnp array, ``HostStatLog.table`` as a numpy array whose
rows are views, and the kernel pins it in a ``(4, M_pad)`` VMEM scratch
for an entire request stream.

The functions here are the *decision core*: target selection scores, the
paper's redirect-threshold guard, the Eq. (1)-(3) log updates, completion
observation, per-window renormalization and queue drain.  They are
parameterized over the array namespace (``xp = jnp`` or ``numpy``) so the
JAX engine and the host twin execute literally the same code; the kernel
mirrors the same formulas with one-hot lane writes (no scatter) and is
held bit-exact by the parity tests in ``tests/test_kernels.py``.

True rates (`SchedState.rates` / `HostStatLog.rates`) are deliberately
NOT part of the table: they belong to the cluster, not the client's log.
Only :func:`drain_loads` (queue drain between windows — the simulator's
ground-truth step) and latency *reporting* consume them.  Scheduling
decisions (ECT scores, threshold guards) read ``est_rates``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# Packed log-tensor rows (DESIGN.md §8).
ROW_LOADS, ROW_PROBS, ROW_EWMA, ROW_EST = 0, 1, 2, 3
N_ROWS = 4
ROW_NAMES = ("loads", "probs", "ewma_lat", "est_rates")

# The in-kernel LCG (numerical recipes constants) — also used by the JAX
# engine when ``PolicyConfig.rng == "lcg"`` so kernel and engine consume
# an identical randomness stream (the bit-exactness contract).
LCG_A = 1664525
LCG_C = 1013904223
_MASK32 = 0xFFFFFFFF


def pack(loads, probs, ewma_lat, est_rates, xp=jnp):
    """Stack the four rows into one (4, M) table."""
    return xp.stack([loads, probs, ewma_lat, est_rates])


def init_table(m: int, xp=jnp, dtype=None):
    """Fresh log: zero loads, round-robin prior p_i = 1/M (paper §3.3.2),
    no observations, optimistic unit estimated rates (= ect_rates(0))."""
    dtype = dtype or (jnp.float32 if xp is jnp else np.float64)
    t = xp.zeros((N_ROWS, m), dtype)
    if xp is np:
        t[ROW_PROBS] = 1.0 / m
        t[ROW_EST] = 1.0
        return t
    return t.at[ROW_PROBS].set(1.0 / m).at[ROW_EST].set(1.0)


# ---------------------------------------------------------------------------
# Shared LCG (kernel randomness, mirrored by the engine's rng="lcg" mode)
# ---------------------------------------------------------------------------


def lcg_step(rng, xp=jnp):
    """One LCG step on a uint32 state."""
    if xp is np:
        return (int(rng) * LCG_A + LCG_C) & _MASK32
    return rng * jnp.uint32(LCG_A) + jnp.uint32(LCG_C)


def lcg_mod(rng, n: int, xp=jnp):
    """Map an LCG state to [0, n): drop the low byte (weak low bits),
    mask to non-negative int32, take the remainder."""
    if xp is np:
        return ((int(rng) >> 8) & 0x7FFFFFFF) % n
    return jax.lax.rem((rng >> jnp.uint32(8)).astype(jnp.int32)
                       & jnp.int32(0x7FFFFFFF), n)


def two_random_draws(rng, n: int, xp=jnp):
    """Two consecutive LCG draws in [0, n); returns (d1, d2, new_rng).

    This is the exact draw sequence of the kernel's ``two_random`` and
    ``trh`` policies — the engine's rng="lcg" mode replays it bit-for-bit.
    """
    r1 = lcg_step(rng, xp)
    r2 = lcg_step(r1, xp)
    return lcg_mod(r1, n, xp), lcg_mod(r2, n, xp), r2


# ---------------------------------------------------------------------------
# Decision core: scores, target selection, threshold guard
# ---------------------------------------------------------------------------


def ect_rates(ewma_lat, xp=jnp):
    """Client-estimated service rates (the ``est_rates`` row) from the
    observation EWMA alone.  Unobserved servers get the best seen rate
    (optimistic initialization -> exploration); an empty log estimates
    1 MB/s everywhere (the static model where MB and seconds coincide).

    By construction this never reads the true ``rates`` — the stale-view
    contract (DESIGN.md §8), property-tested in tests/test_statlog.py.
    """
    default = xp.maximum(xp.max(ewma_lat), 1.0)
    return xp.where(ewma_lat > 0, ewma_lat, default)


def ect_scores(loads, est_rates, length, xp=jnp):
    """Expected completion time per server: (load_i + len) / est_rate_i.
    Scored on the client's ESTIMATED rates, never the true ones."""
    return (loads + length) / est_rates


def redirect_benefit(policy_name: str, loads, est_rates, default, target,
                     length, xp=jnp):
    """Paper's §3.4.1 redirect guard benefit: MB of load for the load-based
    policies, expected seconds for the rate-aware ECT extension."""
    if policy_name == "ect":
        return ((loads[default] + length) / est_rates[default]
                - (loads[target] + length) / est_rates[target])
    return loads[default] - loads[target]


def prob_ranks(probs, xp=jnp):
    """Stable descending rank of each server by selection probability:
    ``rank_i = |{j : p_j > p_i}| + |{j < i : p_j == p_i}|``.

    Matches ``argsort(-probs)`` with stable ties exactly: the server at
    sorted position k is the one with rank k.  This form needs no sort /
    gather, so the kernel can evaluate it on VMEM lanes; the engine uses
    argsort and the equivalence is asserted in tests.
    """
    m = probs.shape[-1]
    gt = probs[None, :] > probs[:, None]          # [i, j] = p_j > p_i
    if xp is np:
        eq = probs[None, :] == probs[:, None]
        before = np.arange(m)[None, :] < np.arange(m)[:, None]
        return (gt.sum(-1) + (eq & before).sum(-1)).astype(np.int64)
    eq = probs[None, :] == probs[:, None]
    before = jnp.arange(m)[None, :] < jnp.arange(m)[:, None]
    return (jnp.sum(gt, -1) + jnp.sum(eq & before, -1)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Eq. (1)-(3) updates, observation, window maintenance
# ---------------------------------------------------------------------------


def assignment_update(loads, probs, server, length, lam: float, m: int,
                      xp=jnp):
    """Eq. (1)-(3): book ``length`` MB on ``server``; decay its selection
    probability and spread the lost mass over the other M-1 servers.

    The jnp form uses one-hot vector writes (`where`) instead of scatter
    — the exact formulation the Pallas kernel executes on VMEM lanes, so
    XLA lowers both layers through the same elementwise ops and the
    engine<->kernel trace stays bit-identical (scatter + scalar-exp
    lowering was observed to differ by 1 ulp inside fused loop bodies).
    """
    if xp is np:
        loads = loads.copy()
        probs = probs.copy()
        loads[server] += length                              # Eq. (1)
        p_i = probs[server]
        decayed = p_i * np.exp(-loads[server] / lam)         # Eq. (2)
        delta = (p_i - decayed) / (m - 1)                    # Eq. (3)
        probs += delta
        probs[server] = decayed
        return loads, probs
    onehot = jnp.arange(loads.shape[-1]) == server
    loads = jnp.where(onehot, loads + length, loads)         # Eq. (1)
    l_i = loads[server]
    p_i = probs[server]
    decayed = p_i * jnp.exp(-l_i / lam)                      # Eq. (2)
    delta = (p_i - decayed) / (m - 1)                        # Eq. (3)
    probs = jnp.where(onehot, decayed, probs + delta)
    return loads, probs


def observe_update(ewma_lat, server, mb_per_s, alpha: float, xp=jnp):
    """Fold one observed service rate into the EWMA row and re-derive the
    estimated-rate row.  Returns (ewma_lat, est_rates).  The est row is a
    pure function of observations — the only way the client ever learns
    about a server's speed (stale-view contract)."""
    if xp is np:
        ewma_lat = ewma_lat.copy()
        old = ewma_lat[server]
        ewma_lat[server] = (mb_per_s if old == 0.0
                            else (1 - alpha) * old + alpha * mb_per_s)
    else:
        old = ewma_lat[server]
        new = jnp.where(old == 0.0, mb_per_s,
                        (1 - alpha) * old + alpha * mb_per_s)
        ewma_lat = ewma_lat.at[server].set(new)
    return ewma_lat, ect_rates(ewma_lat, xp)


def renormalize_probs(probs, xp=jnp):
    """Re-project the probability row onto the simplex (float-drift guard;
    run once per window by every layer that renormalizes).

    The jnp form pads the reduction to the kernel's 128-lane width before
    summing: appended exact zeros never change the sum's value, but they
    make XLA pick the same reduction tree as the Pallas kernel's padded
    VMEM row — the last bit of the engine<->kernel parity contract."""
    if xp is np:
        p = np.clip(probs, 0.0, None)
        return p / p.sum()
    p = jnp.clip(probs, 0.0)
    m = p.shape[-1]
    m_pad = max(-(-m // 128) * 128, 128)
    total = jnp.sum(jnp.pad(p, (0, m_pad - m))) if m_pad != m else jnp.sum(p)
    return p / total


def drain_loads(loads, rates, dt, xp=jnp):
    """Temporal model: drain each server's outstanding queue at its TRUE
    service rate for ``dt`` virtual seconds, clipped at empty.  The one
    place the simulator's ground-truth rates touch the log (queue physics,
    not a scheduling decision)."""
    rates = xp.maximum(rates, 1e-6)
    return xp.maximum(loads - rates * dt, 0.0)


def estimated_latency(loads, rates, server, xp=jnp):
    """Seconds until a request just queued on ``server`` completes, at the
    given (true) service rates — the simulator's latency report."""
    return loads[server] / xp.maximum(rates[server], 1e-6)
