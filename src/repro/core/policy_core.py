"""Single source of truth for the scheduler's decision math.

Every layer of the stack — the jitted JAX engine (`core/engine.py` via
`core/statlog.py` / `core/policies.py`), the numpy host twin on the real
I/O request path (`HostStatLog` / `HostScheduler`, used by `io/client`),
and the Pallas kernel (`kernels/sched_select`) — schedules against the
same packed **log tensor**:

    row 0  ``loads``      expected outstanding MB per server (Eq. 1)
    row 1  ``probs``      selection probability, sums to 1 (Eqs. 2-3)
    row 2  ``ewma_lat``   EWMA of *observed* service rate, MB/s (0 = unseen)
    row 3  ``est_rates``  client-estimated service rate — derived ONLY from
                          completion observations (``ect_rates`` of row 2),
                          never from the cluster's true rates.  Stale by
                          construction: when a server's true rate changes,
                          this row lags until completions reveal it.

one ``(4, M)`` float table (`N_ROWS` x servers).  ``SchedState.log``
stores it as a jnp array, ``HostStatLog.table`` as a numpy array whose
rows are views, and the kernel pins it in a ``(4, M_pad)`` VMEM scratch
for an entire request stream.

The functions here are the *decision core*: target selection scores, the
paper's redirect-threshold guard, the Eq. (1)-(3) log updates, completion
observation, per-window renormalization and queue drain.  They are
parameterized over the array namespace (``xp = jnp`` or ``numpy``) so the
JAX engine and the host twin execute literally the same code; the kernel
mirrors the same formulas with one-hot lane writes (no scatter) and is
held bit-exact by the parity tests in ``tests/test_kernels.py``.

True rates (`SchedState.rates` / `HostStatLog.rates`) are deliberately
NOT part of the table: they belong to the cluster, not the client's log.
Only :func:`drain_loads` (queue drain between windows — the simulator's
ground-truth step) and latency *reporting* consume them.  Scheduling
decisions (ECT scores, threshold guards) read ``est_rates``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# Packed log-tensor rows (DESIGN.md §8).
ROW_LOADS, ROW_PROBS, ROW_EWMA, ROW_EST = 0, 1, 2, 3
N_ROWS = 4
ROW_NAMES = ("loads", "probs", "ewma_lat", "est_rates")

# Fused per-trial stream metrics (DESIGN.md §9): reduced in-VMEM by the
# trial-grid kernel while the latency block is still resident, so the
# headline sweep metrics never round-trip through HBM.  Lane layout of
# the kernel's (T, MET_PAD) metrics output; `stream_metrics` below is
# the bit-exact host/engine twin.
MET_MAKESPAN, MET_P99, MET_LAT_SUM, MET_LAT_MAX, MET_N_VALID = 0, 1, 2, 3, 4
N_METRICS = 5
MET_NAMES = ("makespan", "p99_lat", "lat_sum", "lat_max", "n_valid")
MET_PAD = 128          # kernel metrics row padded to one f32 lane tile

# Cross-client merged metrics (DESIGN.md §11/§14): the 2-D (trials ×
# clients) grid kernel reduces its clients' per-stream metric rows into
# one per-TRIAL row before the block retires — lanes [0, N_METRICS) keep
# the MET_* meaning merged over REAL clients (makespan/lat_max by max,
# lat_sum/n_valid through `masked_client_sum`; the p99 lane is the
# nearest-rank p99 of the MERGED latency block the kernel accumulates in
# VMEM across its client grid steps — `nearest_rank_p99` is layout- and
# order-insensitive, so merging needs no association contract), plus the
# real-client count.  `client_stream_metrics` below is the bit-exact
# host/engine twin.
MET_N_CLIENTS = 5
N_CMETRICS = 6
CMET_NAMES = MET_NAMES + ("n_clients",)

# Clients per program-instance block in the 2-D grid (DESIGN.md §11).
# Like the trial tile it keeps stream-sublane counts at multiples of the
# native f32 sublane count; it is ALSO an association parameter — the
# cross-client float merges sum client blocks of this width (see
# `masked_client_sum`) — so the jax path resolves it through
# `resolve_client_tile` too, even when no kernel runs.  32 (up from 8,
# DESIGN.md §14): per_client blocks stay small because the per-client
# slice shrinks as the client count grows (tt·ct·per ≈ tt·R floats once
# C ≥ ct), and a deeper tile quarters the grid's program count — at the
# 64-client short-stream instance that measured 1.4× end-to-end under
# interpret, where per-program dispatch dominates.
DEFAULT_CLIENT_TILE = 32


def resolve_client_tile(n_clients: int, client_tile=None) -> int:
    """Effective clients-per-block of the 2-D grid AND of the cross-client
    merge association (both layers must resolve it identically)."""
    ct = DEFAULT_CLIENT_TILE if client_tile is None else client_tile
    return max(min(ct, n_clients), 1)


# trials per program instance in the trial-grid form: the sublane count
# of the native f32 (8, 128) TPU tile, so each vectorized table op fills
# whole tiles instead of one sublane in eight.
DEFAULT_TRIAL_TILE = 8


def resolve_trial_tile(n_trials: int, trial_tile=None) -> int:
    """Effective trials-per-block of the trial grid.  The tile is a
    lowering parameter (XLA specializes the block shape to it), so the
    kernel dispatch, the sharded sweep and the engine must all resolve
    it through here — resolving it anywhere else risks two layers
    disagreeing on the association (DESIGN.md §12)."""
    tt = DEFAULT_TRIAL_TILE if trial_tile is None else trial_tile
    return max(min(tt, n_trials), 1)


# Sublane budget of the FUSED multi-trial client block (DESIGN.md §16):
# when the client tile resolves small (a 4-client stream fills 4 of the
# 32 sublanes DEFAULT_CLIENT_TILE aims at), `resolve_grid_tiles` deepens
# the TRIAL tile until the block's stream-sublane count tt*ct reaches
# this budget — packing multiple trials into one sublane tile instead of
# wasting the lanes, and cutting the grid's program count (the dominant
# cost under interpret mode, where dispatch overhead is per program).
FUSED_SUBLANE_BUDGET = 64


def resolve_grid_tiles(n_trials: int, n_clients: int, trial_tile=None,
                       client_tile=None) -> tuple:
    """Joint (trial_tile, client_tile) of the fused multi-trial client
    block.  The client tile resolves exactly as `resolve_client_tile`;
    an unset trial tile then deepens to fill `FUSED_SUBLANE_BUDGET`
    stream sublanes (never below the default).  Both values remain
    ASSOCIATION parameters: every layer (kernel grid, engine twin, jax
    cross-client fold, sharded sweep) must consume the pair this
    function returns — resolving either half anywhere else risks two
    layers disagreeing on the merge association (DESIGN.md §12/§16)."""
    ct = resolve_client_tile(n_clients, client_tile)
    if trial_tile is None:
        trial_tile = max(FUSED_SUBLANE_BUDGET // ct, DEFAULT_TRIAL_TILE)
    return resolve_trial_tile(n_trials, trial_tile), ct

# The in-kernel LCG (numerical recipes constants) — also used by the JAX
# engine when ``PolicyConfig.rng == "lcg"`` so kernel and engine consume
# an identical randomness stream (the bit-exactness contract).
LCG_A = 1664525
LCG_C = 1013904223
_MASK32 = 0xFFFFFFFF


def pack(loads, probs, ewma_lat, est_rates, xp=jnp):
    """Stack the four rows into one (4, M) table."""
    return xp.stack([loads, probs, ewma_lat, est_rates])


def init_table(m: int, xp=jnp, dtype=None, batch=None):
    """Fresh log: zero loads, round-robin prior p_i = 1/M (paper §3.3.2),
    no observations, optimistic unit estimated rates (= ect_rates(0)).

    ``batch`` adds a leading trial axis — a ``(batch, 4, M)`` stack of
    independent fresh logs, the layout the trial-grid kernel slices per
    program instance (also used to pad a trial batch up to the grid
    tile with inert-but-finite tables)."""
    shape = (N_ROWS, m) if batch is None else (batch, N_ROWS, m)
    dtype = dtype or (jnp.float32 if xp is jnp else np.float64)
    t = xp.zeros(shape, dtype)
    if xp is np:
        t[..., ROW_PROBS, :] = 1.0 / m
        t[..., ROW_EST, :] = 1.0
        return t
    return (t.at[..., ROW_PROBS, :].set(1.0 / m)
            .at[..., ROW_EST, :].set(1.0))


# ---------------------------------------------------------------------------
# Shared LCG (kernel randomness, mirrored by the engine's rng="lcg" mode)
# ---------------------------------------------------------------------------


def lcg_step(rng, xp=jnp):
    """One LCG step on a uint32 state."""
    if xp is np:
        return (int(rng) * LCG_A + LCG_C) & _MASK32
    return rng * jnp.uint32(LCG_A) + jnp.uint32(LCG_C)


def lcg_mod(rng, n: int, xp=jnp):
    """Map an LCG state to [0, n): drop the low byte (weak low bits),
    mask to non-negative int32, take the remainder."""
    if xp is np:
        return ((int(rng) >> 8) & 0x7FFFFFFF) % n
    return jax.lax.rem((rng >> jnp.uint32(8)).astype(jnp.int32)
                       & jnp.int32(0x7FFFFFFF), n)


def two_random_draws(rng, n: int, xp=jnp):
    """Two consecutive LCG draws in [0, n); returns (d1, d2, new_rng).

    This is the exact draw sequence of the kernel's ``two_random`` and
    ``trh`` policies — the engine's rng="lcg" mode replays it bit-for-bit.
    """
    r1 = lcg_step(rng, xp)
    r2 = lcg_step(r1, xp)
    return lcg_mod(r1, n, xp), lcg_mod(r2, n, xp), r2


# ---------------------------------------------------------------------------
# Decision core: scores, target selection, threshold guard
# ---------------------------------------------------------------------------


def ect_rates(ewma_lat, xp=jnp):
    """Client-estimated service rates (the ``est_rates`` row) from the
    observation EWMA alone.  Unobserved servers get the best seen rate
    (optimistic initialization -> exploration); an empty log estimates
    1 MB/s everywhere (the static model where MB and seconds coincide).

    By construction this never reads the true ``rates`` — the stale-view
    contract (DESIGN.md §8), property-tested in tests/test_statlog.py.
    """
    default = xp.maximum(xp.max(ewma_lat), 1.0)
    return xp.where(ewma_lat > 0, ewma_lat, default)


def ect_scores(loads, est_rates, length, xp=jnp):
    """Expected completion time per server: (load_i + len) / est_rate_i.
    Scored on the client's ESTIMATED rates, never the true ones."""
    return (loads + length) / est_rates


def redirect_benefit(policy_name: str, loads, est_rates, default, target,
                     length, xp=jnp):
    """Paper's §3.4.1 redirect guard benefit: MB of load for the load-based
    policies, expected seconds for the rate-aware ECT extension."""
    if policy_name == "ect":
        return ((loads[default] + length) / est_rates[default]
                - (loads[target] + length) / est_rates[target])
    return loads[default] - loads[target]


def _next_pow2(n: int) -> int:
    size = 1
    while size < n:
        size *= 2
    return size


def _bitonic_network(keys, idx, payloads, xp, descending: bool):
    """Run the textbook bitonic schedule on pre-padded power-of-two lanes.

    ``keys``/``idx`` order the elements by ``(key desc|asc, index asc)``
    — a strict total order either way; every compare-exchange also moves
    the ``payloads`` lanes with the SAME swap mask, so payload values are
    only ever relocated by selects (never combined arithmetically) and
    land bit-identical to a take along the resulting permutation
    (DESIGN.md §13).  Each stage is two circular rolls plus selects —
    fixed elementwise HLO, no gather, legal inside a fused Pallas body.
    """
    pos = idx
    rp = keys.shape[-1]
    payloads = list(payloads)
    k = 2
    while k <= rp:
        asc = (pos & k) == 0          # comparator-ascending region
        j = k // 2
        while j >= 1:
            is_lo = (pos & j) == 0    # lower element of each (i, i^j) pair
            # partner values: i^j == i+j (lo) / i-j (hi) — two rolls; the
            # wrapped lanes are never selected by the is_lo mask.
            pk = xp.where(is_lo, xp.roll(keys, -j, axis=-1),
                          xp.roll(keys, j, axis=-1))
            pi = xp.where(is_lo, xp.roll(idx, -j, axis=-1),
                          xp.roll(idx, j, axis=-1))
            # partner ranks before self in (key desc|asc, index asc) order
            if descending:
                p_first = (pk > keys) | ((pk == keys) & (pi < idx))
            else:
                p_first = (pk < keys) | ((pk == keys) & (pi < idx))
            swap = xp.where(asc == is_lo, p_first, ~p_first)
            keys = xp.where(swap, pk, keys)
            idx = xp.where(swap, pi, idx)
            for n, p in enumerate(payloads):
                pp = xp.where(is_lo, xp.roll(p, -j, axis=-1),
                              xp.roll(p, j, axis=-1))
                payloads[n] = xp.where(swap, pp, p)
            j //= 2
        k *= 2
    return keys, idx, tuple(payloads)


def _sort_iota(shape, xp):
    if xp is np:
        return np.broadcast_to(np.arange(shape[-1], dtype=np.int32), shape)
    # broadcasted_iota, not arange: 1-D iota does not lower inside
    # TPU Pallas bodies (this runs in the kernel too)
    return jax.lax.broadcasted_iota(jnp.int32, shape, len(shape) - 1)


def bitonic_sort_with_payload(keys, payloads=(), valid=None, xp=jnp):
    """Stable descending sort as an EXPLICIT bitonic compare-exchange
    network, carrying ``payloads`` through every compare-exchange — the
    in-VMEM sort of DESIGN.md §10 extended with the permutation-apply
    fast path of §13.

    ``keys``: (..., R) sort keys; ``valid`` (same shape, optional) masks
    rows to ``-inf`` keys so they sink to the end; each payload has the
    keys' shape and any dtype.  The last axis pads to the next power of
    two with ``-inf`` keys, continuing indices and zero payloads, then
    runs the bitonic schedule (outer width ``k = 2..R_pad``, inner
    stride ``j = k/2..1``).

    The comparator orders by ``(key desc, index asc)`` — a strict total
    order, so ANY correct network yields the one permutation that equals
    ``argsort(-keys, stable)``; using the same schedule in the engine,
    the host twin and the kernel makes the match structural rather than
    coincidental (like :func:`lane_sum`).  Payloads are moved by the
    same swaps, so ``sorted_payloads[i] == payload[order[i]]`` exactly
    (property-pinned against stable argsort + take in
    tests/test_policies.py); the R real elements always sort before the
    R_pad - R padding, so positions ``< R`` never see a padding payload.

    Returns ``(order, sorted_keys, sorted_payloads)``: ``order`` int32
    (..., R_pad) maps sorted position -> original index (positions
    ``>= R`` are padding); ``sorted_keys`` are the masked keys in that
    order (``-inf`` at invalid/padding positions); ``sorted_payloads``
    the payload tuple in that order.
    """
    r = keys.shape[-1]
    rp = _next_pow2(r)
    neg = xp.asarray(-xp.inf, keys.dtype)
    if valid is not None:
        keys = xp.where(valid, keys, neg)
    if rp != r:
        pad = [(0, 0)] * (keys.ndim - 1) + [(0, rp - r)]
        keys = xp.pad(keys, pad, constant_values=-xp.inf)
        payloads = tuple(xp.pad(p, pad) for p in payloads)
    idx = _sort_iota(keys.shape, xp)
    keys, idx, payloads = _bitonic_network(keys, idx, payloads, xp,
                                           descending=True)
    return (idx.astype(np.int32 if xp is np else jnp.int32), keys,
            payloads)


def bitonic_argsort_desc(keys, valid=None, xp=jnp):
    """Stable descending argsort — :func:`bitonic_sort_with_payload`
    with no payload lanes.  Returns ``(order, sorted_keys)``."""
    order, skeys, _ = bitonic_sort_with_payload(keys, (), valid=valid, xp=xp)
    return order, skeys


def bitonic_apply_inverse(order, payloads, xp=jnp):
    """Apply the INVERSE of a sort permutation to payload lanes — the
    one permutation apply per window of DESIGN.md §13.

    ``order``: (..., R_pad) int32 permutation of ``0..R_pad-1`` mapping
    sorted position -> original index (a ``bitonic_sort_with_payload``
    order, R_pad a power of two); ``payloads``: tuple of (..., R_pad)
    arrays in SORTED order.  Returns the payloads moved back to
    ORIGINAL-index order, i.e. ``out[order[p]] = payload[p]``, as one
    ascending bitonic pass keyed on the distinct integers of ``order``
    (strict total order, so the network computes THE unique inverse).
    Values are only relocated — never combined — so the result equals
    the one-hot scatter oracle bit-for-bit (property-pinned in
    tests/test_policies.py); no scatter/gather op, legal inside a fused
    Pallas body.
    """
    idx = _sort_iota(order.shape, xp)
    _, _, payloads = _bitonic_network(order, idx, payloads, xp,
                                      descending=False)
    return payloads


def rank_desc(keys, valid=None, xp=jnp):
    """Rank of every element under ``(key desc, index asc)`` — the sort
    permutation WITHOUT running a sort network (DESIGN.md §13).

    ``rank[i] = #{k : key_k > key_i  or  (key_k == key_i and k < i)}`` —
    one broadcasted all-pairs comparison over an ``(..., R, R)`` tile
    plus an integer row count.  The comparator is the strict total order
    shared with :func:`bitonic_sort_with_payload`, so ``rank`` is exactly
    the INVERSE of the stable ``argsort(-keys)`` permutation: element
    ``i`` lands at sorted position ``rank[i]``.  ``valid`` masks keys to
    ``-inf`` first (invalid rows rank after every valid one, index-asc
    among themselves — the §10 ordering invariant).  Integer compares and
    counts only — bit-exact on every backend, and unlike the network this
    needs no power-of-two padding.

    Returns ``(rank, masked_keys)``: ``rank`` int32 (..., R), and the
    keys after the validity mask (``-inf`` at invalid rows — the
    ``sorted_keys`` source for :func:`permute_to_sorted`).
    """
    i32 = np.int32 if xp is np else jnp.int32
    if valid is not None:
        keys = xp.where(valid, keys, xp.asarray(-xp.inf, keys.dtype))
    idx = _sort_iota(keys.shape, xp)
    a, ia = keys[..., :, None], idx[..., :, None]         # self
    b, ib = keys[..., None, :], idx[..., None, :]         # other
    before = (b > a) | ((b == a) & (ib < ia))
    return xp.sum(before.astype(i32), axis=-1), keys


def _rank_onehot(rank, xp):
    """(..., i, p) boolean: element ``i`` occupies sorted position ``p``."""
    pos = _sort_iota(rank.shape, xp)
    return rank[..., :, None] == pos[..., None, :]


def permute_to_sorted(rank, payloads, xp=jnp):
    """Gather payload lanes into sorted order: ``out[p] = payload[i]``
    where ``rank[i] == p`` (DESIGN.md §13).

    ``rank`` is a :func:`rank_desc` permutation, so exactly ONE element
    maps to each position: the masked sum below has a single non-zero
    term per output lane and is therefore a pure relocation — bit-exact
    for floats too (``x + 0.0 == x``; no value here is ``-0.0``).  One
    ``(..., R, R)`` select + sum per payload, no gather op, no sort
    network — legal inside a fused Pallas body.
    """
    oh = _rank_onehot(rank, xp)
    outs = []
    for x in payloads:
        z = xp.zeros((), x.dtype)
        outs.append(xp.sum(xp.where(oh, x[..., :, None], z), axis=-2))
    return tuple(outs)


def permute_from_sorted(rank, payloads, xp=jnp):
    """Scatter sorted payload lanes back to original-index order:
    ``out[i] = payload[rank[i]]`` — the inverse apply of DESIGN.md §13,
    same single-non-zero-term masked sum as :func:`permute_to_sorted`
    (property-pinned against the one-hot scatter oracle in
    tests/test_policies.py)."""
    oh = _rank_onehot(rank, xp)
    outs = []
    for x in payloads:
        z = xp.zeros((), x.dtype)
        outs.append(xp.sum(xp.where(oh, x[..., None, :], z), axis=-1))
    return tuple(outs)


def recursive_average_bounds(sorted_len, nvalid, n_levels: int, xp=jnp):
    """nLTR §3.4.3 request sectioning on a desc-sorted length list: split
    ``[0, nvalid)`` into ``2^n_levels`` sections by recursive average.

    ``sorted_len``: (..., R) lengths in descending order (padding beyond
    ``nvalid`` never read); ``nvalid``: (..., 1) int32 count of valid
    rows.  Returns (..., K-1) int32 boundary indices in tree (BFS) order
    — section of position ``p`` is ``sum(bounds <= p)`` (order-free, so
    callers never need them sorted).

    Every float reduction goes through :func:`lane_sum` so the engine's
    per-window call, the oracle and the kernel's ``(t_tile, R_pad)``
    tile form associate the section means identically — a mean that
    drifts 1 ulp can flip an integer boundary, which the bit-exactness
    contract (DESIGN.md §10) cannot absorb.  All boundary arithmetic is
    int32 (exact everywhere).
    """
    r = sorted_len.shape[-1]
    i32 = np.int32 if xp is np else jnp.int32
    if xp is np:
        pos = np.arange(r, dtype=np.int32)
    else:  # kernel-legal iota (see bitonic_argsort_desc)
        pos = jax.lax.broadcasted_iota(jnp.int32, sorted_len.shape,
                                       sorted_len.ndim - 1)
    zero = xp.zeros_like(nvalid)
    starts = [zero]
    ends = [nvalid.astype(i32)]
    bounds = []
    for _ in range(n_levels):
        new_starts, new_ends = [], []
        for s, e in zip(starts, ends):
            inside = (pos >= s) & (pos < e)
            cnt = xp.maximum(xp.sum(inside, axis=-1, keepdims=True), 1)
            # zeros_like, NOT 0.0 * sorted_len: the padded tail carries
            # -inf sort keys and 0 * -inf would leak NaN into the sum
            mean = lane_sum(xp.where(inside, sorted_len,
                                     xp.zeros_like(sorted_len)), xp) / cnt
            # desc order: elements > mean come first; boundary = first
            # index with value <= mean inside [s, e)
            gt = inside & (sorted_len > mean)
            b = s + xp.sum(gt, axis=-1, keepdims=True).astype(i32)
            # keep the boundary strictly inside (s, e): no empty section
            b = xp.clip(b, s + (e > s + 1), xp.maximum(e - 1, s + 1))
            bounds.append(b)
            new_starts.extend([s, b])
            new_ends.extend([b, e])
        starts, ends = new_starts, new_ends
    return xp.concatenate(bounds, axis=-1)


# ---------------------------------------------------------------------------
# Eq. (1)-(3) updates, observation, window maintenance
# ---------------------------------------------------------------------------


def assignment_update(loads, probs, server, length, lam: float, m: int,
                      xp=jnp):
    """Eq. (1)-(3): book ``length`` MB on ``server``; decay its selection
    probability and spread the lost mass over the other M-1 servers.

    The jnp form uses one-hot vector writes (`where`) instead of scatter
    — the exact formulation the Pallas kernel executes on VMEM lanes, so
    XLA lowers both layers through the same elementwise ops and the
    engine<->kernel trace stays bit-identical (scatter + scalar-exp
    lowering was observed to differ by 1 ulp inside fused loop bodies).

    Eq. (3)'s redistributed mass is computed as ``p_i * (1 - e) / (M-1)``
    rather than the algebraically equal ``(p_i - p_i * e) / (M-1)``: the
    latter is a mul-feeding-sub that XLA/LLVM contracts into an FMA in
    some lowering contexts and not others (observed tile-dependent in the
    trial-grid kernel — DESIGN.md §9), while here every product feeds a
    select or a divide, which nothing contracts.
    """
    if xp is np:
        loads = loads.copy()
        probs = probs.copy()
        loads[server] += length                              # Eq. (1)
        p_i = probs[server]
        e = np.exp(-loads[server] / lam)
        decayed = p_i * e                                    # Eq. (2)
        delta = p_i * (1.0 - e) / (m - 1)                    # Eq. (3)
        probs += delta
        probs[server] = decayed
        return loads, probs
    onehot = jnp.arange(loads.shape[-1]) == server
    loads = jnp.where(onehot, loads + length, loads)         # Eq. (1)
    l_i = loads[server]
    p_i = probs[server]
    e = jnp.exp(-l_i / lam)
    decayed = p_i * e                                        # Eq. (2)
    delta = p_i * (1.0 - e) / (m - 1)                        # Eq. (3)
    probs = jnp.where(onehot, decayed, probs + delta)
    return loads, probs


def observe_update(ewma_lat, server, mb_per_s, alpha: float, xp=jnp):
    """Fold one observed service rate into the EWMA row and re-derive the
    estimated-rate row.  Returns (ewma_lat, est_rates).  The est row is a
    pure function of observations — the only way the client ever learns
    about a server's speed (stale-view contract)."""
    if xp is np:
        ewma_lat = ewma_lat.copy()
        old = ewma_lat[server]
        ewma_lat[server] = (mb_per_s if old == 0.0
                            # contract-ok: CC-FMA EWMA row is 1e-6-soft (§9)
                            else (1 - alpha) * old + alpha * mb_per_s)
    else:
        old = ewma_lat[server]
        new = jnp.where(old == 0.0, mb_per_s,
                        # contract-ok: CC-FMA EWMA row is 1e-6-soft (§9)
                        (1 - alpha) * old + alpha * mb_per_s)
        ewma_lat = ewma_lat.at[server].set(new)
    return ewma_lat, ect_rates(ewma_lat, xp)


def lane_sum(x, xp=jnp):
    """Deterministic last-axis sum: an EXPLICIT pairwise halving tree
    (pad to the next power of two with exact zeros, then repeatedly add
    the upper half onto the lower).  ``jnp.sum``'s reduction tree is a
    backend/shape-dependent lowering choice — the trial-grid kernel's
    ``(t_tile, 128)`` row sum was observed to associate differently from
    the engine's ``(M,)`` sum, a 1-ulp drift per window that breaks the
    §9 parity contract.  Explicit adds are fixed HLO ops no backend may
    reassociate, and leading halvings over all-zero upper halves are
    exact identities, so any zero-padded width yields the same bits.
    Returns shape (..., 1)."""
    # contract-ok: CC-TWIN np arm IS the f64 host oracle (§9)
    if xp is np:
        # contract-ok: CC-SUM host-twin sum is the reference association (§9)
        return x.sum(axis=-1, keepdims=True)
    m = x.shape[-1]
    size = 1
    while size < m:
        size *= 2
    if size != m:
        pad = [(0, 0)] * (x.ndim - 1) + [(0, size - m)]
        x = jnp.pad(x, pad)
    while x.shape[-1] > 1:
        h = x.shape[-1] // 2
        x = x[..., :h] + x[..., h:]
    return x


def renormalize_probs(probs, xp=jnp):
    """Re-project the probability row onto the simplex (float-drift guard;
    run once per window by every layer that renormalizes).

    The reduction runs through :func:`lane_sum` so the engine, the oracle
    and the (tiled) kernel all associate the sum identically — the last
    bit of the engine<->kernel parity contract."""
    # contract-ok: CC-TWIN np arm IS the f64 host oracle (§9)
    if xp is np:
        p = np.clip(probs, 0.0, None)
        # contract-ok: CC-SUM host-twin sum is the reference association (§9)
        return p / p.sum(axis=-1, keepdims=True)
    p = jnp.clip(probs, 0.0)
    return p / lane_sum(p)


def absorb_probs(loads, lam: float, m: int, xp=jnp):
    """Probability row absorbing known initial loads — the vectorized
    fixed point of Eq. (2): ``p_i ∝ (1/M) · e^{-l_i/λ}`` (DESIGN.md §14).

    The normalization runs through :func:`lane_sum` so the batched trial
    prep (vmapped over the trial axis, ``(T, M)`` rows) and the
    sequential ``lax.map`` prep (``(M,)`` rows) associate the sum
    identically — the halving tree is batch-shape-invariant, whereas
    ``jnp.sum``'s reduction tree is a lowering choice that may differ
    between the two contexts.  Works on any ``(..., M)`` batch."""
    # contract-ok: CC-TWIN np arm IS the f64 host oracle (§9)
    if xp is np:
        p = np.exp(-loads / lam) / m
        # contract-ok: CC-SUM host-twin sum is the reference association (§9)
        return p / p.sum(axis=-1, keepdims=True)
    p = jnp.exp(-loads / lam) / m
    return p / lane_sum(p)


def server_segment_sum(values, idx, m: int, xp=jnp, block: int = 128):
    """Pinned per-server float sum: ``out[s] = Σ values[r] · [idx[r] == s]``
    with an EXPLICIT association no backend may reshuffle — sequential
    (ascending) over ``ceil(R / block)`` request chunks, each chunk's
    one-hot contributions folded by :func:`tree_sum` over the request
    axis (DESIGN.md §14).

    ``jax.ops.segment_sum`` lowers to a scatter-add whose duplicate-index
    combine order is a backend choice that may differ between the vmapped
    batched post step and the per-trial ``lax.map`` oracle; this
    formulation is the same in both contexts by construction (the chunk
    walk mirrors `masked_client_sum`'s sequential-over-blocks /
    tree-within-block shape).  Integer sums don't need it — they are
    exact under any order.  ``values``/``idx``: (..., R); returns
    (..., m)."""
    r = values.shape[-1]
    n_blocks = max(-(-r // block), 1)
    if xp is np:
        lane = np.arange(m, dtype=np.int64)
    else:
        lane = jnp.arange(m, dtype=jnp.int32)
    out = None
    for b in range(n_blocks):
        v = values[..., b * block:(b + 1) * block]
        i = idx[..., b * block:(b + 1) * block]
        onehot = i[..., :, None] == lane            # (..., blk, m)
        contrib = xp.where(onehot, v[..., :, None], xp.zeros_like(v)[..., None])
        blk_sum = tree_sum(contrib, axis=-2, xp=xp)[..., 0, :]
        out = blk_sum if out is None else out + blk_sum
    return out


def window_decrements(rates, dt, xp=jnp):
    """Per-window drain decrement ``max(rates, 1e-6) * dt`` — computed
    ONCE, outside the fused loop body that subtracts it.

    This materialization is a correctness contract, not a micro-opt
    (DESIGN.md §9): when the product sits next to the subtraction inside
    one fused computation, XLA/LLVM may contract ``loads - rates * dt``
    into an FMA — and whether it does was observed to depend on the
    lowering context (the scan-body engine and the t_tile = 1 kernel
    fused; the trial-tiled kernel did not), a 1-ulp drift that breaks
    the engine<->kernel bit-exactness contract.  A decrement that enters
    the loop as a materialized array (scan ``xs`` row / pallas operand)
    leaves only a bare subtract inside the body, which every backend
    rounds identically.

    The scan-xs materialization alone is NOT sufficient: XLA simplifies
    a single-iteration window scan away, the orphaned product lands in
    the same kLoop fusion as the subtract, and LLVM contracts the pair
    into an FMA at instruction selection — a level no graph construct
    reaches (``optimization_barrier`` and even an int32 bitcast
    round-trip were both observed to contract anyway; found under the
    per_client vmap² engine at one-window-per-client shapes, DESIGN.md
    §11).  The fix is arithmetic: clamp the decrement at zero.  A drain
    decrement is nonnegative by construction, so ``maximum(dec, 0)`` is
    a bit-exact identity — but the subtract's operand is now a
    ``maximum``, not a ``multiply``, and fp contraction only fuses a
    multiply that DIRECTLY feeds the add/sub (the compiler cannot drop
    the clamp either: the rates are runtime values whose sign it cannot
    prove)."""
    return xp.maximum(xp.maximum(rates, 1e-6) * dt, 0.0)


def drain_loads(loads, rates, dt, xp=jnp, dec=None):
    """Temporal model: drain each server's outstanding queue at its TRUE
    service rate for ``dt`` virtual seconds, clipped at empty.  The one
    place the simulator's ground-truth rates touch the log (queue physics,
    not a scheduling decision).

    ``dec`` is the precomputed :func:`window_decrements` row; pass it
    whenever the drain runs inside a fused loop body (see that helper's
    FMA-contraction note).  ``dec=None`` computes it inline — fine for
    the numpy host twin and one-shot jnp calls."""
    if dec is None:
        dec = window_decrements(rates, dt, xp)
    return xp.maximum(loads - dec, 0.0)


def estimated_latency(loads, rates, server, xp=jnp):
    """Seconds until a request just queued on ``server`` completes, at the
    given (true) service rates — the simulator's latency report."""
    return loads[server] / xp.maximum(rates[server], 1e-6)


# ---------------------------------------------------------------------------
# Fused stream metrics — the trial-grid kernel's in-VMEM reduction twin
# ---------------------------------------------------------------------------

P99_Q = 0.99          # nearest-rank quantile the kernel reduces in-VMEM
P99_BISECT_ITERS = 48  # f32 bisection steps (converges to lane adjacency)


def nearest_rank_p99(lats, valid, xp=jnp):
    """Nearest-rank p99 of the valid latencies via value bisection — the
    EXACT float algorithm the kernel runs on its VMEM-resident latency
    block (DESIGN.md §9): ``P99_BISECT_ITERS`` halvings of ``[-1, max]``
    keeping ``count(lats <= lo) < k <= count(lats <= hi)`` with
    ``k = ceil(0.99 * n_valid)``, then the smallest element above ``lo``.
    Supports a leading batch axis; all arithmetic is f32 so the kernel
    and this twin agree bit-for-bit.
    """
    lats = lats.astype(jnp.float32) if xp is jnp else lats.astype(np.float32)
    validf = valid.astype(lats.dtype)
    # contract-ok: CC-SUM counting exact 0/1 floats — every association agrees (§9)
    nval = xp.sum(validf, axis=-1, keepdims=True)
    k = xp.ceil(lats.dtype.type(P99_Q) * nval) if xp is np \
        else xp.ceil(jnp.float32(P99_Q) * nval)
    lo = xp.full(nval.shape, -1.0, lats.dtype)
    hi = xp.max(xp.where(valid, lats, 0.0), axis=-1, keepdims=True)
    for _ in range(P99_BISECT_ITERS):
        mid = lats.dtype.type(0.5) * (lo + hi) if xp is np \
            else jnp.float32(0.5) * (lo + hi)
        cnt = xp.sum(xp.where(valid & (lats <= mid), validf, 0.0 * validf),
                     axis=-1, keepdims=True)
        go_hi = cnt >= k
        lo, hi = xp.where(go_hi, lo, mid), xp.where(go_hi, mid, hi)
    big = lats.dtype.type(3.4e38)
    p99 = xp.min(xp.where(valid & (lats > lo), lats, big),
                 axis=-1, keepdims=True)
    return xp.where(nval > 0, p99, 0.0 * p99)


def stream_metrics(lats, valid, window_dt: float, window_size: int, xp=jnp):
    """Per-trial fused metrics over a scheduled stream, in the EXACT
    accumulation order of the trial-grid kernel (request order for the
    order-sensitive ``lat_sum``; ``makespan``/``lat_max``/``n_valid`` are
    order-free reductions; ``p99_lat`` via :func:`nearest_rank_p99`).

    ``lats``/``valid``: (..., N) per-step latencies and validity with
    ``N = W * window_size``; completion of step ``i`` is
    ``(i // window_size) * window_dt + lat_i`` (the simulator's
    window-open clock).  Returns (..., N_METRICS) f32 in ``MET_*`` order.
    """
    lats = lats.astype(jnp.float32 if xp is jnp else np.float32)
    latv = xp.where(valid, lats, 0.0 * lats)
    n = lats.shape[-1]
    idx = xp.arange(n, dtype=np.int32 if xp is np else jnp.int32)
    # f32 cast BEFORE the multiply — the kernel's wopen = f32(w) * f32(dt)
    w_open = (idx // window_size).astype(lats.dtype) * lats.dtype.type(
        window_dt) if xp is np else \
        (idx // window_size).astype(jnp.float32) * jnp.float32(window_dt)
    makespan = xp.max(xp.where(valid, w_open + lats, 0.0 * lats),
                      axis=-1, keepdims=True)
    lat_max = xp.max(latv, axis=-1, keepdims=True)
    n_valid = xp.sum(xp.where(valid, xp.ones_like(latv), 0.0 * latv),
                     axis=-1, keepdims=True)
    if xp is np:
        lat_sum = np.zeros(latv.shape[:-1] + (1,), np.float32)
        for i in range(n):                       # sequential f32 adds —
            lat_sum = lat_sum + latv[..., i:i + 1]   # the kernel's order
    else:
        lat_sum = jax.lax.fori_loop(
            0, n, lambda i, s: s + jax.lax.dynamic_slice_in_dim(latv, i, 1,
                                                                axis=-1),
            jnp.zeros(latv.shape[:-1] + (1,), jnp.float32))
    p99 = nearest_rank_p99(lats, valid, xp)
    return xp.concatenate([makespan, p99, lat_sum, lat_max, n_valid],
                          axis=-1)


# ---------------------------------------------------------------------------
# Cross-client merge — the 2-D (trials × clients) grid's reduction twins
# ---------------------------------------------------------------------------


def tree_sum(x, axis: int = 0, xp=jnp):
    """Deterministic sum over ``axis``: the explicit pairwise halving tree
    of :func:`lane_sum`, generalized to any axis (zero-pad to the next
    power of two, then repeatedly fold the upper half onto the lower).
    Keeps the axis with size 1.  This is the WITHIN-BLOCK association of
    the cross-client merge: the 2-D grid kernel folds its ``client_tile``
    client sublanes through exactly these adds (DESIGN.md §11)."""
    c = x.shape[axis]
    size = _next_pow2(c)
    if size != c:
        pad = [(0, 0)] * x.ndim
        pad[axis] = (0, size - c)
        x = xp.pad(x, pad)
    lo = [slice(None)] * x.ndim
    hi = [slice(None)] * x.ndim
    while x.shape[axis] > 1:
        h = x.shape[axis] // 2
        lo[axis] = slice(0, h)
        hi[axis] = slice(h, None)
        x = x[tuple(lo)] + x[tuple(hi)]
    return x


def _mask_clients(x, client_valid, xp=jnp):
    """Zero the rows of phantom clients (leading client axis)."""
    cv = client_valid.reshape(client_valid.shape + (1,) * (x.ndim - 1))
    return xp.where(cv, x, xp.zeros_like(x))


def masked_client_sum(x, client_valid, client_tile: int, xp=jnp):
    """Cross-client masked sum over the LEADING client axis with the 2-D
    grid's float association: sequential (ascending) over
    ``ceil(C / client_tile)`` client blocks, each block folded by
    :func:`tree_sum`.  This mirrors exactly how the grid kernel
    accumulates — within a program instance the ``client_tile`` client
    sublanes fold through the halving tree, and the per-trial
    accumulator adds one block per client grid step — so the jax path,
    the oracle and the kernel produce bit-identical merged floats
    (DESIGN.md §11).  ``client_valid``: (C,) bool — phantom clients
    (padded slices that scheduled nothing) contribute exact zeros.
    Returns ``x.shape[1:]``."""
    c = x.shape[0]
    xm = _mask_clients(x, client_valid, xp)
    n_blocks = -(-c // client_tile)
    if n_blocks * client_tile != c:
        pad = [(0, n_blocks * client_tile - c)] + [(0, 0)] * (x.ndim - 1)
        xm = xp.pad(xm, pad)
    out = None
    for b in range(n_blocks):
        blk = tree_sum(xm[b * client_tile:(b + 1) * client_tile], 0, xp)[0]
        out = blk if out is None else out + blk
    return out


def masked_client_mean(x, client_valid, client_tile: int, xp=jnp):
    """Masked cross-client mean: :func:`masked_client_sum` divided by the
    REAL client count (at least 1) — the "typical client's view"
    aggregate of the per_client contention model, shared verbatim by
    ``simulate``'s jax path and re-derived bit-identically by the grid
    kernel's in-VMEM merge."""
    total = masked_client_sum(x, client_valid, client_tile, xp)
    dtype = total.dtype
    n_real = masked_client_sum(
        xp.ones(client_valid.shape, dtype), client_valid, client_tile, xp)
    return total / xp.maximum(n_real, xp.ones((), dtype))


def masked_client_max(x, client_valid, xp=jnp):
    """Masked cross-client max over the leading client axis (floored at 0
    — every merged metric is nonnegative).  ``max`` is order-free, so no
    association contract is needed."""
    return xp.max(_mask_clients(x, client_valid, xp), axis=0)


def client_stream_metrics(metrics, client_valid, client_tile: int, xp=jnp,
                          merged_lats=None, merged_valid=None):
    """Merge per-client stream-metric rows into the per-trial row the 2-D
    grid kernel fuses in-VMEM (DESIGN.md §11/§14).  ``metrics``:
    (C, >= N_METRICS) per-client rows (:func:`stream_metrics` layout);
    ``client_valid``: (C,) bool.  Returns (N_CMETRICS,) f32 in ``MET_*``
    + ``MET_N_CLIENTS`` order.

    ``merged_lats``/``merged_valid``: the (C, N) per-client grouped-step
    latency block and its validity — when given, the cross-client p99
    lane is :func:`nearest_rank_p99` over the flattened merged block
    (every reduction in it — counts of exact 0/1 floats, min/max — is
    order- and layout-insensitive, so ANY client/step ordering of the
    same multiset gives identical bits; the kernel's VMEM accumulation
    order needs no association contract, DESIGN.md §14).  When omitted
    the lane is 0 — the pre-merged-block behaviour."""
    f32 = jnp.float32 if xp is jnp else np.float32
    metrics = metrics.astype(f32)
    mx = masked_client_max(metrics, client_valid, xp)
    sm = masked_client_sum(metrics, client_valid, client_tile, xp)
    n_real = masked_client_sum(xp.ones(client_valid.shape, f32),
                               client_valid, client_tile, xp)
    if merged_lats is None:
        p99 = xp.zeros((), f32)
    else:
        p99 = nearest_rank_p99(merged_lats.reshape(-1),
                               merged_valid.reshape(-1), xp)[0]
    return xp.stack([mx[MET_MAKESPAN], p99,
                     sm[MET_LAT_SUM], mx[MET_LAT_MAX], sm[MET_N_VALID],
                     n_real])


# ---------------------------------------------------------------------------
# Sharded cross-client merge — the device axis as one more association
# parameter (DESIGN.md §12)
# ---------------------------------------------------------------------------


def resolve_shard_width(n_clients: int, n_shards: int) -> int:
    """Clients per contiguous device shard of the client axis — the
    device-axis twin of :func:`resolve_client_tile`, shared by the
    sharded sweep dispatch (``parallel/sweep.py``) and the host oracle
    :func:`sharded_client_sum` so both layers pad and split the client
    axis identically (trailing shards fill up with phantom clients)."""
    if n_shards < 1:
        raise ValueError(f"n_shards={n_shards!r} must be >= 1")
    return -(-n_clients // n_shards)


def psum_tree(x, axis_name: str):
    """Deterministic cross-device sum over mesh axis ``axis_name``: the
    collective twin of :func:`tree_sum`.  ``all_gather`` stacks every
    device's pre-reduced partial in mesh-coordinate order, then the
    pinned halving tree folds the stack — NEVER ``jax.lax.psum``, whose
    reduction order is backend/topology-dependent.  Every device gathers
    identical operands and folds them through the same tree, so the
    result is replicated across the axis and bit-identical to the host
    oracle (:func:`sharded_client_sum`'s outer fold)."""
    g = jax.lax.all_gather(x, axis_name, axis=0)
    return tree_sum(g, axis=0)[0]


def sharded_client_sum(x, client_valid, client_tile, n_shards: int, xp=jnp):
    """Host oracle of the SHARDED cross-client merge (DESIGN.md §12):
    what ``parallel/sweep.py`` computes when the client axis is split
    over ``n_shards`` mesh devices.  Two association levels stack:

    1. pad the client axis with phantoms to ``n_shards`` equal
       contiguous shards of :func:`resolve_shard_width` clients and run
       :func:`masked_client_sum` WITHIN each shard — the per-device
       partial, with ``client_tile`` re-resolved against the shard
       width exactly as each device's 2-D grid kernel resolves it
       against its local client count;
    2. fold the per-shard partials with :func:`tree_sum` over the shard
       axis — what :func:`psum_tree` computes via ``all_gather``.

    ``n_shards == 1`` degenerates bit-exactly to ``masked_client_sum``
    with the no-mesh tile resolution.  ``client_tile`` may be ``None``
    (the package default), matching the config-level knob."""
    c = x.shape[0]
    w = resolve_shard_width(c, n_shards)
    c_pad = w * n_shards
    if c_pad != c:
        pad = [(0, c_pad - c)] + [(0, 0)] * (x.ndim - 1)
        x = xp.pad(x, pad)
        client_valid = xp.pad(client_valid, (0, c_pad - c))
    ct = resolve_client_tile(w, client_tile)
    parts = xp.stack([
        masked_client_sum(x[s * w:(s + 1) * w],
                          client_valid[s * w:(s + 1) * w], ct, xp)
        for s in range(n_shards)])
    return tree_sum(parts, 0, xp)[0]


def sharded_client_mean(x, client_valid, client_tile, n_shards: int, xp=jnp):
    """Sharded twin of :func:`masked_client_mean`: the shard-merged sum
    over the shard-merged real-client count (at least 1) — the division
    happens ONCE, globally, after the cross-device fold (a mean is not
    composable across devices; the kernel ships raw sums with
    ``merge_mean=False`` for exactly this reason)."""
    total = sharded_client_sum(x, client_valid, client_tile, n_shards, xp)
    dtype = total.dtype
    n_real = sharded_client_sum(xp.ones(client_valid.shape, dtype),
                                client_valid, client_tile, n_shards, xp)
    return total / xp.maximum(n_real, xp.ones((), dtype))
