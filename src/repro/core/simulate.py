"""Paper §4 simulation harness.

Reproduces the evaluation environment of the paper:

* 100 object storage servers, 200 compute nodes;
* 2,000 I/O requests per trial in three size classes — small (< 4 MB),
  medium (4-10 MB), large (> 10 MB, up to ~1 GB so the large-only workload
  spans O(20 GB)-O(2 TB) as in §4);
* initial OSS loads ~ Normal(mean, small sigma);
* 100 trials, reporting the average per-OSS load;
* straggler injection: 10 % of servers receive 5x the average load.

Everything is one jitted, ``vmap``-over-trials program per policy.

Two client models are provided:

* ``shared_log``  (default, used for the paper's figures): all requests go
  through one collective statistic log — the paper's §3.2 collective-I/O
  scheduling model.
* ``per_client``  (contention study, beyond the paper's figures): requests
  are partitioned over ``n_clients`` independent logs which do NOT see each
  other's decisions; reported loads are the true per-server sums.  This
  quantifies the multi-client blind spot discussed in DESIGN.md.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import engine, policies, statlog
from repro.core.engine import Workload
from repro.core.policies import PolicyConfig
from repro.core.statlog import LogConfig, SchedState

SIZE_CLASSES = ("small", "medium", "large", "mixed")


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Paper §4 simulation parameters (defaults = the paper's numbers)."""

    n_servers: int = 100
    n_clients: int = 200
    n_requests: int = 2000
    n_trials: int = 100
    workload: str = "mixed"          # small | medium | large | mixed
    window_size: int = 100           # requests per time window
    init_load_mean: float = 50.0     # MB, Normal initial loads
    init_load_std: float = 5.0       # "small standard deviation"
    straggler_frac: float = 0.0      # 0.10 for the Fig. 18 experiment
    straggler_factor: float = 5.0    # 5x average extra load on stragglers
    client_model: str = "shared_log"  # shared_log | per_client
    # size-class boundaries (MB) per §4
    small_lo: float = 0.25
    small_hi: float = 4.0
    medium_hi: float = 10.0
    large_hi: float = 1024.0

    def __post_init__(self):
        assert self.workload in SIZE_CLASSES
        assert self.client_model in ("shared_log", "per_client")


class TrialResult(NamedTuple):
    """Per-trial outputs (leading trial axis after vmap)."""

    server_loads: jax.Array    # (M,) final true load per server, MB
    n_assigned: jax.Array      # (M,) requests landed per server
    chosen: jax.Array          # (R,) server per request
    probe_msgs: jax.Array      # () probe messages issued
    straggler_hits: jax.Array  # () requests landed on injected stragglers
    redirected: jax.Array      # () requests redirected away from default
    init_loads: jax.Array      # (M,) initial (pre-scheduling) loads
    straggler_mask: jax.Array  # (M,) bool


def sample_workload(key: jax.Array, cfg: SimConfig) -> Workload:
    """Synthetic request stream per §4's three size classes."""
    k_obj, k_cls, k_small, k_med, k_large = jax.random.split(key, 5)
    r = cfg.n_requests
    object_ids = jax.random.randint(k_obj, (r,), 0, 8 * cfg.n_servers,
                                    dtype=jnp.int32)
    small = jax.random.uniform(k_small, (r,), minval=cfg.small_lo,
                               maxval=cfg.small_hi)
    med = jax.random.uniform(k_med, (r,), minval=cfg.small_hi,
                             maxval=cfg.medium_hi)
    large = jax.random.uniform(k_large, (r,), minval=cfg.medium_hi,
                               maxval=cfg.large_hi)
    if cfg.workload == "small":
        lengths = small
    elif cfg.workload == "medium":
        lengths = med
    elif cfg.workload == "large":
        lengths = large
    else:  # mixed: uniform over the three classes
        cls = jax.random.randint(k_cls, (r,), 0, 3)
        lengths = jnp.where(cls == 0, small, jnp.where(cls == 1, med, large))
    return Workload(object_ids=object_ids, lengths=lengths.astype(jnp.float32),
                    valid=jnp.ones((r,), bool))


def mean_request_mb(cfg: SimConfig) -> float:
    """Expected request size per workload class (MB)."""
    return {
        "small": (cfg.small_lo + cfg.small_hi) / 2,
        "medium": (cfg.small_hi + cfg.medium_hi) / 2,
        "large": (cfg.medium_hi + cfg.large_hi) / 2,
        "mixed": ((cfg.small_lo + cfg.small_hi) / 2
                  + (cfg.small_hi + cfg.medium_hi) / 2
                  + (cfg.medium_hi + cfg.large_hi) / 2) / 3,
    }[cfg.workload]


def expected_server_load_mb(cfg: SimConfig) -> float:
    """Expected FINAL average per-server load from scheduling alone."""
    return cfg.n_requests * mean_request_mb(cfg) / cfg.n_servers


def initial_loads(key: jax.Array, cfg: SimConfig) -> Tuple[jax.Array, jax.Array]:
    """Normal initial loads + optional straggler injection (§4).

    Paper: stragglers carry '5 times more load compared with the average
    loads assigned on other storage servers' — i.e. the extra is scaled to
    the run's expected per-server load, not the (small) initial load.
    """
    k_norm, k_strag = jax.random.split(key)
    loads = cfg.init_load_mean + cfg.init_load_std * jax.random.normal(
        k_norm, (cfg.n_servers,))
    loads = jnp.maximum(loads, 0.0)
    n_strag = int(round(cfg.straggler_frac * cfg.n_servers))
    mask = jnp.zeros((cfg.n_servers,), bool)
    if n_strag > 0:
        idx = jax.random.choice(k_strag, cfg.n_servers, (n_strag,),
                                replace=False)
        mask = mask.at[idx].set(True)
        extra = cfg.straggler_factor * expected_server_load_mb(cfg)
        loads = loads + mask * extra
    return loads.astype(jnp.float32), mask


def absorb_initial_loads(state: SchedState, loads: jax.Array,
                         log_cfg: LogConfig) -> SchedState:
    """Fold known initial loads into the log: p_i ∝ (1/M)·e^{-l_i/λ}.

    This is the vectorized fixed point of applying Eq. (2) once per server
    for its initial load, then renormalizing — how a client that has been
    running for a while would see the cluster.
    """
    m = state.n_servers
    probs = jnp.exp(-loads / log_cfg.lam) / m
    probs = probs / jnp.sum(probs)
    return state._replace(loads=loads, probs=probs.astype(jnp.float32))


def _run_shared_log(key: jax.Array, cfg: SimConfig, policy: PolicyConfig,
                    log_cfg: LogConfig) -> TrialResult:
    k_load, k_work, k_sched = jax.random.split(key, 3)
    init, strag_mask = initial_loads(k_load, cfg)
    work = sample_workload(k_work, cfg)
    state = statlog.init_state(log_cfg)
    state = absorb_initial_loads(state, init, log_cfg)
    res = engine.run_stream(state, work, k_sched, policy=policy,
                            log_cfg=log_cfg, window_size=cfg.window_size,
                            group_steps=True)
    written = jax.ops.segment_sum(work.lengths, res.chosen,
                                  num_segments=cfg.n_servers)
    n_assigned = jax.ops.segment_sum(jnp.ones_like(res.chosen), res.chosen,
                                     num_segments=cfg.n_servers)
    hits = jnp.sum(strag_mask[res.chosen])
    return TrialResult(server_loads=init + written, n_assigned=n_assigned,
                       chosen=res.chosen, probe_msgs=res.probe_msgs,
                       straggler_hits=hits,
                       redirected=jnp.sum(res.redirected),
                       init_loads=init, straggler_mask=strag_mask)


def _run_per_client(key: jax.Array, cfg: SimConfig, policy: PolicyConfig,
                    log_cfg: LogConfig) -> TrialResult:
    """Contention model: each client schedules its slice with a private log
    that starts from the same initial-load snapshot but never sees other
    clients' decisions.  True server loads are the cross-client sums."""
    k_load, k_work, k_sched = jax.random.split(key, 3)
    init, strag_mask = initial_loads(k_load, cfg)
    work = sample_workload(k_work, cfg)
    n_c = cfg.n_clients
    per = -(-cfg.n_requests // n_c)
    pad = n_c * per - cfg.n_requests

    def pad_to(a, fill=0):
        return jnp.concatenate([a, jnp.full((pad,), fill, a.dtype)]) if pad else a

    obj = pad_to(work.object_ids).reshape(n_c, per)
    lens = pad_to(work.lengths).reshape(n_c, per)
    val = pad_to(work.valid, False).reshape(n_c, per)
    keys = jax.random.split(k_sched, n_c)

    def one_client(o, ln, v, k):
        state = statlog.init_state(log_cfg)
        state = absorb_initial_loads(state, init, log_cfg)
        res = engine.run_stream(state, Workload(o, ln, v), k, policy=policy,
                                log_cfg=log_cfg, window_size=min(cfg.window_size, per))
        return res.chosen, res.probe_msgs, res.redirected

    chosen, probes, redirected = jax.vmap(one_client)(obj, lens, val, keys)
    chosen = chosen.reshape(-1)[:cfg.n_requests]
    redirected = redirected.reshape(-1)[:cfg.n_requests]
    written = jax.ops.segment_sum(work.lengths, chosen,
                                  num_segments=cfg.n_servers)
    n_assigned = jax.ops.segment_sum(jnp.ones_like(chosen), chosen,
                                     num_segments=cfg.n_servers)
    return TrialResult(server_loads=init + written, n_assigned=n_assigned,
                       chosen=chosen, probe_msgs=jnp.sum(probes),
                       straggler_hits=jnp.sum(strag_mask[chosen]),
                       redirected=jnp.sum(redirected),
                       init_loads=init, straggler_mask=strag_mask)


@functools.partial(jax.jit, static_argnames=("cfg", "policy", "log_cfg"))
def run_trials(key: jax.Array, cfg: SimConfig, policy: PolicyConfig,
               log_cfg: LogConfig) -> TrialResult:
    """Run ``cfg.n_trials`` independent trials (vmapped + jitted)."""
    keys = jax.random.split(key, cfg.n_trials)
    fn = _run_shared_log if cfg.client_model == "shared_log" else _run_per_client
    return jax.vmap(lambda k: fn(k, cfg, policy, log_cfg))(keys)


def default_log_cfg(cfg: SimConfig, lam: Optional[float] = None) -> LogConfig:
    """λ on the order of the expected per-server load so Eq. (2)'s
    exponential stays in a resolvable range over the whole run
    (DESIGN.md numerical-fidelity note; λ -> 0 recovers the literal
    paper behaviour)."""
    if lam is None:
        lam = max(4.0 * mean_request_mb(cfg), expected_server_load_mb(cfg))
    return LogConfig(n_servers=cfg.n_servers, lam=lam)


def run_paper_eval(seed: int = 0, cfg: Optional[SimConfig] = None,
                   policy_names: Tuple[str, ...] = ("rr", "mlml", "trh",
                                                    "nltr", "two_choice"),
                   threshold: float = 5.0,
                   nltr_ns: Tuple[int, ...] = (1, 2)) -> dict:
    """Run the full §4 evaluation; returns {label: TrialResult}."""
    cfg = cfg or SimConfig()
    log_cfg = default_log_cfg(cfg)
    key = jax.random.key(seed)
    out = {}
    for name in policy_names:
        if name == "nltr":
            for n in nltr_ns:
                pol = PolicyConfig(name="nltr", threshold=threshold, nltr_n=n)
                out[f"{n}ltr"] = run_trials(key, cfg, pol, log_cfg)
        else:
            pol = PolicyConfig(name=name, threshold=threshold)
            out[name] = run_trials(key, cfg, pol, log_cfg)
    return out
