"""Paper §4 simulation harness.

Reproduces the evaluation environment of the paper:

* 100 object storage servers, 200 compute nodes;
* 2,000 I/O requests per trial in three size classes — small (< 4 MB),
  medium (4-10 MB), large (> 10 MB, up to ~1 GB so the large-only workload
  spans O(20 GB)-O(2 TB) as in §4);
* initial OSS loads ~ Normal(mean, small sigma);
* 100 trials, reporting the average per-OSS load;
* straggler injection: 10 % of servers receive 5x the average load.

Everything is one jitted, ``vmap``-over-trials program per policy.

Two client models are provided:

* ``shared_log``  (default, used for the paper's figures): all requests go
  through one collective statistic log — the paper's §3.2 collective-I/O
  scheduling model.
* ``per_client``  (contention study, beyond the paper's figures): requests
  are partitioned over ``n_clients`` independent logs which do NOT see each
  other's decisions; reported loads are the true per-server sums.  This
  quantifies the multi-client blind spot discussed in DESIGN.md.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import engine, policies, statlog
from repro.core.engine import ClusterTrace, Workload
from repro.core.policies import PolicyConfig
from repro.core.statlog import LogConfig, SchedState

SIZE_CLASSES = ("small", "medium", "large", "mixed")

SCENARIOS = ("static", "permanent_slow", "transient", "flapping",
             "correlated_rack")

# Canonical policy set for temporal sweeps (the paper's log-assisted
# policies + the rate-aware ECT extension); benchmarks import this so the
# ranking tables track the scenario/policy libraries automatically.
SWEEP_POLICIES = ("rr", "mlml", "trh", "nltr", "ect")


@dataclasses.dataclass(frozen=True)
class ScenarioConfig:
    """Temporal straggler scenario (DESIGN.md §Temporal-model).

    Generates a :class:`~repro.core.engine.ClusterTrace` per trial (random
    straggler identities, deterministic per trial key):

    * ``static``          — all rates equal, no events; with the default
                            ``window_dt = 0`` this is the degenerate trace
                            that reproduces the paper's static-load model
                            bit-for-bit (Fig. 18's extra-load stragglers
                            stay available via ``SimConfig.straggler_frac``).
    * ``permanent_slow``  — a random ``straggler_frac`` subset serves at
                            ``base/slow_factor`` for the whole run
                            (permanent heterogeneity, arXiv:1911.05918's
                            slow-service setting).
    * ``transient``       — same subset degrades at ``onset`` and recovers
                            at ``recover`` (fractions of the stream
                            horizon) — the IOPathTune-style runtime drift.
    * ``flapping``        — the subset alternates slow/normal ``n_flaps``
                            times over the horizon.
    * ``correlated_rack`` — a random contiguous rack of ``rack_size``
                            servers degrades at ``onset`` and stays slow
                            (correlated failure domain).
    """

    name: str = "static"
    base_rate_mb_s: float = 200.0
    slow_factor: float = 8.0
    straggler_frac: float = 0.10
    # None -> auto: the time in which the *healthy* cluster exactly drains
    # one window's bytes, so stragglers accumulate queue (static -> 0.0).
    window_dt: Optional[float] = None
    onset: float = 0.25       # fraction of horizon (transient/flapping/rack)
    recover: float = 0.65     # transient recovery point (fraction of horizon)
    n_flaps: int = 8
    rack_size: int = 8

    def __post_init__(self):
        if self.name not in SCENARIOS:
            raise ValueError(
                f"unknown scenario {self.name!r}; choose from {SCENARIOS}")


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Paper §4 simulation parameters (defaults = the paper's numbers)."""

    n_servers: int = 100
    n_clients: int = 200
    n_requests: int = 2000
    n_trials: int = 100
    workload: str = "mixed"          # small | medium | large | mixed
    window_size: int = 100           # requests per time window
    init_load_mean: float = 50.0     # MB, Normal initial loads
    init_load_std: float = 5.0       # "small standard deviation"
    straggler_frac: float = 0.0      # 0.10 for the Fig. 18 experiment
    straggler_factor: float = 5.0    # 5x average extra load on stragglers
    client_model: str = "shared_log"  # shared_log | per_client
    # temporal scenario (None = the seed's static-load model, no trace)
    scenario: Optional[ScenarioConfig] = None
    # scheduling substrate: "jax" (lax.scan engine, every policy) or
    # "kernel" (the Pallas trial-grid kernel — every §3.4 policy incl.
    # the sort-based mlml/nltr (DESIGN.md §10), shared_log model; ALL
    # trials run as ONE pallas_call, grid = trial tiles; DESIGN.md §9).
    backend: str = "jax"
    # trials per kernel program instance (kernel backend; None = the
    # kernels package default, the native f32 sublane count 8)
    trial_tile: Optional[int] = None
    # size-class boundaries (MB) per §4
    small_lo: float = 0.25
    small_hi: float = 4.0
    medium_hi: float = 10.0
    large_hi: float = 1024.0

    def __post_init__(self):
        # real exceptions, not asserts: `python -O` strips asserts, and a
        # mis-built sweep config must fail loudly either way.
        if self.workload not in SIZE_CLASSES:
            raise ValueError(
                f"workload={self.workload!r} is not one of {SIZE_CLASSES}")
        if self.client_model not in ("shared_log", "per_client"):
            raise ValueError(
                f"client_model={self.client_model!r} must be 'shared_log' "
                "or 'per_client'")
        if self.backend not in ("jax", "kernel"):
            raise ValueError(
                f"backend={self.backend!r} must be 'jax' or 'kernel'")
        if self.backend == "kernel" and self.client_model != "shared_log":
            raise ValueError(
                "backend='kernel' models one shared log, got "
                f"client_model={self.client_model!r} (n_clients="
                f"{self.n_clients}); use backend='jax' for the "
                "per-client contention study")
        if self.trial_tile is not None and self.trial_tile < 1:
            raise ValueError(
                f"trial_tile={self.trial_tile!r} must be a positive trial"
                " count per kernel program instance (or None for the"
                " kernels-package default)")

    @property
    def n_windows(self) -> int:
        return -(-self.n_requests // self.window_size)


class TrialResult(NamedTuple):
    """Per-trial outputs (leading trial axis after vmap)."""

    server_loads: jax.Array    # (M,) final true load per server, MB
    n_assigned: jax.Array      # (M,) requests landed per server
    chosen: jax.Array          # (R,) server per request
    probe_msgs: jax.Array      # () probe messages issued
    straggler_hits: jax.Array  # () requests landed on injected stragglers
    redirected: jax.Array      # () requests redirected away from default
    init_loads: jax.Array      # (M,) initial (pre-scheduling) loads
    straggler_mask: jax.Array  # (M,) bool — load-injected OR trace-slowed
    # -- temporal extension (meaningful when cfg.scenario is set) ----------
    latencies: jax.Array       # (R,) est. completion latency per request, s
    phase_time: jax.Array      # () makespan: latest est. completion time, s
    window_loads: jax.Array    # (W, M) post-drain load snapshot per window


def sample_workload(key: jax.Array, cfg: SimConfig) -> Workload:
    """Synthetic request stream per §4's three size classes."""
    k_obj, k_cls, k_small, k_med, k_large = jax.random.split(key, 5)
    r = cfg.n_requests
    object_ids = jax.random.randint(k_obj, (r,), 0, 8 * cfg.n_servers,
                                    dtype=jnp.int32)
    small = jax.random.uniform(k_small, (r,), minval=cfg.small_lo,
                               maxval=cfg.small_hi)
    med = jax.random.uniform(k_med, (r,), minval=cfg.small_hi,
                             maxval=cfg.medium_hi)
    large = jax.random.uniform(k_large, (r,), minval=cfg.medium_hi,
                               maxval=cfg.large_hi)
    if cfg.workload == "small":
        lengths = small
    elif cfg.workload == "medium":
        lengths = med
    elif cfg.workload == "large":
        lengths = large
    else:  # mixed: uniform over the three classes
        cls = jax.random.randint(k_cls, (r,), 0, 3)
        lengths = jnp.where(cls == 0, small, jnp.where(cls == 1, med, large))
    return Workload(object_ids=object_ids, lengths=lengths.astype(jnp.float32),
                    valid=jnp.ones((r,), bool))


def mean_request_mb(cfg: SimConfig) -> float:
    """Expected request size per workload class (MB)."""
    return {
        "small": (cfg.small_lo + cfg.small_hi) / 2,
        "medium": (cfg.small_hi + cfg.medium_hi) / 2,
        "large": (cfg.medium_hi + cfg.large_hi) / 2,
        "mixed": ((cfg.small_lo + cfg.small_hi) / 2
                  + (cfg.small_hi + cfg.medium_hi) / 2
                  + (cfg.medium_hi + cfg.large_hi) / 2) / 3,
    }[cfg.workload]


def expected_server_load_mb(cfg: SimConfig) -> float:
    """Expected FINAL average per-server load from scheduling alone."""
    return cfg.n_requests * mean_request_mb(cfg) / cfg.n_servers


def initial_loads(key: jax.Array, cfg: SimConfig) -> Tuple[jax.Array, jax.Array]:
    """Normal initial loads + optional straggler injection (§4).

    Paper: stragglers carry '5 times more load compared with the average
    loads assigned on other storage servers' — i.e. the extra is scaled to
    the run's expected per-server load, not the (small) initial load.
    """
    k_norm, k_strag = jax.random.split(key)
    loads = cfg.init_load_mean + cfg.init_load_std * jax.random.normal(
        k_norm, (cfg.n_servers,))
    loads = jnp.maximum(loads, 0.0)
    n_strag = int(round(cfg.straggler_frac * cfg.n_servers))
    mask = jnp.zeros((cfg.n_servers,), bool)
    if n_strag > 0:
        idx = jax.random.choice(k_strag, cfg.n_servers, (n_strag,),
                                replace=False)
        mask = mask.at[idx].set(True)
        extra = cfg.straggler_factor * expected_server_load_mb(cfg)
        loads = loads + mask * extra
    return loads.astype(jnp.float32), mask


def absorb_initial_loads(state: SchedState, loads: jax.Array,
                         log_cfg: LogConfig) -> SchedState:
    """Fold known initial loads into the log: p_i ∝ (1/M)·e^{-l_i/λ}.

    This is the vectorized fixed point of applying Eq. (2) once per server
    for its initial load, then renormalizing — how a client that has been
    running for a while would see the cluster.
    """
    m = state.n_servers
    probs = jnp.exp(-loads / log_cfg.lam) / m
    probs = probs / jnp.sum(probs)
    return state.with_rows(loads=loads.astype(jnp.float32),
                           probs=probs.astype(jnp.float32))


def resolve_window_dt(cfg: SimConfig, scn: ScenarioConfig) -> float:
    """Static virtual seconds per window.  Auto default: the time in which
    the healthy cluster in aggregate exactly drains one window's expected
    bytes — so balanced load stays bounded while stragglers accumulate."""
    if scn.window_dt is not None:
        return float(scn.window_dt)
    if scn.name == "static":
        return 0.0
    per_window_mb = cfg.window_size * mean_request_mb(cfg)
    return per_window_mb / (cfg.n_servers * scn.base_rate_mb_s)


def make_trace(key: jax.Array, cfg: SimConfig,
               scn: ScenarioConfig) -> ClusterTrace:
    """Build the scenario's rate-event schedule (static shapes per scenario).

    Straggler identities are drawn from ``key`` so every vmapped trial sees
    a different slow subset (matching the paper's per-trial straggler
    injection).  Event times are fractions of the stream horizon
    ``n_windows * window_dt``.
    """
    m = cfg.n_servers
    base = scn.base_rate_mb_s
    horizon = max(cfg.n_windows * resolve_window_dt(cfg, scn), 1e-6)
    base_row = jnp.full((m,), base, jnp.float32)

    if scn.name == "static":
        return ClusterTrace(times=jnp.zeros((1,), jnp.float32),
                            rates=base_row[None])

    if scn.name == "correlated_rack":
        rack = min(scn.rack_size, m)
        start = jax.random.randint(key, (), 0, m - rack + 1)
        idx = jnp.arange(m)
        mask = (idx >= start) & (idx < start + rack)
    else:
        n_strag = max(int(round(scn.straggler_frac * m)), 1)
        idx = jax.random.choice(key, m, (n_strag,), replace=False)
        mask = jnp.zeros((m,), bool).at[idx].set(True)
    slow_row = jnp.where(mask, base / scn.slow_factor, base).astype(jnp.float32)

    if scn.name == "permanent_slow":
        return ClusterTrace(times=jnp.zeros((1,), jnp.float32),
                            rates=slow_row[None])
    if scn.name == "transient":
        times = jnp.asarray([0.0, scn.onset * horizon, scn.recover * horizon],
                            jnp.float32)
        return ClusterTrace(times=times,
                            rates=jnp.stack([base_row, slow_row, base_row]))
    if scn.name == "flapping":
        n_ev = max(scn.n_flaps, 2)
        times = jnp.arange(n_ev, dtype=jnp.float32) * (horizon / n_ev)
        rows = jnp.stack([base_row if e % 2 == 0 else slow_row
                          for e in range(n_ev)])
        return ClusterTrace(times=times, rates=rows)
    if scn.name == "correlated_rack":
        times = jnp.asarray([0.0, scn.onset * horizon], jnp.float32)
        return ClusterTrace(times=times, rates=jnp.stack([base_row, slow_row]))
    raise AssertionError(scn.name)


def trace_straggler_mask(trace: ClusterTrace, scn: ScenarioConfig) -> jax.Array:
    """(M,) bool: servers that are slow at any point of the trace."""
    return jnp.any(trace.rates < scn.base_rate_mb_s * (1.0 - 1e-6), axis=0)


def _trial_setup(key: jax.Array, cfg: SimConfig, log_cfg: LogConfig):
    """Per-trial simulation inputs: (init_loads, straggler_mask, work,
    state, trace) — shared verbatim by the sequential and the trial-grid
    paths so both schedule bit-identical streams."""
    k_load, k_work, k_sched = jax.random.split(key, 3)
    init, strag_mask = initial_loads(k_load, cfg)
    work = sample_workload(k_work, cfg)
    state = statlog.init_state(log_cfg)
    state = absorb_initial_loads(state, init, log_cfg)
    trace = None
    if cfg.scenario is not None:
        # fold_in keeps the 3-way split above byte-identical to the static
        # path, so the degenerate trace reproduces it bit-for-bit.
        trace = make_trace(jax.random.fold_in(key, 0x7e3), cfg, cfg.scenario)
        state = state._replace(rates=trace.rates[0])
    return init, strag_mask, work, state, trace, k_sched


def _trial_result(cfg: SimConfig, window_dt: float, init, strag_mask, work,
                  trace, chosen, probe_msgs, redirected, latencies,
                  window_loads,
                  phase_time: Optional[jax.Array] = None) -> TrialResult:
    """Fold one scheduled stream into the TrialResult bookkeeping.

    ``phase_time`` overrides the host-side makespan reduction — the
    trial-grid path passes the kernel's fused in-VMEM metric (bit-equal:
    ``max`` is order-free and grouped steps share their duplicates'
    latency)."""
    written = jax.ops.segment_sum(work.lengths, chosen,
                                  num_segments=cfg.n_servers)
    n_assigned = jax.ops.segment_sum(jnp.ones_like(chosen), chosen,
                                     num_segments=cfg.n_servers)
    if cfg.scenario is not None:
        strag_mask = strag_mask | trace_straggler_mask(trace, cfg.scenario)
    hits = jnp.sum(strag_mask[chosen])
    if phase_time is None:
        # completion estimate = window open time + queueing latency
        w_open = (jnp.arange(cfg.n_requests) // cfg.window_size) * window_dt
        completion = w_open.astype(jnp.float32) + latencies
        phase_time = jnp.max(completion)
    return TrialResult(server_loads=init + written, n_assigned=n_assigned,
                       chosen=chosen, probe_msgs=probe_msgs,
                       straggler_hits=hits,
                       redirected=jnp.sum(redirected),
                       init_loads=init, straggler_mask=strag_mask,
                       latencies=latencies,
                       phase_time=phase_time,
                       window_loads=window_loads)


def _observe(cfg: SimConfig) -> bool:
    # the degenerate static scenario must stay bit-identical to the
    # no-trace path for EVERY policy, so its completion feedback is off
    # (the static model never observes)
    return cfg.scenario is not None and cfg.scenario.name != "static"


def _run_shared_log(key: jax.Array, cfg: SimConfig, policy: PolicyConfig,
                    log_cfg: LogConfig) -> TrialResult:
    init, strag_mask, work, state, trace, k_sched = _trial_setup(key, cfg,
                                                                 log_cfg)
    window_dt = (resolve_window_dt(cfg, cfg.scenario)
                 if cfg.scenario is not None else 0.0)
    res = engine.run_stream(state, work, k_sched, policy=policy,
                            log_cfg=log_cfg, window_size=cfg.window_size,
                            group_steps=True, trace=trace,
                            window_dt=window_dt, observe=_observe(cfg),
                            backend=cfg.backend)
    return _trial_result(cfg, window_dt, init, strag_mask, work, trace,
                         res.chosen, res.probe_msgs, res.redirected,
                         res.latencies, res.window_loads)


def _run_shared_log_batch(keys: jax.Array, cfg: SimConfig,
                          policy: PolicyConfig,
                          log_cfg: LogConfig) -> TrialResult:
    """Trial-grid path (DESIGN.md §9): every trial's whole windowed stream
    scheduled by ONE pallas_call (`engine.run_stream_batch`).

    Setup and bookkeeping run under ``lax.map`` — NOT ``vmap`` — on
    purpose: mapping traces the per-trial computation at the exact
    shapes of the sequential path, so sampled workloads, absorbed
    initial tables and per-server sums are bit-identical to
    ``lax.map(_run_shared_log)`` (vmapped elementwise ops may pick
    different reduction/contraction lowerings at batched shapes; the
    heavy work — scheduling — is the batched kernel either way).  The
    per-trial makespan comes from the kernel's fused metrics row instead
    of a host-side reduction over the latency block."""
    from repro.core.policy_core import MET_MAKESPAN

    window_dt = (resolve_window_dt(cfg, cfg.scenario)
                 if cfg.scenario is not None else 0.0)
    init, strag_mask, works, states, traces, k_sched = jax.lax.map(
        lambda k: _trial_setup(k, cfg, log_cfg), keys)
    res, metrics = engine.run_stream_batch(
        states, works, k_sched, policy=policy, log_cfg=log_cfg,
        window_size=cfg.window_size, group_steps=True, traces=traces,
        window_dt=window_dt, observe=_observe(cfg),
        trial_tile=cfg.trial_tile)

    def post(xs):
        (init_i, strag_i, work_i, trace_i, chosen_i, probes_i, redir_i,
         lat_i, wl_i, mk_i) = xs
        return _trial_result(cfg, window_dt, init_i, strag_i, work_i,
                             trace_i, chosen_i, probes_i, redir_i, lat_i,
                             wl_i, phase_time=mk_i)

    return jax.lax.map(post, (init, strag_mask, works, traces, res.chosen,
                              res.probe_msgs, res.redirected, res.latencies,
                              res.window_loads,
                              metrics[:, MET_MAKESPAN]))


def _run_per_client(key: jax.Array, cfg: SimConfig, policy: PolicyConfig,
                    log_cfg: LogConfig) -> TrialResult:
    """Contention model: each client schedules its slice with a private log
    that starts from the same initial-load snapshot but never sees other
    clients' decisions.  True server loads are the cross-client sums."""
    k_load, k_work, k_sched = jax.random.split(key, 3)
    init, strag_mask = initial_loads(k_load, cfg)
    work = sample_workload(k_work, cfg)
    n_c = cfg.n_clients
    per = -(-cfg.n_requests // n_c)
    pad = n_c * per - cfg.n_requests
    win = min(cfg.window_size, per)
    trace, window_dt = None, 0.0
    if cfg.scenario is not None:
        trace = make_trace(jax.random.fold_in(key, 0x7e3), cfg, cfg.scenario)
        window_dt = resolve_window_dt(cfg, cfg.scenario)

    def pad_to(a, fill=0):
        return jnp.concatenate([a, jnp.full((pad,), fill, a.dtype)]) if pad else a

    obj = pad_to(work.object_ids).reshape(n_c, per)
    lens = pad_to(work.lengths).reshape(n_c, per)
    val = pad_to(work.valid, False).reshape(n_c, per)
    keys = jax.random.split(k_sched, n_c)

    observe = cfg.scenario is not None and cfg.scenario.name != "static"

    def one_client(o, ln, v, k):
        state = statlog.init_state(log_cfg)
        state = absorb_initial_loads(state, init, log_cfg)
        if trace is not None:
            state = state._replace(rates=trace.rates[0])
        res = engine.run_stream(state, Workload(o, ln, v), k, policy=policy,
                                log_cfg=log_cfg, window_size=win,
                                trace=trace, window_dt=window_dt,
                                observe=observe)
        return (res.chosen, res.probe_msgs, res.redirected, res.latencies,
                res.window_loads)

    chosen, probes, redirected, lat, wloads = \
        jax.vmap(one_client)(obj, lens, val, keys)
    chosen = chosen.reshape(-1)[:cfg.n_requests]
    redirected = redirected.reshape(-1)[:cfg.n_requests]
    latencies = lat.reshape(-1)[:cfg.n_requests]
    written = jax.ops.segment_sum(work.lengths, chosen,
                                  num_segments=cfg.n_servers)
    n_assigned = jax.ops.segment_sum(jnp.ones_like(chosen), chosen,
                                     num_segments=cfg.n_servers)
    if cfg.scenario is not None:
        strag_mask = strag_mask | trace_straggler_mask(trace, cfg.scenario)
    w_open = (jnp.arange(per) // win).astype(jnp.float32) * window_dt
    completion = (w_open[None, :] + lat).reshape(-1)[:cfg.n_requests]
    # Mask per-client reductions by validity: an uneven split
    # (n_requests % n_clients != 0) pads the last clients' slices — and
    # when n_clients * per > n_requests + per, whole PHANTOM clients that
    # scheduled nothing.  Averaging their untouched private logs (and
    # summing their probe rows) into the contention numbers dilutes the
    # "typical client" view, so every cross-client reduction weights by
    # clients that actually scheduled a valid request.
    client_valid = jnp.any(val, axis=1)                   # (n_clients,)
    n_real = jnp.maximum(jnp.sum(client_valid.astype(jnp.float32)), 1.0)
    wloads_mean = (jnp.sum(jnp.where(client_valid[:, None, None], wloads,
                                     0.0), axis=0) / n_real)
    probe_msgs = jnp.sum(jnp.where(client_valid, probes, 0))
    return TrialResult(server_loads=init + written, n_assigned=n_assigned,
                       chosen=chosen, probe_msgs=probe_msgs,
                       straggler_hits=jnp.sum(strag_mask[chosen]),
                       redirected=jnp.sum(redirected),
                       init_loads=init, straggler_mask=strag_mask,
                       latencies=latencies,
                       phase_time=jnp.max(completion),
                       # real clients' private views; mean = typical client
                       window_loads=wloads_mean)


@functools.partial(jax.jit, static_argnames=("cfg", "policy", "log_cfg"))
def run_trials(key: jax.Array, cfg: SimConfig, policy: PolicyConfig,
               log_cfg: LogConfig) -> TrialResult:
    """Run ``cfg.n_trials`` independent trials (vmapped + jitted).

    The kernel backend runs the WHOLE sweep as one trial-grid pallas_call
    (`engine.run_stream_batch`, grid = trial tiles, per-trial makespan
    fused in-VMEM — DESIGN.md §9); every §3.4 policy dispatches through
    it since the in-VMEM sorts of DESIGN.md §10; decisions, latencies,
    loads and phase_time are bit-exact vs. mapping the sequential kernel
    path trial by trial (asserted in tests/test_kernels.py)."""
    policies.validate_policy(policy, cfg.n_servers)
    keys = jax.random.split(key, cfg.n_trials)
    if cfg.backend == "kernel":
        return _run_shared_log_batch(keys, cfg, policy, log_cfg)
    fn = _run_shared_log if cfg.client_model == "shared_log" else _run_per_client
    return jax.vmap(lambda k: fn(k, cfg, policy, log_cfg))(keys)


def default_log_cfg(cfg: SimConfig, lam: Optional[float] = None) -> LogConfig:
    """λ on the order of the expected per-server load so Eq. (2)'s
    exponential stays in a resolvable range over the whole run
    (DESIGN.md numerical-fidelity note; λ -> 0 recovers the literal
    paper behaviour)."""
    if lam is None:
        lam = max(4.0 * mean_request_mb(cfg), expected_server_load_mb(cfg))
    return LogConfig(n_servers=cfg.n_servers, lam=lam)


def run_scenario_eval(seed: int = 0, cfg: Optional[SimConfig] = None,
                      scenario_names: Tuple[str, ...] = SCENARIOS,
                      policy_names: Tuple[str, ...] = SWEEP_POLICIES,
                      threshold: float = 5.0,
                      ect_threshold: float = 0.05,
                      scenario: Optional[ScenarioConfig] = None) -> dict:
    """Temporal sweep: {scenario: {policy: TrialResult}}, all jitted.

    ``threshold`` is in MB (load benefit) for the paper's policies; the
    rate-aware ECT guard is in expected *seconds*, hence the separate
    ``ect_threshold``.  ``scenario`` overrides the per-name defaults'
    common knobs (rates, straggler fraction, ...).
    """
    cfg = cfg or SimConfig()
    base_scn = scenario or ScenarioConfig()
    key = jax.random.key(seed)
    out: dict = {}
    for scn_name in scenario_names:
        scn_cfg = dataclasses.replace(base_scn, name=scn_name)
        s_cfg = dataclasses.replace(cfg, scenario=scn_cfg)
        log_cfg = default_log_cfg(s_cfg)
        row = {}
        for name in policy_names:
            thr = ect_threshold if name == "ect" else threshold
            pol = PolicyConfig(name=name, threshold=thr)
            row[name] = run_trials(key, s_cfg, pol, log_cfg)
        out[scn_name] = row
    return out


def run_paper_eval(seed: int = 0, cfg: Optional[SimConfig] = None,
                   policy_names: Tuple[str, ...] = ("rr", "mlml", "trh",
                                                    "nltr", "two_choice"),
                   threshold: float = 5.0,
                   nltr_ns: Tuple[int, ...] = (1, 2)) -> dict:
    """Run the full §4 evaluation; returns {label: TrialResult}."""
    cfg = cfg or SimConfig()
    log_cfg = default_log_cfg(cfg)
    key = jax.random.key(seed)
    out = {}
    for name in policy_names:
        if name == "nltr":
            for n in nltr_ns:
                pol = PolicyConfig(name="nltr", threshold=threshold, nltr_n=n)
                out[f"{n}ltr"] = run_trials(key, cfg, pol, log_cfg)
        else:
            pol = PolicyConfig(name=name, threshold=threshold)
            out[name] = run_trials(key, cfg, pol, log_cfg)
    return out
