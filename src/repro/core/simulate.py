"""Paper §4 simulation harness.

Reproduces the evaluation environment of the paper:

* 100 object storage servers, 200 compute nodes;
* 2,000 I/O requests per trial in three size classes — small (< 4 MB),
  medium (4-10 MB), large (> 10 MB, up to ~1 GB so the large-only workload
  spans O(20 GB)-O(2 TB) as in §4);
* initial OSS loads ~ Normal(mean, small sigma);
* 100 trials, reporting the average per-OSS load;
* straggler injection: 10 % of servers receive 5x the average load.

Everything dispatches through ONE batched trial runner (`_run_batched`)
per policy, jitted end to end.

Two client models are provided:

* ``shared_log``  (default, used for the paper's figures): all requests go
  through one collective statistic log — the paper's §3.2 collective-I/O
  scheduling model.
* ``per_client``  (contention study, beyond the paper's figures): requests
  are partitioned over ``n_clients`` independent logs which do NOT see each
  other's decisions; reported loads are the true per-server sums.  This
  quantifies the multi-client blind spot discussed in DESIGN.md.

Both models run on either backend: ``backend="jax"`` (vmapped lax.scan
engine) or ``backend="kernel"`` — the whole sweep as ONE pallas_call,
grid = trial tiles for shared_log (DESIGN.md §9) and trial tiles ×
client tiles for per_client (DESIGN.md §11), bit-exact across backends.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import engine, policies, policy_core, statlog
from repro.core.engine import ClusterTrace, Workload
from repro.core.policies import PolicyConfig
from repro.core.statlog import LogConfig, SchedState
from repro.tune import profile as tune_profile
from repro.tune import table as tune_table

SIZE_CLASSES = ("small", "medium", "large", "mixed")

SCENARIOS = ("static", "permanent_slow", "transient", "flapping",
             "correlated_rack")

# Canonical policy set for temporal sweeps (the paper's log-assisted
# policies + the rate-aware ECT extension); benchmarks import this so the
# ranking tables track the scenario/policy libraries automatically.
SWEEP_POLICIES = ("rr", "mlml", "trh", "nltr", "ect")


@dataclasses.dataclass(frozen=True)
class ScenarioConfig:
    """Temporal straggler scenario (DESIGN.md §Temporal-model).

    Generates a :class:`~repro.core.engine.ClusterTrace` per trial (random
    straggler identities, deterministic per trial key):

    * ``static``          — all rates equal, no events; with the default
                            ``window_dt = 0`` this is the degenerate trace
                            that reproduces the paper's static-load model
                            bit-for-bit (Fig. 18's extra-load stragglers
                            stay available via ``SimConfig.straggler_frac``).
    * ``permanent_slow``  — a random ``straggler_frac`` subset serves at
                            ``base/slow_factor`` for the whole run
                            (permanent heterogeneity, arXiv:1911.05918's
                            slow-service setting).
    * ``transient``       — same subset degrades at ``onset`` and recovers
                            at ``recover`` (fractions of the stream
                            horizon) — the IOPathTune-style runtime drift.
    * ``flapping``        — the subset alternates slow/normal ``n_flaps``
                            times over the horizon.
    * ``correlated_rack`` — a random contiguous rack of ``rack_size``
                            servers degrades at ``onset`` and stays slow
                            (correlated failure domain).
    """

    name: str = "static"
    base_rate_mb_s: float = 200.0
    slow_factor: float = 8.0
    straggler_frac: float = 0.10
    # None -> auto: the time in which the *healthy* cluster exactly drains
    # one window's bytes, so stragglers accumulate queue (static -> 0.0).
    window_dt: Optional[float] = None
    onset: float = 0.25       # fraction of horizon (transient/flapping/rack)
    recover: float = 0.65     # transient recovery point (fraction of horizon)
    n_flaps: int = 8
    rack_size: int = 8

    def __post_init__(self):
        if self.name not in SCENARIOS:
            raise ValueError(
                f"unknown scenario {self.name!r}; choose from {SCENARIOS}")


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Paper §4 simulation parameters (defaults = the paper's numbers)."""

    n_servers: int = 100
    n_clients: int = 200
    n_requests: int = 2000
    n_trials: int = 100
    workload: str = "mixed"          # small | medium | large | mixed
    window_size: int = 100           # requests per time window
    init_load_mean: float = 50.0     # MB, Normal initial loads
    init_load_std: float = 5.0       # "small standard deviation"
    straggler_frac: float = 0.0      # 0.10 for the Fig. 18 experiment
    straggler_factor: float = 5.0    # 5x average extra load on stragglers
    client_model: str = "shared_log"  # shared_log | per_client
    # temporal scenario (None = the seed's static-load model, no trace)
    scenario: Optional[ScenarioConfig] = None
    # scheduling substrate: "jax" (lax.scan engine, every policy) or
    # "kernel" (the Pallas trial-grid kernel — every §3.4 policy incl.
    # the sort-based mlml/nltr (DESIGN.md §10); ALL trials run as ONE
    # pallas_call, grid = trial tiles for shared_log (DESIGN.md §9) and
    # trial tiles x client tiles for per_client (DESIGN.md §11)).
    backend: str = "jax"
    # trials per kernel program instance (kernel backend; None = the
    # kernels package default, the native f32 sublane count 8)
    trial_tile: Optional[int] = None
    # clients per 2-D-grid program instance (per_client model; None =
    # `policy_core.DEFAULT_CLIENT_TILE`).  Also the block width of the
    # cross-client merge association (`policy_core.masked_client_sum`),
    # so it is resolved identically on the jax backend.
    client_tile: Optional[int] = None
    # tile resolution mode (DESIGN.md §16): "default" = the static
    # resolver defaults (the pre-tuner behaviour); "fused" = the
    # `policy_core.resolve_grid_tiles` multi-trial client block (deepen
    # the trial tile when the client tile is small); "tuned" = the
    # cached `repro.tune` autotuner winner for this configuration (a
    # cache miss degrades to "fused").  Whatever the mode, the pair is
    # resolved ONCE per dispatch (`repro.tune.table.resolve_sim_tiles`)
    # and threaded through every layer, so the tiles stay association
    # parameters; explicit trial_tile/client_tile settings always win.
    tiles: str = "default"
    # trial prep/post halo dispatch (DESIGN.md §14): "batched" traces
    # `_trial_setup` / `_trial_result` ONCE for the whole trial batch
    # (vmap) — bit-identical to the sequential shapes because the
    # shape-sensitive reductions inside go through pinned association
    # primitives (`policy_core.absorb_probs` / `server_segment_sum`);
    # "sequential" is the lax.map escape hatch, the per-trial-shape
    # parity oracle the batched path is asserted against.
    prep: str = "batched"
    # device mesh of the sharded sweep dispatch (parallel/sweep.py,
    # DESIGN.md §12): None = single-device; ``(t_dev,)`` shards the
    # trial axis over t_dev devices; ``(t_dev, c_dev)`` also shards the
    # per_client client axis, lifting the cross-client merges to
    # psum_tree/pmax collectives.  The shape's product must divide
    # `jax.device_count()` (checked at dispatch by
    # `launch.mesh.make_sweep_mesh`, which names the device count).
    mesh_shape: Optional[Tuple[int, ...]] = None
    # size-class boundaries (MB) per §4
    small_lo: float = 0.25
    small_hi: float = 4.0
    medium_hi: float = 10.0
    large_hi: float = 1024.0

    def __post_init__(self):
        # real exceptions, not asserts: `python -O` strips asserts, and a
        # mis-built sweep config must fail loudly either way.
        if self.workload not in SIZE_CLASSES:
            raise ValueError(
                f"workload={self.workload!r} is not one of {SIZE_CLASSES}")
        if self.client_model not in ("shared_log", "per_client"):
            raise ValueError(
                f"client_model={self.client_model!r} must be 'shared_log' "
                "or 'per_client'")
        if self.backend not in ("jax", "kernel"):
            raise ValueError(
                f"backend={self.backend!r} must be 'jax' or 'kernel'")
        if self.prep not in ("batched", "sequential"):
            raise ValueError(
                f"prep={self.prep!r} must be 'batched' (vmapped trial "
                "prep/post, DESIGN.md §14) or 'sequential' (the lax.map "
                "parity oracle)")
        if self.n_clients < 1:
            raise ValueError(
                f"n_clients={self.n_clients!r} must be >= 1 (the "
                "per_client contention model partitions n_requests="
                f"{self.n_requests} over the clients)")
        if self.trial_tile is not None and self.trial_tile < 1:
            raise ValueError(
                f"trial_tile={self.trial_tile!r} must be a positive trial"
                " count per kernel program instance (or None for the"
                " kernels-package default)")
        if self.client_tile is not None and self.client_tile < 1:
            raise ValueError(
                f"client_tile={self.client_tile!r} must be a positive"
                " client count per 2-D-grid program instance (or None for"
                f" the policy_core default; n_clients={self.n_clients})")
        if self.tiles not in tune_table.TILE_MODES:
            raise ValueError(
                f"tiles={self.tiles!r} must be one of "
                f"{tune_table.TILE_MODES} (DESIGN.md §16)")
        if self.mesh_shape is not None:
            try:
                ms = tuple(int(s) for s in self.mesh_shape)
            except (TypeError, ValueError):
                raise ValueError(
                    f"mesh_shape={self.mesh_shape!r} must be a tuple of "
                    "1 or 2 positive device counts ((trials,) or "
                    "(trials, clients)), or None for the single-device "
                    "dispatch") from None
            # normalize (lists hash differently; jit statics need a tuple)
            object.__setattr__(self, "mesh_shape", ms)
            if len(ms) not in (1, 2) or any(s < 1 for s in ms):
                raise ValueError(
                    f"mesh_shape={ms!r} must be (trials,) or "
                    "(trials, clients) positive device counts (or None "
                    "for the single-device dispatch)")
            if len(ms) == 2 and ms[1] > 1 \
                    and self.client_model != "per_client":
                raise ValueError(
                    f"mesh_shape={ms} shards a client axis but "
                    f"client_model={self.client_model!r} has none — use "
                    "client_model='per_client' or a (trials,) mesh")

    @property
    def n_windows(self) -> int:
        return -(-self.n_requests // self.window_size)


class TrialResult(NamedTuple):
    """Per-trial outputs (leading trial axis after vmap)."""

    server_loads: jax.Array    # (M,) final true load per server, MB
    n_assigned: jax.Array      # (M,) requests landed per server
    chosen: jax.Array          # (R,) server per request
    probe_msgs: jax.Array      # () probe messages issued
    straggler_hits: jax.Array  # () requests landed on injected stragglers
    redirected: jax.Array      # () requests redirected away from default
    init_loads: jax.Array      # (M,) initial (pre-scheduling) loads
    straggler_mask: jax.Array  # (M,) bool — load-injected OR trace-slowed
    # -- temporal extension (meaningful when cfg.scenario is set) ----------
    latencies: jax.Array       # (R,) est. completion latency per request, s
    phase_time: jax.Array      # () makespan: latest est. completion time, s
    window_loads: jax.Array    # (W, M) post-drain load snapshot per window
    #   (per_client: masked client-MEAN of the real clients' private
    #   views — the typical client, phantom padded clients excluded)
    window_size_eff: jax.Array  # () int32 EFFECTIVE per-stream window size:
    #   cfg.window_size, except under per_client where it clamps to the
    #   per-client slice length min(window_size, ceil(R / n_clients)) —
    #   recorded (and warned about at dispatch) so window-size sweeps
    #   across client counts can detect they compared different windows


def sample_workload(key: jax.Array, cfg: SimConfig) -> Workload:
    """Synthetic request stream per §4's three size classes."""
    k_obj, k_cls, k_small, k_med, k_large = jax.random.split(key, 5)
    r = cfg.n_requests
    object_ids = jax.random.randint(k_obj, (r,), 0, 8 * cfg.n_servers,
                                    dtype=jnp.int32)
    small = jax.random.uniform(k_small, (r,), minval=cfg.small_lo,
                               maxval=cfg.small_hi)
    med = jax.random.uniform(k_med, (r,), minval=cfg.small_hi,
                             maxval=cfg.medium_hi)
    large = jax.random.uniform(k_large, (r,), minval=cfg.medium_hi,
                               maxval=cfg.large_hi)
    if cfg.workload == "small":
        lengths = small
    elif cfg.workload == "medium":
        lengths = med
    elif cfg.workload == "large":
        lengths = large
    else:  # mixed: uniform over the three classes
        cls = jax.random.randint(k_cls, (r,), 0, 3)
        lengths = jnp.where(cls == 0, small, jnp.where(cls == 1, med, large))
    return Workload(object_ids=object_ids, lengths=lengths.astype(jnp.float32),
                    valid=jnp.ones((r,), bool))


def mean_request_mb(cfg: SimConfig) -> float:
    """Expected request size per workload class (MB)."""
    return {
        "small": (cfg.small_lo + cfg.small_hi) / 2,
        "medium": (cfg.small_hi + cfg.medium_hi) / 2,
        "large": (cfg.medium_hi + cfg.large_hi) / 2,
        "mixed": ((cfg.small_lo + cfg.small_hi) / 2
                  + (cfg.small_hi + cfg.medium_hi) / 2
                  + (cfg.medium_hi + cfg.large_hi) / 2) / 3,
    }[cfg.workload]


def expected_server_load_mb(cfg: SimConfig) -> float:
    """Expected FINAL average per-server load from scheduling alone."""
    return cfg.n_requests * mean_request_mb(cfg) / cfg.n_servers


def initial_loads(key: jax.Array, cfg: SimConfig) -> Tuple[jax.Array, jax.Array]:
    """Normal initial loads + optional straggler injection (§4).

    Paper: stragglers carry '5 times more load compared with the average
    loads assigned on other storage servers' — i.e. the extra is scaled to
    the run's expected per-server load, not the (small) initial load.
    """
    k_norm, k_strag = jax.random.split(key)
    # FMA guard (DESIGN.md §9, the `window_decrements` clamp idiom):
    # a multiply DIRECTLY feeding the add may contract to an FMA, and
    # whether it does was observed to depend on the lowering context —
    # the vmapped (T,)-batched prep of §14 fused where the per-trial
    # lax.map shape did not, a 1-ulp drift breaking the batched ==
    # sequential prep contract.  min(max(x, -big), big) is a bit-exact
    # identity on any f32 the normal can produce, but the add's operand
    # is now a clamp, not a multiply, so no backend contracts it.
    big = jnp.float32(3.4e38)
    noise = cfg.init_load_std * jax.random.normal(k_norm, (cfg.n_servers,))
    loads = cfg.init_load_mean + jnp.minimum(jnp.maximum(noise, -big), big)
    loads = jnp.maximum(loads, 0.0)
    n_strag = int(round(cfg.straggler_frac * cfg.n_servers))
    mask = jnp.zeros((cfg.n_servers,), bool)
    if n_strag > 0:
        idx = jax.random.choice(k_strag, cfg.n_servers, (n_strag,),
                                replace=False)
        mask = mask.at[idx].set(True)
        extra = cfg.straggler_factor * expected_server_load_mb(cfg)
        # same guard: the injected extra is nonnegative by construction
        loads = loads + jnp.maximum(mask * extra, 0.0)
    return loads.astype(jnp.float32), mask


def absorb_initial_loads(state: SchedState, loads: jax.Array,
                         log_cfg: LogConfig) -> SchedState:
    """Fold known initial loads into the log: p_i ∝ (1/M)·e^{-l_i/λ}.

    This is the vectorized fixed point of applying Eq. (2) once per server
    for its initial load, then renormalizing — how a client that has been
    running for a while would see the cluster.  The math lives in
    `policy_core.absorb_probs`, whose `lane_sum` normalizer makes the
    batched (T, M) prep of DESIGN.md §14 associate bit-identically to
    this per-trial shape.
    """
    probs = policy_core.absorb_probs(loads, log_cfg.lam, state.n_servers)
    return state.with_rows(loads=loads.astype(jnp.float32),
                           probs=probs.astype(jnp.float32))


def resolve_window_dt(cfg: SimConfig, scn: ScenarioConfig) -> float:
    """Static virtual seconds per window.  Auto default: the time in which
    the healthy cluster in aggregate exactly drains one window's expected
    bytes — so balanced load stays bounded while stragglers accumulate."""
    if scn.window_dt is not None:
        return float(scn.window_dt)
    if scn.name == "static":
        return 0.0
    per_window_mb = cfg.window_size * mean_request_mb(cfg)
    return per_window_mb / (cfg.n_servers * scn.base_rate_mb_s)


def make_trace(key: jax.Array, cfg: SimConfig,
               scn: ScenarioConfig) -> ClusterTrace:
    """Build the scenario's rate-event schedule (static shapes per scenario).

    Straggler identities are drawn from ``key`` so every vmapped trial sees
    a different slow subset (matching the paper's per-trial straggler
    injection).  Event times are fractions of the stream horizon
    ``n_windows * window_dt``.
    """
    m = cfg.n_servers
    base = scn.base_rate_mb_s
    horizon = max(cfg.n_windows * resolve_window_dt(cfg, scn), 1e-6)
    base_row = jnp.full((m,), base, jnp.float32)

    if scn.name == "static":
        return ClusterTrace(times=jnp.zeros((1,), jnp.float32),
                            rates=base_row[None])

    if scn.name == "correlated_rack":
        rack = min(scn.rack_size, m)
        start = jax.random.randint(key, (), 0, m - rack + 1)
        idx = jnp.arange(m)
        mask = (idx >= start) & (idx < start + rack)
    else:
        n_strag = max(int(round(scn.straggler_frac * m)), 1)
        idx = jax.random.choice(key, m, (n_strag,), replace=False)
        mask = jnp.zeros((m,), bool).at[idx].set(True)
    slow_row = jnp.where(mask, base / scn.slow_factor, base).astype(jnp.float32)

    if scn.name == "permanent_slow":
        return ClusterTrace(times=jnp.zeros((1,), jnp.float32),
                            rates=slow_row[None])
    if scn.name == "transient":
        times = jnp.asarray([0.0, scn.onset * horizon, scn.recover * horizon],
                            jnp.float32)
        return ClusterTrace(times=times,
                            rates=jnp.stack([base_row, slow_row, base_row]))
    if scn.name == "flapping":
        n_ev = max(scn.n_flaps, 2)
        times = jnp.arange(n_ev, dtype=jnp.float32) * (horizon / n_ev)
        rows = jnp.stack([base_row if e % 2 == 0 else slow_row
                          for e in range(n_ev)])
        return ClusterTrace(times=times, rates=rows)
    if scn.name == "correlated_rack":
        times = jnp.asarray([0.0, scn.onset * horizon], jnp.float32)
        return ClusterTrace(times=times, rates=jnp.stack([base_row, slow_row]))
    raise AssertionError(scn.name)


def trace_straggler_mask(trace: ClusterTrace, scn: ScenarioConfig) -> jax.Array:
    """(M,) bool: servers that are slow at any point of the trace."""
    return jnp.any(trace.rates < scn.base_rate_mb_s * (1.0 - 1e-6), axis=0)


def _trial_setup(key: jax.Array, cfg: SimConfig, log_cfg: LogConfig):
    """Per-trial simulation inputs: (init_loads, straggler_mask, work,
    state, trace) — shared verbatim by the sequential and the trial-grid
    paths so both schedule bit-identical streams."""
    k_load, k_work, k_sched = jax.random.split(key, 3)
    init, strag_mask = initial_loads(k_load, cfg)
    work = sample_workload(k_work, cfg)
    state = statlog.init_state(log_cfg)
    state = absorb_initial_loads(state, init, log_cfg)
    trace = None
    if cfg.scenario is not None:
        # fold_in keeps the 3-way split above byte-identical to the static
        # path, so the degenerate trace reproduces it bit-for-bit.
        trace = make_trace(jax.random.fold_in(key, 0x7e3), cfg, cfg.scenario)
        state = state._replace(rates=trace.rates[0])
    return init, strag_mask, work, state, trace, k_sched


def _trial_result(cfg: SimConfig, window_dt: float, init, strag_mask, work,
                  trace, chosen, probe_msgs, redirected, latencies,
                  window_loads,
                  phase_time: Optional[jax.Array] = None,
                  window_size_eff: Optional[int] = None) -> TrialResult:
    """Fold one scheduled stream into the TrialResult bookkeeping — the
    ONE post step every client-model x backend combination shares.

    ``phase_time`` overrides the host-side makespan reduction — the
    kernel paths pass the fused in-VMEM metric (bit-equal: ``max`` is
    order-free and grouped steps share their duplicates' latency), the
    per_client jax path the masked cross-client max.

    The f32 per-server sum goes through `policy_core.server_segment_sum`
    (pinned one-hot + tree_sum association, DESIGN.md §14) so the
    batched (T, R) post and this per-trial shape produce bit-identical
    loads; the integer request count keeps the backend ``segment_sum``
    (integer adds are exact under any association)."""
    written = policy_core.server_segment_sum(work.lengths, chosen,
                                             cfg.n_servers)
    n_assigned = jax.ops.segment_sum(jnp.ones_like(chosen), chosen,
                                     num_segments=cfg.n_servers)
    if cfg.scenario is not None:
        strag_mask = strag_mask | trace_straggler_mask(trace, cfg.scenario)
    # integer sum: hit counts are backend-invariant under any association
    hits = jnp.sum(strag_mask[chosen].astype(jnp.int32))
    if phase_time is None:
        # completion estimate = window open time + queueing latency.
        # max(·, 0) is the §9 FMA guard (a window open time is
        # nonnegative by construction): the add's operand must not be a
        # multiply, or the batched §14 post contracts it where the
        # sequential shape does not.
        w_open = jnp.maximum(
            (jnp.arange(cfg.n_requests) // cfg.window_size).astype(
                jnp.float32) * jnp.float32(window_dt), 0.0)
        completion = w_open + latencies
        phase_time = jnp.max(completion)
    if window_size_eff is None:
        window_size_eff = cfg.window_size
    return TrialResult(server_loads=init + written, n_assigned=n_assigned,
                       chosen=chosen, probe_msgs=probe_msgs,
                       straggler_hits=hits,
                       redirected=jnp.sum(redirected.astype(jnp.int32)),
                       init_loads=init, straggler_mask=strag_mask,
                       latencies=latencies,
                       phase_time=phase_time,
                       window_loads=window_loads,
                       window_size_eff=jnp.int32(window_size_eff))


def _observe(cfg: SimConfig) -> bool:
    # the degenerate static scenario must stay bit-identical to the
    # no-trace path for EVERY policy, so its completion feedback is off
    # (the static model never observes)
    return cfg.scenario is not None and cfg.scenario.name != "static"


def run_one_trial(key: jax.Array, cfg: SimConfig, policy: PolicyConfig,
                  log_cfg: LogConfig) -> TrialResult:
    """Sequential single-trial REFERENCE: `_trial_setup` + ONE
    `engine.run_stream` + `_trial_result`, all at unbatched shapes.

    `run_trials` never calls this — every client_model x backend combo
    dispatches through `_run_batched` — it is the comparator that parity
    tests and benchmarks ``lax.map`` over to prove the batched dispatch
    is bit-exact trial by trial (with ``cfg.backend == "kernel"`` it is
    the SEQUENTIAL kernel path, one pallas_call per trial).  shared_log
    only: the per_client reference is the same engine vmapped over
    client slices, i.e. ``_run_batched`` on the jax backend."""
    if cfg.client_model != "shared_log":
        raise ValueError(
            "run_one_trial is the shared_log sequential reference; got "
            f"client_model={cfg.client_model!r} (use backend='jax' "
            "run_trials as the per_client comparator)")
    init, strag_mask, work, state, trace, k_sched = _trial_setup(key, cfg,
                                                                 log_cfg)
    window_dt = (resolve_window_dt(cfg, cfg.scenario)
                 if cfg.scenario is not None else 0.0)
    res = engine.run_stream(state, work, k_sched, policy=policy,
                            log_cfg=log_cfg, window_size=cfg.window_size,
                            group_steps=True, trace=trace,
                            window_dt=window_dt, observe=_observe(cfg),
                            backend=cfg.backend)
    return _trial_result(cfg, window_dt, init, strag_mask, work, trace,
                         res.chosen, res.probe_msgs, res.redirected,
                         res.latencies, res.window_loads)


def _client_split_shape(cfg: SimConfig) -> Tuple[int, int, int, int]:
    """(n_clients, per-client slice length, tail padding, effective
    window size) of the per_client request partition."""
    c = cfg.n_clients
    per = -(-cfg.n_requests // c)
    pad = c * per - cfg.n_requests
    win = min(cfg.window_size, per)
    return c, per, pad, win


def _split_clients(works: Workload, c: int, per: int, pad: int) -> Workload:
    """Partition (T, R) request streams into (T, C, per) client slices
    (tail padding invalid; trailing clients may be whole PHANTOMS that
    scheduled nothing when n_clients > n_requests)."""
    def sp(a, fill):
        if pad:
            a = jnp.concatenate(
                [a, jnp.full(a.shape[:-1] + (pad,), fill, a.dtype)],
                axis=-1)
        return a.reshape(a.shape[:-1] + (c, per))

    return Workload(object_ids=sp(works.object_ids, 0),
                    lengths=sp(works.lengths, 0),
                    valid=sp(works.valid, False))


def _resolved_window_dt(cfg: SimConfig) -> float:
    return (resolve_window_dt(cfg, cfg.scenario)
            if cfg.scenario is not None else 0.0)


def _prep_trials(keys: jax.Array, cfg: SimConfig, log_cfg: LogConfig):
    """Stage 1 of the batched pipeline (DESIGN.md §14): per-trial
    simulation inputs for the whole (T,) key batch in ONE traced
    program.

    ``cfg.prep == "batched"`` vmaps `_trial_setup`; the shape-sensitive
    reduction inside (the Eq. (2) absorb normalizer) goes through
    `policy_core.absorb_probs`, whose `lane_sum` halving tree is
    batch-shape-invariant, so the vmapped tables are bit-identical to
    ``"sequential"`` — the ``lax.map`` escape hatch that traces each
    trial at the exact per-trial shapes of `run_one_trial` (the parity
    oracle, asserted in tests/test_simulate.py)."""
    one = lambda k: _trial_setup(k, cfg, log_cfg)  # noqa: E731
    if cfg.prep == "sequential":
        return jax.lax.map(one, keys)
    out = jax.vmap(one)(keys)
    # fusion fence (DESIGN.md §14): without it XLA fuses downstream
    # scheduling ops INTO the vmapped setup graph, and the changed
    # fusion context was observed to alter the codegen of the setup's
    # transcendentals (the absorb exp / the normal's erfinv) by 1 ulp
    # vs the sequential oracle — whose scan loop boundary is an
    # implicit fence.  The barrier makes the batched stage the same
    # isolated compilation unit the scan body is.
    return jax.lax.optimization_barrier(out)


def _sched_trials(cfg: SimConfig, policy: PolicyConfig, log_cfg: LogConfig,
                  works: Workload, states, k_sched: jax.Array, traces):
    """Stage 2 of the batched pipeline: the scheduling dispatch + the
    cross-client fold, (T,)-batched throughout.

    ONE pallas_call for the kernel backend (trial grid, or the 2-D
    trials x clients grid under per_client), the vmapped lax.scan
    engine for the jax backend, the shard_map'd sweep when
    ``cfg.mesh_shape`` is set.

    per_client (the contention model): each trial's request stream is
    partitioned over ``n_clients`` private logs that share the trial's
    initial-load snapshot and trace but never see each other's
    decisions; the per-stream window size CLAMPS to the slice length
    (``window_size_eff`` in the result, warned about at dispatch), and
    every cross-client aggregate — window_loads mean, probe sum, phase
    makespan — masks phantom clients and merges with the
    `policy_core.masked_client_sum` association, so the kernel's
    in-VMEM merge is bit-identical to the jax path's.

    Returns ``(chosen, probes, redirected, latencies, wl, phase)`` in
    original request order; ``phase`` is None when no fused/folded
    makespan exists (shared_log jax) and `_post_trials` reduces it
    host-side."""
    per_client = cfg.client_model == "per_client"
    window_dt = _resolved_window_dt(cfg)
    observe = _observe(cfg)
    t = k_sched.shape[0]

    if per_client:
        c, per, pad, win = _client_split_shape(cfg)
        if win < cfg.window_size:
            warnings.warn(
                f"per_client window clamp: window_size={cfg.window_size} "
                f"exceeds the per-client slice (n_requests="
                f"{cfg.n_requests} over n_clients={c} -> {per}/client); "
                f"scheduling with window_size_eff={win} — sweeps "
                "comparing window sizes across client counts are "
                "comparing different windows", stacklevel=2)
        run_works = _split_clients(works, c, per, pad)
        run_keys = jax.vmap(lambda k: jax.random.split(k, c))(k_sched)
        run_states = jax.tree.map(
            lambda a: jnp.broadcast_to(a[:, None], (t, c) + a.shape[1:]),
            states)
    else:
        win = cfg.window_size
        run_works, run_keys, run_states = works, k_sched, states

    # THE tuned-tile resolution point (DESIGN.md §16): resolve the
    # (trial_tile, client_tile) pair ONCE — whichever mode cfg.tiles
    # selects — and thread the explicit ints through every dispatch
    # below (sweep, kernel grid, and the jax path's cross-client fold),
    # so all layers consume identical tiles and the association contract
    # holds no matter where the values came from.
    n_dev = 1
    if cfg.mesh_shape is not None:
        for s in cfg.mesh_shape:
            n_dev *= int(s)
    eff_tt, eff_ct = tune_table.resolve_sim_tiles(
        mode=cfg.tiles, policy=policy.name, backend=cfg.backend,
        n_servers=cfg.n_servers, n_requests=cfg.n_requests,
        n_clients=(c if per_client else 1), n_trials=t,
        window_size=cfg.window_size, device_count=n_dev,
        form=("grid" if per_client else "batch"),
        trial_tile=cfg.trial_tile, client_tile=cfg.client_tile)

    metrics = merged = smerge = None
    if cfg.mesh_shape is not None:
        # sharded sweep: the same dispatch wrapped in shard_map over the
        # sweep mesh, cross-client merges lifted to collectives
        # (parallel/sweep.py, DESIGN.md §12)
        from repro.parallel import sweep
        res, metrics, smerge = sweep.run_sweep(
            run_states, run_works, run_keys, mesh_shape=cfg.mesh_shape,
            policy=policy, log_cfg=log_cfg, window_size=win,
            backend=cfg.backend, group_steps=True, traces=traces,
            window_dt=window_dt, observe=observe,
            trial_tile=eff_tt, client_tile=eff_ct)
    elif cfg.backend == "kernel":
        res, metrics, merged = engine.run_stream_batch(
            run_states, run_works, run_keys, policy=policy,
            log_cfg=log_cfg, window_size=win, group_steps=True,
            traces=traces, window_dt=window_dt, observe=observe,
            trial_tile=eff_tt, client_tile=eff_ct)
    else:
        res, _, _ = engine.run_stream_batch(
            run_states, run_works, run_keys, policy=policy,
            log_cfg=log_cfg, window_size=win, group_steps=True,
            traces=traces, window_dt=window_dt, observe=observe,
            backend="jax")

    if per_client:
        # cross-client fold: true loads are the cross-client sums (the
        # request order is the original stream), the contention
        # aggregates the masked merges over REAL clients
        r = cfg.n_requests
        ct = eff_ct          # the resolved association width (see above)
        cvalid = jnp.any(run_works.valid, axis=-1)           # (T, C)
        chosen = res.chosen.reshape(t, c * per)[:, :r]
        redirected = res.redirected.reshape(t, c * per)[:, :r]
        latencies = res.latencies.reshape(t, c * per)[:, :r]
        probes = jnp.sum(jnp.where(cvalid, res.probe_msgs, 0),
                         axis=-1).astype(jnp.int32)
        if smerge is not None:
            # the sharded sweep's collective merge (parallel/sweep.py):
            # already the global mean/max/sum rows, uniform across
            # backends
            wl = smerge.window_loads_mean
            phase = smerge.phase_time
            probes = smerge.probe_msgs
        elif merged is not None:
            # the 2-D grid kernel's in-VMEM merge (bit-identical to the
            # jax branch below — asserted in tests/test_simulate.py)
            wl = merged.window_loads_mean
            phase = merged.metrics[:, policy_core.MET_MAKESPAN]
        else:
            wl = jax.vmap(
                lambda w, v: policy_core.masked_client_mean(w, v, ct)
            )(res.window_loads, cvalid)
            w_open = ((jnp.arange(per) // win).astype(jnp.float32)
                      * jnp.float32(window_dt))
            comp = jnp.where(run_works.valid,
                             w_open[None, None, :] + res.latencies, 0.0)
            phase = jnp.max(comp, axis=(1, 2))
    else:
        chosen, redirected = res.chosen, res.redirected
        latencies, probes, wl = res.latencies, res.probe_msgs, \
            res.window_loads
        phase = (metrics[:, policy_core.MET_MAKESPAN]
                 if metrics is not None else None)
    return chosen, probes, redirected, latencies, wl, phase


def _post_trials(cfg: SimConfig, init, strag_mask, works: Workload, traces,
                 chosen, probes, redirected, latencies, wl,
                 phase) -> TrialResult:
    """Stage 3 of the batched pipeline: the whole (T,) TrialResult stack
    from one traced `_trial_result` program.

    ``cfg.prep == "batched"`` vmaps it; every op inside is exact under
    batching — gathers, bool masks, integer segment sums, order-free
    maxes — except the f32 per-server load sum, which goes through the
    pinned `policy_core.server_segment_sum` association, so the stack is
    bit-identical to the ``"sequential"`` ``lax.map`` oracle."""
    window_dt = _resolved_window_dt(cfg)
    win = (_client_split_shape(cfg)[3]
           if cfg.client_model == "per_client" else cfg.window_size)
    xs = (init, strag_mask, works, traces, chosen, probes, redirected,
          latencies, wl)
    if phase is not None:
        one = lambda x: _trial_result(  # noqa: E731
            cfg, window_dt, *x[:-1], phase_time=x[-1], window_size_eff=win)
        xs = xs + (phase,)
    else:
        one = lambda x: _trial_result(  # noqa: E731
            cfg, window_dt, *x, window_size_eff=win)
    if cfg.prep == "sequential":
        return jax.lax.map(one, xs)
    # fusion fence on the INPUT side (same §14 story as `_prep_trials`):
    # keeps the scheduling stage's producers from fusing into the
    # vmapped bookkeeping graph, matching the sequential oracle's scan
    # loop boundary.
    return jax.vmap(one)(jax.lax.optimization_barrier(xs))


def _run_batched(keys: jax.Array, cfg: SimConfig, policy: PolicyConfig,
                 log_cfg: LogConfig) -> TrialResult:
    """THE trial runner: one batched dispatch for every client_model x
    backend combination (DESIGN.md §9/§11), composed from the three
    (T,)-batched pipeline stages (DESIGN.md §14) — `_prep_trials`
    (workloads / initial loads / absorbed tables / traces),
    `_sched_trials` (the scheduling dispatch + cross-client fold) and
    `_post_trials` (the TrialResult bookkeeping stack).  Each stage is
    independently jittable with ``cfg``/``policy``/``log_cfg`` static,
    which is how `benchmarks/sched_perf.py` times the prep/sched/post
    phase breakdown.

    The `repro.tune.profile.stage` wrappers are inert unless a
    ``profile.collect()`` block is active (an eager profiling run);
    under normal jitted dispatch they cost nothing and record nothing
    (timing a traced stage would measure tracing, DESIGN.md §16)."""
    with tune_profile.stage("prep"):
        prep = _prep_trials(keys, cfg, log_cfg)
    init, strag_mask, works, states, traces, k_sched = prep
    with tune_profile.stage("sched"):
        sched = _sched_trials(cfg, policy, log_cfg, works, states, k_sched,
                              traces)
    with tune_profile.stage("post"):
        return _post_trials(cfg, init, strag_mask, works, traces, *sched)


@functools.partial(jax.jit, static_argnames=("cfg", "policy", "log_cfg"))
def run_trials(key: jax.Array, cfg: SimConfig, policy: PolicyConfig,
               log_cfg: LogConfig) -> TrialResult:
    """Run ``cfg.n_trials`` independent trials (one batched dispatch,
    jitted).

    Every client_model x backend combination goes through the SAME
    `_run_batched` runner.  The kernel backend runs the WHOLE sweep as
    one pallas_call — grid = trial tiles for shared_log (DESIGN.md §9),
    ``(trial tiles, client tiles)`` for the per_client contention model
    (DESIGN.md §11) — with per-trial makespan (and, under per_client,
    the cross-client merges) fused in-VMEM; every §3.4 policy dispatches
    through it since the in-VMEM sorts of DESIGN.md §10.  Decisions,
    latencies, loads, window_loads and phase_time are bit-exact vs.
    mapping the sequential kernel path trial by trial AND vs. the
    vmapped jax engine (asserted in tests/test_kernels.py and
    tests/test_simulate.py)."""
    policies.validate_policy(policy, cfg.n_servers)
    keys = jax.random.split(key, cfg.n_trials)
    return _run_batched(keys, cfg, policy, log_cfg)


def default_log_cfg(cfg: SimConfig, lam: Optional[float] = None) -> LogConfig:
    """λ on the order of the expected per-server load so Eq. (2)'s
    exponential stays in a resolvable range over the whole run
    (DESIGN.md numerical-fidelity note; λ -> 0 recovers the literal
    paper behaviour)."""
    if lam is None:
        lam = max(4.0 * mean_request_mb(cfg), expected_server_load_mb(cfg))
    return LogConfig(n_servers=cfg.n_servers, lam=lam)


def run_scenario_eval(seed: int = 0, cfg: Optional[SimConfig] = None,
                      scenario_names: Tuple[str, ...] = SCENARIOS,
                      policy_names: Tuple[str, ...] = SWEEP_POLICIES,
                      threshold: float = 5.0,
                      ect_threshold: float = 0.05,
                      scenario: Optional[ScenarioConfig] = None) -> dict:
    """Temporal sweep: {scenario: {policy: TrialResult}}, all jitted.

    ``threshold`` is in MB (load benefit) for the paper's policies; the
    rate-aware ECT guard is in expected *seconds*, hence the separate
    ``ect_threshold``.  ``scenario`` overrides the per-name defaults'
    common knobs (rates, straggler fraction, ...).
    """
    cfg = cfg or SimConfig()
    base_scn = scenario or ScenarioConfig()
    key = jax.random.key(seed)
    out: dict = {}
    for scn_name in scenario_names:
        scn_cfg = dataclasses.replace(base_scn, name=scn_name)
        s_cfg = dataclasses.replace(cfg, scenario=scn_cfg)
        log_cfg = default_log_cfg(s_cfg)
        row = {}
        for name in policy_names:
            thr = ect_threshold if name == "ect" else threshold
            pol = PolicyConfig(name=name, threshold=thr)
            row[name] = run_trials(key, s_cfg, pol, log_cfg)
        out[scn_name] = row
    return out


def run_paper_eval(seed: int = 0, cfg: Optional[SimConfig] = None,
                   policy_names: Tuple[str, ...] = ("rr", "mlml", "trh",
                                                    "nltr", "two_choice"),
                   threshold: float = 5.0,
                   nltr_ns: Tuple[int, ...] = (1, 2)) -> dict:
    """Run the full §4 evaluation; returns {label: TrialResult}."""
    cfg = cfg or SimConfig()
    log_cfg = default_log_cfg(cfg)
    key = jax.random.key(seed)
    out = {}
    for name in policy_names:
        if name == "nltr":
            for n in nltr_ns:
                pol = PolicyConfig(name="nltr", threshold=threshold, nltr_n=n)
                out[f"{n}ltr"] = run_trials(key, cfg, pol, log_cfg)
        else:
            pol = PolicyConfig(name=name, threshold=threshold)
            out[name] = run_trials(key, cfg, pol, log_cfg)
    return out
