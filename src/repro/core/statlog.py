"""Client-side server statistic log — the paper's core data structure.

Implements the two tables of Fig. 8 and the maintenance equations:

    Eq. (1)  l_i  <- l'_i + Len                      (load bookkeeping)
    Eq. (2)  p_i  <- p'_i * exp(-l_i / lam)          (probability decay)
    Eq. (3)  p_j  <- p'_j + (p'_i - p'_i e^{-l_i}) / (M-1),  j != i

``lam`` is the load-normalization scale (see DESIGN.md "numerical
fidelity"): the paper's literal Eq. (2) uses raw byte counts in the
exponent, which underflows after a single multi-MB assignment.  ``lam``
defaults to a scale on the order of the mean request size; ``lam -> 0+``
recovers the paper's literal greedy behaviour.

Two implementations share these formulas:

* a pure-JAX functional form (``SchedState`` + ``apply_assignment``) used
  by the jitted scheduling engine / simulator, and
* ``HostStatLog``, a mutable numpy twin used on the request hot path of
  the real I/O client (``repro.io.client``), cross-validated in tests.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class SchedState(NamedTuple):
    """Server statistic table (jnp arrays, one row per OSS).

    The temporal extension (DESIGN.md §Temporal-model) adds per-server
    service *rates* and virtual completion-time clocks so the jitted
    engine can drain queues between time windows and feed completion
    observations back into ``ewma_lat`` (making slow — not merely loaded
    — servers visible to the ECT policy in the JAX path).  With
    ``rates == 1`` and ``advance_time`` never called, the state degrades
    exactly to the paper's static-load model.
    """

    loads: jax.Array        # (M,) expected outstanding bytes (MB) per server
    probs: jax.Array        # (M,) selection probability, sums to 1
    n_assigned: jax.Array   # (M,) int32 — requests scheduled per server
    ewma_lat: jax.Array     # (M,) observed MB/s EWMA (ECT extension; 0 = unseen)
    rates: jax.Array        # (M,) current true service rate, MB per virtual s
    vclock: jax.Array       # ()  virtual time since stream start, seconds
    free_at: jax.Array      # (M,) virtual completion-time clock: when each
    #                          server's outstanding queue drains (vclock
    #                          units).  Derived state for introspection /
    #                          metrics: refreshed ONLY by advance_time (it
    #                          is stale between drains); no policy reads it.

    @property
    def n_servers(self) -> int:
        return self.loads.shape[-1]


@dataclasses.dataclass(frozen=True)
class LogConfig:
    """Static knobs of the statistic log."""

    n_servers: int
    lam: float = 32.0          # Eq.(2) normalization scale, in MB
    ewma_alpha: float = 0.25   # ECT extension only
    renorm: bool = True        # re-project probs onto the simplex per window


def init_state(cfg: LogConfig, init_loads: Optional[jax.Array] = None,
               rates: Optional[jax.Array] = None) -> SchedState:
    """Fresh log: round-robin prior p_i = 1/M (paper §3.3.2).

    ``rates`` defaults to 1 MB/s everywhere — the static-load degenerate
    model where "seconds" and "MB" coincide."""
    m = cfg.n_servers
    loads = jnp.zeros((m,), jnp.float32) if init_loads is None else init_loads.astype(jnp.float32)
    probs = jnp.full((m,), 1.0 / m, jnp.float32)
    rates = jnp.ones((m,), jnp.float32) if rates is None else rates.astype(jnp.float32)
    return SchedState(
        loads=loads,
        probs=probs,
        n_assigned=jnp.zeros((m,), jnp.int32),
        ewma_lat=jnp.zeros((m,), jnp.float32),
        rates=rates,
        vclock=jnp.zeros((), jnp.float32),
        free_at=jnp.zeros((m,), jnp.float32),
    )


def apply_assignment(state: SchedState, server: jax.Array, length: jax.Array,
                     cfg: LogConfig) -> SchedState:
    """Update the log after scheduling ``length`` MB onto ``server``.

    Faithful to Eqs. (1)-(3): the decayed probability mass of the chosen
    server is redistributed evenly over the other M-1 servers, keeping
    sum(p) == 1 exactly (up to float error; see ``renormalize``).
    """
    m = state.loads.shape[-1]
    loads = state.loads.at[server].add(length)           # Eq. (1)
    l_i = loads[server]                                  # updated load of i
    p_i = state.probs[server]
    decayed = p_i * jnp.exp(-l_i / cfg.lam)              # Eq. (2)
    delta = (p_i - decayed) / (m - 1)                    # Eq. (3)
    probs = state.probs + delta
    probs = probs.at[server].set(decayed)
    n_assigned = state.n_assigned.at[server].add(1)
    return state._replace(loads=loads, probs=probs, n_assigned=n_assigned)


def observe_completion(state: SchedState, server: jax.Array, mb_per_s: jax.Array,
                       cfg: LogConfig) -> SchedState:
    """ECT extension (beyond paper): fold an observed service rate into the
    log. A server that is *slow* (not merely loaded) becomes visible here."""
    old = state.ewma_lat[server]
    new = jnp.where(old == 0.0, mb_per_s, (1 - cfg.ewma_alpha) * old + cfg.ewma_alpha * mb_per_s)
    return state._replace(ewma_lat=state.ewma_lat.at[server].set(new))


def advance_time(state: SchedState, dt: jax.Array) -> SchedState:
    """Temporal model: advance the virtual clock by ``dt`` seconds.

    Each server drains its outstanding queue at its *current* service rate
    (piecewise-constant between :class:`~repro.core.engine.ClusterTrace`
    events), clipped at empty; the per-server completion-time clock
    ``free_at`` is re-derived from the residual queue.  ``dt == 0`` is the
    exact identity on non-negative loads, which is what makes the
    degenerate (static) trace reproduce the paper's original model
    bit-for-bit.  jit-compatible; used inside the engine's window scan.
    """
    rates = jnp.maximum(state.rates, 1e-6)
    loads = jnp.maximum(state.loads - rates * dt, 0.0)
    vclock = state.vclock + dt
    free_at = vclock + loads / rates
    return state._replace(loads=loads, vclock=vclock, free_at=free_at)


def estimated_latency(state: SchedState, server: jax.Array) -> jax.Array:
    """Seconds until a request just queued on ``server`` completes: the
    whole outstanding queue (which includes that request, Eq. (1) already
    applied) divided by the server's current service rate."""
    return state.loads[server] / jnp.maximum(state.rates[server], 1e-6)


def renormalize(state: SchedState) -> SchedState:
    """Re-project probs onto the simplex (guards float drift; analytic sum
    is already 1 — see tests/test_statlog.py property tests)."""
    p = jnp.clip(state.probs, 0.0)
    return state._replace(probs=p / jnp.sum(p))


# ---------------------------------------------------------------------------
# Host-side (numpy) twin — used by repro.io.client on the request hot path.
# ---------------------------------------------------------------------------


class HostStatLog:
    """Mutable numpy mirror of (SchedState, apply_assignment).

    Kept deliberately tiny: the whole point of the paper is that the
    client's scheduling state is a few KB resident in local memory —
    no RPC, no probing.
    """

    def __init__(self, cfg: LogConfig, init_loads: Optional[np.ndarray] = None):
        self.cfg = cfg
        m = cfg.n_servers
        self.loads = np.zeros(m, np.float64) if init_loads is None else np.asarray(init_loads, np.float64).copy()
        self.probs = np.full(m, 1.0 / m, np.float64)
        self.n_assigned = np.zeros(m, np.int64)
        self.ewma_lat = np.zeros(m, np.float64)
        self.rates = np.ones(m, np.float64)   # MB per virtual second
        self.vclock = 0.0
        self.free_at = np.zeros(m, np.float64)
        # I/O request table (Fig. 8, left): (object_id, offset, length) rows.
        self.request_log: list[tuple[int, int, float]] = []

    @property
    def n_servers(self) -> int:
        return self.cfg.n_servers

    def record_request(self, object_id: int, offset: int, length_mb: float) -> None:
        self.request_log.append((object_id, offset, length_mb))

    def apply_assignment(self, server: int, length_mb: float) -> None:
        m = self.cfg.n_servers
        self.loads[server] += length_mb                          # Eq. (1)
        p_i = self.probs[server]
        decayed = p_i * np.exp(-self.loads[server] / self.cfg.lam)  # Eq. (2)
        delta = (p_i - decayed) / (m - 1)                        # Eq. (3)
        self.probs += delta
        self.probs[server] = decayed
        self.n_assigned[server] += 1

    def observe_completion(self, server: int, mb_per_s: float) -> None:
        a = self.cfg.ewma_alpha
        old = self.ewma_lat[server]
        self.ewma_lat[server] = mb_per_s if old == 0.0 else (1 - a) * old + a * mb_per_s

    def complete(self, server: int, length_mb: float) -> None:
        """Bytes drained from a server's outstanding queue (write finished)."""
        self.loads[server] = max(0.0, self.loads[server] - length_mb)

    def set_rates(self, rates: np.ndarray) -> None:
        self.rates = np.asarray(rates, np.float64).copy()

    def advance_time(self, dt: float) -> None:
        """Numpy twin of :func:`advance_time`: drain queues at the current
        per-server rates and advance the virtual clock."""
        rates = np.maximum(self.rates, 1e-6)
        self.loads = np.maximum(self.loads - rates * dt, 0.0)
        self.vclock += dt
        self.free_at = self.vclock + self.loads / rates

    def estimated_latency(self, server: int) -> float:
        return float(self.loads[server] / max(self.rates[server], 1e-6))

    def renormalize(self) -> None:
        p = np.clip(self.probs, 0.0, None)
        self.probs = p / p.sum()

    def absorb_loads(self, loads: Optional[np.ndarray] = None) -> None:
        """Seed probabilities from known loads: p_i ∝ (1/M)·e^{-l_i/λ}
        (vectorized Eq. (2) fixed point — how a client that has observed
        the cluster for a while would start; see simulate.absorb_initial_loads)."""
        if loads is not None:
            self.loads = np.asarray(loads, np.float64).copy()
        p = np.exp(-self.loads / self.cfg.lam)
        self.probs = p / p.sum()

    def snapshot(self) -> SchedState:
        return SchedState(
            loads=jnp.asarray(self.loads, jnp.float32),
            probs=jnp.asarray(self.probs, jnp.float32),
            n_assigned=jnp.asarray(self.n_assigned, jnp.int32),
            ewma_lat=jnp.asarray(self.ewma_lat, jnp.float32),
            rates=jnp.asarray(self.rates, jnp.float32),
            vclock=jnp.asarray(self.vclock, jnp.float32),
            free_at=jnp.asarray(self.free_at, jnp.float32),
        )
