"""Client-side server statistic log — the paper's core data structure.

Implements the two tables of Fig. 8 and the maintenance equations:

    Eq. (1)  l_i  <- l'_i + Len                      (load bookkeeping)
    Eq. (2)  p_i  <- p'_i * exp(-l_i / lam)          (probability decay)
    Eq. (3)  p_j  <- p'_j + (p'_i - p'_i e^{-l_i}) / (M-1),  j != i

``lam`` is the load-normalization scale (see DESIGN.md "numerical
fidelity"): the paper's literal Eq. (2) uses raw byte counts in the
exponent, which underflows after a single multi-MB assignment.  ``lam``
defaults to a scale on the order of the mean request size; ``lam -> 0+``
recovers the paper's literal greedy behaviour.

Since PR 2 the log is ONE packed ``(4, M)`` tensor — rows ``loads /
probs / ewma_lat / est_rates`` (`repro.core.policy_core` defines the
layout and all update formulas).  The same representation backs all
three scheduling layers:

* ``SchedState.log`` — jnp array carried through the jitted engine;
* ``HostStatLog.table`` — numpy array whose rows are views, used on the
  request hot path of the real I/O client (``repro.io.client``);
* the Pallas kernel's VMEM scratch (``repro.kernels.sched_select``).

The client's view is stale by construction: ``rates`` (true, trace-
driven, used only to drain queues and report latencies) is NOT part of
the table; the ``est_rates`` row is derived purely from completion
observations and is what ECT schedules on in every layer.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policy_core
from repro.core.policy_core import (N_ROWS, ROW_EST, ROW_EWMA, ROW_LOADS,
                                    ROW_PROBS)


class SchedState(NamedTuple):
    """Scheduling state: the packed log tensor + true-cluster fields.

    ``log`` is the client's whole statistic table — the few KB the paper
    keeps resident in client memory.  The temporal extension (DESIGN.md
    §Temporal-model) adds per-server TRUE service ``rates`` and virtual
    completion-time clocks so the jitted engine can drain queues between
    time windows; those are simulator ground truth, not client knowledge,
    which is why they live outside the table.  With ``rates == 1`` and
    ``advance_time`` never called, the state degrades exactly to the
    paper's static-load model.
    """

    log: jax.Array          # (4, M) packed table (policy_core layout)
    n_assigned: jax.Array   # (M,) int32 — requests scheduled per server
    rates: jax.Array        # (M,) current TRUE service rate, MB per virtual s
    vclock: jax.Array       # ()  virtual time since stream start, seconds
    free_at: jax.Array      # (M,) virtual completion-time clock: when each
    #                          server's outstanding queue drains (vclock
    #                          units).  Derived state for introspection /
    #                          metrics: refreshed ONLY by advance_time (it
    #                          is stale between drains); no policy reads it.

    @property
    def loads(self) -> jax.Array:
        return self.log[..., ROW_LOADS, :]

    @property
    def probs(self) -> jax.Array:
        return self.log[..., ROW_PROBS, :]

    @property
    def ewma_lat(self) -> jax.Array:
        return self.log[..., ROW_EWMA, :]

    @property
    def est_rates(self) -> jax.Array:
        """Client-estimated service rates — observations only, never the
        true ``rates`` (the stale-view contract)."""
        return self.log[..., ROW_EST, :]

    @property
    def n_servers(self) -> int:
        return self.log.shape[-1]

    def with_rows(self, *, loads=None, probs=None, ewma_lat=None,
                  est_rates=None) -> "SchedState":
        """Functionally replace individual rows of the packed table."""
        log = self.log
        for row, val in ((ROW_LOADS, loads), (ROW_PROBS, probs),
                         (ROW_EWMA, ewma_lat), (ROW_EST, est_rates)):
            if val is not None:
                log = log.at[..., row, :].set(val)
        return self._replace(log=log)


@dataclasses.dataclass(frozen=True)
class LogConfig:
    """Static knobs of the statistic log."""

    n_servers: int
    lam: float = 32.0          # Eq.(2) normalization scale, in MB
    ewma_alpha: float = 0.25   # ECT extension only
    renorm: bool = True        # re-project probs onto the simplex per window


def init_state(cfg: LogConfig, init_loads: Optional[jax.Array] = None,
               rates: Optional[jax.Array] = None) -> SchedState:
    """Fresh log: round-robin prior p_i = 1/M (paper §3.3.2).

    ``rates`` defaults to 1 MB/s everywhere — the static-load degenerate
    model where "seconds" and "MB" coincide."""
    m = cfg.n_servers
    log = policy_core.init_table(m, xp=jnp)
    if init_loads is not None:
        log = log.at[ROW_LOADS].set(init_loads.astype(jnp.float32))
    rates = jnp.ones((m,), jnp.float32) if rates is None else rates.astype(jnp.float32)
    return SchedState(
        log=log,
        n_assigned=jnp.zeros((m,), jnp.int32),
        rates=rates,
        vclock=jnp.zeros((), jnp.float32),
        free_at=jnp.zeros((m,), jnp.float32),
    )


def apply_assignment(state: SchedState, server: jax.Array, length: jax.Array,
                     cfg: LogConfig) -> SchedState:
    """Update the log after scheduling ``length`` MB onto ``server``.

    Faithful to Eqs. (1)-(3) via the shared decision core: the decayed
    probability mass of the chosen server is redistributed evenly over
    the other M-1 servers, keeping sum(p) == 1 exactly (up to float
    error; see ``renormalize``).
    """
    loads, probs = policy_core.assignment_update(
        state.loads, state.probs, server, length, cfg.lam, state.n_servers)
    n_assigned = state.n_assigned.at[server].add(1)
    return state.with_rows(loads=loads, probs=probs)._replace(
        n_assigned=n_assigned)


def observe_completion(state: SchedState, server: jax.Array, mb_per_s: jax.Array,
                       cfg: LogConfig) -> SchedState:
    """ECT extension (beyond paper): fold an observed service rate into the
    log.  A server that is *slow* (not merely loaded) becomes visible here
    — and ONLY here: this is the single path that writes the client's
    ``est_rates`` row."""
    ewma, est = policy_core.observe_update(state.ewma_lat, server, mb_per_s,
                                           cfg.ewma_alpha)
    return state.with_rows(ewma_lat=ewma, est_rates=est)


def advance_time(state: SchedState, dt: jax.Array,
                 dec: Optional[jax.Array] = None) -> SchedState:
    """Temporal model: advance the virtual clock by ``dt`` seconds.

    Each server drains its outstanding queue at its *current* TRUE service
    rate (piecewise-constant between :class:`~repro.core.engine.ClusterTrace`
    events), clipped at empty; the per-server completion-time clock
    ``free_at`` is re-derived from the residual queue.  ``dt == 0`` is the
    exact identity on non-negative loads, which is what makes the
    degenerate (static) trace reproduce the paper's original model
    bit-for-bit.  jit-compatible; used inside the engine's window scan —
    which passes ``dec``, the precomputed
    :func:`~repro.core.policy_core.window_decrements` row, so no
    FMA-contractable ``rates * dt`` product exists inside the fused scan
    body (the §9 bit-exactness contract).
    """
    loads = policy_core.drain_loads(state.loads, state.rates, dt, dec=dec)
    vclock = state.vclock + dt
    free_at = vclock + loads / jnp.maximum(state.rates, 1e-6)
    return state.with_rows(loads=loads)._replace(vclock=vclock,
                                                 free_at=free_at)


def estimated_latency(state: SchedState, server: jax.Array) -> jax.Array:
    """Seconds until a request just queued on ``server`` completes: the
    whole outstanding queue (which includes that request, Eq. (1) already
    applied) divided by the server's current TRUE service rate."""
    return policy_core.estimated_latency(state.loads, state.rates, server)


def renormalize(state: SchedState) -> SchedState:
    """Re-project probs onto the simplex (guards float drift; analytic sum
    is already 1 — see tests/test_statlog.py property tests)."""
    return state.with_rows(probs=policy_core.renormalize_probs(state.probs))


# ---------------------------------------------------------------------------
# Host-side (numpy) twin — used by repro.io.client on the request hot path.
# ---------------------------------------------------------------------------


class HostStatLog:
    """Mutable numpy mirror of (SchedState, apply_assignment).

    Kept deliberately tiny: the whole point of the paper is that the
    client's scheduling state is a few KB resident in local memory —
    no RPC, no probing.  ``table`` is the SAME packed (4, M) layout as
    ``SchedState.log`` (rows are numpy views, so in-place edits like
    ``log.loads[s] = x`` hit the table directly), and every update calls
    the shared ``policy_core`` formulas with ``xp=numpy``.
    """

    def __init__(self, cfg: LogConfig, init_loads: Optional[np.ndarray] = None):
        self.cfg = cfg
        m = cfg.n_servers
        self.table = policy_core.init_table(m, xp=np)     # (4, M) float64
        if init_loads is not None:
            self.table[ROW_LOADS] = np.asarray(init_loads, np.float64)
        self.n_assigned = np.zeros(m, np.int64)
        self.rates = np.ones(m, np.float64)   # TRUE MB per virtual second
        self.vclock = 0.0
        self.free_at = np.zeros(m, np.float64)
        # I/O request table (Fig. 8, left): (object_id, offset, length) rows.
        self.request_log: list[tuple[int, int, float]] = []

    # -- packed-table row views ---------------------------------------------
    @property
    def loads(self) -> np.ndarray:
        return self.table[ROW_LOADS]

    @loads.setter
    def loads(self, v) -> None:
        self.table[ROW_LOADS] = np.asarray(v, np.float64)

    @property
    def probs(self) -> np.ndarray:
        return self.table[ROW_PROBS]

    @probs.setter
    def probs(self, v) -> None:
        self.table[ROW_PROBS] = np.asarray(v, np.float64)

    @property
    def ewma_lat(self) -> np.ndarray:
        return self.table[ROW_EWMA]

    @ewma_lat.setter
    def ewma_lat(self, v) -> None:
        self.table[ROW_EWMA] = np.asarray(v, np.float64)

    @property
    def est_rates(self) -> np.ndarray:
        """Client-estimated rates: observations only (stale view)."""
        return self.table[ROW_EST]

    @est_rates.setter
    def est_rates(self, v) -> None:
        self.table[ROW_EST] = np.asarray(v, np.float64)

    @property
    def n_servers(self) -> int:
        return self.cfg.n_servers

    def record_request(self, object_id: int, offset: int, length_mb: float) -> None:
        self.request_log.append((object_id, offset, length_mb))

    def apply_assignment(self, server: int, length_mb: float) -> None:
        loads, probs = policy_core.assignment_update(
            self.loads, self.probs, server, length_mb, self.cfg.lam,
            self.cfg.n_servers, xp=np)
        self.table[ROW_LOADS] = loads
        self.table[ROW_PROBS] = probs
        self.n_assigned[server] += 1

    def observe_completion(self, server: int, mb_per_s: float) -> None:
        ewma, est = policy_core.observe_update(
            self.ewma_lat, server, mb_per_s, self.cfg.ewma_alpha, xp=np)
        self.table[ROW_EWMA] = ewma
        self.table[ROW_EST] = est

    def complete(self, server: int, length_mb: float) -> None:
        """Bytes drained from a server's outstanding queue (write finished)."""
        self.loads[server] = max(0.0, self.loads[server] - length_mb)

    def set_rates(self, rates: np.ndarray) -> None:
        self.rates = np.asarray(rates, np.float64).copy()

    def advance_time(self, dt: float) -> None:
        """Numpy twin of :func:`advance_time`: drain queues at the current
        TRUE per-server rates and advance the virtual clock."""
        self.table[ROW_LOADS] = policy_core.drain_loads(self.loads,
                                                        self.rates, dt, xp=np)
        self.vclock += dt
        self.free_at = self.vclock + self.loads / np.maximum(self.rates, 1e-6)

    def estimated_latency(self, server: int) -> float:
        return float(policy_core.estimated_latency(self.loads, self.rates,
                                                   server, xp=np))

    def renormalize(self) -> None:
        self.table[ROW_PROBS] = policy_core.renormalize_probs(self.probs,
                                                              xp=np)

    def absorb_loads(self, loads: Optional[np.ndarray] = None) -> None:
        """Seed probabilities from known loads: p_i ∝ (1/M)·e^{-l_i/λ}
        (vectorized Eq. (2) fixed point — how a client that has observed
        the cluster for a while would start; see simulate.absorb_initial_loads)."""
        if loads is not None:
            self.table[ROW_LOADS] = np.asarray(loads, np.float64)
        p = np.exp(-self.loads / self.cfg.lam)
        self.table[ROW_PROBS] = p / p.sum()

    def snapshot(self) -> SchedState:
        return SchedState(
            log=jnp.asarray(self.table, jnp.float32),
            n_assigned=jnp.asarray(self.n_assigned, jnp.int32),
            rates=jnp.asarray(self.rates, jnp.float32),
            vclock=jnp.asarray(self.vclock, jnp.float32),
            free_at=jnp.asarray(self.free_at, jnp.float32),
        )
