"""repro.data — deterministic step-indexed pipelines (synthetic +
object-store-backed via the straggler-aware scheduler)."""

from repro.data.pipeline import (  # noqa: F401
    DataConfig, ObjectStoreTokens, SyntheticTokens,
)
