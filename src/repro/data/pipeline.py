"""Deterministic, step-indexed, host-sharded data pipeline.

Two sources, one contract — ``batch_at(step)`` is a pure function of
``(seed, step, host)``, so a restarted (or re-sharded / elastically scaled)
job replays the exact token stream with no iterator state to checkpoint:

* :class:`SyntheticTokens` — counter-based RNG (`np.random.default_rng`
  seeded with ``[seed, step, host]``), zero I/O.  Used by training tests,
  smoke tests and the dry-run.
* :class:`ObjectStoreTokens` — token shards prepared once into the object
  store *through the straggler-aware scheduler* and read back per step via
  the redirect-aware read path.  This is the data-loading face of the
  paper (reads hitting a straggler OSS gate the whole input pipeline).

Batches follow the model ``input_specs`` contract:
``{"tokens": (B_host, S) int32, "targets": (B_host, S) int32}`` where
targets are next-token shifted; padding id 0.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.io.client import IOClient
from repro.io.objectstore import MB


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    n_hosts: int = 1
    host_id: int = 0
    seed: int = 0

    def __post_init__(self):
        if self.global_batch % self.n_hosts:
            raise ValueError("global_batch must divide evenly over hosts")
        if not (0 <= self.host_id < self.n_hosts):
            raise ValueError("bad host_id")

    @property
    def host_batch(self) -> int:
        return self.global_batch // self.n_hosts


class SyntheticTokens:
    """Deterministic synthetic LM tokens; exactly resumable at any step."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        out_tokens = np.empty((cfg.host_batch, cfg.seq_len + 1), np.int32)
        for row in range(cfg.host_batch):
            # global example index — independent of host count, so elastic
            # rescaling replays the identical global batch
            ex = step * cfg.global_batch + cfg.host_id * cfg.host_batch + row
            rng = np.random.default_rng([cfg.seed, ex])
            out_tokens[row] = rng.integers(
                1, cfg.vocab_size, cfg.seq_len + 1, dtype=np.int32)
        return {
            "tokens": out_tokens[:, :-1],
            "targets": out_tokens[:, 1:],
        }

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class ObjectStoreTokens:
    """Token shards stored as objects; reads scheduled via the log client.

    ``prepare()`` writes ``n_shards`` shard files (each holding
    ``rows_per_shard`` examples) through the straggler-aware scheduler.
    ``batch_at(step)`` gathers the step's rows from the owning shards using
    the redirect-aware read path.
    """

    FILE_BASE = 0x5EED_0000_0000

    def __init__(self, cfg: DataConfig, client: IOClient,
                 rows_per_shard: int = 64):
        self.cfg = cfg
        self.client = client
        self.rows_per_shard = rows_per_shard
        self._synth = SyntheticTokens(
            dataclasses.replace(cfg, n_hosts=1, host_id=0))

    def _row_bytes(self) -> int:
        return (self.cfg.seq_len + 1) * 4

    def _shard_size(self) -> int:
        return self.rows_per_shard * self._row_bytes()

    def n_shards_for(self, n_steps: int) -> int:
        rows = n_steps * self.cfg.global_batch
        return -(-rows // self.rows_per_shard)

    def prepare(self, n_steps: int) -> int:
        """Write the first ``n_steps`` steps' rows into the store."""
        n_shards = self.n_shards_for(n_steps)
        row_b = self._row_bytes()
        for shard in range(n_shards):
            buf = bytearray(self._shard_size())
            for i in range(self.rows_per_shard):
                ex = shard * self.rows_per_shard + i
                rng = np.random.default_rng([self.cfg.seed, ex])
                row = rng.integers(1, self.cfg.vocab_size,
                                   self.cfg.seq_len + 1, dtype=np.int32)
                buf[i * row_b:(i + 1) * row_b] = row.tobytes()
            self.client.write_file(self.FILE_BASE + shard, bytes(buf))
        self.client.flush()
        return n_shards

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        row_b = self._row_bytes()
        rows = np.empty((cfg.host_batch, cfg.seq_len + 1), np.int32)
        # cache whole shards across the rows of one batch
        cache: Dict[int, bytes] = {}
        for r in range(cfg.host_batch):
            ex = step * cfg.global_batch + cfg.host_id * cfg.host_batch + r
            shard, within = divmod(ex, self.rows_per_shard)
            if shard not in cache:
                cache[shard] = self.client.read_file(
                    self.FILE_BASE + shard, self._shard_size())
            raw = cache[shard][within * row_b:(within + 1) * row_b]
            rows[r] = np.frombuffer(raw, np.int32)
        return {"tokens": rows[:, :-1], "targets": rows[:, 1:]}
