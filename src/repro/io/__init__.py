"""repro.io — object-storage substrate: striping, simulated/local stores
with redirect tables + metadata maintainer, and the client-side scheduler
client (paper Fig. 5)."""

from repro.io.striping import (  # noqa: F401
    MB, ObjectRequest, StripingConfig, object_id_for, stripe_file,
    stripe_request,
)
from repro.io.objectstore import (  # noqa: F401
    LocalFSStore, MaintainerThread, ObjectMissingError, RedirectTable,
    ServerFailedError, SimulatedCluster, WriteResult,
)
from repro.io.client import IOClient, IOClientConfig, WriteRecord  # noqa: F401
