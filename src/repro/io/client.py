"""Client-side I/O scheduler client (paper Fig. 5, left box).

``IOClient`` is the thing that runs on a compute node: it holds the
client-side server statistic log (:class:`~repro.core.statlog.HostStatLog`
— the packed ``(4, M)`` log tensor of `repro.core.policy_core`, the SAME
representation the jitted engine carries and the Pallas kernel pins in
VMEM), a scheduling policy (:class:`~repro.core.policies.HostScheduler`),
and a handle to the object store.  Every file write is striped into
objects, scheduled as one *time window* through the log (zero probe
messages for the log-assisted policies), written — possibly redirected
away from the default home, recorded in the home's redirect table — and
observed back into the log: completion rates feed the ``ewma_lat`` /
``est_rates`` rows, the ONLY channel through which the client learns
about server speed (the stale-view contract, DESIGN.md §8).  ECT here
therefore ranks servers by the same client-estimated latency numbers as
the engine and the kernel backend.

Fault tolerance: a write that hits a failed server masks that server in the
scheduler and retries on the next-best target (up to ``max_retries``), which
is exactly the behaviour the checkpoint layer leans on at scale.  Optional
``replication`` writes each object to N distinct servers.

Works against both backends:

* :class:`~repro.io.objectstore.LocalFSStore` — payloads are real ``bytes``;
* :class:`~repro.io.objectstore.SimulatedCluster` — payloads are MB floats
  (pass ``data_mb=`` instead of ``data=``).
"""

from __future__ import annotations

import dataclasses
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.policies import HostScheduler, PolicyConfig
from repro.core.statlog import HostStatLog, LogConfig
from repro.io import striping
from repro.io.objectstore import (MB, ObjectMissingError, ServerFailedError,
                                  WriteResult)


@dataclasses.dataclass
class WriteRecord:
    object_id: int
    stripe_index: int
    server: int
    mb: float
    seconds: float
    redirected: bool
    retries: int
    replicas: List[int]


@dataclasses.dataclass(frozen=True)
class IOClientConfig:
    policy: PolicyConfig = PolicyConfig(name="trh", threshold=4.0)
    lam_mb: float = 32.0
    stripe_size: int = 4 * MB
    max_retries: int = 3
    replication: int = 1
    async_writers: int = 0          # 0 = synchronous writes
    observe_completions: bool = True
    drain_on_complete: bool = True  # drain log load when a write finishes
    # Recompute p_i ∝ e^{-l_i/λ} from CURRENT loads at each window start
    # instead of relying only on Eq. (2)'s incremental decay.  The paper's
    # repeated multiplicative decay makes the probability RANKING drift
    # from the load ranking over long runs (found in §Perf hillclimb C);
    # the memoryless refresh keeps the same exponential law. Beyond-paper.
    refresh_probs: bool = False


class IOClient:
    def __init__(self, store, cfg: IOClientConfig = IOClientConfig(),
                 seed: int = 0):
        self.store = store
        self.cfg = cfg
        self.log = HostStatLog(LogConfig(n_servers=store.n_servers,
                                         lam=cfg.lam_mb))
        self.sched = HostScheduler(cfg.policy, self.log, seed=seed)
        self.striping = striping.StripingConfig(stripe_size=cfg.stripe_size)
        self._lock = threading.RLock()
        self._pool = (ThreadPoolExecutor(max_workers=cfg.async_writers)
                      if cfg.async_writers > 0 else None)
        self._pending: List[Future] = []
        self.records: List[WriteRecord] = []
        self.failed_writes = 0

    # ------------------------------------------------------------------ utils
    @property
    def n_servers(self) -> int:
        return self.store.n_servers

    @property
    def probe_messages(self) -> int:
        return self.sched.probe_messages

    def _is_sim(self) -> bool:
        return hasattr(self.store, "clock")

    def _alive_min_load(self) -> int:
        masked = self.sched.masked_servers
        alive = [s for s in range(self.n_servers) if s not in masked]
        if not alive:
            raise ServerFailedError("all servers masked")
        return min(alive, key=lambda s: self.log.loads[s])

    # ------------------------------------------------------------- write path
    def _write_one(self, req: striping.ObjectRequest,
                   payload, server: int) -> WriteRecord:
        """Write one object (with retry-on-failure), update the log."""
        mb = req.length / MB if isinstance(payload, (bytes, bytearray, memoryview)) \
            else float(payload)
        retries = 0
        replicas: List[int] = []
        current = server
        while True:
            try:
                res: WriteResult = self.store.write_object(
                    req.object_id, payload, current)
                break
            except ServerFailedError:
                with self._lock:
                    self.failed_writes += 1
                    self.sched.mask_server(current)
                    # undo the load we booked on the dead server, then pick
                    # the next-best target from the live log.
                    self.log.complete(current, mb)
                    retries += 1
                    if retries > self.cfg.max_retries:
                        raise
                    current = self._alive_min_load()
                    self.log.apply_assignment(current, mb)
        with self._lock:
            if self.cfg.observe_completions:
                self.log.observe_completion(res.server, res.mb_per_s)
            if self.cfg.drain_on_complete and not self._is_sim():
                self.log.complete(res.server, mb)
        replicas.append(res.server)
        # extra replicas on distinct servers (fault tolerance at scale)
        for _ in range(self.cfg.replication - 1):
            with self._lock:
                masked = set(self.sched.masked_servers) | set(replicas)
                alive = [s for s in range(self.n_servers) if s not in masked]
                if not alive:
                    break
                rep = min(alive, key=lambda s: self.log.loads[s])
                self.log.apply_assignment(rep, mb)
            try:
                rres = self.store.write_object(req.object_id, payload, rep)
                replicas.append(rres.server)
            except ServerFailedError:
                with self._lock:
                    self.sched.mask_server(rep)
                    self.log.complete(rep, mb)
        home = req.object_id % self.n_servers
        rec = WriteRecord(object_id=req.object_id,
                          stripe_index=req.stripe_index,
                          server=res.server, mb=mb, seconds=res.seconds,
                          redirected=res.server != home, retries=retries,
                          replicas=replicas)
        with self._lock:
            self.records.append(rec)
        return rec

    def write_file(self, file_id: int, data: Optional[bytes] = None, *,
                   size_mb: Optional[float] = None) -> List[WriteRecord]:
        """Stripe + schedule + write one file (one time window).

        ``data`` for real stores; ``size_mb`` for the simulated cluster.
        """
        if (data is None) == (size_mb is None):
            raise ValueError("pass exactly one of data / size_mb")
        size = len(data) if data is not None else int(size_mb * MB)
        reqs = striping.stripe_file(self.striping, file_id, max(size, 1))
        with self._lock:
            if self.cfg.refresh_probs:
                self.log.absorb_loads()
            self.sched.begin_window([r.length / MB for r in reqs])
            planned = []
            for r in reqs:
                server = self.sched.schedule(r.object_id, r.length / MB,
                                             offset=r.offset)
                planned.append((r, server))
        out: List[WriteRecord] = []
        futures: List[Future] = []
        for r, server in planned:
            payload = (data[r.file_offset:r.file_offset + r.length]
                       if data is not None else r.length / MB)
            if self._pool is not None:
                futures.append(self._pool.submit(self._write_one, r, payload,
                                                 server))
            else:
                out.append(self._write_one(r, payload, server))
        if futures:
            self._pending.extend(futures)
            out.extend(f.result() for f in futures)
        return out

    def write_file_async(self, file_id: int, data: bytes) -> List[Future]:
        """Schedule now, write in background; ``flush()`` is the barrier."""
        if self._pool is None:
            raise RuntimeError("configure async_writers > 0")
        reqs = striping.stripe_file(self.striping, file_id, max(len(data), 1))
        with self._lock:
            if self.cfg.refresh_probs:
                self.log.absorb_loads()
            self.sched.begin_window([r.length / MB for r in reqs])
            planned = [(r, self.sched.schedule(r.object_id, r.length / MB,
                                               offset=r.offset)) for r in reqs]
        futs = []
        for r, server in planned:
            payload = data[r.file_offset:r.file_offset + r.length]
            futs.append(self._pool.submit(self._write_one, r, payload, server))
        self._pending.extend(futs)
        return futs

    def flush(self) -> float:
        """Barrier: wait for async writes; advance the sim clock if any.
        Returns the sim phase time (0.0 for real stores)."""
        pending, self._pending = self._pending, []
        for f in pending:
            f.result()
        if self._is_sim():
            phase = self.store.barrier()
            # phase end: outstanding queues drained -> forget booked loads
            for s in range(self.n_servers):
                self.log.loads[s] = self.store.queued_mb(s)
            return phase
        return 0.0

    # -------------------------------------------------------------- read path
    def read_file(self, file_id: int, size: int) -> bytes:
        """Read via default home -> redirect table -> replica scan."""
        reqs = striping.stripe_file(self.striping, file_id, size)
        buf = bytearray(size)
        for r in reqs:
            data = self.store.read_object(r.object_id)
            if len(data) < r.offset + r.length:
                raise ObjectMissingError(
                    f"object {r.object_id:#x} truncated: "
                    f"{len(data)} < {r.offset + r.length}")
            buf[r.file_offset:r.file_offset + r.length] = \
                data[r.offset:r.offset + r.length]
        return bytes(buf)

    def read_file_sim(self, file_id: int, size_mb: float) -> float:
        """Simulated read of a whole file; returns total MB touched."""
        reqs = striping.stripe_file(self.striping, file_id, int(size_mb * MB))
        total = 0.0
        for r in reqs:
            mb, _, _ = self.store.read_object(r.object_id)
            total += mb
        return total

    def close(self) -> None:
        if self._pool is not None:
            self.flush()
            self._pool.shutdown(wait=True)

    # ----------------------------------------------------------------- stats
    @property
    def log_table(self) -> np.ndarray:
        """Snapshot of the packed (4, M) log tensor (loads / probs /
        ewma_lat / est_rates) — the client's whole scheduling state."""
        return self.log.table.copy()

    def stats(self) -> Dict[str, float]:
        if not self.records:
            return {"writes": 0}
        mbs = np.array([r.mb for r in self.records])
        secs = np.array([r.seconds for r in self.records])
        est = self.log.est_rates
        return {
            "writes": len(self.records),
            "total_mb": float(mbs.sum()),
            "redirect_rate": float(np.mean([r.redirected for r in self.records])),
            "mean_write_mb_s": float((mbs / secs).mean()),
            "p50_write_s": float(np.percentile(secs, 50)),
            "p99_write_s": float(np.percentile(secs, 99)),
            "probe_messages": float(self.probe_messages),
            "retries": float(sum(r.retries for r in self.records)),
            "failed_writes": float(self.failed_writes),
            # stale-view summary: the client's own rate estimates
            "est_rate_min_mb_s": float(est.min()),
            "est_rate_max_mb_s": float(est.max()),
            "est_slowest_server": int(np.argmin(est)),
        }
