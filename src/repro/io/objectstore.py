"""Object storage substrates: simulated OSS cluster + local-FS store.

Implements the server-side components of the paper's architecture (§3.1):

* **redirect table** — one per object storage server; remembers, for every
  object whose default home is this server, where its bytes actually live
  after a straggler-avoiding redirect (Fig. 6).
* **metadata maintainer** — migrates redirected objects back to their
  default home when the system is idle, deleting the redirect entry, so
  later reads go straight to the default server.

Two backends share that machinery:

* :class:`SimulatedCluster` — a virtual-clock queueing model (one FIFO
  queue per server, configurable service rate) used for latency /
  throughput evaluation of the scheduling policies, with straggler
  injection (slow-rate and extra-load) and fail/heal APIs.
* :class:`LocalFSStore` — a real-bytes store (one directory per server)
  used by the checkpoint layer end-to-end; stragglers are emulated with a
  per-server write delay, failures with a marker file.
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from typing import Dict, List, Optional, Tuple

MB = 1024 * 1024


class ServerFailedError(RuntimeError):
    """The targeted object storage server is down."""


class ObjectMissingError(KeyError):
    """No server holds the requested object."""


@dataclasses.dataclass
class WriteResult:
    server: int
    mb: float
    issued_at: float
    finished_at: float

    @property
    def seconds(self) -> float:
        return max(self.finished_at - self.issued_at, 1e-9)

    @property
    def mb_per_s(self) -> float:
        return self.mb / self.seconds


class RedirectTable:
    """Per-server object_id -> actual_server map (paper Fig. 6)."""

    def __init__(self):
        self._entries: Dict[int, int] = {}
        self._lock = threading.Lock()

    def set(self, object_id: int, actual_server: int) -> None:
        with self._lock:
            self._entries[object_id] = actual_server

    def get(self, object_id: int) -> Optional[int]:
        with self._lock:
            return self._entries.get(object_id)

    def pop(self, object_id: int) -> Optional[int]:
        with self._lock:
            return self._entries.pop(object_id, None)

    def items(self) -> List[Tuple[int, int]]:
        with self._lock:
            return list(self._entries.items())

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)


# ---------------------------------------------------------------------------
# Simulated cluster (virtual clock, queueing model)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _SimServer:
    rate_mb_s: float
    free_at: float = 0.0
    pending_mb: float = 0.0
    total_written_mb: float = 0.0
    n_requests: int = 0
    failed: bool = False


class SimulatedCluster:
    """M object storage servers with FIFO queues on a shared virtual clock.

    The client issues writes at the current clock; each lands at the tail
    of its server's queue: ``finish = max(clock, free_at) + mb / rate``.
    ``barrier()`` implements the HPC synchronous I/O-phase semantics — it
    returns the phase's completion time (the max across servers touched
    since the last barrier) and advances the clock there.
    """

    def __init__(self, n_servers: int, base_rate_mb_s: float = 200.0,
                 rate_jitter: float = 0.0, seed: int = 0, trace=None):
        import numpy as np
        rng = np.random.default_rng(seed)
        self.n_servers = n_servers
        self.clock = 0.0
        self.servers = [
            _SimServer(rate_mb_s=float(
                base_rate_mb_s * (1.0 + rate_jitter * rng.standard_normal())))
            for _ in range(n_servers)
        ]
        for s in self.servers:
            s.rate_mb_s = max(s.rate_mb_s, 1e-3)
        self.redirects = [RedirectTable() for _ in range(n_servers)]
        self._locations: Dict[int, int] = {}      # object -> server actually holding it
        self._sizes: Dict[int, float] = {}        # object -> MB
        self._phase_finish = 0.0
        self._phase_touched: set = set()          # servers serving this phase
        self.migrated_objects = 0
        # Optional rate-event schedule: the SAME ClusterTrace the jitted
        # engine consumes (repro.core.engine.ClusterTrace, or anything with
        # .times (E,) / .rates (E, M)), so host-path and JAX-path results
        # are comparable on identical scenarios.  Events apply as the
        # clock passes them (advance_time / barrier).
        self._trace_times = self._trace_rates = None
        self._next_event = 0
        if trace is not None:
            self._trace_times = np.asarray(trace.times, np.float64)
            self._trace_rates = np.asarray(trace.rates, np.float64)
            if self._trace_rates.shape != (len(self._trace_times), n_servers):
                raise ValueError("trace.rates must be (n_events, n_servers)")
            self._apply_trace_events(0.0)

    # -- straggler / failure injection --------------------------------------
    def set_rate(self, server: int, rate_mb_s: float) -> None:
        """Change a server's service rate, preserving its queued WORK:
        bytes still pending keep their volume, their drain time rescales."""
        s = self.servers[server]
        remaining_mb = max(s.free_at - self.clock, 0.0) * s.rate_mb_s
        s.rate_mb_s = max(rate_mb_s, 1e-3)
        s.free_at = self.clock + remaining_mb / s.rate_mb_s
        if server in self._phase_touched:
            # re-derive: a slowdown extends the phase, a recovery SHORTENS
            # it (the raise-only update would leave a stale-high finish)
            self._phase_finish = self._projected_finish()

    def _apply_trace_events(self, up_to: float) -> None:
        """Apply all trace rate events with time <= ``up_to`` (in order,
        advancing the clock to each event so queues rescale correctly)."""
        if self._trace_times is None:
            return
        while (self._next_event < len(self._trace_times)
               and self._trace_times[self._next_event] <= up_to):
            ev_t = float(self._trace_times[self._next_event])
            self.clock = max(self.clock, ev_t)
            for srv, rate in enumerate(self._trace_rates[self._next_event]):
                self.set_rate(srv, float(rate))
            self._next_event += 1

    def advance_time(self, dt: float) -> float:
        """Temporal model: move the virtual clock forward ``dt`` seconds
        (queues drain implicitly — ``free_at`` is absolute), applying any
        trace rate events passed on the way.  Returns the new clock."""
        target = self.clock + max(dt, 0.0)
        self._apply_trace_events(target)
        self.clock = max(self.clock, target)
        self._phase_finish = max(self._phase_finish, self.clock)
        return self.clock

    def make_straggler(self, server: int, slow_factor: float = 5.0) -> None:
        """Slow-rate straggler: service rate divided by ``slow_factor``
        (queue-preserving: already-queued bytes rescale like set_rate)."""
        self.set_rate(server, self.servers[server].rate_mb_s / slow_factor)

    def add_external_load(self, server: int, mb: float) -> None:
        """Busy straggler: queue ``mb`` of foreign bytes on the server.

        Foreign work delays OUR requests behind it but is not part of our
        phase — the barrier only waits for requests we issued (Fig. 1
        semantics)."""
        s = self.servers[server]
        s.free_at = max(s.free_at, self.clock) + mb / s.rate_mb_s
        s.pending_mb += mb

    def fail_server(self, server: int) -> None:
        self.servers[server].failed = True

    def heal_server(self, server: int) -> None:
        self.servers[server].failed = False

    # -- log-visible state ---------------------------------------------------
    def queued_mb(self, server: int) -> float:
        """What a probing client would learn (used by two_choice baseline)."""
        s = self.servers[server]
        return max(s.free_at - self.clock, 0.0) * s.rate_mb_s

    def default_home(self, object_id: int) -> int:
        return object_id % self.n_servers

    def locate(self, object_id: int) -> int:
        """Default home, then its redirect table (read path, Fig. 6)."""
        if object_id in self._locations:
            return self._locations[object_id]
        raise ObjectMissingError(object_id)

    # -- data path -----------------------------------------------------------
    def write_object(self, object_id: int, mb: float, server: int) -> WriteResult:
        s = self.servers[server]
        if s.failed:
            raise ServerFailedError(f"server {server} is down")
        start = max(self.clock, s.free_at)
        finish = start + mb / s.rate_mb_s
        s.free_at = finish
        s.pending_mb += mb
        s.total_written_mb += mb
        s.n_requests += 1
        self._phase_finish = max(self._phase_finish, finish)
        self._phase_touched.add(server)
        home = self.default_home(object_id)
        prev = self._locations.get(object_id)
        self._locations[object_id] = server
        self._sizes[object_id] = mb
        if server != home:
            self.redirects[home].set(object_id, server)
        elif prev is not None and prev != home:
            self.redirects[home].pop(object_id)
        return WriteResult(server=server, mb=mb, issued_at=self.clock,
                           finished_at=finish)

    def read_object(self, object_id: int) -> Tuple[float, int, WriteResult]:
        server = self.locate(object_id)
        s = self.servers[server]
        if s.failed:
            raise ServerFailedError(f"server {server} is down")
        mb = self._sizes[object_id]
        start = max(self.clock, s.free_at)
        finish = start + mb / s.rate_mb_s
        s.free_at = finish
        s.n_requests += 1
        self._phase_finish = max(self._phase_finish, finish)
        self._phase_touched.add(server)
        return mb, server, WriteResult(server=server, mb=mb,
                                       issued_at=self.clock, finished_at=finish)

    def _projected_finish(self) -> float:
        """Latest completion among servers serving this phase's requests."""
        touched = [self.servers[i].free_at for i in self._phase_touched]
        return max(max(touched), self.clock) if touched else self.clock

    def barrier(self) -> float:
        """Synchronous I/O-phase end: advance the clock to the slowest
        server's finish (the paper's Fig. 1 semantics). Returns phase time.

        With a trace, rate events firing BEFORE the projected finish are
        stepped through in order (queues rescale at each event), so a
        mid-phase slowdown extends the phase exactly as the jitted engine
        models it — not just the next phase's rates."""
        t0 = self.clock
        if self._trace_times is not None:
            while self._next_event < len(self._trace_times):
                ev_t = float(self._trace_times[self._next_event])
                if ev_t > self._projected_finish():
                    break
                self.clock = max(self.clock, ev_t)
                for srv, rate in enumerate(self._trace_rates[self._next_event]):
                    self.set_rate(srv, float(rate))
                self._next_event += 1
            self._phase_finish = self._projected_finish()
        phase = max(self._phase_finish - t0, 0.0)
        self.clock = max(self.clock, self._phase_finish)
        for s in self.servers:
            if s.free_at <= self.clock:
                s.pending_mb = 0.0
        self._phase_finish = self.clock
        self._phase_touched.clear()
        return phase

    # -- metadata maintainer (§3.1) -------------------------------------------
    def maintainer_tick(self, max_objects: int = 16) -> int:
        """Migrate up to ``max_objects`` redirected objects back to their
        default homes, if both ends are idle.  Returns #migrated."""
        moved = 0
        for home, table in enumerate(self.redirects):
            if moved >= max_objects:
                break
            if self.servers[home].failed or self.servers[home].free_at > self.clock:
                continue
            for object_id, actual in table.items():
                if moved >= max_objects:
                    break
                src = self.servers[actual]
                if src.failed or src.free_at > self.clock:
                    continue
                mb = self._sizes.get(object_id, 0.0)
                # read at actual + write at home
                src.free_at = max(src.free_at, self.clock) + mb / src.rate_mb_s
                dst = self.servers[home]
                dst.free_at = max(dst.free_at, self.clock) + mb / dst.rate_mb_s
                self._locations[object_id] = home
                table.pop(object_id)
                self.migrated_objects += 1
                moved += 1
        return moved

    def stats(self) -> Dict[str, float]:
        import numpy as np
        written = np.array([s.total_written_mb for s in self.servers])
        return {
            "clock_s": self.clock,
            "max_written_mb": float(written.max()),
            "cv_written": float(written.std() / written.mean()) if written.mean() else 0.0,
            "redirect_entries": float(sum(len(t) for t in self.redirects)),
            "migrated": float(self.migrated_objects),
        }


# ---------------------------------------------------------------------------
# Local-FS store (real bytes; used by repro.checkpoint end-to-end)
# ---------------------------------------------------------------------------


class LocalFSStore:
    """Object store backed by one directory per server.

    Layout::

        root/server_003/obj_<hex16>.bin     object bytes
        root/server_003/_redirect.json      that server's redirect table
        root/server_003/_FAILED             failure marker (injection)

    Stragglers are emulated with a per-server ``delay_s_per_mb`` (sleep on
    write/read), so tests exercise the ECT policy's rate observations with
    real wall-clock signal.
    """

    def __init__(self, root: str, n_servers: int):
        self.root = root
        self.n_servers = n_servers
        self._delay: Dict[int, float] = {}
        self._lock = threading.Lock()
        for srv in range(n_servers):
            os.makedirs(self._srv_dir(srv), exist_ok=True)

    # -- paths ----------------------------------------------------------------
    def _srv_dir(self, server: int) -> str:
        return os.path.join(self.root, f"server_{server:04d}")

    def _obj_path(self, server: int, object_id: int) -> str:
        return os.path.join(self._srv_dir(server), f"obj_{object_id:016x}.bin")

    def _redir_path(self, server: int) -> str:
        return os.path.join(self._srv_dir(server), "_redirect.json")

    # -- failure / straggler injection -----------------------------------------
    def fail_server(self, server: int) -> None:
        with open(os.path.join(self._srv_dir(server), "_FAILED"), "w"):
            pass

    def heal_server(self, server: int) -> None:
        try:
            os.remove(os.path.join(self._srv_dir(server), "_FAILED"))
        except FileNotFoundError:
            pass

    def is_failed(self, server: int) -> bool:
        return os.path.exists(os.path.join(self._srv_dir(server), "_FAILED"))

    def set_write_delay(self, server: int, delay_s_per_mb: float) -> None:
        self._delay[server] = delay_s_per_mb

    # -- redirect table ---------------------------------------------------------
    def _load_redir(self, server: int) -> Dict[str, int]:
        try:
            with open(self._redir_path(server)) as f:
                return json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            return {}

    def _save_redir(self, server: int, table: Dict[str, int]) -> None:
        tmp = self._redir_path(server) + ".tmp"
        with open(tmp, "w") as f:
            json.dump(table, f)
        os.replace(tmp, self._redir_path(server))

    def set_redirect(self, home: int, object_id: int, actual: int) -> None:
        with self._lock:
            t = self._load_redir(home)
            t[str(object_id)] = actual
            self._save_redir(home, t)

    def get_redirect(self, home: int, object_id: int) -> Optional[int]:
        with self._lock:
            return self._load_redir(home).get(str(object_id))

    def pop_redirect(self, home: int, object_id: int) -> None:
        with self._lock:
            t = self._load_redir(home)
            if t.pop(str(object_id), None) is not None:
                self._save_redir(home, t)

    def redirect_count(self) -> int:
        with self._lock:
            return sum(len(self._load_redir(s)) for s in range(self.n_servers))

    # -- data path ----------------------------------------------------------------
    def default_home(self, object_id: int) -> int:
        return object_id % self.n_servers

    def write_object(self, object_id: int, data: bytes, server: int) -> WriteResult:
        if self.is_failed(server):
            raise ServerFailedError(f"server {server} is down")
        t0 = time.monotonic()
        mb = len(data) / MB
        delay = self._delay.get(server, 0.0)
        if delay:
            time.sleep(delay * max(mb, 0.001))
        tmp = self._obj_path(server, object_id) + ".tmp"
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, self._obj_path(server, object_id))
        home = self.default_home(object_id)
        if server != home:
            self.set_redirect(home, object_id, server)
        else:
            self.pop_redirect(home, object_id)
        return WriteResult(server=server, mb=mb, issued_at=t0,
                           finished_at=time.monotonic())

    def locate(self, object_id: int) -> int:
        """Default home -> redirect entry -> replica scan; failed servers
        are skipped so reads fall through to a surviving copy."""
        home = self.default_home(object_id)
        if not self.is_failed(home) and \
                os.path.exists(self._obj_path(home, object_id)):
            return home
        redir = self.get_redirect(home, object_id) \
            if not self.is_failed(home) else None
        if redir is not None and not self.is_failed(redir) and \
                os.path.exists(self._obj_path(redir, object_id)):
            return redir
        # scan as last resort (failed home / replica reads)
        for srv in range(self.n_servers):
            if not self.is_failed(srv) and \
                    os.path.exists(self._obj_path(srv, object_id)):
                return srv
        raise ObjectMissingError(object_id)

    def read_object(self, object_id: int, server: Optional[int] = None) -> bytes:
        server = self.locate(object_id) if server is None else server
        if self.is_failed(server):
            raise ServerFailedError(f"server {server} is down")
        delay = self._delay.get(server, 0.0)
        path = self._obj_path(server, object_id)
        with open(path, "rb") as f:
            data = f.read()
        if delay:
            time.sleep(delay * max(len(data) / MB, 0.001))
        return data

    def delete_object(self, object_id: int) -> None:
        for srv in range(self.n_servers):
            try:
                os.remove(self._obj_path(srv, object_id))
            except FileNotFoundError:
                pass
        self.pop_redirect(self.default_home(object_id), object_id)

    # -- metadata maintainer ---------------------------------------------------------
    def maintainer_tick(self, max_objects: int = 16) -> int:
        """Move redirected objects home and drop their entries (§3.1)."""
        moved = 0
        for home in range(self.n_servers):
            if self.is_failed(home):
                continue
            for oid_s, actual in list(self._load_redir(home).items()):
                if moved >= max_objects:
                    return moved
                oid = int(oid_s)
                if self.is_failed(actual):
                    continue
                try:
                    data = self.read_object(oid, actual)
                except (FileNotFoundError, ObjectMissingError):
                    self.pop_redirect(home, oid)
                    continue
                self.write_object(oid, data, home)
                try:
                    os.remove(self._obj_path(actual, oid))
                except FileNotFoundError:
                    pass
                moved += 1
        return moved


class MaintainerThread(threading.Thread):
    """Background metadata maintainer (§3.1's 'runs when idle' thread)."""

    def __init__(self, store, interval_s: float = 0.05, max_objects: int = 16):
        super().__init__(daemon=True)
        self.store = store
        self.interval_s = interval_s
        self.max_objects = max_objects
        # NB: must not be named _stop — threading.Thread.join() calls the
        # private Thread._stop() internally on CPython >= 3.10.
        self._stop_evt = threading.Event()
        self.total_moved = 0

    def run(self) -> None:
        while not self._stop_evt.is_set():
            try:
                self.total_moved += self.store.maintainer_tick(self.max_objects)
            except Exception:  # pragma: no cover - never kill the daemon
                pass
            self._stop_evt.wait(self.interval_s)

    def stop(self) -> None:
        self._stop_evt.set()
        self.join(timeout=5.0)
