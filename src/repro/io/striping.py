"""File → object striping (paper §2.1, Fig. 3).

Object-based parallel file systems split each file into fixed-size objects
distributed over object storage servers.  An I/O request that crosses an
object boundary is split into per-object sub-requests, each scheduled
independently (Fig. 3's ``I/O_2`` example).

Object IDs are derived from ``(file_id, stripe_index)`` with a mixing hash
so that the default round-robin home ``object_id mod M`` spreads files
evenly (a linear id scheme would alias every file's stripe k onto the same
server for M | stripe_count).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, List, Sequence

MB = 1024 * 1024


def _mix64(x: int) -> int:
    """SplitMix64 finalizer — cheap, stable across runs/processes."""
    x = (x + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
    return (x ^ (x >> 31)) & 0x7FFFFFFFFFFFFFFF  # keep it positive int63


def object_id_for(file_id: int, stripe_index: int) -> int:
    return _mix64((file_id << 20) ^ stripe_index)


@dataclasses.dataclass(frozen=True)
class ObjectRequest:
    """One scheduled unit: a contiguous byte range of one object (Fig. 8's
    I/O request table row: object id, offset, length)."""

    object_id: int
    offset: int          # bytes from the object's start
    length: int          # bytes
    file_id: int = -1
    stripe_index: int = -1
    file_offset: int = 0  # where these bytes live in the file

    @property
    def length_mb(self) -> float:
        return self.length / MB


@dataclasses.dataclass(frozen=True)
class StripingConfig:
    stripe_size: int = 4 * MB   # object size in bytes (Lustre-like default)

    def __post_init__(self):
        if self.stripe_size <= 0:
            raise ValueError("stripe_size must be positive")


def stripe_request(cfg: StripingConfig, file_id: int, offset: int,
                   length: int) -> List[ObjectRequest]:
    """Split a file-level (offset, length) request into object sub-requests."""
    if length < 0 or offset < 0:
        raise ValueError("offset/length must be non-negative")
    out: List[ObjectRequest] = []
    pos = offset
    end = offset + length
    while pos < end:
        stripe = pos // cfg.stripe_size
        within = pos - stripe * cfg.stripe_size
        take = min(cfg.stripe_size - within, end - pos)
        out.append(ObjectRequest(
            object_id=object_id_for(file_id, stripe),
            offset=within, length=take,
            file_id=file_id, stripe_index=stripe, file_offset=pos))
        pos += take
    return out


def stripe_file(cfg: StripingConfig, file_id: int, size: int) -> List[ObjectRequest]:
    """Full-file write/read plan: one request per stripe."""
    return stripe_request(cfg, file_id, 0, size)


def n_stripes(cfg: StripingConfig, size: int) -> int:
    return max(1, -(-size // cfg.stripe_size))
