"""repro.kernels — Pallas TPU kernels for the perf-critical hot spots.

* ``flash_attention`` — VMEM-tiled online-softmax GQA attention
  (causal / sliding-window / chunked-local), the fused form of
  ``repro.models.attention.attend_blocked``.
* ``sched_select``    — the paper's per-request scheduling loop with the
  packed (4, M) statistic table (policy_core layout) resident in VMEM
  (log streaming, zero probes).  Its temporal form ``sched_stream`` runs
  an entire windowed ``engine.run_stream`` trace — selection, threshold
  guard, Eq. (1)-(3), completion feedback, per-window renorm + queue
  drain — as ONE pallas_call, bit-exact with the JAX engine
  (``engine.run_stream(backend="kernel")``).

Each kernel ships ``kernel.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jit'd wrapper, auto-interpret on CPU) and ``ref.py`` (pure-jnp oracle);
tests sweep shapes/dtypes and assert allclose against the oracle.
"""
