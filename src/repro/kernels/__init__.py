"""repro.kernels — Pallas TPU kernels for the perf-critical hot spots.

* ``flash_attention`` — VMEM-tiled online-softmax GQA attention
  (causal / sliding-window / chunked-local), the fused form of
  ``repro.models.attention.attend_blocked``.
* ``sched_select``    — the paper's per-request scheduling loop with the
  server statistic table resident in VMEM (log streaming, zero probes).

Each kernel ships ``kernel.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jit'd wrapper, auto-interpret on CPU) and ``ref.py`` (pure-jnp oracle);
tests sweep shapes/dtypes and assert allclose against the oracle.
"""
