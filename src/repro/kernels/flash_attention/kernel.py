"""Pallas TPU flash-attention kernel (causal / sliding-window / chunked).

TPU adaptation of the paper-side insight "keep hot state resident instead
of re-reading it": the online-softmax accumulators (acc, m, l) live in VMEM
scratch across the whole KV sweep, and K/V stream through VMEM tiles sized
by BlockSpec — the (S x S) score matrix never touches HBM (the XLA path in
``repro.models.attention`` materializes per-block scores to HBM; compare
the §Roofline memory terms).

Grid: ``(B*H, n_q_blocks, n_k_blocks)`` — the innermost (k) dimension is
sequential on TPU, so scratch carries state across it; the output tile is
written at the last k step.  GQA is handled in the index maps (query head
-> kv head arithmetic), masking supports causal, sliding-window and
chunked-local with full-block skipping via ``pl.when``.

Validated in interpret mode on CPU against ``ref.py`` (tests sweep shapes,
dtypes, window/chunk modes); TPU is the deployment target.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  block_q: int, block_k: int, seq_len: int, n_k: int,
                  causal: bool, window: Optional[int], chunk: Optional[int],
                  scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # --- full-block skip test (static policy, dynamic indices) ------------
    if causal:
        live = k_start <= q_start + block_q - 1          # not fully future
        if window is not None:
            # block fully left of every query's window?
            live = jnp.logical_and(
                live, k_start + block_k - 1 >= q_start - (window - 1))
        if chunk is not None:
            live = jnp.logical_and(
                live, k_start + block_k - 1 >= (q_start // chunk) * chunk)
    else:
        live = True

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = cols < seq_len                             # kv padding
        if causal:
            mask = jnp.logical_and(mask, cols <= rows)
            if window is not None:
                mask = jnp.logical_and(mask, rows - cols < window)
            if chunk is not None:
                mask = jnp.logical_and(mask, rows // chunk == cols // chunk)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]                             # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)         # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)                   # (bq, 1)
        p = jnp.exp(s - m_new)                            # (bq, bk)
        # fully-masked rows: keep accumulators exactly zero
        p = jnp.where(m_new > NEG_INF / 2, p, 0.0)
        alpha = jnp.where(m_new > NEG_INF / 2, alpha, 1.0)

        v = v_ref[0].astype(jnp.float32)                  # (bk, hd)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(ki == n_k - 1)
    def _finish():
        l = l_ref[:, :1]
        o_ref[0, ...] = (acc_ref[...] /
                         jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: Optional[int] = None,
                        chunk: Optional[int] = None, block_q: int = 128,
                        block_k: int = 128,
                        interpret: bool = False) -> jax.Array:
    """q: (B, S, H, hd); k, v: (B, S, KV, hd) -> (B, S, H, hd).

    S is padded to block multiples internally; GQA via index-map
    arithmetic.  This is the inference/forward kernel; training uses the
    XLA path (a bwd kernel is a straightforward extension).
    """
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    s_pad = -(-s // max(block_q, block_k)) * max(block_q, block_k)

    def pad_seq(x):
        if x.shape[1] == s_pad:
            return x
        return jnp.pad(x, ((0, 0), (0, s_pad - x.shape[1]), (0, 0), (0, 0)))

    # fold batch x heads; keep kv shared per group via index arithmetic
    qf = pad_seq(q).transpose(0, 2, 1, 3).reshape(b * h, s_pad, hd)
    kf = pad_seq(k).transpose(0, 2, 1, 3).reshape(b * kvh, s_pad, hd)
    vf = pad_seq(v).transpose(0, 2, 1, 3).reshape(b * kvh, s_pad, hd)

    n_q = s_pad // block_q
    n_k = s_pad // block_k

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        return ((bh // h) * kvh + (bh % h) // g, ki, 0)

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, seq_len=s,
        n_k=n_k, causal=causal, window=window, chunk=chunk,
        scale=1.0 / (hd ** 0.5))

    out = pl.pallas_call(
        kernel,
        grid=(b * h, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), q_map),
            pl.BlockSpec((1, block_k, hd), kv_map),
            pl.BlockSpec((1, block_k, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((b * h, s_pad, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),   # acc
            pltpu.VMEM((block_q, 1), jnp.float32),    # m (running max)
            pltpu.VMEM((block_q, 1), jnp.float32),    # l (running sum)
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out.reshape(b, h, s_pad, hd).transpose(0, 2, 1, 3)
    return out[:, :s]
