"""Jit'd public wrapper for the flash attention kernel.

On CPU (this container) the kernel runs in interpret mode automatically;
on TPU it compiles to Mosaic.  ``repro.models.attention.self_attend``
routes here when ``cfg.use_pallas_attn`` is set.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.flash_attention.kernel import flash_attention_fwd


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "chunk",
                                             "is_global", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    chunk: Optional[int] = None,
                    is_global: bool = False,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None):
    """Fused GQA attention. q: (B,S,H,hd); k,v: (B,S,KV,hd)."""
    if is_global:          # llama4 global layers: plain causal
        window = chunk = None
    if interpret is None:
        interpret = _on_cpu()
    s = q.shape[1]
    bq = min(block_q, max(8, s))
    bk = min(block_k, max(8, s))
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               chunk=chunk, block_q=bq, block_k=bk,
                               interpret=interpret)
