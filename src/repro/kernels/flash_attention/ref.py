"""Pure-jnp oracle for the flash attention kernel (no blocking tricks)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: Optional[int] = None,
                  chunk: Optional[int] = None) -> jax.Array:
    """q: (B,S,H,hd); k,v: (B,S,KV,hd) -> (B,S,H,hd), f32 math."""
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qf = q.astype(jnp.float32).reshape(b, s, kvh, g, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qf, kf) / (hd ** 0.5)
    if causal:
        rows = jnp.arange(s)[:, None]
        cols = jnp.arange(s)[None, :]
        mask = cols <= rows
        if window is not None:
            mask &= rows - cols < window
        if chunk is not None:
            mask &= rows // chunk == cols // chunk
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, vf)
    return out.reshape(b, s, h, hd).astype(q.dtype)
