from repro.kernels.sched_select.ops import sched_select  # noqa: F401
from repro.kernels.sched_select.ops import sched_stream  # noqa: F401
from repro.kernels.sched_select.ops import sched_stream_batch  # noqa: F401
from repro.kernels.sched_select.ops import sched_stream_grid  # noqa: F401
from repro.kernels.sched_select.ref import sched_select_ref  # noqa: F401
from repro.kernels.sched_select.ref import sched_stream_ref  # noqa: F401
from repro.kernels.sched_select.ref import sched_stream_batch_ref  # noqa: F401
from repro.kernels.sched_select.ref import sched_stream_grid_ref  # noqa: F401
