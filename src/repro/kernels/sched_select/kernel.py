"""Pallas kernel: stream I/O requests through a VMEM-resident statistic log.

This is the TPU-native adaptation of the paper's core insight (DESIGN.md):
*replace remote probing with local state*.  Scheduling a request stream
against an M-server statistic table is a sequential-dependence loop whose
working set — the packed ``(4, M)`` log tensor of `repro.core.policy_core`
(rows ``loads / probs / ewma_lat / est_rates``) — is reused every
iteration: the kernel pins the whole table in one VMEM scratch for the
entire stream and emits one assignment per request, instead of bouncing
the carry through XLA's while-loop machinery (HBM round trips per
decision).

TRIAL-GRID form (DESIGN.md §9): the whole Monte-Carlo sweep — T
independent windowed `run_stream` traces — runs as ONE ``pallas_call``
with ``grid = (T / t_tile,)``.  Each program instance owns a
``t_tile``-trial slice of the packed ``(T, 4, M)`` log stack, the
``(T, W, M)`` rate traces and the ``(T, N)`` request/latency blocks, and
holds its trials' tables in one ``(4, t_tile, M_pad)`` VMEM scratch.
Trials are INDEPENDENT streams, so the per-request decision loop
vectorizes over the trial sublane axis: every op below acts on
``(t_tile, M_pad)`` tiles — the native f32 ``(8, 128)`` TPU tile at the
default ``t_tile = 8`` — and ``t_tile = 1`` degenerates to the original
single-stream kernel bit-for-bit (same ops on ``(1, M_pad)`` rows).
Grid = independent clients OR independent trials; there is no cross-
stream gossip, exactly as in the paper §3.3.

2-D (TRIALS × CLIENTS) GRID form (DESIGN.md §11): the per_client
contention model — T trials, each partitioned over C private-log
clients — runs as ONE ``pallas_call`` with ``grid = (T / t_tile,
C / c_tile)``.  Per-stream operands carry both axes; the rate/drain
traces stay per-TRIAL (a trial's clients share its cluster schedule)
and broadcast over the client sublanes in-VMEM.  The decision loop is
the SAME function — the block flattens to ``t_tile * c_tile`` stream
sublanes — and before a block retires it folds its clients into
per-trial cross-client aggregates (masked client-mean window loads,
merged metric row with real-client count) accumulated across the
client grid dimension with the `policy_core.masked_client_sum`
association, so the jax path's merge is bit-identical.

Per window the kernel snapshots the probability ranking (TRH's plan),
loops the window's requests (selection → threshold guard → Eq. (1)-(3)
one-hot updates → completion feedback into the ewma/est rows), then
renormalizes the probability row and drains each server's queue at the
window's TRUE service rates (``advance_time`` semantics; rates streamed
in as a ``(W, M)`` input).  Policies (selected statically):

* ``minload``    — argmin of current load (greedy; ECT with unit rates);
* ``two_random`` — power-of-two-choices over ALL servers (no probe
  messages; the in-VMEM LCG supplies the randomness);
* ``ect``        — argmin expected completion time ``(load+len)/est_rate``
  on the client-ESTIMATED rate row (stale view — observations only);
* ``trh``        — Two Random from Top Half: two LCG draws over the
  lightest M/2 servers of the probability ranking (paper Alg. 2);
* ``rr``         — round-robin baseline (``object_id mod M``, no guard);
* ``two_choice`` — the SC'14 probing baseline: default + LCG-random
  candidates, lightest by live load (probes counted host-side);
* ``mlml``       — Max Length - Min Load (paper Alg. 1): the window's
  requests sorted by length desc, paired circularly with the
  probability-sorted servers;
* ``nltr``       — n-Level Two Random (paper Alg. 3): servers cut into
  ``K = 2**n`` sections of the probability ranking, requests cut into K
  sections by recursive average of the sorted lengths; two LCG draws
  inside the matching section.

All policies except ``rr`` apply the paper's redirect-threshold guard
against the round-robin default ``object_id mod M`` and the Eq. (1)-(3)
updates with one-hot *vector* writes (no scatter — TPU lanes update
masked).  SORT-BASED POLICIES (DESIGN.md §10, §13): the per-window
server ranking AND the MLML/nLTR request ordering run IN-VMEM through
`policy_core.rank_desc` — ONE all-pairs (key desc, index asc)
comparison per ranking instead of a compare-exchange network — and
`policy_core.permute_to_sorted`, which lands obj/len/valid (and the
server ids) in sorted order as a single masked-sum permutation apply
(no gather op; ``jnp.argsort`` does not lower inside a fused Pallas
body, and its tie/tree behaviour is a backend choice).  The comparator
is a strict total order, so the permutation equals the engine's stable
``argsort`` bit-for-bit; nLTR's section bounds come from the shared
`policy_core.recursive_average_bounds` on the natural-width sorted keys
with `lane_sum`-associated means.  MLML/nLTR loop the window in sorted
order via POSITION one-hots, accumulate decisions/latencies in sorted
order, and unsort both with ONE vectorized
`policy_core.permute_from_sorted` apply per window; the fused metrics
then reduce in ORIGINAL request order, matching
`policy_core.stream_metrics` (maxima and the valid count are order-free
exact and collapse to vectorized masked reductions — only the latency
sum keeps the host twin's sequential per-request float-add chain).

FUSED METRICS (DESIGN.md §9): before a program instance retires, it
reduces its trials' per-step latencies — still VMEM-resident — into a
``(t_tile, MET_PAD)`` metrics row (makespan, nearest-rank p99 via f32
value bisection, latency sum in request order, latency max, valid count;
`policy_core.MET_*` layout), so the sweep's headline numbers never
round-trip through HBM.  ``policy_core.stream_metrics`` is the bit-exact
host twin.

``ref.py`` is the bit-exact jnp oracle; `engine.run_stream(backend=...)`
/ `engine.run_stream_batch` parity is asserted in tests/test_kernels.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.policy_core import (LCG_A, LCG_C, MET_LAT_MAX, MET_LAT_SUM,
                                    MET_MAKESPAN, MET_N_CLIENTS, MET_N_VALID,
                                    MET_P99, MET_PAD, N_ROWS,
                                    P99_BISECT_ITERS, P99_Q, ROW_EST,
                                    ROW_EWMA, ROW_LOADS, ROW_PROBS, lane_sum,
                                    permute_from_sorted, permute_to_sorted,
                                    rank_desc, recursive_average_bounds,
                                    tree_sum, window_decrements)

_BIG = 3.4e38  # padding-lane load: never selected, never drained


def _lcg(rng):
    return rng * jnp.uint32(LCG_A) + jnp.uint32(LCG_C)


def _lcg_mod(rng, n: int):
    return jax.lax.rem((rng >> jnp.uint32(8)).astype(jnp.int32)
                       & jnp.int32(0x7FFFFFFF), n)


def _sched_stream_kernel(objs_ref, lens_ref, valid_ref, table_ref, seed_ref,
                         rates_ref, dec_ref, choices_ref, lats_ref,
                         final_table_ref, wloads_ref, metrics_ref, *rest,
                         n_windows: int,
                         window_size: int, n_servers: int, m_pad: int,
                         t_tile: int, threshold: float, lam: float,
                         alpha: float, window_dt: float, policy: str,
                         observe: bool, renorm: bool, nltr_n: int,
                         probe_choices: int, client_tile: int = 0,
                         n_client_blocks: int = 1, merge_mean: bool = True,
                         ablate: int = 0):
    """One program instance of the stream kernel.

    ``ablate`` (trial-grid form only) drops whole window phases for
    DIFFERENTIAL per-phase profiling (DESIGN.md §16): 0 = the full
    kernel; 1 = skip the fused metrics reduction; 2 = also skip the
    per-request step loop; 3 = also skip the window-start sort/plan.
    Levels are cumulative so every retained phase still sees the inputs
    it would normally see.  Ablated outputs are NOT contract-bearing
    (choices/latencies/metrics are zeros past the dropped phase) — the
    levels exist only so `benchmarks/sched_perf.py` can attribute the
    kernel's wall time phase by phase via timing differences.

    Trial-grid form (``client_tile == 0``): refs carry a leading
    ``t_tile`` stream axis; ``rest`` is the ``(N_ROWS, t_tile, m_pad)``
    table scratch.  2-D (trials × clients) grid form (DESIGN.md §11):
    per-stream refs carry ``(t_tile, client_tile)`` leading axes, the
    per-trial rate/decrement refs stay client-shared ``(t_tile, ...)``,
    and ``rest`` is ``(cm_wloads_ref, cm_metrics_ref, cm_lats_ref,
    cm_lval_ref, tbl)`` — the per-TRIAL cross-client accumulators
    revisited across the client grid dimension (merged metric row,
    window-load sums, and the MERGED LATENCY BLOCK of DESIGN.md §14:
    each client grid step deposits its clients' masked grouped-step
    latencies and validity into a ``(t_tile, C_pad, N)`` VMEM-resident
    pair, so the last step can reduce the cross-client nearest-rank p99
    while the whole merged block is still on-chip), plus the scratch.
    The decision loop itself is identical: the ``t_tile * client_tile``
    independent streams ride the sublane axis exactly like trials do in
    the 1-D form."""
    m = n_servers
    grid_2d = client_tile > 0
    if grid_2d and ablate:
        raise ValueError("ablate profiling levels support the trial-grid "
                         "(1-D) form only")
    do_metrics = ablate < 1
    do_steps = ablate < 2
    do_plan = ablate < 3
    if grid_2d:
        cm_wloads_ref, cm_metrics_ref, cm_lats_ref, cm_lval_ref, tbl = rest
        s_tile = t_tile * client_tile

        def req_read(ref, start, size):
            return jnp.reshape(ref[:, :, pl.ds(start, size)], (s_tile, size))

        def req_write(ref, start, val):
            ref[:, :, pl.ds(start, val.shape[-1])] = jnp.reshape(
                val, (t_tile, client_tile) + val.shape[1:])

        def trial_row(ref, w):
            # (t_tile, m_pad) per-trial row, broadcast over the client
            # sublanes (all of a trial's clients share its trace rates)
            r = ref[:, pl.ds(w, 1), :][:, 0, :]
            return jnp.reshape(jnp.broadcast_to(
                r[:, None, :], (t_tile, client_tile, m_pad)),
                (s_tile, m_pad))

        def wl_write(ref, w, val):
            ref[:, :, pl.ds(w, 1), :] = jnp.reshape(
                val, (t_tile, client_tile, 1, m_pad))

        def ftab_write(row, val):
            final_table_ref[:, :, row, :] = jnp.reshape(
                val, (t_tile, client_tile, m_pad))

        def all_req(ref):
            return jnp.reshape(ref[...], (s_tile, -1))

        intab = jnp.reshape(table_ref[...], (s_tile, N_ROWS, m_pad))
        seed0 = jnp.reshape(seed_ref[...], (s_tile, 1))
    else:
        (tbl,) = rest
        s_tile = t_tile

        def req_read(ref, start, size):
            return ref[:, pl.ds(start, size)]

        def req_write(ref, start, val):
            ref[:, pl.ds(start, val.shape[-1])] = val

        def trial_row(ref, w):
            return ref[:, pl.ds(w, 1), :][:, 0, :]

        def wl_write(ref, w, val):
            ref[:, pl.ds(w, 1), :] = val[:, None, :]

        def ftab_write(row, val):
            final_table_ref[:, row, :] = val

        def all_req(ref):
            return ref[...]

        intab = table_ref[...]                  # (t_tile, 4, m_pad)
        seed0 = seed_ref[...]                   # (t_tile, 1)

    lane = jax.lax.broadcasted_iota(jnp.int32, (1, m_pad), 1)
    lv = lane < m                               # valid (non-padding) lanes

    # --- pin the packed log stack in VMEM scratch --------------------------
    # tbl is (N_ROWS, s_tile, m_pad): tbl[row] is this tile's streams' row,
    # one (s_tile, m_pad) tile per op below (streams ride the sublanes).
    tbl[ROW_LOADS] = jnp.where(lv, intab[:, ROW_LOADS, :], _BIG)
    tbl[ROW_PROBS] = jnp.where(lv, intab[:, ROW_PROBS, :], 0.0)
    tbl[ROW_EWMA] = jnp.where(lv, intab[:, ROW_EWMA, :], 0.0)
    tbl[ROW_EST] = jnp.where(lv, intab[:, ROW_EST, :], 1.0)

    def pick(rows, onehot):
        """Extract rows[onehot] per trial without gather (masked sum)."""
        return jnp.sum(jnp.where(onehot, rows, 0.0), axis=-1, keepdims=True)

    def window_body(w, carry):
        rng, mk, lsum, lmax, nval = carry
        cur_rates = jnp.where(lv, trial_row(rates_ref, w), 1.0)
        sort_policy = policy in ("mlml", "nltr")

        if policy in ("trh", "mlml", "nltr") and do_plan:
            # Window-start plan (DESIGN.md §13): rank servers by
            # probability desc with ONE all-pairs comparison, then land
            # the server ids in rank order with a single permutation
            # apply.  Padding lanes get -inf keys so positions [0, M)
            # are exactly the engine's stable argsort(-probs)
            # permutation — same strict total order, no sort network.
            rank_srv, _ = rank_desc(
                tbl[ROW_PROBS], valid=jnp.broadcast_to(lv, (s_tile, m_pad)))
            (order_srv,) = permute_to_sorted(
                rank_srv, (jnp.broadcast_to(lane, (s_tile, m_pad)),))
            srt_lane = jax.lax.broadcasted_iota(
                jnp.int32, (1, order_srv.shape[-1]), 1)

        def server_at(p):
            """Server id at sorted position p (one-hot masked sum)."""
            return jnp.sum(jnp.where(srt_lane == p, order_srv, 0), axis=-1,
                           keepdims=True).astype(jnp.int32)

        if sort_policy and do_plan:
            # MLML/nLTR process the window's requests in length-desc
            # order (DESIGN.md §13): rank the request block with one
            # all-pairs comparison, land obj/len/valid in sorted order
            # with one permutation apply, and loop over POSITIONS — the
            # per-step one-hot selects by position, no gathered order
            # value and no per-step ref reads.
            start = w * window_size
            obj_w = req_read(objs_ref, start, window_size)   # (s, ws)
            len_w = req_read(lens_ref, start, window_size)
            val_w = req_read(valid_ref, start, window_size) != 0
            rank_req, mkeys = rank_desc(len_w, valid=val_w)
            obj_s, len_s, val_s = permute_to_sorted(
                rank_req, (obj_w, len_w, val_w.astype(jnp.int32)))
            ws_lane = jax.lax.broadcasted_iota(jnp.int32, (1, window_size), 1)
            if policy == "nltr":
                nvalid = jnp.sum(val_w.astype(jnp.int32), axis=-1,
                                 keepdims=True)
                # sorted keys (-inf at invalid) for the section bounds;
                # natural width — lane_sum's zero-padded halving tree is
                # width-independent, so the bounds match the engine's
                skeys = permute_to_sorted(rank_req, (mkeys,))[0]
                bounds = recursive_average_bounds(skeys, nvalid, nltr_n)
                sec_size = max(m // 2 ** nltr_n, 1)
                n_sections = 2 ** nltr_n

        def schedule_one(j, obj, ln, v, rng):
            """Selection + guard + Eq. (1)-(3)/feedback for one request per
            trial; mutates the VMEM table, returns (choose, lat, latv,
            rng).  ``j`` is the PROCESSING position in the window (==
            request position except for the sorted policies)."""
            loads = tbl[ROW_LOADS]
            probs = tbl[ROW_PROBS]
            est = tbl[ROW_EST]
            default = jax.lax.rem(obj, m)

            # -- target selection (policy_core decision math) --------------
            if policy == "rr":
                target = default
            elif policy == "minload":
                target = jnp.argmin(loads, axis=-1,
                                    keepdims=True).astype(jnp.int32)
            elif policy == "ect":
                scores = (loads + ln) / est
                target = jnp.argmin(scores, axis=-1,
                                    keepdims=True).astype(jnp.int32)
            elif policy == "mlml":
                # j-th longest request -> j-th lightest server (Alg. 1)
                target = server_at(jnp.reshape(jax.lax.rem(j, m), (1, 1)))
            elif policy == "nltr":
                # request section from the recursive-average bounds, two
                # LCG draws inside the matching server section (Alg. 3)
                sec = jnp.sum((j >= bounds).astype(jnp.int32), axis=-1,
                              keepdims=True)
                sec = jnp.clip(sec, 0, n_sections - 1)
                lo = sec * sec_size
                r1 = _lcg(rng)
                r2 = _lcg(r1)
                rng = r2
                c1 = server_at(lo + _lcg_mod(r1, sec_size))
                c2 = server_at(lo + _lcg_mod(r2, sec_size))
                l1 = pick(loads, lane == c1)
                l2 = pick(loads, lane == c2)
                target = jnp.where(l1 <= l2, c1, c2).astype(jnp.int32)
            elif policy == "two_choice":
                # SC'14 baseline: default + LCG-random candidates, first
                # min by live load (matches jnp.argmin's tie rule)
                target = default
                best_l = pick(loads, lane == default)
                for _ in range(probe_choices - 1):
                    rng = _lcg(rng)
                    c = _lcg_mod(rng, m)
                    l_c = pick(loads, lane == c)
                    better = l_c < best_l
                    target = jnp.where(better, c, target).astype(jnp.int32)
                    best_l = jnp.where(better, l_c, best_l)
            elif policy in ("two_random", "trh"):
                r1 = _lcg(rng)
                r2 = _lcg(r1)
                rng = r2
                if policy == "two_random":
                    c1 = _lcg_mod(r1, m)
                    c2 = _lcg_mod(r2, m)
                else:  # trh: two positions in the lightest half
                    half = max(m // 2, 1)
                    c1 = server_at(_lcg_mod(r1, half))
                    c2 = server_at(_lcg_mod(r2, half))
                l1 = pick(loads, lane == c1)
                l2 = pick(loads, lane == c2)
                target = jnp.where(l1 <= l2, c1, c2).astype(jnp.int32)
            else:  # pragma: no cover
                raise ValueError(policy)

            # -- redirect-threshold guard (§3.4.1; rr has no guard) --------
            if policy == "rr":
                choose = default
            else:
                l_def = pick(loads, lane == default)
                l_tgt = pick(loads, lane == target)
                if policy == "ect":
                    # rate-aware benefit in expected seconds, on EST rates
                    r_def = pick(est, lane == default)
                    r_tgt = pick(est, lane == target)
                    benefit = (l_def + ln) / r_def - (l_tgt + ln) / r_tgt
                else:
                    benefit = l_def - l_tgt
                choose = jnp.where(benefit > threshold, target,
                                   default).astype(jnp.int32)

            # -- Eq. (1)-(3) one-hot updates (masked on padding rows) ------
            onehot = lane == choose                          # (t, m_pad)
            upd = onehot & v
            new_loads = jnp.where(upd, loads + ln, loads)    # Eq. (1)
            tbl[ROW_LOADS] = new_loads
            p_i = pick(probs, onehot)
            l_i = pick(new_loads, onehot)
            e = jnp.exp(-l_i / lam)
            decayed = p_i * e                                # Eq. (2)
            delta = p_i * (1.0 - e) / (m - 1)                # Eq. (3)
            new_probs = jnp.where(onehot, decayed,
                                  jnp.where(lv, probs + delta, 0.0))
            tbl[ROW_PROBS] = jnp.where(v, new_probs, probs)

            # -- estimated completion latency + completion feedback --------
            l_after = pick(new_loads, onehot)
            rate_c = pick(cur_rates, onehot)                 # TRUE rate
            lat = l_after / jnp.maximum(rate_c, 1e-6)
            latv = jnp.where(v, lat, 0.0)
            if observe:
                # effective MB/s this request will see -> ewma row; est
                # row re-derived from observations ONLY (stale view).
                mbps = ln / jnp.maximum(lat, 1e-9)
                ewma = tbl[ROW_EWMA]
                old = pick(ewma, onehot)
                new = jnp.where(old == 0.0, mbps,
                                # contract-ok: CC-FMA EWMA row is 1e-6-soft (§9)
                                (1 - alpha) * old + alpha * mbps)
                new_ewma = jnp.where(upd, new, ewma)
                tbl[ROW_EWMA] = new_ewma
                dflt = jnp.maximum(jnp.max(new_ewma, axis=-1, keepdims=True),
                                   1.0)
                tbl[ROW_EST] = jnp.where(new_ewma > 0, new_ewma, dflt)
            return choose, lat, latv, rng

        wopen = w.astype(jnp.float32) * jnp.float32(window_dt)

        if not do_steps:
            # ablate >= 2: the window keeps its renorm/drain bookkeeping
            # but schedules nothing — the timing delta vs level 1 is the
            # step loop's cost.
            carry = (rng, mk, lsum, lmax, nval)
        elif sort_policy:
            def sorted_req_body(j, carry):
                rng, ch_acc, lat_acc = carry
                sel = ws_lane == j              # PROCESSING position j
                obj = jnp.sum(jnp.where(sel, obj_s, 0), axis=-1,
                              keepdims=True)
                ln = jnp.sum(jnp.where(sel, len_s, 0.0), axis=-1,
                             keepdims=True)
                v = jnp.sum(jnp.where(sel, val_s, 0), axis=-1,
                            keepdims=True) != 0
                choose, lat, latv, rng = schedule_one(j, obj, ln, v, rng)
                # accumulate in SORTED order; ONE inverse apply at the
                # window close moves everything back at once (§13)
                ch_acc = jnp.where(sel, choose, ch_acc)
                lat_acc = jnp.where(sel, latv, lat_acc)
                return rng, ch_acc, lat_acc

            rng, ch_acc, lat_acc = jax.lax.fori_loop(
                0, window_size, sorted_req_body,
                (rng, jnp.zeros((s_tile, window_size), jnp.int32),
                 jnp.zeros((s_tile, window_size), jnp.float32)),
                unroll=False)
            ch_req, lat_req = permute_from_sorted(rank_req,
                                                  (ch_acc, lat_acc))
            req_write(choices_ref, start, ch_req)
            req_write(lats_ref, start, lat_req)

            # fused metrics in ORIGINAL request order (stream_metrics
            # twin).  makespan/lat_max are exact order-free f32 maxima
            # and the valid count is integer-exact under any summation
            # tree, so they collapse to vectorized masked reductions;
            # ONLY the latency sum keeps the host twin's sequential
            # per-request float-add chain (f32 adds do not reassociate).
            mk = jnp.maximum(mk, jnp.max(
                jnp.where(val_w, wopen + lat_req, -_BIG), axis=-1,
                keepdims=True))
            lmax = jnp.maximum(lmax, jnp.max(lat_req, axis=-1,
                                             keepdims=True))
            nval = nval + jnp.sum(jnp.where(val_w, 1.0, 0.0), axis=-1,
                                  keepdims=True)

            def lsum_body(j, acc):
                return acc + jnp.sum(jnp.where(ws_lane == j, lat_req, 0.0),
                                     axis=-1, keepdims=True)

            lsum = jax.lax.fori_loop(0, window_size, lsum_body, lsum,
                                     unroll=False)
            carry = (rng, mk, lsum, lmax, nval)
        else:
            def req_body(j, carry):
                rng, mk, lsum, lmax, nval = carry
                i = w * window_size + j
                obj = req_read(objs_ref, i, 1)               # (s, 1)
                ln = req_read(lens_ref, i, 1)
                v = req_read(valid_ref, i, 1) != 0
                choose, lat, latv, rng = schedule_one(j, obj, ln, v, rng)
                req_write(choices_ref, i, choose)
                req_write(lats_ref, i, latv)
                # -- fused metric accumulators (stream_metrics twin) -------
                mk = jnp.where(v, jnp.maximum(mk, wopen + lat), mk)
                lsum = lsum + latv
                lmax = jnp.maximum(lmax, latv)
                nval = nval + jnp.where(v, 1.0, 0.0)
                return rng, mk, lsum, lmax, nval

            carry = jax.lax.fori_loop(0, window_size, req_body,
                                      (rng, mk, lsum, lmax, nval),
                                      unroll=False)
            rng = carry[0]

        # -- window close: renormalize probs, drain queues (advance_time) --
        if renorm:
            # lane_sum: the shared explicit halving tree (§9 parity)
            p = jnp.clip(tbl[ROW_PROBS], 0.0)
            tbl[ROW_PROBS] = p / lane_sum(p)
        if window_dt:
            # Drain decrements arrive PRE-MULTIPLIED (window_decrements,
            # materialized as a kernel operand): an in-body rates*dt next
            # to this subtract gets FMA-contracted in some lowering
            # contexts but not others (observed tile-dependent), a 1-ulp
            # drift that breaks the §9 parity contract.  A bare subtract
            # rounds identically everywhere.
            loads = tbl[ROW_LOADS]
            dec = jnp.where(lv, trial_row(dec_ref, w), 0.0)
            drained = jnp.maximum(loads - dec, 0.0)
            tbl[ROW_LOADS] = jnp.where(lv, drained, _BIG)
        wl_write(wloads_ref, w, jnp.where(lv, tbl[ROW_LOADS], 0.0))
        return carry

    seed = seed0.astype(jnp.uint32)                          # (s, 1)
    zero = jnp.zeros((s_tile, 1), jnp.float32)
    _, mk, lsum, lmax, nval = jax.lax.fori_loop(
        0, n_windows, window_body, (seed, zero, zero, zero, zero),
        unroll=False)
    zero_pad = jnp.broadcast_to(~lv, (s_tile, m_pad))
    for row in range(N_ROWS):
        ftab_write(row, jnp.where(zero_pad, 0.0, tbl[row]))

    # -- fused metrics: reduce the VMEM-resident latency block -------------
    # (policy_core.stream_metrics / nearest_rank_p99 are the bit-exact
    # host twins — keep the float ops in lockstep with them.)
    lats_all = all_req(lats_ref)                             # (s, N)
    val_all = all_req(valid_ref) != 0
    mlane = jax.lax.broadcasted_iota(jnp.int32, (1, MET_PAD), 1)
    if do_metrics:
        k = jnp.ceil(jnp.float32(P99_Q) * nval)
        lo = jnp.full((s_tile, 1), -1.0, jnp.float32)
        hi = lmax

        def bisect(_, lo_hi):
            lo, hi = lo_hi
            mid = jnp.float32(0.5) * (lo + hi)
            cnt = jnp.sum(jnp.where(val_all & (lats_all <= mid), 1.0, 0.0),
                          axis=-1, keepdims=True)
            go_hi = cnt >= k
            return jnp.where(go_hi, lo, mid), jnp.where(go_hi, mid, hi)

        lo, _ = jax.lax.fori_loop(0, P99_BISECT_ITERS, bisect, (lo, hi))
        p99 = jnp.min(jnp.where(val_all & (lats_all > lo), lats_all, _BIG),
                      axis=-1, keepdims=True)
        p99 = jnp.where(nval > 0, p99, 0.0)
        met_row = (jnp.where(mlane == MET_MAKESPAN, mk, 0.0)
                   + jnp.where(mlane == MET_P99, p99, 0.0)
                   + jnp.where(mlane == MET_LAT_SUM, lsum, 0.0)
                   + jnp.where(mlane == MET_LAT_MAX, lmax, 0.0)
                   + jnp.where(mlane == MET_N_VALID, nval, 0.0))
    else:
        met_row = jnp.zeros((s_tile, MET_PAD), jnp.float32)
    if not grid_2d:
        metrics_ref[...] = met_row
        return
    metrics_ref[...] = jnp.reshape(met_row,
                                   (t_tile, client_tile, MET_PAD))

    # -- cross-client merge (2-D grid, DESIGN.md §11) ----------------------
    # Fold this block's client_tile client sublanes into per-TRIAL
    # aggregates while everything is VMEM-resident, then accumulate into
    # the (t_tile, ...) merge outputs revisited across the client grid
    # dimension: within-block sums run the policy_core.tree_sum halving
    # tree and blocks add SEQUENTIALLY in ascending client order — the
    # exact association of policy_core.masked_client_sum, so the jax
    # path's merge is bit-identical.  A client is REAL iff it scheduled
    # at least one valid step (nval > 0 ⇔ any(valid) — phantom padded
    # clients contribute exact zeros everywhere).
    j = pl.program_id(1)
    mk_c = jnp.reshape(mk, (t_tile, client_tile))
    lsum_c = jnp.reshape(lsum, (t_tile, client_tile))
    lmax_c = jnp.reshape(lmax, (t_tile, client_tile))
    nval_c = jnp.reshape(nval, (t_tile, client_tile))
    cvalid = nval_c > 0.0

    def csum(x):
        return tree_sum(jnp.where(cvalid, x, 0.0), axis=1)[:, 0:1]

    def cmax(x):
        return jnp.max(jnp.where(cvalid, x, 0.0), axis=1, keepdims=True)

    blk_row = (jnp.where(mlane == MET_MAKESPAN, cmax(mk_c), 0.0)
               + jnp.where(mlane == MET_LAT_SUM, csum(lsum_c), 0.0)
               + jnp.where(mlane == MET_LAT_MAX, cmax(lmax_c), 0.0)
               + jnp.where(mlane == MET_N_VALID, csum(nval_c), 0.0)
               + jnp.where(mlane == MET_N_CLIENTS,
                           csum(jnp.ones_like(nval_c)), 0.0))
    wl_c = jnp.reshape(wloads_ref[...],
                       (t_tile, client_tile, n_windows, m_pad))
    blk_wl = tree_sum(jnp.where(cvalid[:, :, None, None], wl_c, 0.0),
                      axis=1)[:, 0]                    # (t, n_win, m_pad)
    is_max_lane = (mlane == MET_MAKESPAN) | (mlane == MET_LAT_MAX)

    @pl.when(j == 0)
    def _init_merge():
        cm_wloads_ref[...] = blk_wl
        cm_metrics_ref[...] = blk_row

    @pl.when(j > 0)
    def _acc_merge():
        cm_wloads_ref[...] = cm_wloads_ref[...] + blk_wl
        prev = cm_metrics_ref[...]
        cm_metrics_ref[...] = jnp.where(is_max_lane,
                                        jnp.maximum(prev, blk_row),
                                        prev + blk_row)

    # -- merged latency block (DESIGN.md §14): deposit this block's
    # clients' masked grouped-step latencies into the per-TRIAL
    # (t_tile, C_pad, N) accumulator pair.  Each client grid step owns a
    # disjoint client slice, so every column is written exactly once per
    # trial row — no init/accumulate split needed.  Values are masked to
    # 0 where invalid (phantom clients are all-invalid, so they deposit
    # exact zeros) with the validity shipped alongside as 0/1 f32.
    n_req = n_windows * window_size
    lat_blk = jnp.reshape(jnp.where(val_all, lats_all, 0.0),
                          (t_tile, client_tile, n_req))
    val_blk = jnp.reshape(jnp.where(val_all, 1.0, 0.0),
                          (t_tile, client_tile, n_req))
    cm_lats_ref[:, pl.ds(j * client_tile, client_tile), :] = lat_blk
    cm_lval_ref[:, pl.ds(j * client_tile, client_tile), :] = val_blk

    if merge_mean:
        @pl.when(j == n_client_blocks - 1)
        def _finish_merge():
            # masked client-MEAN of the window loads: divide the
            # accumulated sum by the real-client count (>= 1) —
            # masked_client_mean's twin.  ``merge_mean=False`` skips the
            # divide and ships the raw masked client SUM instead: a mean
            # is not composable across devices, so the sharded sweep
            # (DESIGN.md §12) psum_tree's these per-device sum blocks and
            # divides once, globally.
            row = cm_metrics_ref[...]
            n_real = jnp.sum(jnp.where(mlane == MET_N_CLIENTS, row, 0.0),
                             axis=-1, keepdims=True)      # (t_tile, 1)
            denom = jnp.maximum(n_real, 1.0)[:, :, None]  # (t_tile, 1, 1)
            cm_wloads_ref[...] = cm_wloads_ref[...] / denom

        @pl.when(j == n_client_blocks - 1)
        def _finish_p99():
            # cross-client merged nearest-rank p99 (DESIGN.md §14): the
            # whole merged latency block is VMEM-resident now — run the
            # SAME f32 value bisection as the per-stream fused metrics
            # over the flattened (C_pad * N) merged lanes and land the
            # result in the one cm_metrics lane the client-step
            # accumulation left at 0.  Every reduction here (counts of
            # exact 0/1 floats, min/max) is order- and layout-
            # insensitive, so this matches `policy_core.nearest_rank_p99`
            # on the host's merged block bit-for-bit regardless of how
            # the clients were deposited.  ``merge_mean=False`` (the
            # sharded sweep) skips it — a local p99 is not composable
            # across devices; the sweep gathers the raw blocks and
            # bisects once, globally (parallel/sweep.py).
            c_pad = n_client_blocks * client_tile
            lats_m = jnp.reshape(cm_lats_ref[...], (t_tile, c_pad * n_req))
            lv_m = jnp.reshape(cm_lval_ref[...],
                               (t_tile, c_pad * n_req)) != 0.0
            nval_m = jnp.sum(jnp.where(lv_m, 1.0, 0.0),
                             axis=-1, keepdims=True)
            k_m = jnp.ceil(jnp.float32(P99_Q) * nval_m)
            lo_m = jnp.full((t_tile, 1), -1.0, jnp.float32)
            hi_m = jnp.max(jnp.where(lv_m, lats_m, 0.0),
                           axis=-1, keepdims=True)

            def bisect_m(_, lo_hi):
                lo, hi = lo_hi
                mid = jnp.float32(0.5) * (lo + hi)
                cnt = jnp.sum(jnp.where(lv_m & (lats_m <= mid), 1.0, 0.0),
                              axis=-1, keepdims=True)
                go_hi = cnt >= k_m
                return jnp.where(go_hi, lo, mid), jnp.where(go_hi, mid, hi)

            lo_m, _ = jax.lax.fori_loop(0, P99_BISECT_ITERS, bisect_m,
                                        (lo_m, hi_m))
            p99_m = jnp.min(jnp.where(lv_m & (lats_m > lo_m), lats_m, _BIG),
                            axis=-1, keepdims=True)
            p99_m = jnp.where(nval_m > 0, p99_m, 0.0)
            cm_metrics_ref[...] = (cm_metrics_ref[...]
                                   + jnp.where(mlane == MET_P99, p99_m, 0.0))


def sched_stream_call(object_ids: jax.Array, lengths: jax.Array,
                      valid: jax.Array, tables: jax.Array, seeds: jax.Array,
                      win_rates: jax.Array, *, n_servers: int,
                      window_size: int, threshold: float, lam: float,
                      alpha: float, window_dt: float, policy: str,
                      observe: bool, renorm: bool, trial_tile: int = 1,
                      nltr_n: int = 2, probe_choices: int = 2,
                      ablate: int = 0, interpret: bool = False):
    """Temporal stream kernel over T independent streams (clients/trials).

    ``ablate`` drops trailing window phases for differential profiling
    (see `_sched_stream_kernel`); outputs past the dropped phase are
    zeros, so nonzero levels are for timing only.

    object_ids/lengths/valid: (T, N) with N = W * window_size;
    tables: (T, 4, M_pad) packed log tensors; seeds: (T, 1) uint32;
    win_rates: (T, W, M_pad) TRUE service rates per window.  T must be a
    multiple of ``trial_tile``; each of the ``T / trial_tile`` program
    instances runs its tile of streams vectorized over VMEM sublanes.

    Returns (choices (T, N) int32, latencies (T, N) f32,
    final_tables (T, 4, M_pad) f32, window_loads (T, W, M_pad) f32,
    metrics (T, MET_PAD) f32 in `policy_core.MET_*` lane order).
    """
    t, n = object_ids.shape
    m_pad = tables.shape[-1]
    n_win = win_rates.shape[1]
    assert n == n_win * window_size, (n, n_win, window_size)
    assert t % trial_tile == 0, (t, trial_tile)
    tt = trial_tile
    # drain decrements: pre-multiplied OUTSIDE the kernel (§9 FMA note)
    win_dec = window_decrements(win_rates, window_dt).astype(jnp.float32)
    kernel = functools.partial(
        _sched_stream_kernel, n_windows=n_win, window_size=window_size,
        n_servers=n_servers, m_pad=m_pad, t_tile=tt, threshold=threshold,
        lam=lam, alpha=alpha, window_dt=window_dt, policy=policy,
        observe=observe, renorm=renorm, nltr_n=nltr_n,
        probe_choices=probe_choices, ablate=ablate)
    return pl.pallas_call(
        kernel,
        grid=(t // tt,),
        in_specs=[
            pl.BlockSpec((tt, n), lambda i: (i, 0)),
            pl.BlockSpec((tt, n), lambda i: (i, 0)),
            pl.BlockSpec((tt, n), lambda i: (i, 0)),
            pl.BlockSpec((tt, N_ROWS, m_pad), lambda i: (i, 0, 0)),
            pl.BlockSpec((tt, 1), lambda i: (i, 0)),
            pl.BlockSpec((tt, n_win, m_pad), lambda i: (i, 0, 0)),
            pl.BlockSpec((tt, n_win, m_pad), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tt, n), lambda i: (i, 0)),
            pl.BlockSpec((tt, n), lambda i: (i, 0)),
            pl.BlockSpec((tt, N_ROWS, m_pad), lambda i: (i, 0, 0)),
            pl.BlockSpec((tt, n_win, m_pad), lambda i: (i, 0, 0)),
            pl.BlockSpec((tt, MET_PAD), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, n), jnp.int32),
            jax.ShapeDtypeStruct((t, n), jnp.float32),
            jax.ShapeDtypeStruct((t, N_ROWS, m_pad), jnp.float32),
            jax.ShapeDtypeStruct((t, n_win, m_pad), jnp.float32),
            jax.ShapeDtypeStruct((t, MET_PAD), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((N_ROWS, tt, m_pad), jnp.float32),   # the log stack
        ],
        interpret=interpret,
    )(object_ids, lengths, valid, tables, seeds, win_rates, win_dec)


def sched_stream_grid_call(object_ids: jax.Array, lengths: jax.Array,
                           valid: jax.Array, tables: jax.Array,
                           seeds: jax.Array, win_rates: jax.Array, *,
                           n_servers: int, window_size: int, threshold: float,
                           lam: float, alpha: float, window_dt: float,
                           policy: str, observe: bool, renorm: bool,
                           trial_tile: int = 1, client_tile: int = 1,
                           nltr_n: int = 2, probe_choices: int = 2,
                           merge_mean: bool = True,
                           interpret: bool = False):
    """2-D (trials × clients) grid form of the stream kernel (§11).

    object_ids/lengths/valid: (T, C, N) per-stream request slices (N =
    W * window_size); tables: (T, C, 4, M_pad) private log tensors;
    seeds: (T, C) uint32; win_rates: (T, W, M_pad) per-TRIAL true rates
    (all of a trial's clients share its trace — broadcast over the
    client sublanes in-VMEM, never materialized per client).  T and C
    must be multiples of ``trial_tile`` / ``client_tile``; the grid runs
    ``(T / tt, C / ct)`` program instances, each vectorizing its
    ``tt * ct`` streams over VMEM sublanes.

    Returns (choices (T, C, N) int32, latencies (T, C, N) f32,
    final_tables (T, C, 4, M_pad) f32, window_loads (T, C, W, M_pad)
    f32, metrics (T, C, MET_PAD) f32 per stream, cm_wloads (T, W,
    M_pad) f32 — the masked client-MEAN window loads, or the raw masked
    client SUM when ``merge_mean=False`` (the pre-reduced per-device
    block the sharded sweep's ``psum_tree`` consumes, DESIGN.md §12) —
    cm_metrics (T, MET_PAD) f32 cross-client merged rows, accumulated
    in-VMEM across the client grid dimension (the MET_P99 lane holds the
    merged nearest-rank p99 when ``merge_mean=True``, DESIGN.md §14),
    and cm_lats / cm_lval (T, C, N) f32 — the merged latency block:
    masked grouped-step latencies and 0/1 validity, the operand the
    sharded sweep gathers to bisect the GLOBAL merged p99).
    """
    t, c, n = object_ids.shape
    m_pad = tables.shape[-1]
    n_win = win_rates.shape[1]
    assert n == n_win * window_size, (n, n_win, window_size)
    assert t % trial_tile == 0, (t, trial_tile)
    assert c % client_tile == 0, (c, client_tile)
    tt, ct = trial_tile, client_tile
    win_dec = window_decrements(win_rates, window_dt).astype(jnp.float32)
    kernel = functools.partial(
        _sched_stream_kernel, n_windows=n_win, window_size=window_size,
        n_servers=n_servers, m_pad=m_pad, t_tile=tt, threshold=threshold,
        lam=lam, alpha=alpha, window_dt=window_dt, policy=policy,
        observe=observe, renorm=renorm, nltr_n=nltr_n,
        probe_choices=probe_choices, client_tile=ct,
        n_client_blocks=c // ct, merge_mean=merge_mean)
    return pl.pallas_call(
        kernel,
        grid=(t // tt, c // ct),
        in_specs=[
            pl.BlockSpec((tt, ct, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((tt, ct, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((tt, ct, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((tt, ct, N_ROWS, m_pad), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((tt, ct), lambda i, j: (i, j)),
            pl.BlockSpec((tt, n_win, m_pad), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((tt, n_win, m_pad), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tt, ct, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((tt, ct, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((tt, ct, N_ROWS, m_pad), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((tt, ct, n_win, m_pad), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((tt, ct, MET_PAD), lambda i, j: (i, j, 0)),
            # per-TRIAL cross-client accumulators: constant in j, so the
            # block stays VMEM-resident across a trial row's client
            # steps and retires once per trial tile (DESIGN.md §11);
            # the last two are the merged latency block + validity
            # (DESIGN.md §14) — FULL client axis per block, each client
            # step depositing its disjoint (tt, ct, n) slice
            pl.BlockSpec((tt, n_win, m_pad), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((tt, MET_PAD), lambda i, j: (i, 0)),
            pl.BlockSpec((tt, c, n), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((tt, c, n), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, c, n), jnp.int32),
            jax.ShapeDtypeStruct((t, c, n), jnp.float32),
            jax.ShapeDtypeStruct((t, c, N_ROWS, m_pad), jnp.float32),
            jax.ShapeDtypeStruct((t, c, n_win, m_pad), jnp.float32),
            jax.ShapeDtypeStruct((t, c, MET_PAD), jnp.float32),
            jax.ShapeDtypeStruct((t, n_win, m_pad), jnp.float32),
            jax.ShapeDtypeStruct((t, MET_PAD), jnp.float32),
            jax.ShapeDtypeStruct((t, c, n), jnp.float32),
            jax.ShapeDtypeStruct((t, c, n), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((N_ROWS, tt * ct, m_pad), jnp.float32),
        ],
        interpret=interpret,
    )(object_ids, lengths, valid, tables, seeds, win_rates, win_dec)


def sched_select_call(object_ids: jax.Array, lengths: jax.Array,
                      init_loads: jax.Array, seeds: jax.Array, *,
                      n_servers: int, threshold: float, lam: float,
                      policy: str, interpret: bool = False):
    """Legacy single-window entry (paper's static-load model).

    object_ids/lengths: (C, N); init_loads: (C, M_pad); seeds: (C, 1).
    Returns (choices (C, N) int32, final_loads (C, M_pad) f32).  This is
    the temporal kernel degenerated to one window: uniform probability
    prior, no observations, no drain, no renormalization — bit-identical
    to the pre-refactor kernel.
    """
    c, n = object_ids.shape
    m_pad = init_loads.shape[1]
    m = n_servers
    probs = jnp.full((c, m_pad), 1.0 / m, jnp.float32)
    tables = jnp.stack([
        init_loads.astype(jnp.float32),
        probs,
        jnp.zeros((c, m_pad), jnp.float32),
        jnp.ones((c, m_pad), jnp.float32),
    ], axis=1)                                    # (C, 4, m_pad)
    valid = jnp.ones((c, n), jnp.int32)
    rates = jnp.ones((c, 1, m_pad), jnp.float32)  # one window, unit rates
    choices, _, final_tables, _, _ = sched_stream_call(
        object_ids, lengths, valid, tables, seeds, rates, n_servers=m,
        window_size=n, threshold=threshold, lam=lam, alpha=0.25,
        window_dt=0.0, policy=policy, observe=False, renorm=False,
        interpret=interpret)
    return choices, final_tables[:, ROW_LOADS, :]
