"""Pallas kernel: stream I/O requests through a VMEM-resident statistic log.

This is the TPU-native adaptation of the paper's core insight (DESIGN.md):
*replace remote probing with local state*.  Scheduling a request stream
against an M-server statistic table is a sequential-dependence loop whose
working set — the packed ``(4, M)`` log tensor of `repro.core.policy_core`
(rows ``loads / probs / ewma_lat / est_rates``) — is reused every
iteration: the kernel pins the whole table in one VMEM scratch for the
entire stream and emits one assignment per request, instead of bouncing
the carry through XLA's while-loop machinery (HBM round trips per
decision).

Grid = independent clients (each compute node runs its own log; there is
no cross-client gossip, exactly as in the paper §3.3).

The TEMPORAL form (`_sched_stream_kernel`) runs a whole `run_stream`
trace as one ``pallas_call``: the stream is split into windows; per
window the kernel snapshots the probability ranking (TRH's plan), loops
the window's requests (selection → threshold guard → Eq. (1)-(3) one-hot
updates → completion feedback into the ewma/est rows), then renormalizes
the probability row and drains each server's queue at the window's TRUE
service rates (``advance_time`` semantics; rates streamed in as a
``(W, M)`` input).  Policies (selected statically):

* ``minload``    — argmin of current load (greedy; ECT with unit rates);
* ``two_random`` — power-of-two-choices over ALL servers (no probe
  messages; the in-VMEM LCG supplies the randomness);
* ``ect``        — argmin expected completion time ``(load+len)/est_rate``
  on the client-ESTIMATED rate row (stale view — observations only);
* ``trh``        — Two Random from Top Half: two LCG draws over the
  lightest M/2 servers of the probability ranking (paper Alg. 2).

All policies apply the paper's redirect-threshold guard against the
round-robin default ``object_id mod M`` and the Eq. (1)-(3) updates with
one-hot *vector* writes (no scatter — TPU lanes update masked).  TRH's
ranking uses the sort-free stable-rank identity
(`policy_core.prob_ranks`): rank_i = |{p_j > p_i}| + |{j<i : p_j = p_i}|,
an O(M^2) lane-parallel compare that equals ``argsort(-probs)`` exactly.
MLML/nLTR need per-window request sorts and stay in the JAX engine.
``ref.py`` is the bit-exact jnp oracle; `engine.run_stream(backend=...)`
parity is asserted in tests/test_kernels.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.policy_core import (LCG_A, LCG_C, N_ROWS, ROW_EST, ROW_EWMA,
                                    ROW_LOADS, ROW_PROBS)

_BIG = 3.4e38  # padding-lane load: never selected, never drained


def _lcg(rng):
    return rng * jnp.uint32(LCG_A) + jnp.uint32(LCG_C)


def _lcg_mod(rng, n: int):
    return jax.lax.rem((rng >> jnp.uint32(8)).astype(jnp.int32)
                       & jnp.int32(0x7FFFFFFF), n)


def _sched_stream_kernel(objs_ref, lens_ref, valid_ref, table_ref, seed_ref,
                         rates_ref, choices_ref, lats_ref, final_table_ref,
                         wloads_ref, tbl, *, n_windows: int, window_size: int,
                         n_servers: int, m_pad: int, threshold: float,
                         lam: float, alpha: float, window_dt: float,
                         policy: str, observe: bool, renorm: bool):
    m = n_servers
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, m_pad), 1)
    lv = lane < m                               # valid (non-padding) lanes

    # --- pin the packed log tensor in VMEM scratch -------------------------
    intab = table_ref[...]                      # (1, 4, m_pad)
    tbl[ROW_LOADS:ROW_LOADS + 1, :] = jnp.where(lv, intab[:, ROW_LOADS, :],
                                                _BIG)
    tbl[ROW_PROBS:ROW_PROBS + 1, :] = jnp.where(lv, intab[:, ROW_PROBS, :],
                                                0.0)
    tbl[ROW_EWMA:ROW_EWMA + 1, :] = jnp.where(lv, intab[:, ROW_EWMA, :], 0.0)
    tbl[ROW_EST:ROW_EST + 1, :] = jnp.where(lv, intab[:, ROW_EST, :], 1.0)

    def pick(row, onehot):
        """Extract row[onehot] without gather (one-hot masked sum)."""
        return jnp.sum(jnp.where(onehot, row, 0.0))

    def window_body(w, rng):
        cur_rates = jnp.where(
            lv, rates_ref[0, pl.ds(w, 1), :], 1.0)          # (1, m_pad)

        if policy == "trh":
            # Window-start plan: stable descending probability rank
            # (== argsort(-probs); see policy_core.prob_ranks).  Padding
            # lanes (p = 0, largest indices) always rank >= M.
            p = tbl[ROW_PROBS:ROW_PROBS + 1, :]
            pj = jnp.broadcast_to(p, (m_pad, m_pad))         # [i,j] = p_j
            pi = jnp.broadcast_to(jnp.transpose(p), (m_pad, m_pad))
            jpos = jax.lax.broadcasted_iota(jnp.int32, (m_pad, m_pad), 1)
            ipos = jax.lax.broadcasted_iota(jnp.int32, (m_pad, m_pad), 0)
            cnt = ((pj > pi) | ((pj == pi) & (jpos < ipos))).astype(jnp.int32)
            rank = jnp.transpose(jnp.sum(cnt, axis=1, keepdims=True))
        else:
            rank = lane                                      # unused

        def rank_to_server(r):
            """Server id at sorted position r (rank is a permutation)."""
            return jnp.sum(jnp.where(rank == r, lane, 0)).astype(jnp.int32)

        def req_body(j, rng):
            i = w * window_size + j
            obj = objs_ref[0, i]
            ln = lens_ref[0, i]
            v = valid_ref[0, i] != 0
            loads = tbl[ROW_LOADS:ROW_LOADS + 1, :]
            probs = tbl[ROW_PROBS:ROW_PROBS + 1, :]
            est = tbl[ROW_EST:ROW_EST + 1, :]
            default = jax.lax.rem(obj, m)

            # -- target selection (policy_core decision math) --------------
            if policy == "minload":
                target = jnp.argmin(loads[0, :]).astype(jnp.int32)
            elif policy == "ect":
                scores = (loads + ln) / est
                target = jnp.argmin(scores[0, :]).astype(jnp.int32)
            elif policy in ("two_random", "trh"):
                r1 = _lcg(rng)
                r2 = _lcg(r1)
                rng = r2
                if policy == "two_random":
                    c1 = _lcg_mod(r1, m)
                    c2 = _lcg_mod(r2, m)
                else:  # trh: two positions in the lightest half
                    half = max(m // 2, 1)
                    c1 = rank_to_server(_lcg_mod(r1, half))
                    c2 = rank_to_server(_lcg_mod(r2, half))
                l1 = pick(loads, lane == c1)
                l2 = pick(loads, lane == c2)
                target = jnp.where(l1 <= l2, c1, c2).astype(jnp.int32)
            else:  # pragma: no cover
                raise ValueError(policy)

            # -- redirect-threshold guard (§3.4.1) -------------------------
            l_def = pick(loads, lane == default)
            l_tgt = pick(loads, lane == target)
            if policy == "ect":
                # rate-aware benefit in expected seconds, on EST rates
                r_def = pick(est, lane == default)
                r_tgt = pick(est, lane == target)
                benefit = (l_def + ln) / r_def - (l_tgt + ln) / r_tgt
            else:
                benefit = l_def - l_tgt
            choose = jnp.where(benefit > threshold, target,
                               default).astype(jnp.int32)

            # -- Eq. (1)-(3) one-hot updates (masked on padding rows) ------
            onehot = lane == choose
            upd = onehot & v
            new_loads = jnp.where(upd, loads + ln, loads)    # Eq. (1)
            tbl[ROW_LOADS:ROW_LOADS + 1, :] = new_loads
            p_i = pick(probs, onehot)
            l_i = pick(new_loads, onehot)
            decayed = p_i * jnp.exp(-l_i / lam)              # Eq. (2)
            delta = (p_i - decayed) / (m - 1)                # Eq. (3)
            new_probs = jnp.where(onehot, decayed,
                                  jnp.where(lv, probs + delta, 0.0))
            tbl[ROW_PROBS:ROW_PROBS + 1, :] = jnp.where(v, new_probs, probs)

            # -- estimated completion latency + completion feedback --------
            l_after = pick(new_loads, onehot)
            rate_c = pick(cur_rates, onehot)                 # TRUE rate
            lat = l_after / jnp.maximum(rate_c, 1e-6)
            choices_ref[0, pl.ds(i, 1)] = choose.reshape(1)
            lats_ref[0, pl.ds(i, 1)] = jnp.where(v, lat, 0.0).reshape(1)
            if observe:
                # effective MB/s this request will see -> ewma row; est
                # row re-derived from observations ONLY (stale view).
                mbps = ln / jnp.maximum(lat, 1e-9)
                ewma = tbl[ROW_EWMA:ROW_EWMA + 1, :]
                old = pick(ewma, onehot)
                new = jnp.where(old == 0.0, mbps,
                                (1 - alpha) * old + alpha * mbps)
                new_ewma = jnp.where(upd, new, ewma)
                tbl[ROW_EWMA:ROW_EWMA + 1, :] = new_ewma
                dflt = jnp.maximum(jnp.max(new_ewma), 1.0)
                tbl[ROW_EST:ROW_EST + 1, :] = jnp.where(new_ewma > 0,
                                                        new_ewma, dflt)
            return rng

        rng = jax.lax.fori_loop(0, window_size, req_body, rng, unroll=False)

        # -- window close: renormalize probs, drain queues (advance_time) --
        if renorm:
            p = jnp.clip(tbl[ROW_PROBS:ROW_PROBS + 1, :], 0.0)
            tbl[ROW_PROBS:ROW_PROBS + 1, :] = p / jnp.sum(p)
        if window_dt:
            loads = tbl[ROW_LOADS:ROW_LOADS + 1, :]
            drained = jnp.maximum(
                loads - jnp.maximum(cur_rates, 1e-6) * window_dt, 0.0)
            tbl[ROW_LOADS:ROW_LOADS + 1, :] = jnp.where(lv, drained, _BIG)
        wloads_ref[0, pl.ds(w, 1), :] = jnp.where(
            lv, tbl[ROW_LOADS:ROW_LOADS + 1, :], 0.0)
        return rng

    seed = seed_ref[0, 0].astype(jnp.uint32)
    jax.lax.fori_loop(0, n_windows, window_body, seed, unroll=False)
    out = tbl[...]
    zero_pad = jnp.broadcast_to(~lv, (N_ROWS, m_pad))
    final_table_ref[...] = jnp.where(zero_pad, 0.0, out)[None]


def sched_stream_call(object_ids: jax.Array, lengths: jax.Array,
                      valid: jax.Array, tables: jax.Array, seeds: jax.Array,
                      win_rates: jax.Array, *, n_servers: int,
                      window_size: int, threshold: float, lam: float,
                      alpha: float, window_dt: float, policy: str,
                      observe: bool, renorm: bool, interpret: bool = False):
    """Temporal stream kernel over C independent clients.

    object_ids/lengths/valid: (C, N) with N = W * window_size;
    tables: (C, 4, M_pad) packed log tensors; seeds: (C, 1) uint32;
    win_rates: (C, W, M_pad) TRUE service rates per window.

    Returns (choices (C, N) int32, latencies (C, N) f32,
    final_tables (C, 4, M_pad) f32, window_loads (C, W, M_pad) f32).
    """
    c, n = object_ids.shape
    m_pad = tables.shape[-1]
    n_win = win_rates.shape[1]
    assert n == n_win * window_size, (n, n_win, window_size)
    kernel = functools.partial(
        _sched_stream_kernel, n_windows=n_win, window_size=window_size,
        n_servers=n_servers, m_pad=m_pad, threshold=threshold, lam=lam,
        alpha=alpha, window_dt=window_dt, policy=policy, observe=observe,
        renorm=renorm)
    return pl.pallas_call(
        kernel,
        grid=(c,),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (i, 0)),
            pl.BlockSpec((1, N_ROWS, m_pad), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, n_win, m_pad), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (i, 0)),
            pl.BlockSpec((1, N_ROWS, m_pad), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n_win, m_pad), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c, n), jnp.int32),
            jax.ShapeDtypeStruct((c, n), jnp.float32),
            jax.ShapeDtypeStruct((c, N_ROWS, m_pad), jnp.float32),
            jax.ShapeDtypeStruct((c, n_win, m_pad), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((N_ROWS, m_pad), jnp.float32),   # the packed log
        ],
        interpret=interpret,
    )(object_ids, lengths, valid, tables, seeds, win_rates)


def sched_select_call(object_ids: jax.Array, lengths: jax.Array,
                      init_loads: jax.Array, seeds: jax.Array, *,
                      n_servers: int, threshold: float, lam: float,
                      policy: str, interpret: bool = False):
    """Legacy single-window entry (paper's static-load model).

    object_ids/lengths: (C, N); init_loads: (C, M_pad); seeds: (C, 1).
    Returns (choices (C, N) int32, final_loads (C, M_pad) f32).  This is
    the temporal kernel degenerated to one window: uniform probability
    prior, no observations, no drain, no renormalization — bit-identical
    to the pre-refactor kernel.
    """
    c, n = object_ids.shape
    m_pad = init_loads.shape[1]
    m = n_servers
    probs = jnp.full((c, m_pad), 1.0 / m, jnp.float32)
    tables = jnp.stack([
        init_loads.astype(jnp.float32),
        probs,
        jnp.zeros((c, m_pad), jnp.float32),
        jnp.ones((c, m_pad), jnp.float32),
    ], axis=1)                                    # (C, 4, m_pad)
    valid = jnp.ones((c, n), jnp.int32)
    rates = jnp.ones((c, 1, m_pad), jnp.float32)  # one window, unit rates
    choices, _, final_tables, _ = sched_stream_call(
        object_ids, lengths, valid, tables, seeds, rates, n_servers=m,
        window_size=n, threshold=threshold, lam=lam, alpha=0.25,
        window_dt=0.0, policy=policy, observe=False, renorm=False,
        interpret=interpret)
    return choices, final_tables[:, ROW_LOADS, :]
