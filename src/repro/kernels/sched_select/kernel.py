"""Pallas kernel: stream I/O requests through a VMEM-resident statistic log.

This is the TPU-native adaptation of the paper's core insight (DESIGN.md):
*replace remote probing with local state*.  Scheduling a request stream
against an (M,)-server statistic table is a sequential-dependence loop
whose working set (loads + probs, a few KB) is reused every iteration —
the kernel pins the table in VMEM scratch for the whole stream and emits
one assignment per request, instead of bouncing the carry through XLA's
while-loop machinery (HBM round trips per decision).

Grid = independent clients (each compute node runs its own log; there is
no cross-client gossip, exactly as in the paper §3.3).

Policies (selected statically):

* ``minload``    — argmin of current load (greedy; ECT with unit rates);
* ``two_random`` — power-of-two-choices from the log (no probe messages;
  the in-kernel LCG supplies the randomness).

Both apply the paper's redirect-threshold guard against the round-robin
default ``object_id mod M`` and the Eq. (1)-(3) log updates with one-hot
*vector* writes (no scatter — TPU lanes update masked).  MLML/TRH/nLTR
need per-window sorts and stay in the JAX engine; the kernel covers the
per-request decision hot path.  ``ref.py`` is the bit-exact jnp oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _sched_kernel(objs_ref, lens_ref, init_loads_ref, seed_ref,
                  choices_ref, final_loads_ref, loads_ref, probs_ref, *,
                  n_requests: int, n_servers: int, m_pad: int,
                  threshold: float, lam: float, policy: str):
    # --- init VMEM-resident table -----------------------------------------
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, m_pad), 1)
    valid = lane < n_servers
    big = jnp.float32(3.4e38)
    loads_ref[...] = jnp.where(valid, init_loads_ref[...], big)
    probs_ref[...] = jnp.where(valid, 1.0 / n_servers, 0.0)

    def body(i, rng):
        obj = objs_ref[0, i]
        ln = lens_ref[0, i]
        loads = loads_ref[...]                      # (1, m_pad)
        default = jax.lax.rem(obj, n_servers)

        if policy == "minload":
            target = jnp.argmin(loads[0, :]).astype(jnp.int32)
            new_rng = rng
        elif policy == "two_random":
            r1 = rng * jnp.uint32(1664525) + jnp.uint32(1013904223)
            r2 = r1 * jnp.uint32(1664525) + jnp.uint32(1013904223)
            new_rng = r2
            c1 = jax.lax.rem((r1 >> jnp.uint32(8)).astype(jnp.int32)
                             & jnp.int32(0x7FFFFFFF), n_servers)
            c2 = jax.lax.rem((r2 >> jnp.uint32(8)).astype(jnp.int32)
                             & jnp.int32(0x7FFFFFFF), n_servers)
            l1 = jnp.sum(jnp.where(lane == c1, loads, 0.0))
            l2 = jnp.sum(jnp.where(lane == c2, loads, 0.0))
            target = jnp.where(l1 <= l2, c1, c2).astype(jnp.int32)
        else:  # pragma: no cover
            raise ValueError(policy)

        l_def = jnp.sum(jnp.where(lane == default, loads, 0.0))
        l_tgt = jnp.sum(jnp.where(lane == target, loads, 0.0))
        choose = jnp.where(l_def - l_tgt > threshold, target,
                           default).astype(jnp.int32)

        onehot = lane == choose
        # Eq. (1): l <- l' + Len
        new_loads = jnp.where(onehot, loads + ln, loads)
        loads_ref[...] = new_loads
        # Eq. (2)-(3): decay chosen prob, spread the mass over the rest
        probs = probs_ref[...]
        p_i = jnp.sum(jnp.where(onehot, probs, 0.0))
        l_i = jnp.sum(jnp.where(onehot, new_loads, 0.0))
        decayed = p_i * jnp.exp(-l_i / lam)
        delta = (p_i - decayed) / (n_servers - 1)
        probs_ref[...] = jnp.where(
            onehot, decayed, jnp.where(valid, probs + delta, 0.0))

        choices_ref[0, pl.ds(i, 1)] = choose.reshape(1)
        return new_rng

    seed = seed_ref[0, 0].astype(jnp.uint32)
    jax.lax.fori_loop(0, n_requests, body, seed, unroll=False)
    final_loads_ref[...] = jnp.where(valid, loads_ref[...], 0.0)


def sched_select_call(object_ids: jax.Array, lengths: jax.Array,
                      init_loads: jax.Array, seeds: jax.Array, *,
                      n_servers: int, threshold: float, lam: float,
                      policy: str, interpret: bool = False):
    """object_ids/lengths: (C, N); init_loads: (C, M_pad); seeds: (C, 1).

    Returns (choices (C, N) int32, final_loads (C, M_pad) f32).
    """
    c, n = object_ids.shape
    m_pad = init_loads.shape[1]
    kernel = functools.partial(
        _sched_kernel, n_requests=n, n_servers=n_servers, m_pad=m_pad,
        threshold=threshold, lam=lam, policy=policy)
    return pl.pallas_call(
        kernel,
        grid=(c,),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (i, 0)),
            pl.BlockSpec((1, m_pad), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, n), lambda i: (i, 0)),
            pl.BlockSpec((1, m_pad), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c, n), jnp.int32),
            jax.ShapeDtypeStruct((c, m_pad), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, m_pad), jnp.float32),   # loads table
            pltpu.VMEM((1, m_pad), jnp.float32),   # probs table
        ],
        interpret=interpret,
    )(object_ids, lengths, init_loads, seeds)
