"""Jit'd wrappers for the sched_select kernels (auto-interpret on CPU).

Four entry points:

* :func:`sched_select` — the legacy single-window static-load form
  (minload / two_random), kept bit-identical to the seed kernel;
* :func:`sched_stream` — the temporal stream form: a whole
  ``engine.run_stream`` trace (windows, drain, completion feedback) as
  ONE ``pallas_call`` over the packed ``(4, M)`` log tensor.  This is
  what ``engine.run_stream(backend="kernel")`` dispatches to.
* :func:`sched_stream_batch` — the TRIAL-GRID form (DESIGN.md §9): a
  whole T-trial Monte-Carlo sweep as ONE ``pallas_call`` with
  ``grid = (ceil(T / trial_tile),)``; each program instance runs
  ``trial_tile`` trials vectorized over VMEM sublanes and reduces its
  fused per-trial metrics in-VMEM.  ``engine.run_stream_batch`` (and
  through it ``simulate.run_trials(backend="kernel")``) dispatches here.
* :func:`sched_stream_grid` — the 2-D (TRIALS × CLIENTS) grid form
  (DESIGN.md §11): the per_client contention model's whole sweep — T
  trials × C private-log clients — as ONE ``pallas_call`` with
  ``grid = (ceil(T / trial_tile), ceil(C / client_tile))`` and the
  cross-client merges fused in-VMEM.  ``engine.run_stream_batch`` with
  a ``(T, C)`` leading batch (and through it ``simulate.run_trials(
  backend="kernel", client_model="per_client")``) dispatches here.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy_core import (DEFAULT_TRIAL_TILE, N_CMETRICS,
                                    N_METRICS, init_table,
                                    resolve_client_tile,
                                    resolve_trial_tile)
from repro.kernels.sched_select.kernel import (sched_select_call,
                                               sched_stream_call,
                                               sched_stream_grid_call)

POLICIES = ("minload", "two_random", "ect", "trh", "rr", "two_choice",
            "mlml", "nltr")
# the paper's policies that need per-window sorts — in-VMEM since
# DESIGN.md §10, on the §13 permutation-apply fast path (one all-pairs
# rank + a constant number of permutation applies per window, no sort
# network) since PR 7
SORT_POLICIES = ("mlml", "nltr")
# policies available through the legacy static entry point
STATIC_POLICIES = ("minload", "two_random")


def _check_policy(policy: str, n_servers: int, nltr_n: int) -> None:
    if policy not in POLICIES:
        raise ValueError(f"kernel policy must be one of {POLICIES}")
    if policy == "nltr" and 2 ** nltr_n > n_servers:
        raise ValueError(
            f"nltr needs 2**nltr_n <= n_servers: nltr_n={nltr_n} gives "
            f"K={2 ** nltr_n} sections for n_servers={n_servers}")


def _pad_servers(m: int) -> int:
    return max(-(-m // 128) * 128, 128)


def _auto_interpret(interpret: Optional[bool]) -> bool:
    return jax.default_backend() == "cpu" if interpret is None else interpret


@functools.partial(jax.jit, static_argnames=("n_servers", "threshold",
                                             "lam", "policy", "interpret"))
def sched_select(object_ids: jax.Array, lengths: jax.Array,
                 init_loads: jax.Array, seeds: jax.Array, *,
                 n_servers: int, threshold: float = 0.0, lam: float = 32.0,
                 policy: str = "minload",
                 interpret: Optional[bool] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Schedule request streams for C independent clients (static model).

    object_ids/lengths: (C, N); init_loads: (C, M) true server loads known
    to each client's log; seeds: (C,) uint32.  Returns (choices (C, N),
    final_loads (C, M)).
    """
    if policy not in STATIC_POLICIES:
        raise ValueError(f"kernel policy must be one of {STATIC_POLICIES}")
    interpret = _auto_interpret(interpret)
    c, n = object_ids.shape
    m = init_loads.shape[1]
    m_pad = _pad_servers(m)
    loads_p = jnp.pad(init_loads.astype(jnp.float32),
                      ((0, 0), (0, m_pad - m)))
    choices, final_loads = sched_select_call(
        object_ids.astype(jnp.int32), lengths.astype(jnp.float32),
        loads_p, seeds.reshape(c, 1).astype(jnp.uint32),
        n_servers=n_servers, threshold=threshold, lam=lam, policy=policy,
        interpret=interpret)
    return choices, final_loads[:, :m]


@functools.partial(jax.jit, static_argnames=("n_servers", "window_size",
                                             "threshold", "lam", "alpha",
                                             "window_dt", "policy",
                                             "observe", "renorm", "nltr_n",
                                             "probe_choices", "interpret"))
def sched_stream(object_ids: jax.Array, lengths: jax.Array,
                 valid: jax.Array, table: jax.Array, seed: jax.Array,
                 win_rates: jax.Array, *, n_servers: int, window_size: int,
                 threshold: float = 0.0, lam: float = 32.0,
                 alpha: float = 0.25, window_dt: float = 0.0,
                 policy: str = "ect", observe: bool = True,
                 renorm: bool = True, nltr_n: int = 2,
                 probe_choices: int = 2, interpret: Optional[bool] = None
                 ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Temporal kernel: one client's whole windowed stream in VMEM.

    object_ids/lengths/valid: (N,) with N = W * window_size (padding rows
    ``valid == False``); table: the (4, M) packed log tensor
    (`SchedState.log`); seed: () uint32 LCG state; win_rates: (W, M) TRUE
    service rates at each window open (drain + latency reporting — the
    decision path only ever reads the table's est row).

    Returns (choices (N,), latencies (N,), final_table (4, M),
    window_loads (W, M) post-drain snapshots).

    Batched form: pass (C, N) / (C, 4, M) / (C,) / (C, W, M) arrays and
    every output gains the leading client axis (grid = clients).
    """
    _check_policy(policy, n_servers, nltr_n)
    interpret = _auto_interpret(interpret)
    single = object_ids.ndim == 1
    if single:
        object_ids, lengths, valid = (object_ids[None], lengths[None],
                                      valid[None])
        table, seed, win_rates = table[None], seed[None], win_rates[None]
    c, n = object_ids.shape
    m = table.shape[-1]
    n_win = win_rates.shape[1]
    m_pad = _pad_servers(m)
    pad = ((0, 0), (0, 0), (0, m_pad - m))
    tables_p = jnp.pad(table.astype(jnp.float32), pad)
    rates_p = jnp.pad(win_rates.astype(jnp.float32), pad)
    choices, lats, ftab, wloads, _ = sched_stream_call(
        object_ids.astype(jnp.int32), lengths.astype(jnp.float32),
        valid.astype(jnp.int32), tables_p,
        seed.reshape(c, 1).astype(jnp.uint32), rates_p,
        n_servers=n_servers, window_size=window_size, threshold=threshold,
        lam=lam, alpha=alpha, window_dt=window_dt, policy=policy,
        observe=observe, renorm=renorm, nltr_n=nltr_n,
        probe_choices=probe_choices, interpret=interpret)
    ftab = ftab[:, :, :m]
    wloads = wloads[:, :, :m]
    if single:
        return choices[0], lats[0], ftab[0], wloads[0]
    return choices, lats, ftab, wloads


@functools.partial(jax.jit, static_argnames=("n_servers", "window_size",
                                             "threshold", "lam", "alpha",
                                             "window_dt", "policy",
                                             "observe", "renorm",
                                             "trial_tile", "nltr_n",
                                             "probe_choices", "ablate",
                                             "interpret"))
def sched_stream_batch(object_ids: jax.Array, lengths: jax.Array,
                       valid: jax.Array, tables: jax.Array, seeds: jax.Array,
                       win_rates: jax.Array, *, n_servers: int,
                       window_size: int, threshold: float = 0.0,
                       lam: float = 32.0, alpha: float = 0.25,
                       window_dt: float = 0.0, policy: str = "ect",
                       observe: bool = True, renorm: bool = True,
                       trial_tile: Optional[int] = None,
                       nltr_n: int = 2, probe_choices: int = 2,
                       ablate: int = 0,
                       interpret: Optional[bool] = None
                       ) -> Tuple[jax.Array, jax.Array, jax.Array,
                                  jax.Array, jax.Array]:
    """Trial-grid kernel: T whole windowed streams as ONE ``pallas_call``.

    object_ids/lengths/valid: (T, N) per-trial streams (N = W *
    window_size, padding rows ``valid == False``); tables: (T, 4, M)
    packed log tensors; seeds: (T,) uint32 LCG states; win_rates:
    (T, W, M) TRUE per-window service rates.  T is padded up to a
    multiple of ``trial_tile`` with inert trials (all-invalid requests,
    fresh tables, unit rates) and the grid runs ``ceil(T / trial_tile)``
    program instances, each vectorizing its tile of trials over VMEM
    sublanes — bit-exact per trial vs. mapping :func:`sched_stream`
    sequentially (asserted in tests/test_kernels.py).

    Returns (choices (T, N) int32, latencies (T, N) f32, final_tables
    (T, 4, M) f32, window_loads (T, W, M) f32, metrics (T, N_METRICS)
    f32 in `policy_core.MET_*` order — the fused in-VMEM reduction).

    ``ablate`` > 0 drops trailing kernel phases (1 = fused metrics, 2 =
    + step loop, 3 = + window-start plan) for DIFFERENTIAL per-phase
    profiling (DESIGN.md §16); ablated outputs are zeros past the
    dropped phase, so nonzero levels are for timing only.
    """
    _check_policy(policy, n_servers, nltr_n)
    interpret = _auto_interpret(interpret)
    t, n = object_ids.shape
    m = tables.shape[-1]
    tile = resolve_trial_tile(t, trial_tile)
    t_pad = -(-t // tile) * tile
    m_pad = _pad_servers(m)
    if t_pad != t:
        extra = t_pad - t
        object_ids = jnp.concatenate(
            [object_ids, jnp.zeros((extra, n), object_ids.dtype)])
        lengths = jnp.concatenate(
            [lengths, jnp.zeros((extra, n), lengths.dtype)])
        valid = jnp.concatenate(
            [valid, jnp.zeros((extra, n), valid.dtype)])
        tables = jnp.concatenate(
            [tables, jnp.broadcast_to(init_table(m),
                                      (extra,) + tables.shape[1:])])
        seeds = jnp.concatenate([seeds, jnp.zeros((extra,), seeds.dtype)])
        win_rates = jnp.concatenate(
            [win_rates, jnp.ones((extra,) + win_rates.shape[1:],
                                 win_rates.dtype)])
    pad = ((0, 0), (0, 0), (0, m_pad - m))
    tables_p = jnp.pad(tables.astype(jnp.float32), pad)
    rates_p = jnp.pad(win_rates.astype(jnp.float32), pad)
    choices, lats, ftab, wloads, metrics = sched_stream_call(
        object_ids.astype(jnp.int32), lengths.astype(jnp.float32),
        valid.astype(jnp.int32), tables_p,
        seeds.reshape(t_pad, 1).astype(jnp.uint32), rates_p,
        n_servers=n_servers, window_size=window_size, threshold=threshold,
        lam=lam, alpha=alpha, window_dt=window_dt, policy=policy,
        observe=observe, renorm=renorm, trial_tile=tile, nltr_n=nltr_n,
        probe_choices=probe_choices, ablate=ablate, interpret=interpret)
    return (choices[:t], lats[:t], ftab[:t, :, :m], wloads[:t, :, :m],
            metrics[:t, :N_METRICS])


@functools.partial(jax.jit, static_argnames=("n_servers", "window_size",
                                             "threshold", "lam", "alpha",
                                             "window_dt", "policy",
                                             "observe", "renorm",
                                             "trial_tile", "client_tile",
                                             "nltr_n", "probe_choices",
                                             "merge_mean", "interpret"))
def sched_stream_grid(object_ids: jax.Array, lengths: jax.Array,
                      valid: jax.Array, tables: jax.Array, seeds: jax.Array,
                      win_rates: jax.Array, *, n_servers: int,
                      window_size: int, threshold: float = 0.0,
                      lam: float = 32.0, alpha: float = 0.25,
                      window_dt: float = 0.0, policy: str = "ect",
                      observe: bool = True, renorm: bool = True,
                      trial_tile: Optional[int] = None,
                      client_tile: Optional[int] = None,
                      nltr_n: int = 2, probe_choices: int = 2,
                      merge_mean: bool = True,
                      interpret: Optional[bool] = None
                      ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array,
                                 jax.Array, jax.Array, jax.Array, jax.Array,
                                 jax.Array]:
    """2-D (trials × clients) grid kernel (DESIGN.md §11): T trials of C
    private-log client streams — the per_client contention model's whole
    Monte-Carlo sweep — as ONE ``pallas_call``.

    object_ids/lengths/valid: (T, C, N) per-client request slices (N =
    W * window_size, padding rows ``valid == False``; a client whose
    slice is ALL padding is a phantom client and is masked out of every
    cross-client aggregate); tables: (T, C, 4, M) private packed log
    tensors; seeds: (T, C) uint32 LCG states; win_rates: (T, W, M)
    per-TRIAL true service rates (a trial's clients share its trace).
    T / C pad up to ``trial_tile`` / ``client_tile`` multiples with
    inert streams and the grid runs ``(ceil(T/tt), ceil(C/ct))``
    program instances — bit-exact per stream vs. mapping
    :func:`sched_stream` over every (trial, client) pair.

    Returns (choices (T, C, N) int32, latencies (T, C, N) f32,
    final_tables (T, C, 4, M) f32, window_loads (T, C, W, M) f32,
    metrics (T, C, N_METRICS) f32 per stream, cm_wloads (T, W, M) f32 —
    the masked client-MEAN post-drain loads, `policy_core.
    masked_client_mean`'s in-VMEM twin, or the raw masked client SUM
    when ``merge_mean=False`` (the per-device partial the sharded
    sweep's `policy_core.psum_tree` folds across devices, DESIGN.md
    §12) — cm_metrics (T, N_CMETRICS) f32 cross-client merged rows,
    `policy_core.client_stream_metrics`'s twin (its MET_P99 lane is the
    MERGED nearest-rank p99 over the whole trial's latency block when
    ``merge_mean=True``, 0 otherwise — DESIGN.md §14), and cm_lats /
    cm_lval (T, C, N) f32 — the merged latency block: grouped-step
    latencies masked to 0 where invalid, plus 0/1 validity (what the
    sharded sweep all-gathers to bisect the global merged p99)."""
    _check_policy(policy, n_servers, nltr_n)
    interpret = _auto_interpret(interpret)
    t, c, n = object_ids.shape
    m = tables.shape[-1]
    tile_t = resolve_trial_tile(t, trial_tile)
    tile_c = resolve_client_tile(c, client_tile)
    t_pad = -(-t // tile_t) * tile_t
    c_pad = -(-c // tile_c) * tile_c
    m_pad = _pad_servers(m)

    def pad_streams(a, fill):
        """Pad the client then the trial axis with inert streams."""
        if c_pad != c:
            extra = jnp.broadcast_to(fill, (a.shape[0], c_pad - c)
                                     + a.shape[2:]).astype(a.dtype)
            a = jnp.concatenate([a, extra], axis=1)
        if t_pad != t:
            extra = jnp.broadcast_to(fill, (t_pad - t,) + a.shape[1:]
                                     ).astype(a.dtype)
            a = jnp.concatenate([a, extra], axis=0)
        return a

    object_ids = pad_streams(object_ids.astype(jnp.int32), 0)
    lengths = pad_streams(lengths.astype(jnp.float32), 0.0)
    valid = pad_streams(valid.astype(jnp.int32), 0)
    seeds = pad_streams(seeds.astype(jnp.uint32), jnp.uint32(0))
    tables = pad_streams(tables.astype(jnp.float32), init_table(m))
    if t_pad != t:   # inert trials: unit rates (never divided by ~0)
        win_rates = jnp.concatenate(
            [win_rates, jnp.ones((t_pad - t,) + win_rates.shape[1:],
                                 win_rates.dtype)])
    pad = ((0, 0), (0, 0), (0, m_pad - m))
    tables_p = jnp.pad(tables, ((0, 0),) + pad)
    rates_p = jnp.pad(win_rates.astype(jnp.float32), pad)
    choices, lats, ftab, wloads, metrics, cm_wl, cm_met, cm_lats, cm_lval = \
        sched_stream_grid_call(
            object_ids, lengths, valid, tables_p, seeds, rates_p,
            n_servers=n_servers, window_size=window_size,
            threshold=threshold, lam=lam, alpha=alpha, window_dt=window_dt,
            policy=policy, observe=observe, renorm=renorm,
            trial_tile=tile_t, client_tile=tile_c, nltr_n=nltr_n,
            probe_choices=probe_choices, merge_mean=merge_mean,
            interpret=interpret)
    return (choices[:t, :c], lats[:t, :c], ftab[:t, :c, :, :m],
            wloads[:t, :c, :, :m], metrics[:t, :c, :N_METRICS],
            cm_wl[:t, :, :m], cm_met[:t, :N_CMETRICS],
            cm_lats[:t, :c], cm_lval[:t, :c])
