"""Jit'd wrapper for the sched_select kernel (auto-interpret on CPU)."""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.sched_select.kernel import sched_select_call

POLICIES = ("minload", "two_random")


def _pad_servers(m: int) -> int:
    return max(-(-m // 128) * 128, 128)


@functools.partial(jax.jit, static_argnames=("n_servers", "threshold",
                                             "lam", "policy", "interpret"))
def sched_select(object_ids: jax.Array, lengths: jax.Array,
                 init_loads: jax.Array, seeds: jax.Array, *,
                 n_servers: int, threshold: float = 0.0, lam: float = 32.0,
                 policy: str = "minload",
                 interpret: Optional[bool] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Schedule request streams for C independent clients.

    object_ids/lengths: (C, N); init_loads: (C, M) true server loads known
    to each client's log; seeds: (C,) uint32.  Returns (choices (C, N),
    final_loads (C, M)).
    """
    if policy not in POLICIES:
        raise ValueError(f"kernel policy must be one of {POLICIES}")
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    c, n = object_ids.shape
    m = init_loads.shape[1]
    m_pad = _pad_servers(m)
    loads_p = jnp.pad(init_loads.astype(jnp.float32),
                      ((0, 0), (0, m_pad - m)))
    choices, final_loads = sched_select_call(
        object_ids.astype(jnp.int32), lengths.astype(jnp.float32),
        loads_p, seeds.reshape(c, 1).astype(jnp.uint32),
        n_servers=n_servers, threshold=threshold, lam=lam, policy=policy,
        interpret=interpret)
    return choices, final_loads[:, :m]
