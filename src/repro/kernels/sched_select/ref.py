"""Pure-jnp oracles for the sched_select kernels (bit-identical math).

Replays the same LCG, selection, threshold guard and Eq. (1)-(3) updates
with a ``lax.scan`` carry — the exact state-passing formulation the kernel
replaces with VMEM-resident streaming.  ``sched_stream_ref`` mirrors the
temporal kernel (windows, drain, completion feedback, TRH rank plan) on
the packed (4, M) log tensor of `repro.core.policy_core`.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.policy_core import (ROW_EST, ROW_EWMA, ROW_LOADS, ROW_PROBS,
                                    client_stream_metrics, drain_loads,
                                    masked_client_mean, permute_from_sorted,
                                    permute_to_sorted, rank_desc,
                                    recursive_average_bounds,
                                    renormalize_probs, resolve_client_tile,
                                    stream_metrics, window_decrements)


def _lcg(rng: jax.Array) -> jax.Array:
    return rng * jnp.uint32(1664525) + jnp.uint32(1013904223)


def _rand_server(rng: jax.Array, m: int) -> jax.Array:
    return jax.lax.rem((rng >> jnp.uint32(8)).astype(jnp.int32)
                       & jnp.int32(0x7FFFFFFF), m)


def sched_select_ref(object_ids: jax.Array, lengths: jax.Array,
                     init_loads: jax.Array, seed: jax.Array, *,
                     n_servers: int, threshold: float, lam: float,
                     policy: str) -> Tuple[jax.Array, jax.Array]:
    """Single client. object_ids/lengths: (N,); init_loads: (M_pad,)."""
    m_pad = init_loads.shape[0]
    m = n_servers
    lane = jnp.arange(m_pad)
    valid = lane < m
    loads0 = jnp.where(valid, init_loads, 3.4e38).astype(jnp.float32)
    probs0 = jnp.where(valid, 1.0 / m, 0.0).astype(jnp.float32)

    def step(carry, xs):
        loads, probs, rng = carry
        obj, ln = xs
        default = jax.lax.rem(obj, m)
        if policy == "minload":
            target = jnp.argmin(loads).astype(jnp.int32)
        elif policy == "two_random":
            r1 = _lcg(rng)
            r2 = _lcg(r1)
            rng = r2
            c1, c2 = _rand_server(r1, m), _rand_server(r2, m)
            target = jnp.where(loads[c1] <= loads[c2], c1, c2).astype(jnp.int32)
        else:
            raise ValueError(policy)
        choose = jnp.where(loads[default] - loads[target] > threshold,
                           target, default).astype(jnp.int32)
        onehot = lane == choose
        loads = jnp.where(onehot, loads + ln, loads)
        p_i = probs[choose]
        l_i = loads[choose]
        e = jnp.exp(-l_i / lam)
        decayed = p_i * e                                    # Eq. (2)
        delta = p_i * (1.0 - e) / (m - 1)                    # Eq. (3)
        probs = jnp.where(onehot, decayed,
                          jnp.where(valid, probs + delta, 0.0))
        return (loads, probs, rng), choose

    (loads, probs, _), choices = jax.lax.scan(
        step, (loads0, probs0, seed.astype(jnp.uint32)),
        (object_ids, lengths))
    return choices, jnp.where(valid, loads, 0.0)


def sched_stream_ref(object_ids: jax.Array, lengths: jax.Array,
                     valid: jax.Array, table: jax.Array, seed: jax.Array,
                     win_rates: jax.Array, *, n_servers: int,
                     window_size: int, threshold: float, lam: float,
                     alpha: float = 0.25, window_dt: float = 0.0,
                     policy: str = "ect", observe: bool = True,
                     renorm: bool = True, nltr_n: int = 2,
                     probe_choices: int = 2
                     ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Single-client oracle for the temporal stream kernel.

    Same signature semantics as ``ops.sched_stream`` (single-client form):
    object_ids/lengths/valid (N,), table (4, M) packed log tensor, seed ()
    uint32, win_rates (W, M).  Scan-carried replay of the identical
    per-request decision math, per-window renormalization and drain; the
    sort-based policies (mlml/nltr) replay the kernel's in-VMEM window
    plan — the shared all-pairs rank / permutation-apply primitives and
    recursive-average section bounds (DESIGN.md §10, §13) — processing
    in length-desc order and unsorting decisions with the same inverse
    apply.
    """
    m = n_servers
    n_win = win_rates.shape[0]
    obj_w = object_ids.reshape(n_win, window_size)
    len_w = lengths.reshape(n_win, window_size)
    val_w = valid.reshape(n_win, window_size)
    sort_policy = policy in ("mlml", "nltr")
    k_sections = 2 ** nltr_n
    sec_size = max(m // k_sections, 1)

    loads0 = table[ROW_LOADS].astype(jnp.float32)
    probs0 = table[ROW_PROBS].astype(jnp.float32)
    ewma0 = table[ROW_EWMA].astype(jnp.float32)
    est0 = table[ROW_EST].astype(jnp.float32)
    lane = jnp.arange(m)

    def window(carry, xs):
        loads, probs, ewma, est, rng = carry
        obj, lens, val, rates, dec = xs
        # window-start plan (DESIGN.md §13, shared with the kernel):
        # all-pairs rank == inverse of the stable argsort(-probs)
        # permutation; one permutation apply lands the server ids in
        # rank order — no sort network, no backend argsort
        rank_srv, _ = rank_desc(probs)
        order = permute_to_sorted(rank_srv,
                                  (lane.astype(jnp.int32),))[0]
        if sort_policy:
            # §13 fast path: rank the request block once, land
            # obj/len/valid in length-desc order with one permutation
            # apply — the same relocations a stable argsort + take
            # would perform
            rank_req, mkeys = rank_desc(lens, valid=val)
            obj_p, len_p, val_p = permute_to_sorted(
                rank_req, (obj, lens, val.astype(jnp.int32)))
            val_p = val_p != 0
            if policy == "nltr":
                nvalid = jnp.sum(val.astype(jnp.int32)).reshape(1)
                skeys = permute_to_sorted(rank_req, (mkeys,))[0]
                bounds = recursive_average_bounds(skeys, nvalid, nltr_n)
        else:
            obj_p, len_p, val_p = obj, lens, val

        def step(c, x):
            loads, probs, ewma, est, rng = c
            pos, o, ln, v = x
            default = jax.lax.rem(o, m)
            if policy == "rr":
                target = default
            elif policy == "minload":
                target = jnp.argmin(loads).astype(jnp.int32)
            elif policy == "ect":
                target = jnp.argmin((loads + ln) / est).astype(jnp.int32)
            elif policy == "mlml":
                target = order[jax.lax.rem(pos, m)].astype(jnp.int32)
            elif policy == "nltr":
                sec = jnp.clip(jnp.sum((pos >= bounds).astype(jnp.int32)),
                               0, k_sections - 1)
                lo = sec * sec_size
                r1 = _lcg(rng)
                r2 = _lcg(r1)
                rng = r2
                c1 = order[lo + _rand_server(r1, sec_size)].astype(jnp.int32)
                c2 = order[lo + _rand_server(r2, sec_size)].astype(jnp.int32)
                target = jnp.where(loads[c1] <= loads[c2], c1,
                                   c2).astype(jnp.int32)
            elif policy == "two_choice":
                target = default
                best_l = loads[default]
                for _ in range(probe_choices - 1):
                    rng = _lcg(rng)
                    c2 = _rand_server(rng, m)
                    better = loads[c2] < best_l
                    target = jnp.where(better, c2, target).astype(jnp.int32)
                    best_l = jnp.where(better, loads[c2], best_l)
            elif policy in ("two_random", "trh"):
                r1 = _lcg(rng)
                r2 = _lcg(r1)
                rng = r2
                if policy == "two_random":
                    c1, c2 = _rand_server(r1, m), _rand_server(r2, m)
                else:
                    half = max(m // 2, 1)
                    c1 = order[_rand_server(r1, half)].astype(jnp.int32)
                    c2 = order[_rand_server(r2, half)].astype(jnp.int32)
                target = jnp.where(loads[c1] <= loads[c2], c1,
                                   c2).astype(jnp.int32)
            else:
                raise ValueError(policy)
            if policy == "rr":
                choose = default
            elif policy == "ect":
                benefit = ((loads[default] + ln) / est[default]
                           - (loads[target] + ln) / est[target])
                choose = jnp.where(benefit > threshold, target,
                                   default).astype(jnp.int32)
            else:
                benefit = loads[default] - loads[target]
                choose = jnp.where(benefit > threshold, target,
                                   default).astype(jnp.int32)
            onehot = lane == choose
            upd = onehot & v
            new_loads = jnp.where(upd, loads + ln, loads)
            # one-hot masked sums, exactly as the kernel extracts lanes
            p_i = jnp.sum(jnp.where(onehot, probs, 0.0))
            l_i = jnp.sum(jnp.where(onehot, new_loads, 0.0))
            e = jnp.exp(-l_i / lam)
            decayed = p_i * e                                # Eq. (2)
            delta = p_i * (1.0 - e) / (m - 1)                # Eq. (3)
            new_probs = jnp.where(onehot, decayed, probs + delta)
            probs = jnp.where(v, new_probs, probs)
            loads = new_loads
            lat = loads[choose] / jnp.maximum(rates[choose], 1e-6)
            if observe:
                mbps = ln / jnp.maximum(lat, 1e-9)
                old = ewma[choose]
                new = jnp.where(old == 0.0, mbps,
                                # contract-ok: CC-FMA EWMA row is 1e-6-soft (§9)
                                (1 - alpha) * old + alpha * mbps)
                ewma = jnp.where(upd, jnp.where(onehot, new, ewma), ewma)
                dflt = jnp.maximum(jnp.max(ewma), 1.0)
                est = jnp.where(v, jnp.where(ewma > 0, ewma, dflt), est)
            return (loads, probs, ewma, est, rng), \
                (choose, jnp.where(v, lat, 0.0))

        pos = jnp.arange(window_size, dtype=jnp.int32)
        (loads, probs, ewma, est, rng), (ch, lt) = jax.lax.scan(
            step, (loads, probs, ewma, est, rng), (pos, obj_p, len_p, val_p))
        if sort_policy:
            # unsort with ONE vectorized inverse apply (§13) — bit-equal
            # to the one-hot scatter it replaces: every value only MOVES
            ch, lt = permute_from_sorted(rank_req, (ch, lt))
        if renorm:
            # shared core: lane_sum's explicit halving tree (§9 contract)
            probs = renormalize_probs(probs)
        if window_dt:
            # shared core: dec materialized outside the scan (§9 contract)
            loads = drain_loads(loads, rates, window_dt, dec=dec)
        return (loads, probs, ewma, est, rng), (ch, lt, loads)

    rates_f = win_rates.astype(jnp.float32)
    carry0 = (loads0, probs0, ewma0, est0, seed.astype(jnp.uint32))
    (loads, probs, ewma, est, _), (choices, lats, wloads) = jax.lax.scan(
        window, carry0, (obj_w, len_w, val_w, rates_f,
                         window_decrements(rates_f, window_dt)))
    final = jnp.stack([loads, probs, ewma, est])
    return choices.reshape(-1), lats.reshape(-1), final, wloads


def sched_stream_batch_ref(object_ids: jax.Array, lengths: jax.Array,
                           valid: jax.Array, tables: jax.Array,
                           seeds: jax.Array, win_rates: jax.Array, *,
                           n_servers: int, window_size: int,
                           threshold: float, lam: float, alpha: float = 0.25,
                           window_dt: float = 0.0, policy: str = "ect",
                           observe: bool = True, renorm: bool = True,
                           nltr_n: int = 2, probe_choices: int = 2
                           ) -> Tuple[jax.Array, jax.Array, jax.Array,
                                      jax.Array, jax.Array]:
    """Trial-batched oracle for ``ops.sched_stream_batch``: the per-trial
    scan replay vmapped over the leading trial axis, plus the fused
    metrics twin (`policy_core.stream_metrics`) over the per-trial
    latencies.  Same shapes as the grid kernel: object_ids/lengths/valid
    (T, N), tables (T, 4, M), seeds (T,), win_rates (T, W, M); returns
    (choices, latencies, final_tables, window_loads, metrics
    (T, N_METRICS))."""
    one = functools.partial(
        sched_stream_ref, n_servers=n_servers, window_size=window_size,
        threshold=threshold, lam=lam, alpha=alpha, window_dt=window_dt,
        policy=policy, observe=observe, renorm=renorm, nltr_n=nltr_n,
        probe_choices=probe_choices)
    choices, lats, finals, wloads = jax.vmap(one)(
        object_ids, lengths, valid, tables, seeds, win_rates)
    metrics = stream_metrics(lats, valid.astype(bool), window_dt,
                             window_size)
    return choices, lats, finals, wloads, metrics


def sched_stream_grid_ref(object_ids: jax.Array, lengths: jax.Array,
                          valid: jax.Array, tables: jax.Array,
                          seeds: jax.Array, win_rates: jax.Array, *,
                          n_servers: int, window_size: int,
                          threshold: float, lam: float, alpha: float = 0.25,
                          window_dt: float = 0.0, policy: str = "ect",
                          observe: bool = True, renorm: bool = True,
                          client_tile=None, nltr_n: int = 2,
                          probe_choices: int = 2):
    """2-D (trials × clients) oracle for ``ops.sched_stream_grid``: the
    per-stream scan replay vmapped over BOTH leading axes (a trial's
    clients share its ``win_rates`` trace), plus the cross-client merge
    twins — `policy_core.masked_client_mean` over the per-client window
    loads and `policy_core.client_stream_metrics` over the per-client
    fused metric rows (its MET_P99 lane the nearest-rank p99 over the
    trial's MERGED latency block, DESIGN.md §14), with a client REAL iff
    its slice holds any valid request.  Same shapes as the grid kernel:
    object_ids/lengths/valid (T, C, N), tables (T, C, 4, M), seeds
    (T, C), win_rates (T, W, M); returns (choices, latencies,
    final_tables, window_loads, metrics (T, C, N_METRICS), cm_wloads
    (T, W, M), cm_metrics (T, N_CMETRICS), cm_lats (T, C, N) masked
    latencies, cm_lval (T, C, N) 0/1 validity).
    """
    one = functools.partial(
        sched_stream_ref, n_servers=n_servers, window_size=window_size,
        threshold=threshold, lam=lam, alpha=alpha, window_dt=window_dt,
        policy=policy, observe=observe, renorm=renorm, nltr_n=nltr_n,
        probe_choices=probe_choices)
    per_trial = jax.vmap(one, in_axes=(0, 0, 0, 0, 0, None))
    choices, lats, finals, wloads = jax.vmap(per_trial)(
        object_ids, lengths, valid, tables, seeds, win_rates)
    validb = valid.astype(bool)
    metrics = stream_metrics(lats, validb, window_dt, window_size)
    ct = resolve_client_tile(object_ids.shape[1], client_tile)
    cvalid = jnp.any(validb, axis=-1)                        # (T, C)
    cm_lats = jnp.where(validb, lats, 0.0)                   # (T, C, N)
    cm_lval = jnp.where(validb, 1.0, 0.0)
    cm_wl = jax.vmap(lambda w, v: masked_client_mean(w, v, ct))(
        wloads, cvalid)
    cm_met = jax.vmap(
        lambda m, v, ml, mv: client_stream_metrics(
            m, v, ct, merged_lats=ml, merged_valid=mv)
    )(metrics, cvalid, cm_lats, validb)
    return (choices, lats, finals, wloads, metrics, cm_wl, cm_met,
            cm_lats, cm_lval)
