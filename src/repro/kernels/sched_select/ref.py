"""Pure-jnp oracle for the sched_select kernel (bit-identical math).

Replays the same LCG, selection, threshold guard and Eq. (1)-(3) updates
with a ``lax.scan`` carry — the exact state-passing formulation the kernel
replaces with VMEM-resident streaming.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


def _lcg(rng: jax.Array) -> jax.Array:
    return rng * jnp.uint32(1664525) + jnp.uint32(1013904223)


def _rand_server(rng: jax.Array, m: int) -> jax.Array:
    return jax.lax.rem((rng >> jnp.uint32(8)).astype(jnp.int32)
                       & jnp.int32(0x7FFFFFFF), m)


def sched_select_ref(object_ids: jax.Array, lengths: jax.Array,
                     init_loads: jax.Array, seed: jax.Array, *,
                     n_servers: int, threshold: float, lam: float,
                     policy: str) -> Tuple[jax.Array, jax.Array]:
    """Single client. object_ids/lengths: (N,); init_loads: (M_pad,)."""
    m_pad = init_loads.shape[0]
    m = n_servers
    lane = jnp.arange(m_pad)
    valid = lane < m
    loads0 = jnp.where(valid, init_loads, 3.4e38).astype(jnp.float32)
    probs0 = jnp.where(valid, 1.0 / m, 0.0).astype(jnp.float32)

    def step(carry, xs):
        loads, probs, rng = carry
        obj, ln = xs
        default = jax.lax.rem(obj, m)
        if policy == "minload":
            target = jnp.argmin(loads).astype(jnp.int32)
        elif policy == "two_random":
            r1 = _lcg(rng)
            r2 = _lcg(r1)
            rng = r2
            c1, c2 = _rand_server(r1, m), _rand_server(r2, m)
            target = jnp.where(loads[c1] <= loads[c2], c1, c2).astype(jnp.int32)
        else:
            raise ValueError(policy)
        choose = jnp.where(loads[default] - loads[target] > threshold,
                           target, default).astype(jnp.int32)
        onehot = lane == choose
        loads = jnp.where(onehot, loads + ln, loads)
        p_i = probs[choose]
        l_i = loads[choose]
        decayed = p_i * jnp.exp(-l_i / lam)
        delta = (p_i - decayed) / (m - 1)
        probs = jnp.where(onehot, decayed,
                          jnp.where(valid, probs + delta, 0.0))
        return (loads, probs, rng), choose

    (loads, probs, _), choices = jax.lax.scan(
        step, (loads0, probs0, seed.astype(jnp.uint32)),
        (object_ids, lengths))
    return choices, jnp.where(valid, loads, 0.0)
