"""repro.launch — mesh, dry-run, train and serve drivers.

NOTE: do not import ``repro.launch.dryrun`` from library code — it force-
sets the XLA host device count at import time (dry-run only).
"""

from repro.launch.mesh import (  # noqa: F401
    HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh, n_chips,
)
