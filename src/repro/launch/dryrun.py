"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST set the forced device count before ANY other import (jax locks the
device count on first init)::

    python -m repro.launch.dryrun --arch all --shape all --mesh both

Per cell this produces (and appends to a resumable JSON):

* ``compiled.memory_analysis()``  — per-device argument/temp/output bytes
  (proves the cell fits 16 GB HBM);
* ``compiled.cost_analysis()``    — per-device HLO FLOPs / bytes accessed;
* collective bytes parsed from the partitioned HLO text (all-gather /
  all-reduce / reduce-scatter / all-to-all / collective-permute), with
  ring-wire adjustments per replica-group size;
* the three roofline terms (seconds) + MODEL_FLOPS bookkeeping for
  EXPERIMENTS.md §Roofline.
"""

import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ARCH_IDS, SHAPES, get_config, input_specs,
                           shape_applies)
from repro.launch import mesh as M
from repro.models.config import ModelConfig
from repro.parallel import sharding as PS
from repro.train import (OptConfig, abstract_state, make_decode_step,
                         make_prefill_step, make_train_step)

# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<ret>[^=]*?)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def parse_collectives(hlo_text: str) -> Dict[str, Any]:
    """Per-device collective byte totals from partitioned HLO text.

    Returns raw local-output bytes per op kind plus a ring-model 'wire'
    estimate: all-gather (n-1)/n*out, all-reduce 2*(n-1)/n*bytes,
    reduce-scatter (n-1)*out, all-to-all (n-1)/n*bytes, permute 1x.
    """
    raw = {}
    wire = 0.0
    count = 0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        b = _shape_bytes(m.group("ret"))
        if b == 0:
            continue
        n = max(_group_size(line), 1)
        count += 1
        raw[op] = raw.get(op, 0) + b
        if op == "all-gather":
            wire += b * (n - 1) / n
        elif op == "all-reduce":
            wire += 2 * b * (n - 1) / n
        elif op == "reduce-scatter":
            wire += b * (n - 1)
        elif op == "all-to-all":
            wire += b * (n - 1) / n
        else:  # collective-permute
            wire += b
    return {"raw_bytes": raw, "wire_bytes": wire, "n_ops": count}


# ---------------------------------------------------------------------------
# sharding resolution (shared with launch/train.py)
# ---------------------------------------------------------------------------

from repro.launch.shardutil import (  # noqa: E402
    roles_to_shardings, state_shardings)


# ---------------------------------------------------------------------------
# per-cell lowering
# ---------------------------------------------------------------------------

def model_flops_for(cfg: ModelConfig, kind: str, batch: int, seq: int,
                    actual_params: int) -> float:
    """MODEL_FLOPS: 6·N·D for train, 2·N·D for prefill, 2·N_active·B for
    one decode token (N = active params for MoE)."""
    frac_active = cfg.active_param_count() / max(cfg.param_count(), 1)
    n_active = actual_params * frac_active
    if kind == "train":
        return 6.0 * n_active * batch * seq
    if kind == "prefill":
        return 2.0 * n_active * batch * seq
    return 2.0 * n_active * batch  # decode: one token


def inner_scan_correction(cfg: ModelConfig, kind: str, batch: int,
                          seq: int) -> float:
    """Analytic FLOPs for *inner time-scan* bodies that stay rolled even in
    the unrolled cost pass (mamba / sLSTM per-step recurrences, mLSTM
    per-chunk bodies): HloCostAnalysis counts each body once, so we add
    (trips - 1) x body_flops, TOTAL across devices.  Documented in
    EXPERIMENTS.md §Roofline; zero for pure-attention archs.
    """
    if kind == "decode":
        return 0.0  # single step: body counted exactly once
    t = seq
    mult = 3.0 if kind == "train" else 1.0  # fwd+bwd vs fwd
    d = cfg.d_model
    counts = {k: 0 for k in ("mamba", "slstm", "mlstm")}
    for i in range(cfg.n_layers):
        k = cfg.group_pattern[i % cfg.group_size]
        if k in counts:
            counts[k] += 1
    total = 0.0
    if counts["mamba"]:
        inner = cfg.ssm.expand * d
        body = 8.0 * batch * inner * cfg.ssm.d_state
        total += counts["mamba"] * (t - 1) * body
    if counts["slstm"]:
        dh = d // cfg.n_heads
        body = 8.0 * batch * d * dh + 30.0 * batch * d
        total += counts["slstm"] * (t - 1) * body
    if counts["mlstm"]:
        ck = cfg.ssm.chunk
        if t > 1 and t % ck == 0:
            hd = 2 * d // cfg.n_heads
            h = cfg.n_heads
            body = (4.0 * batch * h * ck * ck * hd      # qk^T + w@v
                    + 4.0 * batch * h * ck * hd * hd    # inter + state upd
                    + 8.0 * batch * h * ck * ck)        # decay/mask elemwise
            total += counts["mlstm"] * (t // ck - 1) * body
    return mult * total


# --- §Perf hillclimb variants (EXPERIMENTS.md records each iteration) -----
import dataclasses as _dcv


def _v_gw(cfg, rules):
    """ZeRO-3 weight gathering: all-gather bf16 weights over the FSDP axis
    at use instead of psum-ing fp32 activation partials."""
    return _dcv.replace(cfg, gather_weights=True), rules


def _v_serve(cfg, rules):
    """Serving sharding: bf16 params, TP-only (no FSDP axis) so decode
    never all-gathers weights per token; DP replicas hold full TP shards."""
    return (_dcv.replace(cfg, param_dtype="bfloat16"),
            _dcv.replace(rules, fsdp_axis=None))


def _v_serve_bf16s(cfg, rules):
    """serve + bf16 attention scores/softmax (halves the decode memory
    term's score materialization; f32 accumulators live in the Pallas
    kernel on real TPU)."""
    cfg, rules = _v_serve(cfg, rules)
    return _dcv.replace(cfg, attn_score_dtype="bfloat16"), rules


def _v_serve_int8kv(cfg, rules):
    """serve + int8 KV cache (halves cache bytes — the decode floor)."""
    cfg, rules = _v_serve_bf16s(cfg, rules)
    return _dcv.replace(cfg, kv_cache_dtype="int8"), rules


def _v_gw_dots(cfg, rules):
    """gather-weights + dots-saveable remat (recompute less in backward)."""
    return (_dcv.replace(cfg, gather_weights=True, remat="dots"),
            rules)


def _v_cache4(cfg, rules):
    """llama4: express layers as groups of 4 so only the every-4th global
    layer gets a full-length KV cache (local layers: chunk-sized ring)."""
    assert cfg.global_every == 4 and cfg.group_pattern == ("attn",)
    return _dcv.replace(cfg, group_pattern=("attn",) * 4), rules


def _v_gw_qblock(cfg, rules):
    """gather-weights + smaller attention q-block (512): smaller score
    temporaries per scan step."""
    return (_dcv.replace(cfg, gather_weights=True, attn_q_block=512),
            rules)


def _v_moelocal(cfg, rules):
    """Per-DP-shard MoE dispatch (local capacity pools, gathered bf16
    expert weights) instead of the global-cumsum GShard dispatch."""
    assert cfg.moe is not None
    return (_dcv.replace(cfg, moe=_dcv.replace(cfg.moe, dispatch="local")),
            rules)


def _v_bf16_moelocal(cfg, rules):
    """local MoE dispatch + bf16 params (training in pure bf16 with fp32
    optimizer states would need a master-weight copy; here it bounds the
    memory-term contribution of weight reads)."""
    cfg, rules = _v_moelocal(cfg, rules)
    return _dcv.replace(cfg, param_dtype="bfloat16"), rules


VARIANTS = {
    "gw": _v_gw,
    "serve": _v_serve,
    "serve+bf16s": _v_serve_bf16s,
    "serve+int8kv": _v_serve_int8kv,
    "gw+dots": _v_gw_dots,
    "cache4": _v_cache4,
    "gw+cache4": lambda c, r: _v_gw(*_v_cache4(c, r)),
    "serve+cache4": lambda c, r: _v_serve(*_v_cache4(c, r)),
    "gw+qb512": _v_gw_qblock,
    "moelocal": _v_moelocal,
    "moelocal+bf16": _v_bf16_moelocal,
}


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               cfg_override: Optional[ModelConfig] = None,
               extra_tag: str = "") -> Dict[str, Any]:
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "tag": extra_tag,
    }
    ok, why = shape_applies(cfg, shape)
    if not ok:
        rec.update(status="skip", reason=why)
        return rec

    mesh = M.make_production_mesh(multi_pod=multi_pod)
    rules = PS.make_rules(mesh)
    if extra_tag:
        cfg, rules = VARIANTS[extra_tag](cfg, rules)
    n_dev = M.n_chips(multi_pod)
    t0 = time.time()

    def build_lowered(c: ModelConfig):
        with mesh, PS.use_mesh_rules(rules):
            if shape.kind == "train":
                state_abs = abstract_state(c)
                args_abs, roles = input_specs(c, shape)
                batch_sh = roles_to_shardings(args_abs[0], roles[0], rules)
                st_sh = state_shardings(state_abs, rules)
                step = make_train_step(c, OptConfig())
                return state_abs, jax.jit(
                    step, in_shardings=(st_sh, batch_sh),
                    out_shardings=(st_sh, None),
                    donate_argnums=(0,)).lower(state_abs, args_abs[0])
            state_abs = abstract_state(c)
            params_abs = state_abs.params
            p_sh = jax.tree.map(lambda s: NamedSharding(rules.mesh, s),
                                PS.param_specs(params_abs, rules))
            if shape.kind == "prefill":
                args_abs, roles = input_specs(c, shape)
                batch_sh = roles_to_shardings(args_abs[0], roles[0], rules)
                step = make_prefill_step(c)
                return state_abs, jax.jit(
                    step, in_shardings=(p_sh, batch_sh)).lower(
                    params_abs, args_abs[0])
            (caches_abs, tok_abs, pos_abs), (c_roles, t_roles, _) = \
                input_specs(c, shape)
            c_sh = roles_to_shardings(caches_abs, c_roles, rules)
            t_sh = roles_to_shardings(tok_abs, t_roles, rules)
            rep = NamedSharding(rules.mesh, P())
            step = make_decode_step(c)
            return state_abs, jax.jit(
                step, in_shardings=(p_sh, c_sh, t_sh, rep),
                out_shardings=(None, c_sh),
                donate_argnums=(1,)).lower(
                params_abs, caches_abs, tok_abs, pos_abs)

    try:
        # Pass 1 (deployed artifact, rolled scans): memory analysis.
        state_abs, lowered = build_lowered(cfg)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()

        # Pass 2: exact FLOPs / bytes / collective counts.  HloCostAnalysis
        # counts a while body ONCE regardless of trip count, so instead of
        # compiling the (expensive) fully-unrolled production model we use
        # the exact linearity of per-group cost: lower unrolled 1-group and
        # 2-group twins and extrapolate F(G) = F1 + (G-1)(F2 - F1).  Every
        # per-group quantity (fwd/bwd compute, optimizer, collectives) is
        # linear in group count; the fixed part (embed/head/loss) cancels.
        # The §Roofline table is single-pod only, so the multi-pod pass
        # skips this (compile success + memory are its point).
        import dataclasses as _dc
        t1 = time.time()
        if multi_pod:
            cost = compiled.cost_analysis()
            coll = parse_collectives(compiled.as_text())
            cost_source = "rolled-body-once (roofline uses 16x16 rows)"
        else:
            g = cfg.group_size
            total_g = cfg.n_groups
            g1, g2 = (2, 4) if total_g >= 4 else (1, 2)

            def scaled(ng):
                enc = (max(cfg.n_enc_layers * ng // total_g, 1)
                       if cfg.enc_dec else 0)
                return _dc.replace(cfg, n_layers=ng * g, n_enc_layers=enc,
                                   unroll_scans=True)

            _, l1 = build_lowered(scaled(g1))
            c1 = l1.compile()
            _, l2 = build_lowered(scaled(g2))
            c2 = l2.compile()
            ca1, ca2 = c1.cost_analysis(), c2.cost_analysis()
            co1 = parse_collectives(c1.as_text())
            co2 = parse_collectives(c2.as_text())
            slope = (total_g - g1) / (g2 - g1)

            def lerp(a, b):
                return a + slope * (b - a)

            cost = {k: lerp(float(ca1.get(k, 0.0)), float(ca2.get(k, 0.0)))
                    for k in ("flops", "bytes accessed", "transcendentals")}
            raw = {k: lerp(co1["raw_bytes"].get(k, 0),
                           co2["raw_bytes"].get(k, 0))
                   for k in set(co1["raw_bytes"]) | set(co2["raw_bytes"])}
            coll = {"raw_bytes": raw,
                    "wire_bytes": lerp(co1["wire_bytes"], co2["wire_bytes"]),
                    "n_ops": co2["n_ops"],
                    "extrapolated_from_groups": [g1, g2]}
            cost_source = f"{g1}g/{g2}g-unrolled-extrapolation"
        t_compile_u = time.time() - t1
        actual_params = sum(int(np.prod(l.shape))
                            for l in jax.tree.leaves(state_abs.params))
        corr = inner_scan_correction(cfg, shape.kind, shape.global_batch,
                                     shape.seq_len)
        hlo_flops = float(cost.get("flops", 0.0)) + corr / n_dev
        hlo_bytes = float(cost.get("bytes accessed", 0.0))
        mf = model_flops_for(cfg, shape.kind, shape.global_batch,
                             shape.seq_len, actual_params)
        compute_s = hlo_flops / M.PEAK_FLOPS_BF16
        memory_s = hlo_bytes / M.HBM_BW
        coll_s = coll["wire_bytes"] / M.ICI_BW
        terms = {"compute_s": compute_s, "memory_s": memory_s,
                 "collective_s": coll_s}
        dominant = max(terms, key=terms.get)
        per_dev_bytes = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                         + mem.output_size_in_bytes - mem.alias_size_in_bytes)
        rec.update(
            status="ok",
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            compile_unrolled_s=round(t_compile_u, 2),
            cost_source=cost_source,
            inner_scan_corr_flops=corr,
            n_devices=n_dev,
            params=actual_params,
            memory=dict(
                argument_bytes=mem.argument_size_in_bytes,
                output_bytes=mem.output_size_in_bytes,
                temp_bytes=mem.temp_size_in_bytes,
                alias_bytes=mem.alias_size_in_bytes,
                peak_per_device_bytes=per_dev_bytes,
                fits_16gb=bool(per_dev_bytes < 16e9),
            ),
            cost=dict(hlo_flops_per_dev=hlo_flops,
                      hlo_bytes_per_dev=hlo_bytes),
            collectives=coll,
            roofline=dict(
                **{k: float(v) for k, v in terms.items()},
                dominant=dominant,
                model_flops=mf,
                model_flops_per_dev=mf / n_dev,
                useful_flops_ratio=(mf / n_dev) / hlo_flops if hlo_flops else 0.0,
                roofline_frac=max(terms.values()) and
                    (mf / n_dev / M.PEAK_FLOPS_BF16) / max(terms.values()),
            ),
        )
    except Exception as e:  # lowering/compile failure IS a bug — record it
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    return rec


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def run(arches, shapes, meshes, out_path: str, force: bool = False,
        tag: str = ""):
    results = []
    if os.path.exists(out_path):
        with open(out_path) as f:
            results = json.load(f)
    if force:  # recompute ONLY the requested cells; keep everything else
        requested = {(a, s, m, tag) for a in arches for s in shapes
                     for m in meshes}
        results = [r for r in results
                   if (r["arch"], r["shape"], r["mesh"],
                       r.get("tag", "")) not in requested]
    done = {(r["arch"], r["shape"], r["mesh"], r.get("tag", ""))
            for r in results if r["status"] != "error"}  # retry errors
    results = [r for r in results if r["status"] != "error"]
    for mesh_name in meshes:
        multi = mesh_name == "2x16x16"
        for arch in arches:
            for shape in shapes:
                key = (arch, shape, mesh_name, tag)
                if key in done:
                    continue
                print(f"[dryrun] {arch} x {shape} x {mesh_name} "
                      f"tag={tag or '-'} ...", flush=True)
                rec = lower_cell(arch, shape, multi, extra_tag=tag)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    extra = (f" compile={rec['compile_s']}s "
                             f"dom={rec['roofline']['dominant']} "
                             f"fits={rec['memory']['fits_16gb']}")
                elif status == "error":
                    extra = " " + rec["error"][:160]
                elif status == "skip":
                    extra = " " + rec["reason"][:80]
                print(f"[dryrun]   -> {status}{extra}", flush=True)
                results.append(rec)
                with open(out_path, "w") as f:
                    json.dump(results, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skip, {n_err} error")
    return 1 if n_err else 0


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all' or comma list")
    ap.add_argument("--shape", default="all",
                    help="shape name or 'all' or comma list")
    ap.add_argument("--mesh", default="both",
                    choices=["16x16", "2x16x16", "both"])
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--force", action="store_true",
                    help="recompute cells already in --out")
    ap.add_argument("--variant", default="",
                    help=f"perf variant tag: one of {list(VARIANTS)}")
    args = ap.parse_args()
    arches = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = (["16x16", "2x16x16"] if args.mesh == "both" else [args.mesh])
    raise SystemExit(run(arches, shapes, meshes, args.out, args.force,
                         tag=args.variant))


if __name__ == "__main__":
    main()
