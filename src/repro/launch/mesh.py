"""Production mesh definitions (TPU v5e target).

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init; smoke tests see the real single device.

``make_sweep_mesh`` is the Monte-Carlo sweep's mesh (DESIGN.md §12):
unlike the hard-coded 256/512-chip production meshes it adapts to
whatever ``jax.device_count()`` the process actually has — one forced
host device in CPU tests, {2, 4, 8} under
``XLA_FLAGS=--xla_force_host_platform_device_count=N``, real chips on
TPU — so the sharded sweep dispatch (``parallel/sweep.py``) and its
parity tests construct meshes everywhere.

Hardware constants for the roofline model live here too (per chip):
197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s per ICI link.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np

# TPU v5e per-chip roofline constants (used by benchmarks/roofline.py)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link

SINGLE_POD = (16, 16)
MULTI_POD = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    from repro.compat import make_mesh
    return make_mesh(shape, axes)


def make_sweep_mesh(shape: Optional[Tuple[int, ...]] = None):
    """Sweep mesh over the process's actual devices (DESIGN.md §12).

    ``shape=None`` puts every device on one ``("trials",)`` axis.  An
    explicit 1-tuple names the trial-axis device count; a 2-tuple
    ``(t_dev, c_dev)`` adds a ``"clients"`` axis for the per_client
    contention model.  The shape's product must divide
    ``jax.device_count()`` (the mesh takes the first ``prod(shape)``
    devices), so a config validated on an 8-device CI shard fails
    loudly — naming the actual device count — on a 1-device box instead
    of silently resharding.
    """
    n = jax.device_count()
    if shape is None:
        shape = (n,)
    shape = tuple(int(s) for s in shape)
    if len(shape) not in (1, 2) or any(s < 1 for s in shape):
        raise ValueError(
            f"sweep mesh shape must be (trials,) or (trials, clients) "
            f"positive device counts, got {shape!r}")
    total = 1
    for s in shape:
        total *= s
    if n % total != 0:
        raise ValueError(
            f"sweep mesh shape {shape} needs {total} devices, which does "
            f"not divide jax.device_count()={n}; pick axis sizes whose "
            "product divides the device count (or run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={total})")
    axes = ("trials",) if len(shape) == 1 else ("trials", "clients")
    devices = np.asarray(jax.devices()[:total]).reshape(shape)
    return jax.sharding.Mesh(devices, axes)


def n_chips(multi_pod: bool = False) -> int:
    shape = MULTI_POD if multi_pod else SINGLE_POD
    n = 1
    for s in shape:
        n *= s
    return n
