"""Production mesh definitions (TPU v5e target).

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init; smoke tests see the real single device.

Hardware constants for the roofline model live here too (per chip):
197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s per ICI link.
"""

from __future__ import annotations

import jax

# TPU v5e per-chip roofline constants (used by benchmarks/roofline.py)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link

SINGLE_POD = (16, 16)
MULTI_POD = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    from repro.compat import make_mesh
    return make_mesh(shape, axes)


def n_chips(multi_pod: bool = False) -> int:
    shape = MULTI_POD if multi_pod else SINGLE_POD
    n = 1
    for s in shape:
        n *= s
    return n
