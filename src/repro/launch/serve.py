"""Batched serving driver: prefill a batch of prompts, then decode.

Demonstrates the serving face of the framework — continuous batched decode
with ring KV caches — at CPU scale with reduced configs::

    PYTHONPATH=src python -m repro.launch.serve \
        --arch mixtral-8x22b --reduced --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import encdec as E
from repro.models import transformer as T
from repro.train import make_decode_step


def serve(args) -> dict:
    cfg = get_config(args.arch, reduced=args.reduced)
    key = jax.random.key(args.seed)
    b, s = args.batch, args.prompt_len
    cache_len = s + args.gen

    if cfg.enc_dec:
        params = E.init_encdec(key, cfg)
        frames = jax.random.normal(jax.random.key(1), (b, cfg.enc_seq,
                                                       cfg.d_model))
        enc_out = E.encode(params, frames, cfg)
        caches = E.init_caches(params, enc_out, cfg, b, cache_len)
    else:
        params = T.init_lm(key, cfg)
        caches = T.init_caches(cfg, b, cache_len)

    prompts = jax.random.randint(jax.random.key(2), (b, s), 1,
                                 cfg.vocab_size, dtype=jnp.int32)
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))

    # prefill by teacher-forcing the prompt through the decode path (the
    # blocked prefill kernel is exercised by forward_train / dry-run)
    t0 = time.time()
    logits = None
    for t in range(s):
        logits, caches = decode(params, caches, prompts[:, t:t + 1], t)
    t_prefill = time.time() - t0

    # greedy decode
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, caches = decode(params, caches, tok, s + i)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = np.asarray(jnp.concatenate(out, axis=1))
    toks_per_s = b * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"[serve] arch={cfg.name} batch={b} prompt={s} gen={args.gen}")
    print(f"[serve] prefill {t_prefill:.2f}s, decode {t_decode:.2f}s "
          f"({toks_per_s:.1f} tok/s)")
    print(f"[serve] sample row 0: {gen[0][:16].tolist()}")
    return {"tokens": gen, "tok_per_s": toks_per_s}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    serve(ap.parse_args())


if __name__ == "__main__":
    main()
