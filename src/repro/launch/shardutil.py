"""Shared sharding-resolution helpers for launchers (dryrun / train / serve).

Kept separate from ``dryrun`` so importing these never touches the forced
XLA device-count flag that dryrun must set at import time.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.parallel import sharding as PS


def _resolve_role(role, dim: int, rules: PS.MeshRules):
    if role is None:
        return None
    if role == "batch":
        ax = rules.batch_axes
    elif role in ("model", "seq_model"):
        ax = rules.tp_axis
    elif role == "fsdp":
        ax = rules.fsdp_axis
    else:
        raise ValueError(role)
    if ax is None or dim % rules.axis_size(ax) != 0:
        return None
    return ax


def roles_to_shardings(args_abs, roles, rules: PS.MeshRules):
    """Map role pytrees (lists per leaf) -> NamedSharding pytrees."""
    def one(leaf, role_list):
        if role_list is None:
            return NamedSharding(rules.mesh, P())
        parts = [_resolve_role(r, leaf.shape[i], rules)
                 for i, r in enumerate(role_list)]
        return NamedSharding(rules.mesh, P(*parts))

    return jax.tree.map(one, args_abs, roles,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def state_shardings(state_abs, rules: PS.MeshRules):
    """TrainState shardings: params by rule table, m/v like params,
    scalars replicated (ZeRO-1 falls out of matching specs)."""
    from repro.train.optimizer import OptState
    from repro.train.steps import TrainState
    pspecs = PS.param_specs(state_abs.params, rules)
    to_ns = lambda tree: jax.tree.map(
        lambda s: NamedSharding(rules.mesh, s), tree)
    rep = NamedSharding(rules.mesh, P())
    return TrainState(
        params=to_ns(pspecs),
        opt=OptState(m=to_ns(pspecs), v=to_ns(pspecs), count=rep),
        step=rep)


def param_shardings(params_abs, rules: PS.MeshRules):
    return jax.tree.map(lambda s: NamedSharding(rules.mesh, s),
                        PS.param_specs(params_abs, rules))
