"""End-to-end training driver.

Wires every subsystem together: arch registry -> model -> pjit'd train step
-> deterministic data pipeline -> straggler-aware checkpointing (the
paper's scheduler on the checkpoint write path) -> restart/resume.

CPU-scale example (reduced config, local object store, injected straggler)::

    PYTHONPATH=src python -m repro.launch.train \
        --arch gemma-2b --reduced --steps 60 --ckpt-every 20 \
        --ckpt-dir /tmp/ckpt --policy trh --inject-straggler 2

On a real cluster the same driver runs under ``jax.distributed`` with the
production mesh; mesh axes come from ``--mesh``.
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointConfig, Checkpointer
from repro.configs import get_config
from repro.core.policies import PolicyConfig
from repro.data import DataConfig, SyntheticTokens
from repro.io.client import IOClientConfig
from repro.models.config import ModelConfig
from repro.parallel import sharding as PS
from repro.train import OptConfig, TrainState, init_state, make_train_step


def build_mesh(spec: str):
    if spec == "none" or jax.device_count() == 1:
        return None
    dims = [int(x) for x in spec.split("x")]
    names = ("data", "model")[:len(dims)] if len(dims) <= 2 else \
        ("pod", "data", "model")
    from repro.compat import make_mesh
    return make_mesh(tuple(dims), names)


def make_checkpointer(args, n_servers: int = 8) -> Checkpointer:
    io_cfg = IOClientConfig(
        policy=PolicyConfig(name=args.policy, threshold=args.threshold),
        stripe_size=1 << 20)
    return Checkpointer(
        args.ckpt_dir, n_servers=n_servers,
        cfg=CheckpointConfig(shard_size_mb=4.0, keep_n=3,
                             async_save=args.async_ckpt, io=io_cfg))


def train(args) -> dict:
    cfg = get_config(args.arch, reduced=args.reduced)
    if args.seq_len:
        pass  # seq length is a data property here, not a model one
    opt_cfg = OptConfig(peak_lr=args.lr, warmup_steps=args.warmup,
                        total_steps=args.steps)
    data = SyntheticTokens(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len or 64,
        global_batch=args.batch, seed=args.seed))

    mesh = build_mesh(args.mesh)
    rules = PS.make_rules(mesh) if mesh is not None else None

    ckpt = make_checkpointer(args) if args.ckpt_dir else None
    if args.inject_straggler >= 0 and ckpt is not None:
        ckpt.store.set_write_delay(args.inject_straggler, 0.05)

    state = init_state(jax.random.key(args.seed), cfg)
    start_step = 0
    if ckpt is not None and ckpt.latest_step() is not None and not args.fresh:
        state = ckpt.restore(target=state)
        start_step = int(np.asarray(state.step))
        print(f"[train] resumed from step {start_step}")

    step_fn = make_train_step(cfg, opt_cfg)
    if mesh is not None:
        from repro.launch.shardutil import state_shardings
        st_sh = state_shardings(jax.eval_shape(lambda: state), rules)
        state = jax.device_put(state, st_sh)
        step_fn = jax.jit(step_fn, in_shardings=(st_sh, None),
                          out_shardings=(st_sh, None), donate_argnums=(0,))
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0,))

    metrics = {}
    t0 = time.time()
    ctx = PS.use_mesh_rules(rules) if rules is not None else _null()
    with ctx:
        for step in range(start_step, args.steps):
            batch = data.batch_at(step)
            state, metrics = step_fn(state, batch)
            if args.ckpt_every and ckpt is not None \
                    and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, state, block=not args.async_ckpt)
            if (step + 1) % args.log_every == 0:
                m = {k: float(np.asarray(v)) for k, v in metrics.items()}
                print(f"[train] step {step+1:5d} loss={m['loss']:.4f} "
                      f"nll={m.get('nll', 0):.4f} "
                      f"gnorm={m.get('grad_norm', 0):.3f} "
                      f"({(time.time()-t0)/(step-start_step+1):.2f}s/step)",
                      flush=True)
    out = {k: float(np.asarray(v)) for k, v in metrics.items()}
    if ckpt is not None:
        ckpt.save(args.steps, state)
        out["ckpt_stats"] = ckpt.client.stats()
        ckpt.close()
    return out


class _null:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced (smoke) config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default="none",
                    help="'none' or e.g. '2x4' / '2x2x2'")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--async-ckpt", action="store_true")
    ap.add_argument("--fresh", action="store_true",
                    help="ignore existing checkpoints")
    ap.add_argument("--policy", default="trh",
                    choices=["rr", "mlml", "trh", "nltr", "two_choice", "ect"])
    ap.add_argument("--threshold", type=float, default=4.0)
    ap.add_argument("--inject-straggler", type=int, default=-1,
                    help="object-server id to slow down (-1 = none)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()
    out = train(args)
    print("[train] final:", {k: v for k, v in out.items()
                             if not isinstance(v, dict)})


if __name__ == "__main__":
    main()
