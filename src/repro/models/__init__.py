"""repro.models — composable model substrate: GQA transformers, MoE,
Mamba/mLSTM/sLSTM blocks, hybrid interleaves, encoder-decoder."""

from repro.models.config import ModelConfig, MoEConfig, SSMConfig  # noqa: F401
from repro.models import (  # noqa: F401
    attention, encdec, layers, moe, ssm, transformer,
)
