"""GQA attention: blocked full/prefill path + single-token decode path.

Design notes (TPU adaptation, see DESIGN.md):

* The train/prefill path is *blocked*: queries are processed in chunks of
  ``q_block`` via ``lax.scan``, so the (S x S) score matrix is never
  materialized — at 32k context the full matrix would be ~4 TB global.
  The per-iteration working set is (B, KV, G, q_block, S) fp32 scores.
  (The Pallas flash kernel in ``repro.kernels.flash_attention`` is the
  fused VMEM-tiled form of the same loop; ``use_pallas_attn`` swaps it in.)
* Locality masks: causal, sliding-window (danube/mixtral), chunked-local
  (llama4), or none (whisper cross-attention).  ``is_global`` may be a
  *traced* per-layer boolean (llama4 interleaves local/global inside one
  scanned stack) — both masks are formed and selected elementwise.
* Decode uses a ring KV cache sized to the layer's actual receptive field
  (full: S; SWA: window; chunked: chunk) with absolute slot positions for
  masking; keys are stored post-RoPE.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig

Params = Dict[str, jax.Array]

NEG_INF = -1e30


# ------------------------------------------------------------------- params

def init_attention(key, cfg: ModelConfig, cross: bool = False) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.he_init(ks[0], (d, h * hd), cfg.pdtype, fan_in=d),
        "wk": L.he_init(ks[1], (d, kv * hd), cfg.pdtype, fan_in=d),
        "wv": L.he_init(ks[2], (d, kv * hd), cfg.pdtype, fan_in=d),
        "wo": L.he_init(ks[3], (h * hd, d), cfg.pdtype, fan_in=h * hd),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((h * hd,), cfg.pdtype)
        p["bk"] = jnp.zeros((kv * hd,), cfg.pdtype)
        p["bv"] = jnp.zeros((kv * hd,), cfg.pdtype)
    return p


def project_q(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    b, s, _ = x.shape
    q = x @ L.wcast(p, "wq", cfg, [None, "model"])
    if "bq" in p:
        q = q + L.cast_to(p["bq"], cfg.cdtype)
    return q.reshape(b, s, cfg.n_heads, cfg.hd)


def project_kv(p: Params, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    b, s, _ = x.shape
    k = x @ L.wcast(p, "wk", cfg, [None, "model"])
    v = x @ L.wcast(p, "wv", cfg, [None, "model"])
    if "bk" in p:
        k = k + L.cast_to(p["bk"], cfg.cdtype)
        v = v + L.cast_to(p["bv"], cfg.cdtype)
    return (k.reshape(b, s, cfg.n_kv_heads, cfg.hd),
            v.reshape(b, s, cfg.n_kv_heads, cfg.hd))


def out_proj(p: Params, o: jax.Array, cfg: ModelConfig) -> jax.Array:
    b, s = o.shape[:2]
    return o.reshape(b, s, cfg.n_heads * cfg.hd) @ \
        L.wcast(p, "wo", cfg, ["model", None])


def maybe_rope(x: jax.Array, positions, cfg: ModelConfig,
               use_rope=True) -> jax.Array:
    """RoPE / M-RoPE / partial-rotary; ``use_rope`` may be traced."""
    if not cfg.use_rope:
        return x
    if cfg.mrope:
        roped = L.apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        roped = L.apply_rope(x, positions, cfg.rope_theta, cfg.rotary_pct)
    if isinstance(use_rope, bool):
        return roped if use_rope else x
    return jnp.where(use_rope, roped, x)


# ------------------------------------------------------------------ masking

def _local_mask(qpos: jax.Array, kpos: jax.Array, cfg: ModelConfig,
                is_global) -> jax.Array:
    """(Tq, Tk) bool mask. qpos/kpos are absolute positions; is_global may
    be traced (llama4 global layers use plain causal)."""
    causal = kpos[None, :] <= qpos[:, None]
    local = causal
    if cfg.sliding_window is not None:
        local = causal & (qpos[:, None] - kpos[None, :] < cfg.sliding_window)
    if cfg.chunk_attn is not None:
        local = causal & (qpos[:, None] // cfg.chunk_attn
                          == kpos[None, :] // cfg.chunk_attn)
    if isinstance(is_global, bool):
        return causal if is_global else local
    return jnp.where(is_global, causal, local)


# --------------------------------------------------------- full / prefill

def gqa_attend(q: jax.Array, k: jax.Array, v: jax.Array,
               mask: Optional[jax.Array], cfg: ModelConfig) -> jax.Array:
    """One attention pass. q: (B,Tq,H,hd); k,v: (B,Tk,KV,hd);
    mask: (Tq,Tk) or (B,Tq,Tk) bool or None."""
    b, tq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    sdt = jnp.dtype(cfg.attn_score_dtype)
    qg = q.reshape(b, tq, kvh, g, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                        preferred_element_type=sdt)
    scores = scores * jnp.asarray(1.0 / jnp.sqrt(hd), sdt)
    if mask is not None:
        m = mask if mask.ndim == 3 else mask[None]
        scores = jnp.where(m[:, None, None],
                           scores, jnp.asarray(NEG_INF, sdt))
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(b, tq, h, hd)


def attend_blocked(q: jax.Array, k: jax.Array, v: jax.Array,
                   cfg: ModelConfig, *, is_global=False,
                   causal: bool = True, q_offset: int = 0,
                   q_block: Optional[int] = None) -> jax.Array:
    """Query-blocked attention (never materializes S x S scores)."""
    q_block = q_block or cfg.attn_q_block
    b, s, h, hd = q.shape
    tk = k.shape[1]
    kpos = jnp.arange(tk)
    if s <= q_block:
        mask = (_local_mask(jnp.arange(s) + q_offset, kpos, cfg, is_global)
                if causal else None)
        return gqa_attend(q, k, v, mask, cfg)
    nb = -(-s // q_block)
    pad = nb * q_block - s
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else q
    qb = jnp.moveaxis(qp.reshape(b, nb, q_block, h, hd), 1, 0)

    def body(carry, xs):
        qi, blk = xs
        qpos = qi * q_block + jnp.arange(q_block) + q_offset
        mask = _local_mask(qpos, kpos, cfg, is_global) if causal else None
        return carry, gqa_attend(blk, k, v, mask, cfg)

    _, ob = jax.lax.scan(body, None, (jnp.arange(nb), qb),
                         unroll=nb if cfg.unroll_scans else 1)
    out = jnp.moveaxis(ob, 0, 1).reshape(b, nb * q_block, h, hd)
    return out[:, :s]


# -------------------------------------------------------------- decode path

def cache_size_for(cfg: ModelConfig, seq_len: int, layer_has_global: bool) -> int:
    """Ring-cache slots a layer actually needs at decode time."""
    if layer_has_global:
        return seq_len
    size = seq_len
    if cfg.sliding_window is not None:
        size = min(size, cfg.sliding_window)
    if cfg.chunk_attn is not None:
        size = min(size, cfg.chunk_attn)
    return size


def init_kv_cache(cfg: ModelConfig, batch: int, size: int) -> Params:
    """Empty ring cache. ``slot_pos`` holds each slot's absolute position
    (-1 = empty); keys are stored post-RoPE.

    ``kv_cache_dtype="int8"`` stores symmetric per-(slot, head) quantized
    entries + f32 scales (§Perf: halves decode cache bytes vs bf16)."""
    kv, hd = cfg.n_kv_heads, cfg.hd
    cache: Params = {"slot_pos": jnp.full((size,), -1, jnp.int32)}
    if cfg.kv_cache_dtype == "int8":
        cache["k"] = jnp.zeros((batch, size, kv, hd), jnp.int8)
        cache["v"] = jnp.zeros((batch, size, kv, hd), jnp.int8)
        cache["k_scale"] = jnp.zeros((batch, size, kv, 1), jnp.float32)
        cache["v_scale"] = jnp.zeros((batch, size, kv, 1), jnp.float32)
    else:
        cache["k"] = jnp.zeros((batch, size, kv, hd), cfg.cdtype)
        cache["v"] = jnp.zeros((batch, size, kv, hd), cfg.cdtype)
    return cache


def _quantize_kv(x: jax.Array):
    """Symmetric int8 per-(token, head) quantization over head_dim."""
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                                keepdims=True) / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                 -127, 127).astype(jnp.int8)
    return q, scale


def decode_attend(p: Params, x1: jax.Array, cache: Params, pos: jax.Array,
                  cfg: ModelConfig, is_global=False,
                  use_rope=True) -> Tuple[jax.Array, Params]:
    """One-token decode: write (k,v) at ``pos % size``, attend the ring.

    x1: (B, 1, d); pos: scalar int32 absolute position of the new token.
    """
    b = x1.shape[0]
    size = cache["k"].shape[1]
    q = project_q(p, x1, cfg)
    k1, v1 = project_kv(p, x1, cfg)
    if cfg.mrope:
        pos3 = jnp.broadcast_to(pos, (3, b, 1)).astype(jnp.int32)
        q = maybe_rope(q, pos3, cfg, use_rope)
        k1 = maybe_rope(k1, pos3, cfg, use_rope)
    else:
        pos_b = jnp.broadcast_to(pos, (b, 1)).astype(jnp.int32)
        q = maybe_rope(q, pos_b, cfg, use_rope)
        k1 = maybe_rope(k1, pos_b, cfg, use_rope)
    slot = (pos % size).astype(jnp.int32)
    new_cache: Params = {}
    if cfg.kv_cache_dtype == "int8":
        kq, ks = _quantize_kv(k1)
        vq, vs = _quantize_kv(v1)
        kqc = jax.lax.dynamic_update_slice(cache["k"], kq, (0, slot, 0, 0))
        vqc = jax.lax.dynamic_update_slice(cache["v"], vq, (0, slot, 0, 0))
        ksc = jax.lax.dynamic_update_slice(cache["k_scale"], ks,
                                           (0, slot, 0, 0))
        vsc = jax.lax.dynamic_update_slice(cache["v_scale"], vs,
                                           (0, slot, 0, 0))
        kc = (kqc.astype(cfg.cdtype)
              * ksc.astype(cfg.cdtype))
        vc = (vqc.astype(cfg.cdtype)
              * vsc.astype(cfg.cdtype))
        new_cache.update(k=kqc, v=vqc, k_scale=ksc, v_scale=vsc)
    else:
        kc = jax.lax.dynamic_update_slice(cache["k"], k1, (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], v1, (0, slot, 0, 0))
        new_cache.update(k=kc, v=vc)
    slot_pos = cache["slot_pos"].at[slot].set(pos.astype(jnp.int32))
    new_cache["slot_pos"] = slot_pos

    # ring mask from absolute slot positions
    sp = slot_pos
    valid = (sp >= 0) & (sp <= pos)
    if cfg.sliding_window is not None:
        local_valid = valid & (pos - sp < cfg.sliding_window)
    elif cfg.chunk_attn is not None:
        local_valid = valid & (sp // cfg.chunk_attn == pos // cfg.chunk_attn)
    else:
        local_valid = valid
    if isinstance(is_global, bool):
        mask = valid if is_global else local_valid
    else:
        mask = jnp.where(is_global, valid, local_valid)

    out = gqa_attend(q, kc, vc, jnp.broadcast_to(mask[None, None, :],
                                                 (b, 1, size)), cfg)
    y = out_proj(p, out, cfg)
    return y, new_cache


# --------------------------------------------------------------- train path

def self_attend(p: Params, x: jax.Array, positions, cfg: ModelConfig, *,
                is_global=False, use_rope=True,
                q_block: Optional[int] = None) -> jax.Array:
    """Full causal self-attention over x: (B, S, d)."""
    q = project_q(p, x, cfg)
    k, v = project_kv(p, x, cfg)
    q = maybe_rope(q, positions, cfg, use_rope)
    k = maybe_rope(k, positions, cfg, use_rope)
    if cfg.use_pallas_attn:
        from repro.kernels.flash_attention import ops as fops
        o = fops.flash_attention(
            q, k, v, causal=True,
            window=cfg.sliding_window, chunk=cfg.chunk_attn,
            is_global=bool(is_global) if isinstance(is_global, bool) else False)
    else:
        o = attend_blocked(q, k, v, cfg, is_global=is_global, causal=True,
                           q_block=q_block)
    return out_proj(p, o, cfg)


def cross_attend(p: Params, x: jax.Array, enc_kv: Tuple[jax.Array, jax.Array],
                 cfg: ModelConfig) -> jax.Array:
    """Encoder-decoder cross attention (no mask, no rope)."""
    q = project_q(p, x, cfg)
    k, v = enc_kv
    o = attend_blocked(q, k, v, cfg, causal=False)
    return out_proj(p, o, cfg)


def precompute_cross_kv(p: Params, enc_out: jax.Array,
                        cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    return project_kv(p, L.cast_to(enc_out, cfg.cdtype), cfg)
