"""Unified model configuration covering all ten assigned architectures.

One dataclass describes dense / GQA / MQA transformers, MoE, SSM (mamba),
xLSTM (mLSTM + sLSTM), hybrid interleaves, and encoder-decoder backbones.
Layer layout is expressed as a repeating *group pattern*: a tuple of block
kinds of length G; the stack is ``n_layers / G`` scanned groups whose
parameters are stacked along a leading group axis (small HLO for 80-layer
models).

Block kinds: ``"attn"`` (self-attention + MLP/MoE), ``"mamba"`` (selective
SSM + MLP/MoE), ``"mlstm"`` / ``"slstm"`` (xLSTM blocks, self-contained).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    every_n_layers: int = 1       # MoE on layers where (layer % n) == n-1
    router_aux_weight: float = 0.01
    # "global": one token-ordered capacity pool (paper-faithful GShard
    #           cumsum; SPMD cost = full-buffer psums per MoE layer).
    # "local":  per-data-shard capacity pools — dispatch scatter/gather
    #           stay local to each DP shard and the expert weights are
    #           all-gathered (bf16) instead; §Perf hillclimb for the
    #           collective-bound MoE trains.  Identical when DP size = 1.
    dispatch: str = "global"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    chunk: int = 128              # chunked-scan length (TPU-friendly)
    dt_rank: Optional[int] = None  # defaults to ceil(d_model/16)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None        # default d_model // n_heads
    group_pattern: Tuple[str, ...] = ("attn",)
    norm: str = "rmsnorm"                 # rmsnorm | layernorm
    activation: str = "swiglu"            # swiglu | geglu | gelu
    qkv_bias: bool = False
    tie_embeddings: bool = False
    embed_scale: bool = False             # gemma: x *= sqrt(d_model)

    # positional encoding
    use_rope: bool = True                 # whisper: sinusoidal abs instead
    rope_theta: float = 1e6
    rotary_pct: float = 1.0               # stablelm: 0.25
    mrope: bool = False                   # qwen2-vl M-RoPE (3 sections)
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # fractions of hd/2

    # attention locality
    sliding_window: Optional[int] = None  # SWA width (danube, mixtral)
    chunk_attn: Optional[int] = None      # llama4 chunked-local width
    global_every: Optional[int] = None    # llama4: every Nth layer global

    # mixtures / ssm
    moe: Optional[MoEConfig] = None
    ssm: SSMConfig = SSMConfig()

    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500                   # whisper frame count after conv

    # numerics / execution
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "block"                  # none | block | dots
    use_pallas_attn: bool = False
    logit_softcap: Optional[float] = None
    attn_q_block: int = 1024              # query-block size (XLA attention)
    # Attention score/softmax dtype.  float32 for training fidelity;
    # serving configs use bfloat16 (halves the dominant decode memory
    # term; the Pallas flash kernel keeps f32 accumulators in VMEM either
    # way). §Perf serve iteration.
    attn_score_dtype: str = "float32"
    # KV-cache storage dtype: "bfloat16" (default) or "int8" (per-slot
    # per-head symmetric quantization; halves decode cache bytes — the
    # dominant decode memory term once serving sharding is fixed).
    kv_cache_dtype: str = "bfloat16"
    # Fully unroll group/attention scans.  Used by the dry-run's *cost*
    # lowering only: XLA's HloCostAnalysis counts a while-loop body ONCE
    # regardless of trip count, so rolled-scan FLOPs/collectives are
    # undercounted; unrolled lowering gives exact totals.  The deployed
    # (memory-analysis) artifact keeps rolled scans.
    unroll_scans: bool = False
    # ZeRO-3-style weight gathering (§Perf hillclimb): constrain the bf16
    # cast of every FSDP-sharded weight to drop the "data"-axis sharding at
    # use, so XLA all-gathers the (small) weights over the FSDP axis
    # instead of psum-ing the (huge) activation partials it otherwise
    # prefers.  Off = paper-faithful baseline sharding; see EXPERIMENTS.md.
    gather_weights: bool = False

    # scale metadata (roofline bookkeeping)
    notes: str = ""

    def __post_init__(self):
        if self.n_layers % len(self.group_pattern):
            raise ValueError(f"{self.name}: n_layers {self.n_layers} not a "
                             f"multiple of group size {len(self.group_pattern)}")
        if self.n_heads % self.n_kv_heads:
            raise ValueError("n_heads must be a multiple of n_kv_heads")

    # ----------------------------------------------------------- dimensions
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 so the embedding/head shard on 16-way TP
        (DESIGN.md: configs keep the true vocab; padding is internal)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.group_pattern)

    @property
    def group_size(self) -> int:
        return len(self.group_pattern)

    def block_kind(self, pos: int) -> str:
        return self.group_pattern[pos]

    def layer_is_moe(self, layer_idx: int) -> bool:
        if self.moe is None:
            return False
        n = self.moe.every_n_layers
        return layer_idx % n == n - 1

    def layer_is_global_attn(self, layer_idx: int) -> bool:
        """llama4: every ``global_every``-th layer attends globally (NoPE)."""
        if self.global_every is None:
            return False
        return (layer_idx + 1) % self.global_every == 0

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    # ------------------------------------------------------------- counting
    def param_count(self) -> int:
        """Exact parameter count (embedding + blocks + norms + head)."""
        d, ff, v, hd = self.d_model, self.d_ff, self.vocab_size, self.hd
        h, kv = self.n_heads, self.n_kv_heads
        total = v * d                                   # embedding
        if not self.tie_embeddings:
            total += d * v                              # lm head
        total += d                                      # final norm
        for i in range(self.n_layers):
            kind = self.block_kind(i % self.group_size)
            if kind == "attn":
                total += d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d
                if self.qkv_bias:
                    total += h * hd + 2 * kv * hd
                total += d  # attn norm
            elif kind == "mamba":
                s = self.ssm
                inner = s.expand * d
                dtr = s.dt_rank or -(-d // 16)
                total += d * 2 * inner            # in_proj (x, z)
                total += inner * s.d_conv         # conv
                total += inner * (dtr + 2 * s.d_state)  # x -> dt,B,C
                total += dtr * inner + inner      # dt proj + bias
                total += inner * s.d_state + inner  # A_log, D
                total += inner * d                # out_proj
                total += d                        # norm
            elif kind == "mlstm":
                inner = 2 * d
                total += d * 2 * inner            # up proj (x, z)
                total += 3 * inner * inner // 4   # q,k,v proj (blockdiag/4 heads)
                total += 3 * inner                # i,f,o gates (per-dim)
                total += inner * d                # down proj
                total += 2 * d                    # norms
            elif kind == "slstm":
                total += 4 * d * d + 4 * d        # input gates W (i,f,z,o)
                total += 4 * d * d                # recurrent R (i,f,z,o)
                total += 2 * d * ff_slstm(d)      # post-FFN up/down (4/3 d)
                total += 2 * d                    # norms
            if kind in ("attn", "mamba"):
                if self.layer_is_moe(i):
                    m = self.moe
                    total += d * m.n_experts            # router
                    n_mats = 3 if self.activation in ("swiglu", "geglu") else 2
                    total += m.n_experts * n_mats * d * ff
                else:
                    n_mats = 3 if self.activation in ("swiglu", "geglu") else 2
                    total += n_mats * d * ff
                total += d  # mlp norm
        if self.enc_dec:
            # encoder layers + cross attention in decoder
            for _ in range(self.n_enc_layers):
                total += d * (h * hd) * 2 + 2 * d * (kv * hd) + 3 * d * ff + 2 * d
            total += self.n_layers * (d * (h * hd) + 2 * d * (kv * hd)
                                      + (h * hd) * d + d)
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE top-k instead of all experts)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        n_mats = 3 if self.activation in ("swiglu", "geglu") else 2
        per_expert = n_mats * self.d_model * self.d_ff
        n_moe_layers = sum(1 for i in range(self.n_layers)
                           if self.layer_is_moe(i))
        return (self.param_count()
                - n_moe_layers * (m.n_experts - m.top_k) * per_expert)


def ff_slstm(d: int) -> int:
    """sLSTM post-FFN width: 4/3 * d, rounded up to 128 (TP divisibility)."""
    return -(-(4 * d // 3) // 128) * 128
