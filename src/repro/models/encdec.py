"""Encoder-decoder backbone (whisper-tiny).

The conv/mel frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, S_enc, d) — here we add
sinusoidal positions and run the transformer encoder.  The decoder is a
causal transformer with cross-attention; token/position embeddings are
sinusoidal (deviation from whisper's learned positional embeddings, noted
in DESIGN.md — shape/FLOP identical).

Decode path: self-attention ring caches + cross-attention K/V precomputed
once from the encoder output.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.parallel import sharding as PS

Params = Dict[str, Any]


def sinusoid(seq: int, d: int, dtype) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ----------------------------------------------------------------- init

def _init_enc_block(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "attn_norm": L.init_norm(cfg.norm, cfg.d_model, jnp.float32),
        "attn": A.init_attention(ks[0], cfg),
        "mlp_norm": L.init_norm(cfg.norm, cfg.d_model, jnp.float32),
        "mlp": L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.activation,
                          cfg.pdtype),
    }


def _init_dec_block(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "attn_norm": L.init_norm(cfg.norm, cfg.d_model, jnp.float32),
        "attn": A.init_attention(ks[0], cfg),
        "cross_norm": L.init_norm(cfg.norm, cfg.d_model, jnp.float32),
        "cross": A.init_attention(ks[1], cfg, cross=True),
        "mlp_norm": L.init_norm(cfg.norm, cfg.d_model, jnp.float32),
        "mlp": L.init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.activation,
                          cfg.pdtype),
    }


def init_encdec(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4)
    enc_keys = jax.random.split(ks[0], cfg.n_enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "embed": L.init_embedding(ks[2], cfg.padded_vocab, cfg.d_model,
                                  cfg.pdtype),
        "enc_groups": {"pos_0": jax.vmap(
            lambda k: _init_enc_block(k, cfg))(enc_keys)},
        "enc_final_norm": L.init_norm(cfg.norm, cfg.d_model, jnp.float32),
        "groups": {"pos_0": jax.vmap(
            lambda k: _init_dec_block(k, cfg))(dec_keys)},
        "final_norm": L.init_norm(cfg.norm, cfg.d_model, jnp.float32),
    }


# -------------------------------------------------------------- encoder

def encode(params: Params, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """frames: (B, S_enc, d) stub embeddings -> encoder states."""
    b, s, d = frames.shape
    x = L.cast_to(frames, cfg.cdtype) + sinusoid(s, d, cfg.cdtype)[None]
    x = PS.activations(x)

    def body(x, gp):
        h = L.apply_norm(cfg.norm, gp["attn_norm"], x)
        q = A.project_q(gp["attn"], h, cfg)
        k, v = A.project_kv(gp["attn"], h, cfg)
        o = A.attend_blocked(q, k, v, cfg, causal=False)
        x = x + A.out_proj(gp["attn"], o, cfg)
        h = L.apply_norm(cfg.norm, gp["mlp_norm"], x)
        x = x + L.apply_mlp(gp["mlp"], h, cfg)
        return PS.activations(x), None

    x, _ = jax.lax.scan(body, x, params["enc_groups"]["pos_0"],
                        unroll=cfg.n_enc_layers if cfg.unroll_scans else 1)
    return L.apply_norm(cfg.norm, params["enc_final_norm"], x)


# --------------------------------------------------------------- decoder

def _dec_block(gp: Params, x: jax.Array, enc_out: jax.Array,
               cfg: ModelConfig, positions) -> jax.Array:
    h = L.apply_norm(cfg.norm, gp["attn_norm"], x)
    x = x + A.self_attend(gp["attn"], h, positions, cfg)
    h = L.apply_norm(cfg.norm, gp["cross_norm"], x)
    enc_kv = A.precompute_cross_kv(gp["cross"], enc_out, cfg)
    x = x + A.cross_attend(gp["cross"], h, enc_kv, cfg)
    h = L.apply_norm(cfg.norm, gp["mlp_norm"], x)
    return x + L.apply_mlp(gp["mlp"], h, cfg)


def forward_train(params: Params, batch: Dict[str, jax.Array],
                  cfg: ModelConfig) -> Tuple[jax.Array, Any]:
    """batch: {"frames": (B,S_enc,d), "tokens": (B,S_dec)} -> logits."""
    enc_out = encode(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = L.embed(params["embed"], tokens, cfg.cdtype)
    x = x + sinusoid(s, cfg.d_model, cfg.cdtype)[None]
    x = PS.activations(x)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(x, gp):
        return PS.activations(_dec_block(gp, x, enc_out, cfg, positions)), None

    body = jax.checkpoint(body) if cfg.remat != "none" else body
    x, _ = jax.lax.scan(body, x, params["groups"]["pos_0"],
                        unroll=cfg.n_layers if cfg.unroll_scans else 1)
    x = L.apply_norm(cfg.norm, params["final_norm"], x)
    logits = L.unembed(None, params["embed"], x, cfg.cdtype)  # tied head
    return logits, None


def lm_loss(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, _ = forward_train(params, batch, cfg)
    targets = batch["targets"]
    logits32 = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits32, axis=-1)
    viota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, logits.shape[-1]), 2)
    gold = jnp.sum(jnp.where(viota == targets[..., None], logits32, 0.0),
                   axis=-1)
    nll = jnp.mean(lse - gold)
    return nll, {"nll": nll}


# ---------------------------------------------------------------- decode

def init_caches(params: Params, enc_out: jax.Array, cfg: ModelConfig,
                batch: int, seq_len: int) -> Params:
    """Self-attn ring caches + precomputed cross K/V for every dec layer."""
    def cross_kv(gp):
        k, v = A.precompute_cross_kv(gp["cross"], enc_out, cfg)
        return {"ck": k, "cv": v}

    cross = jax.vmap(cross_kv)(params["groups"]["pos_0"])
    self_cache = jax.vmap(lambda _: A.init_kv_cache(cfg, batch, seq_len))(
        jnp.arange(cfg.n_layers))
    return {"self": self_cache, "cross": cross}


def decode_step(params: Params, caches: Params, tokens: jax.Array,
                pos, cfg: ModelConfig) -> Tuple[jax.Array, Params]:
    """tokens: (B, 1); pos: scalar. Returns (logits, new caches)."""
    pos = jnp.asarray(pos, jnp.int32)
    b = tokens.shape[0]
    x = L.embed(params["embed"], tokens, cfg.cdtype)
    x = x + _pos_embed_at(pos, cfg)
    x = PS.constrain(x, ["batch", None, None])

    def body(x, xs):
        gp, sc, cc = xs
        h = L.apply_norm(cfg.norm, gp["attn_norm"], x)
        y, sc = A.decode_attend(gp["attn"], h, sc, pos, cfg)
        x = x + y
        h = L.apply_norm(cfg.norm, gp["cross_norm"], x)
        x = x + A.cross_attend(gp["cross"], h, (cc["ck"], cc["cv"]), cfg)
        h = L.apply_norm(cfg.norm, gp["mlp_norm"], x)
        x = x + L.apply_mlp(gp["mlp"], h, cfg)
        return x, sc

    x, new_self = jax.lax.scan(
        body, x, (params["groups"]["pos_0"], caches["self"], caches["cross"]))
    x = L.apply_norm(cfg.norm, params["final_norm"], x)
    logits = L.unembed(None, params["embed"], x, cfg.cdtype)
    return logits, {"self": new_self, "cross": caches["cross"]}


def _pos_embed_at(pos, cfg: ModelConfig) -> jax.Array:
    d = cfg.d_model
    dim = jnp.arange(d // 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None].astype(
        cfg.cdtype)
