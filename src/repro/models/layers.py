"""Shared primitive layers: norms, MLPs, rotary embeddings, initializers.

Convention: every layer is a pair of pure functions ``init_*(key, ...) ->
params`` and ``apply_*(params, x, ...) -> y`` over plain dict pytrees.
Parameters are stored in ``cfg.param_dtype`` and cast to
``cfg.compute_dtype`` at use; norm/softmax reductions run in float32.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, jax.Array]


# ----------------------------------------------------------------- numerics

def cast_to(x: jax.Array, dtype) -> jax.Array:
    return x.astype(dtype) if x.dtype != dtype else x


def wcast(p: Dict[str, jax.Array], name: str, cfg, roles) -> jax.Array:
    """Cast a weight to compute dtype, optionally dropping its FSDP-axis
    sharding at use (cfg.gather_weights: ZeRO-3 weight all-gather instead
    of XLA's default activation-partial psum — see §Perf)."""
    w = cast_to(p[name], cfg.cdtype)
    if cfg.gather_weights:
        from repro.parallel import sharding as PS
        w = PS.constrain(w, roles)
    return w


def he_init(key, shape, dtype, fan_in: Optional[int] = None):
    fan = fan_in if fan_in is not None else shape[0]
    return (jax.random.normal(key, shape, jnp.float32)
            * (1.0 / jnp.sqrt(jnp.maximum(fan, 1)))).astype(dtype)


# -------------------------------------------------------------------- norms

def init_norm(kind: str, d: int, dtype) -> Params:
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((d,), dtype)}          # gemma-style (1+s)
    if kind == "layernorm":
        return {"scale": jnp.zeros((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    raise ValueError(kind)


def apply_norm(kind: str, p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * (1.0 + p["scale"].astype(jnp.float32))
    elif kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = ((xf - mu) * jax.lax.rsqrt(var + eps)
             * (1.0 + p["scale"].astype(jnp.float32))
             + p["bias"].astype(jnp.float32))
    else:
        raise ValueError(kind)
    return y.astype(dtype)


# --------------------------------------------------------------------- MLPs

def init_mlp(key, d: int, ff: int, activation: str, dtype) -> Params:
    ks = jax.random.split(key, 3)
    p = {"w_out": he_init(ks[2], (ff, d), dtype, fan_in=ff)}
    if activation in ("swiglu", "geglu"):
        p["w_gate"] = he_init(ks[0], (d, ff), dtype, fan_in=d)
        p["w_in"] = he_init(ks[1], (d, ff), dtype, fan_in=d)
    else:  # plain gelu MLP (whisper)
        p["w_in"] = he_init(ks[1], (d, ff), dtype, fan_in=d)
    return p


def apply_mlp(p: Params, x: jax.Array, cfg) -> jax.Array:
    activation = cfg.activation
    x = cast_to(x, cfg.cdtype)
    w_in = wcast(p, "w_in", cfg, [None, "model"])
    w_out = wcast(p, "w_out", cfg, ["model", None])
    if activation == "swiglu":
        g = x @ wcast(p, "w_gate", cfg, [None, "model"])
        h = jax.nn.silu(g) * (x @ w_in)
    elif activation == "geglu":
        g = x @ wcast(p, "w_gate", cfg, [None, "model"])
        h = jax.nn.gelu(g, approximate=True) * (x @ w_in)
    elif activation == "gelu":
        h = jax.nn.gelu(x @ w_in, approximate=True)
    else:
        raise ValueError(activation)
    return h @ w_out


# ------------------------------------------------------------------- rotary

def rope_freqs(hd_rot: int, theta: float) -> jax.Array:
    """(hd_rot/2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, hd_rot, 2, dtype=jnp.float32) / hd_rot))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               rotary_pct: float = 1.0) -> jax.Array:
    """Rotate the first ``rotary_pct`` fraction of head_dim.

    x: (..., S, H, hd); positions: broadcastable to (..., S).
    """
    hd = x.shape[-1]
    hd_rot = int(hd * rotary_pct) // 2 * 2
    if hd_rot == 0:
        return x
    xr, xp = x[..., :hd_rot], x[..., hd_rot:]
    freqs = rope_freqs(hd_rot, theta)                       # (hd_rot/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    ang = ang[..., None, :]                                 # (..., S, 1, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(xr.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float,
                sections: Tuple[int, int, int]) -> jax.Array:
    """Qwen2-VL M-RoPE: head_dim/2 frequency slots split into (t, h, w)
    sections, each rotated by its own position stream.

    x: (B, S, H, hd); positions3: (3, B, S).
    """
    hd = x.shape[-1]
    half = hd // 2
    total = sum(sections)
    # normalize sections to cover exactly half the head dim
    scale = half / total
    widths = [int(round(s * scale)) for s in sections]
    widths[-1] = half - sum(widths[:-1])
    freqs = rope_freqs(hd, theta)                           # (half,)
    # per-slot position stream id: 0,1,2 over the freq axis
    slot_pos = []
    for comp, w in enumerate(widths):
        slot_pos += [comp] * w
    slot = jnp.asarray(slot_pos)                            # (half,)
    pos = positions3.astype(jnp.float32)[slot]              # (half, B, S)
    ang = jnp.einsum("hbs,h->bsh", pos, freqs)              # (B, S, half)
    ang = ang[..., None, :]                                 # (B, S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- embedding

def init_embedding(key, vocab: int, d: int, dtype) -> Params:
    return {"table": jax.random.normal(key, (vocab, d), jnp.float32)
            .astype(dtype) * 0.02}


def embed(p: Params, tokens: jax.Array, cdtype, scale: bool = False) -> jax.Array:
    x = cast_to(p["table"], cdtype)[tokens]
    if scale:
        x = x * jnp.asarray(jnp.sqrt(p["table"].shape[-1]), cdtype)
    return x


def unembed(p_head: Optional[Params], p_embed: Params, x: jax.Array,
            cdtype, softcap: Optional[float] = None) -> jax.Array:
    if p_head is not None:
        logits = cast_to(x, cdtype) @ cast_to(p_head["w"], cdtype)
    else:  # tied
        logits = cast_to(x, cdtype) @ cast_to(p_embed["table"], cdtype).T
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits
