"""Mixture-of-Experts FFN: top-k router + capacity-bounded dispatch.

GShard/Switch-style dispatch, XLA-SPMD friendly (no ragged ops):

    router logits (T, E) -> top-k experts/weights per token
    position-in-expert via cumulative sum over the token axis
    scatter into an (E, C, d) buffer, dropped tokens (pos >= C) fall
    through on the residual path
    batched expert FFN: einsum over the stacked (E, d, ff) weights
    weighted combine back to (T, d)

Aux losses: load-balancing loss (mean_prob * mean_assignment, Switch eq. 4)
and router z-loss, both returned for the train step to add.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig, MoEConfig

Params = Dict[str, jax.Array]


class MoEAux(NamedTuple):
    load_balance_loss: jax.Array   # scalar
    router_z_loss: jax.Array       # scalar
    dropped_fraction: jax.Array    # scalar (monitoring)


def init_moe(key, cfg: ModelConfig) -> Params:
    m = cfg.moe
    d, ff, e = cfg.d_model, cfg.d_ff, m.n_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": L.he_init(ks[0], (d, e), jnp.float32, fan_in=d),
        "w_in": L.he_init(ks[1], (e, d, ff), cfg.pdtype, fan_in=d),
        "w_out": L.he_init(ks[2], (e, ff, d), cfg.pdtype, fan_in=ff),
    }
    if cfg.activation in ("swiglu", "geglu"):
        p["w_gate"] = L.he_init(ks[3], (e, d, ff), cfg.pdtype, fan_in=d)
    return p


def capacity(cfg: MoEConfig, n_tokens: int) -> int:
    c = int(cfg.capacity_factor * cfg.top_k * n_tokens / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # pad to 8 for TPU lanes


def _dp_shards() -> int:
    """Active data-parallel shard count (1 when no mesh rules are bound)."""
    from repro.parallel import sharding as PS
    rules = PS.current_rules()
    if rules is None or not rules.batch_axes:
        return 1
    return rules.axis_size(rules.batch_axes)


def apply_moe(p: Params, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, MoEAux]:
    """x: (B, S, d) -> (B, S, d), aux losses."""
    if cfg.moe.dispatch == "local":
        dp = _dp_shards()
        if dp > 1 and (x.shape[0] * x.shape[1]) % dp == 0:
            return _apply_moe_local(p, x, cfg, dp)
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = m.n_experts, m.top_k
    xt = x.reshape(t, d)

    logits = xt.astype(jnp.float32) @ p["router"]            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_idx = jax.lax.top_k(probs, k)               # (T, k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # --- capacity assignment via cumsum over token order -----------------
    c = capacity(m, t)
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)  # (T, k, E)
    # priority: earlier (token, slot) pairs claim capacity first
    flat = onehot.reshape(t * k, e)
    pos_in_expert = (jnp.cumsum(flat, axis=0) - flat)        # (T*k, E)
    pos = jnp.sum(pos_in_expert * flat, axis=-1).reshape(t, k)  # (T, k)
    fits = pos < c
    dropped = 1.0 - jnp.mean(fits.astype(jnp.float32))

    # --- dispatch: scatter tokens into (E, C, d) --------------------------
    # destination flat index e*C + pos (clipped); invalid slots -> sink row
    dest = gate_idx * c + jnp.clip(pos, 0, c - 1).astype(jnp.int32)
    dest = jnp.where(fits, dest, e * c)                      # (T, k)
    buf = jnp.zeros((e * c + 1, d), xt.dtype)
    buf = buf.at[dest.reshape(-1)].add(
        jnp.repeat(xt, k, axis=0).reshape(t * k, d))
    expert_in = buf[:e * c].reshape(e, c, d)

    # --- batched expert FFN ----------------------------------------------
    cdt = cfg.cdtype
    w_in = L.wcast(p, "w_in", cfg, [None, None, "model"])
    w_out = L.wcast(p, "w_out", cfg, [None, "model", None])
    hin = jnp.einsum("ecd,edf->ecf", expert_in, w_in)
    if cfg.activation == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", expert_in,
                       L.wcast(p, "w_gate", cfg, [None, None, "model"]))
        h = jax.nn.silu(g) * hin
    elif cfg.activation == "geglu":
        g = jnp.einsum("ecd,edf->ecf", expert_in,
                       L.wcast(p, "w_gate", cfg, [None, None, "model"]))
        h = jax.nn.gelu(g, approximate=True) * hin
    else:
        h = jax.nn.gelu(hin, approximate=True)
    expert_out = jnp.einsum("ecf,efd->ecd", h, w_out)        # (E, C, d)

    # --- combine -----------------------------------------------------------
    flat_out = jnp.concatenate(
        [expert_out.reshape(e * c, d), jnp.zeros((1, d), expert_out.dtype)])
    gathered = flat_out[dest.reshape(-1)].reshape(t, k, d)
    yt = jnp.sum(gathered * gate_w[..., None].astype(gathered.dtype), axis=1)

    # --- aux losses (Switch Transformer eq. 4 + z-loss) --------------------
    me = probs.mean(axis=0)                                   # (E,)
    ce = onehot.sum(axis=1).mean(axis=0)                      # (E,) assignment
    lb = e * jnp.sum(me * ce)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return yt.reshape(b, s, d), MoEAux(lb, 1e-3 * z, dropped)


def _apply_moe_local(p: Params, x: jax.Array, cfg: ModelConfig,
                     dp: int) -> Tuple[jax.Array, MoEAux]:
    """Per-DP-shard capacity dispatch (§Perf): the scatter/gather and the
    position-in-expert cumsum run *within* each data shard's token slice,
    so no dispatch buffer ever crosses the DP axis; the (small, bf16)
    expert weights are all-gathered instead.  GShard-style local groups —
    drop semantics are per-group rather than global (documented)."""
    from repro.parallel import sharding as PS
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = m.n_experts, m.top_k
    tl = t // dp                                              # tokens/shard
    xt = x.reshape(dp, tl, d)
    xt = PS.constrain(xt, ["batch", None, None])

    logits = xt.astype(jnp.float32) @ p["router"]             # (D, tl, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_idx = jax.lax.top_k(probs, k)                # (D, tl, k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    c = capacity(m, tl)
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)   # (D, tl, k, E)
    flat = onehot.reshape(dp, tl * k, e)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat           # local cumsum
    pos = jnp.sum(pos_in_expert * flat, axis=-1).reshape(dp, tl, k)
    fits = pos < c
    dropped = 1.0 - jnp.mean(fits.astype(jnp.float32))

    dest = gate_idx * c + jnp.clip(pos, 0, c - 1).astype(jnp.int32)
    dest = jnp.where(fits, dest, e * c)                       # (D, tl, k)
    didx = jnp.arange(dp)[:, None]
    buf = jnp.zeros((dp, e * c + 1, d), xt.dtype)
    upd = jnp.repeat(xt, k, axis=1)                           # (D, tl*k, d)
    buf = buf.at[didx, dest.reshape(dp, tl * k)].add(upd)
    # pin the scatter RESULT to the DP axis too — without this the SPMD
    # partitioner materializes a replicated buffer + all-reduce (§Perf
    # iteration 3: removed ~17 GB/device/layer-pair of scatter psums)
    buf = PS.constrain(buf, ["batch", None, None])
    expert_in = buf[:, :e * c].reshape(dp, e, c, d)
    expert_in = PS.constrain(expert_in, ["batch", None, None, None])

    # batched expert FFN with data-gathered (bf16) weights; ff stays TP
    gather = [None, None, "model"]
    w_in = PS.constrain(L.cast_to(p["w_in"], cfg.cdtype), gather)
    w_out = PS.constrain(L.cast_to(p["w_out"], cfg.cdtype),
                         [None, "model", None])
    hin = jnp.einsum("gecd,edf->gecf", expert_in, w_in)
    if cfg.activation in ("swiglu", "geglu"):
        g = jnp.einsum("gecd,edf->gecf", expert_in,
                       PS.constrain(L.cast_to(p["w_gate"], cfg.cdtype),
                                    gather))
        act = jax.nn.silu(g) if cfg.activation == "swiglu" else \
            jax.nn.gelu(g, approximate=True)
        h = act * hin
    else:
        h = jax.nn.gelu(hin, approximate=True)
    expert_out = jnp.einsum("gecf,efd->gecd", h, w_out)       # (D,E,C,d)

    flat_out = jnp.concatenate(
        [expert_out.reshape(dp, e * c, d),
         jnp.zeros((dp, 1, d), expert_out.dtype)], axis=1)
    flat_out = PS.constrain(flat_out, ["batch", None, None])
    gathered = jnp.take_along_axis(
        flat_out, dest.reshape(dp, tl * k)[..., None], axis=1)
    gathered = PS.constrain(gathered, ["batch", None, None])
    gathered = gathered.reshape(dp, tl, k, d)
    yt = jnp.sum(gathered * gate_w[..., None].astype(gathered.dtype),
                 axis=2)

    me = probs.mean(axis=(0, 1))
    ce = onehot.sum(axis=2).mean(axis=(0, 1))
    lb = e * jnp.sum(me * ce)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return yt.reshape(b, s, d), MoEAux(lb, 1e-3 * z, dropped)
