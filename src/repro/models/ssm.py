"""State-space / recurrent blocks: Mamba (selective SSM), mLSTM, sLSTM.

TPU adaptation notes (DESIGN.md):

* **Mamba** — selective scan with diagonal state, implemented as a
  ``lax.scan`` over time carrying (B, inner, d_state).  dt/B/C projections
  are computed batched outside the scan; the per-step update is elementwise
  + small contractions, which XLA fuses into the loop body.  Decode is the
  natural single-step form of the same update.
* **mLSTM** — the matrix-memory LSTM *is* gated linear attention; we use
  the chunkwise-parallel form (intra-chunk attention matmuls + inter-chunk
  (hd x hd) state carry) so the MXU does the work.  A sequential reference
  (``mlstm_sequential``) backs the correctness tests.
* **sLSTM** — scalar memory with exponential gating and block-diagonal
  recurrence; inherently sequential -> ``lax.scan`` over time.

All gating uses the xLSTM stabilizer state m (log-space running max), so
exp() never overflows; the chunkwise and sequential mLSTM forms share the
same stabilizer convention and match to float tolerance.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig, SSMConfig

Params = Dict[str, jax.Array]


# ===========================================================================
# Mamba (selective SSM, diagonal state)
# ===========================================================================

def mamba_dims(cfg: ModelConfig) -> Tuple[int, int]:
    inner = cfg.ssm.expand * cfg.d_model
    dt_rank = cfg.ssm.dt_rank or -(-cfg.d_model // 16)
    return inner, dt_rank


def init_mamba(key, cfg: ModelConfig) -> Params:
    d, s = cfg.d_model, cfg.ssm
    inner, dtr = mamba_dims(cfg)
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A
    a = jnp.broadcast_to(jnp.arange(1, s.d_state + 1, dtype=jnp.float32),
                         (inner, s.d_state))
    return {
        "in_proj": L.he_init(ks[0], (d, 2 * inner), cfg.pdtype, fan_in=d),
        "conv_w": L.he_init(ks[1], (s.d_conv, inner), cfg.pdtype,
                            fan_in=s.d_conv),
        "x_proj": L.he_init(ks[2], (inner, dtr + 2 * s.d_state), cfg.pdtype,
                            fan_in=inner),
        "dt_proj": L.he_init(ks[3], (dtr, inner), cfg.pdtype, fan_in=dtr),
        "dt_bias": jnp.log(jnp.expm1(  # softplus^-1 of U(1e-3, 1e-1)
            jax.random.uniform(ks[4], (inner,), minval=1e-3, maxval=1e-1)
        )).astype(jnp.float32),
        "A_log": jnp.log(a),
        "D": jnp.ones((inner,), jnp.float32),
        "out_proj": L.he_init(ks[5], (inner, d), cfg.pdtype, fan_in=inner),
    }


class MambaState(NamedTuple):
    conv: jax.Array  # (B, d_conv-1, inner) last inputs for the causal conv
    ssm: jax.Array   # (B, inner, d_state) fp32


def init_mamba_state(cfg: ModelConfig, batch: int) -> MambaState:
    inner, _ = mamba_dims(cfg)
    return MambaState(
        conv=jnp.zeros((batch, cfg.ssm.d_conv - 1, inner), cfg.cdtype),
        ssm=jnp.zeros((batch, inner, cfg.ssm.d_state), jnp.float32))


def _mamba_inner(p: Params, xz: jax.Array, cfg: ModelConfig,
                 state: Optional[MambaState]) -> Tuple[jax.Array, MambaState]:
    """Core selective scan. xz: (B, S, 2*inner) already projected."""
    s = cfg.ssm
    inner, dtr = mamba_dims(cfg)
    b, t, _ = xz.shape
    x, z = jnp.split(xz, 2, axis=-1)

    # causal depthwise conv (window d_conv) with carried context
    conv_ctx = (state.conv if state is not None
                else jnp.zeros((b, s.d_conv - 1, inner), x.dtype))
    xc = jnp.concatenate([conv_ctx, x], axis=1)              # (B, T+dc-1, in)
    w = L.cast_to(p["conv_w"], x.dtype)                      # (dc, inner)
    xconv = sum(xc[:, i:i + t, :] * w[i] for i in range(s.d_conv))
    new_conv = xc[:, t:, :] if t >= s.d_conv - 1 else xc[:, -(s.d_conv - 1):, :]
    xs = jax.nn.silu(xconv)

    # input-dependent dt, B, C
    proj = xs @ L.wcast(p, "x_proj", cfg, ["model", None])   # (B,T,dtr+2N)
    dt, bmat, cmat = jnp.split(proj, [dtr, dtr + s.d_state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) @ p["dt_proj"].astype(jnp.float32)
                         + p["dt_bias"])                     # (B,T,inner)
    a = -jnp.exp(p["A_log"])                                 # (inner, N)
    bmat = bmat.astype(jnp.float32)
    cmat = cmat.astype(jnp.float32)
    xs32 = xs.astype(jnp.float32)

    def step(h, xs_t):
        dt_t, b_t, c_t, x_t = xs_t                           # (B,in),(B,N),(B,N),(B,in)
        da = jnp.exp(dt_t[..., None] * a)                    # (B,in,N)
        dbx = (dt_t * x_t)[..., None] * b_t[:, None, :]      # (B,in,N)
        h = da * h + dbx
        y = jnp.einsum("bin,bn->bi", h, c_t)                 # (B,in)
        return h, y

    h0 = (state.ssm if state is not None
          else jnp.zeros((b, inner, s.d_state), jnp.float32))
    xs_seq = (jnp.moveaxis(dt, 1, 0), jnp.moveaxis(bmat, 1, 0),
              jnp.moveaxis(cmat, 1, 0), jnp.moveaxis(xs32, 1, 0))
    h_last, ys = jax.lax.scan(step, h0, xs_seq)
    y = jnp.moveaxis(ys, 0, 1) + xs32 * p["D"]               # (B,T,inner)
    y = y.astype(xz.dtype) * jax.nn.silu(z)
    return y, MambaState(conv=new_conv, ssm=h_last)


def apply_mamba(p: Params, x: jax.Array, cfg: ModelConfig,
                state: Optional[MambaState] = None
                ) -> Tuple[jax.Array, MambaState]:
    """x: (B, S, d) -> (B, S, d). ``state`` enables decode continuation."""
    xz = L.cast_to(x, cfg.cdtype) @ L.wcast(p, "in_proj", cfg,
                                            [None, "model"])
    y, new_state = _mamba_inner(p, xz, cfg, state)
    return y @ L.wcast(p, "out_proj", cfg, ["model", None]), new_state


# ===========================================================================
# mLSTM (matrix memory; chunkwise-parallel = gated linear attention)
# ===========================================================================

def mlstm_dims(cfg: ModelConfig) -> Tuple[int, int]:
    inner = 2 * cfg.d_model
    hd = inner // cfg.n_heads
    return inner, hd


def init_mlstm(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    inner, hd = mlstm_dims(cfg)
    h = cfg.n_heads
    ks = jax.random.split(key, 6)
    return {
        "up_proj": L.he_init(ks[0], (d, 2 * inner), cfg.pdtype, fan_in=d),
        # q,k,v as block-diagonal per head: (H, hd, hd)
        "wq": L.he_init(ks[1], (h, hd, hd), cfg.pdtype, fan_in=hd),
        "wk": L.he_init(ks[2], (h, hd, hd), cfg.pdtype, fan_in=hd),
        "wv": L.he_init(ks[3], (h, hd, hd), cfg.pdtype, fan_in=hd),
        # per-dim gate projections from the block input
        "w_i": L.he_init(ks[4], (inner, h), jnp.float32, fan_in=inner),
        "w_f": L.he_init(ks[5], (inner, h), jnp.float32, fan_in=inner),
        "b_i": jnp.zeros((h,), jnp.float32),
        "b_f": jnp.full((h,), 3.0, jnp.float32),   # forget-gate bias > 0
        "ln_scale": jnp.zeros((inner,), jnp.float32),
        "down_proj": L.he_init(jax.random.fold_in(key, 7), (inner, d),
                               cfg.pdtype, fan_in=inner),
    }


class MLSTMState(NamedTuple):
    c: jax.Array  # (B, H, hd, hd) fp32 matrix memory
    n: jax.Array  # (B, H, hd) normalizer
    m: jax.Array  # (B, H) log-space stabilizer


def init_mlstm_state(cfg: ModelConfig, batch: int) -> MLSTMState:
    _, hd = mlstm_dims(cfg)
    h = cfg.n_heads
    return MLSTMState(c=jnp.zeros((batch, h, hd, hd), jnp.float32),
                      n=jnp.zeros((batch, h, hd), jnp.float32),
                      m=jnp.full((batch, h), -1e30, jnp.float32))


def _mlstm_gates(p: Params, xin: jax.Array):
    """log input/forget gate pre-activations. xin: (B,T,inner) ->
    li, lf: (B,T,H) fp32."""
    xf = xin.astype(jnp.float32)
    li = xf @ p["w_i"] + p["b_i"]
    lf = jax.nn.log_sigmoid(xf @ p["w_f"] + p["b_f"])
    return li, lf


def mlstm_sequential(q, k, v, li, lf, state: MLSTMState
                     ) -> Tuple[jax.Array, MLSTMState]:
    """Reference recurrence. q,k,v: (B,T,H,hd); li,lf: (B,T,H)."""
    hd = q.shape[-1]
    scale = 1.0 / jnp.sqrt(hd)

    def step(st: MLSTMState, xs):
        qt, kt, vt, lit, lft = xs                # (B,H,hd)x3, (B,H)x2
        m_new = jnp.maximum(lft + st.m, lit)
        fp = jnp.exp(lft + st.m - m_new)
        ip = jnp.exp(lit - m_new)
        kts = kt * scale
        c = fp[..., None, None] * st.c + ip[..., None, None] * \
            jnp.einsum("bhk,bhv->bhkv", kts, vt)
        n = fp[..., None] * st.n + ip[..., None] * kts
        num = jnp.einsum("bhk,bhkv->bhv", qt, c)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qt, n)),
                          jnp.exp(-m_new))
        y = num / den[..., None]
        return MLSTMState(c, n, m_new), y

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0)
               for a in (q, k, v, li, lf))
    state, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1), state


def mlstm_chunkwise(q, k, v, li, lf, state: MLSTMState, chunk: int
                    ) -> Tuple[jax.Array, MLSTMState]:
    """Chunkwise-parallel mLSTM, exact w.r.t. the sequential form.

    Shapes as in :func:`mlstm_sequential`; T must be a multiple of chunk.
    """
    b, t, h, hd = q.shape
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk
    scale = 1.0 / jnp.sqrt(hd)

    def resh(a):
        return jnp.moveaxis(
            a.astype(jnp.float32).reshape(b, nc, chunk, *a.shape[2:]), 1, 0)

    qc, kc, vc, lic, lfc = map(resh, (q, k, v, li, lf))      # (nc,B,L,H,...)

    def chunk_step(st: MLSTMState, xs):
        qt, kt, vt, lit, lft = xs                            # (B,L,H,...)
        kt = kt * scale
        bcum = jnp.cumsum(lft, axis=1)                       # (B,L,H) sum lf
        btot = bcum[:, -1]                                   # (B,H)
        # row stabilizers
        g = bcum + st.m[:, None, :]                          # (B,L,H) inter
        a_mat = (bcum[:, :, None, :] - bcum[:, None, :, :]
                 + lit[:, None, :, :])                       # (B,Lq,Ls,H)
        lq = jnp.arange(chunk)
        causal = lq[:, None] >= lq[None, :]
        a_mat = jnp.where(causal[None, :, :, None], a_mat, -jnp.inf)
        a_max = jnp.max(a_mat, axis=2)                       # (B,L,H)
        m_t = jnp.maximum(g, a_max)                          # (B,L,H)

        inter_w = jnp.exp(g - m_t)                           # (B,L,H)
        intra_w = jnp.exp(a_mat - m_t[:, :, None, :])        # (B,Lq,Ls,H)
        s_qk = jnp.einsum("blhk,bshk->blsh", qt, kt)         # (B,Lq,Ls,H)
        w = intra_w * s_qk
        num = (jnp.einsum("blsh,bshv->blhv", w, vt)
               + inter_w[..., None] * jnp.einsum("blhk,bhkv->blhv", qt, st.c))
        den_intra = jnp.sum(w, axis=2)                       # (B,L,H)
        den_inter = inter_w * jnp.einsum("blhk,bhk->blh", qt, st.n)
        den = jnp.maximum(jnp.abs(den_intra + den_inter), jnp.exp(-m_t))
        y = num / den[..., None]                             # (B,L,H,hd)

        # chunk-final state
        m_out = jnp.maximum(btot + st.m,
                            jnp.max(btot[:, None] - bcum + lit, axis=1))
        carry_w = jnp.exp(btot + st.m - m_out)               # (B,H)
        in_w = jnp.exp(btot[:, None] - bcum + lit - m_out[:, None])  # (B,L,H)
        c_new = (carry_w[..., None, None] * st.c
                 + jnp.einsum("blh,blhk,blhv->bhkv", in_w, kt, vt))
        n_new = (carry_w[..., None] * st.n
                 + jnp.einsum("blh,blhk->bhk", in_w, kt))
        return MLSTMState(c_new, n_new, m_out), y

    state, ys = jax.lax.scan(chunk_step, state, (qc, kc, vc, lic, lfc))
    return jnp.moveaxis(ys, 0, 1).reshape(b, t, h, hd), state


def apply_mlstm(p: Params, x: jax.Array, cfg: ModelConfig,
                state: Optional[MLSTMState] = None, chunk: Optional[int] = None
                ) -> Tuple[jax.Array, MLSTMState]:
    """Full mLSTM block body (pre-norm residual handled by caller).

    x: (B, S, d) -> (B, S, d).
    """
    b, t, d = x.shape
    inner, hd = mlstm_dims(cfg)
    h = cfg.n_heads
    cdt = cfg.cdtype
    up = L.cast_to(x, cdt) @ L.wcast(p, "up_proj", cfg, [None, "model"])
    xin, z = jnp.split(up, 2, axis=-1)                       # (B,T,inner)x2
    xh = xin.reshape(b, t, h, hd)
    qkv_roles = [None, None, "model"]
    q = jnp.einsum("bthi,hij->bthj", xh, L.wcast(p, "wq", cfg, qkv_roles))
    k = jnp.einsum("bthi,hij->bthj", xh, L.wcast(p, "wk", cfg, qkv_roles))
    v = jnp.einsum("bthi,hij->bthj", xh, L.wcast(p, "wv", cfg, qkv_roles))
    li, lf = _mlstm_gates(p, xin)
    if state is None:
        state = init_mlstm_state(cfg, b)
    ck = chunk or cfg.ssm.chunk
    if t == 1 or t % ck != 0:
        y, state = mlstm_sequential(q, k, v, li, lf, state)
    else:
        y, state = mlstm_chunkwise(q, k, v, li, lf, state, ck)
    y = y.reshape(b, t, inner)
    # per-dim RMS "group norm" then gate
    yn = L.apply_norm("rmsnorm", {"scale": p["ln_scale"]}, y.astype(cdt))
    out = (yn * jax.nn.silu(z)) @ L.wcast(p, "down_proj", cfg,
                                          ["model", None])
    return out, state


# ===========================================================================
# sLSTM (scalar memory, exponential gating, block-diagonal recurrence)
# ===========================================================================

def init_slstm(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 4)
    ff = -(-4 * d // 3)
    return {
        "w": L.he_init(ks[0], (d, 4 * d), cfg.pdtype, fan_in=d),   # i,f,z,o
        "b": jnp.concatenate([jnp.zeros((d,)), jnp.full((d,), 3.0),
                              jnp.zeros((2 * d,))]).astype(jnp.float32),
        "r": L.he_init(ks[1], (h, dh, 4 * dh), cfg.pdtype, fan_in=dh),
        "ff_in": L.he_init(ks[2], (d, ff), cfg.pdtype, fan_in=d),
        "ff_out": L.he_init(ks[3], (ff, d), cfg.pdtype, fan_in=ff),
    }


class SLSTMState(NamedTuple):
    c: jax.Array  # (B, d)
    n: jax.Array  # (B, d)
    h: jax.Array  # (B, d)
    m: jax.Array  # (B, d)


def init_slstm_state(cfg: ModelConfig, batch: int) -> SLSTMState:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SLSTMState(c=z, n=z, h=z, m=jnp.full((batch, d), -1e30, jnp.float32))


def apply_slstm_cell(p: Params, x: jax.Array, cfg: ModelConfig,
                     state: Optional[SLSTMState] = None
                     ) -> Tuple[jax.Array, SLSTMState]:
    """Sequential sLSTM over x: (B, T, d) (cell only, no FFN)."""
    b, t, d = x.shape
    h_heads = cfg.n_heads
    dh = d // h_heads
    wx = (x.astype(jnp.float32) @ p["w"].astype(jnp.float32)
          + p["b"])                                          # (B,T,4d)
    if state is None:
        state = init_slstm_state(cfg, b)
    r = p["r"].astype(jnp.float32)

    def step(st: SLSTMState, wx_t):
        hh = st.h.reshape(b, h_heads, dh)
        rec = jnp.einsum("bhi,hio->bho", hh, r).reshape(b, 4 * d)
        pre = wx_t + rec
        li_, lf_, z_, o_ = jnp.split(pre, 4, axis=-1)
        lf = jax.nn.log_sigmoid(lf_)
        z = jnp.tanh(z_)
        o = jax.nn.sigmoid(o_)
        m_new = jnp.maximum(lf + st.m, li_)
        fp = jnp.exp(lf + st.m - m_new)
        ip = jnp.exp(li_ - m_new)
        c = fp * st.c + ip * z
        n = jnp.maximum(fp * st.n + ip, 1e-6)
        h = o * c / n
        return SLSTMState(c, n, h, m_new), h

    state, ys = jax.lax.scan(step, state, jnp.moveaxis(wx, 1, 0))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), state


def apply_slstm(p: Params, x: jax.Array, cfg: ModelConfig,
                state: Optional[SLSTMState] = None
                ) -> Tuple[jax.Array, SLSTMState]:
    """Cell + post-FFN (projection factor 4/3), as one residual body."""
    y, state = apply_slstm_cell(p, x, cfg, state)
    cdt = cfg.cdtype
    hmid = jax.nn.gelu(L.cast_to(y, cdt) @ L.wcast(p, "ff_in", cfg, [None, "model"]),
                       approximate=True)
    return hmid @ L.wcast(p, "ff_out", cfg, ["model", None]), state
