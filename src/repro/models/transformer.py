"""Decoder-LM assembly: scanned layer groups over heterogeneous blocks.

Layers are stacked into groups of ``cfg.group_pattern`` (e.g. jamba's
``(mamba x4, attn, mamba x3)``) whose parameters carry a leading
``(n_groups, ...)`` axis; the stack is applied with ``lax.scan`` so an
80-layer model lowers to one group body (small HLO, fast compiles).
Per-layer variation *across* groups (llama4's every-4th-layer global
attention) rides in as scanned boolean flags.

Three entry points, all pure functions over a params pytree:

* ``forward_train(params, batch, cfg)``       -> logits (B, S, Vp)
* ``forward_prefill(params, batch, cfg)``     -> logits, decode caches
* ``decode_step(params, caches, tokens, pos, cfg)`` -> logits, caches

MoE aux losses accumulate through the scan carry and come back in a
metrics dict.  ``repro.parallel.sharding.activations`` pins (B, S, d)
activations to the DP axes at group boundaries.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as S
from repro.models.config import ModelConfig
from repro.parallel import sharding as PS

Params = Dict[str, Any]


# ===========================================================================
# init
# ===========================================================================

def _init_block(key, cfg: ModelConfig, kind: str, layer_is_moe: bool) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {}
    if kind == "attn":
        p["attn_norm"] = L.init_norm(cfg.norm, cfg.d_model, jnp.float32)
        p["attn"] = A.init_attention(ks[0], cfg)
    elif kind == "mamba":
        p["attn_norm"] = L.init_norm(cfg.norm, cfg.d_model, jnp.float32)
        p["mamba"] = S.init_mamba(ks[0], cfg)
    elif kind == "mlstm":
        p["norm"] = L.init_norm(cfg.norm, cfg.d_model, jnp.float32)
        p["cell"] = S.init_mlstm(ks[0], cfg)
        return p
    elif kind == "slstm":
        p["norm"] = L.init_norm(cfg.norm, cfg.d_model, jnp.float32)
        p["ff_norm"] = L.init_norm(cfg.norm, cfg.d_model, jnp.float32)
        p["cell"] = S.init_slstm(ks[0], cfg)
        return p
    else:
        raise ValueError(kind)
    p["mlp_norm"] = L.init_norm(cfg.norm, cfg.d_model, jnp.float32)
    if layer_is_moe:
        p["moe"] = MOE.init_moe(ks[1], cfg)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.activation,
                              cfg.pdtype)
    return p


def group_flags(cfg: ModelConfig) -> jax.Array:
    """(n_groups, G) bool — per-layer 'global attention' flag (llama4)."""
    flags = np.zeros((cfg.n_groups, cfg.group_size), bool)
    for li in range(cfg.n_layers):
        flags[li // cfg.group_size, li % cfg.group_size] = \
            cfg.layer_is_global_attn(li)
    return jnp.asarray(flags)


def init_lm(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, 4 + cfg.group_size)
    params: Params = {
        "embed": L.init_embedding(keys[0], cfg.padded_vocab, cfg.d_model,
                                  cfg.pdtype),
        "final_norm": L.init_norm(cfg.norm, cfg.d_model, jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["head"] = {"w": L.he_init(keys[1], (cfg.d_model,
                                                   cfg.padded_vocab),
                                         cfg.pdtype, fan_in=cfg.d_model)}
    groups: Params = {}
    for pos, kind in enumerate(cfg.group_pattern):
        is_moe = cfg.layer_is_moe(pos)  # every_n divides G (validated below)
        if cfg.moe is not None and cfg.group_size % cfg.moe.every_n_layers:
            raise ValueError("moe.every_n_layers must divide group size")

        def init_one(k, kind=kind, is_moe=is_moe):
            return _init_block(k, cfg, kind, is_moe)

        gkeys = jax.random.split(keys[4 + pos], cfg.n_groups)
        groups[f"pos_{pos}"] = jax.vmap(init_one)(gkeys)
    params["groups"] = groups
    return params


# ===========================================================================
# forward (train / prefill)
# ===========================================================================

class ScanAux(NamedTuple):
    lb_loss: jax.Array
    z_loss: jax.Array
    dropped: jax.Array


ZERO_AUX = ScanAux(jnp.zeros(()), jnp.zeros(()), jnp.zeros(()))


def _apply_mlp_or_moe(p: Params, x: jax.Array, cfg: ModelConfig
                      ) -> Tuple[jax.Array, ScanAux]:
    h = L.apply_norm(cfg.norm, p["mlp_norm"], x)
    if "moe" in p:
        y, aux = MOE.apply_moe(p["moe"], h, cfg)
        return x + y, ScanAux(aux.load_balance_loss, aux.router_z_loss,
                              aux.dropped_fraction)
    return x + L.apply_mlp(p["mlp"], h, cfg), ZERO_AUX


def _block_train(p: Params, x: jax.Array, kind: str, cfg: ModelConfig,
                 positions, is_global) -> Tuple[jax.Array, ScanAux]:
    if kind == "attn":
        h = L.apply_norm(cfg.norm, p["attn_norm"], x)
        use_rope = (not is_global) if isinstance(is_global, bool) \
            else jnp.logical_not(is_global)  # llama4: NoPE on global layers
        x = x + A.self_attend(p["attn"], h, positions, cfg,
                              is_global=is_global, use_rope=use_rope)
        return _apply_mlp_or_moe(p, x, cfg)
    if kind == "mamba":
        h = L.apply_norm(cfg.norm, p["attn_norm"], x)
        y, _ = S.apply_mamba(p["mamba"], h, cfg)
        return _apply_mlp_or_moe(p, x + y, cfg)
    if kind == "mlstm":
        h = L.apply_norm(cfg.norm, p["norm"], x)
        y, _ = S.apply_mlstm(p["cell"], h, cfg)
        return x + y, ZERO_AUX
    if kind == "slstm":
        h = L.apply_norm(cfg.norm, p["norm"], x)
        y, _ = S.apply_slstm_cell(p["cell"], h, cfg)
        x = x + y
        h2 = L.apply_norm(cfg.norm, p["ff_norm"], x)
        cdt = cfg.cdtype
        ff = jax.nn.gelu(L.cast_to(h2, cdt)
                         @ L.wcast(p["cell"], "ff_in", cfg, [None, "model"]),
                         approximate=True)
        return x + ff @ L.wcast(p["cell"], "ff_out", cfg, ["model", None]), ZERO_AUX
    raise ValueError(kind)


def _group_body_train(gparams: Params, x: jax.Array, flags: jax.Array,
                      cfg: ModelConfig, positions) -> Tuple[jax.Array, ScanAux]:
    aux = ZERO_AUX
    for pos, kind in enumerate(cfg.group_pattern):
        x, a = _block_train(gparams[f"pos_{pos}"], x, kind, cfg, positions,
                            flags[pos] if cfg.global_every else False)
        aux = ScanAux(*(s + t for s, t in zip(aux, a)))
        x = PS.activations(x)
    return x, aux


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)  # "block": save only group boundaries


def backbone(params: Params, x: jax.Array, cfg: ModelConfig,
             positions) -> Tuple[jax.Array, ScanAux]:
    """Run all layer groups over embedded activations x: (B, S, d)."""
    flags = group_flags(cfg)

    def body(carry, xs):
        x, aux = carry
        gp, fl = xs
        x, a = _group_body_train(gp, x, fl, cfg, positions)
        return (x, ScanAux(*(s + t for s, t in zip(aux, a)))), None

    body = _maybe_remat(body, cfg)
    (x, aux), _ = jax.lax.scan(body, (x, ZERO_AUX),
                               (params["groups"], flags),
                               unroll=cfg.n_groups if cfg.unroll_scans else 1)
    return x, aux


def forward_train(params: Params, batch: Dict[str, jax.Array],
                  cfg: ModelConfig) -> Tuple[jax.Array, ScanAux]:
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = L.embed(params["embed"], tokens, cfg.cdtype, scale=cfg.embed_scale)
    if "patch_embeds" in batch:
        # VLM stub frontend: precomputed patch embeddings occupy the first
        # P token slots (early fusion); the vision tower itself is out of
        # scope per the assignment.
        patches = L.cast_to(batch["patch_embeds"], cfg.cdtype)
        x = jax.lax.dynamic_update_slice(x, patches, (0, 0, 0))
    x = PS.activations(x)
    if cfg.mrope:
        positions = batch.get("positions")
        if positions is None:
            pos = jnp.broadcast_to(jnp.arange(s), (b, s))
            positions = jnp.broadcast_to(pos, (3, b, s))
    else:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x, aux = backbone(params, x, cfg, positions)
    x = L.apply_norm(cfg.norm, params["final_norm"], x)
    logits = L.unembed(params.get("head"), params["embed"], x, cfg.cdtype,
                       softcap=cfg.logit_softcap)
    logits = PS.constrain(logits, ["batch", None, "model"])
    return logits, aux


# ===========================================================================
# loss
# ===========================================================================

def lm_loss(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux = forward_train(params, batch, cfg)
    targets = batch["targets"]
    logits32 = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits32, axis=-1)
    # gold logit via iota-mask reduction, NOT take_along_axis: a gather over
    # the TP-sharded vocab axis makes XLA all-gather the full (B,S,V) f32
    # logits per device (tens of GB at 4k x 256); the masked reduce stays
    # sharded and fuses.
    viota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, logits.shape[-1]), 2)
    gold = jnp.sum(jnp.where(viota == targets[..., None], logits32, 0.0),
                   axis=-1)
    mask = batch.get("loss_mask", jnp.ones_like(targets, jnp.float32))
    nll = jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    n_moe_layers = sum(1 for i in range(cfg.n_layers) if cfg.layer_is_moe(i))
    scale = 1.0 / max(n_moe_layers, 1)
    aux_w = cfg.moe.router_aux_weight if cfg.moe else 0.0
    total = nll + aux_w * scale * aux.lb_loss + scale * aux.z_loss
    return total, {"nll": nll, "lb_loss": aux.lb_loss * scale,
                   "z_loss": aux.z_loss * scale,
                   "moe_dropped": aux.dropped * scale}


# ===========================================================================
# decode (serve path)
# ===========================================================================

def init_caches(cfg: ModelConfig, batch: int, seq_len: int) -> Params:
    """Decode caches stacked over groups, one entry per group position."""
    caches: Params = {}
    for pos, kind in enumerate(cfg.group_pattern):
        if kind == "attn":
            # a position's layers may mix local/global across groups
            # (llama4) -> size for the largest receptive field among them
            has_global = any(
                cfg.layer_is_global_attn(g * cfg.group_size + pos)
                for g in range(cfg.n_groups))
            size = A.cache_size_for(cfg, seq_len, has_global)
            one = lambda _=None: A.init_kv_cache(cfg, batch, size)
        elif kind == "mamba":
            one = lambda _=None: S.init_mamba_state(cfg, batch)._asdict()
        elif kind == "mlstm":
            one = lambda _=None: S.init_mlstm_state(cfg, batch)._asdict()
        elif kind == "slstm":
            one = lambda _=None: S.init_slstm_state(cfg, batch)._asdict()
        else:
            raise ValueError(kind)
        caches[f"pos_{pos}"] = jax.vmap(one)(jnp.arange(cfg.n_groups))
    return caches


def _block_decode(p: Params, cache: Params, x: jax.Array, kind: str,
                  cfg: ModelConfig, pos_scalar, is_global
                  ) -> Tuple[jax.Array, Params, ScanAux]:
    if kind == "attn":
        h = L.apply_norm(cfg.norm, p["attn_norm"], x)
        use_rope = (not is_global) if isinstance(is_global, bool) \
            else jnp.logical_not(is_global)
        y, cache = A.decode_attend(p["attn"], h, cache, pos_scalar, cfg,
                                   is_global=is_global, use_rope=use_rope)
        x, aux = _apply_mlp_or_moe(p, x + y, cfg)
        return x, cache, aux
    if kind == "mamba":
        h = L.apply_norm(cfg.norm, p["attn_norm"], x)
        y, st = S.apply_mamba(p["mamba"], h, cfg,
                              state=S.MambaState(**cache))
        x, aux = _apply_mlp_or_moe(p, x + y, cfg)
        return x, st._asdict(), aux
    if kind == "mlstm":
        h = L.apply_norm(cfg.norm, p["norm"], x)
        y, st = S.apply_mlstm(p["cell"], h, cfg, state=S.MLSTMState(**cache))
        return x + y, st._asdict(), ZERO_AUX
    if kind == "slstm":
        h = L.apply_norm(cfg.norm, p["norm"], x)
        y, st = S.apply_slstm_cell(p["cell"], h, cfg,
                                   state=S.SLSTMState(**cache))
        x = x + y
        h2 = L.apply_norm(cfg.norm, p["ff_norm"], x)
        cdt = cfg.cdtype
        ff = jax.nn.gelu(L.cast_to(h2, cdt)
                         @ L.wcast(p["cell"], "ff_in", cfg, [None, "model"]),
                         approximate=True)
        return x + ff @ L.wcast(p["cell"], "ff_out", cfg, ["model", None]), \
            st._asdict(), ZERO_AUX
    raise ValueError(kind)


def decode_step(params: Params, caches: Params, tokens: jax.Array,
                pos, cfg: ModelConfig) -> Tuple[jax.Array, Params]:
    """One decode step. tokens: (B, 1); pos: scalar absolute position."""
    pos = jnp.asarray(pos, jnp.int32)
    x = L.embed(params["embed"], tokens, cfg.cdtype, scale=cfg.embed_scale)
    x = PS.constrain(x, ["batch", None, None])
    flags = group_flags(cfg)

    def body(x, xs):
        gp, gcache, fl = xs
        new_cache = {}
        for p_i, kind in enumerate(cfg.group_pattern):
            key = f"pos_{p_i}"
            x, c, _ = _block_decode(gp[key], gcache[key], x, kind, cfg, pos,
                                    fl[p_i] if cfg.global_every else False)
            new_cache[key] = c
        return x, new_cache

    x, new_caches = jax.lax.scan(body, x, (params["groups"], caches, flags),
                                 unroll=cfg.n_groups if cfg.unroll_scans else 1)
    x = L.apply_norm(cfg.norm, params["final_norm"], x)
    logits = L.unembed(params.get("head"), params["embed"], x, cfg.cdtype,
                       softcap=cfg.logit_softcap)
    return logits, new_caches


def forward_prefill(params: Params, batch: Dict[str, jax.Array],
                    cfg: ModelConfig, cache_len: Optional[int] = None
                    ) -> Tuple[jax.Array, Params]:
    """Prefill: run the train forward while filling decode caches.

    Used by the serving example / tests (small shapes); the dry-run's
    ``prefill_32k`` cell lowers ``forward_train`` (logits only), and its
    ``decode_*`` cells take pre-existing caches as inputs.
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    cache_len = cache_len or s
    caches = init_caches(cfg, b, cache_len)
    logits, _ = forward_train(params, batch, cfg)

    # fill caches by replaying tokens one at a time (exact, small-scale)
    def step(caches, t):
        _, caches = decode_step(params, caches, jax.lax.dynamic_slice(
            tokens, (0, t), (b, 1)), t, cfg)
        return caches, None

    caches, _ = jax.lax.scan(step, caches, jnp.arange(s))
    return logits, caches
