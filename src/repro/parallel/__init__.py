"""repro.parallel — logical-axis sharding rules for pjit distribution."""

from repro.parallel.sharding import (  # noqa: F401
    MeshRules, activations, constrain, current_rules, make_rules,
    named_shardings, param_specs, use_mesh_rules,
)
