"""Logical-axis sharding rules: params + activations -> PartitionSpecs.

Mesh contract (launch/mesh.py): single-pod ``("data", "model")`` = (16, 16),
multi-pod ``("pod", "data", "model")`` = (2, 16, 16).

Placement strategy (DESIGN.md §4):

* batch dims of activations       -> ("pod", "data")   (DP)
* weight d_model dims             -> "data"            (FSDP / ZeRO-3)
* weight d_ff / heads / vocab dims-> "model"           (TP)
* optimizer state                 -> same spec as its parameter (ZeRO-1)
* any dim not divisible by its mesh axis -> replicated on that axis

Parameter specs are derived from (path, shape) name rules with divisibility
guards, so every architecture (6-head whisper, 4-head xlstm, 40-head
llama4) lowers without manual per-arch tables.  Activation constraints are
applied through a context (:func:`use_mesh_rules`) so model code stays
mesh-agnostic and tests/smoke runs (1 CPU device) skip constraints.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import simple_keystr


@dataclasses.dataclass(frozen=True)
class MeshRules:
    mesh: Mesh
    batch_axes: Tuple[str, ...]         # ("pod","data") or ("data",)
    fsdp_axis: Optional[str] = "data"   # weight d_model dim
    tp_axis: Optional[str] = "model"    # weight ff/head/vocab dim
    seq_axis: Optional[str] = None      # sequence sharding (long-context)

    def axis_size(self, name: Optional[str]) -> int:
        if name is None:
            return 1
        if isinstance(name, tuple):
            return int(np.prod([self.mesh.shape[a] for a in name]))
        return self.mesh.shape[name]


_tls = threading.local()


def current_rules() -> Optional[MeshRules]:
    return getattr(_tls, "rules", None)


@contextlib.contextmanager
def use_mesh_rules(rules: Optional[MeshRules]):
    prev = getattr(_tls, "rules", None)
    _tls.rules = rules
    try:
        yield rules
    finally:
        _tls.rules = prev


def make_rules(mesh: Mesh, seq_axis: Optional[str] = None,
               fsdp_over_pod: bool = False) -> MeshRules:
    axes = mesh.axis_names
    batch = tuple(a for a in ("pod", "data") if a in axes)
    fsdp = "data" if "data" in axes else None
    if fsdp_over_pod and "pod" in axes:
        fsdp = ("pod", "data")  # ZeRO-3 across pods (a §Perf lever)
    return MeshRules(mesh=mesh, batch_axes=batch, fsdp_axis=fsdp,
                     tp_axis="model" if "model" in axes else None,
                     seq_axis=seq_axis)


# ------------------------------------------------------------- activations

def constrain(x: jax.Array, spec_dims: Sequence[Optional[str]]) -> jax.Array:
    """Apply a sharding constraint described with logical dim roles.

    Roles: "batch", "model", "seq", "fsdp", None (replicated).  No-op when
    no rules context is active (CPU smoke tests).
    """
    rules = current_rules()
    if rules is None:
        return x
    parts = []
    for role in spec_dims:
        if role is None:
            parts.append(None)
        elif role == "batch":
            parts.append(rules.batch_axes if rules.batch_axes else None)
        elif role == "model":
            parts.append(rules.tp_axis)
        elif role == "fsdp":
            parts.append(rules.fsdp_axis)
        elif role == "seq":
            parts.append(rules.seq_axis)
        else:  # pragma: no cover
            raise ValueError(role)
    # divisibility guard
    parts = [p if p is not None and x.shape[i] % rules.axis_size(p) == 0
             else None
             for i, p in enumerate(parts)]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, P(*parts)))


def activations(x: jax.Array) -> jax.Array:
    """Standard (B, S, d) activation constraint: batch on DP axes."""
    if x.ndim == 3:
        return constrain(x, ["batch", None, None])
    return x


# ------------------------------------------------------------------ params

def _divisible(dim: int, rules: MeshRules, axis) -> bool:
    return axis is not None and dim % rules.axis_size(axis) == 0


def _spec_for(path: str, shape: Tuple[int, ...], rules: MeshRules) -> P:
    """Name-rule param spec with divisibility fallbacks."""
    fsdp, tp = rules.fsdp_axis, rules.tp_axis
    name = path.rsplit("/", 1)[-1]

    def d2(a_axis, b_axis, off=0):
        """Spec for the trailing 2 dims, leading dims replicated."""
        a = a_axis if _divisible(shape[off + 0], rules, a_axis) else None
        b = b_axis if _divisible(shape[off + 1], rules, b_axis) else None
        lead = [None] * off
        return P(*lead, a, b)

    if name in ("table",):                       # embedding (V, d)
        return d2(tp, fsdp)
    if name == "w" and len(shape) == 2 and "head" in path:  # lm head (d, V)
        return d2(fsdp, tp)
    if name in ("wq", "wk", "wv", "w_gate", "w_in", "in_proj", "x_proj",
                "up_proj", "ff_in", "dt_proj", "w") and len(shape) == 2:
        return d2(fsdp, tp)
    if name in ("wo", "w_out", "out_proj", "down_proj", "ff_out") \
            and len(shape) == 2:
        return d2(tp, fsdp)
    if len(shape) == 3 and name in ("w_in", "w_gate"):   # MoE (E, d, ff)
        return d2(fsdp, tp, off=1)
    if len(shape) == 3 and name == "w_out":              # MoE (E, ff, d)
        return d2(tp, fsdp, off=1)
    if len(shape) == 3 and name in ("wq", "wk", "wv", "r"):  # per-head blocks
        return d2(fsdp, tp, off=1)
    if name == "router":
        return d2(fsdp, None)
    if name in ("A_log", "conv_w"):
        a = tp if _divisible(shape[-1], rules, tp) else None
        return P(*([None] * (len(shape) - 1)), a)
    if len(shape) == 1:
        # big 1-D vectors (biases over ff/heads) shard on tp when divisible
        if name in ("bq", "bk", "bv", "D", "dt_bias", "ln_scale") \
                and _divisible(shape[0], rules, tp):
            return P(tp)
        return P()
    if len(shape) == 2:
        return d2(fsdp, tp)
    return P(*([None] * len(shape)))


def param_specs(params, rules: MeshRules, stacked_prefixes=("groups",
                                                            "enc_groups")):
    """Pytree of PartitionSpec matching ``params``.

    Leaves under a stacked-groups prefix have a leading group axis that is
    always replicated (it is scanned over).
    """
    import jax.tree_util as jtu

    flat, treedef = jtu.tree_flatten_with_path(params)
    specs = []
    for kp, leaf in flat:
        path = simple_keystr(kp)
        stacked = any(path.startswith(pfx + "/") for pfx in stacked_prefixes)
        shape = tuple(leaf.shape)
        if stacked:
            inner = _spec_for(path, shape[1:], rules)
            specs.append(P(None, *inner))
        else:
            specs.append(_spec_for(path, shape, rules))
    return jtu.tree_unflatten(treedef, specs)


def named_shardings(params, rules: MeshRules):
    return jax.tree.map(lambda s: NamedSharding(rules.mesh, s),
                        param_specs(params, rules))


def spec_bytes_per_device(shape: Tuple[int, ...], dtype, spec: P,
                          rules: MeshRules) -> int:
    """Napkin-math per-device bytes of an array under a spec."""
    n = int(np.prod(shape)) if shape else 1
    denom = 1
    for p in spec:
        denom *= rules.axis_size(p)
    return n * np.dtype(dtype).itemsize // max(denom, 1)
