"""Sharded Monte-Carlo sweep dispatch (DESIGN.md §12).

:func:`run_sweep` wraps :func:`engine.run_stream_batch` — BOTH backends
— in ``shard_map`` over a sweep mesh (``("trials",)`` or ``("trials",
"clients")``, `launch.mesh.make_sweep_mesh`), sharding the (T[, C], …)
request/latency/log stacks across devices while the per-trial rate
traces stay replicated on the client axis (a trial's clients share its
cluster trace, on one device or eight).

Bit-exactness is the whole design:

* the TRIAL axis is embarrassingly parallel — per-stream outputs are
  device-count-invariant provided every lowering-sensitive association
  parameter resolves identically on every device, so the effective
  trial tile is pinned from the GLOBAL trial count (the single-device
  resolution) and each device's shard is padded up to at least one full
  tile;
* the CLIENT axis adds one more association level to the cross-client
  merge: each device folds its local clients with
  `policy_core.masked_client_sum` (in-VMEM on the kernel backend with
  ``merge_mean=False`` — raw SUM blocks, a mean is not cross-device
  composable), then `policy_core.psum_tree` — ``all_gather`` + the
  pinned `tree_sum` halving tree, never a backend ``psum`` — folds the
  per-device partials in mesh-coordinate order.  The device count is
  resolved by shared code (`policy_core.resolve_shard_width`) exactly
  like ``client_tile``, and `policy_core.sharded_client_sum` is the
  host oracle of the whole two-level association.

Padding: the trial axis pads by REPLICATING trial 0 (padded trials
recompute a real trial and are dropped after the dispatch — merges are
per-trial, so they never contaminate anything); the client axis pads
with PHANTOM clients (``valid=False`` slices) that every masked merge
excludes, exactly like the 2-D grid kernel's own client padding.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map_unchecked
from repro.core import engine, policy_core
from repro.launch.mesh import make_sweep_mesh


class SweepMerge(NamedTuple):
    """Per-trial cross-CLIENT aggregates of the sharded (T, C) sweep,
    merged across the client mesh axis with the DESIGN.md §12
    association (per-device `masked_client_sum` partials folded by
    `psum_tree`; maxes by ``pmax``, integer probe counts by ``psum``).
    The sharded twin of :class:`engine.ClientMerge`, uniform across
    backends — exactly the rows `simulate._run_batched`'s per_client
    fold consumes.

    ``p99`` is the GLOBAL cross-client nearest-rank p99 (DESIGN.md
    §14): each device's local merged latency block (the kernel's
    in-VMEM ``ClientMerge.lats``/``lats_valid`` pair, or the jax twin
    `engine.grouped_latency_block`) is ``all_gather``ed over the client
    mesh axis and bisected ONCE with `policy_core.nearest_rank_p99` —
    which is order- and layout-insensitive, so the gathered shard order
    cannot drift the result vs the single-device merged block."""

    window_loads_mean: jax.Array  # (T, W, M) masked client-mean snapshots
    phase_time: jax.Array         # (T,) merged makespan over real clients
    probe_msgs: jax.Array         # (T,) int32 probe total over real clients
    p99: jax.Array                # (T,) global merged nearest-rank p99


def _edge_pad(tree, axis: int, new: int):
    """Pad ``axis`` up to length ``new`` by replicating index 0 (cheap,
    deterministic, finite — the padded slots recompute slot 0).

    Implemented as a GATHER (clipped-index take), not
    broadcast+concatenate: under jit, GSPMD mispartitions a concatenate
    feeding a shard_map operand that is replicated on one axis of a
    2-D mesh — devices receive wrong (even nonexistent) trace rows.
    The gather form partitions correctly; the parity tests pin it."""
    def one(a):
        if a.shape[axis] == new:
            return a
        ar = jnp.arange(new)
        idx = jnp.where(ar < a.shape[axis], ar, 0)
        return a[(slice(None),) * axis + (idx,)]

    return None if tree is None else jax.tree.map(one, tree)


def run_sweep(states, works, keys, *, mesh_shape: Optional[Tuple[int, ...]],
              policy, log_cfg, window_size: int, backend: str = "kernel",
              group_steps: bool = True, traces=None, window_dt: float = 0.0,
              observe: Optional[bool] = None,
              trial_tile: Optional[int] = None,
              client_tile: Optional[int] = None):
    """The whole (T[, C]) sweep as one ``shard_map`` dispatch.

    Arguments mirror :func:`engine.run_stream_batch` (``states`` /
    ``works`` / ``keys`` with a ``(T,)`` or ``(T, C)`` leading batch,
    ``traces`` per-trial); ``mesh_shape`` picks the sweep mesh
    (`launch.mesh.make_sweep_mesh`).  Returns ``(result, metrics,
    sweep_merge)``: ``result``/``metrics`` exactly as the single-device
    dispatch returns them (padded trials/clients stripped), and
    ``sweep_merge`` a :class:`SweepMerge` for the (T, C) form (``None``
    for (T,), where there is nothing to merge).
    """
    from repro.kernels.sched_select import ops as kops

    mesh = make_sweep_mesh(mesh_shape)
    axes = mesh.axis_names
    t_dev = mesh.shape["trials"]
    c_dev = mesh.shape["clients"] if "clients" in axes else 1

    batch_shape = works.object_ids.shape[:-1]
    two_d = len(batch_shape) == 2
    if c_dev > 1 and not two_d:
        raise ValueError(
            f"mesh shape {tuple(mesh.shape.values())} shards a client axis "
            "but the batch has no client axis (pass (T, C) stacks or a "
            "(trials,) mesh)")
    t = batch_shape[0]
    if observe is None:
        observe = traces is not None

    # ---- trial-axis padding: replicate trial 0 up to t_dev equal shards
    # of at least one full trial tile.  The tile is a LOWERING parameter
    # (XLA specializes elementwise code to the block shape), so it must
    # resolve on every device exactly as the single-device dispatch
    # resolves it from the global T: pin the globally-resolved tile and
    # keep every shard at least that long so `ops`' resolve_trial_tile
    # of T_local cannot clamp it differently (DESIGN.md §12).
    tt_eff = kops.resolve_trial_tile(t, trial_tile)
    t_loc = max(-(-t // t_dev), tt_eff) if backend == "kernel" \
        else -(-t // t_dev)
    t_pad = t_loc * t_dev

    # ---- client-axis padding: phantoms up to c_dev equal shards (the
    # shard width is the association parameter the host oracle
    # `policy_core.sharded_client_sum` re-derives)
    if two_d:
        c = batch_shape[1]
        shard_w = policy_core.resolve_shard_width(c, c_dev)
        c_pad = shard_w * c_dev
        if c_pad != c:
            states = _edge_pad(states, 1, c_pad)
            keys = _edge_pad(keys, 1, c_pad)
            works = _edge_pad(works, 1, c_pad)
            cmask = jnp.arange(c_pad) < c
            works = works._replace(
                valid=works.valid & cmask[None, :, None])
    states = _edge_pad(states, 0, t_pad)
    works = _edge_pad(works, 0, t_pad)
    keys = _edge_pad(keys, 0, t_pad)
    traces = _edge_pad(traces, 0, t_pad)

    spec_tc = P("trials", "clients") if (two_d and "clients" in axes) \
        else P("trials")
    collective = two_d and "clients" in axes

    def body(states, works, keys, traces):
        res, metrics, merged = engine.run_stream_batch(
            states, works, keys, policy=policy, log_cfg=log_cfg,
            window_size=window_size, group_steps=group_steps,
            traces=traces, window_dt=window_dt, observe=observe,
            trial_tile=tt_eff if backend == "kernel" else trial_tile,
            client_tile=client_tile, merge_mean=False, backend=backend)
        if not two_d:
            return res, metrics, None

        # ---- cross-client merge: per-device partials with the local
        # masked_client_sum association, folded across the client mesh
        # axis by psum_tree (sums), pmax (makespan) and psum (integer
        # probe counts)
        cvalid = jnp.any(works.valid, axis=-1)        # (t_loc, c_loc)
        c_loc = cvalid.shape[1]
        ct = policy_core.resolve_client_tile(c_loc, client_tile)
        if merged is not None:
            # kernel backend: the in-VMEM merge shipped raw SUM blocks
            # (merge_mean=False above) plus the raw merged latency block
            wl_sum = merged.window_loads_mean
            n_real = merged.metrics[:, policy_core.MET_N_CLIENTS]
            phase_loc = merged.metrics[:, policy_core.MET_MAKESPAN]
            lats_loc = merged.lats
            lval_loc = merged.lats_valid != 0.0
        else:
            # jax backend: the host twins of the in-VMEM merge
            wl_sum = jax.vmap(
                lambda w, v: policy_core.masked_client_sum(w, v, ct)
            )(res.window_loads, cvalid)
            n_real = jax.vmap(
                lambda v: policy_core.masked_client_sum(
                    jnp.ones(v.shape, jnp.float32), v, ct))(cvalid)
            per = works.valid.shape[-1]
            w_open = ((jnp.arange(per) // window_size).astype(jnp.float32)
                      * jnp.float32(window_dt))
            comp = jnp.where(works.valid,
                             w_open[None, None, :] + res.latencies, 0.0)
            phase_loc = jnp.max(comp, axis=(1, 2))
            lats_loc, lval_loc = engine.grouped_latency_block(
                works, res.latencies, window_size, group_steps)
        probes_loc = jnp.sum(jnp.where(cvalid, res.probe_msgs, 0),
                             axis=-1).astype(jnp.int32)
        if collective:
            wl_sum = policy_core.psum_tree(wl_sum, "clients")
            n_real = policy_core.psum_tree(n_real, "clients")
            phase_loc = jax.lax.pmax(phase_loc, "clients")
            probes_loc = jax.lax.psum(probes_loc, "clients")
            # global p99: gather every device's raw block and bisect
            # ONCE — `nearest_rank_p99` is order-insensitive, so the
            # shard-major gather layout is immaterial (DESIGN.md §14)
            lats_loc = jax.lax.all_gather(lats_loc, "clients", axis=1)
            lval_loc = jax.lax.all_gather(lval_loc, "clients", axis=1)
        t_loc = lats_loc.shape[0]
        p99 = policy_core.nearest_rank_p99(
            lats_loc.reshape(t_loc, -1), lval_loc.reshape(t_loc, -1))[:, 0]
        wl_mean = wl_sum / jnp.maximum(n_real, 1.0)[:, None, None]
        return res, metrics, SweepMerge(window_loads_mean=wl_mean,
                                        phase_time=phase_loc,
                                        probe_msgs=probes_loc,
                                        p99=p99)

    f = shard_map_unchecked(
        body, mesh,
        in_specs=(spec_tc, spec_tc, spec_tc, P("trials")),
        out_specs=(spec_tc, spec_tc, P("trials")))
    res, metrics, smerge = f(states, works, keys, traces)

    # ---- strip the padding back off
    def unpad(tree, clients: bool):
        if tree is None:
            return None
        tree = jax.tree.map(lambda a: a[:t], tree)
        if clients and two_d and c_pad != c:
            tree = jax.tree.map(lambda a: a[:, :c], tree)
        return tree

    res = unpad(res, clients=True)
    metrics = unpad(metrics, clients=True)
    smerge = unpad(smerge, clients=False)
    return res, metrics, smerge
