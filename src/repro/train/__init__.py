"""repro.train — optimizer, step functions, gradient compression."""

from repro.train.optimizer import OptConfig, OptState, init, lr_at, update  # noqa: F401
from repro.train.steps import (  # noqa: F401
    TrainState, abstract_state, init_state, make_decode_step,
    make_prefill_step, make_train_step,
)
from repro.train import compression  # noqa: F401
