"""Gradient compression: int8 error-feedback all-reduce (beyond paper).

At 1000+ nodes the data-parallel gradient all-reduce crosses pod boundaries
(slow links).  This module provides a quantized collective for that axis:

    q = round(g / scale) in int8, scale = max|g + e| / 127 (per leaf)
    psum(q) over the DP axis, dequantize, carry the residual e forward

Error feedback keeps the *accumulated* quantization error in the update
path, so SGD-style convergence is preserved (Karimireddy et al., 2019).
Wire bytes drop 4x vs fp32 / 2x vs bf16; the EXPERIMENTS.md §Perf entry
quantifies the collective-term change on the dry-run mesh.

Usable two ways:

* inside ``jax.shard_map`` over the DP axis — :func:`compressed_psum`;
* as a pure single-device transform for tests — :func:`quantize` /
  :func:`dequantize` round-trip with explicit error state.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    """Per-leaf error-feedback residuals (same treedef as grads)."""
    residual: Any


def init_ef(grads) -> EFState:
    return EFState(residual=jax.tree.map(
        lambda g: jnp.zeros_like(g, jnp.float32), grads))


def quantize(g: jax.Array, e: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(g + e) -> int8 q with per-tensor scale; returns (q, scale, new_e)."""
    x = g.astype(jnp.float32) + e
    scale = jnp.maximum(jnp.max(jnp.abs(x)) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    new_e = x - q.astype(jnp.float32) * scale
    return q, scale, new_e


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, ef: EFState, axis_name: str
                    ) -> Tuple[Any, EFState]:
    """Mean-all-reduce of ``grads`` over ``axis_name`` in int8 wire format.

    Must run inside ``shard_map``/``pmap`` that binds ``axis_name``.  The
    per-tensor scales are all-gathered implicitly by psum-of-scaled values:
    each participant dequantizes with its own scale *before* the psum of
    fp32?  No — that would defeat the wire saving.  Instead we psum the
    int8 payload (as int32 accumulators) and psum the scales separately
    (tiny), dequantizing with the mean scale bound per participant.  This
    is the standard "shared-scale" scheme: scale = psum(max|x|)/n/127 so
    every participant quantizes against the same grid.
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        x = g.astype(jnp.float32) + e
        local_max = jnp.max(jnp.abs(x))
        gmax = jax.lax.pmax(local_max, axis_name)       # tiny collective
        scale = jnp.maximum(gmax / 127.0, 1e-12)
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        new_e = x - q.astype(jnp.float32) * scale
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)  # int8 wire
        mean = summed.astype(jnp.float32) * scale / n
        return mean.astype(g.dtype), new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef.residual)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_e = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return new_g, EFState(residual=new_e)


def wire_bytes(grads, compressed: bool) -> int:
    """Ring-all-reduce wire bytes per step for the DP axis (2(n-1)/n ~ 2x
    payload): payload bytes summed over leaves."""
    total = 0
    for g in jax.tree.leaves(grads):
        total += g.size * (1 if compressed else 4)
    return 2 * total
