"""AdamW + global-norm clipping + warmup-cosine schedule (pure pytrees).

Optimizer state mirrors the parameter pytree leaf-for-leaf, so ZeRO-1
sharding falls out of ``param_specs`` automatically (m/v adopt their
parameter's PartitionSpec in the train step's shardings).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.compat import simple_keystr


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    min_lr_ratio: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    m: Any
    v: Any
    count: jax.Array


def init(params) -> OptState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return OptState(m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params),
                    count=jnp.zeros((), jnp.int32))


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.peak_lr * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def _decay_mask(path: str) -> bool:
    """No weight decay on norms/biases/1-D params (by path name)."""
    leaf = path.rsplit("/", 1)[-1]
    return leaf not in ("scale", "bias", "b", "b_i", "b_f", "bq", "bk", "bv",
                        "dt_bias", "ln_scale", "D")


def update(cfg: OptConfig, grads, state: OptState, params
           ) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    """One AdamW step; returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    count = state.count + 1
    lr = lr_at(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    import jax.tree_util as jtu
    flat_p, treedef = jtu.tree_flatten_with_path(params)
    flat_g = jtu.tree_leaves(grads)
    flat_m = jtu.tree_leaves(state.m)
    flat_v = jtu.tree_leaves(state.v)
    new_p, new_m, new_v = [], [], []
    for (kp, p), g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        path = simple_keystr(kp)
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        upd = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        if cfg.weight_decay and _decay_mask(path):
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
        new_m.append(m)
        new_v.append(v)
    params = jtu.tree_unflatten(treedef, new_p)
    st = OptState(m=jtu.tree_unflatten(treedef, new_m),
                  v=jtu.tree_unflatten(treedef, new_v), count=count)
    return params, st, {"grad_norm": gnorm, "lr": lr}
