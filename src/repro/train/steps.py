"""Train / prefill / decode step functions (the things the dry-run lowers).

``make_train_step`` builds the canonical fused step:

    loss/grad (remat per config) -> clip -> AdamW -> new TrainState

The returned function is pure (state, batch) -> (state, metrics) and is
jitted/pjitted by the caller with shardings from ``repro.parallel``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import encdec as E
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.train import optimizer as O


class TrainState(NamedTuple):
    params: Any
    opt: O.OptState
    step: jax.Array


def init_state(key, cfg: ModelConfig) -> TrainState:
    init_fn = E.init_encdec if cfg.enc_dec else T.init_lm
    params = init_fn(key, cfg)
    return TrainState(params=params, opt=O.init(params),
                      step=jnp.zeros((), jnp.int32))


def abstract_state(cfg: ModelConfig, key=None) -> TrainState:
    """Shape/dtype-only TrainState (no allocation) for dry-run lowering."""
    key = key if key is not None else jax.random.key(0)
    return jax.eval_shape(lambda k: init_state(k, cfg), key)


def loss_fn_for(cfg: ModelConfig) -> Callable:
    return E.lm_loss if cfg.enc_dec else T.lm_loss


def make_train_step(cfg: ModelConfig, opt_cfg: O.OptConfig
                    ) -> Callable[[TrainState, Dict[str, jax.Array]],
                                  Tuple[TrainState, Dict[str, jax.Array]]]:
    loss_fn = loss_fn_for(cfg)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, batch, cfg)
        params, opt, opt_metrics = O.update(opt_cfg, grads, state.opt,
                                            state.params)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        new_state = TrainState(params=params, opt=opt, step=state.step + 1)
        return new_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    """Prefill = full forward over the prompt, logits out (dry-run cell)."""
    fwd = E.forward_train if cfg.enc_dec else T.forward_train

    def prefill_step(params, batch):
        logits, _ = fwd(params, batch, cfg)
        return logits

    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    """One-token serve step: (params, caches, tokens(B,1), pos) -> logits."""
    if cfg.enc_dec:
        def decode(params, caches, tokens, pos):
            return E.decode_step(params, caches, tokens, pos, cfg)
    else:
        def decode(params, caches, tokens, pos):
            return T.decode_step(params, caches, tokens, pos, cfg)
    return decode


def eval_ppl(params, batches, cfg: ModelConfig) -> float:
    """Mean token NLL over a list of host batches (examples/quickstart)."""
    loss_fn = loss_fn_for(cfg)
    f = jax.jit(lambda p, b: loss_fn(p, b, cfg)[1]["nll"])
    import numpy as np
    return float(np.mean([jax.device_get(f(params, b)) for b in batches]))
