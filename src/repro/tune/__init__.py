"""Profile-guided lowering autotuner (DESIGN.md §16).

Three pieces:

* :mod:`repro.tune.table` — the versioned on-disk tile table
  (``TUNE_sched.json``) and :func:`~repro.tune.table.resolve_sim_tiles`,
  the ONE resolution point `simulate._sched_trials` routes every
  backend's (trial_tile, client_tile) through;
* :mod:`repro.tune.profile` — wall-clock stage hooks (used by
  `simulate._run_batched` / `engine.run_stream_batch`) plus the
  differential kernel phase profiler built on the kernel's ``ablate``
  levels;
* :mod:`repro.tune.autotune` — the candidate sweep that times tile
  shapes and caches the winner (imported lazily: it depends on
  `repro.core.simulate`, which itself imports :mod:`repro.tune.table`).

``python -m repro.tune --print`` dumps the cached table;
``python -m repro.tune --tune <preset>`` re-tunes a named config.
"""

from repro.tune import profile, table  # noqa: F401
from repro.tune.table import (config_key, load_table,  # noqa: F401
                              resolve_sim_tiles, save_table)
