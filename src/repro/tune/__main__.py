"""CLI for the lowering autotuner.

``python -m repro.tune --print``          dump the cached tile table
``python -m repro.tune --tune <preset>``  re-tune a named configuration
``python -m repro.tune --tune all``       re-tune every preset

Presets cover the benchmark surface of ``benchmarks/sched_perf.py`` —
the shared_log trial grid at paper scale (ect + the sort policies) and
the per_client contention grid at small/large client counts.
"""

from __future__ import annotations

import argparse
import json
import sys


def _presets():
    from repro.core.policies import PolicyConfig
    from repro.core.simulate import SimConfig

    base = dict(n_servers=100, n_requests=2000, n_trials=100,
                window_size=100, backend="kernel")
    pol = lambda name, thr=5.0: PolicyConfig(  # noqa: E731
        name=name, threshold=thr, rng="lcg")
    return {
        "batch_ect": (SimConfig(**base), pol("ect", 0.05)),
        "batch_mlml": (SimConfig(**base), pol("mlml")),
        "batch_nltr": (SimConfig(**base), pol("nltr")),
        "per_client_4c": (SimConfig(client_model="per_client", n_clients=4,
                                    **base), pol("ect", 0.05)),
        "per_client_64c": (SimConfig(client_model="per_client", n_clients=64,
                                     **base), pol("ect", 0.05)),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.tune",
                                 description=__doc__)
    ap.add_argument("--print", action="store_true", dest="print_table",
                    help="dump the cached tile table as JSON")
    ap.add_argument("--tune", metavar="PRESET",
                    help="re-tune a named config preset (or 'all')")
    ap.add_argument("--reps", type=int, default=3,
                    help="timed repetitions per candidate (default 3)")
    ap.add_argument("--path", default=None,
                    help="table path override (default: repo-root "
                         "TUNE_sched.json or $SCHED_TUNE_PATH)")
    args = ap.parse_args(argv)

    from repro.tune import table

    if not args.print_table and not args.tune:
        ap.print_help()
        return 2

    if args.tune:
        from repro.tune import autotune

        presets = _presets()
        if args.tune != "all" and args.tune not in presets:
            print(f"unknown preset {args.tune!r}; choose from "
                  f"{sorted(presets)} or 'all'", file=sys.stderr)
            return 2
        names = sorted(presets) if args.tune == "all" else [args.tune]
        for name in names:
            cfg, pol = presets[name]
            key, entry = autotune.tune_config(cfg, pol, reps=args.reps,
                                              path=args.path)
            print(f"{name}: {key}\n  -> {json.dumps(entry, sort_keys=True)}")

    if args.print_table:
        print(json.dumps({"version": table.TABLE_VERSION,
                          "entries": table.load_table(args.path)},
                         indent=1, sort_keys=True))
    return 0


if __name__ == "__main__":
    sys.exit(main())
