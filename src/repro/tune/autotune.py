"""The lowering autotuner: sweep tile candidates, cache the winner.

For one simulation configuration the tuner prepares the trial batch
once (`simulate._prep_trials`), then times the jitted scheduling stage
(`simulate._sched_trials`) for every candidate (trial_tile,
client_tile) shape and stores the fastest under the configuration's
`repro.tune.table.config_key` in the versioned on-disk table.  Only the
SCHEDULING stage is timed — prep/post are tile-invariant, so including
them would just dilute the signal.

Tiles stay association parameters throughout: a candidate run resolves
its pair through the same `simulate` dispatch as production, and the
cached winner is replayed through `repro.tune.table.resolve_sim_tiles`
— so a tuned run is one of the bit-exact results the contract already
pins, just the fastest-lowered one (DESIGN.md §16).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

from repro.core.policy_core import (DEFAULT_TRIAL_TILE, resolve_client_tile,
                                    resolve_trial_tile)
from repro.tune import profile, table

# Candidate depths for each tile axis; every value is clamped to the
# instance (dedup keeps the sweep small).  The stream-sublane product
# tt * ct of a 2-D candidate is capped so the per-program VMEM working
# set stays well under the ~16 MB budget at paper scale.
TRIAL_TILE_CANDIDATES = (DEFAULT_TRIAL_TILE, 16, 32, 64, 128)
CLIENT_TILE_CANDIDATES = (8, 16, 32, 64)
MAX_STREAM_SUBLANES = 512


def candidate_tiles(n_trials: int, n_clients: int = 1,
                    form: str = "batch") -> List[Tuple[int, int]]:
    """Deduplicated, clamped (trial_tile, client_tile) candidates."""
    tts = sorted({resolve_trial_tile(n_trials, tt)
                  for tt in TRIAL_TILE_CANDIDATES + (n_trials,)})
    if form == "batch":
        return [(tt, 1) for tt in tts]
    cts = sorted({resolve_client_tile(n_clients, ct)
                  for ct in CLIENT_TILE_CANDIDATES + (n_clients,)})
    return [(tt, ct) for tt in tts for ct in cts
            if tt * ct <= MAX_STREAM_SUBLANES]


def _device_count(cfg) -> int:
    if cfg.mesh_shape is None:
        return 1
    n = 1
    for s in cfg.mesh_shape:
        n *= int(s)
    return n


def tune_config(cfg, policy, log_cfg=None, *, reps: int = 3, seed: int = 0,
                path: Optional[str] = None,
                timer: Optional[Callable[[Callable[[], object]], float]]
                = None) -> Tuple[str, dict]:
    """Time every candidate tile shape for ``(cfg, policy)`` and cache
    the winner; returns ``(key, entry)``.

    ``timer`` (tests) overrides the wall-clock measurement: it receives
    an argless runnable for one candidate and returns its cost in
    seconds — with a deterministic timer the sweep, the winner and the
    written table bytes are all reproducible.
    """
    import jax

    from repro.core import simulate

    if log_cfg is None:
        log_cfg = simulate.default_log_cfg(cfg)
    timer = timer or (lambda run: profile.best_time(run, reps=reps))
    form = "grid" if cfg.client_model == "per_client" else "batch"

    keys = jax.random.split(jax.random.key(seed), cfg.n_trials)
    prep_jit = jax.jit(simulate._prep_trials, static_argnums=(1, 2))
    _, _, works, states, traces, k_sched = jax.block_until_ready(
        prep_jit(keys, cfg, log_cfg))
    sched_jit = jax.jit(simulate._sched_trials, static_argnums=(0, 1, 2))

    results = []
    for tt, ct in candidate_tiles(cfg.n_trials, cfg.n_clients, form):
        cand = dataclasses.replace(cfg, trial_tile=tt, client_tile=ct,
                                   tiles="default")
        secs = timer(lambda: sched_jit(cand, policy, log_cfg, works,
                                       states, k_sched, traces))
        results.append((float(secs), tt, ct))
    # ties break toward the shallower (least-memory) shape: sort on
    # (time, tt, ct) and take the head
    secs, tt, ct = sorted(results)[0]
    total_req = cfg.n_trials * cfg.n_requests
    entry = {"trial_tile": tt, "client_tile": ct,
             "sched_s": secs, "req_s": total_req / max(secs, 1e-12)}
    key = table.config_key(
        policy=policy.name, backend=cfg.backend, n_servers=cfg.n_servers,
        n_requests=cfg.n_requests,
        n_clients=(cfg.n_clients if form == "grid" else 1),
        n_trials=cfg.n_trials, window_size=cfg.window_size,
        device_count=_device_count(cfg), form=form)
    table.store(key, entry, path)
    return key, entry
