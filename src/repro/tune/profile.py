"""Wall-clock profiling hooks + the differential kernel phase profiler.

Two layers (DESIGN.md §16):

* STAGE HOOKS — `simulate._run_batched` and `engine.run_stream_batch`
  wrap their pipeline stages in :func:`stage`.  The hooks are inert (a
  no-op context) unless a :func:`collect` block is active, so they cost
  nothing on the hot path and nothing under tracing; a profiler that
  wants real wall numbers runs the pipeline eagerly (or stage-jitted,
  see :func:`pipeline_stage_profile`) inside ``collect()``.  Timing
  lives HERE, not in the engine files — the scheduling surface is under
  the `contractcheck` CC-TIME rule (no clocks near the contract code).

* KERNEL PHASE PROFILER — :func:`kernel_phase_profile` attributes the
  trial-grid kernel's wall time to its window phases by DIFFERENTIAL
  timing over the kernel's cumulative ``ablate`` levels (0 = full, 1 =
  no fused metrics, 2 = also no step loop, 3 = also no sort/plan):
  ``metrics_s = t0 - t1``, ``steps_s = t1 - t2``, ``plan_s = t2 - t3``
  and ``dispatch_s = t3`` (grid dispatch + per-window renorm/drain
  bookkeeping — the interpret-mode floor).  A clock inside the fused
  kernel body is impossible (and banned by CC-TIME), so ablation is the
  only honest per-phase attribution.
"""

from __future__ import annotations

import contextlib
import time
from typing import Callable, Dict, Iterator, Optional

_ACTIVE: Optional[Dict[str, float]] = None


@contextlib.contextmanager
def collect() -> Iterator[Dict[str, float]]:
    """Activate the stage hooks; yields the {stage: seconds} dict they
    accumulate into (re-entrant: nested collects see their own dict)."""
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, {}
    try:
        yield _ACTIVE
    finally:
        _ACTIVE = prev


@contextlib.contextmanager
def stage(name: str) -> Iterator[None]:
    """Accumulate the block's wall time under ``name`` when a collect()
    is active; otherwise a zero-cost no-op."""
    if _ACTIVE is None:
        yield
        return
    t0 = time.perf_counter()
    try:
        yield
    finally:
        _ACTIVE[name] = _ACTIVE.get(name, 0.0) + time.perf_counter() - t0


def median_time(run: Callable[[], object], reps: int = 3) -> float:
    """Median wall seconds of ``run()`` over ``reps`` timed calls after
    one untimed warmup (compile + cache)."""
    import jax

    jax.block_until_ready(run())
    times = []
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(run())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def best_time(run: Callable[[], object], reps: int = 3) -> float:
    """Best (minimum) wall seconds of ``run()`` over ``reps`` timed
    calls after one untimed warmup.  The workload is deterministic, so
    every rep above the minimum is measurement noise (scheduler
    preemption, cache pollution from a neighbouring stage); on a busy
    single-core container one such spike under a median flips candidate
    winners between tuning runs, while the minimum stays stable."""
    import jax

    jax.block_until_ready(run())
    best = float("inf")
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(run())
        best = min(best, time.perf_counter() - t0)
    return best


def kernel_phase_profile(*, n_servers: int = 100, n_requests: int = 2000,
                         window_size: int = 100, n_trials: int = 100,
                         policy: str = "ect", threshold: float = 0.05,
                         trial_tile: Optional[int] = None, reps: int = 3,
                         seed: int = 0) -> Dict[str, float]:
    """Per-window-phase wall-time attribution of the trial-grid kernel
    (differential over ``ablate`` levels; see module docstring).

    Returns ``{"total_s", "metrics_s", "steps_s", "plan_s",
    "dispatch_s"}`` — the last four are clamped nonnegative and the
    deltas are taken on one shared prep, so engine-side dispatch costs
    cancel out of every phase except the ``dispatch_s`` floor."""
    import jax

    from repro.core import simulate
    from repro.core.policies import PolicyConfig
    from repro.core.statlog import LogConfig

    cfg = simulate.SimConfig(n_servers=n_servers, n_requests=n_requests,
                             window_size=window_size, n_trials=n_trials,
                             backend="kernel", trial_tile=trial_tile)
    pol = PolicyConfig(name=policy, threshold=threshold, rng="lcg")
    log_cfg = LogConfig(n_servers=n_servers,
                        lam=simulate.default_log_cfg(cfg).lam)
    keys = jax.random.split(jax.random.key(seed), n_trials)
    prep_jit = jax.jit(simulate._prep_trials, static_argnums=(1, 2))
    _, _, works, states, traces, k_sched = jax.block_until_ready(
        prep_jit(keys, cfg, log_cfg))

    from repro.core import engine

    def runner(level: int) -> Callable[[], object]:
        fn = jax.jit(lambda st, w, k: engine.run_stream_batch(
            st, w, k, policy=pol, log_cfg=log_cfg,
            window_size=cfg.window_size, group_steps=True, traces=traces,
            window_dt=0.0, observe=False, trial_tile=cfg.trial_tile,
            ablate=level))
        return lambda: fn(states, works, k_sched)

    t = [median_time(runner(level), reps=reps) for level in range(4)]
    return {
        "total_s": t[0],
        "metrics_s": max(t[0] - t[1], 0.0),
        "steps_s": max(t[1] - t[2], 0.0),
        "plan_s": max(t[2] - t[3], 0.0),
        "dispatch_s": t[3],
    }


def pipeline_stage_profile(cfg, policy, log_cfg, *, reps: int = 3,
                           seed: int = 0) -> Dict[str, float]:
    """Per-stage wall times of the `simulate._run_batched` pipeline —
    each stage jitted independently (cfg/policy/log_cfg static, the
    DESIGN.md §14 property) and timed end to end."""
    import jax

    from repro.core import simulate

    keys = jax.random.split(jax.random.key(seed), cfg.n_trials)
    prep_jit = jax.jit(simulate._prep_trials, static_argnums=(1, 2))
    sched_jit = jax.jit(simulate._sched_trials, static_argnums=(0, 1, 2))
    post_jit = jax.jit(simulate._post_trials, static_argnums=(0,))

    out: Dict[str, float] = {}
    with collect() as stages:
        with stage("prep"):
            prep = jax.block_until_ready(prep_jit(keys, cfg, log_cfg))
        init, strag_mask, works, states, traces, k_sched = prep
        with stage("sched"):
            sched = jax.block_until_ready(sched_jit(
                cfg, policy, log_cfg, works, states, k_sched, traces))
        with stage("post"):
            jax.block_until_ready(post_jit(
                cfg, init, strag_mask, works, traces, *sched))
    # first pass included compilation; re-time the dominant sched stage
    out["prep_s"] = stages["prep"]
    out["post_s"] = stages["post"]
    out["sched_s"] = median_time(
        lambda: sched_jit(cfg, policy, log_cfg, works, states, k_sched,
                          traces), reps=reps)
    return out
