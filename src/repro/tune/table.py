"""Versioned on-disk lowering-tile table + the ONE tuned-tile resolver.

The autotuner (`repro.tune.autotune`) times candidate (trial_tile,
client_tile) shapes per configuration and caches the winner here, in a
flat JSON table at the repo root (``TUNE_sched.json``, committed next to
``BENCH_sched.json`` so tuned runs are reproducible from a checkout; the
``SCHED_TUNE_PATH`` env var points tests and experiments at a private
table).

Keying (DESIGN.md §16): one entry per ``(policy, backend, M, R, C, T,
window_size, device_count, form)`` — everything the winning lowering
shape can depend on.  Lookup falls back from the exact backend to the
CANONICAL ``backend="kernel"`` entry: the client tile is an ASSOCIATION
parameter (it fixes the cross-client merge grouping, DESIGN.md §11), so
a jax-backend run of a kernel-tuned shape must resolve the *same* tiles
or the two backends would agree on different bit-exact results.

Robustness: a missing, unreadable, corrupt, or stale-``version`` table
degrades to the static defaults — tuning is an optimization, never a
correctness dependency, so nothing in this module raises on bad cache
state.
"""

from __future__ import annotations

import json
import os
from typing import Dict, Optional, Tuple

from repro.core.policy_core import (resolve_client_tile, resolve_grid_tiles,
                                    resolve_trial_tile)

TABLE_VERSION = 1
TABLE_BASENAME = "TUNE_sched.json"
ENV_PATH = "SCHED_TUNE_PATH"

# simulate dispatch forms: "batch" = the 1-D trial grid (shared_log),
# "grid" = the 2-D trials x clients grid (per_client)
FORMS = ("batch", "grid")

TILE_MODES = ("default", "tuned", "fused")


def default_path() -> str:
    env = os.environ.get(ENV_PATH)
    if env:
        return env
    here = os.path.dirname(os.path.abspath(__file__))
    # src/repro/tune -> repo root
    return os.path.join(os.path.dirname(os.path.dirname(
        os.path.dirname(here))), TABLE_BASENAME)


def config_key(*, policy: str, backend: str, n_servers: int,
               n_requests: int, n_clients: int, n_trials: int,
               window_size: int, device_count: int = 1,
               form: str = "batch") -> str:
    """Canonical string key of one tuning configuration."""
    if form not in FORMS:
        raise ValueError(f"form={form!r} must be one of {FORMS}")
    return (f"policy={policy}|backend={backend}|M={n_servers}"
            f"|R={n_requests}|C={n_clients}|T={n_trials}"
            f"|W={window_size}|D={device_count}|form={form}")


def load_table(path: Optional[str] = None) -> Dict[str, dict]:
    """The cached ``{key: entry}`` map; {} on ANY bad cache state
    (missing file, unreadable bytes, non-JSON, wrong schema, stale
    version) — never raises."""
    path = path or default_path()
    try:
        with open(path, "r", encoding="utf-8") as fh:
            raw = json.load(fh)
    except (OSError, ValueError):
        return {}
    if not isinstance(raw, dict) or raw.get("version") != TABLE_VERSION:
        return {}
    entries = raw.get("entries")
    if not isinstance(entries, dict):
        return {}
    out: Dict[str, dict] = {}
    for key, entry in sorted(entries.items()):
        if isinstance(key, str) and isinstance(entry, dict):
            out[key] = dict(entry)
    return out


def save_table(entries: Dict[str, dict], path: Optional[str] = None) -> str:
    """Write the versioned table (sorted keys — byte-deterministic for a
    given entry map).  Returns the path written."""
    path = path or default_path()
    payload = {"version": TABLE_VERSION,
               "entries": {k: entries[k] for k in sorted(entries)}}
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(payload, fh, indent=1, sort_keys=True)
        fh.write("\n")
    return path


def store(key: str, entry: dict, path: Optional[str] = None) -> str:
    entries = load_table(path)
    entries[key] = dict(entry)
    return save_table(entries, path)


def _entry_tiles(entry: Optional[dict]) -> Tuple[Optional[int],
                                                 Optional[int]]:
    if not isinstance(entry, dict):
        return None, None
    tt, ct = entry.get("trial_tile"), entry.get("client_tile")
    tt = int(tt) if isinstance(tt, (int, float)) and tt >= 1 else None
    ct = int(ct) if isinstance(ct, (int, float)) and ct >= 1 else None
    return tt, ct


def lookup(*, policy: str, backend: str, n_servers: int, n_requests: int,
           n_clients: int, n_trials: int, window_size: int,
           device_count: int = 1, form: str = "batch",
           path: Optional[str] = None) -> Optional[dict]:
    """The cached entry for a configuration, trying the exact backend
    first and falling back to the canonical ``kernel`` entry (see module
    docstring — association safety across backends)."""
    entries = load_table(path)
    for be in (backend, "kernel"):
        entry = entries.get(config_key(
            policy=policy, backend=be, n_servers=n_servers,
            n_requests=n_requests, n_clients=n_clients, n_trials=n_trials,
            window_size=window_size, device_count=device_count, form=form))
        if entry is not None:
            return entry
    return None


def resolve_sim_tiles(*, mode: str, policy: str, backend: str,
                      n_servers: int, n_requests: int, n_clients: int,
                      n_trials: int, window_size: int, device_count: int = 1,
                      form: str = "batch", trial_tile=None, client_tile=None,
                      path: Optional[str] = None) -> Tuple[int, int]:
    """THE tuned-tile resolution point (DESIGN.md §16).

    `simulate._sched_trials` calls this ONCE per dispatch and threads
    the returned pair through every layer — kernel grid, engine twin,
    the jax cross-client fold and the sharded sweep — so the tiles stay
    association parameters no matter which mode picked them: tuning
    changes *which* bit-exact result every layer agrees on, never the
    agreement itself.  Explicit ``trial_tile``/``client_tile`` settings
    always win over the table; ``mode``:

    * ``"default"`` — the static `resolve_trial_tile` /
      `resolve_client_tile` defaults (the pre-tuner behaviour, and the
      fallback for every bad-cache state);
    * ``"fused"``   — the `resolve_grid_tiles` fused multi-trial client
      block (deepen the trial tile when the client tile is small);
    * ``"tuned"``   — the cached autotuner winner for this
      configuration, clamped through the static resolvers; a cache miss
      degrades to ``"fused"`` (the profile-guided static heuristic).
    """
    if mode not in TILE_MODES:
        raise ValueError(f"tiles mode {mode!r} must be one of {TILE_MODES}")
    if mode == "tuned":
        entry = lookup(policy=policy, backend=backend, n_servers=n_servers,
                       n_requests=n_requests, n_clients=n_clients,
                       n_trials=n_trials, window_size=window_size,
                       device_count=device_count, form=form, path=path)
        tuned_tt, tuned_ct = _entry_tiles(entry)
        if tuned_tt is None and tuned_ct is None:
            mode = "fused"          # cache miss: the static heuristic
        else:
            tt = resolve_trial_tile(
                n_trials, tuned_tt if trial_tile is None else trial_tile)
            ct = resolve_client_tile(
                n_clients, tuned_ct if client_tile is None else client_tile)
            return tt, ct
    if mode == "fused":
        return resolve_grid_tiles(n_trials, n_clients, trial_tile,
                                  client_tile)
    return (resolve_trial_tile(n_trials, trial_tile),
            resolve_client_tile(n_clients, client_tile))
