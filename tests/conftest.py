import os
import sys

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke
# tests and benches must see the real single CPU device (dry-run only).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from hypothesis import settings

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")
