import os
import sys
import types

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke
# tests and benches must see the real single CPU device (dry-run only).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# ``hypothesis`` is optional: offline environments must still collect and
# run the tier-1 suite.  When it is missing we install a no-op stand-in
# module whose ``@given`` skips the property tests (everything else runs).
try:
    from hypothesis import settings

    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")
except ImportError:  # offline: stub out the API surface the tests use
    import pytest

    def _given(*_a, **_k):
        def deco(fn):
            # zero-arg wrapper: hypothesis-provided params must NOT look
            # like pytest fixtures, so don't preserve the signature
            def wrapper():
                pytest.skip("hypothesis not installed")
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    class _Anything:
        """Stands in for ``hypothesis.strategies``: every attribute is a
        callable returning None (strategies are only consumed by @given,
        which skips before the test body runs)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    class _Settings:
        def __init__(self, *a, **k):
            pass

        def __call__(self, fn):
            return fn

        @staticmethod
        def register_profile(*a, **k):
            pass

        @staticmethod
        def load_profile(*a, **k):
            pass

    _fake = types.ModuleType("hypothesis")
    _fake.given = _given
    _fake.settings = _Settings
    _fake.strategies = _Anything()
    sys.modules["hypothesis"] = _fake
    sys.modules["hypothesis.strategies"] = _fake.strategies
