"""Benchmark tooling regressions: BENCH_sched.json trajectory rendering.

The trajectory renderer consumes a history file that GROWS its schema
over time (new series appear per PR) and can be empty or half-written
(interrupted emit_bench_point, fresh checkout).  These tests pin the
tolerant behaviour: mixed-schema points render with missing cells, an
empty/corrupt/missing file reports instead of raising.
"""

import json

import pytest

sched_perf = pytest.importorskip("benchmarks.sched_perf")

# A realistic mixed-schema history: run 0 predates the kernel backend
# entirely, run 1 predates kernel_batch_req_s, run 2 has everything
# including the sort-policy series; run 3 is schema junk (not a dict).
MIXED_HISTORY = [
    {"ts": 1700000000.0, "phase_s_rr": 5.0, "phase_s_trh": 3.0,
     "phase_s_ect": 2.5, "transient_p99_trh": 1.2},
    {"ts": 1700000100.0, "phase_s_rr": 5.1, "phase_s_trh": 3.1,
     "phase_s_ect": 2.4, "transient_p99_trh": 1.1,
     "kernel_backend_phase_s": 0.9, "kernel_req_s": 100000.0,
     "engine_req_s": 200000.0, "kernel_bit_exact": True},
    {"ts": 1700000200.0, "phase_s_rr": 5.0, "phase_s_trh": 3.0,
     "phase_s_ect": 2.3, "transient_p99_trh": 1.0,
     "kernel_backend_phase_s": 0.8, "kernel_req_s": 150000.0,
     "engine_req_s": 180000.0, "kernel_batch_req_s": 390000.0,
     "kernel_batch_req_s_mlml": 120000.0,
     "kernel_batch_req_s_nltr": 110000.0, "bench_reps": 3},
    ["schema", "junk"],
]


def test_trajectory_tolerates_mixed_schema(tmp_path, capsys):
    path = tmp_path / "BENCH_sched.json"
    path.write_text(json.dumps(MIXED_HISTORY))
    hist = sched_perf.trajectory(str(path), str(tmp_path / "fig.png"))
    out = capsys.readouterr().out
    assert len(hist) == 3                       # junk point dropped
    assert "perf trajectory (3 runs" in out
    # missing series render as placeholders, present ones as numbers
    assert "—" in out
    assert "390000" in out
    assert "kernel_batch_req_s_mlml" in out


def test_trajectory_empty_file_renders_without_error(tmp_path, capsys):
    """Regression: a zero-byte BENCH_sched.json used to raise
    JSONDecodeError out of trajectory()."""
    path = tmp_path / "BENCH_sched.json"
    path.write_text("")
    assert sched_perf.trajectory(str(path), str(tmp_path / "f.png")) == []
    assert "empty or unreadable" in capsys.readouterr().out


def test_trajectory_corrupt_file_renders_without_error(tmp_path, capsys):
    path = tmp_path / "BENCH_sched.json"
    path.write_text('[{"ts": 17')                # interrupted write
    assert sched_perf.trajectory(str(path), str(tmp_path / "f.png")) == []
    assert "empty or unreadable" in capsys.readouterr().out


def test_trajectory_missing_file_renders_without_error(tmp_path, capsys):
    missing = tmp_path / "nope.json"
    assert sched_perf.trajectory(str(missing),
                                 str(tmp_path / "f.png")) == []
    assert "not found" in capsys.readouterr().out


def test_trajectory_empty_list_renders_without_error(tmp_path, capsys):
    path = tmp_path / "BENCH_sched.json"
    path.write_text("[]")
    assert sched_perf.trajectory(str(path), str(tmp_path / "f.png")) == []
    assert "is empty" in capsys.readouterr().out
