"""Benchmark tooling regressions: BENCH_sched.json trajectory rendering.

The trajectory renderer consumes a history file that GROWS its schema
over time (new series appear per PR) and can be empty or half-written
(interrupted emit_bench_point, fresh checkout).  These tests pin the
tolerant behaviour: mixed-schema points render with missing cells, an
empty/corrupt/missing file reports instead of raising.
"""

import json

import pytest

sched_perf = pytest.importorskip("benchmarks.sched_perf")

# A realistic mixed-schema history: run 0 predates the kernel backend
# entirely, run 1 predates kernel_batch_req_s, run 2 has everything
# including the sort-policy series; run 3 is schema junk (not a dict).
MIXED_HISTORY = [
    {"ts": 1700000000.0, "phase_s_rr": 5.0, "phase_s_trh": 3.0,
     "phase_s_ect": 2.5, "transient_p99_trh": 1.2},
    {"ts": 1700000100.0, "phase_s_rr": 5.1, "phase_s_trh": 3.1,
     "phase_s_ect": 2.4, "transient_p99_trh": 1.1,
     "kernel_backend_phase_s": 0.9, "kernel_req_s": 100000.0,
     "engine_req_s": 200000.0, "kernel_bit_exact": True},
    {"ts": 1700000200.0, "phase_s_rr": 5.0, "phase_s_trh": 3.0,
     "phase_s_ect": 2.3, "transient_p99_trh": 1.0,
     "kernel_backend_phase_s": 0.8, "kernel_req_s": 150000.0,
     "engine_req_s": 180000.0, "kernel_batch_req_s": 390000.0,
     "kernel_batch_req_s_mlml": 120000.0,
     "kernel_batch_req_s_nltr": 110000.0, "bench_reps": 3},
    ["schema", "junk"],
]


def test_trajectory_tolerates_mixed_schema(tmp_path, capsys):
    path = tmp_path / "BENCH_sched.json"
    path.write_text(json.dumps(MIXED_HISTORY))
    hist = sched_perf.trajectory(str(path), str(tmp_path / "fig.png"))
    out = capsys.readouterr().out
    assert len(hist) == 3                       # junk point dropped
    assert "perf trajectory (3 runs" in out
    # missing series render as placeholders, present ones as numbers
    assert "—" in out
    assert "390000" in out
    assert "kernel_batch_req_s_mlml" in out


def test_trajectory_empty_file_renders_without_error(tmp_path, capsys):
    """Regression: a zero-byte BENCH_sched.json used to raise
    JSONDecodeError out of trajectory()."""
    path = tmp_path / "BENCH_sched.json"
    path.write_text("")
    assert sched_perf.trajectory(str(path), str(tmp_path / "f.png")) == []
    assert "empty or unreadable" in capsys.readouterr().out


def test_trajectory_corrupt_file_renders_without_error(tmp_path, capsys):
    path = tmp_path / "BENCH_sched.json"
    path.write_text('[{"ts": 17')                # interrupted write
    assert sched_perf.trajectory(str(path), str(tmp_path / "f.png")) == []
    assert "empty or unreadable" in capsys.readouterr().out


def test_trajectory_missing_file_renders_without_error(tmp_path, capsys):
    missing = tmp_path / "nope.json"
    assert sched_perf.trajectory(str(missing),
                                 str(tmp_path / "f.png")) == []
    assert "not found" in capsys.readouterr().out


def test_trajectory_empty_list_renders_without_error(tmp_path, capsys):
    path = tmp_path / "BENCH_sched.json"
    path.write_text("[]")
    assert sched_perf.trajectory(str(path), str(tmp_path / "f.png")) == []
    assert "is empty" in capsys.readouterr().out


# ----------------------------------------------- git-dirty stamping (§16)

def test_stamp_git_warns_loudly_on_dirty_tree(monkeypatch, capsys):
    monkeypatch.setattr(sched_perf, "_git_sha", lambda: "f" * 40)
    monkeypatch.setattr(sched_perf, "_git_dirty", lambda: True)
    point = sched_perf._stamp_git({})
    assert point["git_dirty"] is True
    assert point["git_sha"] == "f" * 40
    err = capsys.readouterr().err
    assert "DIRTY" in err and "regression baseline" in err


def test_stamp_git_silent_on_clean_tree(monkeypatch, capsys):
    monkeypatch.setattr(sched_perf, "_git_sha", lambda: "a" * 40)
    monkeypatch.setattr(sched_perf, "_git_dirty", lambda: False)
    point = sched_perf._stamp_git({})
    assert point["git_dirty"] is False
    assert capsys.readouterr().err == ""


def test_trajectory_renders_dirty_marker_column(tmp_path, capsys):
    """Points stamped git_dirty render a D in the dirty column; clean
    points a ·; pre-stamp points a ?."""
    history = [
        {"ts": 1700000000.0, "phase_s_rr": 5.0},                 # pre-stamp
        {"ts": 1700000100.0, "phase_s_rr": 5.0, "git_dirty": True},
        {"ts": 1700000200.0, "phase_s_rr": 5.0, "git_dirty": False},
    ]
    path = tmp_path / "BENCH_sched.json"
    path.write_text(json.dumps(history))
    sched_perf.trajectory(str(path), str(tmp_path / "f.png"))
    out = capsys.readouterr().out
    lines = [ln for ln in out.splitlines() if ln.strip()]
    # tmp_path embeds this test's name, so the banner line (which prints
    # the json path) also contains "dirty" — key on the header shape
    header = next(ln for ln in lines if "when" in ln and "dirty" in ln)
    col = header.index("dirty") + len("dirty") - 1
    rows = lines[lines.index(header) + 1:lines.index(header) + 4]
    assert [row[col] for row in rows] == ["?", "D", "·"]


def test_latest_bench_point_carries_dirty_stamp():
    """The shipped BENCH_sched.json's newest point must carry the
    git_dirty stamp — the marker the regression gate and the trajectory
    column both key on."""
    import os
    if not os.path.exists(sched_perf.BENCH_PATH):
        pytest.skip("no BENCH_sched.json in this checkout")
    with open(sched_perf.BENCH_PATH) as f:
        history = json.load(f)
    assert isinstance(history, list) and history
    latest = history[-1]
    assert isinstance(latest.get("git_dirty"), bool)
    assert isinstance(latest.get("git_sha"), str)


# ------------------------------------------- regression gate (run.py)

run_mod = pytest.importorskip("benchmarks.run")

CLEAN_BASE = {"ts": 1.0, "git_sha": "b" * 40, "git_dirty": False,
              "kernel_req_s": 100000.0, "kernel_batch_req_s": 400000.0,
              "sharded_req_s_8d": 300000.0}


def test_check_regression_passes_within_tolerance(tmp_path, capsys):
    latest = {"ts": 2.0, "git_sha": "c" * 40, "git_dirty": False,
              "kernel_req_s": 90000.0, "kernel_batch_req_s": 395000.0,
              "sharded_req_s_8d": 290000.0}
    p = tmp_path / "BENCH.json"
    p.write_text(json.dumps([CLEAN_BASE, latest]))
    assert run_mod.check_regression(str(p)) == 0
    assert "ok (3 series)" in capsys.readouterr().out


def test_check_regression_fails_past_tolerance(tmp_path, capsys):
    latest = {"ts": 2.0, "git_sha": "c" * 40,
              "kernel_req_s": 100000.0,
              "kernel_batch_req_s": 100000.0}       # -75%: regressed
    p = tmp_path / "BENCH.json"
    p.write_text(json.dumps([CLEAN_BASE, latest]))
    assert run_mod.check_regression(str(p)) == 1
    out = capsys.readouterr().out
    assert "REGRESSED" in out and "kernel_batch_req_s" in out


def test_check_regression_skips_dirty_baselines(tmp_path, capsys):
    dirty = dict(CLEAN_BASE, git_dirty=True,
                 kernel_batch_req_s=9999999.0)      # tempting but dirty
    latest = {"ts": 3.0, "kernel_batch_req_s": 390000.0}
    p = tmp_path / "BENCH.json"
    p.write_text(json.dumps([CLEAN_BASE, dirty, latest]))
    assert run_mod.check_regression(str(p)) == 0    # vs CLEAN_BASE, not dirty
    assert "ok" in capsys.readouterr().out


def test_check_regression_trivial_passes(tmp_path, capsys):
    p = tmp_path / "BENCH.json"
    assert run_mod.check_regression(str(p)) == 0            # missing
    p.write_text(json.dumps([CLEAN_BASE]))
    assert run_mod.check_regression(str(p)) == 0            # one point
    p.write_text(json.dumps([{"ts": 1.0, "git_dirty": True},
                             {"ts": 2.0}]))
    assert run_mod.check_regression(str(p)) == 0            # no clean base
    capsys.readouterr()
