"""Checkpoint layer: atomicity, async, GC, elastic restore, failure retry."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.checkpoint.manifest as M
from repro.checkpoint import CheckpointConfig, Checkpointer
from repro.core.policies import PolicyConfig
from repro.io import IOClientConfig
from repro.io.striping import MB


def _tree(seed=0):
    k = jax.random.key(seed)
    return {
        "layer": {"w": jax.random.normal(k, (300, 200)),
                  "b": jnp.zeros((200,), jnp.bfloat16)},
        "step": jnp.asarray(17, jnp.int32),
        "nested": [jnp.arange(5.0), jnp.ones((2, 3, 4))],
    }


def _ckpt(d, **kw):
    io = IOClientConfig(policy=PolicyConfig(name="trh", threshold=0.1),
                        stripe_size=MB // 4)
    cfg = CheckpointConfig(shard_size_mb=0.25, keep_n=2, io=io, **kw)
    return Checkpointer(d, n_servers=5, cfg=cfg)


def _assert_tree_equal(a, b):
    fa, fb = M.flatten_with_paths(a), M.flatten_with_paths(b)
    for (pa, la), (pb, lb) in zip(fa, fb):
        assert pa == pb
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb), pa)


def test_save_restore_exact_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        ck = _ckpt(d)
        tree = _tree()
        ck.save(5, tree)
        back = ck.restore(target=jax.tree.map(np.zeros_like, tree))
        _assert_tree_equal(tree, back)
        # dtype preservation incl. bf16
        assert back["layer"]["b"].dtype == jnp.bfloat16


def test_restore_without_target_gives_named_dict():
    with tempfile.TemporaryDirectory() as d:
        ck = _ckpt(d)
        ck.save(1, _tree())
        named = ck.restore()
        assert "layer/w" in named and named["layer/w"].shape == (300, 200)


def test_gc_keeps_newest_n():
    with tempfile.TemporaryDirectory() as d:
        ck = _ckpt(d)
        for s in (10, 20, 30, 40):
            ck.save(s, _tree())
        assert M.committed_steps(ck.manifest_dir) == [30, 40]
        back = ck.restore(step=40)
        assert back is not None


def test_uncommitted_save_is_invisible():
    """Kill-mid-save: shards + manifest written but no COMMIT marker ->
    restore falls back to the previous committed step."""
    with tempfile.TemporaryDirectory() as d:
        ck = _ckpt(d)
        t1 = _tree(1)
        ck.save(1, t1)
        t2 = _tree(2)
        # simulate a crash between manifest write and commit:
        named = [(p, np.asarray(jax.device_get(a)))
                 for p, a in M.flatten_with_paths(t2)]
        real_commit = M.commit
        try:
            M.commit = lambda root, step: (_ for _ in ()).throw(
                KeyboardInterrupt())
            with pytest.raises(KeyboardInterrupt):
                ck._write_tree(2, named, {})
        finally:
            M.commit = real_commit
        assert ck.latest_step() == 1
        back = ck.restore(target=jax.tree.map(np.zeros_like, t1))
        _assert_tree_equal(t1, back)


def test_async_save_overlaps_and_barriers():
    with tempfile.TemporaryDirectory() as d:
        ck = _ckpt(d, async_save=True)
        tree = _tree()
        ck.save(7, tree, block=False)
        ck.wait_until_finished()
        assert ck.latest_step() == 7
        # mutating the live tree after save() must not corrupt the snapshot
        ck.save(8, tree, block=False)
        tree["layer"]["w"] = tree["layer"]["w"] * 0  # host-side mutation
        ck.wait_until_finished()
        back = ck.restore(step=8)
        assert float(np.abs(back["layer/w"]).sum()) > 0


def test_save_survives_server_failure():
    """A failed object server mid-save is masked + retried (scheduler)."""
    with tempfile.TemporaryDirectory() as d:
        ck = _ckpt(d)
        ck.store.fail_server(1)
        ck.store.fail_server(3)
        tree = _tree()
        ck.save(3, tree)
        back = ck.restore(target=jax.tree.map(np.zeros_like, tree))
        _assert_tree_equal(tree, back)
        assert ck.client.stats()["failed_writes"] >= 0


def test_checksum_detects_corruption():
    with tempfile.TemporaryDirectory() as d:
        ck = _ckpt(d)
        ck.save(1, {"x": jnp.arange(100000.0)})
        # corrupt one object file
        objdir = os.path.join(d, "objects")
        victim = None
        for root, _, files in os.walk(objdir):
            for f in files:
                if f.endswith(".bin"):
                    victim = os.path.join(root, f)
        with open(victim, "r+b") as f:
            f.seek(10)
            f.write(b"\xff\xff\xff\xff")
        with pytest.raises(IOError):
            ck.restore(step=1, target={"x": np.zeros(100000, np.float32)})


def test_elastic_restore_onto_new_shardings():
    """Restore maps leaves through a shardings callable (new mesh)."""
    with tempfile.TemporaryDirectory() as d:
        ck = _ckpt(d)
        tree = {"w": jnp.arange(64.0).reshape(8, 8)}
        ck.save(2, tree)
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.compat import make_mesh
        mesh = make_mesh((1,), ("data",))
        sh = lambda path: NamedSharding(mesh, P("data"))
        back = ck.restore(step=2, target=tree, shardings=sh)
        np.testing.assert_array_equal(np.asarray(back["w"]),
                                      np.asarray(tree["w"]))
        assert back["w"].sharding.spec == P("data")


def test_scheduler_balances_checkpoint_objects():
    """The paper's point, on the checkpoint path: straggler-aware placement
    spreads shard objects; with a straggler server injected, fewer bytes
    land on it than under RR."""
    def bytes_on(policy, straggler_delay):
        with tempfile.TemporaryDirectory() as d:
            # ECT thresholds are in expected SECONDS of benefit
            thr = 0.001 if policy == "ect" else 0.05
            io = IOClientConfig(policy=PolicyConfig(name=policy,
                                                    threshold=thr),
                                stripe_size=MB // 4)
            ck = Checkpointer(d, n_servers=4,
                              cfg=CheckpointConfig(shard_size_mb=0.25,
                                                   io=io))
            ck.store.set_write_delay(0, straggler_delay)
            big = {"w": jnp.ones((1200, 1200))}  # ~5.5 MB
            ck.save(1, big)
            sdir = os.path.join(d, "objects", "server_0000")
            return sum(os.path.getsize(os.path.join(sdir, f))
                       for f in os.listdir(sdir) if f.endswith(".bin"))

    rr = bytes_on("rr", 0.0)
    ect = bytes_on("ect", 0.05)  # ECT sees the slow server via rates
    assert ect <= rr
