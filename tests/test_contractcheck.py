"""DESIGN.md §15 contract-checker tests.

Three groups: AST-layer fixtures (one good/bad pair per rule, plus
suppression, allowlist and twin-drift corpora), jaxpr-layer toys (a
``jnp.sort`` planted behind an innocuously-named helper inside a pallas
body — invisible to the AST, caught from the traced jaxpr), and the
merge gate (the shipped tree is strict-clean; the CLI exits 0).
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.contractcheck import check_source, load_config
from repro.contractcheck.jaxprcheck import check_callable
from repro.contractcheck.rules import RULES

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CFG = load_config(REPO)

# a fused-scope path and a dispatch-scope path from the shipped config
FUSED = "src/repro/core/policy_core.py"
DISPATCH = "src/repro/core/engine.py"


def lint(src, relpath=FUSED, rules=None, fused=None):
    return check_source(src, relpath, CFG, rules=rules, fused=fused)


def ids(findings, only_live=True):
    return {f.rule_id for f in findings if not (only_live and f.suppressed)}


# ---------------------------------------------------------------- fixtures

BAD_SUM = """
import jax.numpy as jnp
def drain(lat, valid):
    return jnp.sum(lat)
"""

GOOD_SUM = """
import jax.numpy as jnp
from repro.core.policy_core import lane_sum
def drain(lat, valid):
    a = lane_sum(lat)
    b = jnp.sum(jnp.where(valid, lat, 0.0))          # masked: passes
    c = jnp.sum(valid.astype(jnp.int32))             # integer: passes
    d = jnp.sum((lat >= 0.0).astype(jnp.int32))      # compare: passes
    return a + b + c + d
"""

BAD_SORT = """
import jax.numpy as jnp
def pick(keys):
    return jnp.argsort(-keys)
"""

GOOD_SORT = """
from repro.core.policy_core import rank_desc
def pick(keys):
    return rank_desc(keys)
"""

BAD_CUMSUM = """
import jax.numpy as jnp
def prefix(x):
    return jnp.cumsum(x)
"""

BAD_RNG_NP = """
import numpy as np
def jitter(n):
    return np.random.rand(n)
"""

BAD_RNG_JAX = """
import jax
def jitter(key, n):
    return jax.random.uniform(key, (n,))
"""

GOOD_RNG = """
from repro.core.policy_core import lcg_step
def jitter(seed):
    return lcg_step(seed)
"""

BAD_TIME = """
import time
def stamp():
    return time.time()
"""

BAD_FMA = """
def drain(load, rate, dt):
    return load - rate * dt
"""

GOOD_FMA = """
import jax.numpy as jnp
def drain(load, rate, dt):
    dec = jnp.minimum(rate * dt, load)    # mul feeds a clamp, not a sub
    return load - dec

def index(window_size: int, n: int):
    for w in range(n):
        i = w * window_size + 1           # integer index math: passes
    pad = [(0, 0)] * (n - 1) + [(0, 2)]   # shape/list math: passes
    return i, pad
"""

BAD_ASSOC = """
def dispatch(t, trial_tile):
    tile = min(trial_tile, t) if t else 1
    return tile
"""

BAD_ASSOC_DEFAULT = """
def dispatch(t, trial_tile=None):
    if trial_tile is None:
        trial_tile = 8
    return trial_tile
"""

GOOD_ASSOC = """
DEFAULT_TRIAL_TILE = 8
def resolve_trial_tile(n_trials, trial_tile=None):
    tt = DEFAULT_TRIAL_TILE if trial_tile is None else trial_tile
    return max(min(tt, n_trials), 1)
def dispatch(t, trial_tile=None):
    return resolve_trial_tile(t, trial_tile)
"""

BAD_TILE = """
def dispatch(cfg):
    return kernel(tile=cfg.trial_tile)
"""

GOOD_TILE = """
from repro.tune.table import resolve_sim_tiles
def dispatch(cfg):
    tt, ct = resolve_sim_tiles(mode=cfg.tiles, trial_tile=cfg.trial_tile,
                               client_tile=cfg.client_tile)
    return tt, ct

def resolve_grid_tiles(n_trials, cfg):
    return cfg.trial_tile                 # resolver bodies are blessed
"""

SIMCONFIG_TILE = """
class SimConfig:
    def __post_init__(self):
        if self.trial_tile is not None and self.trial_tile < 1:
            raise ValueError("bad tile")
"""

BAD_TWIN = """
import numpy as np
import jax.numpy as jnp
def norm(p, xp=jnp):
    if xp is np:
        return p - np.max(p)
    return p / jnp.max(p)
"""

GOOD_TWIN = """
import numpy as np
import jax.numpy as jnp
def norm(p, xp=jnp):
    if xp is np:
        return p / np.max(p)
    return p / jnp.max(p)
"""

SUPPRESSED = """
import jax.numpy as jnp
def host_twin(p):
    # contract-ok: CC-SUM host twin sums in f64 — the reference (§9)
    return p / p.sum()
"""

NO_REASON = """
import jax.numpy as jnp
def host_twin(p):
    # contract-ok: CC-SUM
    return p / p.sum()
"""

ALLOWLISTED = """
import random
class HostScheduler:
    def pick(self, n):
        return random.randrange(n)
"""


def test_cc_sum():
    assert ids(lint(BAD_SUM)) == {"CC-SUM"}
    assert ids(lint(GOOD_SUM)) == set()


def test_cc_sort():
    assert ids(lint(BAD_SORT)) == {"CC-SORT"}
    assert ids(lint(GOOD_SORT)) == set()


def test_cc_cumsum():
    assert ids(lint(BAD_CUMSUM)) == {"CC-CUMSUM"}


def test_cc_rng():
    assert ids(lint(BAD_RNG_NP)) == {"CC-RNG"}
    # jax.random is contract-clean in dispatch scope (seeding)…
    assert ids(lint(BAD_RNG_JAX, DISPATCH, rules=["CC-RNG"])) == set()
    # …but banned inside a fused body
    assert ids(lint(BAD_RNG_JAX, fused=True)) == {"CC-RNG"}
    assert ids(lint(GOOD_RNG)) == set()


def test_cc_time():
    assert ids(lint(BAD_TIME)) == {"CC-TIME"}


def test_cc_fma():
    """The acceptance fixture: a seeded multiply-feeding-sub in a fused
    scope (the §9 drain-clamp hazard shape) must be flagged; the clamped
    rewrite and integer index/shape arithmetic must not."""
    assert ids(lint(BAD_FMA)) == {"CC-FMA"}
    assert ids(lint(GOOD_FMA)) == set()


def test_cc_assoc():
    assert ids(lint(BAD_ASSOC, DISPATCH)) == {"CC-ASSOC"}
    assert ids(lint(BAD_ASSOC_DEFAULT, DISPATCH)) == {"CC-ASSOC"}
    # resolution inside the registered resolver is the one blessed home
    assert ids(lint(GOOD_ASSOC, DISPATCH)) == set()


def test_cc_tile():
    """§16: raw attribute reads of tile fields are flagged; feeding them
    TO a resolver (or reading them inside one) is the blessed shape."""
    assert ids(lint(BAD_TILE, DISPATCH)) == {"CC-TILE"}
    assert ids(lint(GOOD_TILE, DISPATCH)) == set()


def test_cc_tile_simconfig_allowance():
    # SimConfig.__post_init__ validates its own tile fields before any
    # resolver sees them — allowlisted in the shipped config
    assert ids(lint(SIMCONFIG_TILE, "src/repro/core/simulate.py")) == set()
    assert ids(lint(SIMCONFIG_TILE, DISPATCH)) == {"CC-TILE"}


def test_cc_twin():
    found = lint(BAD_TWIN)
    assert ids(found) == {"CC-TWIN"}
    assert all(f.severity == "warning" for f in found)
    assert ids(lint(GOOD_TWIN)) == set()


def test_suppression():
    found = lint(SUPPRESSED)
    assert [f.rule_id for f in found if f.suppressed] == ["CC-SUM"]
    assert ids(found) == set()          # suppressed findings never fail


def test_suppression_needs_reason():
    assert ids(lint(NO_REASON)) == {"CC-NOREASON"}


def test_allowlist_scope():
    # HostScheduler is allowlisted for CC-RNG in policies.py only
    assert ids(lint(ALLOWLISTED, "src/repro/core/policies.py")) == set()
    assert ids(lint(ALLOWLISTED, DISPATCH)) == {"CC-RNG"}


def test_fixture_corpus_breadth():
    """Acceptance: the fixture corpus exercises >= 6 distinct rule IDs."""
    corpus = [lint(BAD_SUM), lint(BAD_SORT), lint(BAD_CUMSUM),
              lint(BAD_RNG_NP), lint(BAD_TIME), lint(BAD_FMA),
              lint(BAD_ASSOC, DISPATCH), lint(BAD_TWIN), lint(NO_REASON)]
    seen = set().union(*map(ids, corpus))
    assert len(seen) >= 6, seen
    assert seen <= set(RULES)


# ------------------------------------------------------------ jaxpr layer

def _toy_pallas(body):
    from jax.experimental import pallas as pl

    def call(x):
        return pl.pallas_call(
            body, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=True)(x)

    return call


def _freshen(row):
    """Innocuously-named helper hiding a backend sort — the AST lint of
    the kernel body below sees only a call to `_freshen`."""
    return jnp.sort(row, axis=-1)


def test_cj_sort_behind_helper():
    """Acceptance: a sort_p smuggled into a pallas body through a helper
    is invisible to the AST layer but caught from the traced jaxpr."""

    def body(x_ref, o_ref):
        o_ref[...] = _freshen(x_ref[...])

    kernel_src = """
def body(x_ref, o_ref):
    o_ref[...] = _freshen(x_ref[...])
"""
    assert ids(lint(kernel_src, fused=True)) == set()   # AST sees nothing

    found = check_callable(_toy_pallas(body), (jnp.ones((8, 128)),),
                           label="toy")
    assert "CJ-SORT" in ids(found)


def test_cj_sum_raw_vs_blessed():
    def raw(x):
        return jnp.sum(x)

    def blessed(x):
        m = x > 0.0
        return (jnp.sum(jnp.where(m, x, 0.0))        # masked select
                + jnp.sum(jnp.where(m, 1.0, 0.0))    # select -> weak cast
                + jnp.sum(m.astype(jnp.int32)))      # integer count

    x = jnp.ones((16,))
    assert ids(check_callable(raw, (x,), fused_whole=True)) == {"CJ-SUM"}
    assert ids(check_callable(blessed, (x,), fused_whole=True)) == set()


def test_cj_rng():
    def sample(key):
        return jax.random.uniform(key, (4,))

    found = check_callable(sample, (jax.random.PRNGKey(0),),
                           fused_whole=True)
    assert "CJ-RNG" in ids(found)


def test_real_kernel_body_is_clean():
    """The shipped trial-grid kernel body passes the jaxpr rules."""
    from repro.contractcheck.jaxprcheck import trace_kernel_calls
    assert ids(trace_kernel_calls(["ect"])) == set()


# ------------------------------------------------------------- merge gate

def test_shipped_tree_is_strict_clean():
    """Every scoped file passes the AST layer with zero live findings —
    deliberate deviations are annotated or allowlisted, so any new
    finding is a regression."""
    from repro.contractcheck import check_tree
    live = [f for f in check_tree(CFG) if not f.suppressed]
    assert live == [], [f.format() for f in live]


def test_cli_smoke():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.contractcheck", "--strict",
         "--no-jaxpr"],
        cwd=REPO, env=env, capture_output=True, text=True)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "0 failing" in out.stdout

    listing = subprocess.run(
        [sys.executable, "-m", "repro.contractcheck", "--list-rules"],
        cwd=REPO, env=env, capture_output=True, text=True)
    assert listing.returncode == 0
    for rid in RULES:
        assert rid in listing.stdout
