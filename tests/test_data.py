"""Data pipeline: determinism, elasticity, object-store read path."""

import tempfile

import numpy as np

from repro.data import DataConfig, ObjectStoreTokens, SyntheticTokens
from repro.io import IOClient, IOClientConfig, LocalFSStore
from repro.io.striping import MB


def test_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=777, seq_len=16, global_batch=4, seed=9)
    p = SyntheticTokens(cfg)
    a, b = p.batch_at(12), p.batch_at(12)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = p.batch_at(13)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_targets_are_shifted_tokens():
    p = SyntheticTokens(DataConfig(vocab_size=100, seq_len=8,
                                   global_batch=2))
    b = p.batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])
    assert b["tokens"].min() >= 1 and b["tokens"].max() < 100


def test_elastic_host_resharding_replays_global_batch():
    """2-host view concatenates to the 1-host view (elastic rescale)."""
    base = dict(vocab_size=500, seq_len=12, global_batch=6, seed=3)
    full = SyntheticTokens(DataConfig(**base)).batch_at(4)
    h0 = SyntheticTokens(DataConfig(**base, n_hosts=2, host_id=0)).batch_at(4)
    h1 = SyntheticTokens(DataConfig(**base, n_hosts=2, host_id=1)).batch_at(4)
    np.testing.assert_array_equal(
        np.concatenate([h0["tokens"], h1["tokens"]]), full["tokens"])


def test_object_store_pipeline_matches_synthetic():
    with tempfile.TemporaryDirectory() as d:
        store = LocalFSStore(d, 4)
        cli = IOClient(store, IOClientConfig(stripe_size=MB // 8))
        cfg = DataConfig(vocab_size=333, seq_len=24, global_batch=4, seed=5)
        ost = ObjectStoreTokens(cfg, cli, rows_per_shard=8)
        ost.prepare(n_steps=3)
        for step in range(3):
            got = ost.batch_at(step)
            want = SyntheticTokens(cfg).batch_at(step)
            np.testing.assert_array_equal(got["tokens"], want["tokens"])


def test_object_store_pipeline_redirect_aware():
    """Reads follow redirects after straggler-avoiding writes."""
    with tempfile.TemporaryDirectory() as d:
        store = LocalFSStore(d, 4)
        from repro.core.policies import PolicyConfig
        cli = IOClient(store, IOClientConfig(
            policy=PolicyConfig(name="ect", threshold=0.0),
            stripe_size=MB // 8))
        store.set_write_delay(1, 0.02)
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2, seed=1)
        ost = ObjectStoreTokens(cfg, cli, rows_per_shard=4)
        ost.prepare(n_steps=2)
        got = ost.batch_at(1)
        want = SyntheticTokens(cfg).batch_at(1)
        np.testing.assert_array_equal(got["tokens"], want["tokens"])
