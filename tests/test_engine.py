"""Window/step engine: grouping, windowing, padding invariance."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from repro.core import engine, statlog
from repro.core.engine import Workload
from repro.core.policies import PolicyConfig
from repro.core.statlog import LogConfig


def test_group_by_object_aggregates_lengths():
    work = Workload(jnp.asarray([3, 1, 3, 2, 1], jnp.int32),
                    jnp.asarray([1.0, 2.0, 4.0, 8.0, 16.0]),
                    jnp.ones((5,), bool))
    g = engine.group_by_object(work)
    got = {int(o): float(l) for o, l, v in
           zip(g.object_ids, g.lengths, g.valid) if bool(v)}
    assert got == {1: 18.0, 2: 8.0, 3: 5.0}
    assert int(g.valid.sum()) == 3


def test_group_by_object_respects_padding():
    work = Workload(jnp.asarray([5, 5, 7], jnp.int32),
                    jnp.asarray([1.0, 1.0, 9.0]),
                    jnp.asarray([True, False, True]))
    g = engine.group_by_object(work)
    got = {int(o): float(l) for o, l, v in
           zip(g.object_ids, g.lengths, g.valid) if bool(v)}
    assert got == {5: 1.0, 7: 9.0}


@given(n=st.integers(1, 40), w=st.integers(1, 17))
def test_stream_padding_invariance(n, w):
    """Total scheduled bytes are independent of window size."""
    rng = np.random.default_rng(0)
    obj = jnp.asarray(rng.integers(0, 50, n), jnp.int32)
    lens = jnp.asarray(rng.uniform(1, 10, n), jnp.float32)
    cfg = LogConfig(n_servers=7, lam=32.0)
    work = Workload(obj, lens, jnp.ones((n,), bool))
    res = engine.run_stream(statlog.init_state(cfg), work,
                            jax.random.key(1),
                            policy=PolicyConfig(name="rr"), log_cfg=cfg,
                            window_size=w)
    assert res.chosen.shape == (n,)
    # RR must equal object mod M regardless of windowing
    np.testing.assert_array_equal(np.asarray(res.chosen),
                                  np.asarray(obj) % 7)


def test_stream_load_accounting_matches_chosen():
    rng = np.random.default_rng(3)
    n, m = 64, 10
    obj = jnp.asarray(rng.integers(0, 200, n), jnp.int32)
    lens = jnp.asarray(rng.uniform(1, 5, n), jnp.float32)
    cfg = LogConfig(n_servers=m, lam=64.0)
    work = Workload(obj, lens, jnp.ones((n,), bool))
    res = engine.run_stream(statlog.init_state(cfg), work,
                            jax.random.key(0),
                            policy=PolicyConfig(name="trh", threshold=0.5),
                            log_cfg=cfg, window_size=16, group_steps=False)
    per_server = np.zeros(m)
    for s, l in zip(np.asarray(res.chosen), np.asarray(lens)):
        per_server[s] += l
    np.testing.assert_allclose(np.asarray(res.state.loads), per_server,
                               rtol=1e-4)


def test_jit_cache_stable():
    """run_stream_jit compiles once per static config."""
    cfg = LogConfig(n_servers=4, lam=32.0)
    pol = PolicyConfig(name="trh", threshold=1.0)
    work = Workload(jnp.arange(8, dtype=jnp.int32),
                    jnp.ones((8,), jnp.float32), jnp.ones((8,), bool))
    r1 = engine.run_stream_jit(statlog.init_state(cfg), work,
                               jax.random.key(0), policy=pol, log_cfg=cfg,
                               window_size=4)
    r2 = engine.run_stream_jit(statlog.init_state(cfg), work,
                               jax.random.key(0), policy=pol, log_cfg=cfg,
                               window_size=4)
    np.testing.assert_array_equal(np.asarray(r1.chosen),
                                  np.asarray(r2.chosen))
