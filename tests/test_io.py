"""I/O substrate: striping, stores, redirect tables, maintainer, client."""

import os
import tempfile

import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.policies import PolicyConfig
from repro.io import (IOClient, IOClientConfig, LocalFSStore,
                      MaintainerThread, ServerFailedError, SimulatedCluster,
                      striping)
from repro.io.striping import MB, StripingConfig, stripe_file, stripe_request


@given(offset=st.integers(0, 10 * MB), length=st.integers(0, 20 * MB),
       stripe=st.sampled_from([MB, 2 * MB, 4 * MB]))
def test_striping_covers_range_exactly(offset, length, stripe):
    cfg = StripingConfig(stripe_size=stripe)
    reqs = stripe_request(cfg, file_id=7, offset=offset, length=length)
    assert sum(r.length for r in reqs) == length
    # contiguous, in-order, object-boundary-respecting
    pos = offset
    for r in reqs:
        assert r.file_offset == pos
        assert r.offset == pos % stripe
        assert r.offset + r.length <= stripe
        pos += r.length
    ids = [r.object_id for r in reqs]
    assert len(set(ids)) == len(ids)  # distinct stripes -> distinct objects


def test_boundary_split_example():
    """Paper Fig. 3: an I/O crossing an object boundary splits in two."""
    cfg = StripingConfig(stripe_size=4 * MB)
    reqs = stripe_request(cfg, 1, offset=3 * MB, length=2 * MB)
    assert len(reqs) == 2
    assert reqs[0].length == MB and reqs[1].length == MB
    assert reqs[0].stripe_index == 0 and reqs[1].stripe_index == 1


def test_localfs_roundtrip_and_redirect():
    with tempfile.TemporaryDirectory() as d:
        store = LocalFSStore(d, n_servers=4)
        rng = np.random.default_rng(1)
        data = rng.integers(0, 256, 3 * MB, dtype=np.uint8).tobytes()
        oid = 11  # default home = 3
        res = store.write_object(oid, data, server=1)  # redirected
        assert res.server == 1
        assert store.get_redirect(3, oid) == 1
        assert store.locate(oid) == 1
        assert store.read_object(oid) == data
        # maintainer moves it home and clears the entry (Fig. 6)
        moved = store.maintainer_tick()
        assert moved == 1
        assert store.locate(oid) == 3
        assert store.get_redirect(3, oid) is None
        assert store.read_object(oid) == data


def test_localfs_failure_injection():
    with tempfile.TemporaryDirectory() as d:
        store = LocalFSStore(d, n_servers=2)
        store.fail_server(0)
        with pytest.raises(ServerFailedError):
            store.write_object(5, b"xx", 0)
        store.heal_server(0)
        store.write_object(5, b"xx", 0)


def test_maintainer_thread_runs():
    with tempfile.TemporaryDirectory() as d:
        store = LocalFSStore(d, n_servers=3)
        store.write_object(4, b"abc", 2)  # home 1 -> redirect
        t = MaintainerThread(store, interval_s=0.01)
        t.start()
        import time
        deadline = time.time() + 5
        while store.redirect_count() and time.time() < deadline:
            time.sleep(0.02)
        t.stop()
        assert store.redirect_count() == 0
        assert store.locate(4) == 1


def test_sim_cluster_barrier_semantics():
    sim = SimulatedCluster(4, base_rate_mb_s=100.0)
    sim.write_object(0, 100.0, 0)   # 1s on server 0
    sim.write_object(1, 400.0, 1)   # 4s on server 1 -> gates the phase
    phase = sim.barrier()
    assert phase == pytest.approx(4.0)
    assert sim.clock == pytest.approx(4.0)


def test_sim_straggler_slows_phase():
    sim = SimulatedCluster(4, base_rate_mb_s=100.0)
    sim.make_straggler(2, slow_factor=10.0)
    sim.write_object(0, 100.0, 2)
    assert sim.barrier() == pytest.approx(10.0)


def test_client_write_read_with_failures():
    with tempfile.TemporaryDirectory() as d:
        store = LocalFSStore(d, n_servers=6)
        store.fail_server(2)
        cli = IOClient(store, IOClientConfig(
            policy=PolicyConfig(name="trh", threshold=0.1),
            stripe_size=MB // 2))
        rng = np.random.default_rng(0)
        blobs = {f: rng.integers(0, 256, rng.integers(1, 3 * MB),
                                 dtype=np.uint8).tobytes() for f in range(5)}
        for f, b in blobs.items():
            cli.write_file(f, b)
        for f, b in blobs.items():
            assert cli.read_file(f, len(b)) == b
        st = cli.stats()
        assert st["probe_messages"] == 0
        assert 2 in cli.sched.masked_servers or st["failed_writes"] == 0


def test_client_replication_survives_server_loss():
    with tempfile.TemporaryDirectory() as d:
        store = LocalFSStore(d, n_servers=5)
        cli = IOClient(store, IOClientConfig(
            policy=PolicyConfig(name="mlml", threshold=0.0),
            stripe_size=MB, replication=2))
        data = b"critical" * 1000
        recs = cli.write_file(1, data)
        # kill the primary replica of every object; read must still work
        for r in recs:
            store.fail_server(r.server)
            assert cli.read_file(1, len(data)) == data
            store.heal_server(r.server)


def test_client_async_flush():
    with tempfile.TemporaryDirectory() as d:
        store = LocalFSStore(d, n_servers=4)
        cli = IOClient(store, IOClientConfig(stripe_size=MB,
                                             async_writers=3))
        data = os.urandom(2 * MB + 17)
        cli.write_file_async(9, data)
        cli.flush()
        assert cli.read_file(9, len(data)) == data
        cli.close()


def test_sim_client_straggler_avoidance_beats_rr():
    def run(policy):
        sim = SimulatedCluster(10, base_rate_mb_s=100.0, seed=1)
        sim.make_straggler(3, 8.0)
        sim.add_external_load(3, 300.0)
        cli = IOClient(sim, IOClientConfig(policy=PolicyConfig(
            name=policy, threshold=4.0)))
        cli.log.loads[3] = sim.queued_mb(3)
        for f in range(40):
            cli.write_file(f, size_mb=8.0)
        return cli.flush(), sim.servers[3].n_requests

    t_rr, hits_rr = run("rr")
    t_trh, hits_trh = run("trh")
    assert t_trh < t_rr
    assert hits_trh < hits_rr
