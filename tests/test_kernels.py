"""Pallas kernel sweeps: shapes/dtypes vs pure-jnp oracles (interpret)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.sched_select import sched_select, sched_select_ref

FLASH_CASES = [
    # (B, S, H, KV, hd, window, chunk, dtype)
    (2, 64, 4, 2, 32, None, None, jnp.float32),
    (1, 128, 4, 1, 64, None, None, jnp.float32),     # MQA
    (2, 96, 4, 4, 16, 32, None, jnp.float32),        # MHA + SWA
    (1, 128, 8, 2, 32, None, 32, jnp.float32),       # chunked-local
    (1, 64, 2, 2, 128, None, None, jnp.bfloat16),    # bf16 end-to-end
    (1, 80, 4, 2, 24, 24, None, jnp.float32),        # ragged S, odd hd
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_matches_oracle(case):
    b, s, h, kv, hd, win, ck, dtype = case
    keys = jax.random.split(jax.random.key(hash(case) % 2**31), 3)
    q = jax.random.normal(keys[0], (b, s, h, hd), dtype)
    k = jax.random.normal(keys[1], (b, s, kv, hd), dtype)
    v = jax.random.normal(keys[2], (b, s, kv, hd), dtype)
    out = flash_attention(q, k, v, window=win, chunk=ck,
                          block_q=32, block_k=32)
    ref = attention_ref(q, k, v, window=win, chunk=ck)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    assert err < tol, (case, err)


def test_flash_noncausal_cross():
    b, s, h, hd = 2, 64, 4, 32
    keys = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(keys[0], (b, s, h, hd))
    k = jax.random.normal(keys[1], (b, s, h, hd))
    v = jax.random.normal(keys[2], (b, s, h, hd))
    out = flash_attention(q, k, v, causal=False, block_q=32, block_k=32)
    ref = attention_ref(q, k, v, causal=False)
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-5


def test_flash_block_shape_sweep():
    """Block sizes must not change the math."""
    b, s, h, kv, hd = 1, 128, 4, 2, 32
    keys = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(keys[0], (b, s, h, hd))
    k = jax.random.normal(keys[1], (b, s, kv, hd))
    v = jax.random.normal(keys[2], (b, s, kv, hd))
    ref = attention_ref(q, k, v)
    for bq, bk in [(16, 16), (32, 64), (64, 32), (128, 128)]:
        out = flash_attention(q, k, v, block_q=bq, block_k=bk)
        assert float(jnp.max(jnp.abs(out - ref))) < 2e-5, (bq, bk)


def test_flash_is_global_flag_disables_locality():
    b, s, h, kv, hd = 1, 64, 4, 2, 32
    keys = jax.random.split(jax.random.key(4), 3)
    q = jax.random.normal(keys[0], (b, s, h, hd))
    k = jax.random.normal(keys[1], (b, s, kv, hd))
    v = jax.random.normal(keys[2], (b, s, kv, hd))
    out = flash_attention(q, k, v, window=8, is_global=True,
                          block_q=32, block_k=32)
    ref = attention_ref(q, k, v)  # plain causal
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-5


SCHED_CASES = [
    # (C, N, M, policy, threshold)
    (2, 40, 16, "minload", 0.0),
    (3, 64, 100, "minload", 8.0),
    (2, 40, 16, "two_random", 0.0),
    (1, 100, 100, "two_random", 4.0),
    (4, 16, 3, "minload", 1.0),
]


@pytest.mark.parametrize("case", SCHED_CASES)
def test_sched_select_matches_oracle(case):
    c, n, m, policy, thr = case
    keys = jax.random.split(jax.random.key(hash(case) % 2**31), 3)
    objs = jax.random.randint(keys[0], (c, n), 0, 10_000, dtype=jnp.int32)
    lens = jax.random.uniform(keys[1], (c, n), minval=0.5, maxval=100.0)
    init = jax.random.uniform(keys[2], (c, m), minval=0.0, maxval=50.0)
    seeds = jnp.arange(c, dtype=jnp.uint32) * 13 + 1
    ch, fl = sched_select(objs, lens, init, seeds, n_servers=m,
                          threshold=thr, policy=policy)
    m_pad = max(-(-m // 128) * 128, 128)
    for i in range(c):
        ip = jnp.pad(init[i], (0, m_pad - m))
        rch, rfl = sched_select_ref(objs[i], lens[i], ip, seeds[i],
                                    n_servers=m, threshold=thr, lam=32.0,
                                    policy=policy)
        np.testing.assert_array_equal(np.asarray(ch[i]), np.asarray(rch))
        np.testing.assert_allclose(np.asarray(fl[i]), np.asarray(rfl[:m]),
                                   atol=1e-3)


def test_sched_select_avoids_straggler():
    c, n, m = 2, 60, 12
    objs = jax.random.randint(jax.random.key(0), (c, n), 0, 999,
                              dtype=jnp.int32)
    lens = jnp.ones((c, n)) * 4.0
    init = jnp.zeros((c, m)).at[:, 5].set(1e5)  # server 5 = straggler
    ch, _ = sched_select(objs, lens, init,
                         jnp.asarray([1, 2], jnp.uint32), n_servers=m,
                         threshold=1.0, policy="minload")
    assert int((np.asarray(ch) == 5).sum()) == 0


def test_sched_select_conserves_bytes():
    c, n, m = 1, 30, 8
    objs = jnp.arange(n, dtype=jnp.int32)[None]
    lens = jnp.ones((1, n)) * 2.5
    init = jnp.zeros((1, m))
    ch, fl = sched_select(objs, lens, init, jnp.asarray([9], jnp.uint32),
                          n_servers=m, policy="two_random")
    assert float(fl.sum()) == pytest.approx(n * 2.5, rel=1e-5)
