"""Pallas kernel sweeps: shapes/dtypes vs pure-jnp oracles (interpret)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.sched_select import sched_select, sched_select_ref

FLASH_CASES = [
    # (B, S, H, KV, hd, window, chunk, dtype)
    (2, 64, 4, 2, 32, None, None, jnp.float32),
    (1, 128, 4, 1, 64, None, None, jnp.float32),     # MQA
    (2, 96, 4, 4, 16, 32, None, jnp.float32),        # MHA + SWA
    (1, 128, 8, 2, 32, None, 32, jnp.float32),       # chunked-local
    (1, 64, 2, 2, 128, None, None, jnp.bfloat16),    # bf16 end-to-end
    (1, 80, 4, 2, 24, 24, None, jnp.float32),        # ragged S, odd hd
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_matches_oracle(case):
    b, s, h, kv, hd, win, ck, dtype = case
    keys = jax.random.split(jax.random.key(hash(case) % 2**31), 3)
    q = jax.random.normal(keys[0], (b, s, h, hd), dtype)
    k = jax.random.normal(keys[1], (b, s, kv, hd), dtype)
    v = jax.random.normal(keys[2], (b, s, kv, hd), dtype)
    out = flash_attention(q, k, v, window=win, chunk=ck,
                          block_q=32, block_k=32)
    ref = attention_ref(q, k, v, window=win, chunk=ck)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    assert err < tol, (case, err)


def test_flash_noncausal_cross():
    b, s, h, hd = 2, 64, 4, 32
    keys = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(keys[0], (b, s, h, hd))
    k = jax.random.normal(keys[1], (b, s, h, hd))
    v = jax.random.normal(keys[2], (b, s, h, hd))
    out = flash_attention(q, k, v, causal=False, block_q=32, block_k=32)
    ref = attention_ref(q, k, v, causal=False)
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-5


def test_flash_block_shape_sweep():
    """Block sizes must not change the math."""
    b, s, h, kv, hd = 1, 128, 4, 2, 32
    keys = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(keys[0], (b, s, h, hd))
    k = jax.random.normal(keys[1], (b, s, kv, hd))
    v = jax.random.normal(keys[2], (b, s, kv, hd))
    ref = attention_ref(q, k, v)
    for bq, bk in [(16, 16), (32, 64), (64, 32), (128, 128)]:
        out = flash_attention(q, k, v, block_q=bq, block_k=bk)
        assert float(jnp.max(jnp.abs(out - ref))) < 2e-5, (bq, bk)


def test_flash_is_global_flag_disables_locality():
    b, s, h, kv, hd = 1, 64, 4, 2, 32
    keys = jax.random.split(jax.random.key(4), 3)
    q = jax.random.normal(keys[0], (b, s, h, hd))
    k = jax.random.normal(keys[1], (b, s, kv, hd))
    v = jax.random.normal(keys[2], (b, s, kv, hd))
    out = flash_attention(q, k, v, window=8, is_global=True,
                          block_q=32, block_k=32)
    ref = attention_ref(q, k, v)  # plain causal
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-5


SCHED_CASES = [
    # (C, N, M, policy, threshold)
    (2, 40, 16, "minload", 0.0),
    (3, 64, 100, "minload", 8.0),
    (2, 40, 16, "two_random", 0.0),
    (1, 100, 100, "two_random", 4.0),
    (4, 16, 3, "minload", 1.0),
]


@pytest.mark.parametrize("case", SCHED_CASES)
def test_sched_select_matches_oracle(case):
    c, n, m, policy, thr = case
    keys = jax.random.split(jax.random.key(hash(case) % 2**31), 3)
    objs = jax.random.randint(keys[0], (c, n), 0, 10_000, dtype=jnp.int32)
    lens = jax.random.uniform(keys[1], (c, n), minval=0.5, maxval=100.0)
    init = jax.random.uniform(keys[2], (c, m), minval=0.0, maxval=50.0)
    seeds = jnp.arange(c, dtype=jnp.uint32) * 13 + 1
    ch, fl = sched_select(objs, lens, init, seeds, n_servers=m,
                          threshold=thr, policy=policy)
    m_pad = max(-(-m // 128) * 128, 128)
    for i in range(c):
        ip = jnp.pad(init[i], (0, m_pad - m))
        rch, rfl = sched_select_ref(objs[i], lens[i], ip, seeds[i],
                                    n_servers=m, threshold=thr, lam=32.0,
                                    policy=policy)
        np.testing.assert_array_equal(np.asarray(ch[i]), np.asarray(rch))
        np.testing.assert_allclose(np.asarray(fl[i]), np.asarray(rfl[:m]),
                                   atol=1e-3)


def test_sched_select_avoids_straggler():
    c, n, m = 2, 60, 12
    objs = jax.random.randint(jax.random.key(0), (c, n), 0, 999,
                              dtype=jnp.int32)
    lens = jnp.ones((c, n)) * 4.0
    init = jnp.zeros((c, m)).at[:, 5].set(1e5)  # server 5 = straggler
    ch, _ = sched_select(objs, lens, init,
                         jnp.asarray([1, 2], jnp.uint32), n_servers=m,
                         threshold=1.0, policy="minload")
    assert int((np.asarray(ch) == 5).sum()) == 0


def test_sched_select_conserves_bytes():
    c, n, m = 1, 30, 8
    objs = jnp.arange(n, dtype=jnp.int32)[None]
    lens = jnp.ones((1, n)) * 2.5
    init = jnp.zeros((1, m))
    ch, fl = sched_select(objs, lens, init, jnp.asarray([9], jnp.uint32),
                          n_servers=m, policy="two_random")
    assert float(fl.sum()) == pytest.approx(n * 2.5, rel=1e-5)


# ---------------------------------------------------------------------------
# Temporal stream kernel: kernel == ref == engine (bit-exact, interpret)
# ---------------------------------------------------------------------------

from repro.core import engine, statlog  # noqa: E402
from repro.core.engine import ClusterTrace, Workload  # noqa: E402
from repro.core.policies import PolicyConfig  # noqa: E402
from repro.core.statlog import LogConfig  # noqa: E402
from repro.kernels.sched_select import sched_stream, sched_stream_ref  # noqa: E402


def _transient_trace(m, base=200.0, slow_ids=(3, 5), factor=8.0,
                     onset=0.05, recover=0.15):
    slow = np.full(m, base, np.float32)
    slow[list(slow_ids)] = base / factor
    return ClusterTrace(
        times=jnp.asarray([0.0, onset, recover], jnp.float32),
        rates=jnp.asarray(np.stack([np.full(m, base, np.float32), slow,
                                    np.full(m, base, np.float32)])))


def _stream_case(m, r, seed=0):
    rng = np.random.default_rng(seed)
    return Workload(jnp.asarray(rng.integers(0, 8 * m, r), jnp.int32),
                    jnp.asarray(rng.uniform(1.0, 20.0, r), jnp.float32),
                    jnp.ones((r,), bool))


STREAM_CASES = [
    # (M, R, window, policy, threshold) — M deliberately NOT 128-aligned;
    # R=250/window=60 exercises a padded (partially invalid) last window.
    (100, 240, 60, "ect", 0.05),
    (100, 240, 60, "trh", 4.0),
    (37, 250, 60, "ect", 0.05),
    (37, 250, 60, "trh", 4.0),
    (130, 120, 40, "ect", 0.05),
    (3, 64, 16, "trh", 0.0),
    # sort-based policies (DESIGN.md §10): in-VMEM bitonic request sort
    # (mlml) + recursive-average sections (nltr); odd M, padded windows
    (37, 250, 60, "mlml", 4.0),
    (37, 250, 60, "nltr", 4.0),
    (100, 240, 60, "nltr", 4.0),
    (130, 120, 40, "mlml", 4.0),
    # baselines through the kernel: no-guard rr, probing two_choice
    (24, 130, 40, "rr", 0.0),
    (24, 130, 40, "two_choice", 2.0),
]

_LCG_POLICIES = ("trh", "nltr", "two_choice")


@pytest.mark.parametrize("case", STREAM_CASES)
def test_stream_kernel_engine_parity_transient(case):
    """Every kernel policy runs in-kernel with per-window drain and
    matches the JAX engine BIT-EXACTLY over a transient-straggler trace
    (grouped steps, completion feedback, per-window renorm — the whole
    temporal path).  Randomized policies replay the kernel's LCG via
    PolicyConfig(rng='lcg')."""
    m, r, win, policy, thr = case
    trace = _transient_trace(m, slow_ids=(min(3, m - 1),))
    cfg = LogConfig(n_servers=m, lam=50.0)
    state = statlog.init_state(cfg, rates=trace.rates[0])
    work = _stream_case(m, r, seed=hash(case) % 2**31)
    pol = PolicyConfig(name=policy, threshold=thr,
                       rng="lcg" if policy in _LCG_POLICIES else "jax")
    a = engine.run_stream(state, work, jax.random.key(2), policy=pol,
                          log_cfg=cfg, window_size=win, trace=trace,
                          window_dt=0.04, backend="jax")
    b = engine.run_stream(state, work, jax.random.key(2), policy=pol,
                          log_cfg=cfg, window_size=win, trace=trace,
                          window_dt=0.04, backend="kernel")
    for f in ("chosen", "latencies", "redirected", "window_loads"):
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), err_msg=f)
    np.testing.assert_array_equal(np.asarray(a.state.log),
                                  np.asarray(b.state.log))
    np.testing.assert_array_equal(np.asarray(a.state.n_assigned),
                                  np.asarray(b.state.n_assigned))


@pytest.mark.parametrize("policy", ["ect", "trh", "minload", "two_random",
                                    "mlml", "nltr", "rr", "two_choice"])
def test_stream_kernel_matches_ref_oracle(policy):
    """Kernel == scan oracle on the packed table, padded windows, odd M."""
    m, n_win, win = 37, 4, 32
    rng = np.random.default_rng(7)
    n = n_win * win
    obj = jnp.asarray(rng.integers(0, 500, n), jnp.int32)
    lens = jnp.asarray(rng.uniform(1, 8, n), jnp.float32)
    valid = jnp.asarray(rng.random(n) > 0.2)
    rates = jnp.asarray(rng.uniform(50, 300, (n_win, m)), jnp.float32)
    state = statlog.init_state(LogConfig(n_servers=m, lam=20.0))
    seed = jnp.uint32(12345)
    kw = dict(n_servers=m, window_size=win, threshold=2.0, lam=20.0,
              window_dt=0.01, policy=policy, observe=True, renorm=True)
    ch, lat, tab, wl = sched_stream(obj, lens, valid, state.log, seed,
                                    rates, **kw)
    rch, rlat, rtab, rwl = sched_stream_ref(obj, lens, valid, state.log,
                                            seed, rates, **kw)
    np.testing.assert_array_equal(np.asarray(ch), np.asarray(rch))
    np.testing.assert_array_equal(np.asarray(lat), np.asarray(rlat))
    np.testing.assert_array_equal(np.asarray(tab), np.asarray(rtab))
    np.testing.assert_array_equal(np.asarray(wl), np.asarray(rwl))


def test_stream_kernel_degenerate_static_matches_legacy_minload():
    """With a degenerate static setup (one window, unit rates, no drain,
    no feedback, no renorm) the stream kernel reproduces the legacy
    static kernel bit-for-bit."""
    c, n, m = 2, 50, 24
    keys = jax.random.split(jax.random.key(5), 3)
    objs = jax.random.randint(keys[0], (c, n), 0, 999, dtype=jnp.int32)
    lens = jax.random.uniform(keys[1], (c, n), minval=1.0, maxval=30.0)
    init = jax.random.uniform(keys[2], (c, m), maxval=50.0)
    seeds = jnp.arange(c, dtype=jnp.uint32) * 7 + 3
    for policy in ("minload", "two_random"):
        ch_old, fl_old = sched_select(objs, lens, init, seeds, n_servers=m,
                                      threshold=2.0, policy=policy)
        for i in range(c):
            table = jnp.stack([init[i], jnp.full((m,), 1.0 / m),
                               jnp.zeros((m,)), jnp.ones((m,))])
            ch, _, tab, _ = sched_stream(
                objs[i], lens[i], jnp.ones((n,), bool), table, seeds[i],
                jnp.ones((1, m), jnp.float32), n_servers=m, window_size=n,
                threshold=2.0, lam=32.0, window_dt=0.0, policy=policy,
                observe=False, renorm=False)
            np.testing.assert_array_equal(np.asarray(ch_old[i]),
                                          np.asarray(ch))
            np.testing.assert_allclose(np.asarray(fl_old[i]),
                                       np.asarray(tab[0]), atol=1e-3)


# ---------------------------------------------------------------------------
# Trial-grid batch kernel: the whole sweep as ONE pallas_call (DESIGN.md §9)
# ---------------------------------------------------------------------------

from repro.core import policy_core  # noqa: E402
from repro.kernels.sched_select import (sched_stream_batch,  # noqa: E402
                                        sched_stream_batch_ref)


def _batch_case(t, m, n_win, win, seed=0):
    rng = np.random.default_rng(seed)
    n = n_win * win
    return (jnp.asarray(rng.integers(0, 8 * m, (t, n)), jnp.int32),
            jnp.asarray(rng.uniform(1.0, 20.0, (t, n)), jnp.float32),
            jnp.asarray(rng.random((t, n)) > 0.2),      # padded windows
            jnp.stack([statlog.init_state(LogConfig(n_servers=m,
                                                    lam=50.0)).log] * t),
            jnp.asarray(rng.integers(0, 2**31, (t,)), jnp.uint32),
            jnp.asarray(rng.uniform(50.0, 300.0, (t, n_win, m)), jnp.float32))


BATCH_CASES = [
    # (T, M, W, win, tile, policy) — odd M, T not a multiple of the grid
    # tile (inert padded trials), partially-invalid (padded) windows.
    (5, 37, 4, 32, 2, "ect"),
    (5, 37, 4, 32, 2, "trh"),
    (10, 100, 5, 40, 8, "ect"),      # headline shape, T padded 10 -> 16
    (16, 130, 4, 50, 8, "trh"),      # M wider than one 128-lane tile
    (3, 24, 4, 30, 3, "ect"),
    # M_pad = 384 is NOT a power of two: lane_sum's in-kernel renorm
    # reduction must pad 384 -> 512 (the only path that exercises it)
    (4, 300, 3, 32, 4, "trh"),
    # sort-based policies on the trial grid (DESIGN.md §10): per-window
    # bitonic sorts vectorized over trial sublanes; T % tile != 0
    (5, 37, 4, 32, 2, "mlml"),
    (5, 37, 4, 32, 2, "nltr"),
    (6, 24, 4, 30, 4, "nltr"),
    (3, 24, 3, 30, 3, "rr"),
    (3, 24, 3, 30, 3, "two_choice"),
]


@pytest.mark.parametrize("case", enumerate(BATCH_CASES),
                         ids=lambda c: str(c[1]) if isinstance(c, tuple)
                         else None)
def test_stream_batch_matches_ref_and_sequential(case):
    """Trial-grid kernel == batched oracle == per-trial sequential kernel:
    choices, latencies, loads, window loads and fused metrics BIT-EXACT
    (the tentpole contract); probability/EWMA-derived table rows to float
    tolerance — `jnp.exp`'s polynomial may contract differently at some
    tile widths (DESIGN.md §9), a drift the decision outputs never see."""
    # stable per-case seed (hash() varies with PYTHONHASHSEED — a failing
    # bit-exactness case must reproduce across processes)
    idx, (t, m, n_win, win, tile, policy) = case
    obj, lens, valid, tables, seeds, rates = _batch_case(
        t, m, n_win, win, seed=1000 + idx)
    kw = dict(n_servers=m, window_size=win, threshold=2.0, lam=50.0,
              window_dt=0.02, policy=policy, observe=True, renorm=True)
    ch, lat, tab, wl, met = sched_stream_batch(obj, lens, valid, tables,
                                               seeds, rates,
                                               trial_tile=tile, **kw)
    rch, rlat, rtab, rwl, rmet = sched_stream_batch_ref(
        obj, lens, valid, tables, seeds, rates, **kw)
    np.testing.assert_array_equal(np.asarray(ch), np.asarray(rch))
    np.testing.assert_array_equal(np.asarray(lat), np.asarray(rlat))
    np.testing.assert_array_equal(np.asarray(wl), np.asarray(rwl))
    np.testing.assert_array_equal(np.asarray(met), np.asarray(rmet))
    np.testing.assert_array_equal(
        np.asarray(tab[:, policy_core.ROW_LOADS]),
        np.asarray(rtab[:, policy_core.ROW_LOADS]))
    np.testing.assert_allclose(np.asarray(tab), np.asarray(rtab), atol=1e-6)
    # per-trial sequential kernel (the lax.map path's unit of work)
    for i in range(t):
        c1, l1, _, w1 = sched_stream(obj[i], lens[i], valid[i], tables[i],
                                     seeds[i], rates[i], **kw)
        np.testing.assert_array_equal(np.asarray(ch[i]), np.asarray(c1))
        np.testing.assert_array_equal(np.asarray(lat[i]), np.asarray(l1))
        np.testing.assert_array_equal(np.asarray(wl[i]), np.asarray(w1))


def test_stream_batch_tile1_full_table_exact():
    """At trial_tile=1 the grid form degenerates to the PR-2 single-stream
    kernel: the ENTIRE final table is bit-exact vs the oracle."""
    obj, lens, valid, tables, seeds, rates = _batch_case(3, 37, 4, 32,
                                                         seed=11)
    kw = dict(n_servers=37, window_size=32, threshold=2.0, lam=50.0,
              window_dt=0.02, policy="ect", observe=True, renorm=True)
    outs = sched_stream_batch(obj, lens, valid, tables, seeds, rates,
                              trial_tile=1, **kw)
    refs = sched_stream_batch_ref(obj, lens, valid, tables, seeds, rates,
                                  **kw)
    for a, b in zip(outs, refs):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_stream_batch_fused_metrics_definition():
    """The fused metrics row equals the canonical host-side definitions:
    makespan = max window-open + latency over valid steps; p99 = the
    nearest-rank (ceil(0.99 n)-th order) statistic; sum/max/count over
    the valid latencies."""
    t, m, n_win, win = 6, 24, 5, 30
    obj, lens, valid, tables, seeds, rates = _batch_case(t, m, n_win, win,
                                                         seed=5)
    dt = 0.03
    kw = dict(n_servers=m, window_size=win, threshold=2.0, lam=50.0,
              window_dt=dt, policy="ect", observe=True, renorm=True)
    _, lat, _, _, met = sched_stream_batch(obj, lens, valid, tables, seeds,
                                           rates, trial_tile=3, **kw)
    lat, met, vnp = np.asarray(lat), np.asarray(met), np.asarray(valid)
    for i in range(t):
        vl = lat[i][vnp[i]]
        w_open = ((np.arange(n_win * win) // win).astype(np.float32)
                  * np.float32(dt))
        mk = float((w_open[vnp[i]] + vl).max())
        k = int(np.ceil(0.99 * len(vl)))
        p99 = float(np.sort(vl)[k - 1])
        assert met[i, policy_core.MET_MAKESPAN] == pytest.approx(mk, abs=0)
        assert met[i, policy_core.MET_P99] == pytest.approx(
            p99, rel=1e-6), (met[i, policy_core.MET_P99], p99)
        assert met[i, policy_core.MET_LAT_SUM] == pytest.approx(
            float(vl.sum()), rel=1e-5)
        assert met[i, policy_core.MET_LAT_MAX] == float(vl.max())
        assert met[i, policy_core.MET_N_VALID] == float(len(vl))


def test_run_stream_batch_engine_parity():
    """engine.run_stream_batch == lax.map of run_stream(backend='kernel')
    == the vmapped jax engine, over a transient trace — decisions,
    latencies, loads, redirects and probe accounting bit-exact."""
    t, m, r, win = 5, 37, 250, 60
    trace = _transient_trace(m, slow_ids=(3,))
    cfg = LogConfig(n_servers=m, lam=50.0)
    keys = jax.random.split(jax.random.key(7), t)
    rng = np.random.default_rng(9)
    works = Workload(
        jnp.asarray(rng.integers(0, 8 * m, (t, r)), jnp.int32),
        jnp.asarray(rng.uniform(1.0, 20.0, (t, r)), jnp.float32),
        jnp.ones((t, r), bool))
    state = statlog.init_state(cfg, rates=trace.rates[0])
    states = jax.tree.map(lambda a: jnp.broadcast_to(a, (t,) + a.shape),
                          state)
    traces = jax.tree.map(lambda a: jnp.broadcast_to(a, (t,) + a.shape),
                          trace)
    for policy, rng_mode in (("ect", "jax"), ("trh", "lcg")):
        pol = PolicyConfig(name=policy, threshold=0.05, rng=rng_mode)
        batch, metrics, _ = engine.run_stream_batch(
            states, works, keys, policy=pol, log_cfg=cfg, window_size=win,
            traces=traces, window_dt=0.04, observe=True)

        def one(w_k, backend):
            w, k = w_k
            return engine.run_stream(state, w, k, policy=pol, log_cfg=cfg,
                                     window_size=win, trace=trace,
                                     window_dt=0.04, observe=True,
                                     backend=backend)
        seq = jax.lax.map(lambda wk: one(wk, "kernel"), (works, keys))
        eng = jax.vmap(lambda w, k: one((w, k), "jax"))(works, keys)
        for other in (seq, eng):
            for f in ("chosen", "latencies", "redirected", "window_loads"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(batch, f)),
                    np.asarray(getattr(other, f)), err_msg=(policy, f))
            np.testing.assert_array_equal(
                np.asarray(batch.state.n_assigned),
                np.asarray(other.state.n_assigned))
        np.testing.assert_array_equal(np.asarray(batch.probe_msgs),
                                      np.asarray(seq.probe_msgs))
        # fused makespan == the canonical reduction over the seq path
        w_open = (jnp.arange(r) // win).astype(jnp.float32) * 0.04
        np.testing.assert_array_equal(
            np.asarray(metrics[:, policy_core.MET_MAKESPAN]),
            np.asarray(jnp.max(w_open[None] + seq.latencies, axis=-1)))


@pytest.mark.parametrize("policy", ["mlml", "nltr"])
def test_stream_batch_sort_policy_all_invalid_final_window(policy):
    """A FULLY padded (all-invalid) final window: every sort key in the
    window is -inf, nvalid = 0 collapses the nLTR section bounds to 0,
    and the LCG still advances on the dead steps — kernel == batched
    oracle == engine, bit-exact (DESIGN.md §10 edge case)."""
    t, m, n_win, win = 4, 37, 4, 30
    obj, lens, valid, tables, seeds, rates = _batch_case(t, m, n_win, win,
                                                         seed=21)
    valid = valid.at[:, -win:].set(False)        # kill the last window
    kw = dict(n_servers=m, window_size=win, threshold=2.0, lam=50.0,
              window_dt=0.02, policy=policy, observe=True, renorm=True)
    outs = sched_stream_batch(obj, lens, valid, tables, seeds, rates,
                              trial_tile=2, **kw)
    refs = sched_stream_batch_ref(obj, lens, valid, tables, seeds, rates,
                                  **kw)
    for name, a, b in zip(("ch", "lat", "tab", "wl", "met"), outs, refs):
        if name == "tab":
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6, err_msg=name)
            np.testing.assert_array_equal(
                np.asarray(a[:, policy_core.ROW_LOADS]),
                np.asarray(b[:, policy_core.ROW_LOADS]), err_msg=name)
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=name)
    # dead-window latencies are exactly zero (masked writes)
    np.testing.assert_array_equal(np.asarray(outs[1][:, -win:]), 0.0)


# ---------------------------------------------------------------------------
# 2-D (trials × clients) grid kernel: per_client contention on the kernel
# path with in-VMEM cross-client merge (DESIGN.md §11)
# ---------------------------------------------------------------------------

from repro.kernels.sched_select import (sched_stream_grid,  # noqa: E402
                                        sched_stream_grid_ref)


def _grid_case(t, c, m, n_win, win, seed=0, dead_clients=()):
    """(T, C, N) streams; ``dead_clients`` marks whole client slices
    invalid in every trial (phantom clients)."""
    rng = np.random.default_rng(seed)
    n = n_win * win
    valid = rng.random((t, c, n)) > 0.2
    for dc in dead_clients:
        valid[:, dc, :] = False
    return (jnp.asarray(rng.integers(0, 8 * m, (t, c, n)), jnp.int32),
            jnp.asarray(rng.uniform(1.0, 20.0, (t, c, n)), jnp.float32),
            jnp.asarray(valid),
            jnp.broadcast_to(statlog.init_state(
                LogConfig(n_servers=m, lam=50.0)).log, (t, c, 4, m)),
            jnp.asarray(rng.integers(0, 2**31, (t, c)), jnp.uint32),
            jnp.asarray(rng.uniform(50.0, 300.0, (t, n_win, m)), jnp.float32))


GRID_CASES = [
    # (T, C, M, W, win, t_tile, c_tile, policy, dead_clients) — odd M,
    # T % t_tile != 0 and C % c_tile != 0 (inert trial AND phantom client
    # padding), multi-block client merges, whole dead client slices.
    (3, 5, 37, 3, 20, 2, 2, "ect", ()),
    (2, 3, 24, 2, 16, 8, 8, "trh", ()),          # tiles wider than T, C
    (2, 4, 25, 2, 16, 1, 4, "nltr", ()),
    (3, 2, 24, 2, 10, 2, 1, "mlml", ()),         # c_tile=1: per-client blocks
    (2, 5, 17, 2, 12, 2, 2, "two_choice", (1,)),  # dead client mid-row
    (2, 3, 24, 2, 10, 2, 3, "rr", (0, 2)),        # mostly-dead trials
]


@pytest.mark.parametrize("case", enumerate(GRID_CASES),
                         ids=lambda c: str(c[1]) if isinstance(c, tuple)
                         else None)
def test_stream_grid_matches_ref_and_sequential(case):
    """2-D grid kernel == vmap² oracle == per-stream sequential kernel:
    choices, latencies, loads, window loads, per-stream metrics AND the
    in-VMEM cross-client merges (masked client-mean window loads,
    merged metric row) BIT-EXACT — the §11 tentpole contract.  Same
    float-tolerance carve-out for the probability/EWMA table rows as
    the 1-D grid (DESIGN.md §9).  Stable per-case seed — hash() varies
    with PYTHONHASHSEED, and a failing bit-exactness case must
    reproduce across processes."""
    idx, (t, c, m, n_win, win, tt, ct, policy, dead) = case
    obj, lens, valid, tables, seeds, rates = _grid_case(
        t, c, m, n_win, win, seed=2000 + idx, dead_clients=dead)
    kw = dict(n_servers=m, window_size=win, threshold=2.0, lam=50.0,
              window_dt=0.02, policy=policy, observe=True, renorm=True)
    outs = sched_stream_grid(obj, lens, valid, tables, seeds, rates,
                             trial_tile=tt, client_tile=ct, **kw)
    refs = sched_stream_grid_ref(obj, lens, valid, tables, seeds, rates,
                                 client_tile=ct, **kw)
    names = ("choices", "lats", "tables", "wloads", "metrics",
             "cm_wloads", "cm_metrics", "cm_lats", "cm_lval")
    for name, a, b in zip(names, outs, refs):
        a, b = np.asarray(a), np.asarray(b)
        if name == "tables":
            np.testing.assert_array_equal(a[:, :, policy_core.ROW_LOADS],
                                          b[:, :, policy_core.ROW_LOADS],
                                          err_msg=name)
            np.testing.assert_allclose(a, b, atol=1e-6, err_msg=name)
        else:
            np.testing.assert_array_equal(a, b, err_msg=name)
    # per-stream == the sequential single-stream kernel (all of a
    # trial's clients share its rate trace)
    for i in range(t):
        for j in range(c):
            c1, l1, _, w1 = sched_stream(obj[i, j], lens[i, j], valid[i, j],
                                         tables[i, j], seeds[i, j],
                                         rates[i], **kw)
            np.testing.assert_array_equal(np.asarray(outs[0][i, j]),
                                          np.asarray(c1))
            np.testing.assert_array_equal(np.asarray(outs[1][i, j]),
                                          np.asarray(l1))
            np.testing.assert_array_equal(np.asarray(outs[3][i, j]),
                                          np.asarray(w1))


def test_stream_grid_client_merge_masks_phantoms():
    """The in-VMEM cross-client merge weights REAL clients only: with
    dead (all-invalid) client slices, cm_metrics' client count excludes
    them and cm_wloads equals the policy_core twins computed from the
    surviving per-stream outputs — including across client-tile block
    boundaries (C=5 over c_tile=2 -> 3 blocks with phantom padding).
    The merged latency block (DESIGN.md §14) masks dead clients' rows
    to exact zeros with zero validity, and MET_P99 equals the host
    `nearest_rank_p99` bisection over that block."""
    t, c, m, n_win, win = 2, 5, 24, 2, 12
    obj, lens, valid, tables, seeds, rates = _grid_case(
        t, c, m, n_win, win, seed=77, dead_clients=(0, 3))
    kw = dict(n_servers=m, window_size=win, threshold=2.0, lam=50.0,
              window_dt=0.02, policy="ect", observe=True, renorm=True)
    (_, lats, _, wloads, metrics, cm_wl, cm_met, cm_lats, cm_lval) = \
        sched_stream_grid(obj, lens, valid, tables, seeds, rates,
                          trial_tile=2, client_tile=2, **kw)
    cvalid = jnp.any(valid, axis=-1)
    np.testing.assert_array_equal(
        np.asarray(cm_met[:, policy_core.MET_N_CLIENTS]),
        np.asarray(jnp.sum(cvalid.astype(jnp.float32), axis=-1)))
    assert (np.asarray(cm_met[:, policy_core.MET_N_CLIENTS]) == 3.0).all()
    ref_wl = jax.vmap(
        lambda w, v: policy_core.masked_client_mean(w, v, 2))(wloads, cvalid)
    np.testing.assert_array_equal(np.asarray(cm_wl), np.asarray(ref_wl))
    ref_met = jax.vmap(
        lambda mm, v, ml, mv: policy_core.client_stream_metrics(
            mm, v, 2, merged_lats=ml, merged_valid=mv))(
        metrics, cvalid, cm_lats, valid)
    np.testing.assert_array_equal(np.asarray(cm_met), np.asarray(ref_met))
    # merged latency block: valid slots carry the per-stream latencies
    # verbatim, dead clients / invalid slots are exact zeros
    np.testing.assert_array_equal(
        np.asarray(cm_lats), np.asarray(jnp.where(valid, lats, 0.0)))
    np.testing.assert_array_equal(
        np.asarray(cm_lval), np.asarray(jnp.where(valid, 1.0, 0.0)))
    np.testing.assert_array_equal(np.asarray(cm_lats[:, 0]), 0.0)
    np.testing.assert_array_equal(np.asarray(cm_lval[:, 3]), 0.0)
    # MET_P99 == the host value-bisection over the merged block (order-
    # insensitive, so the (C, N) layout is immaterial)
    host_p99 = policy_core.nearest_rank_p99(
        cm_lats.reshape(t, -1), cm_lval.reshape(t, -1) != 0.0)[:, 0]
    np.testing.assert_array_equal(
        np.asarray(cm_met[:, policy_core.MET_P99]), np.asarray(host_p99))
    # dead clients' latencies are exactly zero (masked writes)
    np.testing.assert_array_equal(np.asarray(lats[:, 0]), 0.0)


def test_stream_grid_merged_p99_oracle_edge_cases():
    """Merged-p99 edge cases (DESIGN.md §14): an ALL-INVALID trial
    (every client dead) pins MET_P99 to exactly 0, and C > R (more
    clients than per-client requests) keeps kernel == vmap² oracle ==
    host bisection bit-exact."""
    # all clients dead in every trial -> nvalid = 0 -> p99 = 0 exactly
    t, c, m, n_win, win = 2, 3, 24, 2, 10
    obj, lens, valid, tables, seeds, rates = _grid_case(
        t, c, m, n_win, win, seed=91, dead_clients=(0, 1, 2))
    kw = dict(n_servers=m, window_size=win, threshold=2.0, lam=50.0,
              window_dt=0.02, policy="ect", observe=True, renorm=True)
    outs = sched_stream_grid(obj, lens, valid, tables, seeds, rates,
                             trial_tile=2, client_tile=2, **kw)
    np.testing.assert_array_equal(
        np.asarray(outs[6][:, policy_core.MET_P99]), 0.0)
    np.testing.assert_array_equal(np.asarray(outs[7]), 0.0)
    np.testing.assert_array_equal(np.asarray(outs[8]), 0.0)
    # C > R: 7 clients of single-window 4-request streams
    t, c, m, n_win, win = 2, 7, 17, 1, 4
    obj, lens, valid, tables, seeds, rates = _grid_case(
        t, c, m, n_win, win, seed=92, dead_clients=(2,))
    kw = dict(n_servers=m, window_size=win, threshold=2.0, lam=50.0,
              window_dt=0.02, policy="trh", observe=True, renorm=True)
    outs = sched_stream_grid(obj, lens, valid, tables, seeds, rates,
                             trial_tile=2, client_tile=3, **kw)
    refs = sched_stream_grid_ref(obj, lens, valid, tables, seeds, rates,
                                 client_tile=3, **kw)
    for name, a, b in zip(("choices", "lats", "tables", "wloads",
                           "metrics", "cm_wloads", "cm_metrics",
                           "cm_lats", "cm_lval"), outs, refs):
        if name == "tables":
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6, err_msg=name)
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=name)
    host_p99 = policy_core.nearest_rank_p99(
        outs[7].reshape(t, -1), outs[8].reshape(t, -1) != 0.0)[:, 0]
    np.testing.assert_array_equal(
        np.asarray(outs[6][:, policy_core.MET_P99]), np.asarray(host_p99))


def test_run_stream_batch_2d_engine_parity():
    """engine.run_stream_batch with a (T, C) leading batch == the vmap²
    jax engine per stream, and its ClientMerge equals the policy_core
    twins — the engine-layer contract the simulator's per_client kernel
    dispatch rides on (trace shared per trial)."""
    t, c, m, r, win = 2, 3, 25, 48, 16
    trace = _transient_trace(m, slow_ids=(3,))
    cfg = LogConfig(n_servers=m, lam=50.0)
    rng = np.random.default_rng(13)
    works = Workload(
        jnp.asarray(rng.integers(0, 8 * m, (t, c, r)), jnp.int32),
        jnp.asarray(rng.uniform(1.0, 20.0, (t, c, r)), jnp.float32),
        jnp.asarray(rng.random((t, c, r)) > 0.1))
    state = statlog.init_state(cfg, rates=trace.rates[0])
    states = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (t, c) + a.shape), state)
    traces = jax.tree.map(lambda a: jnp.broadcast_to(a, (t,) + a.shape),
                          trace)
    keys = jax.random.split(jax.random.key(5), t * c).reshape(t, c)
    pol = PolicyConfig(name="trh", threshold=0.05, rng="lcg")
    batch, metrics, merged = engine.run_stream_batch(
        states, works, keys, policy=pol, log_cfg=cfg, window_size=win,
        traces=traces, window_dt=0.04, observe=True, client_tile=2)
    assert metrics.shape == (t, c, policy_core.N_METRICS)

    def one(st, w, k):
        return engine.run_stream(st, w, k, policy=pol, log_cfg=cfg,
                                 window_size=win, trace=trace,
                                 window_dt=0.04, observe=True,
                                 backend="jax")
    eng = jax.vmap(jax.vmap(one))(states, works, keys)
    for f in ("chosen", "latencies", "redirected", "window_loads"):
        np.testing.assert_array_equal(np.asarray(getattr(batch, f)),
                                      np.asarray(getattr(eng, f)),
                                      err_msg=f)
    np.testing.assert_array_equal(np.asarray(batch.state.n_assigned),
                                  np.asarray(eng.state.n_assigned))
    cvalid = jnp.any(works.valid, axis=-1)
    np.testing.assert_array_equal(
        np.asarray(merged.window_loads_mean),
        np.asarray(jax.vmap(
            lambda w, v: policy_core.masked_client_mean(w, v, 2))(
            batch.window_loads, cvalid)))


def test_mlml_kernel_pairs_longest_with_lightest():
    """Behavioural: with a uniform prior, MLML through the kernel pairs
    the longest request of the window with the lightest (lowest-index)
    server — Alg. 1's circular positional pairing, same as the engine."""
    m, win = 8, 8
    lens = jnp.asarray([3.0, 9.0, 1.0, 7.0, 5.0, 2.0, 8.0, 4.0],
                       jnp.float32)
    obj = jnp.arange(win, dtype=jnp.int32)
    table = statlog.init_state(LogConfig(n_servers=m, lam=1e9)).log
    ch, _, _, _ = sched_stream(
        obj, lens, jnp.ones((win,), bool), table, jnp.uint32(0),
        jnp.ones((1, m), jnp.float32), n_servers=m, window_size=win,
        threshold=-1e9, lam=1e9, window_dt=0.0, policy="mlml",
        observe=False, renorm=False)
    # uniform probs -> sorted_servers = [0..M); k-th longest -> server k
    order = np.argsort(-np.asarray(lens), kind="stable")
    expect = np.empty(win, np.int32)
    expect[order] = np.arange(win)
    np.testing.assert_array_equal(np.asarray(ch), expect)


def test_stream_kernel_avoids_transient_straggler():
    """Behavioural check: during the slow phase of a transient trace, ECT
    (kernel backend) steers work away from the straggler."""
    m, r, win = 24, 360, 60
    trace = _transient_trace(m, slow_ids=(5,), onset=0.02, recover=0.5,
                             factor=16.0)
    cfg = LogConfig(n_servers=m, lam=50.0)
    state = statlog.init_state(cfg, rates=trace.rates[0])
    work = _stream_case(m, r, seed=11)
    res = engine.run_stream(state, work, jax.random.key(0),
                            policy=PolicyConfig(name="ect", threshold=0.05),
                            log_cfg=cfg, window_size=win, trace=trace,
                            window_dt=0.1, backend="kernel")
    chosen = np.asarray(res.chosen)
    # slow phase covers windows 1..2 (onset 2% .. recovery 50% of 0.6s)
    mid = chosen[win:3 * win]
    frac_mid = float((mid == 5).sum()) / len(mid)
    assert frac_mid < 1.0 / m, frac_mid  # well under the uniform share
