"""Per-architecture smoke tests: reduced configs, one forward/train step
on CPU, asserting output shapes + finite values (assignment requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.data import DataConfig, SyntheticTokens
from repro.models import encdec as E
from repro.models import transformer as T
from repro.train import OptConfig, init_state, make_train_step


def _batch_for(cfg, b=2, s=16, seed=0):
    pipe = SyntheticTokens(DataConfig(vocab_size=cfg.vocab_size, seq_len=s,
                                      global_batch=b, seed=seed))
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    if cfg.enc_dec:
        batch["frames"] = jax.random.normal(
            jax.random.key(1), (b, cfg.enc_seq, cfg.d_model))
    if cfg.mrope:
        pos = jnp.broadcast_to(jnp.arange(s), (b, s))
        batch["positions"] = jnp.broadcast_to(pos, (3, b, s))
        batch["patch_embeds"] = jax.random.normal(
            jax.random.key(2), (b, s // 2, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_forward_shapes_and_finite(arch):
    cfg = get_config(arch, reduced=True)
    b, s = 2, 16
    batch = _batch_for(cfg, b, s)
    key = jax.random.key(0)
    if cfg.enc_dec:
        params = E.init_encdec(key, cfg)
        logits, _ = jax.jit(lambda p, x: E.forward_train(p, x, cfg))(
            params, batch)
    else:
        params = T.init_lm(key, cfg)
        logits, _ = jax.jit(lambda p, x: T.forward_train(p, x, cfg))(
            params, batch)
    assert logits.shape == (b, s, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_one_train_step(arch):
    cfg = get_config(arch, reduced=True)
    state = init_state(jax.random.key(0), cfg)
    step = jax.jit(make_train_step(cfg, OptConfig(peak_lr=1e-3,
                                                  warmup_steps=2,
                                                  total_steps=10)))
    batch = _batch_for(cfg, 2, 16)
    state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), (arch, loss)
    assert int(state.step) == 1
    gnorm = float(metrics["grad_norm"])
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", ["qwen2-72b", "mixtral-8x22b",
                                  "jamba-v0.1-52b", "xlstm-1.3b",
                                  "gemma-2b"])
def test_arch_decode_matches_train(arch):
    """Greedy decode logits equal the teacher-forced forward (reduced)."""
    import dataclasses
    cfg = dataclasses.replace(get_config(arch, reduced=True),
                              compute_dtype="float32")
    if cfg.moe is not None:
        # train-time capacity drops don't exist on the decode path; give
        # the test headroom so both paths route identically
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    b, s = 2, 12
    params = T.init_lm(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (b, s), 1,
                                cfg.vocab_size, dtype=jnp.int32)
    ref, _ = T.forward_train(params, {"tokens": tokens}, cfg)
    caches = T.init_caches(cfg, b, s)
    dec = jax.jit(lambda p, c, t, i: T.decode_step(p, c, t, i, cfg))
    outs = []
    for t in range(s):
        lg, caches = dec(params, caches, tokens[:, t:t + 1], t)
        outs.append(lg)
    got = jnp.concatenate(outs, axis=1)
    err = float(jnp.max(jnp.abs(got - ref)))
    assert err < 2e-2, (arch, err)


def test_param_count_analytic_close_to_actual():
    """cfg.param_count() bookkeeping tracks the real init tree (full-size
    formulas validated on reduced instantiations)."""
    for arch in ARCH_IDS:
        cfg = get_config(arch, reduced=True)
        init_fn = E.init_encdec if cfg.enc_dec else T.init_lm
        params = jax.eval_shape(lambda k: init_fn(k, cfg),
                                jax.random.key(0))
        actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
        analytic = cfg.param_count()
        # padded vocab + minor bookkeeping slack
        assert abs(actual - analytic) / actual < 0.30, \
            (arch, actual, analytic)


def test_moe_aux_losses_reported():
    cfg = get_config("mixtral-8x22b", reduced=True)
    state = init_state(jax.random.key(0), cfg)
    step = jax.jit(make_train_step(cfg, OptConfig()))
    _, metrics = step(state, _batch_for(cfg))
    assert float(metrics["lb_loss"]) > 0
    assert 0.0 <= float(metrics["moe_dropped"]) < 1.0


def test_vlm_patch_embedding_stub_changes_logits():
    cfg = get_config("qwen2-vl-72b", reduced=True)
    params = T.init_lm(jax.random.key(0), cfg)
    batch = _batch_for(cfg, 2, 16)
    l1, _ = T.forward_train(params, batch, cfg)
    batch2 = dict(batch)
    batch2["patch_embeds"] = batch["patch_embeds"] * 2.0
    l2, _ = T.forward_train(params, batch2, cfg)
    assert float(jnp.max(jnp.abs(l1 - l2))) > 0
