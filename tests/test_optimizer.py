"""Optimizer + gradient compression unit/property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.train import OptConfig, compression as C, optimizer as O


def test_lr_schedule_shape():
    cfg = OptConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                    min_lr_ratio=0.1)
    lrs = [float(O.lr_at(cfg, jnp.asarray(s))) for s in range(0, 101, 5)]
    assert lrs[0] == 0.0
    assert max(lrs) == pytest.approx(1.0, rel=1e-2)
    assert lrs[-1] == pytest.approx(0.1, rel=1e-2)
    assert all(b <= a + 1e-6 for a, b in zip(lrs[2:], lrs[3:]))  # decays


def test_clip_by_global_norm():
    g = {"a": jnp.ones((4,)) * 3.0, "b": jnp.ones((4,)) * 4.0}
    clipped, norm = O.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(10.0)
    assert float(O.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_weight_decay_mask():
    assert O._decay_mask("groups/pos_0/attn/wq")
    assert not O._decay_mask("groups/pos_0/attn_norm/scale")
    assert not O._decay_mask("groups/pos_0/attn/bq")


def test_adamw_moves_params_and_counts():
    params = {"w": jnp.ones((8, 8)), "norm": {"scale": jnp.zeros((8,))}}
    st_ = O.init(params)
    grads = jax.tree.map(jnp.ones_like, params)
    cfg = OptConfig(peak_lr=0.1, warmup_steps=0, total_steps=10)
    new, st2, m = O.update(cfg, grads, st_, params)
    assert int(st2.count) == 1
    assert float(jnp.abs(new["w"] - params["w"]).max()) > 0
    assert np.isfinite(float(m["grad_norm"]))


@given(st.integers(0, 2**31 - 1))
def test_quantize_error_feedback_identity(seed):
    """q*scale + residual == g + e exactly (error feedback invariant)."""
    g = jax.random.normal(jax.random.key(seed), (256,)) * 10
    e = jax.random.normal(jax.random.key(seed + 1), (256,)) * 0.1
    q, scale, new_e = C.quantize(g, e)
    recon = C.dequantize(q, scale) + new_e
    np.testing.assert_allclose(np.asarray(recon), np.asarray(g + e),
                               rtol=1e-5, atol=1e-5)
    assert q.dtype == jnp.int8
    assert float(jnp.abs(new_e).max()) <= float(scale) * 0.5 + 1e-6


def test_wire_bytes_savings():
    g = {"w": jnp.zeros((1000, 100))}
    assert C.wire_bytes(g, compressed=True) * 4 == \
        C.wire_bytes(g, compressed=False)
