"""Tests for the §Perf features adopted from the hillclimbs."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.statlog import HostStatLog, LogConfig
from repro.models import moe as MOE
from repro.models import transformer as T
from repro.models.config import ModelConfig, MoEConfig


def test_absorb_loads_orders_probs_by_load():
    log = HostStatLog(LogConfig(n_servers=4, lam=10.0))
    log.absorb_loads(np.array([0.0, 5.0, 50.0, 500.0]))
    assert np.all(np.diff(log.probs) < 0)          # monotone decreasing
    assert abs(log.probs.sum() - 1.0) < 1e-12


def test_prob_refresh_restores_ranking_after_drift():
    """Eq. (2) incremental decay drifts the ranking; absorb_loads fixes it
    (the §Perf C finding)."""
    log = HostStatLog(LogConfig(n_servers=3, lam=16.0))
    log.loads[2] = 40.0  # lightly-assigned straggler
    log.absorb_loads()
    for _ in range(30):  # busy clean server 0
        log.apply_assignment(0, 4.0)
        log.complete(0, 4.0)  # drained: true load stays ~0
    assert log.probs[0] < log.probs[2]  # DRIFT: busy clean < straggler
    log.absorb_loads()                   # memoryless refresh
    assert log.probs[0] > log.probs[2]   # ranking restored


def test_moe_local_dispatch_matches_global_when_nothing_drops():
    cfg = ModelConfig(name="m", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=128, vocab_size=256,
                      moe=MoEConfig(n_experts=4, top_k=2,
                                    capacity_factor=8.0),
                      compute_dtype="float32")
    p = MOE.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (4, 8, 64))
    y_g, aux_g = MOE.apply_moe(p, x, cfg)
    for dp in (2, 4):
        y_l, aux_l = MOE._apply_moe_local(p, x, cfg, dp=dp)
        np.testing.assert_allclose(np.asarray(y_g), np.asarray(y_l),
                                   atol=1e-4)
        assert float(aux_l.dropped_fraction) == 0.0


def test_moe_local_falls_back_without_mesh():
    """dispatch="local" with no mesh rules (CPU tests) == global path."""
    cfg = ModelConfig(name="m", n_layers=2, d_model=32, n_heads=4,
                      n_kv_heads=2, d_ff=64, vocab_size=128,
                      moe=MoEConfig(n_experts=4, top_k=1,
                                    dispatch="local"),
                      compute_dtype="float32")
    cfg_g = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch="global"))
    p = MOE.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 8, 32))
    y_l, _ = MOE.apply_moe(p, x, cfg)
    y_g, _ = MOE.apply_moe(p, x, cfg_g)
    np.testing.assert_array_equal(np.asarray(y_l), np.asarray(y_g))


def test_llama4_group4_pattern_shrinks_caches():
    """The adopted cache4 topology sizes local positions at chunk length."""
    from repro.configs import get_config
    cfg = get_config("llama4-scout-17b-a16e")
    assert cfg.group_pattern == ("attn",) * 4
    caches = jax.eval_shape(lambda: T.init_caches(cfg, 1, 32768))
    sizes = {k: v["k"].shape[2] for k, v in caches.items()}
    assert sizes["pos_3"] == 32768       # global every 4th layer
    assert sizes["pos_0"] == 8192        # chunk-local ring
    assert sizes["pos_1"] == 8192 and sizes["pos_2"] == 8192


def test_bf16_score_dtype_close_to_f32():
    cfg32 = ModelConfig(name="a", n_layers=2, d_model=64, n_heads=4,
                        n_kv_heads=2, d_ff=128, vocab_size=256,
                        compute_dtype="float32")
    cfg16 = dataclasses.replace(cfg32, attn_score_dtype="bfloat16")
    params = T.init_lm(jax.random.key(0), cfg32)
    tok = jax.random.randint(jax.random.key(1), (2, 16), 0, 256)
    l32, _ = T.forward_train(params, {"tokens": tok}, cfg32)
    l16, _ = T.forward_train(params, {"tokens": tok}, cfg16)
    # bf16 scores cost ~2-3 decimal digits, not correctness
    assert float(jnp.max(jnp.abs(l32 - l16))) < 0.15


def test_int8_kv_cache_decode_parity():
    """int8 cache decode stays within quantization noise of bf16 and
    agrees on greedy tokens (the §Perf serving iteration)."""
    cfg = ModelConfig(name="q", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=128, vocab_size=256,
                      compute_dtype="float32")
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    b, s = 2, 10
    params = T.init_lm(jax.random.key(0), cfg)
    tok = jax.random.randint(jax.random.key(1), (b, s), 1, 256,
                             dtype=jnp.int32)

    def run(c):
        caches = T.init_caches(c, b, s)
        outs = []
        for t in range(s):
            lg, caches = T.decode_step(params, caches, tok[:, t:t + 1],
                                       t, c)
            outs.append(lg)
        return jnp.concatenate(outs, 1)

    l16, l8 = run(cfg), run(cfg8)
    assert float(jnp.max(jnp.abs(l16 - l8))) < 0.25
    assert float(jnp.mean(jnp.argmax(l8, -1) == jnp.argmax(l16, -1))) > 0.9
    # storage really is int8 + scales
    c8 = T.init_caches(cfg8, b, s)
    assert c8["pos_0"]["k"].dtype == jnp.int8
    assert c8["pos_0"]["k_scale"].dtype == jnp.float32
