"""Scheduling-policy semantics: JAX engine vs host twin, paper examples."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, statlog
from repro.core.engine import Workload
from repro.core.policies import HostScheduler, PolicyConfig
from repro.core.statlog import HostStatLog, LogConfig


def _run_jax(policy, obj, lens, m=8, lam=32.0, threshold=0.0, seed=0,
             init_loads=None, group=False):
    cfg = LogConfig(n_servers=m, lam=lam)
    state = statlog.init_state(cfg, init_loads)
    work = Workload(jnp.asarray(obj, jnp.int32),
                    jnp.asarray(lens, jnp.float32),
                    jnp.ones((len(obj),), bool))
    res = engine.run_window(state, work, jax.random.key(seed),
                            policy=PolicyConfig(name=policy,
                                                threshold=threshold),
                            log_cfg=cfg, group_steps=group)
    return res


def test_rr_is_object_mod_m():
    obj = [0, 5, 9, 13, 21]
    res = _run_jax("rr", obj, [1.0] * 5, m=4)
    np.testing.assert_array_equal(np.asarray(res.chosen),
                                  np.asarray(obj) % 4)
    assert int(res.probe_msgs) == 0


def test_mlml_pairs_longest_with_lightest():
    """Alg. 1: longest request -> highest-prob (lightest) server."""
    m = 4
    init = jnp.asarray([10.0, 0.0, 20.0, 30.0])
    lens = [5.0, 50.0, 1.0]          # sorted desc: 50, 5, 1
    obj = [0, 1, 2]
    res = _run_jax("mlml", obj, lens, m=m, lam=16.0, threshold=0.0,
                   init_loads=init)
    # init probs equal -> after absorb? run_window absorbs nothing: probs
    # uniform; sorted_servers order is argsort(-p) = stable = [0,1,2,3].
    # With uniform probs MLML degenerates to positional pairing.
    assert res.chosen.shape == (3,)


def test_mlml_positional_pairing_with_decayed_probs():
    m = 4
    cfg = LogConfig(n_servers=m, lam=8.0)
    state = statlog.init_state(cfg)
    # load server 0 heavily, 1 lightly -> probs: 2,3 > 1 > 0
    state = statlog.apply_assignment(state, jnp.asarray(0),
                                     jnp.asarray(40.0), cfg)
    state = statlog.apply_assignment(state, jnp.asarray(1),
                                     jnp.asarray(4.0), cfg)
    work = Workload(jnp.asarray([0, 1, 2], jnp.int32),
                    jnp.asarray([9.0, 1.0, 5.0], jnp.float32),
                    jnp.ones((3,), bool))
    res = engine.run_window(state, work, jax.random.key(0),
                            policy=PolicyConfig(name="mlml",
                                                threshold=1e9),
                            log_cfg=cfg, group_steps=False)
    # threshold huge -> always falls back to default RR homes
    np.testing.assert_array_equal(np.asarray(res.chosen), [0, 1, 2])
    assert not bool(res.redirected.any())


def test_trh_picks_from_light_half_and_respects_threshold():
    m = 8
    init = jnp.asarray([0.0, 1.0, 2.0, 3.0, 100.0, 101.0, 102.0, 103.0])
    obj = [4, 5, 6, 7] * 5          # defaults all in the heavy half
    res = _run_jax("trh", obj, [4.0] * 20, m=m, threshold=10.0,
                   init_loads=init)
    chosen = np.asarray(res.chosen)
    assert (chosen < 4).all(), chosen  # redirected into the light half
    assert int(res.probe_msgs) == 0


def test_two_choice_counts_probes():
    res = _run_jax("two_choice", list(range(10)), [1.0] * 10, m=8)
    assert int(res.probe_msgs) == 20  # 2 per request (SC'14 baseline)


def test_nltr_sections_spread_requests():
    m = 16
    init = jnp.arange(16, dtype=jnp.float32) * 10
    lens = [100.0, 90.0, 50.0, 40.0, 5.0, 4.0, 3.0, 2.0]
    res = _run_jax("nltr", list(range(8)), lens, m=m, threshold=0.0,
                   init_loads=init, lam=200.0)
    assert res.chosen.shape == (8,)
    assert int(res.probe_msgs) == 0


def test_ect_uses_observed_rates():
    m = 3
    cfg = LogConfig(n_servers=m)
    state = statlog.init_state(cfg)
    # same loads everywhere, but server 2 observed 10x faster.  ECT reads
    # the est_rates row, which only observations write (stale-view
    # contract) — so seed it through observe_completion.
    state = state.with_rows(loads=jnp.asarray([10.0, 10.0, 10.0]))
    for srv, rate in ((0, 1.0), (1, 1.0), (2, 10.0)):
        state = statlog.observe_completion(state, jnp.asarray(srv),
                                           jnp.asarray(rate), cfg)
    work = Workload(jnp.asarray([0], jnp.int32), jnp.asarray([1.0]),
                    jnp.ones((1,), bool))
    res = engine.run_window(state, work, jax.random.key(0),
                            policy=PolicyConfig(name="ect", threshold=-1e9),
                            log_cfg=cfg, group_steps=False)
    assert int(res.chosen[0]) == 2


def test_host_scheduler_matches_engine_rr_mlml():
    """Deterministic policies agree between host twin and jitted engine."""
    m, n = 6, 24
    rng = np.random.default_rng(0)
    obj = rng.integers(0, 100, n).tolist()
    lens = rng.uniform(1, 30, n).tolist()
    for policy in ("rr", "mlml"):
        res = _run_jax(policy, obj, lens, m=m, lam=32.0, threshold=2.0)
        host = HostScheduler(PolicyConfig(name=policy, threshold=2.0),
                             HostStatLog(LogConfig(n_servers=m, lam=32.0)))
        host.begin_window(lens)
        # engine processes mlml in length-desc order; replay identically
        order = np.argsort([-l for l in lens], kind="stable") \
            if policy == "mlml" else np.arange(n)
        got = np.empty(n, np.int64)
        for pos, idx in enumerate(order):
            got[idx] = host.schedule(obj[idx], lens[idx])
        np.testing.assert_array_equal(np.asarray(res.chosen), got, policy)


def test_ect_completion_feedback_parity_jax_vs_host():
    """Temporal model: the ECT completion-feedback path agrees between the
    jitted engine and the real IOClient when both run the SAME ClusterTrace.

    One request per window, no draining (window_dt=0) and no flush, so the
    observation cadence is identical: schedule -> complete -> observe.  The
    engine's estimated latency (loads_after / rate) must equal the queueing
    cluster's WriteResult.seconds, hence identical ewma_lat and identical
    chosen servers over the whole stream.
    """
    from repro.core.engine import ClusterTrace
    from repro.io import striping
    from repro.io.client import IOClient, IOClientConfig

    m, n, base = 8, 40, 100.0
    rates = np.full(m, base)
    rates[[2, 5]] = base / 8.0          # permanent slow-service stragglers
    trace = ClusterTrace(times=jnp.zeros((1,), jnp.float32),
                         rates=jnp.asarray(rates, jnp.float32)[None])
    rng = np.random.default_rng(7)
    lens = rng.integers(2, 11, n).astype(np.float64)  # whole MB: f32-exact

    # -- JAX path: one request per window over the trace -------------------
    log_cfg = LogConfig(n_servers=m, lam=64.0)
    state = statlog.init_state(log_cfg, rates=jnp.asarray(rates))
    obj = [striping.object_id_for(f, 0) % m for f in range(n)]
    work = Workload(jnp.asarray(obj, jnp.int32),
                    jnp.asarray(lens, jnp.float32), jnp.ones((n,), bool))
    res = engine.run_stream(state, work, jax.random.key(0),
                            policy=PolicyConfig(name="ect", threshold=0.01),
                            log_cfg=log_cfg, window_size=1,
                            group_steps=False, trace=trace, window_dt=0.0)

    # -- host path: IOClient over a SimulatedCluster on the same trace ----
    from repro.io.objectstore import SimulatedCluster
    sim = SimulatedCluster(m, base_rate_mb_s=base, trace=trace)
    cli = IOClient(sim, IOClientConfig(
        policy=PolicyConfig(name="ect", threshold=0.01),
        stripe_size=16 * striping.MB, lam_mb=64.0))
    for f in range(n):
        cli.write_file(f, size_mb=float(lens[f]))     # single-object files

    host_chosen = np.asarray([r.server for r in cli.records])
    jax_chosen = np.asarray(res.chosen)
    # Discovery phase (every server tried once, stragglers observed) must
    # agree exactly.  Beyond it, ECT *equalizes* completion-time scores
    # across the healthy servers, so the argmin rides on sub-epsilon
    # float32-vs-float64 noise — we assert the semantically meaningful
    # invariants instead of bitwise equality of symmetric-server swaps.
    np.testing.assert_array_equal(jax_chosen[:10], host_chosen[:10])
    # both paths hit the slow servers at IDENTICAL positions (no tie there:
    # an observed straggler's score is distinctly worse)
    strag = np.isin(jax_chosen, (2, 5))
    np.testing.assert_array_equal(strag, np.isin(host_chosen, (2, 5)))
    np.testing.assert_array_equal(jax_chosen[strag], host_chosen[strag])
    # per-server landing counts agree up to symmetric near-tie swaps
    cj = np.bincount(jax_chosen, minlength=m)
    ch = np.bincount(host_chosen, minlength=m)
    assert np.abs(cj - ch).max() <= 2, (cj, ch)
    # the observed quantity is the same: wherever the choices agree, the
    # engine's estimated latency equals the cluster's WriteResult.seconds
    agree = jax_chosen == host_chosen
    secs = np.asarray([r.seconds for r in cli.records])
    np.testing.assert_allclose(np.asarray(res.latencies)[agree],
                               secs[agree], rtol=1e-4)
    # slow servers are VISIBLE in the JAX path now: ewma near the true
    # slow service rate on both sides
    ewma = np.asarray(res.state.ewma_lat)
    host_ewma = np.asarray(cli.log.ewma_lat)
    assert (ewma > 0).all() and (host_ewma > 0).all()
    for s in (2, 5):
        assert ewma[s] <= base / 8.0 + 1e-3
        np.testing.assert_allclose(ewma[s], host_ewma[s], rtol=1e-3)


def test_probe_accounting_derives_from_probe_choices():
    """Satellite fix: engine probe accounting and the host twin's counter
    both derive from PolicyConfig.probe_choices — no more hard-coded 2."""
    from repro.core.policies import PROBES_PER_REQUEST
    n, m = 10, 8
    obj = list(range(n))            # unique objects: no grouping merges
    lens = [1.0] * n
    for k in (2, 3, 5):
        pol = PolicyConfig(name="two_choice", probe_choices=k)
        assert pol.probes_per_request == k
        res = _run_jax("two_choice", obj, lens, m=m)._replace()  # warm
        res = engine.run_window(
            statlog.init_state(LogConfig(n_servers=m)),
            Workload(jnp.asarray(obj, jnp.int32),
                     jnp.asarray(lens, jnp.float32), jnp.ones((n,), bool)),
            jax.random.key(0), policy=pol,
            log_cfg=LogConfig(n_servers=m))
        assert int(res.probe_msgs) == n * k
        host = HostScheduler(pol, HostStatLog(LogConfig(n_servers=m)))
        host.begin_window(lens)
        for o, ln in zip(obj, lens):
            host.schedule(o, ln)
        assert host.probe_messages == n * k
    # log-assisted policies never probe, whatever probe_choices says
    for name in ("rr", "mlml", "trh", "nltr", "ect"):
        assert PolicyConfig(name=name,
                            probe_choices=7).probes_per_request == 0
        assert PROBES_PER_REQUEST[name] == 0
    # the paper-default config still matches the documented table
    assert PolicyConfig(name="two_choice").probes_per_request == \
        PROBES_PER_REQUEST["two_choice"]


def test_est_row_lags_true_rate_and_layers_agree():
    """Acceptance: when a straggler's TRUE rate drops mid-stream, the
    client-estimated row lags it (stale by construction), and the kernel,
    engine, and host client all rank servers identically on est_rates."""
    from repro.core.engine import ClusterTrace
    from repro.io.client import IOClient, IOClientConfig
    from repro.io.objectstore import SimulatedCluster
    from repro.io import striping

    m, base, slow_f = 8, 100.0, 10.0
    strag = 2
    slow = np.full(m, base, np.float32)
    slow[strag] = base / slow_f
    # rate drops a quarter of the way in and STAYS slow
    trace = ClusterTrace(times=jnp.asarray([0.0, 1.0], jnp.float32),
                         rates=jnp.asarray(np.stack(
                             [np.full(m, base, np.float32), slow])))
    log_cfg = LogConfig(n_servers=m, lam=64.0)
    rng = np.random.default_rng(3)
    n = 64
    lens = rng.integers(2, 9, n).astype(np.float64)
    obj = [striping.object_id_for(f, 0) % m for f in range(n)]
    work = Workload(jnp.asarray(obj, jnp.int32),
                    jnp.asarray(lens, jnp.float32), jnp.ones((n,), bool))
    pol = PolicyConfig(name="ect", threshold=0.01)
    state = statlog.init_state(log_cfg, rates=trace.rates[0])
    kw = dict(policy=pol, log_cfg=log_cfg, window_size=8,
              group_steps=False, trace=trace, window_dt=0.5)
    eng = engine.run_stream(state, work, jax.random.key(0), backend="jax",
                            **kw)
    ker = engine.run_stream(state, work, jax.random.key(0),
                            backend="kernel", **kw)

    # host path: IOClient over the queueing cluster on the same trace
    sim = SimulatedCluster(m, base_rate_mb_s=base, trace=trace)
    cli = IOClient(sim, IOClientConfig(policy=pol,
                                       stripe_size=16 * striping.MB,
                                       lam_mb=64.0))
    for f in range(n):
        cli.write_file(f, size_mb=float(lens[f]))
        sim.advance_time(0.0625)            # writes spread over the trace

    true_rate = base / slow_f
    for est in (np.asarray(eng.state.est_rates),
                np.asarray(ker.state.est_rates),
                cli.log.est_rates):
        # the estimated row LAGS the true drop: below the healthy rate
        # (the drop is visible) but still above the true slow rate (the
        # EWMA hasn't fully converged — stale by construction)...
        assert true_rate < est[strag] < base, est
        # ...and every layer ranks the straggler slowest on est_rates
        assert int(np.argmin(est)) == strag, est
    # engine and kernel see BIT-IDENTICAL estimated rows
    np.testing.assert_array_equal(np.asarray(eng.state.est_rates),
                                  np.asarray(ker.state.est_rates))


def test_masking_failed_servers():
    host = HostScheduler(PolicyConfig(name="trh", threshold=0.0),
                         HostStatLog(LogConfig(n_servers=4)))
    host.mask_server(0)
    host.mask_server(1)
    host.begin_window()
    for i in range(20):
        s = host.schedule(i, 1.0)
        assert s in (2, 3)
    host.unmask_server(0)
    assert 0 not in host.masked_servers


# ---------------------------------------------------------------------------
# In-VMEM sort contract (DESIGN.md §10): the kernel's bitonic network
# computes THE unique stable permutation, so the engine's backend argsort
# may stand in for it on the hot path.
# ---------------------------------------------------------------------------

from repro.core import policy_core  # noqa: E402


def test_bitonic_argsort_equals_stable_argsort():
    """The (key desc, index asc) comparator is a strict total order: the
    bitonic compare-exchange network and stable argsort have exactly one
    common answer — across sizes, heavy ties, invalid masks, and both
    xp twins.  This equality is what lets plan_window keep jnp.argsort
    while the Pallas kernel sorts in-VMEM (DESIGN.md §10)."""
    rng = np.random.default_rng(3)
    for r in (1, 2, 3, 17, 60, 100, 128):
        for tie_pool in (None, 4):
            if tie_pool is None:
                keys = rng.uniform(0.0, 50.0, r).astype(np.float32)
            else:  # heavy ties exercise the index tiebreak
                keys = rng.choice(np.linspace(0, 3, tie_pool),
                                  r).astype(np.float32)
            valid = rng.random(r) > 0.3
            ref = np.argsort(-np.where(valid, keys, -np.inf), kind="stable")
            got_np, _ = policy_core.bitonic_argsort_desc(keys, valid=valid,
                                                         xp=np)
            got_jnp, skeys = policy_core.bitonic_argsort_desc(
                jnp.asarray(keys), valid=jnp.asarray(valid))
            np.testing.assert_array_equal(got_np[:r], ref, err_msg=str(r))
            np.testing.assert_array_equal(np.asarray(got_jnp)[:r], ref)
            # sorted keys descend over the valid prefix, -inf elsewhere
            sk = np.asarray(skeys)[:valid.sum()]
            assert (np.diff(sk) <= 0).all()


def test_recursive_average_bounds_batched_matches_engine_form():
    """The kernel evaluates the nLTR section bounds on (t_tile, R_pad)
    tiles; the engine on one (R,) row.  Same integers, any batch."""
    rng = np.random.default_rng(5)
    t, ws = 5, 60
    lens = rng.uniform(0.5, 30.0, (t, ws)).astype(np.float32)
    valid = rng.random((t, ws)) > 0.25
    for n in (1, 2, 3):
        rows = []
        for i in range(t):
            order, skeys = policy_core.bitonic_argsort_desc(
                jnp.asarray(lens[i]), valid=jnp.asarray(valid[i]))
            nv = jnp.asarray([valid[i].sum()], jnp.int32)
            rows.append(np.asarray(policy_core.recursive_average_bounds(
                skeys, nv, n)))
        orderb, skeysb = policy_core.bitonic_argsort_desc(
            jnp.asarray(lens), valid=jnp.asarray(valid))
        nvb = jnp.asarray(valid.sum(axis=1), jnp.int32)[:, None]
        batched = np.asarray(policy_core.recursive_average_bounds(
            skeysb, nvb, n))
        np.testing.assert_array_equal(batched, np.stack(rows), err_msg=str(n))


# ---------------------------------------------------------------------------
# Permutation-apply contract (DESIGN.md §13): the payload-carrying bitonic
# network and the inverse-permutation apply are PURE RELOCATIONS — bit-equal
# to (stable argsort + take) and to the one-hot scatter they replaced on the
# kernel's sort-policy window path.
# ---------------------------------------------------------------------------


def test_payload_bitonic_equals_stable_argsort_take():
    """Payload lanes ride the compare-exchange network under the same swap
    mask as the keys, so the sorted payloads equal payload[stable_argsort]
    element-for-element (no arithmetic touches them) — across odd sizes,
    R not a power of two, heavy duplicate keys, all-invalid windows, and
    both xp twins."""
    rng = np.random.default_rng(7)
    for r in (1, 3, 17, 33, 60, 100, 128):
        for tie_pool in (None, 3):
            if tie_pool is None:
                keys = rng.uniform(0.0, 50.0, r).astype(np.float32)
            else:  # duplicate keys: the index tiebreak must carry payloads
                keys = rng.choice(np.linspace(0, 2, tie_pool),
                                  r).astype(np.float32)
            obj = rng.integers(0, 997, r).astype(np.int32)
            vali = (rng.random(r) > 0.3).astype(np.int32)
            for valid in (vali != 0, np.zeros(r, bool)):   # + all-invalid
                ref_ord = np.argsort(-np.where(valid, keys, -np.inf),
                                     kind="stable")
                want = (obj[ref_ord], keys[ref_ord], vali[ref_ord])
                got_np = policy_core.bitonic_sort_with_payload(
                    keys, (obj, keys, vali), valid=valid, xp=np)
                got_jnp = policy_core.bitonic_sort_with_payload(
                    jnp.asarray(keys),
                    (jnp.asarray(obj), jnp.asarray(keys),
                     jnp.asarray(vali)),
                    valid=jnp.asarray(valid))
                for got in (got_np, got_jnp):
                    order, skeys, pays = got
                    np.testing.assert_array_equal(
                        np.asarray(order)[:r], ref_ord, err_msg=str(r))
                    for p, w in zip(pays, want):
                        p = np.asarray(p)
                        np.testing.assert_array_equal(p[:r], w,
                                                      err_msg=str(r))
                        # pad positions carry exact zero payloads
                        np.testing.assert_array_equal(
                            p[r:], np.zeros_like(p[r:]))


def test_bitonic_apply_inverse_equals_onehot_scatter():
    """The inverse-permutation apply (ascending bitonic pass keyed on the
    DISTINCT order integers) lands value j at position order[j] — exactly
    the one-hot scatter oracle ``out[order] = values`` — for permutations
    produced by the payload sort at odd / non-pow2 sizes, duplicate keys,
    all-invalid windows; int and float payloads, both xp twins."""
    rng = np.random.default_rng(11)
    for r in (1, 3, 17, 33, 60, 128):
        keys = rng.choice(np.linspace(0, 2, 3), r).astype(np.float32)
        for valid in ((rng.random(r) > 0.3), np.zeros(r, bool)):
            order, _, _ = policy_core.bitonic_sort_with_payload(
                keys, (), valid=valid, xp=np)
            rp = order.shape[-1]
            vf = rng.uniform(-5.0, 5.0, rp).astype(np.float32)
            vi = rng.integers(0, 100, rp).astype(np.int32)
            want_f = np.empty_like(vf)
            want_i = np.empty_like(vi)
            want_f[order] = vf                    # one-hot scatter oracle
            want_i[order] = vi
            got_np = policy_core.bitonic_apply_inverse(order, (vf, vi),
                                                       xp=np)
            got_jnp = policy_core.bitonic_apply_inverse(
                jnp.asarray(order), (jnp.asarray(vf), jnp.asarray(vi)))
            for gf, gi in (got_np, got_jnp):
                np.testing.assert_array_equal(np.asarray(gf), want_f,
                                              err_msg=str(r))
                np.testing.assert_array_equal(np.asarray(gi), want_i,
                                              err_msg=str(r))


def test_rank_desc_equals_argsort_and_network():
    """The all-pairs rank (DESIGN.md §13 hot path) is the INVERSE of the
    stable argsort(-keys) permutation, and permute_to_sorted /
    permute_from_sorted reproduce take / one-hot scatter exactly — single
    non-zero term per output lane, pure relocation even for floats.
    Pinned against the argsort oracle AND the bitonic network form
    (both compute THE unique strict-total-order permutation), across odd
    and non-pow2 sizes, duplicate keys, all-invalid windows, batched 2-D
    tiles, and both xp twins."""
    rng = np.random.default_rng(13)
    for r in (1, 3, 17, 33, 60, 100, 128):
        keys = rng.choice(np.linspace(0, 2, 3), r).astype(np.float32)
        obj = rng.integers(0, 997, r).astype(np.int32)
        lat = rng.uniform(0.0, 9.0, r).astype(np.float32)
        for valid in ((rng.random(r) > 0.3), np.zeros(r, bool)):
            ref_ord = np.argsort(-np.where(valid, keys, -np.inf),
                                 kind="stable")
            for xp, as_a in ((np, np.asarray), (jnp, jnp.asarray)):
                rank, mkeys = policy_core.rank_desc(as_a(keys),
                                                    valid=as_a(valid),
                                                    xp=xp)
                # rank == inverse of the stable argsort permutation
                inv = np.empty(r, np.int64)
                inv[ref_ord] = np.arange(r)
                np.testing.assert_array_equal(np.asarray(rank), inv,
                                              err_msg=str(r))
                # gather to sorted order == take along the argsort
                obj_s, key_s = policy_core.permute_to_sorted(
                    rank, (as_a(obj), mkeys), xp=xp)
                np.testing.assert_array_equal(np.asarray(obj_s),
                                              obj[ref_ord])
                np.testing.assert_array_equal(
                    np.asarray(key_s),
                    np.where(valid, keys, -np.inf)[ref_ord])
                # network form lands the same payloads at positions < r
                _, _, (obj_net,) = policy_core.bitonic_sort_with_payload(
                    keys, (obj,), valid=valid, xp=np)
                np.testing.assert_array_equal(np.asarray(obj_s),
                                              obj_net[:r])
                # inverse apply == one-hot scatter oracle out[ord] = v
                want = np.empty_like(lat)
                want[ref_ord] = lat
                (back,) = policy_core.permute_from_sorted(
                    rank, (as_a(lat),), xp=xp)
                np.testing.assert_array_equal(np.asarray(back), want,
                                              err_msg=str(r))
    # batched 2-D tile (the kernel's (t_tile, R) shape): every stream row
    # ranks independently
    keys2 = rng.uniform(0.0, 4.0, (5, 33)).astype(np.float32)
    val2 = rng.random((5, 33)) > 0.4
    rank2, _ = policy_core.rank_desc(jnp.asarray(keys2),
                                     valid=jnp.asarray(val2))
    for i in range(5):
        ref = np.argsort(-np.where(val2[i], keys2[i], -np.inf),
                         kind="stable")
        inv = np.empty(33, np.int64)
        inv[ref] = np.arange(33)
        np.testing.assert_array_equal(np.asarray(rank2)[i], inv)
