"""Sharded sweep dispatch (parallel/sweep.py, DESIGN.md §12).

Collective-contract coverage: the sharded sweep must equal the
single-device dispatch BITWISE — per-stream outputs because the trial
axis is embarrassingly parallel (with the trial tile pinned from the
global T), merged outputs because the device axis is one more pinned
association level (`psum_tree` on top of `masked_client_sum`).

The bitwise claim is backend-scoped: the KERNEL backend carries it for
all six policies (pinned tiles make the lowering device-count
invariant); the jax engine carries it only for the lowering-insensitive
policies (ect, rr), because its sort-policy estimate math moves 1 ulp
with vmap batch size / compilation context and near-tied sort decisions
flip (DESIGN.md §12).

The multi-device tests skip at ``jax.device_count() == 1``; the CI
``multidevice`` shard runs them under
``XLA_FLAGS=--xla_force_host_platform_device_count={2,4,8}``
(tests/conftest.py deliberately does NOT force a device count — the
smoke benchmarks must see the real device).  Shapes are chosen so T and
C do NOT divide the mesh axes (padded trial shards, phantom client
shards).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import shard_map_unchecked
from repro.core import engine, policy_core, simulate, statlog
from repro.core.policies import PolicyConfig
from repro.core.simulate import SCENARIOS, ScenarioConfig, SimConfig
from repro.launch.mesh import make_sweep_mesh
from repro.parallel import sweep

DC = jax.device_count()

needs_mesh = pytest.mark.skipif(
    DC < 2, reason="needs >= 2 devices: run under "
    "XLA_FLAGS=--xla_force_host_platform_device_count=N")

# all six kernel policies; the randomized ones pin rng="lcg" so jax and
# kernel backends consume an identical randomness stream
POLICY_SPECS = (("ect", "jax", 0.05), ("rr", "jax", 5.0),
                ("mlml", "jax", 5.0), ("trh", "lcg", 5.0),
                ("nltr", "lcg", 5.0), ("two_choice", "lcg", 5.0))

# T=5 does not divide 2, 4 or 8 -> every mesh pads trial shards
BASE = dict(n_servers=16, n_requests=48, n_trials=5, window_size=16)


def _mk_policy(name, rng, thr):
    return PolicyConfig(name=name, threshold=thr, rng=rng)


def _assert_trials_equal(got, want, label, fields=None):
    for f in fields or simulate.TrialResult._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(got, f)), np.asarray(getattr(want, f)),
            err_msg=f"{label}: TrialResult.{f}")


# ---------------------------------------------------------------------------
# single-device-runnable: config validation, mesh factory, host oracles
# ---------------------------------------------------------------------------


def test_make_sweep_mesh_default_factors_device_count():
    mesh = make_sweep_mesh()
    assert mesh.axis_names == ("trials",)
    assert mesh.shape["trials"] == DC
    mesh2 = make_sweep_mesh((1, 1))
    assert mesh2.axis_names == ("trials", "clients")


def test_make_sweep_mesh_rejects_non_dividing_shape():
    bad = 3 * DC  # never divides the device count
    with pytest.raises(ValueError, match=f"jax.device_count..={DC}"):
        make_sweep_mesh((bad,))
    with pytest.raises(ValueError, match="positive device counts"):
        make_sweep_mesh((0,))
    with pytest.raises(ValueError, match="positive device counts"):
        make_sweep_mesh((1, 1, 1))


def test_simconfig_mesh_shape_validation():
    assert SimConfig(mesh_shape=None).mesh_shape is None
    # lists normalize to (hashable) tuples for the jit static arg
    assert SimConfig(mesh_shape=[1]).mesh_shape == (1,)
    with pytest.raises(ValueError, match="mesh_shape"):
        SimConfig(mesh_shape=(0,))
    with pytest.raises(ValueError, match="mesh_shape"):
        SimConfig(mesh_shape=(1, 2, 3))
    with pytest.raises(ValueError, match="client_model"):
        SimConfig(mesh_shape=(1, 2), client_model="shared_log")
    SimConfig(mesh_shape=(1, 2), client_model="per_client")  # fine


def test_sharded_client_sum_degenerates_to_masked_sum():
    """n_shards=1 must reproduce the no-mesh merge bit-for-bit."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(5, 3)).astype(np.float32)
    cv = np.array([True, True, False, True, True])
    for ct in (None, 2, 8):
        want = policy_core.masked_client_sum(
            x, cv, policy_core.resolve_client_tile(5, ct), xp=np)
        got = policy_core.sharded_client_sum(x, cv, ct, 1, xp=np)
        np.testing.assert_array_equal(got, want)


def test_sharded_client_sum_matches_manual_two_level_fold():
    """The oracle really is per-shard masked sums folded by tree_sum."""
    rng = np.random.default_rng(1)
    c, shards = 5, 2              # width 3: shard 1 gets a phantom pad
    x = rng.normal(size=(c, 4)).astype(np.float32)
    cv = np.array([True, False, True, True, True])
    w = policy_core.resolve_shard_width(c, shards)
    assert w == 3
    ct = policy_core.resolve_client_tile(w, 2)
    xp_pad = np.concatenate([x, np.zeros((1, 4), np.float32)])
    cv_pad = np.concatenate([cv, [False]])
    parts = np.stack([
        policy_core.masked_client_sum(xp_pad[:3], cv_pad[:3], ct, xp=np),
        policy_core.masked_client_sum(xp_pad[3:], cv_pad[3:], ct, xp=np)])
    want = policy_core.tree_sum(parts, 0, xp=np)[0]
    got = policy_core.sharded_client_sum(x, cv, 2, shards, xp=np)
    np.testing.assert_array_equal(got, want)


def test_resolve_shard_width():
    assert policy_core.resolve_shard_width(5, 2) == 3
    assert policy_core.resolve_shard_width(8, 4) == 2
    assert policy_core.resolve_shard_width(1, 4) == 1
    with pytest.raises(ValueError):
        policy_core.resolve_shard_width(5, 0)


# ---------------------------------------------------------------------------
# collective primitives
# ---------------------------------------------------------------------------


@needs_mesh
def test_psum_tree_matches_host_tree_sum():
    """psum_tree == all_gather + pinned tree fold == the host oracle."""
    mesh = make_sweep_mesh()
    x = jax.random.normal(jax.random.key(0), (DC, 3, 7), jnp.float32)
    f = shard_map_unchecked(
        lambda a: policy_core.psum_tree(a[0], "trials"), mesh,
        in_specs=(jax.sharding.PartitionSpec("trials"),),
        out_specs=jax.sharding.PartitionSpec())
    got = f(x)
    want = policy_core.tree_sum(x, axis=0)[0]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# trial-axis sharding: the acceptance bar — all six policies x five
# scenarios, sharded kernel == single-device kernel == single-device jax
# ---------------------------------------------------------------------------


@needs_mesh
@pytest.mark.parametrize("scenario", SCENARIOS)
@pytest.mark.parametrize("name,rng,thr", POLICY_SPECS)
def test_sharded_trials_bit_exact_vs_single_device(scenario, name, rng, thr):
    pol = _mk_policy(name, rng, thr)
    cfg_k = SimConfig(backend="kernel", scenario=ScenarioConfig(
        name=scenario), **BASE)
    log_cfg = simulate.default_log_cfg(cfg_k)
    key = jax.random.key(0)
    single_k = simulate.run_trials(key, cfg_k, pol, log_cfg)
    single_j = simulate.run_trials(
        key, dataclasses.replace(cfg_k, backend="jax"), pol, log_cfg)
    sharded = simulate.run_trials(
        key, dataclasses.replace(cfg_k, mesh_shape=(DC,)), pol, log_cfg)
    _assert_trials_equal(sharded, single_k, f"mesh=({DC},) vs kernel")
    _assert_trials_equal(sharded, single_j, f"mesh=({DC},) vs jax")


@needs_mesh
@pytest.mark.parametrize("scenario,polspec",
                         list(zip(SCENARIOS, POLICY_SPECS[:2])))
def test_sharded_jax_backend_matches_single_device(scenario, polspec):
    """The jax engine under the mesh == the jax engine on one device —
    for the lowering-insensitive policies (ect, rr).  The sort-based
    policies' estimate math is 1-ulp sensitive to the vmap BATCH SIZE
    (the jax engine has no block abstraction to pin it with, unlike the
    kernel's trial tile — DESIGN.md §12), so their device-count
    invariance is covered by the kernel-backend test above and the
    pure-partition test below."""
    pol = _mk_policy(*polspec)
    cfg = SimConfig(backend="jax", scenario=ScenarioConfig(name=scenario),
                    **BASE)
    log_cfg = simulate.default_log_cfg(cfg)
    key = jax.random.key(1)
    single = simulate.run_trials(key, cfg, pol, log_cfg)
    sharded = simulate.run_trials(
        key, dataclasses.replace(cfg, mesh_shape=(DC,)), pol, log_cfg)
    _assert_trials_equal(sharded, single, f"jax mesh=({DC},)")


@needs_mesh
@pytest.mark.parametrize("name,rng,thr", POLICY_SPECS[:2])
def test_sharded_jax_dispatch_is_pure_partition(name, rng, thr):
    """run_sweep(backend="jax") == the SAME gather-padded trial
    partition dispatched shard-by-shard WITHOUT shard_map (traces +
    window_dt threaded through): the sweep layer adds nothing beyond
    the partition.

    ect/rr only, like the test above: for the sort-based policies the
    jax engine's estimate math drifts 1 ulp with COMPILATION CONTEXT
    (vmap batch size, eager vs jit vs the shard_map-staged body — all
    verified empirically to flip near-tied sort decisions), so no
    eager- or jit-side reference reproduces the staged body's bits.
    The kernel backend's pinned tiles are what make sort policies
    device-count-invariant — the 30-case kernel test above and
    DESIGN.md §12."""
    t, n, m = 5, 24, 8
    lcfg = statlog.LogConfig(n_servers=m)
    k = jax.random.key(3)
    ko, kl, ki, kk, kt = jax.random.split(k, 5)
    works = engine.Workload(
        jax.random.randint(ko, (t, n), 0, 8 * m, dtype=jnp.int32),
        jax.random.uniform(kl, (t, n), minval=1.0, maxval=4.0),
        jnp.ones((t, n), bool))
    states = jax.vmap(lambda il: statlog.init_state(lcfg, init_loads=il))(
        jax.random.uniform(ki, (t, m), minval=5.0, maxval=15.0))
    keys = jax.random.split(kk, t)
    traces = engine.ClusterTrace(
        times=jnp.broadcast_to(jnp.array([0.0, 2.0]), (t, 2)),
        rates=jax.random.uniform(kt, (t, 2, m), minval=50.0, maxval=200.0))
    pol = _mk_policy(name, rng, thr)
    kw = dict(policy=pol, log_cfg=lcfg, window_size=8, window_dt=0.3)
    res, _, sm = sweep.run_sweep(states, works, keys, mesh_shape=(DC,),
                                 backend="jax", traces=traces, **kw)
    assert sm is None                    # (T,) batch: nothing to merge
    # manual reference: the identical gather-padded partition, each
    # shard dispatched as its own (t_loc,) batch
    t_loc = -(-t // DC)
    ar = jnp.arange(t_loc * DC)
    idx = jnp.where(ar < t, ar, 0)
    pad_s, pad_w, pad_k, pad_tr = (jax.tree.map(lambda a: a[idx], x)
                                   for x in (states, works, keys, traces))
    sl = lambda tree, s: jax.tree.map(                       # noqa: E731
        lambda a: a[s * t_loc:(s + 1) * t_loc], tree)
    # jit the reference: the shard_map body is staged out and compiled
    # as one program per device, so the apples-to-apples reference is
    # the whole-program-compiled shard, not eager op-by-op dispatch
    ref_fn = jax.jit(lambda s_, w_, k_, tr_: engine.run_stream_batch(
        s_, w_, k_, traces=tr_, backend="jax", **kw)[0])
    parts = [ref_fn(sl(pad_s, s), sl(pad_w, s), sl(pad_k, s),
                    sl(pad_tr, s))
             for s in range(DC)]
    for f in ("chosen", "latencies", "probe_msgs", "redirected"):
        ref = jnp.concatenate([getattr(p, f) for p in parts], 0)[:t]
        np.testing.assert_array_equal(
            np.asarray(getattr(res, f)), np.asarray(ref),
            err_msg=f"pure-partition: {f} ({name})")


# ---------------------------------------------------------------------------
# client-axis sharding: the two-level merge association vs. the oracle
# ---------------------------------------------------------------------------


def _client_mesh_shape():
    """(t_dev, 2) using all devices — 2 client shards of C=5 (phantom
    pad on the last shard)."""
    return (DC // 2, 2) if DC % 2 == 0 else (DC, 1)


def _synthetic_grid(t=3, c=5, per=8, m=5, ws=4):
    lcfg = statlog.LogConfig(n_servers=m)
    k = jax.random.key(7)
    ko, kl, kk, ki = jax.random.split(k, 4)
    obj = jax.random.randint(ko, (t, c, per), 0, 8 * m, dtype=jnp.int32)
    lens = jax.random.uniform(kl, (t, c, per), minval=1.0, maxval=4.0)
    valid = jnp.ones((t, c, per), bool)
    valid = valid.at[:, -1, :].set(False)       # whole phantom client
    valid = valid.at[:, 1, per // 2:].set(False)  # partial client
    works = engine.Workload(obj, lens, valid)
    ils = jax.random.uniform(ki, (t, m), minval=10.0, maxval=20.0)
    states = jax.vmap(lambda il: statlog.init_state(lcfg, init_loads=il))(ils)
    states = jax.tree.map(
        lambda a: jnp.broadcast_to(a[:, None], (t, c) + a.shape[1:]), states)
    keys = jax.vmap(lambda kk_: jax.random.split(kk_, c))(
        jax.random.split(kk, t))
    return lcfg, works, states, keys, ws


@needs_mesh
@pytest.mark.parametrize("backend", ["kernel", "jax"])
def test_run_sweep_client_axis_merge_matches_oracle(backend):
    """C=5 over 2 client shards: merged rows == the two-level host
    oracle `sharded_client_sum/mean`; per-stream outputs == the no-mesh
    jax dispatch of the same (T, C) batch; order-free merges (phase
    max, integer probe sum) == the no-mesh values."""
    mesh_shape = _client_mesh_shape()
    lcfg, works, states, keys, ws = _synthetic_grid()
    pol = PolicyConfig(name="ect", threshold=0.05)
    kw = dict(policy=pol, log_cfg=lcfg, window_size=ws)
    res, _, smerge = sweep.run_sweep(states, works, keys,
                                     mesh_shape=mesh_shape,
                                     backend=backend, **kw)
    # per-stream comparator: the jax engine is shape-independent, so the
    # no-mesh jax dispatch is its bitwise reference; the kernel backend
    # re-tiles streams per device, so ITS bitwise reference is the jax
    # engine under the SAME mesh (same shard shapes — the §11 per-shape
    # kernel==jax contract); merged rows are held to the exact host
    # oracle either way
    if backend == "jax":
        ref, _, _ = engine.run_stream_batch(states, works, keys,
                                            backend="jax", **kw)
    else:
        ref, _, _ = sweep.run_sweep(states, works, keys,
                                    mesh_shape=mesh_shape,
                                    backend="jax", **kw)
    np.testing.assert_array_equal(np.asarray(res.chosen),
                                  np.asarray(ref.chosen))
    np.testing.assert_array_equal(np.asarray(res.latencies),
                                  np.asarray(ref.latencies))

    c_dev = mesh_shape[1]
    cvalid = np.asarray(jnp.any(works.valid, axis=-1))
    wl = np.asarray(res.window_loads)
    want_wl = np.stack([
        policy_core.sharded_client_mean(wl[i], cvalid[i], None, c_dev,
                                        xp=np)
        for i in range(wl.shape[0])])
    np.testing.assert_array_equal(np.asarray(smerge.window_loads_mean),
                                  want_wl)
    # order-free merges: masked max / integer sum over ALL clients
    lat = np.asarray(res.latencies)
    want_phase = np.max(np.where(np.asarray(works.valid), lat, 0.0),
                        axis=(1, 2))
    np.testing.assert_array_equal(np.asarray(smerge.phase_time),
                                  want_phase)
    want_probes = np.sum(np.where(cvalid, np.asarray(res.probe_msgs), 0),
                         axis=-1).astype(np.int32)
    np.testing.assert_array_equal(np.asarray(smerge.probe_msgs),
                                  want_probes)
    # §14 p99 lane: the GLOBAL merged nearest-rank p99 equals the host
    # bisection over the jax-twin grouped latency block of the same
    # per-stream outputs — the all_gather shard layout is immaterial
    # because `nearest_rank_p99` is order-insensitive
    g_lat, g_val = engine.grouped_latency_block(works, res.latencies, ws)
    want_p99 = policy_core.nearest_rank_p99(
        g_lat.reshape(g_lat.shape[0], -1),
        g_val.reshape(g_lat.shape[0], -1))[:, 0]
    np.testing.assert_array_equal(np.asarray(smerge.p99),
                                  np.asarray(want_p99))


@needs_mesh
def test_client_axis_sharded_sim_backend_parity():
    """per_client simulate under a (t_dev, c_dev) mesh: kernel and jax
    backends agree BITWISE (same shard shapes, same association), and
    order-free fields agree bitwise with the no-mesh dispatch; the
    client-mean window loads differ only in association (allclose)."""
    mesh_shape = _client_mesh_shape()
    cfg = SimConfig(client_model="per_client", n_clients=5, client_tile=2,
                    mesh_shape=mesh_shape, backend="kernel",
                    scenario=ScenarioConfig(name="transient"), **BASE)
    log_cfg = simulate.default_log_cfg(cfg)
    pol = PolicyConfig(name="ect", threshold=0.05)
    key = jax.random.key(2)
    mesh_k = simulate.run_trials(key, cfg, pol, log_cfg)
    mesh_j = simulate.run_trials(
        key, dataclasses.replace(cfg, backend="jax"), pol, log_cfg)
    _assert_trials_equal(mesh_k, mesh_j, f"mesh={mesh_shape} kernel vs jax")
    single = simulate.run_trials(
        key, dataclasses.replace(cfg, mesh_shape=None), pol, log_cfg)
    order_free = tuple(f for f in simulate.TrialResult._fields
                       if f != "window_loads")
    _assert_trials_equal(mesh_k, single, f"mesh={mesh_shape} vs single",
                         fields=order_free)
    np.testing.assert_allclose(np.asarray(mesh_k.window_loads),
                               np.asarray(single.window_loads), rtol=1e-6)


@needs_mesh
def test_sharded_rejects_client_mesh_without_client_axis():
    with pytest.raises(ValueError, match="client axis"):
        lcfg, works, states, keys, ws = _synthetic_grid()
        one_d = jax.tree.map(lambda a: a[:, 0], (states, works, keys))
        sweep.run_sweep(one_d[0], one_d[1], one_d[2],
                        mesh_shape=(1, 2),
                        policy=PolicyConfig(name="ect", threshold=0.05),
                        log_cfg=lcfg, window_size=ws)
