"""Sharding rules + multi-device behaviour (subprocess: forced devices)."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_param_specs_divisibility_rules():
    """Rule table shards divisible dims and replicates the rest."""
    from jax.sharding import PartitionSpec as P
    import repro.parallel.sharding as PS

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    rules = PS.MeshRules(mesh=FakeMesh(), batch_axes=("data",),
                         fsdp_axis="data", tp_axis="model")
    assert PS._spec_for("groups/pos_0/attn/wq", (8192, 8192), rules) == \
        P("data", "model")
    # 40 heads * 128 = 5120 q-dim: divisible; d=5120 divisible
    assert PS._spec_for("x/wq", (5120, 5120), rules) == P("data", "model")
    # odd dims -> replicated on that axis
    assert PS._spec_for("x/wq", (120, 5120), rules) == P(None, "model")
    assert PS._spec_for("embed/table", (51968, 384), rules) == \
        P("model", "data")
    assert PS._spec_for("head/w", (384, 51968), rules) == P("data", "model")
    assert PS._spec_for("a/moe/w_in", (8, 6144, 16384), rules) == \
        P(None, "data", "model")
    assert PS._spec_for("n/attn_norm/scale", (8192,), rules) == P()


def test_constrain_noop_without_rules():
    import jax.numpy as jnp
    import repro.parallel.sharding as PS
    x = jnp.ones((4, 4))
    assert PS.constrain(x, ["batch", None]) is x


_SUBPROCESS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, json
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.parallel.sharding as PS
from repro.models.config import ModelConfig
from repro.train import OptConfig, init_state, make_train_step
from repro.launch.shardutil import state_shardings

mesh = jax.make_mesh((4, 2), ("data", "model"))
rules = PS.make_rules(mesh)
cfg = ModelConfig(name="tiny", n_layers=2, d_model=32, n_heads=4,
                  n_kv_heads=2, d_ff=64, vocab_size=256)
state = init_state(jax.random.key(0), cfg)
st_sh = state_shardings(jax.eval_shape(lambda: state), rules)
state = jax.device_put(state, st_sh)
step = jax.jit(make_train_step(cfg, OptConfig(peak_lr=1e-3)),
               in_shardings=(st_sh, None), out_shardings=(st_sh, None))
tok = jnp.ones((8, 16), jnp.int32)
with mesh, PS.use_mesh_rules(rules):
    state, m = step(state, {"tokens": tok, "targets": tok})
loss_sharded = float(m["loss"])

# single-logical-device reference
cfg2 = cfg
state2 = init_state(jax.random.key(0), cfg2)
step2 = jax.jit(make_train_step(cfg2, OptConfig(peak_lr=1e-3)))
state2, m2 = step2(state2, {"tokens": tok, "targets": tok})
loss_ref = float(m2["loss"])

# compressed psum over an axis via shard_map
from repro.train import compression as C
from jax.sharding import PartitionSpec as P
import functools
g = jax.random.normal(jax.random.key(1), (8, 64))
def f(gs):
    ef = C.init_ef({"g": gs})
    out, _ = C.compressed_psum({"g": gs}, ef, "data")
    return out["g"]
from repro.compat import shard_map
fm = shard_map(f, mesh=mesh, in_specs=P("data", None),
                   out_specs=P("data", None))
mean_c = np.asarray(fm(g))
mean_ref = np.broadcast_to(np.asarray(g).reshape(4, 2, 64).mean(0,
                           keepdims=True), (4, 2, 64)).reshape(8, 64)
err = float(np.abs(mean_c - mean_ref).max())
scale = float(np.abs(mean_ref).max())

print(json.dumps({"loss_sharded": loss_sharded, "loss_ref": loss_ref,
                  "psum_err": err, "psum_scale": scale}))
"""


def test_multidevice_training_matches_single(tmp_path):
    """An 8-device (4x2) sharded train step computes the same loss as the
    single-device reference, and the int8 error-feedback psum approximates
    the true mean (subprocess so the forced device count cannot leak)."""
    script = tmp_path / "sub.py"
    script.write_text(_SUBPROCESS_SCRIPT)
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["loss_sharded"] == pytest.approx(res["loss_ref"], rel=2e-2)
    assert res["psum_err"] <= 0.02 * res["psum_scale"] + 1e-3


def test_cache_roles_cover_all_leaves():
    """Every decode-cache leaf gets a role list of matching rank."""
    import jax.tree_util as jtu
    from repro.configs import SHAPES, get_config, input_specs
    for arch in ("qwen2-72b", "jamba-v0.1-52b", "xlstm-1.3b",
                 "whisper-tiny"):
        cfg = get_config(arch, reduced=True)
        (caches, tok, pos), (c_roles, t_roles, _) = \
            input_specs(cfg, SHAPES["decode_32k"])
        flat_c = jtu.tree_leaves(caches)
        flat_r = jtu.tree_leaves(c_roles, is_leaf=lambda x: isinstance(x,
                                                                       list))
        assert len(flat_c) == len(flat_r)
        for leaf, roles in zip(flat_c, flat_r):
            assert len(roles) == len(leaf.shape), (arch, leaf.shape, roles)
