"""Paper §4 simulation claims, reproduced as assertions."""

import jax
import numpy as np
import pytest

from repro.core import analysis, simulate
from repro.core.policies import PolicyConfig
from repro.core.simulate import SimConfig

CFG = SimConfig(n_servers=40, n_requests=600, n_trials=8, window_size=100)
LOG = simulate.default_log_cfg(CFG)
KEY = jax.random.key(0)


def _run(policy, cfg=CFG, **kw):
    pol = PolicyConfig(name=policy, threshold=5.0, **kw)
    return simulate.run_trials(KEY, cfg, pol, LOG)


def test_straggler_aware_beats_rr_on_balance():
    """Figs. 12-17: every log-assisted policy balances better than RR."""
    cv_rr = analysis.load_balance_stats(_run("rr").server_loads)["cv"]
    for policy in ("mlml", "trh", "nltr"):
        cv = analysis.load_balance_stats(_run(policy).server_loads)["cv"]
        assert cv < cv_rr * 0.85, (policy, cv, cv_rr)


def test_fig18_stragglers_avoided():
    """Fig. 18: injected stragglers receive ~zero requests; RR keeps
    hitting them."""
    cfg = simulate.SimConfig(n_servers=40, n_requests=600, n_trials=8,
                             straggler_frac=0.10, straggler_factor=5.0)
    log = simulate.default_log_cfg(cfg)
    rr = simulate.run_trials(KEY, cfg, PolicyConfig(name="rr"), log)
    rr_frac = analysis.straggler_summary(rr)["hit_fraction"]
    assert rr_frac > 0.05  # RR hits stragglers proportionally (~10%)
    for policy in ("mlml", "trh", "nltr"):
        res = simulate.run_trials(KEY, cfg,
                                  PolicyConfig(name=policy, threshold=5.0),
                                  log)
        frac = analysis.straggler_summary(res)["hit_fraction"]
        assert frac < rr_frac * 0.25, (policy, frac, rr_frac)


def test_probe_overhead_eliminated():
    """§1/§5: log-assisted policies issue zero probe messages; the SC'14
    two-choice baseline pays 2 per request."""
    for policy in ("mlml", "trh", "nltr"):
        assert int(np.asarray(_run(policy).probe_msgs).max()) == 0
    tc = _run("two_choice")
    per_req = float(np.asarray(tc.probe_msgs).mean())
    assert per_req > 0
    # grouping merges same-object requests, so <= 2 * n_requests
    assert per_req <= 2 * CFG.n_requests


def test_1ltr_vs_2ltr_similar():
    """§4: 1LTR and 2LTR largely overlap -> n=2 suffices."""
    cv1 = analysis.load_balance_stats(
        _run("nltr", nltr_n=1).server_loads)["cv"]
    cv2 = analysis.load_balance_stats(
        _run("nltr", nltr_n=2).server_loads)["cv"]
    assert abs(cv1 - cv2) < 0.12, (cv1, cv2)


def test_workload_size_classes():
    for wl, lo, hi in [("small", 0.2, 4.0), ("medium", 4.0, 10.0),
                       ("large", 10.0, 1024.0)]:
        cfg = simulate.SimConfig(workload=wl, n_requests=200, n_trials=1)
        w = simulate.sample_workload(jax.random.key(1), cfg)
        lens = np.asarray(w.lengths)
        assert lens.min() >= lo - 1e-3 and lens.max() <= hi + 1e-3, wl


def test_per_client_model_still_avoids_stragglers():
    """Multi-client contention study (beyond-paper): private logs are
    blind to other clients' decisions, but the shared initial-load
    snapshot still lets every client dodge injected stragglers."""
    cfg = simulate.SimConfig(n_servers=20, n_clients=10, n_requests=400,
                             n_trials=4, client_model="per_client",
                             straggler_frac=0.10, straggler_factor=5.0)
    log = simulate.default_log_cfg(cfg)
    trh = simulate.run_trials(KEY, cfg,
                              PolicyConfig(name="trh", threshold=5.0), log)
    rr = simulate.run_trials(KEY, cfg, PolicyConfig(name="rr"), log)
    f_trh = analysis.straggler_summary(trh)["hit_fraction"]
    f_rr = analysis.straggler_summary(rr)["hit_fraction"]
    assert f_rr > 0.05
    assert f_trh < f_rr * 0.5, (f_trh, f_rr)


def test_fig18_curve_shape():
    res = _run("rr")
    xs, ys = analysis.fig18_curve(res.server_loads, res.n_assigned, 20)
    assert xs.shape == (20,) and ys.shape == (20,)
    assert ys.max() > 0
