"""Paper §4 simulation claims, reproduced as assertions."""

import jax
import numpy as np
import pytest

from repro.core import analysis, simulate
from repro.core.policies import PolicyConfig
from repro.core.simulate import SimConfig

CFG = SimConfig(n_servers=40, n_requests=600, n_trials=8, window_size=100)
LOG = simulate.default_log_cfg(CFG)
KEY = jax.random.key(0)


def _run(policy, cfg=CFG, **kw):
    pol = PolicyConfig(name=policy, threshold=5.0, **kw)
    return simulate.run_trials(KEY, cfg, pol, LOG)


def test_straggler_aware_beats_rr_on_balance():
    """Figs. 12-17: every log-assisted policy balances better than RR."""
    cv_rr = analysis.load_balance_stats(_run("rr").server_loads)["cv"]
    for policy in ("mlml", "trh", "nltr"):
        cv = analysis.load_balance_stats(_run(policy).server_loads)["cv"]
        assert cv < cv_rr * 0.85, (policy, cv, cv_rr)


def test_fig18_stragglers_avoided():
    """Fig. 18: injected stragglers receive ~zero requests; RR keeps
    hitting them."""
    cfg = simulate.SimConfig(n_servers=40, n_requests=600, n_trials=8,
                             straggler_frac=0.10, straggler_factor=5.0)
    log = simulate.default_log_cfg(cfg)
    rr = simulate.run_trials(KEY, cfg, PolicyConfig(name="rr"), log)
    rr_frac = analysis.straggler_summary(rr)["hit_fraction"]
    assert rr_frac > 0.05  # RR hits stragglers proportionally (~10%)
    for policy in ("mlml", "trh", "nltr"):
        res = simulate.run_trials(KEY, cfg,
                                  PolicyConfig(name=policy, threshold=5.0),
                                  log)
        frac = analysis.straggler_summary(res)["hit_fraction"]
        assert frac < rr_frac * 0.25, (policy, frac, rr_frac)


def test_probe_overhead_eliminated():
    """§1/§5: log-assisted policies issue zero probe messages; the SC'14
    two-choice baseline pays 2 per request."""
    for policy in ("mlml", "trh", "nltr"):
        assert int(np.asarray(_run(policy).probe_msgs).max()) == 0
    tc = _run("two_choice")
    per_req = float(np.asarray(tc.probe_msgs).mean())
    assert per_req > 0
    # grouping merges same-object requests, so <= 2 * n_requests
    assert per_req <= 2 * CFG.n_requests


def test_1ltr_vs_2ltr_similar():
    """§4: 1LTR and 2LTR largely overlap -> n=2 suffices."""
    cv1 = analysis.load_balance_stats(
        _run("nltr", nltr_n=1).server_loads)["cv"]
    cv2 = analysis.load_balance_stats(
        _run("nltr", nltr_n=2).server_loads)["cv"]
    assert abs(cv1 - cv2) < 0.12, (cv1, cv2)


def test_workload_size_classes():
    for wl, lo, hi in [("small", 0.2, 4.0), ("medium", 4.0, 10.0),
                       ("large", 10.0, 1024.0)]:
        cfg = simulate.SimConfig(workload=wl, n_requests=200, n_trials=1)
        w = simulate.sample_workload(jax.random.key(1), cfg)
        lens = np.asarray(w.lengths)
        assert lens.min() >= lo - 1e-3 and lens.max() <= hi + 1e-3, wl


def test_per_client_model_still_avoids_stragglers():
    """Multi-client contention study (beyond-paper): private logs are
    blind to other clients' decisions, but the shared initial-load
    snapshot still lets every client dodge injected stragglers."""
    cfg = simulate.SimConfig(n_servers=20, n_clients=10, n_requests=400,
                             n_trials=4, client_model="per_client",
                             straggler_frac=0.10, straggler_factor=5.0)
    log = simulate.default_log_cfg(cfg)
    trh = simulate.run_trials(KEY, cfg,
                              PolicyConfig(name="trh", threshold=5.0), log)
    rr = simulate.run_trials(KEY, cfg, PolicyConfig(name="rr"), log)
    f_trh = analysis.straggler_summary(trh)["hit_fraction"]
    f_rr = analysis.straggler_summary(rr)["hit_fraction"]
    assert f_rr > 0.05
    assert f_trh < f_rr * 0.5, (f_trh, f_rr)


def test_fig18_curve_shape():
    res = _run("rr")
    xs, ys = analysis.fig18_curve(res.server_loads, res.n_assigned, 20)
    assert xs.shape == (20,) and ys.shape == (20,)
    assert ys.max() > 0


# ---------------------------------------------------------------------------
# Temporal cluster model (DESIGN.md §Temporal-model)
# ---------------------------------------------------------------------------

import dataclasses

from repro.core.simulate import ScenarioConfig

TCFG = simulate.SimConfig(n_servers=24, n_requests=240, n_trials=100,
                          window_size=60)
SEED_FIELDS = ("server_loads", "n_assigned", "chosen", "probe_msgs",
               "straggler_hits", "redirected", "init_loads",
               "straggler_mask")


def test_degenerate_trace_is_bit_for_bit_static():
    """The all-rates-equal, no-events, dt=0 trace must reproduce the
    static-load model's TrialResult fields bit-for-bit on the same seed."""
    cfg_static = dataclasses.replace(TCFG, n_trials=8,
                                     scenario=ScenarioConfig(name="static"))
    cfg_none = dataclasses.replace(TCFG, n_trials=8)
    log = simulate.default_log_cfg(cfg_none)
    # ect included: the static scenario keeps completion feedback OFF so
    # even the ewma-reading policy stays identical to the no-trace path
    for policy in ("rr", "trh", "mlml", "ect"):
        pol = PolicyConfig(name=policy, threshold=5.0)
        a = simulate.run_trials(KEY, cfg_none, pol, log)
        b = simulate.run_trials(KEY, cfg_static, pol, log)
        for field in SEED_FIELDS:
            av = np.asarray(getattr(a, field))
            bv = np.asarray(getattr(b, field))
            assert (av == bv).all(), (policy, field)


def test_transient_log_assisted_beats_rr_on_tail_latency():
    """Under a transient straggler trace, the rate-aware ECT policy (and
    TRH) beat round-robin on p99 latency AND makespan."""
    cfg = dataclasses.replace(TCFG, n_trials=20,
                              scenario=ScenarioConfig(name="transient"))
    log = simulate.default_log_cfg(cfg)
    stats = {}
    for policy, thr in (("rr", 0.0), ("trh", 5.0), ("ect", 0.05)):
        res = simulate.run_trials(KEY, cfg,
                                  PolicyConfig(name=policy, threshold=thr),
                                  log)
        stats[policy] = (analysis.latency_stats(res.latencies)["p99"],
                         analysis.makespan(res))
    for policy in ("trh", "ect"):
        assert stats[policy][0] < stats["rr"][0], (policy, stats)
        assert stats[policy][1] < stats["rr"][1], (policy, stats)


def test_full_scenario_sweep_jitted():
    """Acceptance criterion: 100 trials x 5 policies x 4 temporal
    scenarios runs jitted end-to-end on CPU; every policy/scenario cell
    yields finite latencies and a positive makespan."""
    out = simulate.run_scenario_eval(
        seed=0, cfg=TCFG,
        scenario_names=("permanent_slow", "transient", "flapping",
                        "correlated_rack"),
        policy_names=("rr", "mlml", "trh", "nltr", "ect"))
    assert len(out) == 4
    for scn, row in out.items():
        assert len(row) == 5
        for pol, res in row.items():
            lat = np.asarray(res.latencies)
            assert lat.shape == (TCFG.n_trials, TCFG.n_requests)
            assert np.isfinite(lat).all() and (lat >= 0).all(), (scn, pol)
            assert float(np.asarray(res.phase_time).min()) > 0.0, (scn, pol)
            wl = np.asarray(res.window_loads)
            assert wl.shape == (TCFG.n_trials, TCFG.n_windows,
                                TCFG.n_servers)
            # trace stragglers are part of the mask
            assert bool(np.asarray(res.straggler_mask).any()), (scn, pol)


def test_window_loads_show_straggler_queue_growth():
    """Under permanent_slow + RR, the slowed servers' residual queues grow
    over windows while healthy servers stay drained."""
    cfg = dataclasses.replace(
        TCFG, n_trials=10,
        scenario=ScenarioConfig(name="permanent_slow"))
    log = simulate.default_log_cfg(cfg)
    res = simulate.run_trials(KEY, cfg, PolicyConfig(name="rr"), log)
    wl = np.asarray(res.window_loads)          # (T, W, M)
    mask = np.asarray(res.straggler_mask)      # (T, M)
    strag_last = np.array([wl[t, -1, mask[t]].mean() for t in range(10)])
    healthy_last = np.array([wl[t, -1, ~mask[t]].mean() for t in range(10)])
    assert strag_last.mean() > 2 * healthy_last.mean()
    # straggler residual grows monotonically window over window
    strag_per_win = np.array([wl[:, w][mask].mean()
                              for w in range(wl.shape[1])])
    assert (np.diff(strag_per_win) > 0).all(), strag_per_win
    hits = analysis.straggler_hits_over_time(res.chosen, res.straggler_mask,
                                             cfg.window_size)
    assert hits.shape == (cfg.n_windows,)


def test_latency_analysis_helpers():
    cfg = dataclasses.replace(TCFG, n_trials=6,
                              scenario=ScenarioConfig(name="transient"))
    log = simulate.default_log_cfg(cfg)
    results = {p: simulate.run_trials(KEY, cfg, PolicyConfig(name=p), log)
               for p in ("rr", "trh")}
    ls = analysis.latency_stats(results["rr"].latencies)
    assert ls["p50"] <= ls["p95"] <= ls["p99"] <= ls["max"]
    xs, ys = analysis.latency_cdf(results["rr"].latencies, 32)
    assert xs.shape == (32,) and ys.shape == (32,)
    assert 0.0 <= ys[0] and abs(ys[-1] - 1.0) < 1e-9
    assert (np.diff(ys) >= 0).all()
    slow = analysis.slowdown_vs_baseline(results, "rr")
    assert abs(slow["rr"]["p99_vs_rr"] - 1.0) < 1e-9
    assert abs(slow["rr"]["makespan_vs_rr"] - 1.0) < 1e-9


# ---------------------------------------------------------------------------
# Trial-grid kernel backend (DESIGN.md §9): run_trials(backend="kernel")
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scenario", simulate.SCENARIOS)
def test_kernel_batch_backend_matches_sequential_and_engine(scenario):
    """SimConfig(backend='kernel') schedules the whole sweep as ONE
    trial-grid pallas_call; every TrialResult field is bit-exact vs (a)
    mapping the sequential kernel path trial-by-trial and (b) the
    vmapped jax engine — across all five scenarios, odd M, padded
    windows and T below the grid tile."""
    cfg_k = SimConfig(n_servers=37, n_requests=250, n_trials=5,
                      window_size=60, backend="kernel",
                      scenario=ScenarioConfig(name=scenario))
    cfg_j = dataclasses.replace(cfg_k, backend="jax")
    log = simulate.default_log_cfg(cfg_k)
    pol = PolicyConfig(name="ect", threshold=0.05)
    batch = simulate.run_trials(KEY, cfg_k, pol, log)
    keys = jax.random.split(KEY, cfg_k.n_trials)
    seq = jax.jit(lambda ks: jax.lax.map(
        lambda k: simulate.run_one_trial(k, cfg_k, pol, log), ks))(keys)
    eng = simulate.run_trials(KEY, cfg_j, pol, log)
    for other, tag in ((seq, "lax.map kernel"), (eng, "vmapped engine")):
        for f in batch._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(batch, f)),
                np.asarray(getattr(other, f)),
                err_msg=f"{scenario}/{tag}/{f}")


def test_kernel_batch_backend_trh_lcg_parity():
    """TRH rides the same trial-grid path once the engine replays the
    kernel's LCG (rng='lcg'), T not a multiple of the tile."""
    cfg_k = SimConfig(n_servers=24, n_requests=240, n_trials=10,
                      window_size=60, backend="kernel", trial_tile=4,
                      scenario=ScenarioConfig(name="transient"))
    cfg_j = dataclasses.replace(cfg_k, backend="jax")
    log = simulate.default_log_cfg(cfg_k)
    pol = PolicyConfig(name="trh", threshold=4.0, rng="lcg")
    batch = simulate.run_trials(KEY, cfg_k, pol, log)
    eng = simulate.run_trials(KEY, cfg_j, pol, log)
    for f in ("chosen", "latencies", "server_loads", "phase_time",
              "straggler_hits", "redirected", "n_assigned"):
        np.testing.assert_array_equal(np.asarray(getattr(batch, f)),
                                      np.asarray(getattr(eng, f)),
                                      err_msg=f)


@pytest.mark.parametrize("scenario,policy,rng",
                         [(s, "nltr", "lcg") for s in simulate.SCENARIOS]
                         + [(s, "mlml", "jax") for s in simulate.SCENARIOS])
def test_kernel_batch_sort_policies_all_scenarios(scenario, policy, rng):
    """Tentpole coverage (DESIGN.md §10): the sort-based mlml/nltr ride
    the trial-grid kernel across every scenario — decisions, latencies
    and loads bit-exact vs (a) lax.map of the sequential kernel path and
    (b) the vmapped jax engine; T below the grid tile, padded windows
    (n_requests % window_size != 0)."""
    cfg_k = SimConfig(n_servers=25, n_requests=130, n_trials=3,
                      window_size=40, backend="kernel",
                      scenario=ScenarioConfig(name=scenario))
    cfg_j = dataclasses.replace(cfg_k, backend="jax")
    log = simulate.default_log_cfg(cfg_k)
    pol = PolicyConfig(name=policy, threshold=5.0, rng=rng)
    batch = simulate.run_trials(KEY, cfg_k, pol, log)
    keys = jax.random.split(KEY, cfg_k.n_trials)
    seq = jax.jit(lambda ks: jax.lax.map(
        lambda k: simulate.run_one_trial(k, cfg_k, pol, log), ks))(keys)
    eng = simulate.run_trials(KEY, cfg_j, pol, log)
    for other, tag in ((seq, "lax.map kernel"), (eng, "vmapped engine")):
        for f in batch._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(batch, f)),
                np.asarray(getattr(other, f)),
                err_msg=f"{scenario}/{policy}/{tag}/{f}")


def test_kernel_batch_backend_runs_all_six_policies_bit_exact():
    """Acceptance: SimConfig(backend='kernel') dispatches every §3.4
    policy — rr, mlml, trh, nltr, two_choice, ect — with decisions,
    latencies and loads bit-exact vs the jax engine (randomized policies
    replay the kernel's LCG)."""
    cfg_k = SimConfig(n_servers=24, n_requests=200, n_trials=4,
                      window_size=60, backend="kernel",
                      scenario=ScenarioConfig(name="transient"))
    cfg_j = dataclasses.replace(cfg_k, backend="jax")
    log = simulate.default_log_cfg(cfg_k)
    from repro.core.policies import POLICIES
    assert len(POLICIES) == 6
    for name in POLICIES:
        rng = "lcg" if name in ("trh", "nltr", "two_choice") else "jax"
        thr = 0.05 if name == "ect" else 5.0
        pol = PolicyConfig(name=name, threshold=thr, rng=rng)
        batch = simulate.run_trials(KEY, cfg_k, pol, log)
        eng = simulate.run_trials(KEY, cfg_j, pol, log)
        for f in ("chosen", "latencies", "server_loads", "window_loads",
                  "phase_time", "probe_msgs", "redirected", "n_assigned"):
            np.testing.assert_array_equal(np.asarray(getattr(batch, f)),
                                          np.asarray(getattr(eng, f)),
                                          err_msg=f"{name}/{f}")


# ---------------------------------------------------------------------------
# 2-D (trials × clients) grid backend (DESIGN.md §11):
# run_trials(backend="kernel", client_model="per_client")
# ---------------------------------------------------------------------------

# every §3.4 policy + both baselines (randomized ones replay the kernel
# LCG so the jax path is its bit-exact twin)
PC_POLICIES = (("rr", "jax", 5.0), ("mlml", "jax", 5.0),
               ("trh", "lcg", 5.0), ("nltr", "lcg", 5.0),
               ("two_choice", "lcg", 5.0), ("ect", "jax", 0.05))


@pytest.mark.filterwarnings("ignore:per_client window clamp")
@pytest.mark.parametrize("scenario", simulate.SCENARIOS)
def test_per_client_kernel_backend_all_policies(scenario):
    """Acceptance (§11 tentpole): run_trials(backend='kernel',
    client_model='per_client') dispatches the whole sweep as ONE 2-D
    grid pallas_call and every TrialResult field — choices, latencies,
    loads, the masked cross-client window_loads mean, probe sums and
    phase_time — is bit-exact vs the jax per_client path, for all six
    §3.4 policies across all five scenarios (odd M, uneven 60/5 split
    with window clamp 16 -> 12)."""
    cfg_k = SimConfig(n_servers=17, n_clients=5, n_requests=60, n_trials=2,
                      window_size=16, backend="kernel",
                      client_model="per_client",
                      scenario=ScenarioConfig(name=scenario))
    cfg_j = dataclasses.replace(cfg_k, backend="jax")
    log = simulate.default_log_cfg(cfg_k)
    for name, rng, thr in PC_POLICIES:
        pol = PolicyConfig(name=name, threshold=thr, rng=rng)
        a = simulate.run_trials(KEY, cfg_k, pol, log)
        b = simulate.run_trials(KEY, cfg_j, pol, log)
        for f in a._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
                err_msg=f"{scenario}/{name}/{f}")


@pytest.mark.filterwarnings("ignore:per_client window clamp")
def test_per_client_kernel_phantom_clients_uneven_tiles():
    """2-D grid edge cases: n_clients > n_requests (whole phantom
    clients), n_clients not a multiple of client_tile, odd M — the
    masked cross-client aggregates match the jax path bitwise, and
    probe accounting stays 2 per scheduled request for two_choice."""
    cfg_k = SimConfig(n_servers=11, n_clients=7, n_requests=5, n_trials=2,
                      window_size=4, backend="kernel",
                      client_model="per_client", client_tile=2,
                      scenario=ScenarioConfig(name="permanent_slow"))
    cfg_j = dataclasses.replace(cfg_k, backend="jax")
    log = simulate.default_log_cfg(cfg_k)
    for name, rng in (("two_choice", "lcg"), ("ect", "jax")):
        pol = PolicyConfig(name=name, threshold=0.05, rng=rng)
        a = simulate.run_trials(KEY, cfg_k, pol, log)
        b = simulate.run_trials(KEY, cfg_j, pol, log)
        for f in a._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
                err_msg=f"{name}/{f}")
        if name == "two_choice":
            np.testing.assert_array_equal(np.asarray(a.probe_msgs),
                                          2 * cfg_k.n_requests)
        # per-client slices are single requests: window clamp recorded
        np.testing.assert_array_equal(np.asarray(a.window_size_eff), 1)


def test_per_client_window_clamp_warns_and_records():
    """Satellite: the silent `win = min(window_size, per)` clamp now
    warns at dispatch (naming both sizes) and records the effective
    window in TrialResult.window_size_eff; unclamped runs stay silent
    and record the configured size."""
    import warnings as _warnings
    cfg = simulate.SimConfig(n_servers=6, n_clients=4, n_requests=12,
                             n_trials=2, window_size=9,
                             client_model="per_client")
    log = simulate.default_log_cfg(cfg)
    with pytest.warns(UserWarning, match="window_size=9.*window_size_eff=3"):
        res = simulate.run_trials(KEY, cfg, PolicyConfig(name="rr"), log)
    np.testing.assert_array_equal(np.asarray(res.window_size_eff), 3)
    # no clamp -> no warning; shared_log never clamps
    for cfg2 in (dataclasses.replace(cfg, window_size=3),
                 dataclasses.replace(cfg, client_model="shared_log",
                                     window_size=4)):
        log2 = simulate.default_log_cfg(cfg2)
        with _warnings.catch_warnings():
            _warnings.filterwarnings("error",
                                     message=".*window clamp.*")
            res2 = simulate.run_trials(KEY, cfg2, PolicyConfig(name="rr"),
                                       log2)
        np.testing.assert_array_equal(np.asarray(res2.window_size_eff),
                                      cfg2.window_size)


def test_per_client_uneven_split_masks_padding():
    """Satellite regression: with n_requests % n_clients != 0 the padded
    slices (and whole phantom clients) must not leak into the per-client
    aggregates — the window_loads mean counts only clients that actually
    scheduled a request, and probe totals stay 2/request for two_choice."""
    # 5 requests over 8 clients -> per = 1, three phantom clients
    cfg = simulate.SimConfig(n_servers=6, n_clients=8, n_requests=5,
                             n_trials=1, window_size=4,
                             client_model="per_client")
    log = simulate.default_log_cfg(cfg)
    res = simulate.run_trials(KEY, cfg,
                              PolicyConfig(name="two_choice"), log)
    # probes: exactly 2 per scheduled request, padding issues none
    assert int(np.asarray(res.probe_msgs)[0]) == 2 * cfg.n_requests
    # window_loads is the mean over REAL clients' private views: each of
    # the 5 real clients saw its own request only, so the mean view
    # carries total_bytes / 5 of scheduled load above the absorbed
    # initial loads; averaging over all 8 (phantoms included) would
    # dilute it to total_bytes / 8 — the pre-fix failure.
    key = jax.random.key(0)
    keys = jax.random.split(key, 1)
    k_load, k_work, _ = jax.random.split(keys[0], 3)
    init, _ = simulate.initial_loads(k_load, cfg)
    work = simulate.sample_workload(k_work, cfg)
    scheduled = float(np.asarray(res.window_loads)[0, -1].sum()
                      - np.asarray(init).sum())
    expect = float(np.asarray(work.lengths).sum()) / cfg.n_requests
    np.testing.assert_allclose(scheduled, expect, rtol=1e-5)


def test_nltr_section_count_validated_against_servers():
    """Satellite regression: 2**nltr_n > n_servers used to silently
    collapse every nLTR section onto the same server range; now the
    dispatch boundary raises a ValueError naming both values."""
    from repro.core import engine, policies, statlog
    cfg = simulate.SimConfig(n_servers=6, n_requests=40, n_trials=1,
                             window_size=20)
    log = simulate.default_log_cfg(cfg)
    bad = PolicyConfig(name="nltr", nltr_n=3)        # K = 8 > M = 6
    with pytest.raises(ValueError, match="nltr_n=3.*n_servers=6"):
        simulate.run_trials(KEY, cfg, bad, log)
    with pytest.raises(ValueError, match="nltr_n=3.*n_servers=6"):
        engine.run_stream(statlog.init_state(log),
                          simulate.sample_workload(KEY, cfg), KEY,
                          policy=bad, log_cfg=log, window_size=20)
    with pytest.raises(ValueError, match="nltr_n=3"):
        policies.HostScheduler(bad, statlog.HostStatLog(log))
    # K == M is the legal edge: one server per section, still runs
    edge = PolicyConfig(name="nltr", nltr_n=2, threshold=5.0)
    cfg8 = simulate.SimConfig(n_servers=4, n_requests=40, n_trials=1,
                              window_size=20)
    res = simulate.run_trials(KEY, cfg8, edge,
                              simulate.default_log_cfg(cfg8))
    chosen = np.asarray(res.chosen)
    assert ((chosen >= 0) & (chosen < 4)).all()


def test_simconfig_rejects_bad_fields_with_values():
    """Satellite: config validation raises ValueError (not assert — gone
    under `python -O`) naming the offending values."""
    with pytest.raises(ValueError, match="huge"):
        SimConfig(workload="huge")
    with pytest.raises(ValueError, match="p2p"):
        SimConfig(client_model="p2p")
    with pytest.raises(ValueError, match="tpu"):
        SimConfig(backend="tpu")
    with pytest.raises(ValueError, match="trial_tile=0"):
        SimConfig(backend="kernel", trial_tile=0)
    # previously failed deep inside a reshape / ValueError'd at dispatch:
    # now validated up front, naming the offending values
    with pytest.raises(ValueError, match="n_clients=0"):
        SimConfig(n_clients=0, client_model="per_client")
    with pytest.raises(ValueError, match="n_clients=-3"):
        SimConfig(n_clients=-3)
    with pytest.raises(ValueError, match="client_tile=0"):
        SimConfig(client_model="per_client", client_tile=0)
    with pytest.raises(ValueError, match="client_tile=-2"):
        SimConfig(client_tile=-2)
    with pytest.raises(ValueError, match="eager"):
        SimConfig(prep="eager")
    # kernel backend + per_client is a SUPPORTED combination now (the
    # 2-D trials x clients grid, DESIGN.md §11)
    cfg = SimConfig(backend="kernel", client_model="per_client")
    assert cfg.n_clients == 200


# ---------------------------------------------------------------------------
# Batched trial prep/post pipeline (DESIGN.md §14): prep="batched" vs the
# lax.map sequential oracle, and the merged nearest-rank p99 lane
# ---------------------------------------------------------------------------


def _batched_vs_sequential(cfg, pol):
    """Every TrialResult field of the default batched pipeline equals
    the ``prep='sequential'`` lax.map oracle bit-for-bit."""
    log = simulate.default_log_cfg(cfg)
    a = simulate.run_trials(KEY, cfg, pol, log)
    b = simulate.run_trials(KEY, dataclasses.replace(cfg, prep="sequential"),
                            pol, log)
    for f in a._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"{cfg.backend}/{cfg.client_model}/{f}")


@pytest.mark.parametrize("scenario", simulate.SCENARIOS)
@pytest.mark.parametrize("backend", ["jax", "kernel"])
def test_batched_prep_matches_sequential_all_scenarios(scenario, backend):
    """§14 tentpole contract: the vmapped prep/post pipeline is
    bit-identical to the sequential lax.map halo on every scenario and
    both backends — odd M (37), T=5 not a multiple of the trial tile.
    The shape-sensitive prep reductions (Eq. (2) absorb normalizer,
    per-server written sums) go through pinned association primitives,
    and the optimization_barrier fences keep XLA from fusing scheduling
    consumers into the vmapped transcendentals (DESIGN.md §14)."""
    cfg = SimConfig(n_servers=37, n_requests=250, n_trials=5,
                    window_size=60, backend=backend,
                    scenario=ScenarioConfig(name=scenario),
                    straggler_frac=0.1)
    _batched_vs_sequential(cfg, PolicyConfig(name="ect", threshold=0.05))


@pytest.mark.parametrize("backend", ["jax", "kernel"])
def test_batched_prep_matches_sequential_per_client(backend):
    """§14 on the per_client 2-D path: phantom clients (7 clients over
    5 requests), uneven client tiles and the cross-client merged fold —
    batched pipeline == sequential oracle bitwise, both backends, plus
    an lcg sort policy on the even-split case."""
    cfg = SimConfig(n_servers=11, n_clients=7, n_requests=5, n_trials=3,
                    window_size=4, backend=backend,
                    client_model="per_client", client_tile=2,
                    scenario=ScenarioConfig(name="permanent_slow"))
    _batched_vs_sequential(cfg, PolicyConfig(name="ect", threshold=0.05))
    cfg2 = SimConfig(n_servers=17, n_clients=5, n_requests=60, n_trials=2,
                     window_size=16, backend=backend,
                     client_model="per_client",
                     scenario=ScenarioConfig(name="transient"))
    _batched_vs_sequential(
        cfg2, PolicyConfig(name="nltr", threshold=5.0, rng="lcg"))


def test_batched_prep_matches_sequential_odd_tile_shared_log():
    """T=13 (not a multiple of the trial tile 8) through the shared_log
    kernel grid: inert padded trials in the batched pipeline cannot leak
    into the real trials' prep or bookkeeping."""
    cfg = SimConfig(n_servers=24, n_requests=240, n_trials=13,
                    window_size=60, backend="kernel",
                    scenario=ScenarioConfig(name="flapping"))
    _batched_vs_sequential(cfg, PolicyConfig(name="trh", threshold=5.0,
                                             rng="lcg"))


def test_nearest_rank_p99_pinned_and_latency_stats():
    """Satellite: the two p99 definitions pinned against a hand-computed
    example.  For n=200 values 1..200: nearest-rank takes the
    ceil(0.99*200)=198th order statistic (198.0 exactly), while
    np.percentile's linear interpolation lands between the 198th and
    199th (198.01).  `analysis.latency_stats` reports both, and
    ``p99_nearest`` equals the `policy_core.nearest_rank_p99` bisection
    the kernel's merged lane runs (MET_P99 / SweepMerge.p99)."""
    from repro.core import policy_core
    lats = np.arange(1.0, 201.0, dtype=np.float32)       # 1..200
    rng = np.random.default_rng(7)
    rng.shuffle(lats)                                    # order-free
    ls = analysis.latency_stats(lats)
    assert ls["p99_nearest"] == 198.0
    np.testing.assert_allclose(ls["p99"], 198.01)
    # the bisection itself: batch axis + validity mask semantics
    p99 = policy_core.nearest_rank_p99(
        np.stack([lats, lats]), np.ones((2, 200), bool), xp=np)
    np.testing.assert_array_equal(np.asarray(p99).reshape(-1), 198.0)
    # masked slots are excluded: with only 1..100 valid the rank is
    # computed in f32 — f32(0.99) * 100 rounds to exactly 99.0 (the
    # product 99.0000009? is under half an ulp above 99), so
    # k = ceil(99.0) = 99 and the p99 is the 99th order statistic.
    # This IS the kernel's semantics (all-f32 by design), pinned here
    # so a "fix" to exact-rational ranks would show up as a break.
    valid = lats <= 100.0
    p99m = policy_core.nearest_rank_p99(lats, valid, xp=np)
    assert float(np.asarray(p99m).reshape(-1)[0]) == 99.0
    # all-invalid -> exactly 0 (the kernel's dead-trial pin)
    p99z = policy_core.nearest_rank_p99(lats, np.zeros(200, bool), xp=np)
    assert float(np.asarray(p99z).reshape(-1)[0]) == 0.0


def test_metric_counts_are_integer_and_backend_invariant():
    """Regression for the §15 contract sweep: ``straggler_hits`` and
    ``redirected`` are integer sums (`jnp.sum` over int32 casts), so the
    counts are exact under any reduction association — the kernel and
    jax backends must agree bit-for-bit and the dtype must stay
    integral (a float accumulation here would be a contract break the
    linter's CC-SUM rule now also flags)."""
    cfg_k = SimConfig(n_servers=20, n_requests=150, n_trials=4,
                      window_size=50, backend="kernel",
                      straggler_frac=0.15, straggler_factor=5.0)
    cfg_j = dataclasses.replace(cfg_k, backend="jax")
    log = simulate.default_log_cfg(cfg_k)
    pol = PolicyConfig(name="trh", threshold=4.0, rng="lcg")
    a = simulate.run_trials(KEY, cfg_k, pol, log)
    b = simulate.run_trials(KEY, cfg_j, pol, log)
    for f in ("straggler_hits", "redirected"):
        xa = np.asarray(getattr(a, f))
        assert np.issubdtype(xa.dtype, np.integer), (f, xa.dtype)
        np.testing.assert_array_equal(xa, np.asarray(getattr(b, f)),
                                      err_msg=f)
