"""Property tests for the client-side server statistic log (Eqs. 1-3)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import statlog
from repro.core.statlog import HostStatLog, LogConfig


@given(m=st.integers(2, 64),
       seq=st.lists(st.tuples(st.integers(0, 63),
                              st.floats(0.01, 500.0)), min_size=1,
                    max_size=60))
def test_probs_stay_simplex(m, seq):
    """After any assignment sequence: sum(p) == 1, p >= 0, loads >= 0."""
    log = HostStatLog(LogConfig(n_servers=m, lam=32.0))
    for srv, ln in seq:
        log.apply_assignment(srv % m, ln)
    assert abs(log.probs.sum() - 1.0) < 1e-6
    assert (log.probs >= -1e-12).all()
    assert (log.loads >= 0).all()


@given(m=st.integers(2, 32), srv=st.integers(0, 31),
       ln=st.floats(0.01, 100.0))
def test_eq123_formulas(m, srv, ln):
    """One assignment matches the closed-form Eqs. (1)-(3)."""
    srv = srv % m
    cfg = LogConfig(n_servers=m, lam=16.0)
    log = HostStatLog(cfg)
    p0 = log.probs.copy()
    log.apply_assignment(srv, ln)
    assert log.loads[srv] == pytest.approx(ln)                     # Eq. 1
    decayed = p0[srv] * np.exp(-ln / cfg.lam)
    assert log.probs[srv] == pytest.approx(decayed)                # Eq. 2
    others = [j for j in range(m) if j != srv]
    expect = p0[others] + (p0[srv] - decayed) / (m - 1)
    np.testing.assert_allclose(log.probs[others], expect, rtol=1e-9)  # Eq. 3


@given(m=st.integers(2, 16),
       seq=st.lists(st.tuples(st.integers(0, 15), st.floats(0.1, 50.0)),
                    min_size=1, max_size=30))
def test_host_and_jax_twins_agree(m, seq):
    cfg = LogConfig(n_servers=m, lam=24.0)
    host = HostStatLog(cfg)
    state = statlog.init_state(cfg)
    for srv, ln in seq:
        srv = srv % m
        host.apply_assignment(srv, ln)
        state = statlog.apply_assignment(state, jnp.asarray(srv),
                                         jnp.asarray(ln, jnp.float32), cfg)
    np.testing.assert_allclose(np.asarray(state.loads), host.loads,
                               rtol=2e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(state.probs), host.probs,
                               rtol=2e-4, atol=1e-6)


def test_heavier_server_has_lower_prob():
    """The exponential weighting orders probabilities by load (§3.3.2)."""
    cfg = LogConfig(n_servers=4, lam=10.0)
    log = HostStatLog(cfg)
    log.apply_assignment(0, 50.0)
    log.apply_assignment(1, 5.0)
    assert log.probs[0] < log.probs[1] < log.probs[2]
    assert log.probs[2] == pytest.approx(log.probs[3])


def test_ewma_observation():
    cfg = LogConfig(n_servers=3, ewma_alpha=0.5)
    log = HostStatLog(cfg)
    log.observe_completion(1, 100.0)
    assert log.ewma_lat[1] == 100.0     # first observation seeds
    log.observe_completion(1, 50.0)
    assert log.ewma_lat[1] == pytest.approx(75.0)


def test_complete_drains_load():
    log = HostStatLog(LogConfig(n_servers=2))
    log.apply_assignment(0, 10.0)
    log.complete(0, 4.0)
    assert log.loads[0] == pytest.approx(6.0)
    log.complete(0, 100.0)  # never negative
    assert log.loads[0] == 0.0


def test_renormalize_fixes_drift():
    log = HostStatLog(LogConfig(n_servers=5))
    log.probs = log.probs * 1.1
    log.renormalize()
    assert abs(log.probs.sum() - 1.0) < 1e-12


def test_request_log_records_fig8_rows():
    """The I/O request table keeps (object, offset, length) rows (Fig. 8)."""
    log = HostStatLog(LogConfig(n_servers=2))
    log.record_request(12, 4096, 2.0)
    log.record_request(99, 0, 0.5)
    assert log.request_log == [(12, 4096, 2.0), (99, 0, 0.5)]
