"""Property tests for the client-side server statistic log (Eqs. 1-3)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import policy_core, statlog
from repro.core.statlog import HostStatLog, LogConfig


@given(m=st.integers(2, 64),
       seq=st.lists(st.tuples(st.integers(0, 63),
                              st.floats(0.01, 500.0)), min_size=1,
                    max_size=60))
def test_probs_stay_simplex(m, seq):
    """After any assignment sequence: sum(p) == 1, p >= 0, loads >= 0."""
    log = HostStatLog(LogConfig(n_servers=m, lam=32.0))
    for srv, ln in seq:
        log.apply_assignment(srv % m, ln)
    assert abs(log.probs.sum() - 1.0) < 1e-6
    assert (log.probs >= -1e-12).all()
    assert (log.loads >= 0).all()


@given(m=st.integers(2, 32), srv=st.integers(0, 31),
       ln=st.floats(0.01, 100.0))
def test_eq123_formulas(m, srv, ln):
    """One assignment matches the closed-form Eqs. (1)-(3)."""
    srv = srv % m
    cfg = LogConfig(n_servers=m, lam=16.0)
    log = HostStatLog(cfg)
    p0 = log.probs.copy()
    log.apply_assignment(srv, ln)
    assert log.loads[srv] == pytest.approx(ln)                     # Eq. 1
    decayed = p0[srv] * np.exp(-ln / cfg.lam)
    assert log.probs[srv] == pytest.approx(decayed)                # Eq. 2
    others = [j for j in range(m) if j != srv]
    expect = p0[others] + (p0[srv] - decayed) / (m - 1)
    np.testing.assert_allclose(log.probs[others], expect, rtol=1e-9)  # Eq. 3


@given(m=st.integers(2, 16),
       seq=st.lists(st.tuples(st.integers(0, 15), st.floats(0.1, 50.0)),
                    min_size=1, max_size=30))
def test_host_and_jax_twins_agree(m, seq):
    cfg = LogConfig(n_servers=m, lam=24.0)
    host = HostStatLog(cfg)
    state = statlog.init_state(cfg)
    for srv, ln in seq:
        srv = srv % m
        host.apply_assignment(srv, ln)
        state = statlog.apply_assignment(state, jnp.asarray(srv),
                                         jnp.asarray(ln, jnp.float32), cfg)
    np.testing.assert_allclose(np.asarray(state.loads), host.loads,
                               rtol=2e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(state.probs), host.probs,
                               rtol=2e-4, atol=1e-6)


def test_heavier_server_has_lower_prob():
    """The exponential weighting orders probabilities by load (§3.3.2)."""
    cfg = LogConfig(n_servers=4, lam=10.0)
    log = HostStatLog(cfg)
    log.apply_assignment(0, 50.0)
    log.apply_assignment(1, 5.0)
    assert log.probs[0] < log.probs[1] < log.probs[2]
    assert log.probs[2] == pytest.approx(log.probs[3])


def test_ewma_observation():
    cfg = LogConfig(n_servers=3, ewma_alpha=0.5)
    log = HostStatLog(cfg)
    log.observe_completion(1, 100.0)
    assert log.ewma_lat[1] == 100.0     # first observation seeds
    log.observe_completion(1, 50.0)
    assert log.ewma_lat[1] == pytest.approx(75.0)


def test_complete_drains_load():
    log = HostStatLog(LogConfig(n_servers=2))
    log.apply_assignment(0, 10.0)
    log.complete(0, 4.0)
    assert log.loads[0] == pytest.approx(6.0)
    log.complete(0, 100.0)  # never negative
    assert log.loads[0] == 0.0


def test_renormalize_fixes_drift():
    log = HostStatLog(LogConfig(n_servers=5))
    log.probs = log.probs * 1.1
    log.renormalize()
    assert abs(log.probs.sum() - 1.0) < 1e-12


def test_request_log_records_fig8_rows():
    """The I/O request table keeps (object, offset, length) rows (Fig. 8)."""
    log = HostStatLog(LogConfig(n_servers=2))
    log.record_request(12, 4096, 2.0)
    log.record_request(99, 0, 0.5)
    assert log.request_log == [(12, 4096, 2.0), (99, 0, 0.5)]


# ---------------------------------------------------------------------------
# Packed log tensor + stale-view (est_rates) contract — DESIGN.md §8
# ---------------------------------------------------------------------------


def test_packed_table_rows_are_views():
    """HostStatLog rows alias the (4, M) table: in-place edits land in it,
    and SchedState shares the identical layout."""
    log = HostStatLog(LogConfig(n_servers=4))
    log.loads[2] = 7.5
    assert log.table[policy_core.ROW_LOADS, 2] == 7.5
    assert log.table.shape == (policy_core.N_ROWS, 4)
    state = statlog.init_state(LogConfig(n_servers=4))
    assert state.log.shape == (policy_core.N_ROWS, 4)
    np.testing.assert_array_equal(np.asarray(state.probs),
                                  np.full(4, 0.25, np.float32))
    np.testing.assert_array_equal(np.asarray(state.est_rates),
                                  np.ones(4, np.float32))


def _apply_ops(state, host, seq, cfg):
    """Replay an op sequence on both twins; returns the jax state."""
    m = cfg.n_servers
    for kind, srv, val in seq:
        srv = srv % m
        if kind == 0:
            state = statlog.apply_assignment(state, jnp.asarray(srv),
                                             jnp.asarray(val, jnp.float32),
                                             cfg)
            host.apply_assignment(srv, val)
        elif kind == 1:
            state = statlog.observe_completion(state, jnp.asarray(srv),
                                               jnp.asarray(val, jnp.float32),
                                               cfg)
            host.observe_completion(srv, val)
        else:
            state = statlog.advance_time(state, jnp.asarray(val / 100.0,
                                                            jnp.float32))
            host.advance_time(val / 100.0)
    return state


@given(m=st.integers(2, 16),
       seq=st.lists(st.tuples(st.integers(0, 2), st.integers(0, 15),
                              st.floats(0.1, 50.0)),
                    min_size=1, max_size=30))
def test_est_rates_is_pure_function_of_observations(m, seq):
    """Stale-view invariant: after ANY op sequence, est_rates ==
    ect_rates(ewma_lat) on both twins — it never reads the true rates."""
    cfg = LogConfig(n_servers=m, lam=24.0)
    host = HostStatLog(cfg)
    state = _apply_ops(statlog.init_state(cfg), host, seq, cfg)
    np.testing.assert_array_equal(
        np.asarray(state.est_rates),
        np.asarray(policy_core.ect_rates(state.ewma_lat)))
    np.testing.assert_array_equal(host.est_rates,
                                  policy_core.ect_rates(host.ewma_lat,
                                                        xp=np))


def test_est_rates_never_reads_true_rates():
    """Same observation stream under WILDLY different true rates must
    produce the identical est_rates row (the client's view is built from
    completions only; `SchedState.rates` is simulator ground truth)."""
    cfg = LogConfig(n_servers=5)
    seq = [(0, 1, 10.0), (1, 1, 80.0), (0, 3, 4.0), (2, 0, 30.0),
           (1, 3, 15.0), (2, 0, 10.0), (1, 1, 60.0)]
    outs = []
    for rates in (np.ones(5), np.asarray([1e-3, 500.0, 7.0, 1e4, 0.5])):
        host = HostStatLog(cfg)
        host.set_rates(rates)
        state = statlog.init_state(cfg, rates=jnp.asarray(rates))
        state = _apply_ops(state, host, seq, cfg)
        outs.append((np.asarray(state.est_rates), host.est_rates.copy(),
                     np.asarray(state.loads)))
    np.testing.assert_array_equal(outs[0][0], outs[1][0])   # jax est
    np.testing.assert_array_equal(outs[0][1], outs[1][1])   # host est
    # sanity: the TRUE-rate-driven drain DID differ (rates are consumed
    # by queue physics, just never by the estimate)
    assert not np.array_equal(outs[0][2], outs[1][2])
