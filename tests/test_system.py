"""End-to-end behaviour tests for the paper's system (top-level claims)."""

import jax
import numpy as np

from repro.core import analysis, simulate
from repro.core.simulate import SimConfig


def test_paper_eval_pipeline_end_to_end():
    """run_paper_eval produces every policy's TrialResult with the headline
    ordering: straggler-aware policies balance better than RR and issue
    zero probe messages."""
    cfg = SimConfig(n_servers=30, n_requests=300, n_trials=4)
    out = simulate.run_paper_eval(seed=0, cfg=cfg,
                                  policy_names=("rr", "mlml", "trh", "nltr",
                                                "two_choice"),
                                  nltr_ns=(1, 2))
    assert set(out) == {"rr", "mlml", "trh", "1ltr", "2ltr", "two_choice"}
    stats = {k: analysis.load_balance_stats(v.server_loads)
             for k, v in out.items()}
    for pol in ("mlml", "trh", "1ltr", "2ltr"):
        assert stats[pol]["cv"] < stats["rr"]["cv"], pol
        assert int(np.asarray(out[pol].probe_msgs).max()) == 0
    probes = analysis.probe_overhead(out, cfg.n_requests)
    assert probes["two_choice"] > 0
    assert probes["trh"] == 0.0


def test_kernel_and_engine_agree_on_minload_semantics():
    """The Pallas sched_select kernel and the JAX engine express the same
    scheduling math (greedy min-load == ect policy with unit rates)."""
    import jax.numpy as jnp
    from repro.core import engine, statlog
    from repro.core.engine import Workload
    from repro.core.policies import PolicyConfig
    from repro.core.statlog import LogConfig
    from repro.kernels.sched_select import sched_select

    m, n = 12, 40
    rng = np.random.default_rng(0)
    objs = rng.integers(0, 500, n)
    lens = rng.uniform(1, 20, n).astype(np.float32)
    init = rng.uniform(0, 30, m).astype(np.float32)

    cfg = LogConfig(n_servers=m, lam=32.0)
    state = statlog.init_state(cfg, jnp.asarray(init))
    work = Workload(jnp.asarray(objs, jnp.int32), jnp.asarray(lens),
                    jnp.ones((n,), bool))
    res = engine.run_window(state, work, jax.random.key(0),
                            policy=PolicyConfig(name="ect", threshold=2.0),
                            log_cfg=cfg, group_steps=False)

    ch, _ = sched_select(jnp.asarray(objs, jnp.int32)[None],
                         jnp.asarray(lens)[None],
                         jnp.asarray(init)[None],
                         jnp.zeros((1,), jnp.uint32),
                         n_servers=m, threshold=2.0, policy="minload")
    np.testing.assert_array_equal(np.asarray(res.chosen),
                                  np.asarray(ch[0]))
