"""Integration: train loop + checkpoint/restart + straggler recovery."""

import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.checkpoint.manifest as M
from repro.checkpoint import CheckpointConfig, Checkpointer
from repro.core.policies import PolicyConfig
from repro.data import DataConfig, SyntheticTokens
from repro.io import IOClientConfig
from repro.io.striping import MB
from repro.models.config import ModelConfig
from repro.train import OptConfig, init_state, make_train_step

CFG = ModelConfig(name="itiny", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab_size=256)
OPT = OptConfig(peak_lr=5e-3, warmup_steps=5, total_steps=60)


def _pipe():
    return SyntheticTokens(DataConfig(vocab_size=CFG.vocab_size, seq_len=32,
                                      global_batch=8, seed=1))


def test_loss_decreases():
    state = init_state(jax.random.key(0), CFG)
    step = jax.jit(make_train_step(CFG, OPT))
    pipe = _pipe()
    first = last = None
    for i in range(25):
        state, m = step(state, pipe.batch_at(i))
        if i == 0:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first - 0.2, (first, last)


def test_checkpoint_restart_bitwise_resume():
    """Kill at step 10, restore, continue -> identical to uninterrupted."""
    pipe = _pipe()
    step = jax.jit(make_train_step(CFG, OPT))

    def run(n, state=None, start=0):
        state = state or init_state(jax.random.key(0), CFG)
        for i in range(start, n):
            state, _ = step(state, pipe.batch_at(i))
        return state

    ref = run(20)

    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, n_servers=4, cfg=CheckpointConfig(
            shard_size_mb=0.5,
            io=IOClientConfig(policy=PolicyConfig(name="trh",
                                                  threshold=0.1),
                              stripe_size=MB // 4)))
        state = run(10)
        ck.save(10, state)
        del state
        template = jax.tree.map(np.zeros_like,
                                init_state(jax.random.key(0), CFG))
        restored = ck.restore(target=template)
        resumed = run(20, state=restored, start=10)

    for (p1, a), (p2, b) in zip(M.flatten_with_paths(ref.params),
                                M.flatten_with_paths(resumed.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6, err_msg=p1)


def test_training_through_straggler_and_failure():
    """Checkpoint every few steps against a store with a straggler AND a
    failing server; training must complete and the last save restore."""
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, n_servers=5, cfg=CheckpointConfig(
            shard_size_mb=0.25, async_save=True,
            io=IOClientConfig(policy=PolicyConfig(name="ect",
                                                  threshold=0.05),
                              stripe_size=MB // 4)))
        ck.store.set_write_delay(2, 0.01)   # straggler
        ck.store.fail_server(4)             # dead server
        state = init_state(jax.random.key(0), CFG)
        step = jax.jit(make_train_step(CFG, OPT))
        pipe = _pipe()
        for i in range(12):
            state, _ = step(state, pipe.batch_at(i))
            if (i + 1) % 4 == 0:
                ck.save(i + 1, state, block=False)
        ck.wait_until_finished()
        assert ck.latest_step() == 12
        template = jax.tree.map(np.zeros_like,
                                init_state(jax.random.key(0), CFG))
        back = ck.restore(target=template)
        assert int(np.asarray(back.step)) == 12
        stats = ck.client.stats()
        assert stats["probe_messages"] == 0  # log-assisted: no probes
        ck.close()


def test_eval_ppl_runs():
    from repro.train.steps import eval_ppl
    state = init_state(jax.random.key(0), CFG)
    pipe = _pipe()
    ppl = eval_ppl(state.params, [pipe.batch_at(i) for i in range(2)], CFG)
    assert np.isfinite(ppl)
